"""End-to-end training driver (deliverable b).

Default preset trains a reduced llama-family model on this CPU container for
a few hundred steps with checkpointing, straggler watchdog, and bit-exact
resume.  ``--preset 100m`` selects a ~100M-parameter configuration for real
hardware (the same code path the dry-run lowers onto the 256/512-chip mesh).

Run:  PYTHONPATH=src python examples/train_lm.py                 # CPU, ~2 min
      PYTHONPATH=src python examples/train_lm.py --preset 100m   # accelerator
"""

import argparse
import dataclasses
import sys

import jax

from repro.configs import base as cb
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.train import fault_tolerance as ft
from repro.train import loop as train_loop


def preset_cpu():
    cfg = dataclasses.replace(cb.smoke("llama3.2-1b"), n_layers=4, d_model=256,
                              d_ff=512, n_heads=8, n_kv_heads=4, vocab_size=2048)
    return cfg, dict(steps=300, global_batch=8, seq_len=128)


def preset_100m():
    # ~100M params: 12L x d768 x ff3072, 32k vocab
    cfg = dataclasses.replace(
        cb.get("llama3.2-1b"), n_layers=12, d_model=768, d_ff=3072,
        n_heads=12, n_kv_heads=4, head_dim=64, vocab_size=32768,
        tied_embeddings=True, remat=False,
    )
    return cfg, dict(steps=300, global_batch=64, seq_len=1024)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["cpu", "100m"], default="cpu")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args(argv)
    cfg, run_args = preset_cpu() if args.preset == "cpu" else preset_100m()

    tcfg = train_loop.TrainConfig(
        lr=3e-3, warmup=20, total_steps=run_args["steps"], log_every=20,
        checkpoint_every=100,
    )
    pipe = TokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=run_args["seq_len"],
        global_batch=run_args["global_batch"], seed=0))
    mgr = ft.CheckpointManager(args.ckpt_dir)
    wd = ft.StragglerWatchdog()

    def log(step, m):
        print(f"step {step:4d}  loss {m['loss']:.4f}  wall {m['wall_s']:.2f}s")

    print(f"preset={args.preset}  devices={len(jax.devices())}  "
          f"params~{_count(cfg)/1e6:.1f}M")
    state, hist = train_loop.run(cfg, tcfg, pipe, ckpt_manager=mgr,
                                 watchdog=wd, hooks=[log])
    mgr.wait()
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"(resumable from {args.ckpt_dir})")


def _count(cfg):
    from repro.models import lm, params as pm
    return pm.param_count(lm.model_specs(cfg))


if __name__ == "__main__":
    main(sys.argv[1:])

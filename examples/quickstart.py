"""Quickstart: the whole paper in one script.

1. Train the 768:256:256:256:10 BNN (sign activations, per-neuron biases).
2. Convert it losslessly to a binary-SNN with per-neuron thresholds ([15]).
3. Run event-driven cycle-accurate inference through the multiport arbiter.
4. Report the system-level operating point for every SRAM cell option and
   check the paper's headline claims (3.1x speed / 2.2x energy, Table 3 row).

Inference runs through *execution plans* (``EsamNetwork.plan``): each plan
is compiled once for a (mode, collect, telemetry) tuple and reused for every
batch — the functional plan below returns logits, hidden spike planes, and
the cost model's arbiter loads in ONE pass.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.esam import bnn, conversion, cost_model as cm
from repro.core.esam.network import reference_activity, system_stats
from repro.data import digits


def main():
    print("== 1. train BNN (synthetic digits; MNIST is offline-unavailable) ==")
    x, y = digits.make_spike_dataset(2048, seed=0)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    params, acc = bnn.fit(jax.random.PRNGKey(0), cm.PAPER_TOPOLOGY, xj, yj,
                          steps=200, batch=128)
    print(f"   BNN train accuracy: {acc*100:.1f}%")

    print("== 2. lossless BNN -> binary-SNN conversion ==")
    net = conversion.bnn_to_snn(params)
    # one compiled functional plan: logits + arbiter loads in a single pass
    fn_plan = net.plan(mode="functional", telemetry=True)
    res = fn_plan(xj.astype(bool))
    snn_acc = float((res.logits.argmax(-1) == yj).mean())
    print(f"   SNN accuracy: {snn_acc*100:.1f}%  topology={net.topology}")

    print("== 2b. packed fused plan (uint32 bitplanes between tiles) ==")
    packed_plan = net.plan()          # mode="packed" is the default
    logits_fused = packed_plan(xj[:256].astype(bool)).logits
    same = bool(jnp.array_equal(logits_fused, res.logits[:256]))
    print(f"   packed plan == functional plan on 256 samples: {same}")

    print("== 3. event-driven (cycle-accurate) plan, 4 ports ==")
    cycle_plan = net.plan(mode="cycle", read_ports=4)
    sample = cycle_plan(jnp.asarray(x[0]).astype(bool))
    cycles = [int(t.cycles) for t in sample.traces]
    print(f"   predicted class: {int(sample.logits.argmax())} (label {int(y[0])})")
    print(f"   cycles per tile until R_empty: {cycles}")

    print("== 4. system-level operating points (Fig 8 / Table 3) ==")
    # the telemetry loads collected in step 2 ARE the measured activity —
    # no tile matmul is re-run
    counts = [np.asarray(c[:256], np.float64) for c in res.loads]
    for ports in range(5):
        s = system_stats(cm.PAPER_TOPOLOGY, counts, ports)
        print(f"   {s.cell:7s}: {s.throughput_inf_s/1e6:6.2f} MInf/s  "
              f"{s.energy_pj_per_inf:7.1f} pJ/Inf  {s.power_mw:5.1f} mW")
    ref = reference_activity()
    s0, s4 = system_stats(cm.PAPER_TOPOLOGY, ref, 0), system_stats(cm.PAPER_TOPOLOGY, ref, 4)
    print(f"   headline (ref profile): speedup "
          f"{s4.throughput_inf_s/s0.throughput_inf_s:.2f}x (paper 3.1x), "
          f"energy-eff {s0.energy_pj_per_inf/s4.energy_pj_per_inf:.2f}x (paper 2.2x)")


if __name__ == "__main__":
    main()

"""Online learning via the transposable port (Sec 4.4.1 + [16]).

A deployed SNN with a random readout adapts on-device through supervised
stochastic STDP.  The epochs run on the fused column-event plane
(`train/online.py`): the frozen prefix is computed once on the packed
datapath, the readout stays transposed-resident across epochs, and every
weight update is a column access through the transposed port — the script
accounts its hardware cost for both the 1RW baseline and the 1RW+4R cell
(the 26.0x / 19.5x claim, end to end).

Run:  PYTHONPATH=src python examples/online_learning.py
"""

import jax
import jax.numpy as jnp

from repro.core.esam import learning
from repro.core.esam.network import EsamNetwork
from repro.data import digits
from repro.train import online as online_train


def main():
    x, y = digits.make_spike_dataset(768, seed=3)
    x, y = jnp.asarray(x).astype(bool), jnp.asarray(y)
    bits = jax.random.bernoulli(jax.random.PRNGKey(0), 0.5, (768, 10)).astype(jnp.int8)
    net = EsamNetwork(
        weight_bits=[bits],
        vth=[jnp.full((10,), 2**31 - 1, jnp.int32)],
        out_offset=jnp.zeros((10,)),
    )

    c4 = learning.column_update_cost(4)
    c0 = learning.column_update_cost(0)
    print(f"column update: 1RW read {c0.read_ns:.1f}ns/write {c0.write_ns:.1f}ns | "
          f"4R transposed read {c4.read_ns}ns ({c4.speedup_read_vs_1rw:.1f}x) "
          f"write {c4.write_ns}ns ({c4.speedup_write_vs_1rw:.1f}x)")

    acc0 = float((jnp.argmax(net.plan(mode="functional")(x).logits, -1) == y).mean())
    res = online_train.train_online(
        net, x, y, epochs=6, key=jax.random.PRNGKey(10), p_pot=0.2, p_dep=0.1)

    print("epoch  accuracy  col-updates  t_4R(us)  t_1RW(us)  E_4R(nJ)  E_1RW(nJ)")
    print(f"  --   {acc0 * 100:7.1f}%")
    for epoch, (acc, n) in enumerate(zip(res.accuracy, res.n_updates)):
        t4 = n * (c4.read_ns + c4.write_ns) * 1e-3
        t0 = n * (c0.read_ns + c0.write_ns) * 1e-3
        e4 = n * c4.energy_pj * 1e-3
        e0 = n * c0.energy_pj * 1e-3
        print(f"  {epoch:2d}   {acc * 100:7.1f}%  {n:10d}  {t4:8.1f}  {t0:9.1f}"
              f"  {e4:8.2f}  {e0:8.1f}")
    print(f"total column updates: {sum(res.n_updates)}")


if __name__ == "__main__":
    main()

"""Online learning via the transposable port (Sec 4.4.1 + [16]).

A deployed SNN with a random readout adapts on-device through supervised
stochastic STDP; every weight update is a column access through the
transposed port, and the script accounts its hardware cost for both the 1RW
baseline and the 1RW+4R cell (the 26.0x / 19.5x claim, end to end).

Run:  PYTHONPATH=src python examples/online_learning.py
"""

import jax
import jax.numpy as jnp

from repro.core.esam import learning, tile
from repro.data import digits


def main():
    x, y = digits.make_spike_dataset(768, seed=3)
    x, y = jnp.asarray(x).astype(bool), jnp.asarray(y)
    bits = jax.random.bernoulli(jax.random.PRNGKey(0), 0.5, (768, 10)).astype(jnp.int8)
    vth = [jnp.full((10,), 2**31 - 1, jnp.int32)]

    def acc(b):
        _, vmem = tile.functional_tile(b, x, vth[0])
        return float((vmem.argmax(-1) == y).mean())

    c4 = learning.column_update_cost(4)
    c0 = learning.column_update_cost(0)
    print(f"column update: 1RW read {c0.read_ns:.1f}ns/write {c0.write_ns:.1f}ns | "
          f"4R transposed read {c4.read_ns}ns ({c4.speedup_read_vs_1rw:.1f}x) "
          f"write {c4.write_ns}ns ({c4.speedup_write_vs_1rw:.1f}x)")
    print(f"epoch  accuracy  col-updates  t_4R(us)  t_1RW(us)  E_4R(nJ)  E_1RW(nJ)")
    total = 0
    print(f"  --   {acc(bits)*100:7.1f}%")
    for epoch in range(6):
        bits, n = learning.online_learning_epoch(
            [bits], vth, x, y, jax.random.PRNGKey(10 + epoch), p_pot=0.2, p_dep=0.1)
        total += n
        t4 = n * (c4.read_ns + c4.write_ns) * 1e-3
        t0 = n * (c0.read_ns + c0.write_ns) * 1e-3
        e4 = n * c4.energy_pj * 1e-3
        e0 = n * c0.energy_pj * 1e-3
        print(f"  {epoch:2d}   {acc(bits)*100:7.1f}%  {n:10d}  {t4:8.1f}  {t0:9.1f}"
              f"  {e4:8.2f}  {e0:8.1f}")
    print(f"total column updates: {total}")


if __name__ == "__main__":
    main()

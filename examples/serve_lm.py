"""Batched serving example: continuous-batching engine over the unified LM.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import numpy as np

from repro.configs import base as cb
from repro.models import lm, params as pm
from repro.serve.engine import Engine, Request


def main():
    cfg = cb.smoke("llama3.2-1b")
    params = pm.init(lm.model_specs(cfg), jax.random.PRNGKey(0))
    eng = Engine(params, cfg, batch_size=4)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=n).astype(np.int32),
                    max_new_tokens=12)
            for n in (5, 9, 7, 4, 11, 6)]
    out = eng.serve(reqs)
    for i, r in enumerate(out):
        print(f"req {i}: prompt len {len(r.prompt):2d} -> {r.output.tolist()}")


if __name__ == "__main__":
    main()

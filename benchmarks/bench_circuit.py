"""Paper Fig 6 + Fig 7: circuit-level access time/energy vs cell option.

This is the calibrated-constants plane (DESIGN.md §2a): the bench emits the
cost-model tables, verifies the paper's stated circuit-level relationships
hold in the model (Vprech saving >=43%, per-port energy minimum before the
4th port, write costs growing with ports), and records the rows to
``BENCH_circuit.json`` (override with env BENCH_CIRCUIT_OUT) so the
calibration trajectory is tracked across PRs."""

from __future__ import annotations

import os
import sys

try:
    from benchmarks.common import Recorder
except ModuleNotFoundError:  # direct `python benchmarks/bench_circuit.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))
    from benchmarks.common import Recorder
from repro.core.esam import cost_model as cm


def run():
    rec = Recorder()
    # Fig 6 analogue: transposed-port write/read energy+time per cell option
    for p in range(5):
        spec = cm.cell_spec(p)
        rec.emit(
            f"fig6_cell_{spec.name}",
            0.0,
            f"tread_pj={spec.e_tread_pj:.3f};twrite_pj={spec.e_write_pj:.3f};"
            f"clock_ns={spec.clock_ns:.2f}",
        )
    # Fig 7 analogue: per-port inference read energy at Vprech=500mV
    for p in range(1, 5):
        spec = cm.cell_spec(p)
        drain = -(-128 // spec.ports)
        access_ns = drain * spec.clock_ns
        rec.emit(
            f"fig7_ports_{p}",
            0.0,
            f"read_pj_per_access={spec.e_read_pj:.3f};"
            f"array_drain_ns={access_ns:.1f}",
        )
    # paper-stated relationships
    assert cm.E_READ_PORT_PJ[0] < cm.E_READ_1RW_PJ * (1 - cm.VPRECH_ENERGY_SAVING) + 0.02
    assert cm.E_READ_PORT_PJ[3] > cm.E_READ_PORT_PJ[2]      # 4th port turns upward
    assert all(a < b for a, b in zip(cm.E_WRITE_PORT_PJ, cm.E_WRITE_PORT_PJ[1:]))
    rec.emit("fig7_vprech_saving", 0.0,
             f"saving>=43%:ok;time_penalty<=19%:{cm.VPRECH_TIME_PENALTY <= 0.19}")
    rec.write_json(os.environ.get("BENCH_CIRCUIT_OUT", "BENCH_circuit.json"))


if __name__ == "__main__":
    run()

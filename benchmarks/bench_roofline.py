"""Roofline table (deliverable g): reads the dry-run JSON cache and emits per
(arch x shape x mesh): the three roofline terms, the dominant bottleneck, and
MODEL_FLOPS/HLO_FLOPs.  Recorded to ``BENCH_roofline.json`` (override with
env BENCH_ROOFLINE_OUT) like the other benches."""

from __future__ import annotations

import glob
import json
import os
import sys

try:
    from benchmarks.common import Recorder
except ModuleNotFoundError:  # direct `python benchmarks/bench_roofline.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))
    from benchmarks.common import Recorder

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells() -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run():
    rec = Recorder()
    cells = load_cells()
    if not cells:
        rec.emit("roofline", 0.0,
                 "NO_DRYRUN_CACHE(run python -m repro.launch.dryrun)")
    for c in cells:
        r = c["roofline"]
        frac = c.get("useful_flops_frac")
        frac_s = f"{frac:.3f}" if frac is not None else "n/a"
        rec.emit(
            f"roofline_{c['key']}",
            0.0,
            f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
            f"collective_s={r['collective_s']:.3e};bottleneck={c['bottleneck']};"
            f"useful_flops_frac={frac_s}",
        )
    if cells:
        n_bad = sum(1 for c in cells if c["bottleneck"] != "compute_s")
        rec.emit("roofline_summary", 0.0,
                 f"cells={len(cells)};non_compute_bound={n_bad}")
    rec.write_json(os.environ.get("BENCH_ROOFLINE_OUT", "BENCH_roofline.json"))


if __name__ == "__main__":
    run()

"""Roofline table (deliverable g): reads the dry-run JSON cache and emits per
(arch x shape x mesh): the three roofline terms, the dominant bottleneck, and
MODEL_FLOPS/HLO_FLOPs."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells() -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run():
    cells = load_cells()
    if not cells:
        emit("roofline", 0.0, "NO_DRYRUN_CACHE(run python -m repro.launch.dryrun)")
        return
    for c in cells:
        r = c["roofline"]
        frac = c.get("useful_flops_frac")
        emit(
            f"roofline_{c['key']}",
            0.0,
            f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
            f"collective_s={r['collective_s']:.3e};bottleneck={c['bottleneck']};"
            f"useful_flops_frac={frac:.3f};" if frac else "useful_flops_frac=n/a;"
        )
    n_bad = sum(1 for c in cells if c["bottleneck"] != "compute_s")
    emit("roofline_summary", 0.0,
         f"cells={len(cells)};non_compute_bound={n_bad}")


if __name__ == "__main__":
    run()

"""Beyond-paper ablation: ESAM-mode (SpikingLinear) FFN inside a tiny LM.

Trains two 2-layer LMs on the synthetic token task — one with a dense FFN,
one with the binary event-driven FFN + top-p arbitration — and reports the
quality gap, the measured event rate, and what that activity would cost on
the ESAM 4R tile per the calibrated cost model (cycles = ceil(events/ports)).
This quantifies where the paper's mechanism could slot into an LM stack and
what it would save/cost.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import spiking
from repro.core.esam import cost_model as cm
from repro.models.params import ParamSpec
import repro.models.params as pm

VOCAB, D, FF, S, B = 256, 64, 128, 32, 16
PORTS = 32  # top-p arbiter limit (per-token event budget)


def _specs(mode: str) -> dict:
    s = {
        # O(1) embeddings: the binary path spikes on sign(x) (scale-free), the
        # dense path needs unit-scale activations for comparable optimization
        "embed": ParamSpec((VOCAB, D), (None, None), init="scaled", scale=0.5,
                           dtype=jnp.float32),
        "w_attn": ParamSpec((D, D), (None, None), dtype=jnp.float32),
        "ln": ParamSpec((D,), (None,), init="ones", dtype=jnp.float32),
    }
    if mode == "dense":
        s["ffn_up"] = ParamSpec((D, FF), (None, None), dtype=jnp.float32)
    else:
        s.update({f"ffn_{k}": v for k, v in spiking.spiking_linear_specs(D, FF).items()})
    s["ffn_down"] = ParamSpec((FF, D), (None, None), dtype=jnp.float32)
    s["unembed"] = ParamSpec((D, VOCAB), (None, None), dtype=jnp.float32)
    return s


def _forward(params, tokens, mode):
    x = params["embed"][tokens]
    # single mixing layer (cumulative mean attention proxy keeps this tiny)
    ctx = jnp.cumsum(x, axis=1) / (jnp.arange(x.shape[1])[None, :, None] + 1)
    x = x + ctx @ params["w_attn"]
    xn = x * params["ln"]
    if mode == "dense":
        h = jax.nn.gelu(xn @ params["ffn_up"])
        rate = jnp.zeros(())
    else:
        h = spiking.spiking_linear(
            {"w": params["ffn_w"], "b": params["ffn_b"]}, xn, ports=PORTS)
        rate = spiking.event_rate(xn, ports=PORTS)
    x = x + h @ params["ffn_down"]
    return x @ params["unembed"], rate


def _train(mode: str, steps: int = 250):
    key = jax.random.PRNGKey(0)
    params = pm.init(_specs(mode), key)
    rng = np.random.default_rng(0)
    # token task with copy structure (predictable from context)
    base = rng.integers(0, VOCAB, size=(B, S + 1))
    base[:, S // 2:] = base[:, : S + 1 - S // 2]

    def loss_fn(p, toks):
        logits, rate = _forward(p, toks[:, :-1], mode)
        lp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(lp, toks[:, 1:, None], axis=2).mean()
        return nll, rate

    @jax.jit
    def step(p, toks):
        (l, rate), g = jax.value_and_grad(loss_fn, has_aux=True)(p, toks)
        p = jax.tree.map(lambda a, b: a - 0.05 * b, p, g)
        return p, l, rate

    toks = jnp.asarray(base)
    l = rate = None
    for _ in range(steps):
        params, l, rate = step(params, toks)
    return float(l), float(rate)


def run():
    # warmup=0: whole multi-step training runs (too expensive to run twice;
    # compile amortizes across the steps).
    us_d, (loss_dense, _) = time_call(lambda: _train("dense"), repeats=1, warmup=0)
    us_s, (loss_spike, rate) = time_call(lambda: _train("spiking"), repeats=1, warmup=0)
    # ESAM hardware cost of the measured activity for one token's FFN MAC:
    # events = rate * D rows; a 4R tile drains them in ceil(events/4) cycles.
    events = rate * D
    spec = cm.cell_spec(4)
    cycles = float(np.ceil(events / spec.ports))
    t_ns = cycles * spec.clock_ns
    e_pj = events * spec.e_read_pj * (FF // 128 + 1)
    emit("spiking_lm_dense", us_d,
         f"final_loss={loss_dense:.3f}(single-batch memorization task)")
    emit("spiking_lm_esam_ffn", us_s,
         f"final_loss={loss_spike:.3f};event_rate={rate:.3f};ports={PORTS};"
         f"esam4R_cycles_per_token={cycles:.0f};t_ns={t_ns:.1f};e_pj={e_pj:.2f};"
         f"note=binary FFN trains through STE and its activity maps onto the "
         f"4R tile at ~{cycles:.0f} cycles/token")


if __name__ == "__main__":
    run()

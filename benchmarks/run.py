"""Benchmark harness entry point — one section per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows.  Mapping to the paper:
  bench_circuit          Fig 6 + Fig 7   (cell-level time/energy)
  bench_timing           Table 2         (pipeline stages / clock)
  bench_online_learning  Sec 4.4.1       (26.0x / 19.5x column access)
  bench_system           Fig 8           (port sweep; 3.1x / 2.2x headline)
  bench_comparison       Table 3         (44 MInf/s, 607 pJ/Inf, 29 mW)
  bench_accuracy         Sec 4.4.2       (BNN->SNN conversion, V3)
  bench_kernels          (TPU plane)     Pallas kernel timings, interpret +
                                          compiled lanes; popcount-domain MAC
                                          and mega-kernel cascade vs the
                                          packed-MXU plane (bit-identity and
                                          speedup-floor gated)
  bench_temporal         (temporal plane) fused LIF scan vs naive loop,
                                          event-stream rates, encoders
  bench_faults           (robustness)    accuracy vs fault rate, spare-column
                                          remap, STDP repair, energy
  bench_roofline         (framework)     dry-run roofline per arch x shape
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_accuracy,
        bench_circuit,
        bench_comparison,
        bench_faults,
        bench_kernels,
        bench_online_learning,
        bench_roofline,
        bench_spiking_lm,
        bench_system,
        bench_temporal,
        bench_timing,
    )

    print("name,us_per_call,derived")
    failures = 0
    for mod in (bench_circuit, bench_timing, bench_online_learning, bench_system,
                bench_comparison, bench_accuracy, bench_kernels, bench_temporal,
                bench_faults, bench_spiking_lm, bench_roofline):
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__},0.0,FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Paper Sec 4.4.2 (V3): BNN training + lossless BNN->SNN conversion.
The conversion-exactness is the actual claim of [15]; absolute accuracy is on
the synthetic digit set (no MNIST offline — DESIGN.md §8)."""

from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import emit, time_call
except ModuleNotFoundError:  # direct `python benchmarks/bench_accuracy.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))
    from benchmarks.common import emit, time_call
from repro.core.esam import bnn, conversion, cost_model as cm
from repro.data import digits


def run():
    x, y = digits.make_spike_dataset(4096, seed=0)
    x_train, y_train = jnp.asarray(x[:3072]), jnp.asarray(y[:3072])
    x_test, y_test = jnp.asarray(x[3072:]), jnp.asarray(y[3072:])

    # warmup=0: a 250-step training run is too expensive to execute twice and
    # amortizes its own compile; everything cheaper uses the warmed default.
    us, (params, train_acc) = time_call(
        lambda: bnn.fit(jax.random.PRNGKey(0), cm.PAPER_TOPOLOGY,
                        x_train, y_train, steps=250, batch=128),
        repeats=1, warmup=0)
    net = conversion.bnn_to_snn(params)
    bnn_pred = bnn.forward(params, x_test).argmax(-1)
    snn_pred = net.plan(mode="functional")(x_test.astype(bool)).logits.argmax(-1)
    bnn_acc = float((bnn_pred == y_test).mean())
    snn_acc = float((snn_pred == y_test).mean())
    mismatch = int((bnn_pred != snn_pred).sum())
    emit("accuracy_bnn_to_snn", us,
         f"bnn_test_acc={bnn_acc*100:.2f};snn_test_acc={snn_acc*100:.2f};"
         f"pred_mismatches={mismatch}(conversion exact iff 0);"
         f"paper_mnist_acc=97.64")


if __name__ == "__main__":
    run()

"""Fault-injection & mitigation plane: accuracy-vs-fault-rate sweeps, spare
column remapping, online STDP repair, energy-vs-mitigation -> BENCH_faults.json.

Four sections (env ``BENCH_FAULTS_SMOKE=1`` shrinks every knob for CI):

  fault_sweep_<type>_<mode>   accuracy of a trained BNN->SNN network vs
                              injected fault rate, one row per fault type
                              (stuck0 / stuck1 / read_disturb) x plan mode
                              (functional / packed).  Every faulted executable
                              is asserted bit-identical across the two modes
                              at every rate, so the rows differ only in which
                              datapath ran.
  fault_mitigation_remap      dead hidden columns mitigated by remapping the
                              worst columns onto spare columns at plan-build
                              time; accuracy vs spare budget plus the silicon
                              cost (``cm.spare_column_area_um2``).
  fault_repair_stdp           online-learning repair (Sec 4.4.1 plane): the
                              readout re-trains through the transposed column
                              port around dead hidden columns; accuracy
                              recovered per epoch and the column-access
                              energy the repair itself spent.
  fault_energy_vs_mitigation  modeled pJ/inference from measured arbiter
                              loads (packed telemetry) for the clean, the
                              faulted, and the remapped executable.

Override the output path with env BENCH_FAULTS_OUT.
"""

from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import Recorder, time_call
except ModuleNotFoundError:  # direct `python benchmarks/bench_faults.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))
    from benchmarks.common import Recorder, time_call
from repro.core.esam import bnn, conversion, cost_model as cm, learning
from repro.core.esam.faults import FaultModel
from repro.data import digits
from repro.train import online as online_train

SMOKE = os.environ.get("BENCH_FAULTS_SMOKE", "") not in ("", "0")
OUT = os.environ.get("BENCH_FAULTS_OUT", "BENCH_faults.json")
READ_PORTS = 4

# rate -> FaultModel per injected fault population
FAULT_TYPES = {
    "stuck0": lambda r: FaultModel(seed=11, stuck0_rate=r),
    "stuck1": lambda r: FaultModel(seed=11, stuck1_rate=r),
    "read_disturb": lambda r: FaultModel(seed=11, read_disturb=r),
}


def _data_and_net():
    n, steps = (512, 40) if SMOKE else (4096, 250)
    x, y = digits.make_spike_dataset(n, seed=0)
    split = (3 * n) // 4
    x_tr, y_tr = jnp.asarray(x[:split]), jnp.asarray(y[:split])
    x_te, y_te = jnp.asarray(x[split:]), jnp.asarray(y[split:])
    params, _ = bnn.fit(jax.random.PRNGKey(0), cm.PAPER_TOPOLOGY,
                        x_tr, y_tr, steps=steps, batch=128)
    net = conversion.bnn_to_snn(params)
    return net, (x_tr.astype(bool), y_tr), (x_te.astype(bool), y_te)


def _acc(logits, y) -> float:
    return float((np.asarray(logits).argmax(-1) == np.asarray(y)).mean())


def _bench_fault_sweep(rec: Recorder, net, x_te, y_te) -> None:
    rates = {
        "stuck0": (0.0, 0.05) if SMOKE else (0.0, 0.01, 0.02, 0.05, 0.1),
        "stuck1": (0.0, 0.05) if SMOKE else (0.0, 0.01, 0.02, 0.05, 0.1),
        "read_disturb": (0.0, 5e-3) if SMOKE else (0.0, 1e-3, 3e-3, 1e-2),
    }
    clean = net.plan(mode="functional")(x_te).logits
    for ftype, make in FAULT_TYPES.items():
        accs: dict[str, list[float]] = {"functional": [], "packed": []}
        us = {}
        for r in rates[ftype]:
            fm = make(r) if r else None
            logits = {}
            for mode in ("functional", "packed"):
                plan = net.plan(mode=mode, faults=fm)
                us[mode], logits[mode] = time_call(
                    lambda p=plan: p(x_te).logits, repeats=1)
                accs[mode].append(_acc(logits[mode], y_te))
            # the fault masks live in the plan, not the mode: both datapaths
            # must compile to the same faulted function
            np.testing.assert_array_equal(np.asarray(logits["functional"]),
                                          np.asarray(logits["packed"]))
            if r == 0.0:
                np.testing.assert_array_equal(
                    np.asarray(logits["functional"]), np.asarray(clean))
        for mode in ("functional", "packed"):
            rec.emit(
                f"fault_sweep_{ftype}_{mode}", us[mode],
                f"rates={list(rates[ftype])};"
                f"acc_pct={[round(a * 100, 2) for a in accs[mode]]};"
                f"modes_bit_identical=yes")


def _bench_remap(rec: Recorder, net, x_te, y_te) -> None:
    dead = 0.4
    spares = (0, 96) if SMOKE else (0, 32, 96)
    accs, areas = [], []
    for k in spares:
        fm = FaultModel(seed=5, dead_col_rate=dead, spare_cols=k)
        us, logits = time_call(
            lambda p=net.plan(mode="functional", faults=fm): p(x_te).logits,
            repeats=1)
        accs.append(_acc(logits, y_te))
        areas.append(cm.spare_column_area_um2(net.topology, k, READ_PORTS))
    clean_acc = _acc(net.plan(mode="functional")(x_te).logits, y_te)
    rec.emit(
        "fault_mitigation_remap", us,
        f"dead_col_rate={dead};spare_cols={list(spares)};"
        f"acc_pct={[round(a * 100, 2) for a in accs]};"
        f"clean_acc_pct={clean_acc * 100:.2f};"
        f"spare_area_um2={[round(a, 1) for a in areas]}")
    assert accs[-1] > accs[0] + 0.02, (
        f"remap recovered {accs[-1] - accs[0]:+.3f} accuracy only")


def _bench_repair(rec: Recorder, net, train, x_te, y_te) -> None:
    x_tr, y_tr = train
    epochs = 2 if SMOKE else 4
    fm = FaultModel(seed=5, dead_col_rate=0.4)
    faulted = net.plan(mode="functional", faults=fm)
    acc_fault = _acc(faulted(x_te).logits, y_te)
    us, res = time_call(
        lambda: online_train.train_online(
            net, x_tr, y_tr, epochs=epochs, shuffle=True,
            eval_spikes=x_te, eval_labels=y_te, faults=fm),
        repeats=1, warmup=0)
    cost = learning.column_update_cost(READ_PORTS)
    repair_pj = cost.energy_pj * sum(res.n_updates)
    deployed = _acc(
        res.network.plan(mode="functional", faults=fm)(x_te).logits, y_te)
    assert abs(deployed - res.accuracy[-1]) < 1e-6
    rec.emit(
        "fault_repair_stdp", us,
        f"dead_col_rate={fm.dead_col_rate};epochs={epochs};"
        f"acc_faulted_pct={acc_fault * 100:.2f};"
        f"acc_per_epoch_pct={[round(a * 100, 2) for a in res.accuracy]};"
        f"n_updates={res.n_updates};repair_energy_pj={repair_pj:.0f}")
    assert max(res.accuracy) > acc_fault, (
        f"STDP repair did not recover accuracy: "
        f"{max(res.accuracy):.3f} vs faulted {acc_fault:.3f}")


def _bench_energy(rec: Recorder, net, x_te) -> None:
    dead = 0.4
    configs = {
        "clean": None,
        "faulted": FaultModel(seed=5, dead_col_rate=dead),
        "remapped": FaultModel(seed=5, dead_col_rate=dead, spare_cols=96),
    }
    energy = {}
    for name, fm in configs.items():
        plan = net.plan(mode="packed", telemetry=True, faults=fm)
        us, loads = time_call(lambda p=plan: p(x_te).loads, repeats=1)
        rs = cm.request_stats(
            net.topology, [np.asarray(ld) for ld in loads], READ_PORTS)
        energy[name] = float(rs.energy_pj.mean())
    rec.emit(
        "fault_energy_vs_mitigation", us,
        f"dead_col_rate={dead};"
        + ";".join(f"pj_per_inf_{k}={v:.1f}" for k, v in energy.items()))


def run(rec: Recorder | None = None) -> None:
    own = rec is None
    if own:
        rec = Recorder()
    net, train, (x_te, y_te) = _data_and_net()
    _bench_fault_sweep(rec, net, x_te, y_te)
    _bench_remap(rec, net, x_te, y_te)
    _bench_repair(rec, net, train, x_te, y_te)
    _bench_energy(rec, net, x_te)
    if own:
        rec.write_json(OUT)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()

"""Benchmark helpers: timing + CSV emission (`name,us_per_call,derived`)."""

from __future__ import annotations

import time
from typing import Callable


def time_call(fn: Callable, *args, repeats: int = 3, **kwargs) -> tuple[float, object]:
    """Median wall-time (us) of fn(*args) with jax block_until_ready."""
    import jax

    out = None
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2], out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")

"""Benchmark helpers: timing + CSV emission (`name,us_per_call,derived`) and
an optional JSON recorder so perf trajectories can be tracked across PRs."""

from __future__ import annotations

import json
import time
from typing import Callable


def time_call(
    fn: Callable, *args, repeats: int = 3, warmup: int = 1, **kwargs
) -> tuple[float, object]:
    """Median wall-time (us) of fn(*args) with jax block_until_ready.

    ``warmup`` calls run (and are fully awaited) before the timed ones, so by
    default no benchmark reports first-call compile time.  Pass ``warmup=0``
    only where the timed section is a long multi-step run that would be too
    expensive to execute twice (compile then amortizes inside it).
    """
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    out = None
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2], out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


class Recorder:
    """Collects emitted rows and writes them as a JSON report (BENCH_*.json)."""

    def __init__(self):
        self.entries: list[dict] = []

    def emit(self, name: str, us_per_call: float, derived: str):
        emit(name, us_per_call, derived)
        self.entries.append(
            {"name": name, "us_per_call": round(us_per_call, 1), "derived": derived}
        )

    def write_json(self, path: str):
        with open(path, "w") as f:
            json.dump({"results": self.entries}, f, indent=2)
        print(f"[bench] wrote {path} ({len(self.entries)} entries)")

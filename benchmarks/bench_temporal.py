"""Temporal event plane: fused scan vs naive loop, stream throughput,
encoder accuracy -> BENCH_temporal.json.

Three sections (env ``BENCH_TEMPORAL_SMOKE=1`` shrinks every knob for CI):

  temporal_fused_vs_naive   one jitted membrane-resident ``lax.scan`` vs the
                            naive per-step Python loop (dense tiles, eager
                            op-by-op dispatch, one device round-trip per
                            timestep) on the same event stream.  Three
                            ratios are recorded: the one-shot naive run
                            (``speedup`` — what the naive implementation
                            costs when actually run; the full run at T=32,
                            batch 256 asserts the >=5x floor on it), the
                            warmed eager loop, and the warmed jitted
                            per-step loop (whose logits are bit-identical
                            to the scan).  On this CPU container device ==
                            host, so the per-step state round-trip is a
                            near-free memcpy and the warm ratios understate
                            what the resident scan buys on a real
                            accelerator, where every step of the naive loop
                            crosses the PCIe/ICI boundary twice.
  temporal_stream_T*        event-stream rate (timesteps/s, input spikes/s)
                            and the modeled pJ/timestep from the measured
                            per-step activity, across T in {4, 8, 16, 32}.
  temporal_encoder_*        rate-vs-latency encoder accuracy of a trained
                            BNN->SNN network on the synthetic digit set.

Override the output path with env BENCH_TEMPORAL_OUT.
"""

from __future__ import annotations

import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import Recorder, time_call
except ModuleNotFoundError:  # direct `python benchmarks/bench_temporal.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))
    from benchmarks.common import Recorder, time_call
from repro.core import packing
from repro.core.esam import bnn, conversion, cost_model as cm, temporal
from repro.core.esam.network import EsamNetwork
from repro.data import digits, events

SMOKE = os.environ.get("BENCH_TEMPORAL_SMOKE", "") not in ("", "0")
OUT = os.environ.get("BENCH_TEMPORAL_OUT", "BENCH_temporal.json")
READ_PORTS = 4


def _rand_net(topology, seed: int = 0) -> EsamNetwork:
    key = jax.random.PRNGKey(seed)
    bits = [
        jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topology[i], topology[i + 1])).astype(jnp.int8)
        for i in range(len(topology) - 1)
    ]
    # mildly positive thresholds keep per-step hidden activity in a
    # plausible band (~30-50%) instead of the all-fire regime of vth=0
    vth = [
        jax.random.randint(jax.random.fold_in(key, 100 + i), (n,), 0, 12,
                           jnp.int32)
        for i, n in enumerate(topology[1:])
    ]
    return EsamNetwork(weight_bits=bits, vth=vth,
                       out_offset=jnp.zeros((topology[-1],), jnp.float32))


def _event_stream(n: int, n_steps: int, seed: int = 0):
    ev, _ = events.encode_digit_events(
        n, n_steps, encoder="rate", seed=seed, gain=0.7)
    return ev  # uint8[T, n, 768]


def _bench_fused_vs_naive(rec: Recorder) -> None:
    n_steps, batch = (4, 32) if SMOKE else (32, 256)
    net = _rand_net((768, 256, 10) if SMOKE else cm.PAPER_TOPOLOGY)
    cfg = temporal.TemporalConfig(n_steps=n_steps, leak=0.125)
    ev = _event_stream(batch, n_steps)
    packed = jnp.asarray(packing.pack_spikes_np(ev))

    plan = net.plan(mode="temporal", temporal=cfg)
    fused_us, res = time_call(lambda: plan(packed).logits)
    # oracle: the jitted per-step loop — bit-identical integer datapath
    jitted_us, jitted_logits = time_call(
        lambda: temporal.temporal_forward_naive(net, ev, cfg),
        warmup=1, repeats=1 if SMOKE else 2)
    np.testing.assert_array_equal(np.asarray(res), np.asarray(jitted_logits))
    # headline baseline: the eager op-by-op per-step loop, run once, cold —
    # the cost the naive first implementation actually pays on this stream
    # (unfused float arithmetic -> ulp-level agreement, not bitwise)
    naive_us, naive_logits = time_call(
        lambda: temporal.temporal_forward_naive(net, ev, cfg, jit_step=False),
        warmup=0, repeats=1)
    np.testing.assert_allclose(
        np.asarray(res), np.asarray(naive_logits), rtol=1e-5, atol=1e-3)
    # steady-state eager (per-op caches warm): the conservative ratio
    warm_us, _ = time_call(
        lambda: temporal.temporal_forward_naive(net, ev, cfg, jit_step=False),
        warmup=0, repeats=1 if SMOKE else 2)
    speedup = naive_us / fused_us
    rec.emit(
        "temporal_fused_vs_naive", fused_us,
        f"T={n_steps};batch={batch};naive_one_shot_us={naive_us:.1f};"
        f"speedup={speedup:.1f}x;warm_eager_us={warm_us:.1f};"
        f"speedup_warm_eager={warm_us / fused_us:.1f}x;"
        f"jitted_loop_us={jitted_us:.1f};"
        f"speedup_vs_jitted_loop={jitted_us / fused_us:.1f}x;"
        f"bit_identical_to_jitted_loop=yes;floor=5x")
    if not SMOKE:
        assert speedup >= 5.0, (
            f"fused temporal scan only {speedup:.1f}x over the naive loop")


def _bench_stream_rates(rec: Recorder) -> None:
    steps_list = (2, 4) if SMOKE else (4, 8, 16, 32)
    batch = 32 if SMOKE else 256
    net = _rand_net((768, 256, 10) if SMOKE else cm.PAPER_TOPOLOGY)
    for n_steps in steps_list:
        cfg = temporal.TemporalConfig(n_steps=n_steps, leak=0.125)
        ev = _event_stream(batch, n_steps, seed=n_steps)
        packed = jnp.asarray(packing.pack_spikes_np(ev))
        plan = net.plan(mode="temporal", temporal=cfg, telemetry=True)
        # return the arrays (PlanResult is not a pytree): time_call must
        # block on the actual device work, not just the dispatch
        def _run():
            r = plan(packed)
            return r.logits, r.loads

        us, (logits, loads) = time_call(_run)
        wall_s = us / 1e6
        rs = cm.temporal_request_stats_device(net.topology, loads, READ_PORTS)
        pj_step = float(np.asarray(rs["energy_pj_per_step"]).mean())
        in_spikes = int(ev.sum())
        rec.emit(
            f"temporal_stream_T{n_steps}", us,
            f"batch={batch};steps_per_s={batch * n_steps / wall_s:,.0f};"
            f"spikes_per_s={in_spikes / wall_s:,.0f};"
            f"pj_per_timestep={pj_step:.1f};"
            f"pj_per_stream={float(np.asarray(rs['energy_pj']).mean()):.1f}")


def _bench_encoder_accuracy(rec: Recorder) -> None:
    n, steps = (512, 40) if SMOKE else (4096, 250)
    n_steps = 4 if SMOKE else 8
    x, y = digits.make_spike_dataset(n, seed=0)
    params, _ = bnn.fit(jax.random.PRNGKey(0), cm.PAPER_TOPOLOGY,
                        jnp.asarray(x), jnp.asarray(y), steps=steps)
    net = conversion.bnn_to_snn(params)
    cfg = temporal.TemporalConfig(n_steps=n_steps)
    plan = net.plan(mode="temporal", temporal=cfg)
    static_acc = float(
        (net.plan(mode="functional")(jnp.asarray(x).astype(bool))
         .logits.argmax(-1) == jnp.asarray(y)).mean())
    for enc in ("rate", "latency"):
        ev = events.encode(x, n_steps, encoder=enc, seed=1, **(
            {"gain": 0.7} if enc == "rate" else {}))
        us, res = time_call(lambda: plan(packing.pack_spikes_np(ev)).logits)
        acc = float((np.asarray(res).argmax(-1) == y).mean())
        rec.emit(
            f"temporal_encoder_{enc}", us,
            f"T={n_steps};n={n};acc={acc * 100:.2f};"
            f"static_acc={static_acc * 100:.2f}")


def run(rec: Recorder | None = None) -> None:
    own = rec is None
    if own:
        rec = Recorder()
    _bench_fused_vs_naive(rec)
    _bench_stream_rates(rec)
    _bench_encoder_accuracy(rec)
    if own:
        rec.write_json(OUT)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()

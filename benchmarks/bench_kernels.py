"""Pallas kernel functional timings (interpret mode — correctness plane) and
MXU utilization estimates for the TPU target (structural, from block shapes)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.kernels.arbiter import ops as arb_ops
from repro.kernels.cim_matmul import ops as cim_ops
from repro.kernels.if_neuron import ops as if_ops
from repro.kernels.stdp import ops as stdp_ops


def run():
    key = jax.random.PRNGKey(0)
    s = jax.random.bernoulli(key, 0.4, (256, 768)).astype(jnp.float32)
    w = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (768, 256)).astype(jnp.int8)
    vth = jnp.zeros((256,), jnp.int32)

    us, _ = time_call(lambda: cim_ops.cim_matmul(s, w, interpret=True))
    flops = 2 * 256 * 768 * 256
    emit("kernel_cim_matmul_256x768x256", us,
         f"flops={flops};tpu_blocks=128x128x128;"
         f"mxu_aligned=yes;vmem_per_block_kb={(128*128*2*3)//1024}")

    us, _ = time_call(lambda: cim_ops.esam_layer(s, w, vth, interpret=True))
    emit("kernel_esam_layer_fused", us,
         "fused=mac+if_fire;vmem_resident_vmem=acc128x128xf32")

    req = jax.random.bernoulli(key, 0.4, (16, 128)).astype(jnp.int8)
    us, _ = time_call(lambda: arb_ops.arbiter(req, ports=4, interpret=True))
    emit("kernel_arbiter_16x128_p4", us, "blocked_prefix=32-lane base encoders")

    upd = jax.random.randint(key, (8, 32, 256), -3, 4, jnp.int32)
    us, _ = time_call(lambda: if_ops.if_neuron(upd, jnp.zeros((256,), jnp.int32),
                                               interpret=True))
    emit("kernel_if_neuron_8x32x256", us, "vmem_resident_vmem=rounds_in_vmem")

    bits = jax.random.bernoulli(key, 0.5, (128, 256)).astype(jnp.int8)
    pre = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (256,)).astype(jnp.int8)
    post = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.2, (128,)).astype(jnp.int8)
    u1 = jax.random.uniform(jax.random.fold_in(key, 4), (128, 256))
    u2 = jax.random.uniform(jax.random.fold_in(key, 5), (128, 256))
    us, _ = time_call(lambda: stdp_ops.stdp_update(
        bits, pre, post, u1, u2, p_pot=0.2, p_dep=0.1, interpret=True))
    emit("kernel_stdp_128x256", us, "layout=column_major_transposed_port")


if __name__ == "__main__":
    run()

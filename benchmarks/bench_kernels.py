"""Pallas kernel timings in BOTH lanes — interpret mode (the correctness
plane that runs everywhere) and the compiled path (TPU; skipped gracefully
elsewhere with a ``lane=compiled_skipped`` row) — so recorded speedups can
never be interpret-mode artifacts: every BENCH_kernels.json row carries its
lane name.

Headline section: the popcount-domain MAC + single-launch mega-kernel
cascade (``kernels/cim_popcount``) vs the unpack-then-MXU packed plane
(``cim_matmul_packed``) at the serving shape 1024x768x768.  The comparison
is *gated*: bit identity against the packed oracle is asserted before any
timing is recorded, and the popcount lanes must clear a >=1x floor over the
packed lanes in the same lane (SPEEDUP_FLOOR, recorded in the row).  Roofline
inputs per datapath come from ``cost_model.mac_datapath_stats`` so the
trajectory carries its own model next to the measurements.

Results go to ``BENCH_kernels.json`` (override with env BENCH_OUT).
"""

from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import Recorder, time_call
except ModuleNotFoundError:  # direct `python benchmarks/bench_kernels.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))
    from benchmarks.common import Recorder, time_call
from repro.core import packing
from repro.core.esam import cost_model
from repro.kernels.arbiter import ops as arb_ops
from repro.kernels.cim_matmul import ops as cim_ops
from repro.kernels.cim_matmul_packed import ops as pk_ops
from repro.kernels.cim_popcount import ops as pop_ops
from repro.kernels.if_neuron import ops as if_ops
from repro.kernels.stdp import ops as stdp_ops

#: popcount lanes must be at least this much faster than the packed-MXU
#: lanes in the same lane (interpret vs interpret, compiled vs compiled)
SPEEDUP_FLOOR = 1.0


def _lanes(rec: Recorder, name: str, make_fn, derived: str, repeats: int = 1):
    """Record one kernel in both lanes; returns (us_interpret, us_compiled).

    ``make_fn(interpret)`` builds the timed call.  The compiled lane is
    attempted everywhere and skipped gracefully (recorded, not timed) where
    non-interpret Pallas does not lower — off-TPU backends.
    """
    us_i, _ = time_call(lambda: make_fn(True), repeats=repeats)
    rec.emit(f"{name}_interpret", us_i, f"lane=interpret;{derived}")
    try:
        us_c, _ = time_call(lambda: make_fn(False), repeats=repeats)
        rec.emit(f"{name}_compiled", us_c, f"lane=compiled;{derived}")
        return us_i, us_c
    except Exception as e:  # noqa: BLE001
        if jax.default_backend() == "tpu":
            raise
        rec.emit(
            f"{name}_compiled", 0.0,
            f"lane=compiled_skipped;backend={jax.default_backend()};"
            f"reason={type(e).__name__};{derived}")
        return us_i, None


def _roofline(datapath: str, B: int, K: int, N: int) -> str:
    r = cost_model.mac_datapath_stats(B, K, N, datapath)
    return (f"hbm_bytes={r['hbm_bytes']};compute_ops={r['compute_ops']};"
            f"unit={r['unit']};t_roofline_us={r['t_roofline_us']:.1f};"
            f"bound={r['bound']}")


def _popcount_comparison(rec: Recorder, key):
    """Popcount-domain MAC + mega cascade vs the packed-MXU plane, gated."""
    B, K, N = 1024, 768, 768
    s = jax.random.bernoulli(key, 0.4, (B, K)).astype(jnp.float32)
    w = jax.random.bernoulli(
        jax.random.fold_in(key, 1), 0.5, (K, N)).astype(jnp.int8)
    vth = jnp.zeros((N,), jnp.int32)
    packed = jax.block_until_ready(packing.pack_spikes(s))
    planes = jax.block_until_ready(packing.pack_weight_planes(w))

    # ---- bit-identity gate before anything is timed -------------------- #
    want = np.asarray(pk_ops.cim_matmul_packed(packed, w, interpret=True))
    got_ref = np.asarray(pop_ops.cim_popcount_ref(packed, planes))
    got_k = np.asarray(pop_ops.cim_popcount_matmul(
        packed, planes, use_kernel=True, interpret=True))
    assert np.array_equal(want, got_ref), "popcount ref != packed oracle"
    assert np.array_equal(want, got_k), "popcount kernel != packed oracle"

    bytes_packed = B * packing.packed_nbytes(K)
    us_pk_i, us_pk_c = _lanes(
        rec, f"kernel_cim_matmul_packed_{B}x{K}x{N}",
        lambda interp: pk_ops.cim_matmul_packed(packed, w, interpret=interp),
        f"spike_bytes_moved={bytes_packed};wire=uint32_bitplane;"
        f"unpack=vmem_shift_mask;{_roofline('packed_mxu', B, K, N)}")
    us_pc_i, us_pc_c = _lanes(
        rec, f"kernel_cim_popcount_{B}x{K}x{N}",
        lambda interp: pop_ops.cim_popcount_matmul(
            packed, planes, use_kernel=True, interpret=interp),
        f"spike_bytes_moved={bytes_packed};wire=uint32_bitplane;"
        f"mac=and_popcount;unpack=none;{_roofline('popcount_vpu', B, K, N)}")
    us_ref, _ = time_call(
        lambda: pop_ops.cim_popcount_matmul(packed, planes, use_kernel=False),
        repeats=1)
    rec.emit(
        f"kernel_cim_popcount_ref_{B}x{K}x{N}", us_ref,
        "lane=jnp_ref;dispatch=non_tpu_backends;mac=and_popcount")

    _lanes(
        rec, f"kernel_esam_layer_popcount_fused_{B}x{K}x{N}",
        lambda interp: pop_ops.esam_layer_popcount(
            packed, planes, vth, use_kernel=True, interpret=interp),
        f"fused=popcount_mac+if_fire+repack;out_bytes={B * N // 8};"
        f"inter_tile_wire=uint32_bitplane")

    # ---- whole cascade: per-tile packed launches vs ONE mega launch ---- #
    from repro.core.esam import plan as plan_mod

    topo = (K, N, N, 10)
    wb = [jax.random.bernoulli(
        jax.random.fold_in(key, 10 + i), 0.5,
        (topo[i], topo[i + 1])).astype(jnp.int8) for i in range(3)]
    vths = [jnp.full((topo[i + 1],), 96, jnp.int32) for i in range(3)]
    tile_planes = [packing.pack_weight_planes(x) for x in wb]
    w_stack, vth_stack = pop_ops.stack_cascade_operands(tile_planes, vths, topo)
    w_stack = jax.block_until_ready(w_stack)

    def packed_cascade(interp):
        p = plan_mod._packed_cascade(wb, vths, packed, interpret=interp)
        return pk_ops.cim_matmul_packed(p, wb[-1], interpret=interp)

    def mega_cascade(interp):
        return pop_ops.esam_cascade_popcount(
            packed, w_stack, vth_stack, topology=topo,
            use_kernel=True, interpret=interp)

    want_l = packed_cascade(True)
    got_l, _ = mega_cascade(True)
    assert np.array_equal(np.asarray(want_l), np.asarray(got_l)), \
        "mega cascade logits != per-tile packed cascade"
    n_launches = len(topo) - 1  # fused hidden tiles + readout vs 1 mega launch
    us_cc_i, us_cc_c = _lanes(
        rec, f"cascade_packed_per_tile_{B}x{'x'.join(map(str, topo))}",
        packed_cascade, f"launches={n_launches};datapath=packed_mxu")
    us_mg_i, us_mg_c = _lanes(
        rec, f"cascade_popcount_mega_{B}x{'x'.join(map(str, topo))}",
        mega_cascade,
        "launches=1;datapath=popcount_vpu;weight_dma=double_buffered;"
        "fired_planes=vmem_resident")

    # ---- the asserted floor, recorded next to the measurement ---------- #
    sp_mat_i = us_pk_i / us_pc_i
    sp_casc_i = us_cc_i / us_mg_i
    assert sp_mat_i >= SPEEDUP_FLOOR, (
        f"popcount matmul interpret lane below floor: {sp_mat_i:.2f}x")
    assert sp_casc_i >= SPEEDUP_FLOOR, (
        f"mega cascade interpret lane below floor: {sp_casc_i:.2f}x")
    compiled = ""
    if us_pc_c is not None and us_pk_c is not None:
        sp_mat_c = us_pk_c / us_pc_c
        sp_casc_c = us_cc_c / us_mg_c
        assert sp_mat_c >= SPEEDUP_FLOOR, (
            f"popcount matmul compiled lane below floor: {sp_mat_c:.2f}x")
        compiled = (f";speedup_compiled_matmul={sp_mat_c:.2f}x"
                    f";speedup_compiled_cascade={sp_casc_c:.2f}x")
    rec.emit(
        "kernel_popcount_speedup_vs_packed", 0.0,
        f"floor={SPEEDUP_FLOOR:.1f}x;asserted=yes;bit_identity=checked;"
        f"speedup_interpret_matmul={sp_mat_i:.2f}x;"
        f"speedup_interpret_cascade={sp_casc_i:.2f}x{compiled}")

    # ---- per-kernel timing through the observability registry ---------- #
    # the same kernel_timer lane the serving stack books device profiles
    # into: each timed call observes into esam_kernel_seconds{kernel=,lane=},
    # so kernel quantiles ride the same scrape surface as serving metrics
    from repro.obs.metrics import Registry
    from repro.obs.profile import kernel_timer

    obs_reg = Registry()
    obs_repeats = 3
    for kname, fn in (("cascade_packed_per_tile", packed_cascade),
                      ("cascade_popcount_mega", mega_cascade)):
        for _ in range(obs_repeats):
            with kernel_timer(obs_reg, kname, lane="interpret"):
                jax.block_until_ready(fn(True))
    h_pk = obs_reg.get("esam_kernel_seconds",
                       kernel="cascade_packed_per_tile", lane="interpret")
    h_mg = obs_reg.get("esam_kernel_seconds",
                       kernel="cascade_popcount_mega", lane="interpret")
    rec.emit(
        "kernel_obs_timing_lane", h_mg.sum / h_mg.count * 1e6,
        f"lane=interpret;registry=esam_kernel_seconds;"
        f"observations={h_pk.count + h_mg.count};"
        f"packed_p50_us={h_pk.quantile(0.5) * 1e6:.0f};"
        f"mega_p50_us={h_mg.quantile(0.5) * 1e6:.0f};"
        f"quantile_source=log_bucketed_histogram")


def run():
    rec = Recorder()
    key = jax.random.PRNGKey(0)
    s = jax.random.bernoulli(key, 0.4, (256, 768)).astype(jnp.float32)
    w = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (768, 256)).astype(jnp.int8)
    vth = jnp.zeros((256,), jnp.int32)

    flops = 2 * 256 * 768 * 256
    _lanes(rec, "kernel_cim_matmul_256x768x256",
           lambda interp: cim_ops.cim_matmul(s, w, interpret=interp),
           f"flops={flops};tpu_blocks=128x128x128;"
           f"mxu_aligned=yes;vmem_per_block_kb={(128*128*2*3)//1024}")

    _lanes(rec, "kernel_esam_layer_fused",
           lambda interp: cim_ops.esam_layer(s, w, vth, interpret=interp),
           "fused=mac+if_fire;vmem_resident_vmem=acc128x128xf32")

    req = jax.random.bernoulli(key, 0.4, (16, 128)).astype(jnp.int8)
    _lanes(rec, "kernel_arbiter_16x128_p4",
           lambda interp: arb_ops.arbiter(req, ports=4, interpret=interp),
           "blocked_prefix=32-lane base encoders")

    upd = jax.random.randint(key, (8, 32, 256), -3, 4, jnp.int32)
    _lanes(rec, "kernel_if_neuron_8x32x256",
           lambda interp: if_ops.if_neuron(
               upd, jnp.zeros((256,), jnp.int32), interpret=interp),
           "vmem_resident_vmem=rounds_in_vmem")

    bits = jax.random.bernoulli(key, 0.5, (128, 256)).astype(jnp.int8)
    pre = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (256,)).astype(jnp.int8)
    post = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.2, (128,)).astype(jnp.int8)
    u1 = jax.random.uniform(jax.random.fold_in(key, 4), (128, 256))
    u2 = jax.random.uniform(jax.random.fold_in(key, 5), (128, 256))
    _lanes(rec, "kernel_stdp_128x256",
           lambda interp: stdp_ops.stdp_update(
               bits, pre, post, u1, u2, p_pot=0.2, p_dep=0.1, interpret=interp),
           "layout=column_major_transposed_port")

    uv1 = jax.random.uniform(jax.random.fold_in(key, 6), (256,))
    uv2 = jax.random.uniform(jax.random.fold_in(key, 7), (256,))
    _lanes(rec, "kernel_stdp_column_event_128x256",
           lambda interp: stdp_ops.stdp_column_event(
               bits, jnp.asarray(5, jnp.int32), jnp.asarray(True),
               pre.astype(bool), uv1, uv2, p_pot=0.2, p_dep=0.1,
               interpret=interp),
           "grid=event_column_only;write=aliased_in_place;"
           "rng_draws_per_event=n_in_not_n_in_x_n_out")

    _popcount_comparison(rec, jax.random.fold_in(key, 9))

    rec.write_json(os.environ.get("BENCH_OUT", "BENCH_kernels.json"))


if __name__ == "__main__":
    run()

"""Pallas kernel functional timings (interpret mode — correctness plane) and
MXU utilization estimates for the TPU target (structural, from block shapes).

Also the packed-vs-unpacked spike-plane comparison (the PR-1 tentpole): the
bit-packed kernels move 32 spikes per uint32 lane word, so spike HBM traffic
drops 8x vs the int8 wire (32x vs f32).  Results are written to
``BENCH_kernels.json`` (override with env BENCH_OUT) so the perf trajectory
is recorded across PRs.
"""

from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp

try:
    from benchmarks.common import Recorder, time_call
except ModuleNotFoundError:  # direct `python benchmarks/bench_kernels.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))
    from benchmarks.common import Recorder, time_call
from repro.core import packing
from repro.kernels.arbiter import ops as arb_ops
from repro.kernels.cim_matmul import ops as cim_ops
from repro.kernels.cim_matmul_packed import ops as pk_ops
from repro.kernels.if_neuron import ops as if_ops
from repro.kernels.stdp import ops as stdp_ops


def _packed_comparison(rec: Recorder, key):
    """Packed vs unpacked dense path at the serving shape B=1024, K=N=768."""
    B, K, N = 1024, 768, 768
    s = jax.random.bernoulli(key, 0.4, (B, K)).astype(jnp.float32)
    w = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (K, N)).astype(jnp.int8)
    vth = jnp.zeros((N,), jnp.int32)
    packed = jax.block_until_ready(packing.pack_spikes(s))

    # spike bytes moved per layer input (the wire the paper optimizes)
    bytes_int8 = B * K                       # 1 byte per spike
    bytes_f32 = B * K * 4                    # the pre-PR functional plane
    bytes_packed = B * packing.packed_nbytes(K)
    red8 = bytes_int8 / bytes_packed
    red32 = bytes_f32 / bytes_packed

    us_d, _ = time_call(
        lambda: cim_ops.cim_matmul(s, w, interpret=True), repeats=1)
    us_p, _ = time_call(
        lambda: pk_ops.cim_matmul_packed(packed, w, interpret=True), repeats=1)
    rec.emit(
        f"kernel_cim_matmul_dense_{B}x{K}x{N}", us_d,
        f"spike_bytes_moved={bytes_int8};wire=int8;tpu_blocks=128x128x128")
    rec.emit(
        f"kernel_cim_matmul_packed_{B}x{K}x{N}", us_p,
        f"spike_bytes_moved={bytes_packed};wire=uint32_bitplane;"
        f"reduction_vs_int8={red8:.1f}x;reduction_vs_f32={red32:.1f}x;"
        f"unpack=vmem_shift_mask")

    us_f, _ = time_call(
        lambda: pk_ops.esam_layer_packed(packed, w, vth, interpret=True), repeats=1)
    rec.emit(
        f"kernel_esam_layer_packed_fused_{B}x{K}x{N}", us_f,
        f"fused=mac+if_fire+repack;out_bytes={B * N // 8};"
        f"inter_tile_wire=uint32_bitplane")


def run():
    rec = Recorder()
    key = jax.random.PRNGKey(0)
    s = jax.random.bernoulli(key, 0.4, (256, 768)).astype(jnp.float32)
    w = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (768, 256)).astype(jnp.int8)
    vth = jnp.zeros((256,), jnp.int32)

    us, _ = time_call(lambda: cim_ops.cim_matmul(s, w, interpret=True))
    flops = 2 * 256 * 768 * 256
    rec.emit("kernel_cim_matmul_256x768x256", us,
             f"flops={flops};tpu_blocks=128x128x128;"
             f"mxu_aligned=yes;vmem_per_block_kb={(128*128*2*3)//1024}")

    us, _ = time_call(lambda: cim_ops.esam_layer(s, w, vth, interpret=True))
    rec.emit("kernel_esam_layer_fused", us,
             "fused=mac+if_fire;vmem_resident_vmem=acc128x128xf32")

    req = jax.random.bernoulli(key, 0.4, (16, 128)).astype(jnp.int8)
    us, _ = time_call(lambda: arb_ops.arbiter(req, ports=4, interpret=True))
    rec.emit("kernel_arbiter_16x128_p4", us, "blocked_prefix=32-lane base encoders")

    upd = jax.random.randint(key, (8, 32, 256), -3, 4, jnp.int32)
    us, _ = time_call(lambda: if_ops.if_neuron(upd, jnp.zeros((256,), jnp.int32),
                                               interpret=True))
    rec.emit("kernel_if_neuron_8x32x256", us, "vmem_resident_vmem=rounds_in_vmem")

    bits = jax.random.bernoulli(key, 0.5, (128, 256)).astype(jnp.int8)
    pre = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (256,)).astype(jnp.int8)
    post = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.2, (128,)).astype(jnp.int8)
    u1 = jax.random.uniform(jax.random.fold_in(key, 4), (128, 256))
    u2 = jax.random.uniform(jax.random.fold_in(key, 5), (128, 256))
    us, _ = time_call(lambda: stdp_ops.stdp_update(
        bits, pre, post, u1, u2, p_pot=0.2, p_dep=0.1, interpret=True))
    rec.emit("kernel_stdp_128x256", us, "layout=column_major_transposed_port")

    uv1 = jax.random.uniform(jax.random.fold_in(key, 6), (256,))
    uv2 = jax.random.uniform(jax.random.fold_in(key, 7), (256,))
    us, _ = time_call(lambda: stdp_ops.stdp_column_event(
        bits, jnp.asarray(5, jnp.int32), jnp.asarray(True),
        pre.astype(bool), uv1, uv2, p_pot=0.2, p_dep=0.1, interpret=True))
    rec.emit("kernel_stdp_column_event_128x256", us,
             "grid=event_column_only;write=aliased_in_place;"
             "rng_draws_per_event=n_in_not_n_in_x_n_out")

    _packed_comparison(rec, jax.random.fold_in(key, 9))

    rec.write_json(os.environ.get("BENCH_OUT", "BENCH_kernels.json"))


if __name__ == "__main__":
    run()

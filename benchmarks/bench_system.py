"""Paper Fig 8: system-level power / throughput / energy / area across the
five SRAM cell options, on the calibration activity profile.  Reproduces the
headline V1 ratios (3.1x speed, 2.2x energy efficiency)."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.esam import cost_model as cm
from repro.core.esam.network import reference_activity, system_stats


def run():
    act = reference_activity()
    stats = [system_stats(cm.PAPER_TOPOLOGY, act, p) for p in range(5)]
    for s in stats:
        emit(
            f"fig8_{s.cell}",
            0.0,
            f"throughput_minf_s={s.throughput_inf_s/1e6:.2f};"
            f"energy_pj_inf={s.energy_pj_per_inf:.0f};"
            f"power_mw={s.power_mw:.1f};area_ratio={s.area_ratio_vs_1rw:.2f};"
            f"latency_ns={s.latency_ns:.1f};bottleneck_tile={s.bottleneck_tile}",
        )
    speedup = stats[4].throughput_inf_s / stats[0].throughput_inf_s
    eff = stats[0].energy_pj_per_inf / stats[4].energy_pj_per_inf
    emit("fig8_headline", 0.0,
         f"speedup_4r={speedup:.2f}x(paper {cm.PAPER_SPEEDUP_4R}x);"
         f"energy_eff_4r={eff:.2f}x(paper {cm.PAPER_ENERGY_EFF_4R}x)")


if __name__ == "__main__":
    run()

"""Paper Fig 8: system-level power / throughput / energy / area across the
five SRAM cell options — now driven by the rank-schedule cycle-accurate
plane, not just the closed-form cost model.

Three sweeps, all recorded to ``BENCH_system.json``:

  fig8_ref_*        cost model on the calibration activity profile (anchor)
  fig8_sim_*        cycle-accurate simulation of a batch pinned to the same
                    profile — the measured loads reproduce the 3.1x / 2.2x
                    headline from simulated traces, and every simulated
                    per-tile cycle count is asserted against the cost model
  fig8_measured_*   ``EsamNetwork.port_sweep`` on a digit batch through a
                    paper-topology network (measured batch activity)

plus ``plane_speedup_batch256``: wall-clock of the rank-schedule plane vs
the retained scan oracle on the first tile at batch 256 (acceptance: >=10x).
"""

from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import Recorder, time_call
except ModuleNotFoundError:  # direct `python benchmarks/bench_system.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))
    from benchmarks.common import Recorder, time_call
from repro.core.esam import cost_model as cm
from repro.core.esam import tile as tile_mod
from repro.core.esam.network import EsamNetwork, reference_activity, system_stats
from repro.data import digits

BATCH = 256


def _emit_sweep(rec: Recorder, tag: str, activity) -> tuple[float, float]:
    """Emit the five cell options + headline ratios for one activity profile."""
    stats = [system_stats(cm.PAPER_TOPOLOGY, activity, p) for p in range(5)]
    for s in stats:
        rec.emit(
            f"fig8_{tag}_{s.cell}",
            0.0,
            f"throughput_minf_s={s.throughput_inf_s/1e6:.2f};"
            f"energy_pj_inf={s.energy_pj_per_inf:.0f};"
            f"power_mw={s.power_mw:.1f};area_ratio={s.area_ratio_vs_1rw:.2f};"
            f"latency_ns={s.latency_ns:.1f};bottleneck_tile={s.bottleneck_tile}",
        )
    speedup = stats[4].throughput_inf_s / stats[0].throughput_inf_s
    eff = stats[0].energy_pj_per_inf / stats[4].energy_pj_per_inf
    rec.emit(
        f"fig8_{tag}_headline", 0.0,
        f"speedup_4r={speedup:.2f}x(paper {cm.PAPER_SPEEDUP_4R}x);"
        f"energy_eff_4r={eff:.2f}x(paper {cm.PAPER_ENERGY_EFF_4R}x)")
    return speedup, eff


def _reference_profile_spikes(n_in: int, per_group: int, batch: int) -> jax.Array:
    """Deterministic batch with exactly ``per_group`` spikes per 128-row group
    (positions rolled per sample so the arbiters see varied request patterns
    at a pinned load)."""
    n_groups = n_in // 128
    base = np.zeros((n_groups, 128), bool)
    base[:, :per_group] = True
    out = np.stack([np.roll(base, i, axis=1) for i in range(batch)])
    return jnp.asarray(out.reshape(batch, n_in))


def _simulated_reference_sweep(rec: Recorder) -> tuple[float, float]:
    """Drive the rank-schedule plane at the calibration loads, tile by tile,
    and evaluate the Fig 8 sweep on the loads the simulator actually drained."""
    key = jax.random.PRNGKey(0)
    topo = cm.PAPER_TOPOLOGY
    measured = []
    for t in range(len(topo) - 1):
        n_in, n_out = topo[t], topo[t + 1]
        bits = jax.random.bernoulli(
            jax.random.fold_in(key, t), 0.5, (n_in, n_out)).astype(jnp.int8)
        vth = jnp.zeros((n_out,), jnp.int32)
        spikes = _reference_profile_spikes(n_in, cm.REF_SPIKES_PER_GROUP[t], BATCH)
        loads = np.asarray(spikes, np.int32).reshape(BATCH, -1, 128).sum(-1)
        for p in range(5):
            ports = max(1, p)
            tr = tile_mod.simulate_tile_batch(bits, spikes, vth, ports)
            # every simulated drain must land on the cost model's cycle count
            want = np.ceil(loads / ports).max(axis=1).astype(np.int32)
            np.testing.assert_array_equal(np.asarray(tr.cycles), want)
        measured.append(loads.astype(np.float64))
    return _emit_sweep(rec, "sim", measured)


def _measured_network_sweep(rec: Recorder):
    """Fig 8 on *measured* batch activity: one jitted ``port_sweep`` through a
    paper-topology network on the digit set, loads taken from its traces."""
    key = jax.random.PRNGKey(1)
    topo = cm.PAPER_TOPOLOGY
    bits = [
        jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topo[i], topo[i + 1])).astype(jnp.int8)
        for i in range(len(topo) - 1)
    ]
    vth = [jnp.zeros((n,), jnp.int32) for n in topo[1:]]
    net = EsamNetwork(weight_bits=bits, vth=vth,
                      out_offset=jnp.zeros((topo[-1],), jnp.float32))
    x, _ = digits.make_spike_dataset(BATCH, seed=3)
    spikes = jnp.asarray(x).astype(bool)

    us, sweep = time_call(net.port_sweep, spikes, range(5))
    logits4 = np.asarray(sweep[4][0])
    np.testing.assert_array_equal(
        logits4, np.asarray(net.plan(mode="functional")(spikes).logits))

    activity = net.measured_activity(spikes, traces=sweep[4][1])
    speedup, eff = _emit_sweep(rec, "measured", activity)
    rec.emit("port_sweep_batched", us,
             f"batch={BATCH};cells=5;plane=rank_schedule;one_jitted_call=True;"
             f"input_activity={activity[0].mean()/128:.2f}")
    return speedup, eff


def _plane_speedup(rec: Recorder) -> float:
    """Wall-clock: rank-schedule plane vs retained scan oracle, batch 256."""
    key = jax.random.PRNGKey(2)
    n_in, n_out = cm.PAPER_TOPOLOGY[0], cm.PAPER_TOPOLOGY[1]
    bits = jax.random.bernoulli(key, 0.5, (n_in, n_out)).astype(jnp.int8)
    vth = jnp.zeros((n_out,), jnp.int32)
    x, _ = digits.make_spike_dataset(BATCH, seed=5)
    spikes = jnp.asarray(x).astype(bool)

    us_sched, tr_sched = time_call(
        tile_mod.simulate_tile_batch, bits, spikes, vth, 4)
    us_scan, tr_scan = time_call(
        tile_mod.simulate_tile_scan_batch, bits, spikes, vth, 4)
    np.testing.assert_array_equal(
        np.asarray(tr_sched.vmem_final), np.asarray(tr_scan.vmem_final))
    np.testing.assert_array_equal(
        np.asarray(tr_sched.grants_per_cycle), np.asarray(tr_scan.grants_per_cycle))
    speedup = us_scan / us_sched
    rec.emit("plane_speedup_batch256", us_sched,
             f"us_scan={us_scan:.1f};speedup={speedup:.1f}x;batch={BATCH};"
             f"tile={n_in}x{n_out};ports=4;bit_identical=True")
    return speedup


def run():
    rec = Recorder()
    ref_speed, ref_eff = _emit_sweep(rec, "ref", reference_activity())
    sim_speed, sim_eff = _simulated_reference_sweep(rec)
    _measured_network_sweep(rec)
    plane_speedup = _plane_speedup(rec)

    # write the report before the acceptance asserts so a failing run still
    # leaves the recorded rows behind for diagnosis
    rec.write_json(os.environ.get("BENCH_SYSTEM_OUT", "BENCH_system.json"))

    # acceptance: the simulated-trace sweep reproduces the paper headline …
    assert abs(sim_speed - cm.PAPER_SPEEDUP_4R) / cm.PAPER_SPEEDUP_4R < 0.05, sim_speed
    assert abs(sim_eff - cm.PAPER_ENERGY_EFF_4R) / cm.PAPER_ENERGY_EFF_4R < 0.05, sim_eff
    assert abs(sim_speed - ref_speed) < 1e-9 and abs(sim_eff - ref_eff) < 1e-9
    # … and the rank-schedule plane beats the scan plane >=10x at batch 256
    assert plane_speedup >= 10.0, f"plane speedup {plane_speedup:.1f}x < 10x"


if __name__ == "__main__":
    run()

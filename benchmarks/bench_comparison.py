"""Paper Table 3: the 1RW+4R system vs published SOTA, on BOTH the
calibration activity profile and the *measured* profile of a freshly trained
BNN (synthetic digits — DESIGN.md §8 notes the MNIST substitution).

Recorded to ``BENCH_comparison.json`` (override with env BENCH_COMPARISON_OUT)
so the Table 3 trajectory is tracked across PRs like the other benches.
"""

from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import Recorder, time_call
except ModuleNotFoundError:  # direct `python benchmarks/bench_comparison.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))
    from benchmarks.common import Recorder, time_call
from repro.core.esam import bnn, conversion, cost_model as cm
from repro.core.esam.network import reference_activity, system_stats
from repro.data import digits

PAPER_ROWS = {
    "wang_assc20[6]": "tech=65nm;power=305nW;acc=97.6;thr=2inf/s;energy=195nJ",
    "chen_jssc19[9]": "tech=10nm;power=196mW;acc=97.9;thr=6250inf/s;energy=1000nJ",
    "kim_fns18[10]": "tech=65nm;power=53mW;acc=97.2;thr=20inf/s;transposable=yes",
}


def run():
    rec = Recorder()
    for name, row in PAPER_ROWS.items():
        rec.emit(f"table3_{name}", 0.0, row)

    # --- reference profile (paper operating point) -------------------
    s4 = system_stats(cm.PAPER_TOPOLOGY, reference_activity(), 4)
    rec.emit("table3_thiswork_ref_profile", 0.0,
             f"tech=3nm;clock_mhz={cm.cell_spec(4).clock_hz/1e6:.0f};"
             f"throughput_minf_s={s4.throughput_inf_s/1e6:.1f}(paper 44);"
             f"energy_pj_inf={s4.energy_pj_per_inf:.0f}(paper 607);"
             f"power_mw={s4.power_mw:.1f}(paper 29.0);"
             f"neurons={cm.PAPER_NEURONS};synapses~{cm.PAPER_SYNAPSES}")

    # --- measured profile from a trained binary-SNN ------------------
    x, y = digits.make_spike_dataset(2048, seed=0)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    params, _ = bnn.fit(jax.random.PRNGKey(0), cm.PAPER_TOPOLOGY, xj, yj,
                        steps=150, batch=128)
    net = conversion.bnn_to_snn(params)

    # ONE compiled plan serves accuracy and cost-model activity together:
    # telemetry loads are reductions on the same pass, no second forward.
    plan = net.plan(mode="functional", telemetry=True)

    def measured():
        res = plan(xj.astype(bool))
        return res.logits, [c[:512] for c in res.loads]

    us, (logits, counts) = time_call(measured, repeats=3, warmup=1)
    counts_np = [np.asarray(c, np.float64) for c in counts]
    s4m = system_stats(cm.PAPER_TOPOLOGY, counts_np, 4)
    s0m = system_stats(cm.PAPER_TOPOLOGY, counts_np, 0)
    acc = float((logits.argmax(-1) == yj).mean())
    rec.emit("table3_thiswork_measured", us,
             "timed=plan_functional_telemetry_2048;"
             f"accuracy={acc*100:.2f}(paper 97.64 on MNIST);"
             f"throughput_minf_s={s4m.throughput_inf_s/1e6:.1f};"
             f"energy_pj_inf={s4m.energy_pj_per_inf:.0f};"
             f"power_mw={s4m.power_mw:.1f};"
             f"speedup_vs_1rw={s4m.throughput_inf_s/s0m.throughput_inf_s:.2f}x;"
             f"energy_eff_vs_1rw={s0m.energy_pj_per_inf/s4m.energy_pj_per_inf:.2f}x")

    rec.write_json(os.environ.get("BENCH_COMPARISON_OUT", "BENCH_comparison.json"))


if __name__ == "__main__":
    run()

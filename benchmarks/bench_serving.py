"""Sharded-plan serving throughput: the ESAM system-level claim as a bench.

Drives ``SpikeEngine`` (admission queue -> power-of-two buckets -> one
compiled, optionally ``shard_map``-ped packed plan) with synthetic digit
traffic and records, per configuration:

  * wall-clock serving rate (requests/s) on this host,
  * the modeled hardware operating point in paper units — pipelined MInf/s
    and pJ/Inf from the device-resident telemetry accumulators,

into ``BENCH_serving.json`` (override with env BENCH_SERVING_OUT).  Run
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise
the data-parallel plan on CPU.
"""

from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import Recorder
except ModuleNotFoundError:  # direct `python benchmarks/bench_serving.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))
    from benchmarks.common import Recorder
from repro.core.esam import cost_model as cm
from repro.core.esam.network import EsamNetwork
from repro.data import digits
from repro.distributed import sharding as shd
from repro.serve.engine import SpikeEngine, SpikeRequest

N_REQUESTS = int(os.environ.get("BENCH_SERVING_REQUESTS", "256"))
MAX_BATCH = 128


def _paper_net(seed: int = 0) -> EsamNetwork:
    key = jax.random.PRNGKey(seed)
    topo = cm.PAPER_TOPOLOGY
    bits = [
        jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topo[i], topo[i + 1])).astype(jnp.int8)
        for i in range(len(topo) - 1)
    ]
    vth = [jnp.zeros((n,), jnp.int32) for n in topo[1:]]
    return EsamNetwork(weight_bits=bits, vth=vth,
                       out_offset=jnp.zeros((topo[-1],), jnp.float32))


def _serve_once(rec: Recorder, tag: str, net, reqs_np, rules) -> None:
    # warm on a throwaway engine serving the same workload, so every bucket
    # the timed run dispatches is already compiled (plans are cached per
    # network) and the timed engine's stats() see only the timed requests —
    # time_call's warmup=1 convention, engine-shaped
    engine_kw = dict(max_batch=MAX_BATCH, telemetry=True, read_ports=4,
                     rules=rules)
    SpikeEngine(net, **engine_kw).serve(
        [SpikeRequest(spikes=r) for r in reqs_np])

    eng = SpikeEngine(net, **engine_kw)
    reqs = [SpikeRequest(spikes=r) for r in reqs_np]
    t0 = time.perf_counter()
    eng.serve(reqs)
    wall_s = time.perf_counter() - t0
    st = eng.stats()
    req_s = len(reqs) / wall_s
    rec.emit(
        f"serving_{tag}", wall_s * 1e6 / len(reqs),
        f"requests={len(reqs)};requests_per_s={req_s:,.0f};"
        f"data_parallel={st['data_parallel']};buckets={eng._buckets};"
        f"model_minf_s={st['throughput_pipelined_inf_s']/1e6:.2f}"
        f"(paper {cm.PAPER_THROUGHPUT_INF_S/1e6:.0f});"
        f"model_energy_pj_inf={st['energy_pj_per_inf']:.0f}"
        f"(paper {cm.PAPER_ENERGY_PJ_PER_INF:.0f});"
        f"cell={st['cell']}",
    )


def run():
    rec = Recorder()
    net = _paper_net()
    x, _ = digits.make_spike_dataset(N_REQUESTS, seed=7)

    _serve_once(rec, "single_device", net, x, rules=None)
    n_dev = len(jax.devices())
    if n_dev > 1:
        rules = shd.make_esam_rules(shd.esam_data_mesh())
        _serve_once(rec, f"sharded_dp{n_dev}", net, x, rules=rules)
    else:
        rec.emit("serving_sharded_skipped", 0.0,
                 "devices=1(set XLA_FLAGS=--xla_force_host_platform_"
                 "device_count=8 for the data-parallel lane)")

    rec.write_json(os.environ.get("BENCH_SERVING_OUT", "BENCH_serving.json"))


if __name__ == "__main__":
    run()

"""Sharded-plan serving throughput: the ESAM system-level claim as a bench.

Drives ``SpikeEngine`` (admission queue -> power-of-two buckets -> one
compiled, optionally ``shard_map``-ped packed plan, with fused multi-round
dispatch + host/device overlap) with synthetic digit traffic and records,
per configuration:

  * wall-clock serving rate (requests/s) on this host,
  * the modeled hardware operating point in paper units — pipelined MInf/s
    and pJ/Inf from the device-resident telemetry accumulators,
  * dp-scaling lanes (dp2/dp4/dp8 on the host-platform mesh): each lane's
    req/s ratio vs the single-device lane (``vs_single``) plus the fused
    round counters — the regression gate for the old dp8 0.29x loss,
  * a cold-start lane: first-request latency on a cold engine vs an
    AOT-warmed one (``SpikeEngine.warmup``), fresh networks per lane so no
    plan cache crosses over,
  * open-loop lanes (seeded Poisson arrivals below and above saturation
    plus a request storm): p50/p99/p99.9 latency, shed / rejected counts,
    and goodput-under-SLO through the overload-hardened plane (bounded
    queue, deadlines, degradation ladder),
  * a chaos lane: two replicas behind the retrying ``FaultAwareRouter``
    with one crashed mid-drain and one slowed — completion accounting and
    retry counts,
  * an observability-overhead lane: the same warmed drain with the tracing
    + metrics plane on vs off (best-of-3 each side) — CI gates the
    ``overhead_pct`` under the plane's 5% budget,

into ``BENCH_serving.json`` (override with env BENCH_SERVING_OUT).  Run
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise
the data-parallel plan on CPU; set ``BENCH_SERVING_SMOKE=1`` for the small
CI configuration.
"""

from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import Recorder
except ModuleNotFoundError:  # direct `python benchmarks/bench_serving.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))
    from benchmarks.common import Recorder
from repro.core.esam import cost_model as cm
from repro.core.esam.network import EsamNetwork
from repro.data import digits
from repro.distributed import sharding as shd
from repro.serve.engine import SpikeEngine, SpikeRequest

# enough requests that the dp lanes measure steady-state super-batching
# (at 256 the whole run is one or two rounds and fixed dispatch overhead
# dominates the scaling ratio)
N_REQUESTS = int(os.environ.get("BENCH_SERVING_REQUESTS", "2048"))
MAX_BATCH = 128


def _paper_net(seed: int = 0) -> EsamNetwork:
    key = jax.random.PRNGKey(seed)
    topo = cm.PAPER_TOPOLOGY
    bits = [
        jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topo[i], topo[i + 1])).astype(jnp.int8)
        for i in range(len(topo) - 1)
    ]
    vth = [jnp.zeros((n,), jnp.int32) for n in topo[1:]]
    return EsamNetwork(weight_bits=bits, vth=vth,
                       out_offset=jnp.zeros((topo[-1],), jnp.float32))


def _serve_once(rec: Recorder, tag: str, net, reqs_np, rules,
                vs_single: float = None) -> float:
    """One throughput lane on the fused async engine.  ``warmup()`` AOT-
    compiles the bucket ladder (and warms the telemetry ops) up front, so
    the timed run measures steady-state serving and the timed engine's
    stats() see only the timed requests.  Returns the req/s rate; dp lanes
    pass the single-device rate as ``vs_single`` to record the scaling
    ratio the CI gate asserts."""
    engine_kw = dict(max_batch=MAX_BATCH, telemetry=True, read_ports=4,
                     rules=rules, fuse_rounds="auto", overlap=True)
    eng = SpikeEngine(net, **engine_kw)
    eng.warmup()
    reqs = [SpikeRequest(spikes=r) for r in reqs_np]
    t0 = time.perf_counter()
    eng.serve(reqs)
    wall_s = time.perf_counter() - t0
    st = eng.stats()
    req_s = len(reqs) / wall_s
    extra = "" if vs_single is None else (
        f"vs_single={req_s / vs_single:.2f}x;"
        f"scaling_efficiency={req_s / (vs_single * st['data_parallel']):.2f};")
    rec.emit(
        f"serving_{tag}", wall_s * 1e6 / len(reqs),
        f"requests={len(reqs)};requests_per_s={req_s:,.0f};"
        f"data_parallel={st['data_parallel']};buckets={eng._buckets};"
        f"{extra}"
        f"fuse={st['fuse_rounds']};overlap={st['overlap']};"
        f"rounds_static={st['rounds_static']};"
        f"fused_rounds={st['fused_rounds']};"
        f"rounds_saved={st['rounds_saved']};"
        f"model_minf_s={st['throughput_pipelined_inf_s']/1e6:.2f}"
        f"(paper {cm.PAPER_THROUGHPUT_INF_S/1e6:.0f});"
        f"model_energy_pj_inf={st['energy_pj_per_inf']:.0f}"
        f"(paper {cm.PAPER_ENERGY_PJ_PER_INF:.0f});"
        f"cell={st['cell']}",
    )
    eng.close()
    return req_s


SMOKE = bool(os.environ.get("BENCH_SERVING_SMOKE"))


def _overload_lanes(rec: Recorder, net) -> None:
    """Open-loop Poisson lanes below and above saturation + a chaos lane.

    The over-saturation lane adds a request storm against a bounded queue,
    so sheds/rejections are structurally guaranteed (the CI overload smoke
    asserts ``shed_total > 0``), and the deadline turns queue growth into
    deadline sheds rather than unbounded latency.
    """
    from repro.serve.overload import DegradationLadder
    from repro.serve.traffic import ChaosConfig, TrafficConfig, run_open_loop
    from repro.train.fault_tolerance import RetryPolicy

    n = 48 if SMOKE else 160
    max_batch = 32
    queue_limit = 2 * max_batch
    n_in = net.topology[0]

    def mk(queue_limit=queue_limit):
        return SpikeEngine(net, max_batch=max_batch, telemetry=True,
                           queue_limit=queue_limit,
                           ladder=DegradationLadder.default(max_batch))

    # AOT-warm every bucket in the ladder, then measure the sustainable
    # rate on an unbounded engine so the lane rates are anchored at this
    # host's actual saturation point.  (Open-loop rounds can be as small as
    # one request; an unwarmed small bucket would charge its compile to the
    # first lane round, shedding everything behind it on the deadline.)
    blend = dict(n_requests=n, p_event=0.0, n_in=n_in)
    warm = mk(queue_limit=None)
    from repro.serve.traffic import build_requests, warmup_engine
    warmup_engine(warm, TrafficConfig(rate_hz=1.0, **blend))
    timed = build_requests(TrafficConfig(rate_hz=1.0, seed=22, **blend))[0]
    t0 = time.perf_counter()
    warm.serve(timed)
    rate_sust = len(timed) / (time.perf_counter() - t0)
    # ~48 requests' worth of service: comfortably above one open-loop
    # drain's latency floor, so goodput separates the lanes (≈1 under
    # saturation, <1 over it) instead of reading 0 everywhere
    deadline_s = 48.0 / rate_sust
    slo_s = deadline_s

    lanes = [
        ("under", 0.5 * rate_sust, None),
        ("over", 2.0 * rate_sust,
         ChaosConfig(storm_at_s=0.0, storm_size=3 * queue_limit)),
    ]
    for tag, rate, chaos in lanes:
        eng = mk()
        cfg = TrafficConfig(rate_hz=rate, seed=23, deadline_s=deadline_s,
                            **blend)
        rep = run_open_loop(eng, cfg, slo_s=slo_s, chaos=chaos)
        shed_total = rep.n_shed + rep.n_rejected
        rec.emit(
            f"serving_openloop_{tag}", rep.p99_ms * 1e3,
            f"rate_hz={rate:.0f};sustainable_hz={rate_sust:.0f};"
            f"offered={rep.n_offered};completed={rep.n_completed};"
            f"p50_ms={rep.p50_ms:.2f};p99_ms={rep.p99_ms:.2f};"
            f"p999_ms={rep.p999_ms:.2f};goodput_slo={rep.goodput_slo:.3f};"
            f"slo_ms={1e3 * slo_s:.1f};deadline_ms={1e3 * deadline_s:.1f};"
            f"shed={rep.n_shed};rejected={rep.n_rejected};"
            f"shed_total={shed_total};"
            f"backpressure={rep.backpressure_events};"
            f"ladder_transitions={rep.ladder_transitions};"
            f"max_degradation_level={rep.max_degradation_level}",
        )

    # chaos lane: replica 0 crashes mid-drain, replica 1 runs 10x slowed —
    # the router's retry/backoff path must complete every admitted request
    engines = [mk(queue_limit=None), mk(queue_limit=None)]
    from repro.serve.engine import FaultAwareRouter
    router = FaultAwareRouter(
        engines, retry=RetryPolicy(max_attempts=4, base_backoff_s=1e-4,
                                   seed=5))
    chaos = ChaosConfig(slowdown=((1, 2e-3),), crash_replica=0,
                        crash_after_rounds=1)
    cfg = TrafficConfig(rate_hz=2.0 * rate_sust, seed=29, **blend)
    rep = run_open_loop(router, cfg, chaos=chaos)
    lost = rep.n_offered - (rep.n_completed + rep.n_shed + rep.n_rejected
                            + rep.n_failed)
    assert lost == 0, f"chaos lane lost {lost} requests"
    rec.emit(
        "serving_chaos", rep.p99_ms * 1e3,
        f"offered={rep.n_offered};completed={rep.n_completed};"
        f"retries={rep.retries};crashes={rep.crashes};"
        f"timeouts={rep.timeouts};failed={rep.n_failed};lost={lost};"
        f"p99_ms={rep.p99_ms:.2f}",
    )


def _obs_overhead_lane(rec: Recorder, net) -> None:
    """Tracer-on vs tracer-off drain cost: the observability plane's <5%
    overhead budget as a measured lane (the CI serving-bench validation
    gates ``overhead_pct`` against it).

    Both sides serve the identical warmed closed-loop workload; best-of-3
    medians each side so a CI noise spike on either doesn't fail the gate.
    Tracing + metrics ride the full path (request spans, round/pack/
    dispatch spans, histogram observes) into a fresh registry per repeat.
    """
    from repro.obs import Observability
    from repro.obs.metrics import Registry

    n = 256 if SMOKE else 1024
    x, _ = digits.make_spike_dataset(n, seed=31)

    def drain_s(obs) -> float:
        eng = SpikeEngine(net, max_batch=MAX_BATCH, telemetry=True,
                          fuse_rounds="auto", overlap=True,
                          observability=obs)
        eng.warmup()
        reqs = [SpikeRequest(spikes=r) for r in x]
        t0 = time.perf_counter()
        eng.serve(reqs)
        wall = time.perf_counter() - t0
        eng.close()
        return wall

    off_s = min(drain_s(None) for _ in range(3))
    on_s = min(drain_s(Observability.enabled(registry=Registry()))
               for _ in range(3))
    overhead_pct = 100.0 * (on_s - off_s) / off_s
    rec.emit(
        "serving_obs_overhead", on_s * 1e6 / n,
        f"requests={n};tracer_off_ms={off_s * 1e3:.1f};"
        f"tracer_on_ms={on_s * 1e3:.1f};"
        f"overhead_pct={overhead_pct:.2f}%;gate=5%;repeats=3",
    )


def _cold_start_lane(rec: Recorder) -> None:
    """First-request latency, cold vs AOT-warmed.

    Each sub-lane builds a *fresh* network (fresh arrays => empty plan
    cache), so the cold lane genuinely pays the first compile in the serve
    path and the warm lane pays it in ``warmup()`` instead.  With the
    persistent compilation cache enabled (env JAX_COMPILATION_CACHE_DIR, or
    ``launch/env.py``) the warmup itself re-warms from disk on a restart.
    """
    def first_request_ms(warm: bool, seed: int):
        net = _paper_net(seed)
        eng = SpikeEngine(net, max_batch=32, telemetry=True)
        warmup_s = 0.0
        if warm:
            t0 = time.perf_counter()
            eng.warmup()
            warmup_s = time.perf_counter() - t0
        spikes = (np.random.default_rng(seed).random(net.topology[0])
                  < 0.3).astype(np.uint8)
        t0 = time.perf_counter()
        eng.serve([SpikeRequest(spikes=spikes)])
        return (time.perf_counter() - t0) * 1e3, warmup_s

    cold_ms, _ = first_request_ms(False, seed=101)
    warm_ms, warmup_s = first_request_ms(True, seed=102)
    rec.emit(
        "serving_cold_start", warm_ms * 1e3,
        f"cold_first_request_ms={cold_ms:.1f};"
        f"warm_first_request_ms={warm_ms:.1f};"
        f"warmup_s={warmup_s:.2f};"
        f"speedup={cold_ms / max(warm_ms, 1e-9):.1f}x;"
        f"compilation_cache="
        f"{'on' if os.environ.get('JAX_COMPILATION_CACHE_DIR') else 'off'}",
    )


def run():
    rec = Recorder()
    net = _paper_net()
    x, _ = digits.make_spike_dataset(N_REQUESTS, seed=7)

    single_req_s = _serve_once(rec, "single_device", net, x, rules=None)
    n_dev = len(jax.devices())
    if n_dev > 1:
        # dp-scaling ladder: every power-of-two mesh up to the host's
        # device count (smoke keeps just the full mesh — the CI gate)
        dps = [n_dev] if SMOKE else sorted(
            d for d in (2, 4, 8) if d <= n_dev)
        for d in dps:
            rules = shd.make_esam_rules(shd.esam_data_mesh(d))
            _serve_once(rec, f"sharded_dp{d}", net, x, rules=rules,
                        vs_single=single_req_s)
    else:
        rec.emit("serving_sharded_skipped", 0.0,
                 "devices=1(set XLA_FLAGS=--xla_force_host_platform_"
                 "device_count=8 for the data-parallel lanes)")

    _cold_start_lane(rec)
    _obs_overhead_lane(rec, net)
    _overload_lanes(rec, net)

    rec.write_json(os.environ.get("BENCH_SERVING_OUT", "BENCH_serving.json"))


if __name__ == "__main__":
    run()

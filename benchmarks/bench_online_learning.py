"""Paper Sec 4.4.1: transposable-port online learning.

Reproduces the 26.0x / 19.5x column read/write speedups, then measures the
fused column-event epoch (PR 2 tentpole) against the PR 1 per-sample scan —
batch 512 on the 768->10 readout tile and on the full 768:256:256:256:10
topology with the packed prefix — with column-updates/s and the hardware
cost accounting for every measured epoch.  Results go to
``BENCH_learning.json`` (override with env BENCH_LEARNING_OUT) so the perf
trajectory is tracked across PRs, next to ``BENCH_kernels.json``.
"""

from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import Recorder, time_call
except ModuleNotFoundError:  # direct `python benchmarks/bench_online_learning.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))
    from benchmarks.common import Recorder, time_call
from repro.core.esam import learning
from repro.data import digits

BATCH = 512


def _hw_cost(n_updates: int) -> str:
    """Hardware time/energy accounting for ``n_updates`` column accesses."""
    c4 = learning.column_update_cost(4)
    c0 = learning.column_update_cost(0)
    t4 = n_updates * (c4.read_ns + c4.write_ns) * 1e-3
    t0 = n_updates * (c0.read_ns + c0.write_ns) * 1e-3
    e4 = n_updates * c4.energy_pj * 1e-3
    e0 = n_updates * c0.energy_pj * 1e-3
    # per-update constant — stays defined even for a zero-update epoch
    speedup = (c0.read_ns + c0.write_ns) / (c4.read_ns + c4.write_ns)
    return (f"column_updates={n_updates};hw_time_4r_us={t4:.1f};"
            f"hw_time_1rw_us={t0:.1f};hw_energy_4r_nj={e4:.1f};"
            f"hw_energy_1rw_nj={e0:.1f};hw_speedup={speedup:.1f}x")


def _timed_epoch(fn, bits):
    """Median of 3 measured runs (time_call warms up / compiles once first)."""
    us, (new_bits, n) = time_call(fn, bits, repeats=3, warmup=1)
    return us, new_bits, int(n)


def _bench_pair(rec: Recorder, tag: str, bits, vth, x, y, key):
    """Old per-sample scan vs fused column-event epoch on one topology."""
    def scan_epoch(b):
        return learning.online_learning_epoch_scan(
            [*bits[:-1], b], vth, x, y, key, p_pot=0.2, p_dep=0.1)

    def fused_epoch(b):
        return learning.online_learning_epoch(
            [*bits[:-1], b], vth, x, y, key, p_pot=0.2, p_dep=0.1)

    us_scan, _, n_scan = _timed_epoch(scan_epoch, bits[-1])
    us_fused, _, n_fused = _timed_epoch(fused_epoch, bits[-1])
    rec.emit(f"learning_epoch_scan_{tag}", us_scan,
             f"plane=pr1_scan;rng=full_matrix_uniforms;batch={BATCH};"
             f"updates_per_s={n_scan / (us_scan * 1e-6):.0f};{_hw_cost(n_scan)}")
    rec.emit(f"learning_epoch_column_event_{tag}", us_fused,
             f"plane=fused_column_event;rng=fold_in_per_column;batch={BATCH};"
             f"speedup_vs_scan={us_scan / us_fused:.1f}x;"
             f"updates_per_s={n_fused / (us_fused * 1e-6):.0f};{_hw_cost(n_fused)}")
    return us_scan / us_fused


def run():
    rec = Recorder()
    base = learning.column_update_cost(0)
    c4 = learning.column_update_cost(4)
    rec.emit("learning_1rw_baseline", 0.0,
             f"col_read_ns={base.read_ns:.1f};col_write_ns={base.write_ns:.1f};"
             f"energy_pj={base.energy_pj:.1f}")
    rec.emit("learning_4r_transposed", 0.0,
             f"col_read_ns={c4.read_ns};col_write_ns={c4.write_ns};"
             f"read_speedup={c4.speedup_read_vs_1rw:.1f}x(paper 26.0x);"
             f"write_speedup={c4.speedup_write_vs_1rw:.1f}x(paper 19.5x)")

    x, y = digits.make_spike_dataset(BATCH, seed=7)
    x, y = jnp.asarray(x).astype(bool), jnp.asarray(y)
    key = jax.random.PRNGKey(1)

    # last tile only: 768 -> 10 (the paper's readout adaptation shape)
    bits = [jax.random.bernoulli(jax.random.PRNGKey(0), 0.5, (768, 10)).astype(jnp.int8)]
    vth = [jnp.full((10,), 2**31 - 1, jnp.int32)]
    _bench_pair(rec, "768x10", bits, vth, x, y, key)

    # full paper topology, frozen prefix: packed fused plane feeds the scan
    topo = (768, 256, 256, 256, 10)
    kw = jax.random.PRNGKey(2)
    bits_full = [
        jax.random.bernoulli(jax.random.fold_in(kw, i), 0.5,
                             (topo[i], topo[i + 1])).astype(jnp.int8)
        for i in range(len(topo) - 1)
    ]
    vth_full = [jnp.zeros((n,), jnp.int32) for n in topo[1:-1]]
    vth_full.append(jnp.full((topo[-1],), 2**31 - 1, jnp.int32))
    _bench_pair(rec, "768x256x256x256x10", bits_full, vth_full, x, y, key)

    # bit-identity of the fused plane vs the reference rule under shared RNG
    b_fused, n_f = learning.online_learning_epoch(
        bits, vth, x, y, key, p_pot=0.2, p_dep=0.1)
    b_ref, n_r = learning.online_learning_epoch_scan(
        bits, vth, x, y, key, p_pot=0.2, p_dep=0.1, rng_scheme="column")
    identical = bool((np.asarray(b_fused) == np.asarray(b_ref)).all()
                     and int(n_f) == int(n_r))
    rec.emit("learning_bit_identity", 0.0,
             f"fused_vs_reference_rule_shared_rng={identical};batch={BATCH}")
    assert identical, "column-event epoch diverged from the reference rule"

    rec.write_json(os.environ.get("BENCH_LEARNING_OUT", "BENCH_learning.json"))


if __name__ == "__main__":
    run()

"""Paper Sec 4.4.1: transposable-port online-learning column access —
reproduces the 26.0x / 19.5x read/write speedups and runs one measured
STDP epoch with its cost accounting."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core.esam import cost_model as cm, learning
from repro.data import digits


def run():
    base = learning.column_update_cost(0)
    c4 = learning.column_update_cost(4)
    emit("learning_1rw_baseline", 0.0,
         f"col_read_ns={base.read_ns:.1f};col_write_ns={base.write_ns:.1f};"
         f"energy_pj={base.energy_pj:.1f}")
    emit("learning_4r_transposed", 0.0,
         f"col_read_ns={c4.read_ns};col_write_ns={c4.write_ns};"
         f"read_speedup={c4.speedup_read_vs_1rw:.1f}x(paper 26.0x);"
         f"write_speedup={c4.speedup_write_vs_1rw:.1f}x(paper 19.5x)")

    # measured online-learning epoch (supervised stochastic STDP, Sec 2.2/[16])
    x, y = digits.make_spike_dataset(512, seed=7)
    x, y = jnp.asarray(x).astype(bool), jnp.asarray(y)
    bits = jax.random.bernoulli(jax.random.PRNGKey(0), 0.5, (768, 10)).astype(jnp.int8)
    vth = [jnp.full((10,), 2**31 - 1, jnp.int32)]

    def epoch(b):
        return learning.online_learning_epoch([b], vth, x, y, jax.random.PRNGKey(1),
                                              p_pot=0.2, p_dep=0.1)

    us, (bits2, n_updates) = time_call(epoch, bits, repeats=1)
    t_4r_us = n_updates * (c4.read_ns + c4.write_ns) * 1e-3
    t_1rw_us = n_updates * (base.read_ns + base.write_ns) * 1e-3
    e_4r_nj = n_updates * c4.energy_pj * 1e-3
    e_1rw_nj = n_updates * base.energy_pj * 1e-3
    emit("learning_epoch_cost", us,
         f"column_updates={n_updates};hw_time_4r_us={t_4r_us:.1f};"
         f"hw_time_1rw_us={t_1rw_us:.1f};hw_energy_4r_nj={e_4r_nj:.1f};"
         f"hw_energy_1rw_nj={e_1rw_nj:.1f};"
         f"end_to_end_speedup={t_1rw_us/t_4r_us:.1f}x")


if __name__ == "__main__":
    run()

"""Paper Table 2: pipeline stage durations + clock period per cell option,
plus the arbiter critical-path claim (tree vs flat, Sec 3.3)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core.esam import cost_model as cm
from repro.kernels.arbiter import ops as arb_ops


def run():
    for p in range(5):
        spec = cm.cell_spec(p)
        bottleneck = "arbiter" if spec.arbiter_ns >= spec.sram_neuron_ns else "sram+neuron"
        emit(
            f"table2_{spec.name}",
            0.0,
            f"arbiter_ns={spec.arbiter_ns};sram_neuron_ns={spec.sram_neuron_ns};"
            f"clock_ns={spec.clock_ns};bottleneck={bottleneck}",
        )
    # 4R system clock ~ published 810 MHz
    emit("table2_clock_check", 0.0,
         f"clock_mhz={cm.cell_spec(4).clock_hz/1e6:.0f};paper=810")
    # arbiter kernel timing (TPU plane, interpret mode -> functional only)
    req = jax.random.bernoulli(jax.random.PRNGKey(0), 0.4, (8, 128)).astype(jnp.int8)
    us, _ = time_call(lambda r: arb_ops.arbiter(r, ports=4, interpret=True), req)
    emit("arbiter_kernel_128x4", us,
         f"tree_path_ps={cm.ARBITER_TREE_CRITICAL_PATH_PS};"
         f"flat_path_ps={cm.ARBITER_FLAT_CRITICAL_PATH_PS};"
         f"area_overhead={cm.ARBITER_TREE_AREA_OVERHEAD}")


if __name__ == "__main__":
    run()

"""JAX version compatibility shims.

The repo targets the current jax API (``jax.shard_map`` with ``check_vma``,
``jax.make_mesh(..., axis_types=...)``) but must also run on the baked-in
0.4.x toolchain, where ``shard_map`` lives in ``jax.experimental`` with the
older ``check_rep`` spelling and meshes have no axis types.  Route every
mesh/shard_map construction through here instead of calling jax directly.
"""

from __future__ import annotations

from typing import Sequence

import jax

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def make_mesh(shape: Sequence[int], names: Sequence[str]) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(
            tuple(shape), tuple(names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(names)),
        )
    return jax.make_mesh(tuple(shape), tuple(names))


def cost_analysis(compiled) -> dict:
    """Normalized ``Compiled.cost_analysis()``: newer jax returns a flat dict,
    0.4.x returns a one-element list of dicts (per partition)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Version-stable shard_map: maps ``check`` onto check_vma / check_rep."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )

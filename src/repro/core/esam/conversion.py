"""BNN -> binary-SNN conversion with per-neuron thresholds (Sec 4.4.2, [15]).

The conversion is *exact*: the SNN's spike pattern equals the BNN's binary
activation pattern layer-by-layer, and the SNN readout is an argmax-preserving
affine transform of the BNN logits.  Derivation (all integer arithmetic):

First tile (inputs are {0,1} spikes s):
    BNN fires:   W.s + b >= 0   <=>   W.s >= -b          => V_th = ceil(-b)

Hidden tiles (BNN activation a = 2s - 1 in {-1,+1}):
    W.a + b = 2 W.s - colsum(W) + b >= 0
                               <=>  W.s >= (colsum - b)/2 => V_th = ceil((colsum-b)/2)

Output tile (real logits, no threshold):
    logits = W.a + b = 2 (V_mem + (b - colsum)/2)
    => per-neuron readout offset (b - colsum)/2; argmax unchanged.

V_mem is integer because spikes are {0,1} and weights {-1,+1}; "k >= x  <=>
k >= ceil(x)" for integer k makes ceil the exact threshold.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.esam import bnn as bnn_mod
from repro.core.esam.network import EsamNetwork


def bnn_to_snn(params: list[dict]) -> EsamNetwork:
    weight_bits, vth = [], []
    offset = None
    for i, layer in enumerate(params):
        wb = bnn_mod.sign_pm1(layer["w"])                  # {-1,+1}
        bits = ((wb + 1) // 2).astype(jnp.int8)            # {0,1} stored bits
        b = layer["b"]
        if i == len(params) - 1:
            # Output tile: readout only (V_th = inf, never fires).  Its
            # inputs are {0,1} spikes for a single-layer network (logits =
            # W.s + b, so the offset is just b) and {-1,+1} activations
            # otherwise (the (b - colsum)/2 fold of the module docstring).
            theta = jnp.full((wb.shape[1],), jnp.inf)
            offset = b if i == 0 else (b - wb.sum(axis=0)) / 2.0
        elif i == 0:
            theta = jnp.ceil(-b)
        else:
            theta = jnp.ceil((wb.sum(axis=0) - b) / 2.0)
        weight_bits.append(bits)
        vth.append(
            jnp.where(jnp.isinf(theta), jnp.iinfo(jnp.int32).max, theta).astype(jnp.int32)
        )
    return EsamNetwork(weight_bits=weight_bits, vth=vth, out_offset=offset)

"""Execution plans: ONE compiled entry point for every ESAM forward variant.

Event-based CIM accelerators get their efficiency from a *fixed dataflow
plan*: the schedule of a layer-stationary pipeline is decided once, before
any spike moves (Chauvaux et al.; Moitra et al.).  This module is that plan
layer for the repo.  An :class:`EsamPlan` is built once from

    (EsamNetwork, mode, collect, telemetry, read_ports, sharding rules)

and compiles exactly one jitted — or, with sharding rules, one
``shard_map``-ped — executable.  Every consumer (the seven legacy
``EsamNetwork.forward*`` wrappers, ``port_sweep``, ``measured_activity``,
the online-learning driver, the serving engine, the benchmarks) runs through
a plan, so the packing, prefix-reuse, popcount-telemetry and cost plumbing
lives here and nowhere else.

Modes
-----
``functional``  dense MAC cascade (bool spikes between tiles) — the oracle.
``packed``      the bit-packed fused cascade: uint32 bitplanes on the wire,
                Pallas MAC+fire+re-pack per hidden tile (the fast plane).
``prefix``      hidden tiles only; returns the last tile's *input* plane
                (packed when every hidden width is 32-aligned, else bool) —
                what the online-learning plane reuses across epochs.
``cycle``       the rank-schedule cycle-accurate plane; with a tuple of
                cell options in ``read_ports`` it becomes the full Fig 8
                port sweep compiled as one executable.
``temporal``    the multi-timestep LIF plane (``core/esam/temporal.py``):
                one jitted membrane-resident ``lax.scan`` over a
                ``[T, batch, n_in]`` event stream; requires a
                :class:`~repro.core.esam.temporal.TemporalConfig`.  With
                T=1, zero leak and zero reset it is bit-identical to
                ``packed`` (property-tested).

Orthogonal flags: ``collect`` returns the inter-tile planes, ``telemetry``
returns the per-tile arbiter loads (group popcounts straight off the wire).

Sharding
--------
Pass :class:`~repro.distributed.sharding.ShardingRules` built by
``sharding.make_esam_rules``: the batch is sharded over the ``spike_batch``
mesh axes (weights replicated), and hidden-layer columns are additionally
sharded over the ``tile_col`` axis where widths divide evenly — each device
fires its slice of a tile's neurons and the fired plane is all-gathered onto
the inter-tile pulse bus.  Both layouts are bit-identical to the
single-device plan (integer datapath, deterministic gather order; enforced
by tests on an ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` mesh).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import packing
from repro.core.esam import arbiter as arb
from repro.core.esam import faults as faults_mod
from repro.core.esam import neuron as nrn
from repro.core.esam import tile as tile_mod
from repro.core.esam import temporal as temporal_mod

MODES = ("functional", "packed", "prefix", "cycle", "temporal")


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """Static description of one compiled ESAM executable."""

    mode: str = "packed"
    collect: bool = False
    telemetry: bool = False
    #: cell option(s).  An int for a single plan; a tuple of cell options
    #: turns ``cycle`` mode into the one-executable port sweep.
    read_ports: int | tuple[int, ...] = 4
    record_vmem_trace: bool = False
    interpret: Optional[bool] = None
    #: temporal mode only: the LIF dynamics (T, leak, reset, refractory) —
    #: part of the cache key, so each (T, collect, telemetry) spec compiles
    #: exactly one executable.
    temporal: Optional[temporal_mod.TemporalConfig] = None
    #: fault population injected into the datapath (frozen + hashable, so
    #: each FaultModel is its own cache entry).  ``None`` compiles the clean
    #: plan, bit-identical to pre-fault builds (property-tested).
    faults: Optional[faults_mod.FaultModel] = None
    #: donate the input buffer to XLA so allocations are reused across rounds
    #: (the serving engine's drain loop dispatches a fresh padded batch per
    #: round).  Only safe when every caller hands the plan arrays it owns —
    #: a donated array is invalidated by the call.
    donate: bool = False

    def __post_init__(self):
        assert self.mode in MODES, (self.mode, MODES)
        if isinstance(self.read_ports, tuple):
            assert self.mode == "cycle", "read_ports sweep needs mode='cycle'"
        if self.mode == "temporal":
            assert self.temporal is not None, (
                "mode='temporal' needs a TemporalConfig")
        else:
            assert self.temporal is None, (self.mode, self.temporal)


@dataclasses.dataclass
class PlanResult:
    """Outputs of one plan execution (fields populated per spec).

    ``planes`` carries what travels the inter-tile wire in that mode: the
    hidden layers' output spikes (``functional``) or the tile-input uint32
    bitplanes including the network input (``packed``) — matching what the
    legacy ``forward(collect=True)`` / ``forward_fused_packed_collect``
    returned.  ``loads`` are int32 arbiter loads per tile input,
    ``[..., n_groups]`` — the cost model's measured activity.  In temporal
    mode ``planes``/``loads`` gain a per-timestep axis after the batch:
    ``[..., T, n_words]`` / ``[..., T, n_groups]`` (batch-first so one
    sharding spec covers every mode).
    """

    logits: Optional[jax.Array] = None
    planes: Optional[tuple] = None
    loads: Optional[tuple] = None
    traces: Optional[tuple] = None           # TileTrace per tile (cycle mode)
    prefix: Optional[jax.Array] = None       # prefix mode only
    sweep: Optional[Mapping[int, Any]] = None  # {cell option: {logits, traces}}


def _packed_cascade(
    weight_bits: Sequence[jax.Array],
    vth: Sequence[jax.Array],
    packed: jax.Array,
    *,
    interpret: Optional[bool] = None,
    collect: bool = False,
    col_axis: Optional[str] = None,
    col_shard: Optional[Sequence[bool]] = None,
):
    """Cascade the hidden tiles (all but the last) on the packed plane.

    The single source of the packed prefix datapath: inference
    (``EsamPlan`` packed/prefix modes, the legacy ``forward*`` wrappers) and
    the online-learning plane (``learning.last_hidden_spikes``) all run
    their frozen tiles through here, so the learning plane's pre-synaptic
    trace can never desynchronize from the serving datapath.

    Hidden widths must be multiples of 32 (128-aligned tile columns in every
    paper topology) so fired planes re-pack exactly.  Under ``tile_col``
    sharding (``col_axis`` inside a shard_map) each device holds a 32-aligned
    column slice of the flagged layers and the fired plane is all-gathered —
    word order equals column order, so the gathered plane is bit-identical
    to the unsharded wire.

    ``collect=True`` returns (prefix, [tile-input bitplane per tile]).
    """
    from repro.kernels.cim_matmul_packed import ops as packed_ops

    for w in weight_bits[:-1]:
        assert w.shape[1] % 32 == 0, (
            "hidden width must be 32-aligned for the packed plane",
            w.shape,
        )
    p = packed
    planes = [p]
    for i, (w, th) in enumerate(zip(weight_bits[:-1], vth[:-1])):
        p = packed_ops.esam_layer_packed(p, w, th, interpret=interpret)
        if col_shard is not None and col_shard[i]:
            p = jax.lax.all_gather(p, col_axis, axis=-1, tiled=True)
        planes.append(p)
    if collect:
        return p, planes
    return p


class EsamPlan:
    """One compiled ESAM executable, built once and reused for every batch.

    Call the plan with spikes ``{0,1}[..., n_in]`` (any dtype / leading
    shape) or, for the packed modes, pre-packed ``uint32[..., n_in/32]``
    wire-format planes; leading dims are flattened into one batch axis, the
    batch is zero-padded to the sharding's divisibility requirement (exact:
    a silent spike never contributes to the CIM MAC), and every output is
    unpadded and reshaped back.  Returns a :class:`PlanResult`.
    """

    def __init__(
        self,
        network,
        spec: PlanSpec,
        rules=None,  # Optional[sharding.ShardingRules]
    ):
        self.spec = spec
        self.rules = rules
        self.network = network
        self.topology = network.topology
        n_tiles = len(self.topology) - 1
        hidden_ok = all(
            w.shape[1] % 32 == 0 for w in network.weight_bits[:-1]
        )
        if spec.mode in ("packed", "temporal"):
            assert hidden_ok, (
                "packed/temporal plans need 32-aligned hidden widths",
                self.topology)
        #: prefix mode runs packed when the hidden widths allow it, else the
        #: dense functional tiles — both bit-identical (tests/test_packing).
        self.prefix_packed = spec.mode == "prefix" and hidden_ok
        self._packed_input = (
            spec.mode in ("packed", "temporal") or self.prefix_packed)
        self._n_in = self.topology[0]
        self._in_width = (
            packing.packed_width(self._n_in) if self._packed_input else self._n_in
        )

        # -------- sharding geometry (static, decided at build time) -------
        if rules is None:
            self._batch_axes: tuple[str, ...] = ()
            self._col_axis = None
            self._dp = 1
            col_size = 1
        else:
            self._batch_axes = rules.mesh_axes("spike_batch")
            assert self._batch_axes, "ESAM rules must map spike_batch"
            self._dp = rules.axis_size("spike_batch")
            col_axes = rules.mesh_axes("tile_col")
            assert len(col_axes) <= 1, "tile_col maps to at most one mesh axis"
            self._col_axis = col_axes[0] if col_axes else None
            col_size = rules.axis_size("tile_col")
            if spec.mode in ("cycle", "temporal"):
                assert col_size == 1, (
                    f"{spec.mode} plans are data-parallel only")
        lane = packing.LANE_BITS if self._packed_input else 1
        self._col_shard = tuple(
            self._col_axis is not None
            and i < n_tiles - 1
            and self.topology[i + 1] % (col_size * lane) == 0
            and col_size > 1
            for i in range(n_tiles)
        )

        # -------- fault masks (drawn once, at plan build) -----------------
        # Cycle-sweep plans need one upset mask per *effective* port count in
        # the sweep (disturb scales with ports); every other mode reads at
        # the plan's single port count.  Counter-based generation makes the
        # masks identical across device counts, so sharded faulted plans stay
        # bit-identical to single-device (the masks just ride the replicated/
        # column-sharded param specs).
        if spec.faults is not None:
            if spec.mode == "cycle" and isinstance(spec.read_ports, tuple):
                opts = spec.read_ports
            else:
                opts = (spec.read_ports if isinstance(spec.read_ports, int)
                        else 4,)
            self._fault_ports = tuple(
                sorted({max(1, int(o)) for o in opts}))
            self._fault_masks = spec.faults.build_masks(
                self.topology, self._fault_ports)
        else:
            self._fault_ports = ()
            self._fault_masks = None

        # -------- operand prep (hoisted out of every call) ----------------
        # The compiled executable never sees raw {0,1}[K, N] stored bits: it
        # closes over mode-native operands — ±1 decodes, uint32 weight bit
        # planes, the mega-kernel DMA slabs — sliced ONCE here (and again
        # only if the network's parameter arrays are swapped; see _prepare).
        #: packed plans run the single-launch popcount mega kernel unless a
        #: tile column is sharded (the inter-tile all_gather cannot happen
        #: inside one launch) — then per-tile popcount kernels + gather.
        self._use_mega = spec.mode == "packed" and not any(self._col_shard)
        self._eff_ports = (max(1, int(spec.read_ports))
                           if isinstance(spec.read_ports, int) else None)
        self._prep_key = None
        self._prep_src = None    # strong refs pin ids against reuse after GC
        self._prep_params = None
        #: AOT-compiled executables keyed on padded batch size (``warmup``).
        #: Compiled objects take the prepped params as a runtime argument, so
        #: a parameter swap (same shapes) never invalidates them.
        self._aot: dict[int, Any] = {}
        self._exec = self._compile()

    # ------------------------------------------------------------------ #
    # operand prep: decode / bit-slice / fault once, serve every batch
    # ------------------------------------------------------------------ #
    def _cycle_port_options(self) -> tuple[int, ...]:
        rp = self.spec.read_ports
        options = rp if isinstance(rp, tuple) else (rp,)
        return tuple(sorted({max(1, int(o)) for o in options}))

    def _build_params(self, wb, vth, off):
        """Mode-native operands from the network's stored bits.

        Fault masks were drawn at build time; applying them here (eagerly,
        outside the executable) keeps every per-call trace free of both the
        {0,1} -> ±1 decode and the mask arithmetic.  Counter-based masks make
        the prepped operands identical across device counts, so sharded
        faulted plans stay bit-identical to single-device.
        """
        from repro.kernels.cim_popcount import ops as pop_ops

        spec, fmk = self.spec, self._fault_masks
        if fmk is not None:
            vth = tuple(faults_mod.faulted_vth(vth, fmk))
            if spec.mode != "cycle":
                wb = tuple(faults_mod.faulted_weights(wb, fmk, self._eff_ports))
        params: dict[str, Any] = {"vth": vth, "out_offset": off}
        if spec.mode == "functional" or (
            spec.mode == "prefix" and not self.prefix_packed
        ):
            params["w_signed"] = tuple(nrn.decode_bitlines(w) for w in wb)
        elif spec.mode in ("packed", "prefix"):
            planes = tuple(packing.pack_weight_planes(w) for w in wb)
            if self._use_mega:
                w_stack, vth_stack = pop_ops.stack_cascade_operands(
                    planes, vth, self.topology)
                params["w_stack"], params["vth_stack"] = w_stack, vth_stack
            else:
                params["w_planes"] = planes
        elif spec.mode == "temporal":
            # both dispatch targets: uint32 planes for the popcount kernel
            # path, the pre-decoded ±1 f32 operand for the BLAS ref path
            params["w_planes"] = tuple(packing.pack_weight_planes(w) for w in wb)
            params["w_signed_f32"] = tuple(
                2.0 * w.astype(jnp.float32) - 1.0 for w in wb)
        else:  # cycle — one ±1 decode per effective port count in the sweep
            by_ports: dict[int, tuple] = {}
            clean = None
            for ports in self._cycle_port_options():
                if fmk is not None:
                    wb_p = faults_mod.faulted_weights(wb, fmk, ports)
                    by_ports[ports] = tuple(
                        nrn.decode_bitlines(w) for w in wb_p)
                else:
                    # no faults: every port count reads the same array
                    if clean is None:
                        clean = tuple(nrn.decode_bitlines(w) for w in wb)
                    by_ports[ports] = clean
            params["cycle_w_signed"] = by_ports
        return params

    def _prepare(self):
        """Cached prep, re-run only when a parameter array is swapped.

        Keyed on the ids of the network's parameter arrays: jax arrays are
        immutable, so value changes can only arrive as *new* array objects
        (e.g. a learned readout swapped in), which changes the key — a cached
        plan can never serve stale parameters.  ``_prep_src`` holds strong
        references so a freed array's id cannot be reused while cached.
        """
        net = self.network
        src = (*net.weight_bits, *net.vth, net.out_offset)
        key = tuple(map(id, src))
        if key != self._prep_key:
            self._prep_params = self._build_params(
                tuple(net.weight_bits), tuple(net.vth), net.out_offset)
            self._prep_key = key
            self._prep_src = src
        return self._prep_params

    # ------------------------------------------------------------------ #
    # the single compiled executable
    # ------------------------------------------------------------------ #
    def _make_fn(self):
        spec = self.spec
        col_axis = self._col_axis
        col_shard = self._col_shard if any(self._col_shard) else None
        topo = self.topology
        # spec.interpret=True forces the Pallas datapath (in interpret mode
        # off-TPU); the default dispatches kernel-on-TPU / popcount-ref
        # elsewhere, mirroring kernels/arbiter.
        use_kernel = True if spec.interpret else None

        def gather(x):
            return jax.lax.all_gather(x, col_axis, axis=-1, tiled=True)

        def dense_prefix(ws, vth, s):
            hidden = []
            for i, (w, th) in enumerate(zip(ws[:-1], vth[:-1])):
                s, _ = tile_mod.functional_tile(None, s, th, w_signed=w)
                if col_shard is not None and col_shard[i]:
                    s = gather(s)
                hidden.append(s)
            return s, hidden

        def popcount_prefix(planes, vth, p):
            """Per-tile popcount cascade (the col-sharded fallback: fired
            slices all_gather onto the pulse bus between launches)."""
            from repro.kernels.cim_popcount import ops as pop_ops

            collected = [p]
            for i, (w, th) in enumerate(zip(planes[:-1], vth[:-1])):
                p = pop_ops.esam_layer_popcount(
                    p, w, th, use_kernel=use_kernel, interpret=spec.interpret)
                if col_shard is not None and col_shard[i]:
                    p = gather(p)
                collected.append(p)
            return p, collected

        def fn(params, x):
            vth = params["vth"]
            off = params["out_offset"]
            out: dict[str, Any] = {}
            if spec.mode == "functional":
                ws = params["w_signed"]
                s, hidden = dense_prefix(ws, vth, x)
                _, vmem = tile_mod.functional_tile(
                    None, s, vth[-1], w_signed=ws[-1])
                out["logits"] = vmem.astype(jnp.float32) + off
                if spec.collect:
                    out["planes"] = tuple(hidden)
                if spec.telemetry:
                    out["loads"] = tuple(
                        arb.split_row_groups(si.astype(jnp.int32)).sum(-1)
                        for si in [x, *hidden]
                    )
            elif spec.mode == "packed":
                from repro.kernels.cim_popcount import ops as pop_ops

                if self._use_mega:
                    vmem, fired = pop_ops.esam_cascade_popcount(
                        x, params["w_stack"], params["vth_stack"],
                        topology=topo, use_kernel=use_kernel,
                        interpret=spec.interpret)
                    planes = (x,) + fired
                else:
                    p, planes = popcount_prefix(params["w_planes"], vth, x)
                    vmem = pop_ops.cim_popcount_matmul(
                        p, params["w_planes"][-1],
                        use_kernel=use_kernel, interpret=spec.interpret)
                out["logits"] = vmem.astype(jnp.float32) + off
                if spec.collect:
                    out["planes"] = tuple(planes)
                if spec.telemetry:
                    out["loads"] = tuple(
                        packing.group_popcount(pl) for pl in planes
                    )
            elif spec.mode == "prefix":
                if self.prefix_packed:
                    p, planes = popcount_prefix(params["w_planes"], vth, x)
                else:
                    p, planes_b = dense_prefix(params["w_signed"], vth, x)
                    planes = [x, *planes_b]
                out["prefix"] = p
                if spec.collect:
                    out["planes"] = tuple(planes)
                if spec.telemetry:
                    out["loads"] = tuple(
                        packing.group_popcount(pl) if self.prefix_packed
                        else arb.split_row_groups(pl.astype(jnp.int32)).sum(-1)
                        for pl in planes
                    )
            elif spec.mode == "temporal":
                # x: uint32[B, T, n_words] batch-first (shardable); the scan
                # wants time leading, and its stacked outputs come back
                # batch-first from temporal_forward.
                res = temporal_mod.temporal_forward(
                    None, vth, off, x.swapaxes(0, 1), spec.temporal,
                    interpret=spec.interpret,
                    collect=spec.collect, telemetry=spec.telemetry,
                    w_planes=params["w_planes"],
                    w_signed_f32=params["w_signed_f32"],
                    topology=topo)
                out.update(res)
            else:  # cycle
                rp = spec.read_ports
                sweep = isinstance(rp, tuple)
                options = rp if sweep else (rp,)
                by_ports: dict[int, dict] = {}
                per_option: dict[int, dict] = {}
                for opt in options:
                    ports = max(1, int(opt))
                    if ports not in by_ports:
                        traces = []
                        s = x
                        for w_sgn, th in zip(
                                params["cycle_w_signed"][ports], vth):
                            tr = tile_mod.simulate_tile_batch(
                                None, s, th, ports, spec.record_vmem_trace,
                                w_signed=w_sgn)
                            traces.append(tr)
                            s = tr.out_spikes
                        logits = traces[-1].vmem_final.astype(jnp.float32) + off
                        by_ports[ports] = {
                            "logits": logits, "traces": tuple(traces)}
                    per_option[int(opt)] = by_ports[ports]
                if sweep:
                    out["sweep"] = per_option
                else:
                    res = per_option[int(rp)]
                    out["logits"] = res["logits"]
                    out["traces"] = res["traces"]
                if spec.telemetry:
                    any_traces = next(iter(by_ports.values()))["traces"]
                    inputs = [x, *(tr.out_spikes for tr in any_traces[:-1])]
                    out["loads"] = tuple(
                        arb.split_row_groups(si.astype(jnp.int32)).sum(-1)
                        for si in inputs
                    )
            return out

        return fn

    def _compile(self):
        fn = self._make_fn()
        donate = (1,) if self.spec.donate else ()
        if self.spec.donate:
            # CPU/interpret backends may decline the donation (shape-mismatched
            # outputs); that is an optimization miss, not an error worth a
            # per-round warning in the serve loop
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
        if self.rules is None:
            return jax.jit(fn, donate_argnums=donate)
        from repro import compat

        ba = self._batch_axes if len(self._batch_axes) > 1 else self._batch_axes[0]
        ca = self._col_axis
        spec = self.spec
        # operand specs mirror _build_params: ±1 decodes shard like the
        # stored bits (columns = last axis), weight bit planes are
        # column-major so the sharded axis is the leading one
        w_specs = tuple(
            P(None, ca) if sh else P(None, None) for sh in self._col_shard
        )
        p_specs = tuple(
            P(ca, None) if sh else P(None, None) for sh in self._col_shard
        )
        v_specs = tuple(P(ca) if sh else P(None) for sh in self._col_shard)
        params_spec: dict[str, Any] = {
            "vth": v_specs, "out_offset": P(None),
        }
        if spec.mode == "functional" or (
            spec.mode == "prefix" and not self.prefix_packed
        ):
            params_spec["w_signed"] = w_specs
        elif spec.mode in ("packed", "prefix"):
            if self._use_mega:
                params_spec["w_stack"] = P(None, None, None)
                params_spec["vth_stack"] = P(None, None)
            else:
                params_spec["w_planes"] = p_specs
        elif spec.mode == "temporal":
            params_spec["w_planes"] = p_specs
            params_spec["w_signed_f32"] = w_specs
        else:  # cycle (data-parallel only — every operand replicated)
            params_spec["cycle_w_signed"] = {
                p: w_specs for p in self._cycle_port_options()
            }
        x_spec = P(ba, None, None) if self.spec.mode == "temporal" else P(ba, None)
        mapped = compat.shard_map(
            fn,
            mesh=self.rules.mesh,
            in_specs=(params_spec, x_spec),
            out_specs=P(ba),
        )
        return jax.jit(mapped, donate_argnums=donate)

    # ------------------------------------------------------------------ #
    # cold start: AOT warmup of the executable's shape ladder
    # ------------------------------------------------------------------ #
    def _input_struct(self, batch: int) -> jax.ShapeDtypeStruct:
        """Abstract input of one padded batch, as ``_normalize`` produces it."""
        if self.spec.mode == "temporal":
            return jax.ShapeDtypeStruct(
                (batch, self.spec.temporal.n_steps, self._in_width),
                jnp.uint32)
        dtype = jnp.uint32 if self._packed_input else jnp.bool_
        return jax.ShapeDtypeStruct((batch, self._in_width), dtype)

    def warmup(self, batch_sizes: Sequence[int], *,
               aot: bool = True) -> dict[int, float]:
        """Compile this plan's executable ahead of serving, one shape per
        (dp-aligned, padded) batch size — typically an engine's bucket ladder.

        With ``aot=True`` (default) each shape is lowered and compiled once
        and the Compiled object cached on the plan: ``__call__`` then invokes
        it directly, bypassing the jit dispatch cache entirely, so a warmed
        shape can never recompile in the serve path (the cold-start
        regression test asserts ``_exec`` is untouched).  Compiled objects
        take the prepped operands as runtime arguments — swapping parameter
        arrays of the same shape keeps the warmup valid.  ``aot=False``
        instead executes a zeros batch per shape, populating the ordinary
        jit cache (useful where a backend rejects AOT calls).

        Returns ``{batch: seconds}`` compile times — with the persistent
        compilation cache enabled (``launch/env.py``) a re-run's times drop
        to the cache-hit cost, which is what makes cold start instant.
        """
        params = self._prepare()
        times: dict[int, float] = {}
        for b in batch_sizes:
            b = int(b)
            assert b >= 1 and b % self._dp == 0, (b, self._dp)
            t0 = time.perf_counter()
            if aot:
                if b not in self._aot:
                    self._aot[b] = self._exec.lower(
                        params, self._input_struct(b)).compile()
            else:
                struct = self._input_struct(b)
                x = jnp.zeros(struct.shape, struct.dtype)
                jax.block_until_ready(self._exec(params, x))
            times[b] = time.perf_counter() - t0
        return times

    # ------------------------------------------------------------------ #
    # execution
    # ------------------------------------------------------------------ #
    def _normalize(self, x) -> tuple[jax.Array, tuple[int, ...]]:
        """Coerce input to a flat 2-D batch; returns (x2d, leading shape).

        Temporal plans instead take a time-first event stream
        ``[T, ..., n_in]`` (spikes or wire format) and flatten it to a
        batch-first ``uint32[B, T, n_words]`` — time is never a batch axis.
        """
        x = jnp.asarray(x)
        if self.spec.mode == "temporal":
            t = self.spec.temporal.n_steps
            if x.ndim < 2 or x.shape[0] != t:
                raise ValueError(
                    f"temporal plan expects events[{t}, ..., n], got {x.shape}")
            lead = x.shape[1:-1]
            if x.dtype == jnp.uint32 and x.shape[-1] == self._in_width:
                pass                                  # already wire format
            elif x.shape[-1] == self._n_in:
                x = packing.pack_spikes(x != 0)       # spikes -> wire format
            else:
                raise ValueError(
                    f"expected events[{t}, ..., {self._n_in}] or packed "
                    f"uint32[{t}, ..., {self._in_width}], got {x.shape} "
                    f"{x.dtype}")
            return x.reshape(t, -1, x.shape[-1]).swapaxes(0, 1), lead
        lead = x.shape[:-1]
        if self._packed_input:
            if x.dtype == jnp.uint32 and x.shape[-1] == self._in_width:
                pass                                  # already wire format
            elif x.shape[-1] == self._n_in:
                x = packing.pack_spikes(x != 0)       # spikes -> wire format
            else:
                raise ValueError(
                    f"expected spikes[..., {self._n_in}] or packed "
                    f"uint32[..., {self._in_width}], got {x.shape} {x.dtype}")
        else:
            if x.shape[-1] != self._n_in:
                raise ValueError(
                    f"expected spikes[..., {self._n_in}], got {x.shape}")
            x = x.astype(bool)
        return x.reshape(-1, x.shape[-1]), lead

    def __call__(self, x) -> PlanResult:
        x, lead = self._normalize(x)
        b = x.shape[0]
        pad = (-b) % self._dp
        if pad:
            x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
        # operands are prepped from the network's *current* arrays (cached on
        # their ids — see _prepare), so a cached plan can never serve stale
        # parameters, yet no decode/bit-slice survives into the call
        exec_fn = self._aot.get(x.shape[0])
        out = (exec_fn or self._exec)(self._prepare(), x)
        out = jax.tree_util.tree_map(
            lambda a: a[:b].reshape(lead + a.shape[1:]), out)
        return PlanResult(**out)

"""Binary Neural Network training (Sec 4.4.2 setup).

The paper trains the 768:256:256:256:10 network "as a Binary Neural Network
(BNN) with a sign activation function and per-neuron biases", then converts it
to a binary-SNN with per-neuron thresholds (Kim et al. [15]).  This module is
the training half: straight-through-estimator (STE) training of a sign-weight,
sign-activation MLP in pure JAX.

Conventions (must match conversion.py exactly):
  * first-layer inputs are binary spikes in {0,1};
  * hidden activations are sign(z) in {-1,+1} with sign(0) = +1;
  * weights used in the forward pass are sign(latent) in {-1,+1};
  * every layer has a real-valued per-neuron bias;
  * the last layer emits real logits (no activation).
"""

from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp


def sign_pm1(x: jax.Array) -> jax.Array:
    """sign with sign(0) = +1 (the hardware compare is V_mem >= V_th)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def ste_sign(x: jax.Array) -> jax.Array:
    """Forward sign, backward clipped-identity (hard-tanh STE)."""
    clipped = jnp.clip(x, -1.0, 1.0)
    return clipped + jax.lax.stop_gradient(sign_pm1(x) - clipped)


def init_params(key: jax.Array, topology: Sequence[int]) -> list[dict]:
    params = []
    for i in range(len(topology) - 1):
        key, sub = jax.random.split(key)
        fan_in = topology[i]
        w = jax.random.normal(sub, (topology[i], topology[i + 1]), jnp.float32)
        w = w * (1.0 / jnp.sqrt(fan_in))
        params.append({"w": w, "b": jnp.zeros((topology[i + 1],), jnp.float32)})
    return params


def forward(params: list[dict], x01: jax.Array) -> jax.Array:
    """x01: float[..., n_in] in {0,1}.  Returns (scaled) real logits.

    Pre-activations are scaled by 1/sqrt(fan_in) *after* the bias so the STE
    hard-tanh window sees unit-variance inputs; sign((W.x+b)/c) == sign(W.x+b)
    for c>0, so the binary behaviour — and hence the SNN conversion — is
    unaffected (tests/test_bnn_conversion.py checks bit-exactness).
    """
    h = x01
    for i, layer in enumerate(params):
        wb = ste_sign(layer["w"])
        inv = 1.0 / jnp.sqrt(jnp.asarray(layer["w"].shape[0], jnp.float32))
        z = (h @ wb + layer["b"]) * inv
        if i < len(params) - 1:
            h = ste_sign(z)      # hidden activations in {-1,+1}
        else:
            return z
    raise AssertionError


def hidden_activations(params: list[dict], x01: jax.Array) -> list[jax.Array]:
    """Exact (non-STE) hidden +-1 activations, for conversion equivalence tests."""
    h = x01
    acts = []
    for layer in params[:-1]:
        wb = sign_pm1(layer["w"])
        h = sign_pm1(h @ wb + layer["b"])
        acts.append(h)
    return acts


def loss_fn(params, x01, labels):
    logits = forward(params, x01)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return nll, logits


@partial(jax.jit, donate_argnums=(0, 1))
def train_step(params, opt_state, x01, labels, lr):
    """One Adam step.  Tiny bespoke Adam: no optax dependency offline."""
    (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, x01, labels)
    m, v, t = opt_state
    t = t + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mh = jax.tree.map(lambda a: a / (1 - b1**t), m)
    vh = jax.tree.map(lambda a: a / (1 - b2**t), v)
    params = jax.tree.map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), params, mh, vh)
    # Latent-weight clipping keeps the STE window alive (standard BNN practice).
    params = jax.tree.map(lambda p: jnp.clip(p, -1.5, 1.5), params)
    acc = (logits.argmax(-1) == labels).mean()
    return params, (m, v, t), loss, acc


def init_opt_state(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return (zeros, jax.tree.map(jnp.zeros_like, params), jnp.zeros((), jnp.int32))


def fit(
    key: jax.Array,
    topology: Sequence[int],
    x01: jax.Array,
    labels: jax.Array,
    *,
    steps: int = 300,
    batch: int = 128,
    lr: float = 3e-3,
):
    """Train a BNN; returns (params, final train accuracy)."""
    params = init_params(key, topology)
    opt = init_opt_state(params)
    n = x01.shape[0]
    acc = jnp.zeros(())
    for s in range(steps):
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (batch,), 0, n)
        params, opt, _, acc = train_step(params, opt, x01[idx], labels[idx], lr)
    return params, float(acc)

"""ESAM core: the paper's contribution as a composable JAX module.

Planes:
  * functional (batched, MXU-friendly): ``EsamNetwork.forward`` — bit-exact
    with the event-driven plane; this is what the TPU kernels accelerate.
  * packed fused (bit-plane wire format): ``EsamNetwork.forward_fused`` —
    spikes travel between tiles as uint32 bitplanes (32 spikes/word, the
    paper's parallel-pulse bus) through the kernels/cim_matmul_packed
    cascade; logits bit-identical to ``forward``.
  * cycle-accurate (event-driven): ``EsamNetwork.forward_cycle_accurate``
    (+ ``_batch``) + ``system_stats`` — reproduces the paper's
    throughput/energy/power claims from the calibrated 3nm cost model.
"""

from repro.core.esam import arbiter, bnn, conversion, cost_model, faults, learning, neuron, network, plan, tile
from repro.core.esam.faults import FaultModel
from repro.core.esam.network import EsamNetwork, SystemStats, reference_activity, system_stats
from repro.core.esam.plan import EsamPlan, PlanResult, PlanSpec

__all__ = [
    "arbiter",
    "bnn",
    "conversion",
    "cost_model",
    "faults",
    "FaultModel",
    "learning",
    "neuron",
    "network",
    "plan",
    "tile",
    "EsamNetwork",
    "EsamPlan",
    "PlanResult",
    "PlanSpec",
    "SystemStats",
    "system_stats",
    "reference_activity",
]

"""ESAM core: the paper's contribution as a composable JAX module.

Planes:
  * functional (batched, MXU-friendly): ``EsamNetwork.forward`` — bit-exact
    with the event-driven plane; this is what the TPU kernels accelerate.
  * cycle-accurate (event-driven): ``EsamNetwork.forward_cycle_accurate`` +
    ``system_stats`` — reproduces the paper's throughput/energy/power claims
    from the calibrated 3nm cost model.
"""

from repro.core.esam import arbiter, bnn, conversion, cost_model, learning, neuron, network, tile
from repro.core.esam.network import EsamNetwork, SystemStats, reference_activity, system_stats

__all__ = [
    "arbiter",
    "bnn",
    "conversion",
    "cost_model",
    "learning",
    "neuron",
    "network",
    "tile",
    "EsamNetwork",
    "SystemStats",
    "system_stats",
    "reference_activity",
]

"""Calibrated timing/energy/area cost model for the ESAM macro.

Every constant below is either taken verbatim from the paper or derived from a
published anchor; provenance is recorded inline.  The cost model is the
"synthesis + SRAM-macro outcomes" plane of the paper's own methodology
(Sec. 4.1: "synthesis results, combined with the SRAM Macro outcomes, are
utilized to simulate the network on a spike-by-spike basis in Python") — the
cycle-accurate simulator in ``network.py`` consumes these constants to produce
the system-level numbers (throughput, energy/inference, power).

Cell naming: port index p in {0,1,2,3,4} == number of *decoupled read ports*.
p=0 is the standard 6T single-port cell ("1RW"); p>=1 are "1RW+<p>R".
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# ----------------------------------------------------------------------------
# Verbatim paper constants
# ----------------------------------------------------------------------------

#: Table 2 — arbiter stage delay (ns) per cell option [1RW, +1R, +2R, +3R, +4R].
ARBITER_STAGE_NS = (1.01, 1.01, 1.04, 1.03, 1.01)

#: Table 2 — SRAM read + neuron accumulate stage delay (ns).
SRAM_NEURON_STAGE_NS = (0.69, 1.08, 1.18, 1.14, 1.23)

#: Sec 3.3 — 128-wide 4-port arbiter critical path: flat (>1100ps -> <800ps via
#: tree decomposition at +8.0% area).  Used by the arbiter kernel docs/tests.
ARBITER_FLAT_CRITICAL_PATH_PS = 1100.0
ARBITER_TREE_CRITICAL_PATH_PS = 800.0
ARBITER_TREE_AREA_OVERHEAD = 0.08

#: Sec 4.2 — 6T cell area (um^2, [20]) and relative areas of multiport cells.
CELL_AREA_6T_UM2 = 0.01512
CELL_AREA_RATIO = (1.0, 1.5, 1.875, 2.25, 2.625)

#: Sec 4.4.1 — transposed-port (online learning) anchors.
#: 1RW full-array (128 rows) read+write: 2*128 cycles, 257.8 ns, 157 pJ.
T1RW_ARRAY_RW_NS = 257.8
T1RW_ARRAY_RW_PJ = 157.0
#: 4R cell, transposed port: column read 9.9 ns (26.0x less), write 8.04 ns
#: (19.5x less); clock period of the transposed path 1.2 ns; 2*4 cycles due to
#: the 4-to-1 column mux.
T4R_COL_READ_NS = 9.9
T4R_COL_WRITE_NS = 8.04
T4R_TRANSPOSED_CLOCK_NS = 1.2
COL_MUX_FACTOR = 4
#: Decoded baselines behind the published "26.0x / 19.5x less" (Sec 4.4.1):
#: column read on 1RW needs precharge+read = 2 cycles per row access
#: (2*128*1.007 ns = 257.8 ns -> 257.8/9.9 = 26.0x) and column write needs one
#: write per row at the 1RW write time of 1.226 ns (Fig 6-derived;
#: 128*1.226 = 157.0 ns -> 157.0/8.04 = 19.5x).
T1RW_COL_READ_NS = 257.8
T_WRITE_1RW_NS = 1.226
T1RW_COL_WRITE_NS = 128 * T_WRITE_1RW_NS

#: Sec 4.1 / Table 1 — supply / precharge voltages (V).
VDD = 0.700
VPRECH = 0.500

#: Sec 4.2 — selecting Vprech=500mV saves >=43% read energy vs 700mV at the
#: cost of <=19% higher access time (all port counts).
VPRECH_ENERGY_SAVING = 0.43
VPRECH_TIME_PENALTY = 0.19

#: Table 3 — published system-level results for the 1RW+4R configuration.
PAPER_THROUGHPUT_INF_S = 44e6
PAPER_ENERGY_PJ_PER_INF = 607.0
PAPER_POWER_MW = 29.0
PAPER_CLOCK_MHZ = 810.0
PAPER_ACCURACY = 0.9764
PAPER_NEURONS = 778
PAPER_SYNAPSES = 330_000  # ~768*256 + 256*256*2 + 256*10 = 328,192

#: Abstract / Fig 8 — headline ratios vs the 1RW baseline (128x128 array).
PAPER_SPEEDUP_4R = 3.1
PAPER_ENERGY_EFF_4R = 2.2

#: Network topology of the paper's MNIST system (Sec 4.4.2).
PAPER_TOPOLOGY = (768, 256, 256, 256, 10)

#: SRAM array size limit (Sec 4.1, NBL-assist V_WD >= -400 mV yield rule).
MAX_ARRAY_ROWS = 128
MAX_ARRAY_COLS = 128

# ----------------------------------------------------------------------------
# Derived / calibrated constants
# ----------------------------------------------------------------------------
# Anchor: 1RW transposed-port average read+write energy per row access
#   157 pJ / 256 accesses = 0.613 pJ.  Fig 6 shows write cost > read cost; we
#   split 0.613 into read 0.48 / write 0.75 (pJ) keeping the published mean.
E_READ_1RW_PJ = 0.48
E_WRITE_1RW_PJ = 0.75

#: Decoupled single-ended read ports run at Vprech=500mV -> >=43% lower energy
#: (Sec 4.2).  Fig 7: average per-access energy is roughly flat for 1..3 ports
#: and rises at the 4th (bigger cell -> more BL parasitics).  Per-read-access
#: energy (pJ) for p = 1..4 decoupled ports:
E_READ_PORT_PJ = (0.285, 0.272, 0.268, 0.292)

#: Write energy via the transposed port grows with ports (Fig 6: parasitics +
#: lower V_WD).  pJ per cell-column write access, p = 0..4:
E_WRITE_PORT_PJ = (0.75, 0.95, 1.10, 1.22, 1.35)

#: Transposed-port read energy also grows with added ports (narrower, more
#: resistive WL; Fig 6).  pJ per row/column read access, p = 0..4:
E_TREAD_PORT_PJ = (0.48, 0.60, 0.68, 0.74, 0.80)

#: Periphery energy per *active* clock cycle, calibrated so the 4R system hits
#: the published 607 pJ/Inf & 29 mW envelope (V2) while the same constants
#: reproduce the 3.1x / 2.2x ratios (V1).  Split per subcomponent:
E_ARBITER_PJ_PER_CYCLE_128 = 0.20    # one 128-wide arbiter slice, any p (Sec 3.3)
E_NEURON_ACCUM_PJ = 0.003            # one neuron accumulating one cycle
E_NEURON_FIRE_PJ = 0.030             # threshold compare + Vmem reset + handshake
E_TILE_CLOCKTREE_PJ_PER_CYCLE = 0.25 # clock/control per 128x128 array per cycle

#: Static (leakage) power of the full MNIST system, mW.  3nm design at 700 mV;
#: calibrated to close the (power - dynamic) gap at the published operating point.
STATIC_POWER_MW = 1.5

#: Fraction of a 6T 128x128 array's area taken by periphery (arbiter incl. its
#: +8% tree overhead, sense amps, neuron array, control).  Calibrated so the
#: system-level area ratio 4R/1RW equals the published 2.4x (Sec 4.4.2) given
#: the 2.625x cell-area ratio: (2.625+q)/(1+q) = 2.4  ->  q = 0.1607.
PERIPHERY_AREA_FRACTION = 0.1607

#: Reference activity profile used for the paper-comparison benchmarks: spikes
#: per 128-row group for each tile of the 768:256:256:256:10 network.  L1 input
#: activity 53% (=68/128), hidden-layer activity 50% (=64/128) — chosen once so
#: the 1RW+4R system lands on the published V2 operating point; the SAME profile
#: must then reproduce V1's 3.1x/2.2x and the Fig-8 trends with no further
#: freedom (checked in tests/benchmarks).  Benchmarks also report the measured
#: profile from the trained BNN side by side.
REF_SPIKES_PER_GROUP = (68, 64, 64, 64)


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """Electrical/timing spec of one SRAM cell option."""

    name: str
    read_ports: int            # decoupled inference read ports (0 => use RW port)
    clock_ns: float            # system clock period (max of Table 2 stages)
    arbiter_ns: float
    sram_neuron_ns: float
    area_ratio: float
    e_read_pj: float           # energy of one inference row-read access
    e_write_pj: float          # transposed-port write access energy
    e_tread_pj: float          # transposed-port read access energy

    @property
    def ports(self) -> int:
        """Usable parallel inference ports (the 1RW cell reads via its RW port)."""
        return max(1, self.read_ports)

    @property
    def clock_hz(self) -> float:
        return 1e9 / self.clock_ns


def cell_spec(read_ports: int) -> CellSpec:
    """Return the spec for the cell with ``read_ports`` decoupled ports (0..4)."""
    if not 0 <= read_ports <= 4:
        raise ValueError(f"read_ports must be in 0..4, got {read_ports}")
    p = read_ports
    return CellSpec(
        name="1RW" if p == 0 else f"1RW+{p}R",
        read_ports=p,
        clock_ns=max(ARBITER_STAGE_NS[p], SRAM_NEURON_STAGE_NS[p]),
        arbiter_ns=ARBITER_STAGE_NS[p],
        sram_neuron_ns=SRAM_NEURON_STAGE_NS[p],
        area_ratio=CELL_AREA_RATIO[p],
        e_read_pj=E_READ_1RW_PJ if p == 0 else E_READ_PORT_PJ[p - 1],
        e_write_pj=E_WRITE_PORT_PJ[p],
        e_tread_pj=E_TREAD_PORT_PJ[p],
    )


ALL_CELLS = tuple(cell_spec(p) for p in range(5))


def array_area_um2(read_ports: int, rows: int = 128, cols: int = 128) -> float:
    """Cell-array area (um^2) for one SRAM array."""
    return CELL_AREA_6T_UM2 * CELL_AREA_RATIO[read_ports] * rows * cols


def tile_geometry(n_in: int, n_out: int) -> tuple[int, int]:
    """(row groups, column groups) of 128x128 arrays for an n_in x n_out tile."""
    return -(-n_in // MAX_ARRAY_ROWS), -(-n_out // MAX_ARRAY_COLS)


def spare_column_area_um2(
    topology: Sequence[int], spare_cols: int, read_ports: int
) -> float:
    """Area overhead (um^2) of ``spare_cols`` redundant columns per tile.

    The column-remapping mitigation (``faults.FaultModel.spare_cols``) buys
    its accuracy back with silicon: each spare column spans every 128-row
    group of its tile, at the chosen cell option's area ratio.  Only cell
    area is charged — the remap itself is a build-time address swizzle, so
    the arbiter/neuron periphery is unchanged.
    """
    area = 0.0
    per_cell = CELL_AREA_6T_UM2 * CELL_AREA_RATIO[read_ports]
    for t in range(len(topology) - 1):
        n_groups, _ = tile_geometry(topology[t], topology[t + 1])
        area += n_groups * MAX_ARRAY_ROWS * spare_cols * per_cell
    return area


@dataclasses.dataclass(frozen=True)
class RequestStats:
    """Per-request hardware cost of a batch of inferences (paper units).

    Every field is a numpy array with leading batch axis B; the system-level
    aggregates in ``network.system_stats`` are means over these, so a serving
    plane can report the same paper-unit telemetry per request.
    """

    read_ports: int
    cycles_per_tile: np.ndarray   # float64[B, T] — drain cycles + 1 fire cycle
    cycles: np.ndarray            # float64[B] — sum over tiles (pipeline latency)
    latency_ns: np.ndarray        # float64[B]
    energy_pj: np.ndarray         # float64[B]


def request_stats(
    topology: Sequence[int],
    spikes_per_group: Sequence[np.ndarray] | Sequence[Sequence[float]],
    read_ports: int,
) -> RequestStats:
    """Per-sample hardware cost from measured arbiter loads.

    Args:
      topology: e.g. (768, 256, 256, 256, 10).
      spikes_per_group: per tile, array[..., n_groups] of arbiter loads for a
        batch of requests (the measured activity of each 128-row group).
      read_ports: 0 (=1RW baseline) .. 4.

    This is the single source of the energy/latency formulas:
    ``network.system_stats`` evaluates an operating point by averaging these
    per-request numbers, and ``serve.SpikeEngine`` attaches them to every
    served request.
    """
    spec = cell_spec(read_ports)
    p = spec.ports
    n_tiles = len(topology) - 1

    cycles_pt, energy = [], None
    for t in range(n_tiles):
        n_in, n_out = topology[t], topology[t + 1]
        n_groups, n_colgroups = tile_geometry(n_in, n_out)
        loads = np.asarray(spikes_per_group[t], dtype=np.float64)
        loads = loads.reshape(-1, n_groups)              # [B, groups]
        drain = np.ceil(loads / p)                       # cycles per group
        tile_cycles = drain.max(axis=1) + 1.0            # +1: compare/fire cycle
        cycles_pt.append(tile_cycles)

        reads = loads.sum(axis=1) * n_colgroups          # row-read accesses
        e = reads * spec.e_read_pj
        e += tile_cycles * n_groups * E_ARBITER_PJ_PER_CYCLE_128
        e += tile_cycles * n_out * E_NEURON_ACCUM_PJ
        e += n_out * E_NEURON_FIRE_PJ
        e += tile_cycles * n_groups * n_colgroups * E_TILE_CLOCKTREE_PJ_PER_CYCLE
        energy = e if energy is None else energy + e

    cycles_per_tile = np.stack(cycles_pt, axis=1)        # [B, T]
    cycles = cycles_per_tile.sum(axis=1)
    return RequestStats(
        read_ports=read_ports,
        cycles_per_tile=cycles_per_tile,
        cycles=cycles,
        latency_ns=cycles * spec.clock_ns,
        energy_pj=energy,
    )


def request_stats_device(
    topology: Sequence[int],
    loads: Sequence,      # per tile, jnp int32[..., n_groups] arbiter loads
    read_ports: int,
) -> dict:
    """``request_stats`` computed on-device (jnp, float32) — no host sync.

    Same formulas as :func:`request_stats`, evaluated lazily on jax arrays so
    a serving plane can accumulate telemetry device-resident and pay ONE host
    transfer per ``stats()`` call instead of one per batch.  float32 agrees
    with the float64 numpy accounting to ~1e-7 relative (tested); cycle
    counts are small integers and stay exact.

    Returns {"cycles_per_tile": f32[B, T], "cycles": f32[B],
    "latency_ns": f32[B], "energy_pj": f32[B]}.
    """
    import jax.numpy as jnp

    spec = cell_spec(read_ports)
    p = spec.ports
    n_tiles = len(topology) - 1
    assert len(loads) == n_tiles, (len(loads), n_tiles)

    cycles_pt, energy = [], None
    for t in range(n_tiles):
        n_in, n_out = topology[t], topology[t + 1]
        n_groups, n_colgroups = tile_geometry(n_in, n_out)
        ld = jnp.asarray(loads[t]).astype(jnp.float32)
        ld = ld.reshape(-1, n_groups)
        drain = jnp.ceil(ld / p)
        tile_cycles = drain.max(axis=1) + 1.0
        cycles_pt.append(tile_cycles)

        reads = ld.sum(axis=1) * n_colgroups
        e = reads * spec.e_read_pj
        e += tile_cycles * (n_groups * E_ARBITER_PJ_PER_CYCLE_128)
        e += tile_cycles * (n_out * E_NEURON_ACCUM_PJ)
        e += n_out * E_NEURON_FIRE_PJ
        e += tile_cycles * (n_groups * n_colgroups * E_TILE_CLOCKTREE_PJ_PER_CYCLE)
        energy = e if energy is None else energy + e

    cycles_per_tile = jnp.stack(cycles_pt, axis=1)
    cycles = cycles_per_tile.sum(axis=1)
    return {
        "cycles_per_tile": cycles_per_tile,
        "cycles": cycles,
        "latency_ns": cycles * spec.clock_ns,
        "energy_pj": energy,
    }


def temporal_request_stats(
    topology: Sequence[int],
    loads: Sequence[np.ndarray],   # per tile, int[B, T, n_groups] per-step loads
    read_ports: int,
) -> dict:
    """Per-request hardware cost of an *event stream* (numpy, float64).

    Every timestep is one full drain of the paper's pipeline — the arbiter
    schedules that step's events, neurons accumulate, R_empty fires — so the
    per-step cost is exactly :func:`request_stats` evaluated on that step's
    *measured* activity (the group popcounts of the inter-step bitplanes),
    and a stream's cost is the sum over its T steps.  Leak/reset/refractory
    ride the existing fire-cycle and neuron-fire terms: they happen on the
    same R_empty event, on the same membrane register.

    Returns {"cycles_per_tile": f64[B, n_tiles] (summed over steps),
    "cycles": f64[B], "latency_ns": f64[B], "energy_pj": f64[B],
    "energy_pj_per_step": f64[B], "n_steps": T}.
    """
    b, t = np.asarray(loads[0]).shape[:2]
    flat = [np.asarray(ld, np.float64).reshape(b * t, -1) for ld in loads]
    rs = request_stats(topology, flat, read_ports)
    n_tiles = len(topology) - 1
    cycles_per_tile = rs.cycles_per_tile.reshape(b, t, n_tiles).sum(axis=1)
    cycles = rs.cycles.reshape(b, t).sum(axis=1)
    energy = rs.energy_pj.reshape(b, t).sum(axis=1)
    return {
        "cycles_per_tile": cycles_per_tile,
        "cycles": cycles,
        "latency_ns": cycles * cell_spec(read_ports).clock_ns,
        "energy_pj": energy,
        "energy_pj_per_step": energy / t,
        "n_steps": t,
    }


def temporal_request_stats_device(
    topology: Sequence[int],
    loads: Sequence,      # per tile, jnp int32[B, T, n_groups] per-step loads
    read_ports: int,
) -> dict:
    """:func:`temporal_request_stats` computed on-device (jnp, float32).

    Same shape contract and formulas, evaluated lazily on jax arrays —
    the event-serving plane accumulates stream telemetry device-resident
    exactly like the static plane does with :func:`request_stats_device`
    (float32 agrees with the float64 numpy accounting to ~1e-6 relative,
    tested; cycle counts stay exact).
    """
    import jax.numpy as jnp

    b, t = loads[0].shape[:2]
    flat = [jnp.asarray(ld).reshape(b * t, -1) for ld in loads]
    rs = request_stats_device(topology, flat, read_ports)
    n_tiles = len(topology) - 1
    cycles_per_tile = rs["cycles_per_tile"].reshape(b, t, n_tiles).sum(axis=1)
    cycles = rs["cycles"].reshape(b, t).sum(axis=1)
    energy = rs["energy_pj"].reshape(b, t).sum(axis=1)
    return {
        "cycles_per_tile": cycles_per_tile,
        "cycles": cycles,
        "latency_ns": cycles * cell_spec(read_ports).clock_ns,
        "energy_pj": energy,
        "energy_pj_per_step": energy / t,
        "n_steps": t,
    }


def column_update_cycles(read_ports: int, rows: int = 128) -> tuple[int, int]:
    """(read_cycles, write_cycles) to read+write one weight column.

    Without transposable multiport cells (p=0 semantics of the paper's
    baseline), updating the synapses of one post-synaptic neuron requires
    touching every row: ``rows`` reads + ``rows`` writes.  With the transposed
    column port, the column is accessed through a ``COL_MUX_FACTOR``-to-1 mux:
    ``COL_MUX_FACTOR`` cycles each way (Sec 4.4.1).
    """
    if read_ports == 0:
        return rows, rows
    return COL_MUX_FACTOR, COL_MUX_FACTOR


# ----------------------------------------------------------------------------
# TPU MAC datapath roofline inputs (framework plane, not paper units)
# ----------------------------------------------------------------------------

#: v5e per-chip roofline anchors (mirrors launch/dryrun.py).
TPU_PEAK_FLOPS = 197e12     # bf16 MXU
TPU_PEAK_VPU_OPS = 3.2e12   # elementwise int32 lane ops (order-of-magnitude)
TPU_HBM_BW = 819e9          # B/s

MAC_DATAPATHS = ("dense_mxu", "packed_mxu", "popcount_vpu")


def mac_datapath_stats(batch: int, n_in: int, n_out: int, datapath: str) -> dict:
    """Compute/byte roofline inputs for one tile MAC, per datapath.

    ``dense_mxu``    int8 spikes from HBM, bf16 MXU matmul (the seed plane).
    ``packed_mxu``   uint32 spike bitplanes from HBM, VMEM unpack (1 shift +
                     1 mask + 1 cast per spike bit), then the same MXU
                     matmul — the wire is 8x thinner but the compute is
                     unchanged plus the unpack tax.
    ``popcount_vpu`` both operands stay uint32 bitplanes; each lane word is
                     one AND + one popcount + one add (3 VPU ops per 32
                     synapses) and a single row-popcount offset — no unpack,
                     no MXU round trip, ~32x fewer compute ops than MACs.

    Returns spike/weight/output HBM bytes, compute op count, the device the
    ops land on, arithmetic intensity, and the roofline-bound time — the
    derived fields ``bench_kernels`` records next to measured lanes so the
    perf trajectory carries its own model.
    """
    assert datapath in MAC_DATAPATHS, (datapath, MAC_DATAPATHS)
    macs = batch * n_in * n_out
    out_bytes = batch * n_out * 4                     # int32 V_mem
    kw = -(-n_in // 32)
    if datapath == "dense_mxu":
        spike_bytes = batch * n_in                    # int8 wire
        weight_bytes = n_in * n_out                   # int8 stored bits
        compute_ops, peak = 2 * macs, TPU_PEAK_FLOPS
        unit = "mxu"
    elif datapath == "packed_mxu":
        spike_bytes = batch * kw * 4
        weight_bytes = n_in * n_out
        # unpack tax: shift+mask+cast per spike bit, on the VPU, then the MAC
        compute_ops, peak = 2 * macs + 3 * batch * n_in, TPU_PEAK_FLOPS
        unit = "mxu+vpu_unpack"
    else:  # popcount_vpu
        spike_bytes = batch * kw * 4
        weight_bytes = n_out * kw * 4                 # uint32 weight planes
        # AND + popcount + add per (sample, neuron, lane word) + row offset
        compute_ops, peak = 3 * batch * n_out * kw + batch * kw, TPU_PEAK_VPU_OPS
        unit = "vpu"
    hbm_bytes = spike_bytes + weight_bytes + out_bytes
    t_compute = compute_ops / peak
    t_hbm = hbm_bytes / TPU_HBM_BW
    return {
        "datapath": datapath,
        "unit": unit,
        "macs": macs,
        "compute_ops": compute_ops,
        "spike_bytes": spike_bytes,
        "weight_bytes": weight_bytes,
        "hbm_bytes": hbm_bytes,
        "intensity_ops_per_byte": compute_ops / hbm_bytes,
        "t_compute_us": t_compute * 1e6,
        "t_hbm_us": t_hbm * 1e6,
        "t_roofline_us": max(t_compute, t_hbm) * 1e6,
        "bound": "compute" if t_compute >= t_hbm else "hbm",
    }

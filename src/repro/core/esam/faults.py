"""Fault-injection & mitigation plane for the ESAM CIM macro.

Real SRAM arrays do not read clean: stuck-at cells and device variation are
the dominant accuracy killers in CIM-for-SNN (Chen's ReRAM-reliability
survey), and robustness has to be modeled jointly across the
device/circuit/system stack (Moitra et al.).  This module is that joint
model for the repo: a :class:`FaultModel` describes a seeded fault
population, and the plan layer (``core/esam/plan.py``) compiles the
population into *every* execution mode's datapath — the faulted executable
is the same jitted (or shard_map-ped) program with the fault masks riding
the params pytree, so ``faults=None`` stays bit-identical to the clean plan
(property-tested) and sharded fault masks are bit-identical to
single-device (deterministic counter-based generation, replicated specs).

Fault classes (all masks drawn once at plan build, device-resident):

``stuck0_rate`` / ``stuck1_rate``
    i.i.d. stuck-at cells: the stored bit reads as 0 / 1 regardless of what
    was written ('0' -> weight -1, '1' -> +1).  Both classes are carved out
    of ONE uniform draw per tile, so they are disjoint by construction.
``dead_col_rate``
    whole-column failures (broken column driver / WL short): every cell of
    the column reads as 0.  Applied to *hidden* tiles only — the readout
    tile's handful of class columns is trivially protected by spares in any
    real deployment, while dead hidden columns are exactly what the
    online-learning repair story (Sec 4.4.1's transposable port) is about.
``vth_sigma``
    per-column threshold variation: the t-bit V_th register of Fig 5 is
    offset by ``round(N(0, vth_sigma))`` LSBs (integer datapath preserved).
``read_disturb``
    per-read upset probability.  The physical scaling is built in:
    disturb grows linearly with the number of decoupled read ports pulling
    on the cell and quadratically with the precharge voltage
    (E ~ C*V^2 stress), so ``upset_rate(p) = read_disturb * p *
    (v_prech/VPRECH)^2``.  Upset masks are *nested* across port counts
    (one shared uniform draw): the p=1 upset set is a subset of the p=4
    set, making the port scaling monotone by construction, not just in
    expectation.

Mitigation 1 — column remapping (``spare_cols``): each tile carries
``spare_cols`` spare columns; the worst-scoring faulty columns (stuck +
upset cell counts + |vth offset|) are remapped onto them at build time.
Remapping is mask surgery *before* packing — the spare column holds the
intended bits, so the wire format and every downstream kernel are
untouched (remap-aware packing for free).  ``dataclasses.replace(fm,
spare_cols=k)`` yields the mitigated variant of the *same* underlying
fault population (identical seed -> identical draws).

Mitigation 2 — online-learning repair: ``train/online.py`` accepts a
``faults=`` model and re-trains the readout around the faulted prefix;
:func:`clamp_readout_t` keeps the learned bits consistent with the array
(writes into stuck cells don't take).  Mitigation 3 — fault-aware serving —
lives in ``serve/engine.py`` (tile health scores + traffic draining).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.esam import cost_model as cm


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """A seeded fault population (frozen + hashable: lives in PlanSpec).

    All rates are per-cell (or per-column) probabilities in [0, 1]; the
    masks they induce are drawn with counter-based keys from ``seed`` only,
    so two models with equal fields inject *identical* faults — on any
    device count, in any plan mode.
    """

    seed: int = 0
    stuck0_rate: float = 0.0
    stuck1_rate: float = 0.0
    dead_col_rate: float = 0.0          # hidden tiles only (see module doc)
    vth_sigma: float = 0.0              # per-column V_th offset, LSBs
    read_disturb: float = 0.0           # per-read upset prob at 1 port, VPRECH
    v_prech: float = cm.VPRECH          # precharge voltage (V)
    spare_cols: int = 0                 # remap budget per tile (mitigation 1)

    def __post_init__(self):
        for f in ("stuck0_rate", "stuck1_rate", "dead_col_rate",
                  "read_disturb"):
            v = getattr(self, f)
            assert 0.0 <= v <= 1.0, (f, v)
        assert self.stuck0_rate + self.stuck1_rate <= 1.0, (
            "stuck0 + stuck1 cannot exceed 1", self)
        assert self.vth_sigma >= 0.0 and self.spare_cols >= 0
        assert self.v_prech > 0.0

    @property
    def any_faults(self) -> bool:
        return any((self.stuck0_rate, self.stuck1_rate, self.dead_col_rate,
                    self.vth_sigma, self.read_disturb))

    def upset_rate(self, ports: int) -> float:
        """Per-read upset probability at ``ports`` effective read ports.

        Linear in the port count (each decoupled port is one more read
        stress per cycle), quadratic in V_prech (C*V^2 bit-line stress),
        normalized so ``read_disturb`` is the 1-port rate at the paper's
        500 mV precharge.  Clipped to 1.
        """
        r = self.read_disturb * max(1, int(ports)) * (
            self.v_prech / cm.VPRECH) ** 2
        return float(min(r, 1.0))

    # ------------------------------------------------------------------ #
    # mask generation (build-time, deterministic)
    # ------------------------------------------------------------------ #
    def build_masks(
        self,
        topology: Sequence[int],
        ports_options: Sequence[int] = (4,),
    ) -> dict:
        """Draw the device-resident fault masks for every tile.

        Returns a params-pytree-shaped dict::

            {"stuck0":  (bool[n_in, n_out] per tile),
             "stuck1":  (bool[n_in, n_out] per tile),
             "vth_off": (int32[n_out]      per tile),
             "upset":   {ports: (bool[n_in, n_out] per tile), ...}}

        with one ``upset`` entry per effective port count in
        ``ports_options`` (nested sets — see module doc).  With
        ``spare_cols > 0`` the remap surgery has already been applied.
        """
        key = jax.random.PRNGKey(int(self.seed))
        ports_options = tuple(sorted({max(1, int(p)) for p in ports_options}))
        n_tiles = len(topology) - 1
        s0r, s1r = float(self.stuck0_rate), float(self.stuck1_rate)
        masks: dict = {"stuck0": [], "stuck1": [], "vth_off": [],
                       "upset": {p: [] for p in ports_options}}
        for t in range(n_tiles):
            shape = (int(topology[t]), int(topology[t + 1]))
            kt = jax.random.fold_in(key, t)
            u = jax.random.uniform(jax.random.fold_in(kt, 0), shape)
            stuck0 = u < s0r                       # disjoint by construction
            stuck1 = (u >= s0r) & (u < s0r + s1r)
            if self.dead_col_rate and t < n_tiles - 1:
                dead = jax.random.uniform(
                    jax.random.fold_in(kt, 1), (shape[1],)
                ) < float(self.dead_col_rate)
                stuck0 = stuck0 | dead[None, :]    # dead column reads all-0
                stuck1 = stuck1 & ~dead[None, :]
            if self.vth_sigma:
                vth_off = jnp.round(
                    jax.random.normal(jax.random.fold_in(kt, 2), (shape[1],))
                    * float(self.vth_sigma)).astype(jnp.int32)
            else:
                vth_off = jnp.zeros((shape[1],), jnp.int32)
            # one shared draw -> nested upset sets across port counts
            uu = jax.random.uniform(jax.random.fold_in(kt, 3), shape)
            ups = {p: uu < self.upset_rate(p) for p in ports_options}

            if self.spare_cols:
                stuck0, stuck1, vth_off, ups = _remap_columns(
                    stuck0, stuck1, vth_off, ups, int(self.spare_cols))
            masks["stuck0"].append(stuck0)
            masks["stuck1"].append(stuck1)
            masks["vth_off"].append(vth_off)
            for p in ports_options:
                masks["upset"][p].append(ups[p])
        return {
            "stuck0": tuple(masks["stuck0"]),
            "stuck1": tuple(masks["stuck1"]),
            "vth_off": tuple(masks["vth_off"]),
            "upset": {p: tuple(v) for p, v in masks["upset"].items()},
        }


def _remap_columns(stuck0, stuck1, vth_off, ups: dict, spare_cols: int):
    """Mitigation 1: clear the worst ``spare_cols`` faulty columns per tile.

    Column fault score = stuck cells + upset cells (at the largest port
    count — the superset, masks being nested) + |vth offset|.  The top
    ``spare_cols`` columns *with a non-zero score* are remapped onto clean
    spares: their masks and threshold offsets are cleared.  Deterministic
    (stable argsort), and performed before bit-packing, so the spare column
    carries the intended bits and no downstream consumer changes.
    """
    p_max = max(ups)
    score = (stuck0.sum(0) + stuck1.sum(0) + ups[p_max].sum(0)
             + jnp.abs(vth_off)).astype(jnp.int32)
    order = jnp.argsort(-score)                  # stable: ties by column index
    sel = order[:spare_cols]
    clear = jnp.zeros(score.shape, bool).at[sel].set(score[sel] > 0)
    stuck0 = stuck0 & ~clear[None, :]
    stuck1 = stuck1 & ~clear[None, :]
    vth_off = jnp.where(clear, 0, vth_off)
    ups = {p: m & ~clear[None, :] for p, m in ups.items()}
    return stuck0, stuck1, vth_off, ups


# ---------------------------------------------------------------------- #
# datapath application (inside the compiled plan)
# ---------------------------------------------------------------------- #
def faulted_bits(w, stuck0, stuck1, upset):
    """Effective stored bits of one tile under its fault masks.

    Read-disturb flips first, then the stuck clamp wins (a stuck cell
    cannot be upset — its node is hard-tied).  All-False masks are exact
    no-ops on the {0,1} integer bits, which is what makes the zero-rate
    model bit-identical to the clean plan.
    """
    w_eff = jnp.where(upset, 1 - w, w)
    w_eff = jnp.where(stuck1, 1, jnp.where(stuck0, 0, w_eff))
    return w_eff.astype(w.dtype)


def faulted_weights(weight_bits, masks: dict, ports: int):
    """Apply the masks at ``ports`` effective read ports to every tile."""
    ups = masks["upset"][ports]
    return [
        faulted_bits(w, s0, s1, u)
        for w, s0, s1, u in zip(
            weight_bits, masks["stuck0"], masks["stuck1"], ups)
    ]


def faulted_vth(vth, masks: dict):
    """Per-column threshold variation: integer LSB offsets on V_th."""
    return [v + off for v, off in zip(vth, masks["vth_off"])]


def mask_specs(masks: dict, w_specs, v_specs) -> dict:
    """Shard specs for the mask pytree, mirroring the weight/vth specs so
    fault masks follow their tile's ``tile_col`` sharding exactly."""
    return {
        "stuck0": w_specs,
        "stuck1": w_specs,
        "vth_off": v_specs,
        "upset": {p: w_specs for p in masks["upset"]},
    }


# ---------------------------------------------------------------------- #
# online-learning repair support (mitigation 2)
# ---------------------------------------------------------------------- #
def clamp_readout_t(bits_t, masks: dict, ports: int = 4):
    """Effective transposed-resident readout bits under the last tile's
    faults: writes into stuck cells don't take, and reads through the
    inference ports see the disturb flips.  The online-learning driver
    applies this between epochs so the learned state it evaluates (and
    ships) is exactly what the faulted array would read back.
    """
    s0 = masks["stuck0"][-1].T
    s1 = masks["stuck1"][-1].T
    up = masks["upset"][ports][-1].T
    return faulted_bits(bits_t, s0, s1, up)


def faulty_cells(masks: dict) -> list[int]:
    """Per-tile count of cells touched by any fault class (reporting)."""
    p_max = max(masks["upset"])
    return [
        int((s0 | s1 | u).sum())
        for s0, s1, u in zip(
            masks["stuck0"], masks["stuck1"], masks["upset"][p_max])
    ]

"""Multiport spike arbiter — functional (pure-jnp) plane.

The paper's arbiter (Sec 3.3, Fig 4) is p cascaded fixed-priority encoders:
port 0 grants the leftmost pending request, port 1 the next-leftmost, etc.,
all within one clock cycle; granted requests are masked out of the request
vector.  A priority chain is sequential gate logic with no SIMD analogue, so
on TPU we re-express the *function* as prefix-sum rank selection:

    rank(i)   = (# of requests at indices <= i) - 1      (exclusive of non-requests)
    grant_k   = one-hot( request with rank == k ),  k < p

which produces bit-identical grant vectors to the hardware cascade (tested
against a pure-Python priority-encoder oracle).  The paper's own critical-path
fix — a *tree* of short priority encoders — is precisely a blocked prefix
structure; the Pallas kernel in ``repro.kernels.arbiter`` mirrors that
blocking for VMEM tiling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def priority_grants(requests: jax.Array, ports: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One arbiter clock cycle.

    Args:
      requests: bool[n] pending spike requests (R).
      ports: number of grant ports p.

    Returns:
      grants:  bool[p, n] — one-hot grant vector per port (all-zero if noR).
      remaining: bool[n] — R' = R minus granted requests.
      valid:   bool[p] — per-port validity flag (False == the paper's noR),
               consumed by the neuron array so unused ports are not summed.
    """
    r = requests.astype(jnp.int32)
    # rank[i] = number of earlier-or-equal requests, minus 1 -> 0-based rank.
    rank = jnp.cumsum(r) - 1
    port_ids = jnp.arange(ports)[:, None]                       # [p, 1]
    grants = (requests[None, :]) & (rank[None, :] == port_ids)  # [p, n]
    granted_any = jnp.any(grants, axis=0)
    remaining = requests & ~granted_any
    valid = jnp.any(grants, axis=1)
    return grants, remaining, valid


def priority_grants_oracle(requests: np.ndarray, ports: int):
    """Pure-Python cascade of fixed-priority encoders (Fig 4 semantics)."""
    r = np.asarray(requests, dtype=bool).copy()
    n = r.shape[0]
    grants = np.zeros((ports, n), dtype=bool)
    valid = np.zeros((ports,), dtype=bool)
    for k in range(ports):  # cascaded 1-port arbiters
        nz = np.flatnonzero(r)
        if nz.size == 0:
            break  # noR propagates to all later ports
        grants[k, nz[0]] = True  # leftmost pending request
        valid[k] = True
        r[nz[0]] = False         # R' masks out the granted request
    return grants, r, valid


def grant_cycles(requests: jax.Array, ports: int) -> jax.Array:
    """Closed-form port schedule: the clock cycle at which each request is
    granted, with no sequential arbitration loop.

    The cascade in :func:`priority_grants` serves requests strictly in rank
    order, p per cycle, so a request whose in-group rank is r is granted at
    cycle ``r // p`` — the whole drain is a static schedule (the same
    property event-driven CIM schedulers exploit; see kernels/arbiter).

    Args:
      requests: bool/{0,1}[..., W] — request vector(s) of one row group.
      ports: p.
    Returns:
      int32[..., W] — grant cycle per lane; non-request lanes carry the
      sentinel ``ceil(W / p)`` (one past the last schedulable cycle), so the
      result doubles as a segment id for cycle-keyed segment sums.
    """
    r = requests.astype(jnp.int32)
    w = r.shape[-1]
    n_cycles = -(-w // ports)
    rank = jnp.cumsum(r, axis=-1) - 1
    return jnp.where(r == 1, rank // ports, n_cycles).astype(jnp.int32)


def drain_cycles(n_pending: jax.Array, ports: int) -> jax.Array:
    """Clock cycles for a p-port arbiter to drain ``n_pending`` requests."""
    return -(-n_pending // ports)  # ceil division; 0 pending -> 0 cycles


def layer_drain_cycles(spike_counts_per_group: jax.Array, ports: int) -> jax.Array:
    """Cycles until R_empty for a layer of 128-row groups, each with its own
    p-port arbiter (Sec 4.4.2: 'Each SRAM has its own 128-wide Arbiter')."""
    return jnp.max(drain_cycles(spike_counts_per_group, ports))


def split_row_groups(requests: jax.Array, group: int = 128) -> jax.Array:
    """Reshape a layer-wide request vector into [n_groups, group] row groups.

    The layer width must be a multiple of ``group`` (the paper pads its first
    layer to exactly 6x128 by cropping MNIST 784 -> 768).
    """
    n = requests.shape[-1]
    if n % group:
        raise ValueError(f"layer width {n} not a multiple of row-group size {group}")
    return requests.reshape(*requests.shape[:-1], n // group, group)

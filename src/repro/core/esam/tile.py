"""Cycle-accurate simulation of one CIM-P tile (Fig 2).

A tile holds one layer's synapse matrix across a grid of <=128x128 SRAM
arrays.  Row groups (pre-synaptic, 128 rows each) each have their own p-port
arbiter; the column groups of a row group read the granted rows in the same
cycle.  Each clock cycle:

  arbiter stage:      every row group grants <= p pending spike requests
  SRAM+neuron stage:  granted rows are read on RBL0..RBL{p-1}; the neuron
                      array adds the validity-flagged {+1,-1} values to V_mem

When every row group's request queue is empty (R_empty), neurons compare
V_mem >= V_th and fire (Sec 3.4).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.esam import arbiter as arb
from repro.core.esam import neuron as nrn


class TileTrace(NamedTuple):
    """Cycle-by-cycle trace of one tile inference."""

    out_spikes: jax.Array      # bool[n_out]
    vmem_final: jax.Array      # int32[n_out] V_mem right before the compare
    cycles: jax.Array          # int32 — cycles until R_empty
    grants_per_cycle: jax.Array  # int32[max_cycles] — total grants each cycle
    vmem_trace: jax.Array      # int32[max_cycles, n_out] when recorded,
    #                            int32[0, n_out] otherwise (opt-in, see below)


def max_drain_cycles(rows: int, ports: int, group: int = 128) -> int:
    """Static upper bound on cycles: a full group drains in ceil(group/p)."""
    del rows
    return -(-group // ports)


@partial(jax.jit, static_argnames=("ports", "record_vmem_trace"))
def simulate_tile(
    weight_bits: jax.Array,   # {0,1}[n_in, n_out] stored bits
    in_spikes: jax.Array,     # bool[n_in]
    vth: jax.Array,           # int32[n_out]
    ports: int,
    record_vmem_trace: bool = False,
) -> TileTrace:
    """Run one tile to R_empty, one arbiter round per scan step.

    ``record_vmem_trace`` opts in to the full per-cycle V_mem history; by
    default the scan carries O(n_out) state instead of O(max_cycles * n_out)
    outputs, which is what makes the vmapped batch plane affordable.
    """
    n_in, n_out = weight_bits.shape
    w_signed = nrn.decode_bitlines(weight_bits)            # {-1,+1} int32
    groups = arb.split_row_groups(in_spikes)               # [G, 128]
    n_groups = groups.shape[0]
    w_grouped = w_signed.reshape(n_groups, 128, n_out)
    max_cycles = max_drain_cycles(n_in, ports)

    def cycle(state, _):
        remaining, vmem = state
        # Every row group arbitrates independently (own 128-wide arbiter).
        grants, rem2, valid = jax.vmap(lambda r: arb.priority_grants(r, ports))(remaining)
        # grants: [G, p, 128]; read the granted rows in every column group.
        port_vals = jnp.einsum("gpr,grn->gpn", grants.astype(jnp.int32), w_grouped)
        contrib = jnp.where(valid[:, :, None], port_vals, 0).sum(axis=(0, 1))
        n_granted = valid.sum().astype(jnp.int32)
        vmem2 = vmem + contrib.astype(jnp.int32)
        ys = (n_granted, vmem2) if record_vmem_trace else n_granted
        return (rem2, vmem2), ys

    init = (groups, jnp.zeros((n_out,), jnp.int32))
    (remaining, vmem), ys = jax.lax.scan(cycle, init, None, length=max_cycles)
    if record_vmem_trace:
        grants_seq, vmem_trace = ys
    else:
        grants_seq = ys
        vmem_trace = jnp.zeros((0, n_out), jnp.int32)
    state = nrn.NeuronState(vmem=vmem, fired=jnp.zeros((n_out,), bool))
    _, out_spikes = nrn.fire(state, vth)
    cycles = jnp.sum(grants_seq > 0).astype(jnp.int32)
    return TileTrace(
        out_spikes=out_spikes,
        vmem_final=vmem,
        cycles=cycles,
        grants_per_cycle=grants_seq,
        vmem_trace=vmem_trace,
    )


@partial(jax.jit, static_argnames=("ports", "record_vmem_trace"))
def simulate_tile_batch(
    weight_bits: jax.Array,   # {0,1}[n_in, n_out]
    in_spikes: jax.Array,     # bool[batch, n_in]
    vth: jax.Array,           # int32[n_out]
    ports: int,
    record_vmem_trace: bool = False,
) -> TileTrace:
    """Cycle-accurate plane over a batch of samples (vmapped ``simulate_tile``).

    Every TileTrace field gains a leading batch axis; per-sample semantics are
    identical to the single-sample simulator (tested).
    """
    return jax.vmap(
        lambda s: simulate_tile(weight_bits, s, vth, ports, record_vmem_trace)
    )(in_spikes)


def functional_tile(
    weight_bits: jax.Array, in_spikes: jax.Array, vth: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Batched functional equivalent: one dense MAC (the TPU-native plane).

    IF accumulation is commutative and the compare happens only at R_empty, so
    the event-driven multiport schedule and a single dense matmul produce
    identical V_mem / spikes — proven in tests/test_esam_equivalence.py.

    Args:
      weight_bits: {0,1}[n_in, n_out]
      in_spikes: bool[..., n_in] (any batch shape)
    Returns:
      (out_spikes bool[..., n_out], vmem int32[..., n_out])
    """
    w_signed = nrn.decode_bitlines(weight_bits)
    vmem = jnp.einsum("...i,io->...o", in_spikes.astype(jnp.int32), w_signed)
    return vmem >= vth, vmem

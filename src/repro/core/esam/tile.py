"""Cycle-accurate simulation of one CIM-P tile (Fig 2).

A tile holds one layer's synapse matrix across a grid of <=128x128 SRAM
arrays.  Row groups (pre-synaptic, 128 rows each) each have their own p-port
arbiter; the column groups of a row group read the granted rows in the same
cycle.  Each clock cycle:

  arbiter stage:      every row group grants <= p pending spike requests
  SRAM+neuron stage:  granted rows are read on RBL0..RBL{p-1}; the neuron
                      array adds the validity-flagged {+1,-1} values to V_mem

When every row group's request queue is empty (R_empty), neurons compare
V_mem >= V_th and fire (Sec 3.4).

Two planes compute that trace:

* ``simulate_tile`` / ``simulate_tile_batch`` — the **rank-schedule plane**.
  The fixed-priority cascade serves requests strictly in rank order, p per
  cycle, so the grant cycle of every request is known in closed form
  (``cycle = rank // p``, ``arbiter.grant_cycles``) and the whole drain
  collapses into one matvec plus cycle-keyed segment sums — no sequential
  loop.  ``kernels/arbiter.port_schedule`` fuses rank + schedule + segment
  counts (Pallas on TPU, jnp ref elsewhere).
* ``simulate_tile_scan`` / ``simulate_tile_scan_batch`` — the original
  arbitration loop, one ``lax.scan`` step per clock cycle.  Kept as the
  bit-identity oracle for the rank-schedule plane (tested field by field).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.esam import arbiter as arb
from repro.core.esam import neuron as nrn


class TileTrace(NamedTuple):
    """Cycle-by-cycle trace of one tile inference."""

    out_spikes: jax.Array      # bool[n_out]
    vmem_final: jax.Array      # int32[n_out] V_mem right before the compare
    cycles: jax.Array          # int32 — cycles until R_empty
    grants_per_cycle: jax.Array  # int32[max_cycles] — total grants each cycle
    vmem_trace: jax.Array      # int32[max_cycles, n_out] when recorded,
    #                            int32[0, n_out] otherwise (opt-in, see below)


def max_drain_cycles(rows: int, ports: int, group: int = 128) -> int:
    """Static upper bound on cycles: a full group drains in ceil(group/p)."""
    del rows
    return -(-group // ports)


# ---------------------------------------------------------------------- #
# Rank-schedule plane (closed form, no sequential loop)
# ---------------------------------------------------------------------- #
def _schedule_trace(
    weight_bits: jax.Array,   # {0,1}[n_in, n_out] (or None with w_signed)
    in_spikes: jax.Array,     # bool[B, n_in]
    vth: jax.Array,           # int32[n_out]
    ports: int,
    record_vmem_trace: bool,
    use_kernel: bool | None,
    w_signed: jax.Array | None = None,
) -> TileTrace:
    """Batched closed-form drain: every TileTrace field as a segment sum.

    The grant cycle of request i is ``rank(i) // p`` (arbiter.grant_cycles),
    so relative to the per-cycle scan:
      vmem_final        -> the one matvec we already compute (functional plane)
      grants_per_cycle  -> histogram of grant cycles over all row groups
      cycles            -> number of non-empty schedule slots
      vmem_trace        -> cumsum of weight-row segment sums keyed by cycle
    All arithmetic is exact int32, so the result is bit-identical to
    ``simulate_tile_scan`` (property-tested).
    """
    from repro.kernels.arbiter import ops as arb_ops

    if w_signed is None:                                   # pre-decoded by
        w_signed = nrn.decode_bitlines(weight_bits)        # EsamPlan prep
    n_in, n_out = w_signed.shape
    batch = in_spikes.shape[0]
    groups = arb.split_row_groups(in_spikes)               # [B, G, 128]
    n_groups = groups.shape[1]
    max_cycles = max_drain_cycles(n_in, ports)

    cycle_of, counts = arb_ops.port_schedule(
        groups.reshape(batch * n_groups, groups.shape[-1]),
        ports=ports,
        use_kernel=use_kernel,
    )
    counts = counts.reshape(batch, n_groups, max_cycles)
    grants_seq = counts.sum(axis=1).astype(jnp.int32)      # [B, max_cycles]
    cycles = jnp.sum(grants_seq > 0, axis=-1).astype(jnp.int32)

    vmem = jnp.einsum("bi,io->bo", in_spikes.astype(jnp.int32), w_signed)
    vmem = vmem.astype(jnp.int32)
    out_spikes = vmem >= vth

    if record_vmem_trace:
        # Segment-sum the weight rows by grant cycle, then prefix-sum over
        # cycles: trace[c] == V_mem after cycle c, exactly as the scan logs it
        # (the sentinel cycle of non-request lanes falls outside the one-hot).
        cyc = cycle_of.reshape(batch, n_in)
        onehot = (cyc[:, :, None] == jnp.arange(max_cycles)[None, None, :])
        contrib = jnp.einsum("bic,io->bco", onehot.astype(jnp.int32), w_signed)
        vmem_trace = jnp.cumsum(contrib, axis=1).astype(jnp.int32)
    else:
        vmem_trace = jnp.zeros((batch, 0, n_out), jnp.int32)

    return TileTrace(
        out_spikes=out_spikes,
        vmem_final=vmem,
        cycles=cycles,
        grants_per_cycle=grants_seq,
        vmem_trace=vmem_trace,
    )


@partial(jax.jit, static_argnames=("ports", "record_vmem_trace", "use_kernel"))
def simulate_tile(
    weight_bits: jax.Array,   # {0,1}[n_in, n_out] stored bits
    in_spikes: jax.Array,     # bool[n_in]
    vth: jax.Array,           # int32[n_out]
    ports: int,
    record_vmem_trace: bool = False,
    use_kernel: bool | None = None,
    w_signed: jax.Array | None = None,
) -> TileTrace:
    """Run one tile to R_empty on the rank-schedule plane (closed form).

    Bit-identical to ``simulate_tile_scan`` in every trace field;
    ``record_vmem_trace`` opts in to the full per-cycle V_mem history.
    """
    trace = _schedule_trace(
        weight_bits, in_spikes[None], vth, ports, record_vmem_trace,
        use_kernel, w_signed,
    )
    return jax.tree_util.tree_map(lambda x: x[0], trace)


@partial(jax.jit, static_argnames=("ports", "record_vmem_trace", "use_kernel"))
def simulate_tile_batch(
    weight_bits: jax.Array,   # {0,1}[n_in, n_out]
    in_spikes: jax.Array,     # bool[batch, n_in]
    vth: jax.Array,           # int32[n_out]
    ports: int,
    record_vmem_trace: bool = False,
    use_kernel: bool | None = None,
    w_signed: jax.Array | None = None,
) -> TileTrace:
    """Rank-schedule plane over a batch of samples.

    Unlike the scan plane this is natively batched — one [B, n_in] matvec and
    one [B*G, 128] schedule call — rather than a vmapped per-sample loop.
    Every TileTrace field gains a leading batch axis; per-sample semantics are
    identical to the single-sample simulator (tested).  ``w_signed`` accepts
    the pre-decoded ±1 operand (hoisted by ``EsamPlan``), skipping the
    per-call ``decode_bitlines``.
    """
    return _schedule_trace(
        weight_bits, in_spikes, vth, ports, record_vmem_trace, use_kernel,
        w_signed,
    )


# ---------------------------------------------------------------------- #
# Scan plane (per-cycle arbitration loop) — the bit-identity oracle
# ---------------------------------------------------------------------- #
@partial(jax.jit, static_argnames=("ports", "record_vmem_trace"))
def simulate_tile_scan(
    weight_bits: jax.Array,   # {0,1}[n_in, n_out] stored bits
    in_spikes: jax.Array,     # bool[n_in]
    vth: jax.Array,           # int32[n_out]
    ports: int,
    record_vmem_trace: bool = False,
) -> TileTrace:
    """Run one tile to R_empty, one arbiter round per scan step.

    This is the literal cycle-by-cycle rendering of the hardware drain; the
    rank-schedule plane above must match it bit for bit (tested), which is
    why it stays in the tree as the oracle and the bench baseline.
    """
    n_in, n_out = weight_bits.shape
    w_signed = nrn.decode_bitlines(weight_bits)            # {-1,+1} int32
    groups = arb.split_row_groups(in_spikes)               # [G, 128]
    n_groups = groups.shape[0]
    w_grouped = w_signed.reshape(n_groups, 128, n_out)
    max_cycles = max_drain_cycles(n_in, ports)

    def cycle(state, _):
        remaining, vmem = state
        # Every row group arbitrates independently (own 128-wide arbiter).
        grants, rem2, valid = jax.vmap(lambda r: arb.priority_grants(r, ports))(remaining)
        # grants: [G, p, 128]; read the granted rows in every column group.
        port_vals = jnp.einsum("gpr,grn->gpn", grants.astype(jnp.int32), w_grouped)
        contrib = jnp.where(valid[:, :, None], port_vals, 0).sum(axis=(0, 1))
        n_granted = valid.sum().astype(jnp.int32)
        vmem2 = vmem + contrib.astype(jnp.int32)
        ys = (n_granted, vmem2) if record_vmem_trace else n_granted
        return (rem2, vmem2), ys

    init = (groups, jnp.zeros((n_out,), jnp.int32))
    (remaining, vmem), ys = jax.lax.scan(cycle, init, None, length=max_cycles)
    if record_vmem_trace:
        grants_seq, vmem_trace = ys
    else:
        grants_seq = ys
        vmem_trace = jnp.zeros((0, n_out), jnp.int32)
    state = nrn.NeuronState(vmem=vmem, fired=jnp.zeros((n_out,), bool))
    _, out_spikes = nrn.fire(state, vth)
    cycles = jnp.sum(grants_seq > 0).astype(jnp.int32)
    return TileTrace(
        out_spikes=out_spikes,
        vmem_final=vmem,
        cycles=cycles,
        grants_per_cycle=grants_seq,
        vmem_trace=vmem_trace,
    )


@partial(jax.jit, static_argnames=("ports", "record_vmem_trace"))
def simulate_tile_scan_batch(
    weight_bits: jax.Array,   # {0,1}[n_in, n_out]
    in_spikes: jax.Array,     # bool[batch, n_in]
    vth: jax.Array,           # int32[n_out]
    ports: int,
    record_vmem_trace: bool = False,
) -> TileTrace:
    """Scan plane over a batch of samples (vmapped ``simulate_tile_scan``)."""
    return jax.vmap(
        lambda s: simulate_tile_scan(weight_bits, s, vth, ports, record_vmem_trace)
    )(in_spikes)


def functional_tile(
    weight_bits: jax.Array,
    in_spikes: jax.Array,
    vth: jax.Array,
    *,
    w_signed: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Batched functional equivalent: one dense MAC (the TPU-native plane).

    IF accumulation is commutative and the compare happens only at R_empty, so
    the event-driven multiport schedule and a single dense matmul produce
    identical V_mem / spikes — proven in tests/test_esam_equivalence.py.

    Args:
      weight_bits: {0,1}[n_in, n_out] (may be None when ``w_signed`` given)
      in_spikes: bool[..., n_in] (any batch shape)
      w_signed: optional pre-decoded ±1 int32[n_in, n_out] — the hoisted
        operand ``EsamPlan`` prepares once, skipping the per-call decode.
    Returns:
      (out_spikes bool[..., n_out], vmem int32[..., n_out])
    """
    if w_signed is None:
        w_signed = nrn.decode_bitlines(weight_bits)
    vmem = jnp.einsum("...i,io->...o", in_spikes.astype(jnp.int32), w_signed)
    return vmem >= vth, vmem

"""Integrate-and-Fire neuron array (Sec 3.4, Fig 5).

Each neuron accumulates the validity-flagged, {+1/-1}-decoded bitline values of
the p inference ports into its m-bit V_mem register every clock cycle.  When
the tile's request queue drains (R_empty), V_mem is compared against the
per-neuron threshold V_th; on fire the output register r is set and V_mem
resets to zero; a granted handshake (g) clears r.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class NeuronState:
    """State of one tile's neuron array."""

    vmem: jax.Array      # int32[n_out] membrane potentials
    fired: jax.Array     # bool[n_out] output spike request register r

    @staticmethod
    def zeros(n_out: int) -> "NeuronState":
        return NeuronState(
            vmem=jnp.zeros((n_out,), jnp.int32),
            fired=jnp.zeros((n_out,), bool),
        )


def accumulate(state: NeuronState, port_values: jax.Array, valid: jax.Array) -> NeuronState:
    """One SRAM-read/neuron-accumulate pipeline stage.

    Args:
      state: neuron state.
      port_values: int32[p, n_out] — sensed bitline values decoded to {+1,-1}
        (weight bit '1' -> +1, '0' -> -1).
      valid: bool[p] — per-port validity flags from the arbiter; an unused
        port must not be "erroneously read as a '1' and added" (Sec 3.4).
    """
    contrib = jnp.where(valid[:, None], port_values, 0).sum(axis=0)
    return NeuronState(vmem=state.vmem + contrib.astype(jnp.int32), fired=state.fired)


def fire(state: NeuronState, vth: jax.Array) -> tuple[NeuronState, jax.Array]:
    """R_empty event: compare V_mem >= V_th, emit spikes, reset V_mem."""
    spikes = state.vmem >= vth
    new = NeuronState(vmem=jnp.where(spikes, 0, 0 * state.vmem), fired=spikes)
    # NOTE: the paper resets V_mem to zero unconditionally on the compare event
    # ("V_mem is reset to zero to start accumulating spikes again") — for the
    # time-static classification task every neuron is compared exactly once per
    # sample, so we reset all neurons.
    return new, spikes


def decode_bitlines(weight_bits: jax.Array) -> jax.Array:
    """Map stored weight bits {0,1} to synaptic values {-1,+1} (Fig 5 decode)."""
    return (2 * weight_bits - 1).astype(jnp.int32)

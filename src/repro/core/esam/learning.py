"""Online learning via the transposable port: stochastic 1-bit STDP.

ESAM's learning contribution is *architectural*: the column-wise RW port makes
"update all synapses of one post-synaptic neuron" a 2x4-cycle operation instead
of 2x128 (Sec 4.4.1).  The learning *rule* it enables is the stochastic-STDP
family with 1-bit weights of Yousefzadeh et al. [16]: on a post-synaptic
learning event, synapses from recently-active pre-neurons potentiate (bit->1)
with probability p_pot and synapses from silent pre-neurons depress (bit->0)
with probability p_dep.

On TPU the transposed port becomes a layout choice: the update is a masked
column write (see kernels/stdp); here is the functional plane plus the cost
accounting that reproduces the paper's 26.0x / 19.5x claims.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.esam import cost_model as cm


def stdp_update(
    weight_bits: jax.Array,   # {0,1}[n_in, n_out]
    pre_spikes: jax.Array,    # bool[n_in]   — pre-synaptic activity trace
    post_events: jax.Array,   # bool[n_out]  — which post neurons learn now
    key: jax.Array,
    p_pot: float = 0.1,
    p_dep: float = 0.05,
) -> jax.Array:
    """One stochastic-STDP event: returns updated weight bits."""
    k1, k2 = jax.random.split(key)
    u_pot = jax.random.uniform(k1, weight_bits.shape)
    u_dep = jax.random.uniform(k2, weight_bits.shape)
    pre = pre_spikes[:, None]
    post = post_events[None, :]
    potentiate = post & pre & (u_pot < p_pot)
    depress = post & ~pre & (u_dep < p_dep)
    new_bits = jnp.where(potentiate, 1, jnp.where(depress, 0, weight_bits))
    return new_bits.astype(weight_bits.dtype)


@dataclasses.dataclass(frozen=True)
class ColumnUpdateCost:
    cell: str
    read_cycles: int
    write_cycles: int
    read_ns: float
    write_ns: float
    energy_pj: float            # read-modify-write of one column
    speedup_read_vs_1rw: float
    speedup_write_vs_1rw: float


def column_update_cost(read_ports: int, rows: int = 128) -> ColumnUpdateCost:
    """Time/energy to read+write one weight column (one learning neuron).

    The 1RW baseline must touch all `rows` rows through the single RW port
    (2 x 128 cycles = 257.8 ns, 157 pJ for the full array, Sec 4.4.1).  With
    the transposed column port, access takes COL_MUX_FACTOR cycles each way at
    the transposed-path clock.
    """
    spec = cm.cell_spec(read_ports)
    rc, wc = cm.column_update_cycles(read_ports, rows)
    if read_ports == 0:
        # 1RW column RMW: precharge+read = 2 cycles per row, then one write per
        # row at the 1RW write time (see cost_model baseline decode).
        read_ns, write_ns = cm.T1RW_COL_READ_NS, cm.T1RW_COL_WRITE_NS
        energy = rows * (cm.E_READ_1RW_PJ + cm.E_WRITE_1RW_PJ)  # RMW every row
    else:
        clock = cm.T4R_TRANSPOSED_CLOCK_NS
        # Measured end-to-end column access times for the 4R cell (Sec 4.4.1);
        # cycle counts for other port counts scale identically (same mux).
        read_ns = cm.T4R_COL_READ_NS if read_ports == 4 else rc * clock + spec.sram_neuron_ns
        write_ns = cm.T4R_COL_WRITE_NS if read_ports == 4 else wc * clock + spec.sram_neuron_ns
        energy = spec.e_tread_pj + spec.e_write_pj   # one column-read + one column-write
    base_read_ns = cm.T1RW_COL_READ_NS
    base_write_ns = cm.T1RW_COL_WRITE_NS
    return ColumnUpdateCost(
        cell=spec.name,
        read_cycles=int(rc),
        write_cycles=int(wc),
        read_ns=float(read_ns),
        write_ns=float(write_ns),
        energy_pj=float(energy),
        speedup_read_vs_1rw=float(base_read_ns / read_ns),
        speedup_write_vs_1rw=float(base_write_ns / write_ns),
    )


def online_learning_epoch(
    network_bits: list[jax.Array],
    vth: list[jax.Array],
    spikes: jax.Array,          # bool[batch, n_in]
    labels: jax.Array,          # int32[batch] — supervised teacher events
    key: jax.Array,
    p_pot: float = 0.12,
    p_dep: float = 0.06,
    pre_spikes: jax.Array | None = None,
):
    """Supervised-STDP pass over a batch for the *last* tile (delta-rule style).

    Teacher signal: the correct class neuron is a potentiation event; the
    argmax-wrong neuron is a depression event.  Returns (new last-layer bits,
    number of column updates) — the count feeds the cost model.

    ``pre_spikes`` takes the last hidden layer's spikes if the caller already
    ran ``EsamNetwork.forward(..., collect=True)`` — the frozen prefix tiles
    are then not re-evaluated here.
    """
    from repro.core.esam import tile as tile_mod

    bits_last = network_bits[-1]
    n_updates = 0
    if pre_spikes is not None:
        s = pre_spikes
    else:
        s = spikes
        for w, th in zip(network_bits[:-1], vth[:-1]):
            s, _ = tile_mod.functional_tile(w, s, th)

    def body(carry, inp):
        bits, key = carry
        s_i, y_i = inp
        _, vmem = tile_mod.functional_tile(bits, s_i, vth[-1])
        pred = jnp.argmax(vmem)
        wrong = pred != y_i
        post_pot = jax.nn.one_hot(y_i, bits.shape[1], dtype=bool) & wrong
        post_dep = jax.nn.one_hot(pred, bits.shape[1], dtype=bool) & wrong
        key, k1, k2 = jax.random.split(key, 3)
        # correct neuron: Hebbian — pull its column toward the pre pattern
        bits = stdp_update(bits, s_i, post_pot, k1, p_pot, p_dep)
        # wrong winner: pure depression of active-pre synapses (bit -> 0).
        # Expressed via stdp_update with the pre trace inverted and
        # potentiation disabled — potentiating silent positions would *raise*
        # the winner's response to shifted variants instead of suppressing it.
        bits = stdp_update(bits, ~s_i, post_dep, k2, 0.0, p_dep)
        return (bits, key), wrong.astype(jnp.int32) * 2

    (bits_last, _), upd = jax.lax.scan(body, (bits_last, key), (s, labels))
    n_updates = int(upd.sum())
    return bits_last, n_updates

"""Online learning via the transposable port: stochastic 1-bit STDP.

ESAM's learning contribution is *architectural*: the column-wise RW port makes
"update all synapses of one post-synaptic neuron" a 2x4-cycle operation instead
of 2x128 (Sec 4.4.1).  The learning *rule* it enables is the stochastic-STDP
family with 1-bit weights of Yousefzadeh et al. [16]: on a post-synaptic
learning event, synapses from recently-active pre-neurons potentiate (bit->1)
with probability p_pot and synapses from silent pre-neurons depress (bit->0)
with probability p_dep.

On TPU the transposed port becomes a layout choice: weights live
transposed-resident as ``{0,1}[N_out, N_in]`` so one learning neuron's
synapses are one contiguous row, and each supervised event is a blocked
row write issued through ``kernels/stdp.stdp_column_event`` (the Pallas
column-port kernel wired into ``online_learning_epoch`` below).  Per sample
only the <= 2 event columns (teacher + wrong winner) draw RNG — counter-based
``fold_in`` keys, never a ``[n_in, n_out]`` uniform matrix — and the whole
epoch runs as one jitted, donated scan (``column_event_epoch``).  The cost
accounting that reproduces the paper's 26.0x / 19.5x claims is below.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.esam import cost_model as cm


# --------------------------------------------------------------------- #
# The update rule (functional plane)
# --------------------------------------------------------------------- #
def stdp_update_from_uniforms(
    weight_bits: jax.Array,   # {0,1}[n_in, n_out]
    pre_spikes: jax.Array,    # bool[n_in]
    post_events: jax.Array,   # bool[n_out]
    u_pot: jax.Array,         # float[n_in, n_out] (or broadcastable)
    u_dep: jax.Array,         # float[n_in, n_out] (or broadcastable)
    p_pot: float,
    p_dep: float,
) -> jax.Array:
    """The pure stochastic-STDP rule given explicit uniform draws.

    This is the single source of truth for the rule; ``stdp_update`` (keyed),
    the scan plane, the column-event plane, and the ``kernels/stdp`` Pallas
    kernels are all bit-exact against it under shared uniforms (tested).
    """
    pre = pre_spikes.astype(bool)[:, None]
    post = post_events.astype(bool)[None, :]
    potentiate = post & pre & (u_pot < p_pot)
    depress = post & ~pre & (u_dep < p_dep)
    new_bits = jnp.where(potentiate, 1, jnp.where(depress, 0, weight_bits))
    return new_bits.astype(weight_bits.dtype)


def stdp_update(
    weight_bits: jax.Array,   # {0,1}[n_in, n_out]
    pre_spikes: jax.Array,    # bool[n_in]   — pre-synaptic activity trace
    post_events: jax.Array,   # bool[n_out]  — which post neurons learn now
    key: jax.Array,
    p_pot: float = 0.1,
    p_dep: float = 0.05,
    *,
    use_kernel: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """One stochastic-STDP event: returns updated weight bits.

    ``use_kernel=True`` routes the masked rewrite through the Pallas
    transposed-layout kernel (``kernels/stdp/ops.stdp_update``) instead of the
    jnp rule — same uniforms, bit-identical output (tested).
    """
    k1, k2 = jax.random.split(key)
    u_pot = jax.random.uniform(k1, weight_bits.shape)
    u_dep = jax.random.uniform(k2, weight_bits.shape)
    if use_kernel:
        from repro.kernels.stdp import ops as stdp_ops

        new_t = stdp_ops.stdp_update(
            weight_bits.T,
            pre_spikes.astype(jnp.int8),
            post_events.astype(jnp.int8),
            u_pot.T,
            u_dep.T,
            p_pot=float(p_pot),
            p_dep=float(p_dep),
            interpret=interpret,
        )
        return new_t.T
    return stdp_update_from_uniforms(
        weight_bits, pre_spikes, post_events, u_pot, u_dep, p_pot, p_dep
    )


# --------------------------------------------------------------------- #
# Column-event RNG: counter-based keys, <= 3 * n_in draws per sample
# --------------------------------------------------------------------- #
def column_event_uniforms(
    key: jax.Array, sample_index: jax.Array, n_in: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-sample uniforms for the <= 2 event columns of supervised STDP.

    Counter-based ``fold_in`` scheme — phase 0 potentiates / phase 1 depresses
    the teacher column, phase 2 depresses the wrong-winner column.  Both the
    fused column-event plane and the scan reference draw through this one
    function, which is what makes them bit-comparable.
    """
    ks = jax.random.fold_in(key, sample_index)
    u_pot = jax.random.uniform(jax.random.fold_in(ks, 0), (n_in,))
    u_dep_teacher = jax.random.uniform(jax.random.fold_in(ks, 1), (n_in,))
    u_dep_wrong = jax.random.uniform(jax.random.fold_in(ks, 2), (n_in,))
    return u_pot, u_dep_teacher, u_dep_wrong


# --------------------------------------------------------------------- #
# Hardware cost accounting (Sec 4.4.1)
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ColumnUpdateCost:
    cell: str
    read_cycles: int
    write_cycles: int
    read_ns: float
    write_ns: float
    energy_pj: float            # read-modify-write of one column
    speedup_read_vs_1rw: float
    speedup_write_vs_1rw: float


def column_update_cost(read_ports: int, rows: int = 128) -> ColumnUpdateCost:
    """Time/energy to read+write one weight column (one learning neuron).

    The 1RW baseline must touch all `rows` rows through the single RW port
    (2 x 128 cycles = 257.8 ns, 157 pJ for the full array, Sec 4.4.1).  With
    the transposed column port, access takes COL_MUX_FACTOR cycles each way at
    the transposed-path clock.
    """
    spec = cm.cell_spec(read_ports)
    rc, wc = cm.column_update_cycles(read_ports, rows)
    if read_ports == 0:
        # 1RW column RMW: precharge+read = 2 cycles per row, then one write per
        # row at the 1RW write time (see cost_model baseline decode).
        read_ns, write_ns = cm.T1RW_COL_READ_NS, cm.T1RW_COL_WRITE_NS
        energy = rows * (cm.E_READ_1RW_PJ + cm.E_WRITE_1RW_PJ)  # RMW every row
    else:
        clock = cm.T4R_TRANSPOSED_CLOCK_NS
        # Measured end-to-end column access times for the 4R cell (Sec 4.4.1);
        # cycle counts for other port counts scale identically (same mux).
        read_ns = cm.T4R_COL_READ_NS if read_ports == 4 else rc * clock + spec.sram_neuron_ns
        write_ns = cm.T4R_COL_WRITE_NS if read_ports == 4 else wc * clock + spec.sram_neuron_ns
        energy = spec.e_tread_pj + spec.e_write_pj   # one column-read + one column-write
    base_read_ns = cm.T1RW_COL_READ_NS
    base_write_ns = cm.T1RW_COL_WRITE_NS
    return ColumnUpdateCost(
        cell=spec.name,
        read_cycles=int(rc),
        write_cycles=int(wc),
        read_ns=float(read_ns),
        write_ns=float(write_ns),
        energy_pj=float(energy),
        speedup_read_vs_1rw=float(base_read_ns / read_ns),
        speedup_write_vs_1rw=float(base_write_ns / write_ns),
    )


# --------------------------------------------------------------------- #
# Frozen-prefix activations
# --------------------------------------------------------------------- #
def last_hidden_spikes(
    network_bits: list[jax.Array],
    vth: list[jax.Array],
    spikes: jax.Array,          # bool[batch, n_in]
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Run the frozen prefix tiles; returns the last tile's input spikes.

    Uses the packed fused plane (PR 1's ``forward_fused_packed`` datapath —
    uint32 bitplanes between tiles) when every hidden width is 32-aligned,
    falling back to the dense functional tiles otherwise.  Both are
    bit-identical (tests/test_packing.py), so the learning plane sees the same
    pre-synaptic trace either way.
    """
    hidden = network_bits[:-1]
    if hidden and all(w.shape[1] % 32 == 0 for w in hidden):
        from repro.core import packing
        from repro.core.esam import network as network_mod

        p = network_mod.packed_prefix(
            network_bits, vth, packing.pack_spikes(spikes), interpret=interpret)
        return packing.unpack_spikes(p, hidden[-1].shape[1], dtype=jnp.bool_)
    from repro.core.esam import tile as tile_mod

    s = spikes
    for w, th in zip(hidden, vth[:-1]):
        s, _ = tile_mod.functional_tile(w, s, th)
    return s


def readout_vmem(bits_t: jax.Array, spikes: jax.Array) -> jax.Array:
    """V_mem = s . (2b - 1) on the transposed-resident ``[n_out, n_in]`` layout.

    Integer arithmetic throughout — bit-identical to ``tile.functional_tile``'s
    einsum on the row-major layout (summation order is irrelevant for int32).
    Accepts a single sample ``[n_in]`` or any batch ``[..., n_in]``.
    """
    sv = spikes.astype(jnp.int32)
    w = bits_t.astype(jnp.int32)
    return 2 * jnp.einsum("...i,oi->...o", sv, w) - sv.sum(-1, keepdims=True)


# --------------------------------------------------------------------- #
# The fused column-event epoch (tentpole plane)
# --------------------------------------------------------------------- #
@functools.partial(
    jax.jit,
    static_argnames=("p_pot", "p_dep", "interpret"),
    donate_argnums=(0,),
)
def column_event_epoch(
    bits_t: jax.Array,          # {0,1}[n_out, n_in] transposed-resident layout
    pre: jax.Array,             # bool[batch, n_in] — last tile's input spikes
    labels: jax.Array,          # int32[batch]
    key: jax.Array,
    *,
    p_pot: float,
    p_dep: float,
    out_offset: jax.Array | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """One supervised-STDP epoch fused into a single jitted scan.

    Per sample: last-tile matvec on the transposed-resident bits, argmax
    readout, teacher / wrong-winner event derivation, and two gated
    column-port writes (``kernels/stdp.stdp_column_event``).  RNG is drawn
    only for the event columns (``column_event_uniforms``), the carry keeps
    the transposed bits resident, and the input buffer is donated — the TPU
    rendering of the paper's online-learning loop through the column RW port.

    ``out_offset`` shifts the argmax that derives the wrong-winner event, so
    learning can target the *deployed* readout (the folded conversion offset
    ``EsamNetwork.forward`` adds before its argmax).  The default ``None``
    keeps the offset-free vmem argmax of the scan reference (bit-comparable).

    Returns (updated bits_t, number of column updates as a device scalar).
    """
    from repro.kernels.stdp import ops as stdp_ops

    n_in = bits_t.shape[1]

    def body(bits_t, inp):
        s_i, y_i, i = inp
        vmem = readout_vmem(bits_t, s_i)
        if out_offset is None:
            pred = jnp.argmax(vmem)
        else:
            pred = jnp.argmax(vmem.astype(jnp.float32) + out_offset)
        wrong = pred != y_i
        u_pot, u_dep_t, u_dep_w = column_event_uniforms(key, i, n_in)
        # teacher column: Hebbian — pull it toward the pre pattern
        bits_t = stdp_ops.stdp_column_event(
            bits_t, y_i, wrong, s_i, u_pot, u_dep_t,
            p_pot=p_pot, p_dep=p_dep, interpret=interpret)
        # wrong winner: pure depression of active-pre synapses (inverted trace,
        # potentiation disabled — same rationale as the scan plane)
        bits_t = stdp_ops.stdp_column_event(
            bits_t, pred, wrong, jnp.logical_not(s_i), u_dep_w, u_dep_w,
            p_pot=0.0, p_dep=p_dep, interpret=interpret)
        return bits_t, wrong

    idx = jnp.arange(pre.shape[0], dtype=jnp.int32)
    bits_t, wrong = jax.lax.scan(body, bits_t, (pre, labels, idx))
    return bits_t, 2 * wrong.sum(dtype=jnp.int32)


def online_learning_epoch(
    network_bits: list[jax.Array],
    vth: list[jax.Array],
    spikes: jax.Array,          # bool[batch, n_in]
    labels: jax.Array,          # int32[batch] — supervised teacher events
    key: jax.Array,
    p_pot: float = 0.12,
    p_dep: float = 0.06,
    pre_spikes: jax.Array | None = None,
    *,
    interpret: bool | None = None,
):
    """Supervised-STDP pass over a batch for the *last* tile (delta-rule style).

    Teacher signal: the correct class neuron is a potentiation event; the
    argmax-wrong neuron is a depression event.  Returns (new last-layer bits,
    number of column updates as an int32 device scalar — cast once at the
    caller if a host int is needed; the count feeds the cost model).

    ``pre_spikes`` takes the last hidden layer's spikes if the caller already
    ran ``EsamNetwork.forward(..., collect=True)``; otherwise the frozen
    prefix runs once through the packed fused plane (``last_hidden_spikes``).
    The epoch itself is the fused column-event scan (``column_event_epoch``).
    """
    s = pre_spikes if pre_spikes is not None else last_hidden_spikes(
        network_bits, vth, spikes, interpret=interpret)
    bits_t = jnp.asarray(network_bits[-1]).T
    bits_t, n_updates = column_event_epoch(
        bits_t, s.astype(bool), labels, key,
        p_pot=float(p_pot), p_dep=float(p_dep), interpret=interpret)
    return bits_t.T, n_updates


def online_learning_epoch_scan(
    network_bits: list[jax.Array],
    vth: list[jax.Array],
    spikes: jax.Array,
    labels: jax.Array,
    key: jax.Array,
    p_pot: float = 0.12,
    p_dep: float = 0.06,
    pre_spikes: jax.Array | None = None,
    rng_scheme: str = "matrix",
):
    """The PR 1 per-sample scan: full ``[n_in, n_out]`` rewrite every sample.

    Kept as the measured baseline (benchmarks/bench_online_learning.py) and
    as the bit-identity oracle for the fused plane:

    * ``rng_scheme="matrix"`` — the original behavior: two full
      ``[n_in, n_out]`` uniform matrices drawn per sample from a split chain.
    * ``rng_scheme="column"`` — the shared counter-based column scheme
      (``column_event_uniforms``), broadcast across columns; only the event
      column's draw ever matters, so this is bit-identical to
      ``online_learning_epoch`` under the same key (tested).
    """
    from repro.core.esam import tile as tile_mod

    assert rng_scheme in ("matrix", "column"), rng_scheme
    bits_last = network_bits[-1]
    n_in, n_out = bits_last.shape
    if pre_spikes is not None:
        s = pre_spikes
    else:
        s = spikes
        for w, th in zip(network_bits[:-1], vth[:-1]):
            s, _ = tile_mod.functional_tile(w, s, th)

    def body(carry, inp):
        bits, k = carry
        s_i, y_i, i = inp
        _, vmem = tile_mod.functional_tile(bits, s_i, vth[-1])
        pred = jnp.argmax(vmem)
        wrong = pred != y_i
        post_pot = jax.nn.one_hot(y_i, n_out, dtype=bool) & wrong
        post_dep = jax.nn.one_hot(pred, n_out, dtype=bool) & wrong
        if rng_scheme == "matrix":
            k, k1, k2 = jax.random.split(k, 3)
            # correct neuron: Hebbian — pull its column toward the pre pattern
            bits = stdp_update(bits, s_i, post_pot, k1, p_pot, p_dep)
            # wrong winner: pure depression of active-pre synapses (bit -> 0).
            # Expressed via stdp_update with the pre trace inverted and
            # potentiation disabled — potentiating silent positions would
            # *raise* the winner's response to shifted variants instead of
            # suppressing it.
            bits = stdp_update(bits, ~s_i, post_dep, k2, 0.0, p_dep)
        else:
            u_pot, u_dep_t, u_dep_w = column_event_uniforms(key, i, n_in)
            bits = stdp_update_from_uniforms(
                bits, s_i, post_pot, u_pot[:, None], u_dep_t[:, None],
                p_pot, p_dep)
            bits = stdp_update_from_uniforms(
                bits, ~s_i, post_dep, u_dep_w[:, None], u_dep_w[:, None],
                0.0, p_dep)
        return (bits, k), wrong.astype(jnp.int32) * 2

    idx = jnp.arange(s.shape[0], dtype=jnp.int32)
    (bits_last, _), upd = jax.lax.scan(body, (bits_last, key), (s, labels, idx))
    return bits_last, upd.sum()

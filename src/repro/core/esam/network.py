"""Multi-tile ESAM network: functional + cycle-accurate simulation and the
system-level performance model (throughput / energy / power / area).

Tiles are cascaded directly; spikes travel between tiles as parallel binary
pulses (Sec 3.1), which lets the tile pipeline overlap consecutive samples:
tile t processes sample s while tile t+1 processes sample s-1.  System
throughput is therefore set by the slowest tile stage; latency is the sum of
stages (both in cycles of the cell-dependent clock, Table 2).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.esam import arbiter as arb
from repro.core.esam import cost_model as cm
from repro.core.esam import tile as tile_mod

ROW_GROUP = 128


@dataclasses.dataclass
class EsamNetwork:
    """A stack of CIM-P tiles (binary SNN).

    weight_bits: per layer, {0,1}[n_in, n_out] stored bits ('1' -> +1, '0' -> -1).
    vth: per layer, int32[n_out] per-neuron thresholds (Fig 5's t-bit register).
    out_offset: float[n_classes] — per-neuron readout offset folded from the
      BNN's final-layer bias during conversion (argmax-preserving).
    """

    weight_bits: list[jax.Array]
    vth: list[jax.Array]
    out_offset: jax.Array

    @property
    def topology(self) -> tuple[int, ...]:
        return tuple([self.weight_bits[0].shape[0]] + [w.shape[1] for w in self.weight_bits])

    @property
    def n_neurons(self) -> int:
        return sum(w.shape[1] for w in self.weight_bits)

    @property
    def n_synapses(self) -> int:
        return sum(int(np.prod(w.shape)) for w in self.weight_bits)

    # ------------------------------------------------------------------ #
    # Functional (batched, MXU-friendly) plane
    # ------------------------------------------------------------------ #
    def forward(self, spikes: jax.Array, collect: bool = False):
        """Batched inference. spikes: bool[..., n_in] -> logits float[..., n_cls].

        The final tile's V_mem plus the folded offset is the classification
        score (output neurons are read out, not thresholded — argmax readout).
        """
        per_layer = []
        s = spikes
        for w, th in zip(self.weight_bits[:-1], self.vth[:-1]):
            s, _ = tile_mod.functional_tile(w, s, th)
            per_layer.append(s)
        _, vmem = tile_mod.functional_tile(self.weight_bits[-1], s, self.vth[-1])
        logits = vmem.astype(jnp.float32) + self.out_offset
        if collect:
            return logits, per_layer
        return logits

    def spike_counts(
        self, spikes: jax.Array, per_layer: Sequence[jax.Array] | None = None
    ) -> list[jax.Array]:
        """Per-layer, per-row-group spike counts for a batch (for the cost model).

        Returns a list over tiles of int32[..., n_groups]: the arbiter load of
        each 128-row group at that tile's input.

        ``per_layer`` takes the hidden-layer spikes a caller already computed
        via ``forward(..., collect=True)`` — the counts are then pure
        reductions and no tile matmul is re-run.
        """
        if per_layer is None:
            per_layer = []
            s = spikes
            for w, th in zip(self.weight_bits[:-1], self.vth[:-1]):
                s, _ = tile_mod.functional_tile(w, s, th)
                per_layer.append(s)
        n_hidden = len(self.weight_bits) - 1
        assert len(per_layer) >= n_hidden, (len(per_layer), n_hidden)
        layer_inputs = [spikes, *per_layer[:n_hidden]]
        return [
            arb.split_row_groups(s.astype(jnp.int32)).sum(-1) for s in layer_inputs
        ]

    # ------------------------------------------------------------------ #
    # Packed (bit-plane) fused plane — the inter-tile pulse bus on TPU
    # ------------------------------------------------------------------ #
    def forward_fused(
        self, spikes: jax.Array, *, interpret: bool | None = None
    ) -> jax.Array:
        """``forward`` on the packed datapath: spikes are bit-packed once at
        the input, every hidden tile runs the fused MAC+fire+re-pack kernel
        (kernels/cim_matmul_packed), and only uint32 bitplanes — 32 spikes per
        lane word, the paper's parallel-pulse wire — travel between tiles.
        Logits are bit-identical to ``forward`` (tested)."""
        from repro.core import packing

        n_in = spikes.shape[-1]
        lead = spikes.shape[:-1]
        packed = packing.pack_spikes(spikes.reshape(-1, n_in))
        logits = self.forward_fused_packed(packed, interpret=interpret)
        return logits.reshape(*lead, logits.shape[-1])

    def forward_prefix_packed(
        self, packed: jax.Array, *, interpret: bool | None = None
    ) -> jax.Array:
        """Run only the frozen hidden tiles on the packed plane.

        Takes and returns the uint32 bitplane wire format: the result is the
        last tile's *input* spike plane, uint32[B, n_hidden/32].  This is the
        prefix the online-learning plane consumes (via the module-level
        ``packed_prefix``) — the learned last tile is excluded, so the prefix
        can be computed once and reused across epochs.
        """
        return packed_prefix(
            self.weight_bits, self.vth, packed, interpret=interpret
        )

    def forward_fused_packed(
        self, packed: jax.Array, *, interpret: bool | None = None
    ) -> jax.Array:
        """Fused cascade over pre-packed spikes uint32[B, ceil(n_in/32)]."""
        logits, _ = self.forward_fused_packed_collect(packed, interpret=interpret)
        return logits

    def forward_fused_packed_collect(
        self, packed: jax.Array, *, interpret: bool | None = None
    ) -> tuple[jax.Array, list[jax.Array]]:
        """``forward_fused_packed`` plus the tile-input bitplane at every tile
        boundary — one pass, nothing unpacked.  The planes' group popcounts
        (``packing.group_popcount``) are the measured arbiter loads, so the
        serving plane's cost telemetry rides the packed datapath for free."""
        from repro.kernels.cim_matmul_packed import ops as packed_ops

        p, planes = packed_prefix(
            self.weight_bits, self.vth, packed, interpret=interpret, collect=True
        )
        vmem = packed_ops.cim_matmul_packed(
            p, self.weight_bits[-1], interpret=interpret
        )
        return vmem.astype(jnp.float32) + self.out_offset, planes

    # ------------------------------------------------------------------ #
    # Cycle-accurate (event-driven) plane
    # ------------------------------------------------------------------ #
    def forward_cycle_accurate(
        self, spikes1: jax.Array, ports: int, record_vmem_trace: bool = False
    ):
        """Single-sample event-driven simulation through every tile.

        Returns (logits, [TileTrace per tile]).  Output logits are bit-identical
        to ``forward`` (tested) — the multiport schedule only changes *when*
        contributions accumulate, never their sum.
        """
        traces = []
        s = spikes1
        for w, th in zip(self.weight_bits, self.vth):
            tr = tile_mod.simulate_tile(w, s, th, ports, record_vmem_trace)
            traces.append(tr)
            s = tr.out_spikes
        logits = traces[-1].vmem_final.astype(jnp.float32) + self.out_offset
        return logits, traces

    def forward_cycle_accurate_batch(
        self, spikes: jax.Array, ports: int, record_vmem_trace: bool = False
    ):
        """Event-driven simulation of a whole batch on the rank-schedule plane.

        spikes: bool[batch, n_in].  Returns (logits float[batch, n_cls],
        [batched TileTrace per tile]) — each trace field has a leading batch
        axis.  With the default ``record_vmem_trace=False`` the per-sample
        state stays O(n_out), which is what makes this plane batchable.
        """
        traces = []
        s = spikes
        for w, th in zip(self.weight_bits, self.vth):
            tr = tile_mod.simulate_tile_batch(w, s, th, ports, record_vmem_trace)
            traces.append(tr)
            s = tr.out_spikes
        logits = traces[-1].vmem_final.astype(jnp.float32) + self.out_offset
        return logits, traces

    def port_sweep(
        self,
        spikes: jax.Array,
        read_ports: Sequence[int] = range(5),
        record_vmem_trace: bool = False,
    ) -> dict[int, tuple[jax.Array, list[tile_mod.TileTrace]]]:
        """Batched cycle-accurate design-space sweep over SRAM cell options.

        Runs the rank-schedule plane through every tile for each cell option
        in ``read_ports`` (0 = the 1RW baseline reading through its RW port),
        all inside ONE jitted call — the Fig 8 workload as a single device
        program instead of a Python loop of simulations.

        spikes: bool[batch, n_in].  Returns {read_ports: (logits, traces)};
        logits are identical across entries (the schedule only moves *when*
        contributions land), while traces carry the per-option cycle counts
        the cost model consumes.
        """
        rp = tuple(int(p) for p in read_ports)
        out = _port_sweep_jit(
            self.weight_bits, self.vth, self.out_offset, spikes, rp,
            record_vmem_trace,
        )
        return dict(zip(rp, out))

    def measured_activity(
        self,
        spikes: jax.Array,
        traces: Sequence[tile_mod.TileTrace] | None = None,
    ) -> list[np.ndarray]:
        """Measured arbiter loads of a batch, ready for ``system_stats``.

        Returns per tile float64[batch, n_groups] — the *measured* activity
        profile (vs the synthetic ``reference_activity``).  Pass the traces of
        a ``port_sweep``/``forward_cycle_accurate_batch`` run to reuse the
        spikes the simulator actually drained; otherwise the functional plane
        recomputes the hidden layers.
        """
        per_layer = None
        if traces is not None:
            per_layer = [tr.out_spikes for tr in traces[:-1]]
        counts = self.spike_counts(spikes, per_layer=per_layer)
        return [np.asarray(c, np.float64) for c in counts]


@partial(jax.jit, static_argnames=("read_ports", "record_vmem_trace"))
def _port_sweep_jit(
    weight_bits, vth, out_offset, spikes, read_ports: tuple[int, ...],
    record_vmem_trace: bool,
):
    """One device program for the whole port sweep (unrolled over options —
    each option has its own static schedule length ceil(128/p)).  Cell
    options sharing an effective port count (0 and 1: the 1RW cell reads
    through its single RW port) share one simulation."""
    by_ports: dict[int, tuple] = {}
    out = []
    for p in read_ports:
        ports = max(1, p)
        if ports not in by_ports:
            traces = []
            s = spikes
            for w, th in zip(weight_bits, vth):
                tr = tile_mod.simulate_tile_batch(w, s, th, ports, record_vmem_trace)
                traces.append(tr)
                s = tr.out_spikes
            logits = traces[-1].vmem_final.astype(jnp.float32) + out_offset
            by_ports[ports] = (logits, traces)
        out.append(by_ports[ports])
    return out


def packed_prefix(
    weight_bits: Sequence[jax.Array],
    vth: Sequence[jax.Array],
    packed: jax.Array,
    *,
    interpret: bool | None = None,
    collect: bool = False,
):
    """Cascade the hidden tiles (all but the last) on the packed plane.

    The single source of the packed prefix datapath: both inference
    (``EsamNetwork.forward_prefix_packed`` / ``forward_fused_packed``) and the
    online-learning plane (``learning.last_hidden_spikes``) run their frozen
    tiles through here, so the learning plane's pre-synaptic trace can never
    desynchronize from the serving datapath.

    Hidden widths must be multiples of 32 (they are 128-aligned tile columns
    in every paper topology) so fired planes re-pack exactly.

    ``collect=True`` returns (prefix, [tile-input bitplane per tile]) — the
    packed wire at every tile boundary, including the last tile's input
    (== the prefix), which is all the cost-model telemetry needs: arbiter
    loads are popcounts of these planes.
    """
    from repro.kernels.cim_matmul_packed import ops as packed_ops

    for w in weight_bits[:-1]:
        assert w.shape[1] % 32 == 0, (
            "hidden width must be 32-aligned for the packed plane",
            w.shape,
        )
    p = packed
    planes = [p]
    for w, th in zip(weight_bits[:-1], vth[:-1]):
        p = packed_ops.esam_layer_packed(p, w, th, interpret=interpret)
        planes.append(p)
    if collect:
        return p, planes
    return p


# ---------------------------------------------------------------------- #
# System-level performance model
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SystemStats:
    cell: str
    read_ports: int
    clock_ns: float
    cycles_per_tile: tuple[float, ...]   # mean cycles until R_empty, + fire cycle
    bottleneck_tile: int
    latency_ns: float                    # single-inference latency
    throughput_inf_s: float              # pipelined
    energy_pj_per_inf: float
    dynamic_power_mw: float
    power_mw: float                      # incl. static
    area_um2: float
    area_ratio_vs_1rw: float


#: (row groups, column groups) of 128x128 arrays for an n_in x n_out tile.
_tile_geometry = cm.tile_geometry


def system_stats(
    topology: Sequence[int],
    spikes_per_group: Sequence[np.ndarray] | Sequence[Sequence[float]],
    read_ports: int,
) -> SystemStats:
    """Evaluate the full-system operating point for one cell option.

    Batch means over ``cost_model.request_stats`` — the same per-request
    accounting the serving plane reports — so an operating point can be
    evaluated on the synthetic calibration profile (``reference_activity``)
    or on *measured* batch activity (``EsamNetwork.measured_activity``)
    interchangeably.

    Args:
      topology: e.g. (768, 256, 256, 256, 10).
      spikes_per_group: per tile, array[..., n_groups] of arbiter loads (may be
        a batch — averaged for throughput/energy; max-over-groups is taken per
        sample *before* averaging, matching how the hardware stalls).
      read_ports: 0 (=1RW baseline) .. 4.
    """
    spec = cm.cell_spec(read_ports)
    rs = cm.request_stats(topology, spikes_per_group, read_ports)
    cycles = rs.cycles_per_tile.mean(axis=0)         # [T] mean incl. fire cycle
    energy = float(rs.energy_pj.mean())
    bottleneck = int(np.argmax(cycles))
    stage_ns = max(cycles) * spec.clock_ns
    throughput = 1e9 / stage_ns
    latency_ns = float(sum(cycles) * spec.clock_ns)
    dyn_mw = energy * 1e-12 * throughput * 1e3
    area = _system_area_um2(topology, read_ports)
    return SystemStats(
        cell=spec.name,
        read_ports=read_ports,
        clock_ns=spec.clock_ns,
        cycles_per_tile=tuple(float(c) for c in cycles),
        bottleneck_tile=bottleneck,
        latency_ns=latency_ns,
        throughput_inf_s=float(throughput),
        energy_pj_per_inf=float(energy),
        dynamic_power_mw=float(dyn_mw),
        power_mw=float(dyn_mw + cm.STATIC_POWER_MW),
        area_um2=area,
        area_ratio_vs_1rw=area / _system_area_um2(topology, 0),
    )


def _system_area_um2(topology: Sequence[int], read_ports: int) -> float:
    area = 0.0
    base = cm.CELL_AREA_6T_UM2 * ROW_GROUP * ROW_GROUP
    for t in range(len(topology) - 1):
        g, c = _tile_geometry(topology[t], topology[t + 1])
        n_arrays = g * c
        area += n_arrays * (base * cm.CELL_AREA_RATIO[read_ports]
                            + base * cm.PERIPHERY_AREA_FRACTION)
    return area


def reference_activity(topology: Sequence[int] = cm.PAPER_TOPOLOGY) -> list[np.ndarray]:
    """The calibration activity profile (see cost_model.REF_SPIKES_PER_GROUP)."""
    out = []
    for t in range(len(topology) - 1):
        n_groups, _ = _tile_geometry(topology[t], topology[t + 1])
        out.append(np.full((1, n_groups), cm.REF_SPIKES_PER_GROUP[t], np.float64))
    return out

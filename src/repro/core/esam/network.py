"""Multi-tile ESAM network: functional + cycle-accurate simulation and the
system-level performance model (throughput / energy / power / area).

Tiles are cascaded directly; spikes travel between tiles as parallel binary
pulses (Sec 3.1), which lets the tile pipeline overlap consecutive samples:
tile t processes sample s while tile t+1 processes sample s-1.  System
throughput is therefore set by the slowest tile stage; latency is the sum of
stages (both in cycles of the cell-dependent clock, Table 2).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.esam import arbiter as arb
from repro.core.esam import cost_model as cm
from repro.core.esam import tile as tile_mod
from repro.core.esam import plan as plan_mod
from repro.core.esam.plan import EsamPlan, PlanSpec

ROW_GROUP = 128

#: The legacy ``forward*`` entry points below are deprecated wrappers over
#: ``EsamNetwork.plan`` — each warns once per process.
_DEPRECATION_WARNED: set[str] = set()


def reset_deprecation_warnings() -> None:
    """Forget which deprecated ``forward*`` wrappers have already warned.

    The warn-once registry is process-global, so a test asserting that a
    wrapper warns would otherwise depend on whether another test tripped the
    same wrapper first.  Warning-assertion tests call this before recording
    (tests/test_plan.py, tests/test_network_deprecations.py).
    """
    _DEPRECATION_WARNED.clear()


def _warn_deprecated(name: str, instead: str) -> None:
    if name in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(name)
    warnings.warn(
        f"EsamNetwork.{name} is deprecated; build an execution plan once via "
        f"EsamNetwork.plan({instead}) and call it per batch.",
        DeprecationWarning,
        stacklevel=3,
    )


@dataclasses.dataclass
class EsamNetwork:
    """A stack of CIM-P tiles (binary SNN).

    weight_bits: per layer, {0,1}[n_in, n_out] stored bits ('1' -> +1, '0' -> -1).
    vth: per layer, int32[n_out] per-neuron thresholds (Fig 5's t-bit register).
    out_offset: float[n_classes] — per-neuron readout offset folded from the
      BNN's final-layer bias during conversion (argmax-preserving).

    All inference entry points compile through :class:`EsamPlan`
    (``core/esam/plan.py``): ``plan(...)`` builds — and caches per network —
    exactly one jitted (or shard_map-ped) executable for a given
    (mode, collect, telemetry, read_ports, sharding) tuple.  The historical
    ``forward*`` methods survive as thin deprecated wrappers over it.
    """

    weight_bits: list[jax.Array]
    vth: list[jax.Array]
    out_offset: jax.Array
    _plan_cache: dict = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)

    @property
    def topology(self) -> tuple[int, ...]:
        return tuple([self.weight_bits[0].shape[0]] + [w.shape[1] for w in self.weight_bits])

    # ------------------------------------------------------------------ #
    # Execution plans — the single compiled entry point
    # ------------------------------------------------------------------ #
    def plan(
        self,
        *,
        mode: str = "packed",
        collect: bool = False,
        telemetry: bool = False,
        read_ports: int | tuple[int, ...] = 4,
        record_vmem_trace: bool = False,
        interpret: bool | None = None,
        temporal=None,  # Optional[temporal.TemporalConfig], mode="temporal"
        faults=None,  # Optional[faults.FaultModel]
        rules=None,
        donate: bool = False,
    ) -> EsamPlan:
        """Build (or fetch from this network's cache) one compiled plan.

        ``rules`` takes :func:`repro.distributed.sharding.make_esam_rules`
        output to compile the plan sharded over a device mesh; plans built
        with rules are cached by rule-object identity.  ``mode="temporal"``
        takes a :class:`~repro.core.esam.temporal.TemporalConfig` — each
        (T, leak, reset, refractory, collect, telemetry) tuple compiles one
        executable, cached like every other spec.  ``faults`` takes a
        :class:`~repro.core.esam.faults.FaultModel` to compile the plan with
        that fault population injected into the datapath (each model is its
        own cache entry; ``None`` is the clean plan, bit-identical to
        pre-fault builds).  ``donate=True`` donates the input batch to XLA
        so drain loops reuse device allocations round-over-round — only for
        callers that own the arrays they pass (the serving engine).
        """
        spec = PlanSpec(
            mode=mode,
            collect=collect,
            telemetry=telemetry,
            read_ports=read_ports,
            record_vmem_trace=record_vmem_trace,
            interpret=interpret,
            temporal=temporal,
            faults=faults,
            donate=donate,
        )
        key = (spec, None if rules is None else id(rules))
        cached = self._plan_cache.get(key)
        if cached is None:
            cached = EsamPlan(self, spec, rules=rules)
            self._plan_cache[key] = cached
        return cached

    @property
    def n_neurons(self) -> int:
        return sum(w.shape[1] for w in self.weight_bits)

    @property
    def n_synapses(self) -> int:
        return sum(int(np.prod(w.shape)) for w in self.weight_bits)

    # ------------------------------------------------------------------ #
    # Functional (batched, MXU-friendly) plane — deprecated wrappers
    # ------------------------------------------------------------------ #
    def forward(self, spikes: jax.Array, collect: bool = False):
        """Batched inference. spikes: bool[..., n_in] -> logits float[..., n_cls].

        The final tile's V_mem plus the folded offset is the classification
        score (output neurons are read out, not thresholded — argmax readout).

        .. deprecated:: use ``plan(mode="functional")``.
        """
        _warn_deprecated("forward", 'mode="functional"')
        res = self.plan(mode="functional", collect=collect)(spikes)
        if collect:
            return res.logits, list(res.planes)
        return res.logits

    def spike_counts(
        self, spikes: jax.Array, per_layer: Sequence[jax.Array] | None = None
    ) -> list[jax.Array]:
        """Per-layer, per-row-group spike counts for a batch (for the cost model).

        Returns a list over tiles of int32[..., n_groups]: the arbiter load of
        each 128-row group at that tile's input.

        ``per_layer`` takes the hidden-layer spikes a caller already computed
        via ``forward(..., collect=True)`` — the counts are then pure
        reductions and no tile matmul is re-run.  Without it the functional
        plan runs once with telemetry on.
        """
        n_hidden = len(self.weight_bits) - 1
        if per_layer is None:
            return list(
                self.plan(mode="functional", telemetry=True)(spikes).loads)
        assert len(per_layer) >= n_hidden, (len(per_layer), n_hidden)
        layer_inputs = [spikes, *per_layer[:n_hidden]]
        return [
            arb.split_row_groups(s.astype(jnp.int32)).sum(-1) for s in layer_inputs
        ]

    # ------------------------------------------------------------------ #
    # Packed (bit-plane) fused plane — deprecated wrappers
    # ------------------------------------------------------------------ #
    def forward_fused(
        self, spikes: jax.Array, *, interpret: bool | None = None
    ) -> jax.Array:
        """``forward`` on the packed datapath: spikes are bit-packed once at
        the input, every hidden tile runs the fused MAC+fire+re-pack kernel
        (kernels/cim_matmul_packed), and only uint32 bitplanes — 32 spikes per
        lane word, the paper's parallel-pulse wire — travel between tiles.
        Logits are bit-identical to ``forward`` (tested).

        .. deprecated:: use ``plan()`` (packed is the default mode).
        """
        _warn_deprecated("forward_fused", 'mode="packed"')
        return self.plan(mode="packed", interpret=interpret)(spikes).logits

    def forward_prefix_packed(
        self, packed: jax.Array, *, interpret: bool | None = None
    ) -> jax.Array:
        """Run only the frozen hidden tiles on the packed plane.

        Takes and returns the uint32 bitplane wire format: the result is the
        last tile's *input* spike plane, uint32[B, n_hidden/32] — the prefix
        the online-learning plane reuses across epochs.

        .. deprecated:: use ``plan(mode="prefix")``.
        """
        _warn_deprecated("forward_prefix_packed", 'mode="prefix"')
        return self.plan(mode="prefix", interpret=interpret)(packed).prefix

    def forward_fused_packed(
        self, packed: jax.Array, *, interpret: bool | None = None
    ) -> jax.Array:
        """Fused cascade over pre-packed spikes uint32[B, ceil(n_in/32)].

        .. deprecated:: use ``plan(mode="packed")``.
        """
        _warn_deprecated("forward_fused_packed", 'mode="packed"')
        return self.plan(mode="packed", interpret=interpret)(packed).logits

    def forward_fused_packed_collect(
        self, packed: jax.Array, *, interpret: bool | None = None
    ) -> tuple[jax.Array, list[jax.Array]]:
        """``forward_fused_packed`` plus the tile-input bitplane at every tile
        boundary — one pass, nothing unpacked.  The planes' group popcounts
        (``packing.group_popcount``) are the measured arbiter loads, so the
        serving plane's cost telemetry rides the packed datapath for free.

        .. deprecated:: use ``plan(mode="packed", collect=True)``.
        """
        _warn_deprecated("forward_fused_packed_collect",
                         'mode="packed", collect=True')
        res = self.plan(mode="packed", collect=True, interpret=interpret)(packed)
        return res.logits, list(res.planes)

    # ------------------------------------------------------------------ #
    # Cycle-accurate (event-driven) plane — deprecated wrappers
    # ------------------------------------------------------------------ #
    def forward_cycle_accurate(
        self, spikes1: jax.Array, ports: int, record_vmem_trace: bool = False
    ):
        """Single-sample event-driven simulation through every tile.

        Returns (logits, [TileTrace per tile]).  Output logits are bit-identical
        to ``forward`` (tested) — the multiport schedule only changes *when*
        contributions accumulate, never their sum.

        .. deprecated:: use ``plan(mode="cycle", read_ports=ports)``.
        """
        _warn_deprecated("forward_cycle_accurate", 'mode="cycle"')
        res = self.plan(
            mode="cycle", read_ports=int(ports),
            record_vmem_trace=record_vmem_trace,
        )(spikes1)
        return res.logits, list(res.traces)

    def forward_cycle_accurate_batch(
        self, spikes: jax.Array, ports: int, record_vmem_trace: bool = False
    ):
        """Event-driven simulation of a whole batch on the rank-schedule plane.

        spikes: bool[batch, n_in].  Returns (logits float[batch, n_cls],
        [batched TileTrace per tile]) — each trace field has a leading batch
        axis.  With the default ``record_vmem_trace=False`` the per-sample
        state stays O(n_out), which is what makes this plane batchable.

        .. deprecated:: use ``plan(mode="cycle", read_ports=ports)``.
        """
        _warn_deprecated("forward_cycle_accurate_batch", 'mode="cycle"')
        res = self.plan(
            mode="cycle", read_ports=int(ports),
            record_vmem_trace=record_vmem_trace,
        )(spikes)
        return res.logits, list(res.traces)

    def port_sweep(
        self,
        spikes: jax.Array,
        read_ports: Sequence[int] = range(5),
        record_vmem_trace: bool = False,
    ) -> dict[int, tuple[jax.Array, list[tile_mod.TileTrace]]]:
        """Batched cycle-accurate design-space sweep over SRAM cell options.

        Runs the rank-schedule plane through every tile for each cell option
        in ``read_ports`` (0 = the 1RW baseline reading through its RW port),
        all inside ONE compiled plan — the Fig 8 workload as a single device
        program instead of a Python loop of simulations.  Cell options
        sharing an effective port count (0 and 1: the 1RW cell reads through
        its single RW port) share one simulation inside the plan.

        spikes: bool[batch, n_in].  Returns {read_ports: (logits, traces)};
        logits are identical across entries (the schedule only moves *when*
        contributions land), while traces carry the per-option cycle counts
        the cost model consumes.
        """
        rp = tuple(int(p) for p in read_ports)
        res = self.plan(
            mode="cycle", read_ports=rp, record_vmem_trace=record_vmem_trace
        )(spikes)
        return {p: (res.sweep[p]["logits"], list(res.sweep[p]["traces"]))
                for p in rp}

    def measured_activity(
        self,
        spikes: jax.Array,
        traces: Sequence[tile_mod.TileTrace] | None = None,
    ) -> list[np.ndarray]:
        """Measured arbiter loads of a batch, ready for ``system_stats``.

        Returns per tile float64[batch, n_groups] — the *measured* activity
        profile (vs the synthetic ``reference_activity``).  Pass the traces of
        a ``port_sweep``/``forward_cycle_accurate_batch`` run to reuse the
        spikes the simulator actually drained; otherwise the functional plan
        runs once with telemetry on.
        """
        if traces is not None:
            per_layer = [tr.out_spikes for tr in traces[:-1]]
            counts = self.spike_counts(spikes, per_layer=per_layer)
        else:
            counts = self.plan(mode="functional", telemetry=True)(spikes).loads
        return [np.asarray(c, np.float64) for c in counts]


#: Back-compat alias: the packed hidden-tile cascade now lives in
#: ``core/esam/plan.py`` (the plan layer is its single owner).
packed_prefix = plan_mod._packed_cascade


# ---------------------------------------------------------------------- #
# System-level performance model
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SystemStats:
    cell: str
    read_ports: int
    clock_ns: float
    cycles_per_tile: tuple[float, ...]   # mean cycles until R_empty, + fire cycle
    bottleneck_tile: int
    latency_ns: float                    # single-inference latency
    throughput_inf_s: float              # pipelined
    energy_pj_per_inf: float
    dynamic_power_mw: float
    power_mw: float                      # incl. static
    area_um2: float
    area_ratio_vs_1rw: float


#: (row groups, column groups) of 128x128 arrays for an n_in x n_out tile.
_tile_geometry = cm.tile_geometry


def system_stats(
    topology: Sequence[int],
    spikes_per_group: Sequence[np.ndarray] | Sequence[Sequence[float]],
    read_ports: int,
) -> SystemStats:
    """Evaluate the full-system operating point for one cell option.

    Batch means over ``cost_model.request_stats`` — the same per-request
    accounting the serving plane reports — so an operating point can be
    evaluated on the synthetic calibration profile (``reference_activity``)
    or on *measured* batch activity (``EsamNetwork.measured_activity``)
    interchangeably.

    Args:
      topology: e.g. (768, 256, 256, 256, 10).
      spikes_per_group: per tile, array[..., n_groups] of arbiter loads (may be
        a batch — averaged for throughput/energy; max-over-groups is taken per
        sample *before* averaging, matching how the hardware stalls).
      read_ports: 0 (=1RW baseline) .. 4.
    """
    spec = cm.cell_spec(read_ports)
    rs = cm.request_stats(topology, spikes_per_group, read_ports)
    cycles = rs.cycles_per_tile.mean(axis=0)         # [T] mean incl. fire cycle
    energy = float(rs.energy_pj.mean())
    bottleneck = int(np.argmax(cycles))
    stage_ns = max(cycles) * spec.clock_ns
    throughput = 1e9 / stage_ns
    latency_ns = float(sum(cycles) * spec.clock_ns)
    dyn_mw = energy * 1e-12 * throughput * 1e3
    area = _system_area_um2(topology, read_ports)
    return SystemStats(
        cell=spec.name,
        read_ports=read_ports,
        clock_ns=spec.clock_ns,
        cycles_per_tile=tuple(float(c) for c in cycles),
        bottleneck_tile=bottleneck,
        latency_ns=latency_ns,
        throughput_inf_s=float(throughput),
        energy_pj_per_inf=float(energy),
        dynamic_power_mw=float(dyn_mw),
        power_mw=float(dyn_mw + cm.STATIC_POWER_MW),
        area_um2=area,
        area_ratio_vs_1rw=area / _system_area_um2(topology, 0),
    )


def _system_area_um2(topology: Sequence[int], read_ports: int) -> float:
    area = 0.0
    base = cm.CELL_AREA_6T_UM2 * ROW_GROUP * ROW_GROUP
    for t in range(len(topology) - 1):
        g, c = _tile_geometry(topology[t], topology[t + 1])
        n_arrays = g * c
        area += n_arrays * (base * cm.CELL_AREA_RATIO[read_ports]
                            + base * cm.PERIPHERY_AREA_FRACTION)
    return area


def reference_activity(topology: Sequence[int] = cm.PAPER_TOPOLOGY) -> list[np.ndarray]:
    """The calibration activity profile (see cost_model.REF_SPIKES_PER_GROUP)."""
    out = []
    for t in range(len(topology) - 1):
        n_groups, _ = _tile_geometry(topology[t], topology[t + 1])
        out.append(np.full((1, n_groups), cm.REF_SPIKES_PER_GROUP[t], np.float64))
    return out

"""Temporal event plane: multi-timestep LIF simulation with membrane-resident
fused scan.

Everything before this module was single-timestep: one spike plane in, one
argmax out, V_mem reset every sample.  The temporal plane runs *event
streams* — T timesteps of binary spike planes (``repro.data.events``) —
through the same tile cascade with membrane potential persisting across
steps, IMPULSE-style (Agrawal et al.: weights and membrane state fused in
one CIM macro; the membrane never leaves the array between timesteps).

The fused forward is a single jitted ``lax.scan`` over timesteps.  Its carry
is the full membrane state of every tile — ``float32[B, n_out]`` V_mem plus
an ``int32[B, n_out]`` refractory counter per hidden tile, and the output
tile's accumulator — so state stays device-resident for the whole stream.
Two structural optimizations ride the fused formulation:

  * the first tile's MAC depends only on the input events, never on state,
    so it is lifted out of the time loop into ONE flattened ``[T*B, n_in]``
    MAC before the scan (far better arithmetic intensity than T small ones);
  * the loop-invariant weight decode ({0,1} bits -> ±1 operand) happens once
    outside the scan instead of once per step.

Per-step work dispatches by backend, mirroring ``kernels/arbiter``: on TPU
the MAC is the popcount-domain Pallas kernel (``kernels/cim_popcount`` —
uint32 bitplanes on the inter-tile wire AND weight bit planes, no unpack)
and the membrane update is the fused ``kernels/lif_step`` kernel; elsewhere
the MAC unpacks
in-jit and runs one float32 BLAS dot (exact: every operand and partial sum
is an integer far below 2^24) and the update is the jnp reference.  Both
paths are bit-identical on the integer datapath.

``temporal_forward_naive`` is the deliberately naive per-step Python loop —
dense per-step tiles with host-resident state and one device round-trip per
timestep.  With ``jit_step=True`` (default) each step is one jitted call:
the bit-identity oracle for the fused scan (tests/test_temporal.py).  With
``jit_step=False`` every op dispatches eagerly — the true first-pass
research implementation, and the baseline ``benchmarks/bench_temporal.py``
records the fused speedup against (eager arithmetic is unfused, so
agreement there is to float32 ulp once a leak is on, bitwise at zero leak).

With ``n_steps=1``, ``leak=0``, ``reset="zero"`` the temporal plane is
bit-identical to the static packed plane (property-tested): one step of
leak-free LIF from zero state *is* the IF fire of the fused cascade.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.esam import arbiter as arb
from repro.kernels.lif_step.ref import RESET_MODES


@dataclasses.dataclass(frozen=True)
class TemporalConfig:
    """Static dynamics of one temporal execution (part of the plan cache key).

    n_steps:    T, the number of timesteps in the event stream.
    leak:       fraction of V_mem lost per step (V *= 1 - leak); 0 disables
                the leak exactly (float32 multiply by 1.0 is the identity).
    reset:      "zero" (V_mem := 0 on fire, the paper's Sec 3.4 behaviour)
                or "subtract" (V_mem -= V_th, carrying the residual).
    refractory: steps a neuron stays silent after firing (0 disables).
    """

    n_steps: int
    leak: float = 0.0
    reset: str = "zero"
    refractory: int = 0

    def __post_init__(self):
        assert self.n_steps >= 1, self.n_steps
        assert 0.0 <= self.leak < 1.0, self.leak
        assert self.reset in RESET_MODES, (self.reset, RESET_MODES)
        assert self.refractory >= 0, self.refractory


def init_state(topology, batch: int):
    """Zero membrane state for one event stream: per hidden tile a
    (vmem float32[B, n], refrac int32[B, n]) pair, plus the output tile's
    float32[B, n_cls] accumulator."""
    hidden = tuple(
        (jnp.zeros((batch, n), jnp.float32), jnp.zeros((batch, n), jnp.int32))
        for n in topology[1:-1]
    )
    return hidden, jnp.zeros((batch, topology[-1]), jnp.float32)


def _mac_packed(plane, w_planes, w_signed_f32, n_in, *, use_kernel, interpret):
    """One tile's CIM MAC on the packed wire -> int32 contributions.

    TPU: the popcount-domain Pallas kernel (``kernels/cim_popcount`` — both
    operands stay uint32 bitplanes, AND + popcount on the VPU, no unpack).
    Elsewhere: unpack in-jit and one f32 BLAS dot against the pre-decoded ±1
    operand — exact integer arithmetic in float32 (|any partial sum| <=
    n_in << 2^24), bit-identical to the kernel (tested via the plan
    identities).
    """
    if use_kernel:
        from repro.kernels.cim_popcount import ops as pop_ops

        return pop_ops.cim_popcount_matmul(
            plane, w_planes, use_kernel=True, interpret=interpret)
    s = packing.unpack_spikes(plane, n_in, jnp.float32)
    out = jax.lax.dot_general(
        s, w_signed_f32, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out.astype(jnp.int32)


def temporal_forward(
    weight_bits,
    vth,
    out_offset,
    events,                 # uint32[T, B, ceil(n_in/32)] packed event stream
    cfg: TemporalConfig,
    *,
    interpret: bool | None = None,
    use_kernel: bool | None = None,
    collect: bool = False,
    telemetry: bool = False,
    w_planes=None,          # per tile uint32[N, ceil(K/32)] (hoisted slices)
    w_signed_f32=None,      # per tile ±1 float32[K, N] (hoisted decode)
    topology=None,
) -> dict:
    """Membrane-resident fused scan over all T timesteps.

    The readout integrates the last tile's contributions with the same leak
    and never fires (argmax readout): ``logits = V_out(T) + out_offset``.
    Per-step outputs come back batch-first — ``planes``/``loads`` are tuples
    over tiles of ``[B, T, ...]`` — so one sharding spec covers every output.

    ``w_planes``/``w_signed_f32`` accept the plan-build-time operands
    (``EsamPlan._prepare``): with them ``weight_bits`` may be ``None`` and no
    per-call decode or bit-slice happens on either dispatch path.
    """
    from repro.kernels.lif_step import ops as lif_ops

    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    t, batch, _ = events.shape
    if topology is None:
        topology = tuple(
            [weight_bits[0].shape[0]] + [w.shape[1] for w in weight_bits])
    topology = tuple(topology)
    decay = jnp.float32(1.0 - cfg.leak)
    # loop-invariant weight operands, hoisted out of the scan — and, when the
    # plan supplies them, out of the call entirely
    if use_kernel:
        wp = (w_planes if w_planes is not None
              else [packing.pack_weight_planes(w) for w in weight_bits])
        wf = [None] * len(wp)
    else:
        wf = (w_signed_f32 if w_signed_f32 is not None
              else [2.0 * w.astype(jnp.float32) - 1.0 for w in weight_bits])
        wp = [None] * len(wf)

    # tile 0's MAC sees only the events — lift it out of the time loop as
    # one flattened [T*B, n_in] MAC (the layer-stationary move)
    c_in = _mac_packed(
        events.reshape(t * batch, -1), wp[0], wf[0], topology[0],
        use_kernel=use_kernel, interpret=interpret,
    ).reshape(t, batch, topology[1])

    def step(state, c_t):
        hidden, out_v = state
        contrib = c_t
        new_hidden, planes, loads = [], [], []
        for i, ((v, r), th) in enumerate(zip(hidden, vth[:-1])):
            spikes, v, r = lif_ops.lif_step(
                v, contrib, th, r,
                leak=cfg.leak, reset=cfg.reset, refractory=cfg.refractory,
                use_kernel=use_kernel, interpret=interpret)
            new_hidden.append((v, r))
            if use_kernel or collect:
                # the packed inter-tile wire (and the collected plane)
                p = packing.pack_spikes(spikes)
                planes.append(p)
            if use_kernel:
                contrib = _mac_packed(
                    p, wp[i + 1], wf[i + 1], topology[i + 1],
                    use_kernel=True, interpret=interpret)
            else:
                # ref path: the spikes just fired in this buffer — feed the
                # f32 dot directly instead of a pack->unpack round-trip
                sf = spikes.astype(jnp.float32)
                contrib = jax.lax.dot_general(
                    sf, wf[i + 1], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32).astype(jnp.int32)
            if telemetry:
                loads.append(
                    packing.group_popcount(planes[-1]) if (use_kernel or collect)
                    else arb.split_row_groups(
                        spikes.astype(jnp.int32)).sum(-1))
        out_v = out_v * decay + contrib.astype(jnp.float32)
        ys = {}
        if collect:
            ys["planes"] = tuple(planes)
        if telemetry:
            ys["loads"] = tuple(loads)
        return (tuple(new_hidden), out_v), ys

    (_, out_v), ys = jax.lax.scan(step, init_state(topology, batch), c_in)
    out: dict = {"logits": out_v + out_offset}
    # scan stacks per-step outputs time-first; move batch first for sharding.
    # tile 0's plane is the input stream itself (its MAC left the loop).
    ev_bf = events.swapaxes(0, 1)
    if collect:
        out["planes"] = (ev_bf,) + tuple(
            p.swapaxes(0, 1) for p in ys["planes"])
    if telemetry:
        out["loads"] = (packing.group_popcount(ev_bf),) + tuple(
            ld.swapaxes(0, 1) for ld in ys["loads"])
    return out


# --------------------------------------------------------------------- #
# naive per-step baseline (and bit-identity oracle)
# --------------------------------------------------------------------- #
def _naive_step_body(weight_bits, vth, hidden, out_v, spikes,
                     *, leak, reset, refractory):
    from repro.kernels.lif_step.ref import lif_step_ref

    s = spikes.astype(jnp.int32)
    new_hidden = []
    for (v, r), w, th in zip(hidden, weight_bits[:-1], vth[:-1]):
        contrib = s @ (2 * w.astype(jnp.int32) - 1)
        s8, v, r = lif_step_ref(
            v, contrib, th, r, leak=leak, reset=reset, refractory=refractory)
        new_hidden.append((v, r))
        s = s8.astype(jnp.int32)
    out_contrib = s @ (2 * weight_bits[-1].astype(jnp.int32) - 1)
    out_v = out_v * jnp.float32(1.0 - leak) + out_contrib.astype(jnp.float32)
    return tuple(new_hidden), out_v


_naive_step_jit = jax.jit(
    _naive_step_body, static_argnames=("leak", "reset", "refractory"))


def temporal_forward_naive(network, events: np.ndarray, cfg: TemporalConfig,
                           *, jit_step: bool = True) -> np.ndarray:
    """The naive implementation: a host Python loop over timesteps.

    ``events``: {0,1}[T, B, n_in] *unpacked* — each step runs dense int32
    tiles on an int8 spike tensor, and the whole membrane state makes a
    device->host round-trip per timestep (``np.asarray``), the way a
    reference SNN loop inspects per-step activity.

    ``jit_step=True`` (default) compiles the per-step body once: the exact
    integer datapath of the fused scan, so logits are bit-identical — the
    oracle in tests/test_temporal.py.  ``jit_step=False`` dispatches every
    op eagerly — the true naive first implementation and the speedup
    baseline of benchmarks/bench_temporal.py (eager arithmetic is unfused,
    so with a nonzero leak it agrees with the fused scan to float32 ulp
    rather than bitwise).
    """
    events = np.asarray(events)
    assert events.ndim == 3 and events.shape[0] == cfg.n_steps, events.shape
    batch = events.shape[1]
    wb = tuple(network.weight_bits)
    vth = tuple(network.vth)
    hidden, out_v = init_state(network.topology, batch)
    hidden = tuple((np.asarray(v), np.asarray(r)) for v, r in hidden)
    out_v = np.asarray(out_v)
    step = _naive_step_jit if jit_step else _naive_step_body
    for t in range(cfg.n_steps):
        hidden_j, out_j = step(
            wb, vth,
            tuple((jnp.asarray(v), jnp.asarray(r)) for v, r in hidden),
            jnp.asarray(out_v), jnp.asarray(events[t], jnp.int8),
            leak=cfg.leak, reset=cfg.reset, refractory=cfg.refractory)
        hidden = tuple((np.asarray(v), np.asarray(r)) for v, r in hidden_j)
        out_v = np.asarray(out_j)
    return out_v + np.asarray(network.out_offset)

"""SpikingLinear — the paper's idea as an optional LM-framework layer
(beyond-paper, DESIGN.md §Arch-applicability).

ESAM's architectural insight is event-driven selection: only active
(spiking) pre-synaptic rows contribute, weights are ±1 bits, and an arbiter
grants at most p events per cycle.  As an LM ablation layer this becomes a
drop-in binary-activation linear:

  * activations binarize to {0,1} spikes with a straight-through estimator;
  * weights binarize to {-1,+1} (latent-float training, sign forward);
  * an optional *top-p activation arbiter* keeps only the p largest
    pre-activations per token — the software analogue of the port limit,
    giving controllable event sparsity;
  * the forward MAC is exactly the `kernels/cim_matmul` binary MAC, so the
    layer runs on the ESAM TPU plane unchanged.

This layer is ablation-grade (binary nets lose accuracy); it is never used
in the faithful assigned-architecture configs.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec


def spiking_linear_specs(d_in: int, d_out: int) -> dict:
    return {
        "w": ParamSpec((d_in, d_out), ("embed", "mlp"), dtype=jnp.float32),
        "b": ParamSpec((d_out,), ("mlp",), init="zeros", dtype=jnp.float32),
    }


def _ste_spike(x: jax.Array) -> jax.Array:
    """{0,1} spikes with clipped-identity backward."""
    hard = (x >= 0).astype(x.dtype)
    soft = jnp.clip(x * 0.5 + 0.5, 0.0, 1.0)
    return soft + jax.lax.stop_gradient(hard - soft)


def _ste_sign(w: jax.Array) -> jax.Array:
    hard = jnp.where(w >= 0, 1.0, -1.0).astype(w.dtype)
    soft = jnp.clip(w, -1.0, 1.0)
    return soft + jax.lax.stop_gradient(hard - soft)


def top_p_arbiter(x: jax.Array, p: int) -> jax.Array:
    """Keep the p largest entries per row (the port-limit analogue).

    Unlike the hardware arbiter (which serializes over cycles), the LM-layer
    version simply masks: events beyond the p-th largest are dropped, which
    bounds the per-token event count exactly like a p-port tile bounds
    per-cycle row reads.
    """
    if p >= x.shape[-1]:
        return x
    thresh = jax.lax.top_k(x, p)[0][..., -1:]
    return jnp.where(x >= thresh, x, -jnp.inf)


def spiking_linear(
    params: dict, x: jax.Array, *, ports: Optional[int] = None
) -> jax.Array:
    """x: [..., d_in] real -> [..., d_out] real (V_mem-style integer-valued).

    ports: optional top-p event limit applied to the pre-spike activations.
    """
    pre = x
    if ports is not None:
        pre = top_p_arbiter(pre, ports)
    spikes = _ste_spike(pre)
    wb = _ste_sign(params["w"])
    return spikes @ wb + params["b"]


def event_rate(x: jax.Array, *, ports: Optional[int] = None) -> jax.Array:
    """Fraction of active events after arbitration (for sparsity accounting
    against the ESAM cost model: cycles = ceil(events / ports))."""
    pre = top_p_arbiter(x, ports) if ports is not None else x
    return (pre >= 0).mean()

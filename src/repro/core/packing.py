"""Bit-packed spike planes: 32 binary spikes per uint32 lane word.

The paper's inter-tile fabric moves spikes as parallel single-bit pulses
(Sec 3.1) — one wire per pre-synaptic neuron, never a full-precision word.
Our functional plane previously stored every spike in its own int8/bf16
element, moving 8-16x the bits the hardware would.  This module defines the
repo-wide wire format that closes that gap:

    spikes {0,1}[..., n]  <->  packed uint32[..., ceil(n/32)]

Bit ``b`` of word ``j`` holds spike ``j*32 + b`` (LSB-first within a word).
Positions past ``n`` in the last word are zero ("silent") — a zero spike
contributes nothing to the CIM MAC regardless of the stored weight bit, so
padding is exact, never approximate.

Both jnp and numpy implementations are provided: the jnp pair is what the
packed Pallas kernels (kernels/cim_matmul_packed) and ``forward_fused`` use;
the numpy pair lets the host-side data pipeline and serving engine emit the
wire format without touching an accelerator.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

LANE_BITS = 32  # spikes per packed word (uint32 lanes)


def packed_width(n: int) -> int:
    """Number of uint32 words needed for n spikes."""
    return -(-n // LANE_BITS)


def packed_nbytes(n: int) -> int:
    """Wire bytes per sample for an n-spike plane (vs n bytes unpacked int8)."""
    return packed_width(n) * 4


# --------------------------------------------------------------------- #
# jnp (device) pair
# --------------------------------------------------------------------- #
def pack_spikes(spikes: jax.Array) -> jax.Array:
    """{0,1}[..., n] (any dtype) -> uint32[..., ceil(n/32)]."""
    n = spikes.shape[-1]
    w = packed_width(n)
    bits = (spikes != 0).astype(jnp.uint32)
    pad = w * LANE_BITS - n
    if pad:
        widths = [(0, 0)] * (bits.ndim - 1) + [(0, pad)]
        bits = jnp.pad(bits, widths)
    b = bits.reshape(bits.shape[:-1] + (w, LANE_BITS))
    shifts = jnp.arange(LANE_BITS, dtype=jnp.uint32)
    # distinct powers of two — the sum is an exact bitwise OR, no overflow
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def group_popcount(packed: jax.Array, group: int = 128) -> jax.Array:
    """Spike count per ``group``-bit row group, straight off the wire format.

    packed: uint32[..., W] bitplanes of a width-(W*32) spike plane whose
    logical width is a multiple of ``group`` (tail padding past it is zero,
    so counts stay exact).  Returns int32[..., W*32/group] — exactly the
    arbiter loads ``EsamNetwork.spike_counts`` measures, without unpacking.
    """
    assert group % LANE_BITS == 0, group
    words_per_group = group // LANE_BITS
    pc = jax.lax.population_count(packed).astype(jnp.int32)
    w = pc.shape[-1]
    assert w % words_per_group == 0, (w, group)
    return pc.reshape(pc.shape[:-1] + (w // words_per_group, words_per_group)).sum(-1)


def unpack_spikes(packed: jax.Array, n: int, dtype=jnp.int8) -> jax.Array:
    """uint32[..., W] -> {0,1}[..., n] in ``dtype``."""
    w = packed.shape[-1]
    assert w == packed_width(n), (w, n)
    shifts = jnp.arange(LANE_BITS, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(packed.shape[:-1] + (w * LANE_BITS,))
    return flat[..., :n].astype(dtype)


# --------------------------------------------------------------------- #
# weight bit planes — the other operand of the popcount-domain MAC
# --------------------------------------------------------------------- #
def pack_weight_planes(weight_bits: jax.Array) -> jax.Array:
    """Stored bits {0,1}[K, N] -> uint32[N, ceil(K/32)] weight bit planes.

    Row ``n`` packs output neuron ``n``'s column of stored bits along the
    pre-synaptic axis, in exactly the spike wire layout (bit ``b`` of word
    ``j`` is pre-neuron ``j*32 + b``, zero tail).  With both operands in this
    layout the CIM MAC never unpacks: for ±1 weights stored as {0,1} bits,

        V[b, n] = sum_k s[b,k] * (2*w[k,n] - 1)
                = 2 * sum_j popcount(spikes[b,j] & planes[n,j]) - popcount(spikes[b])

    and zero padding is exact in *both* terms — a padded spike bit is 0, so
    it joins neither the AND nor the row popcount.  Planes are sliced once at
    plan-build time (``EsamPlan``) and reused for every batch.
    """
    return pack_spikes(jnp.asarray(weight_bits).swapaxes(-1, -2))


def unpack_weight_planes(planes: jax.Array, n_in: int, dtype=jnp.int8) -> jax.Array:
    """uint32[N, ceil(K/32)] -> stored bits {0,1}[K, N] (round trip)."""
    return unpack_spikes(planes, n_in, dtype).swapaxes(-1, -2)


def pack_weight_planes_np(weight_bits: np.ndarray) -> np.ndarray:
    """Host twin of ``pack_weight_planes`` (bit-identical layout)."""
    return pack_spikes_np(np.asarray(weight_bits).swapaxes(-1, -2))


def unpack_weight_planes_np(planes: np.ndarray, n_in: int, dtype=np.int8) -> np.ndarray:
    return unpack_spikes_np(planes, n_in, dtype).swapaxes(-1, -2)


# --------------------------------------------------------------------- #
# numpy (host) pair — bit-identical layout, no jax dependency at call time
# --------------------------------------------------------------------- #
def pack_spikes_np(spikes: np.ndarray) -> np.ndarray:
    n = spikes.shape[-1]
    w = packed_width(n)
    bits = (np.asarray(spikes) != 0).astype(np.uint32)
    pad = w * LANE_BITS - n
    if pad:
        widths = [(0, 0)] * (bits.ndim - 1) + [(0, pad)]
        bits = np.pad(bits, widths)
    b = bits.reshape(bits.shape[:-1] + (w, LANE_BITS))
    shifts = np.arange(LANE_BITS, dtype=np.uint32)
    return np.sum(b << shifts, axis=-1, dtype=np.uint64).astype(np.uint32)


def unpack_spikes_np(packed: np.ndarray, n: int, dtype=np.int8) -> np.ndarray:
    w = packed.shape[-1]
    assert w == packed_width(n), (w, n)
    shifts = np.arange(LANE_BITS, dtype=np.uint32)
    bits = (packed[..., None] >> shifts) & np.uint32(1)
    flat = bits.reshape(packed.shape[:-1] + (w * LANE_BITS,))
    return flat[..., :n].astype(dtype)


# --------------------------------------------------------------------- #
# host-side batch prep — the single copy of pad-to-batch + pack
# --------------------------------------------------------------------- #
def pad_spike_rows_np(rows, batch: int, n_in: int) -> np.ndarray:
    """Stack per-request spike rows into a zero-padded {0,1} uint8 batch.

    ``rows``: sequence of {0,1}[n_in] arrays (any dtype), ``len(rows) <=
    batch``.  Unused slots stay all-zero ("silent"), which is exact padding
    for the binary CIM MAC.  This is the one host-side pad-to-batch
    implementation — the serving engine, the serving bench, and the examples
    all batch through here instead of each rolling their own.
    """
    assert len(rows) <= batch, (len(rows), batch)
    out = np.zeros((batch, n_in), np.uint8)
    for i, r in enumerate(rows):
        r = np.asarray(r)
        assert r.shape == (n_in,), (r.shape, n_in)
        out[i] = r != 0
    return out


def pack_padded_rows_np(rows, batch: int, n_in: int) -> np.ndarray:
    """``pad_spike_rows_np`` straight into the uint32 wire format."""
    return pack_spikes_np(pad_spike_rows_np(rows, batch, n_in))

"""Overload-control primitives for the serving plane.

The paper's operating point (44 MInf/s @ 29 mW) is an edge budget: wearables
and IoT gateways see bursty open-loop traffic, not closed-loop benchmark
batches.  This module holds the host-side control-plane pieces ``SpikeEngine``
uses to survive that traffic without losing the datapath's bit-exactness:

  * ``AdmissionVerdict`` — the return value of ``SpikeEngine.submit``: was the
    request admitted, and is the queue past its high-water mark
    (backpressure)?  Callers that ignore it keep the pre-overload behavior.
  * ``LadderLevel`` / ``DegradationLadder`` — a graceful-degradation ladder.
    Under sustained pressure (queue depth beyond the high-water mark, or
    straggling dispatch rounds flagged by the watchdog EMA) the engine steps
    *down* a level, trading per-request cost for headroom: event streams are
    truncated to fewer timesteps, the cost tier drops to fewer read ports,
    and the bucket ceiling shrinks so rounds stay small and latency bounded.
    When pressure clears for ``step_up_after`` consecutive rounds it steps
    back up.  Every transition is recorded and surfaced through
    ``SpikeEngine.stats()``.

Nothing here touches the device datapath: level 0 with no queue bound and no
deadlines is bit-identical to the pre-overload engine (property-tested).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class AdmissionVerdict:
    """Outcome of one ``SpikeEngine.submit`` admission decision.

    ``admitted`` is False only when a bounded queue is full (``reason ==
    "queue_full"``); ``backpressure`` is True when the request was admitted
    but the queue is past its high-water mark — the caller should slow down
    (an open-loop caller can't, which is exactly when sheds start).
    """

    admitted: bool
    backpressure: bool = False
    reason: str = "ok"                # "ok" | "queue_full"
    queue_depth: int = 0              # depth after this decision


@dataclasses.dataclass(frozen=True)
class LadderLevel:
    """One rung: every field None means "no change from the engine's base".

    ``event_t_cap``   — truncate event streams to at most this many timesteps.
    ``read_ports``    — cost tier for telemetry accounting (fewer decoupled
                        read ports = lower energy per access).
    ``bucket_cap``    — ceiling on the continuous-batching round size (and so
                        on the padded bucket), keeping per-round latency low.
    ``fuse_cap``      — ceiling on the engine's round-fusion factor (how many
                        legacy bucket-rounds may coalesce into one super-batch
                        dispatch).  Degraded rungs cap fusion so a shed/deadline
                        sweep between rounds stays frequent under pressure.
    """

    name: str
    event_t_cap: Optional[int] = None
    read_ports: Optional[int] = None
    bucket_cap: Optional[int] = None
    fuse_cap: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class DegradationLadder:
    """Ordered service levels, full service first.

    ``step_down_after`` consecutive pressured rounds move one level down;
    ``step_up_after`` consecutive clear rounds move one level back up.
    Hysteresis (step_up_after > step_down_after) keeps the ladder from
    oscillating at the saturation boundary.
    """

    levels: tuple[LadderLevel, ...]
    step_down_after: int = 2
    step_up_after: int = 6

    def __post_init__(self):
        assert self.levels, "ladder needs at least the full-service level"
        assert self.step_down_after >= 1 and self.step_up_after >= 1

    def level(self, i: int) -> LadderLevel:
        return self.levels[max(0, min(i, len(self.levels) - 1))]

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    @staticmethod
    def default(max_batch: int = 128,
                read_ports: int = 4) -> "DegradationLadder":
        """The canonical 4-rung ladder for the paper's cell options.

        full -> shorter event streams -> half the read-port tier + half the
        bucket ceiling -> survival (T<=2, single-port tier, quarter buckets).
        Bucket caps stay powers of two so degraded rounds still land on the
        engine's compiled bucket ladder.
        """
        def _pow2_floor(n: int) -> int:
            p = 1
            while p * 2 <= n:
                p *= 2
            return p

        half = max(8, _pow2_floor(max_batch) // 2)
        quarter = max(8, _pow2_floor(max_batch) // 4)
        return DegradationLadder(levels=(
            LadderLevel("full"),
            LadderLevel("reduced_t", event_t_cap=8),
            LadderLevel("economy", event_t_cap=4,
                        read_ports=max(1, read_ports // 2), bucket_cap=half,
                        fuse_cap=2),
            LadderLevel("survival", event_t_cap=2, read_ports=1,
                        bucket_cap=quarter, fuse_cap=1),
        ))

"""Batched serving engines.

``Engine``: LM prefill + decode loop with slot-based continuous batching
(fixed B decode slots; finished sequences free their slot and the next queued
request is prefilled into it).

``SpikeEngine``: ESAM spike-classification serving on the packed plane —
requests are bit-packed host-side into the uint32 wire format (32 spikes per
lane word, the paper's parallel-pulse inter-tile bus) and batched through
``EsamNetwork.forward_fused_packed``, so neither the server->device transfer
nor the tile cascade ever materializes an unpacked spike tensor in HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models import lm


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # int32[prompt_len]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: Optional[np.ndarray] = None


class Engine:
    """Greedy decoder over the unified LM. Single-slot-group implementation:
    requests are served in batches of ``batch_size`` padded to a shared
    prompt length (continuous batching refills the batch between rounds)."""

    def __init__(self, params, cfg, *, batch_size: int = 8,
                 rules: Optional[shd.ShardingRules] = None):
        self.params = params
        self.cfg = cfg
        self.batch_size = batch_size
        self.rules = rules

        def _prefill(params, batch, *, cache_len):
            with shd.use_rules(rules):
                return lm.prefill(params, cfg, batch, cache_len=cache_len)

        def _decode(params, tokens, caches):
            with shd.use_rules(rules):
                return lm.decode_step(params, cfg, tokens, caches)

        self._prefill = jax.jit(_prefill, static_argnames=("cache_len",))
        self._decode = jax.jit(_decode)

    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        max_len = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), max_len), np.int32)
        for i, r in enumerate(reqs):
            toks[i, max_len - len(r.prompt):] = r.prompt  # left-pad
        return toks

    def serve(self, requests: list[Request]) -> list[Request]:
        queue = list(requests)
        while queue:
            batch_reqs = queue[: self.batch_size]
            queue = queue[self.batch_size:]
            self._serve_batch(batch_reqs)
        return requests

    def _serve_batch(self, reqs: list[Request]):
        toks = self._pad_prompts(reqs)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.is_encdec:
            batch["src_frames"] = jnp.zeros(
                (toks.shape[0], toks.shape[1], self.cfg.d_model), jnp.float32
            )
        max_new = max(r.max_new_tokens for r in reqs)
        logits, caches = self._prefill(
            self.params, batch, cache_len=toks.shape[1] + max_new)
        outs = [[] for _ in reqs]
        done = np.zeros(len(reqs), bool)
        next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for _ in range(max_new):
            for i, r in enumerate(reqs):
                if not done[i]:
                    t = int(next_tok[i, 0])
                    outs[i].append(t)
                    if r.eos_id is not None and t == r.eos_id:
                        done[i] = True
                    if len(outs[i]) >= r.max_new_tokens:
                        done[i] = True
            if done.all():
                break
            logits, caches = self._decode(self.params, next_tok, caches)
            next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for r, o in zip(reqs, outs):
            r.output = np.asarray(o, np.int32)


# ------------------------------------------------------------------ #
# ESAM spike-classification serving (packed plane)
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class SpikeRequest:
    spikes: np.ndarray                     # {0,1}[n_in] (any dtype)
    # filled by the engine:
    logits: Optional[np.ndarray] = None    # float32[n_classes]
    label: Optional[int] = None            # argmax readout


class SpikeEngine:
    """Fixed-slot batched inference over an ``EsamNetwork``.

    Requests are packed on the host (numpy — no device round-trip) and padded
    to ``batch_size`` slots; silent (all-zero) pad rows are exact because a
    zero spike never contributes to the CIM MAC.
    """

    def __init__(self, net, *, batch_size: int = 128,
                 interpret: Optional[bool] = None):
        from repro.core import packing

        self.net = net
        self.batch_size = batch_size
        self.n_in = net.topology[0]
        self._packing = packing
        self._fwd = jax.jit(
            lambda packed: net.forward_fused_packed(packed, interpret=interpret)
        )

    def serve(self, requests: list[SpikeRequest]) -> list[SpikeRequest]:
        queue = list(requests)
        while queue:
            batch_reqs = queue[: self.batch_size]
            queue = queue[self.batch_size:]
            self._serve_batch(batch_reqs)
        return requests

    def _serve_batch(self, reqs: list[SpikeRequest]):
        spikes = np.zeros((self.batch_size, self.n_in), np.uint8)
        for i, r in enumerate(reqs):
            assert r.spikes.shape == (self.n_in,), (r.spikes.shape, self.n_in)
            spikes[i] = np.asarray(r.spikes) != 0
        packed = jnp.asarray(self._packing.pack_spikes_np(spikes))
        logits = np.asarray(self._fwd(packed))
        for i, r in enumerate(reqs):
            r.logits = logits[i]
            r.label = int(logits[i].argmax())

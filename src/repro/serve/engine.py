"""Batched serving engines.

``Engine``: LM prefill + decode loop with slot-based continuous batching
(fixed B decode slots; finished sequences free their slot and the next queued
request is prefilled into it).

``SpikeEngine``: ESAM spike-classification serving on the packed plane —
requests are bit-packed host-side into the uint32 wire format (32 spikes per
lane word, the paper's parallel-pulse inter-tile bus) and continuously
batched through ONE compiled ``EsamPlan`` (optionally ``shard_map``-ped over
a device mesh), so neither the server->device transfer nor the tile cascade
ever materializes an unpacked spike tensor in HBM.  Beyond single-shot
``SpikeRequest``s it admits event *streams* (``EventRequest``,
``submit_events``): T timesteps of spike planes with per-request T, bucketed
on (batch, T) and drained through the membrane-resident temporal plan
(``mode="temporal"``) with the same device-resident telemetry discipline.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models import lm
from repro.train import fault_tolerance as ft


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # int32[prompt_len]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: Optional[np.ndarray] = None


class Engine:
    """Greedy decoder over the unified LM. Single-slot-group implementation:
    requests are served in batches of ``batch_size`` padded to a shared
    prompt length (continuous batching refills the batch between rounds)."""

    def __init__(self, params, cfg, *, batch_size: int = 8,
                 rules: Optional[shd.ShardingRules] = None):
        self.params = params
        self.cfg = cfg
        self.batch_size = batch_size
        self.rules = rules

        def _prefill(params, batch, *, cache_len):
            with shd.use_rules(rules):
                return lm.prefill(params, cfg, batch, cache_len=cache_len)

        def _decode(params, tokens, caches):
            with shd.use_rules(rules):
                return lm.decode_step(params, cfg, tokens, caches)

        self._prefill = jax.jit(_prefill, static_argnames=("cache_len",))
        self._decode = jax.jit(_decode)

    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        max_len = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), max_len), np.int32)
        for i, r in enumerate(reqs):
            toks[i, max_len - len(r.prompt):] = r.prompt  # left-pad
        return toks

    def serve(self, requests: list[Request]) -> list[Request]:
        queue = list(requests)
        while queue:
            batch_reqs = queue[: self.batch_size]
            queue = queue[self.batch_size:]
            self._serve_batch(batch_reqs)
        return requests

    def _serve_batch(self, reqs: list[Request]):
        toks = self._pad_prompts(reqs)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.is_encdec:
            batch["src_frames"] = jnp.zeros(
                (toks.shape[0], toks.shape[1], self.cfg.d_model), jnp.float32
            )
        max_new = max(r.max_new_tokens for r in reqs)
        logits, caches = self._prefill(
            self.params, batch, cache_len=toks.shape[1] + max_new)
        outs = [[] for _ in reqs]
        done = np.zeros(len(reqs), bool)
        next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for _ in range(max_new):
            for i, r in enumerate(reqs):
                if not done[i]:
                    t = int(next_tok[i, 0])
                    outs[i].append(t)
                    if r.eos_id is not None and t == r.eos_id:
                        done[i] = True
                    if len(outs[i]) >= r.max_new_tokens:
                        done[i] = True
            if done.all():
                break
            logits, caches = self._decode(self.params, next_tok, caches)
            next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for r, o in zip(reqs, outs):
            r.output = np.asarray(o, np.int32)


# ------------------------------------------------------------------ #
# ESAM spike-classification serving (packed plane, plan-compiled)
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class SpikeRequest:
    spikes: np.ndarray                     # {0,1}[n_in] (any dtype)
    # filled by the engine:
    logits: Optional[np.ndarray] = None    # float32[n_classes]
    label: Optional[int] = None            # argmax readout
    # filled when the engine runs with telemetry (paper-unit hardware cost):
    cycles: Optional[int] = None           # CIM clock cycles, summed over tiles
    latency_ns: Optional[float] = None     # cycles * cell clock period
    energy_pj: Optional[float] = None      # per-inference energy (pJ/inf)


@dataclasses.dataclass
class EventRequest:
    """An event-stream classification request: T timesteps of spike planes.

    ``events``: {0,1}[T, n_in] (any dtype), or pre-packed wire-format
    uint32[T, ceil(n_in/32)].  T may differ per request — the engine buckets
    event rounds on (batch, T).
    """

    events: np.ndarray
    # filled by the engine:
    logits: Optional[np.ndarray] = None    # float32[n_classes]
    label: Optional[int] = None            # argmax readout
    # filled when the engine runs with telemetry (paper-unit hardware cost):
    cycles: Optional[int] = None           # CIM cycles, summed over T steps
    latency_ns: Optional[float] = None     # cycles * cell clock period
    energy_pj: Optional[float] = None      # whole-stream energy
    energy_pj_per_step: Optional[float] = None  # energy_pj / T

    @property
    def n_steps(self) -> int:
        return int(np.asarray(self.events).shape[0])


def _bucket_sizes(max_batch: int, min_bucket: int, dp: int) -> list[int]:
    """Power-of-two bucket ladder: min_bucket, 2*min_bucket, ... >= max_batch.

    Every bucket is a multiple of the data-parallel degree ``dp`` so a padded
    batch always divides the mesh; the smallest bucket never exceeds the
    (rounded-up) ``max_batch`` itself.
    """
    top = 1
    while top < max_batch:
        top <<= 1
    lo = max(min(min_bucket, top), dp)
    b = 1
    while b < lo:
        b <<= 1
    sizes = [b]
    while sizes[-1] < top:
        sizes.append(sizes[-1] * 2)
    return sizes


class SpikeEngine:
    """Continuously-batched ESAM serving over one compiled execution plan.

    Requests enter an admission queue (``submit``; ``serve`` is submit+drain)
    and are dispatched in multi-batch rounds of up to ``max_batch`` requests.
    Each round is zero-padded up to the next power-of-two bucket
    (``min_bucket``-based ladder, always a multiple of the data-parallel
    degree) so the compiled plan sees a handful of static shapes instead of
    one per queue length — silent pad rows are exact for the binary CIM MAC.
    Packing happens on the host (numpy — the device only ever sees the uint32
    wire format); with ``rules`` the plan is compiled ``shard_map``-ped over
    the mesh and each bucket is sharded over the ``spike_batch`` axes.

    With ``telemetry=True`` the plan additionally returns each tile's
    arbiter loads (group popcounts of the inter-tile bitplanes — same pass,
    nothing unpacked) and the paper-unit hardware cost is computed *on
    device* (``cost_model.request_stats_device``), staying device-resident
    through the whole dispatch loop: the engine performs no per-batch host
    sync — per-request costs land on the host in one flush at drain end
    (where the running aggregate folds into exact float64 totals, immune to
    float32 drift over long-lived engines), and ``stats()`` is a pure host
    read.
    """

    def __init__(self, net, *, max_batch: int = 128, min_bucket: int = 8,
                 interpret: Optional[bool] = None,
                 telemetry: bool = False, read_ports: int = 4,
                 temporal=None,  # Optional[temporal.TemporalConfig]
                 faults=None,  # Optional[faults.FaultModel]
                 watchdog: Optional[ft.StragglerWatchdog] = None,
                 health_threshold: float = 0.75,
                 rules: Optional[shd.ShardingRules] = None,
                 batch_size: Optional[int] = None):
        from repro.core import packing
        from repro.core.esam import cost_model as cm
        from repro.core.esam import temporal as temporal_mod

        if batch_size is not None:   # deprecated alias (pre-plan engine)
            max_batch = batch_size
        self.net = net
        self.max_batch = max_batch
        self.n_in = net.topology[0]
        self.telemetry = telemetry
        self.read_ports = read_ports
        self.rules = rules
        self.faults = faults
        self.health_threshold = health_threshold
        self._packing = packing
        self._cm = cm
        self._interpret = interpret
        self._min_bucket = min_bucket
        # dispatch-round straggler watchdog: each continuous-batching round's
        # host-side wall time (packing + dispatch; device work is async) is
        # recorded, and rounds slower than threshold x the EMA are flagged —
        # surfaced through stats() so a coordinator can drain traffic away
        self._watchdog = watchdog or ft.StragglerWatchdog()
        self._rounds = 0
        # LIF dynamics template for event-stream requests; n_steps is taken
        # from each request (per-request T), the rest from this config.  The
        # default (zero leak, zero reset) makes a T=1 event request
        # bit-identical to the static packed path.
        self._temporal = temporal or temporal_mod.TemporalConfig(n_steps=1)
        dp = 1 if rules is None else rules.axis_size("spike_batch")
        self._buckets = _bucket_sizes(max_batch, min_bucket, dp)
        self._plan = net.plan(
            mode="packed", telemetry=telemetry, interpret=interpret,
            faults=faults, rules=rules)
        n_tiles = len(net.topology) - 1
        # tile-health calibration: expected mean drain cycles per tile on the
        # reference activity profile (the paper's 53%/50% calibration point).
        # Measured telemetry deviating from this — up (stuck-at-1 load
        # inflation) or down (dead/stuck-at-0 columns silencing traffic) —
        # marks the tile degraded.
        topo = net.topology
        ref = [
            np.full((1, cm.tile_geometry(topo[t], topo[t + 1])[0]),
                    float(cm.REF_SPIKES_PER_GROUP[t])
                    if t < len(cm.REF_SPIKES_PER_GROUP) else 64.0)
            for t in range(n_tiles)
        ]
        self._expected_tile_cycles = cm.request_stats(
            topo, ref, read_ports).cycles_per_tile.mean(axis=0)  # [n_tiles]
        # admission queues + per-round device results awaiting one host flush
        self._pending: list[SpikeRequest] = []
        self._pending_events: list[EventRequest] = []
        self._inflight: list[tuple[list, jax.Array, Optional[dict]]] = []
        # exact float64 telemetry totals, folded in at each drain flush
        self._served = 0
        self._served_events = 0
        self._served_timesteps = 0
        self._totals = {
            "cycles": 0.0,
            "cycles_per_tile": np.zeros((n_tiles,), np.float64),
            "latency_ns": 0.0,
            "energy_pj": 0.0,
        }
        self._event_totals = {
            "cycles": 0.0,
            "latency_ns": 0.0,
            "energy_pj": 0.0,
        }

    # -------------------------------------------------------------- #
    # admission + dispatch
    # -------------------------------------------------------------- #
    def submit(self, requests) -> None:
        """Queue requests without dispatching (single request or list).

        ``SpikeRequest`` and ``EventRequest`` objects may be mixed; each is
        routed to its own admission queue."""
        if isinstance(requests, (SpikeRequest, EventRequest)):
            requests = [requests]
        for r in requests:
            if isinstance(r, EventRequest):
                self._pending_events.append(r)
            else:
                self._pending.append(r)

    def submit_events(self, requests) -> None:
        """Queue event-stream requests (single ``EventRequest`` or list)."""
        if isinstance(requests, EventRequest):
            requests = [requests]
        assert all(isinstance(r, EventRequest) for r in requests)
        self._pending_events.extend(requests)

    def serve(self, requests=None) -> list:
        """Enqueue ``requests`` (optional), drain both queues, flush results.

        Returns the list of requests served in this call (the passed-in list
        when given, else everything that was pending)."""
        if requests is not None:
            self.submit(requests)
            out = requests if isinstance(requests, list) else [requests]
        else:
            out = list(self._pending) + list(self._pending_events)
        while self._pending:
            round_reqs = self._pending[: self.max_batch]
            del self._pending[: self.max_batch]
            self._timed_round(self._dispatch, round_reqs)
        while self._pending_events:
            # one continuous-batching round per (batch, T) bucket: take the
            # head request's T and everything sharing it, in arrival order
            t = self._pending_events[0].n_steps
            round_reqs, rest = [], []
            for r in self._pending_events:
                if r.n_steps == t and len(round_reqs) < self.max_batch:
                    round_reqs.append(r)
                else:
                    rest.append(r)
            self._pending_events = rest
            self._timed_round(self._dispatch_events, round_reqs, t)
        self._flush()
        return out

    def _timed_round(self, dispatch, *args) -> None:
        """One dispatch round under the straggler watchdog: the host-side
        round wall time (packing + dispatch; device work stays async) feeds
        the EMA, and slow rounds are flagged into ``stats()``."""
        t0 = time.perf_counter()
        dispatch(*args)
        self._watchdog.record(self._rounds, time.perf_counter() - t0)
        self._rounds += 1

    def _bucket(self, n: int) -> int:
        for b in self._buckets:
            if n <= b:
                return b
        return self._buckets[-1]

    def _dispatch(self, reqs: list[SpikeRequest]) -> None:
        """One continuous-batching round: pad to bucket, run the plan, keep
        every result device-side (no host sync here)."""
        bucket = self._bucket(len(reqs))
        packed = jnp.asarray(self._packing.pack_padded_rows_np(
            [r.spikes for r in reqs], bucket, self.n_in))
        res = self._plan(packed)
        rs = None
        if self.telemetry:
            # lazy device-side cost — nothing is synced inside the drain loop
            rs = self._cm.request_stats_device(
                self.net.topology, res.loads, self.read_ports)
        self._served += len(reqs)
        self._inflight.append((reqs, res.logits, rs))

    def _dispatch_events(self, reqs: list[EventRequest], n_steps: int) -> None:
        """One event round: same-T requests padded to a batch bucket and run
        through the temporal plan (compiled once per (batch, T) shape); the
        stream cost stays device-side like the static path's."""
        bucket = self._bucket(len(reqs))
        width = self._packing.packed_width(self.n_in)
        packed = np.zeros((n_steps, bucket, width), np.uint32)
        for i, r in enumerate(reqs):
            ev = np.asarray(r.events)
            assert ev.shape[0] == n_steps, (ev.shape, n_steps)
            if ev.dtype == np.uint32 and ev.shape[-1] == width:
                packed[:, i] = ev
            else:
                assert ev.shape == (n_steps, self.n_in), (ev.shape, self.n_in)
                packed[:, i] = self._packing.pack_spikes_np(ev != 0)
        cfg = dataclasses.replace(self._temporal, n_steps=n_steps)
        plan = self.net.plan(
            mode="temporal", temporal=cfg, telemetry=self.telemetry,
            interpret=self._interpret, faults=self.faults, rules=self.rules)
        res = plan(jnp.asarray(packed))
        rs = None
        if self.telemetry:
            rs = self._cm.temporal_request_stats_device(
                self.net.topology, res.loads, self.read_ports)
        self._served_events += len(reqs)
        self._served_timesteps += len(reqs) * n_steps
        self._inflight.append((reqs, res.logits, rs))

    def _flush(self) -> None:
        """Attach logits/labels (+ per-request cost) and fold the telemetry
        totals — one host transfer per round's arrays, all at drain end
        rather than inside the dispatch loop.  Totals accumulate in float64
        here (the arrays are on the host anyway for per-request attachment),
        masking the zero-padded tail slots of each bucket."""
        for reqs, logits_j, rs in self._inflight:
            n = len(reqs)
            is_event = bool(reqs) and isinstance(reqs[0], EventRequest)
            logits = np.asarray(logits_j)
            for i, r in enumerate(reqs):
                r.logits = logits[i]
                r.label = int(logits[i].argmax())
            if rs is not None:
                cycles = np.asarray(rs["cycles"])
                latency = np.asarray(rs["latency_ns"])
                energy = np.asarray(rs["energy_pj"])
                for i, r in enumerate(reqs):
                    r.cycles = int(cycles[i])
                    r.latency_ns = float(latency[i])
                    r.energy_pj = float(energy[i])
                if is_event:
                    per_step = np.asarray(rs["energy_pj_per_step"])
                    for i, r in enumerate(reqs):
                        r.energy_pj_per_step = float(per_step[i])
                    tot = self._event_totals
                else:
                    # static pipeline: per-tile stage totals feed the
                    # pipelined-throughput bottleneck model
                    self._totals["cycles_per_tile"] += np.asarray(
                        rs["cycles_per_tile"], np.float64)[:n].sum(axis=0)
                    tot = self._totals
                tot["cycles"] += float(cycles[:n].sum(dtype=np.float64))
                tot["latency_ns"] += float(latency[:n].sum(dtype=np.float64))
                tot["energy_pj"] += float(energy[:n].sum(dtype=np.float64))
        self._inflight.clear()

    # -------------------------------------------------------------- #
    # fault-aware serving: tile health + degraded-mesh replan
    # -------------------------------------------------------------- #
    def tile_health(self) -> np.ndarray:
        """Per-tile health score in [0, 1] from device-resident telemetry.

        The engine's telemetry totals already carry each tile's measured
        drain cycles (group popcounts straight off the wire, folded at
        flush).  Health is ``1 - |measured - expected| / expected`` against
        the reference-activity calibration, clipped to [0, 1]: stuck-at-1
        faults inflate a tile's arbiter loads, dead/stuck-at-0 columns
        silence them, and both read as deviation.  Tiles with no traffic yet
        (or telemetry off) score 1.0 — unknown is not degraded.
        """
        n_tiles = len(self.net.topology) - 1
        if not self.telemetry or self._served == 0:
            return np.ones((n_tiles,))
        measured = self._totals["cycles_per_tile"] / self._served
        dev = np.abs(measured - self._expected_tile_cycles) / np.maximum(
            self._expected_tile_cycles, 1e-9)
        return np.clip(1.0 - dev, 0.0, 1.0)

    def health(self) -> float:
        """Engine health: the weakest tile's score (pipeline bottleneck)."""
        return float(self.tile_health().min())

    def replan_degraded(self, n_devices: int) -> ft.ReplanResult:
        """Degraded-mesh operation: shrink the data-parallel mesh to the
        surviving device count and recompile the serving plan.

        In-flight results are flushed first, then ``elastic_replan`` picks
        the largest power-of-two data axis within ``n_devices`` (surplus
        chips idle as hot spares — ``.dropped_chips`` of the returned plan),
        the bucket ladder is rebuilt for the new divisibility, and the
        engine's plan is recompiled with the same fault model.  Telemetry
        totals survive (same network, same tiles).
        """
        self._flush()
        plan = ft.elastic_replan(max(1, int(n_devices)), model_parallel=1)
        (data, _), _ = plan
        self.rules = (shd.make_esam_rules(shd.esam_data_mesh(data))
                      if data > 1 else None)
        dp = 1 if self.rules is None else self.rules.axis_size("spike_batch")
        self._buckets = _bucket_sizes(self.max_batch, self._min_bucket, dp)
        self._plan = self.net.plan(
            mode="packed", telemetry=self.telemetry,
            interpret=self._interpret, faults=self.faults, rules=self.rules)
        return plan

    # -------------------------------------------------------------- #
    # aggregate telemetry
    # -------------------------------------------------------------- #
    def stats(self) -> dict:
        """Aggregate hardware-cost telemetry in paper units.

        Safe to call at any time: before anything is served it returns the
        well-defined empty aggregate (all-zero costs, ``n_requests == 0``).
        A pure host read — no device work: the totals were folded in exact
        float64 at each drain flush.
        """
        spec = self._cm.cell_spec(self.read_ports)
        n = self._served
        ne, nt = self._served_events, self._served_timesteps
        et = self._event_totals
        base = {
            "requests": n,          # legacy key
            "n_requests": n,
            "telemetry": self.telemetry,
            "cell": spec.name,
            "read_ports": self.read_ports,
            "data_parallel": 1 if self.rules is None
            else self.rules.axis_size("spike_batch"),
            # fault-aware serving: health + dispatch-round watchdog
            "faulted": self.faults is not None,
            "tile_health": [float(h) for h in self.tile_health()],
            "health": self.health(),
            "degraded": self.health() < self.health_threshold,
            "dispatch_rounds": self._rounds,
            "straggler_rounds": len(self._watchdog.flagged),
            # event-stream aggregates (temporal plane)
            "n_event_requests": ne,
            "timesteps_total": nt,
            "event_energy_pj_mean": et["energy_pj"] / ne if ne else 0.0,
            "event_latency_ns_mean": et["latency_ns"] / ne if ne else 0.0,
            "event_cycles_mean": et["cycles"] / ne if ne else 0.0,
            "energy_pj_per_timestep": et["energy_pj"] / nt if nt else 0.0,
        }
        if n == 0:
            return {**base, "cycles_mean": 0.0, "latency_ns_mean": 0.0,
                    "energy_pj_per_inf": 0.0, "throughput_inf_s": 0.0,
                    "throughput_pipelined_inf_s": 0.0}
        mean_latency_ns = self._totals["latency_ns"] / n
        # pipelined rate: tiles overlap consecutive samples, so the slowest
        # mean tile stage sets the cadence (same model as system_stats)
        bottleneck_cycles = float(np.max(self._totals["cycles_per_tile"])) / n
        return {
            **base,
            "cycles_mean": self._totals["cycles"] / n,
            "latency_ns_mean": mean_latency_ns,
            "energy_pj_per_inf": self._totals["energy_pj"] / n,
            # un-pipelined device-side rate implied by the mean latency
            "throughput_inf_s":
                1e9 / mean_latency_ns if mean_latency_ns else 0.0,
            "throughput_pipelined_inf_s":
                1e9 / (bottleneck_cycles * spec.clock_ns)
                if bottleneck_cycles else 0.0,
        }


# ------------------------------------------------------------------ #
# fault-aware routing across SpikeEngine replicas
# ------------------------------------------------------------------ #
class FaultAwareRouter:
    """Drains spike traffic around degraded replicas.

    Holds N ``SpikeEngine`` replicas (each typically a physical macro / mesh
    slice, possibly built with its own ``FaultModel``) and routes every
    request by tile health: round-robin across the replicas whose weakest
    tile still scores above ``health_threshold``, falling back to the single
    healthiest replica when all are degraded (serving never stalls).  Health
    comes from each engine's device-resident telemetry — the router performs
    no extra device work — so a replica whose measured tile loads drift from
    the calibration profile (stuck-at load inflation, dead-column silence)
    organically stops receiving traffic as soon as its stats reflect it.
    """

    def __init__(self, engines, *, health_threshold: float = 0.75):
        assert engines, "router needs at least one engine"
        self.engines = list(engines)
        self.health_threshold = health_threshold
        self.routed = [0] * len(self.engines)
        self._rr = 0

    def route(self, request) -> int:
        """Queue one request on the chosen replica; returns its index."""
        scores = [e.health() for e in self.engines]
        healthy = [i for i, s in enumerate(scores)
                   if s >= self.health_threshold]
        if healthy:
            idx = healthy[self._rr % len(healthy)]
            self._rr += 1
        else:
            idx = int(np.argmax(scores))
        self.engines[idx].submit(request)
        self.routed[idx] += 1
        return idx

    def serve(self, requests=None) -> list:
        """Route ``requests`` (optional), then drain every replica."""
        if requests is not None:
            if isinstance(requests, (SpikeRequest, EventRequest)):
                requests = [requests]
            for r in requests:
                self.route(r)
        for eng in self.engines:
            eng.serve()
        return requests if requests is not None else []

    def stats(self) -> dict:
        per_engine = [
            {"health": e.health(), "degraded": h < self.health_threshold,
             "routed": n, "n_requests": e.stats()["n_requests"]}
            for e, n, h in zip(self.engines, self.routed,
                               (e.health() for e in self.engines))
        ]
        return {
            "n_engines": len(self.engines),
            "health_threshold": self.health_threshold,
            "routed": list(self.routed),
            "engines": per_engine,
        }

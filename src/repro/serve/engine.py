"""Batched serving engines.

``Engine``: LM prefill + decode loop with slot-based continuous batching
(fixed B decode slots; finished sequences free their slot and the next queued
request is prefilled into it).

``SpikeEngine``: ESAM spike-classification serving on the packed plane —
requests are bit-packed host-side into the uint32 wire format (32 spikes per
lane word, the paper's parallel-pulse inter-tile bus) and batched through
``EsamNetwork.forward_fused_packed``, so neither the server->device transfer
nor the tile cascade ever materializes an unpacked spike tensor in HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models import lm


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # int32[prompt_len]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: Optional[np.ndarray] = None


class Engine:
    """Greedy decoder over the unified LM. Single-slot-group implementation:
    requests are served in batches of ``batch_size`` padded to a shared
    prompt length (continuous batching refills the batch between rounds)."""

    def __init__(self, params, cfg, *, batch_size: int = 8,
                 rules: Optional[shd.ShardingRules] = None):
        self.params = params
        self.cfg = cfg
        self.batch_size = batch_size
        self.rules = rules

        def _prefill(params, batch, *, cache_len):
            with shd.use_rules(rules):
                return lm.prefill(params, cfg, batch, cache_len=cache_len)

        def _decode(params, tokens, caches):
            with shd.use_rules(rules):
                return lm.decode_step(params, cfg, tokens, caches)

        self._prefill = jax.jit(_prefill, static_argnames=("cache_len",))
        self._decode = jax.jit(_decode)

    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        max_len = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), max_len), np.int32)
        for i, r in enumerate(reqs):
            toks[i, max_len - len(r.prompt):] = r.prompt  # left-pad
        return toks

    def serve(self, requests: list[Request]) -> list[Request]:
        queue = list(requests)
        while queue:
            batch_reqs = queue[: self.batch_size]
            queue = queue[self.batch_size:]
            self._serve_batch(batch_reqs)
        return requests

    def _serve_batch(self, reqs: list[Request]):
        toks = self._pad_prompts(reqs)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.is_encdec:
            batch["src_frames"] = jnp.zeros(
                (toks.shape[0], toks.shape[1], self.cfg.d_model), jnp.float32
            )
        max_new = max(r.max_new_tokens for r in reqs)
        logits, caches = self._prefill(
            self.params, batch, cache_len=toks.shape[1] + max_new)
        outs = [[] for _ in reqs]
        done = np.zeros(len(reqs), bool)
        next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for _ in range(max_new):
            for i, r in enumerate(reqs):
                if not done[i]:
                    t = int(next_tok[i, 0])
                    outs[i].append(t)
                    if r.eos_id is not None and t == r.eos_id:
                        done[i] = True
                    if len(outs[i]) >= r.max_new_tokens:
                        done[i] = True
            if done.all():
                break
            logits, caches = self._decode(self.params, next_tok, caches)
            next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for r, o in zip(reqs, outs):
            r.output = np.asarray(o, np.int32)


# ------------------------------------------------------------------ #
# ESAM spike-classification serving (packed plane)
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class SpikeRequest:
    spikes: np.ndarray                     # {0,1}[n_in] (any dtype)
    # filled by the engine:
    logits: Optional[np.ndarray] = None    # float32[n_classes]
    label: Optional[int] = None            # argmax readout
    # filled when the engine runs with telemetry (paper-unit hardware cost):
    cycles: Optional[int] = None           # CIM clock cycles, summed over tiles
    latency_ns: Optional[float] = None     # cycles * cell clock period
    energy_pj: Optional[float] = None      # per-inference energy (pJ/inf)


class SpikeEngine:
    """Fixed-slot batched inference over an ``EsamNetwork``.

    Requests are packed on the host (numpy — no device round-trip) and padded
    to ``batch_size`` slots; silent (all-zero) pad rows are exact because a
    zero spike never contributes to the CIM MAC.

    With ``telemetry=True`` every served request additionally carries the
    hardware cost the simulated macro would pay for it — cycles, latency and
    pJ/inf from ``cost_model.request_stats`` on the request's *measured*
    arbiter loads (the same accounting ``network.system_stats`` averages for
    the Fig 8 operating points) — and ``stats()`` reports the running
    aggregate in paper units.
    """

    def __init__(self, net, *, batch_size: int = 128,
                 interpret: Optional[bool] = None,
                 telemetry: bool = False, read_ports: int = 4):
        from repro.core import packing

        self.net = net
        self.batch_size = batch_size
        self.n_in = net.topology[0]
        self.telemetry = telemetry
        self.read_ports = read_ports
        self._packing = packing
        self._fwd = jax.jit(
            lambda packed: net.forward_fused_packed(packed, interpret=interpret)
        )

        # Telemetry variant: same single packed pass, but it also returns the
        # per-tile arbiter loads (group popcounts of the inter-tile bitplanes)
        # — no second forward, no unpacked spike tensor.
        def _fwd_collect(packed):
            logits, planes = net.forward_fused_packed_collect(
                packed, interpret=interpret)
            return logits, tuple(packing.group_popcount(p) for p in planes)

        self._fwd_telemetry = jax.jit(_fwd_collect)
        self._served = 0
        self._cycles_total = 0.0
        self._latency_ns_total = 0.0
        self._energy_pj_total = 0.0

    def serve(self, requests: list[SpikeRequest]) -> list[SpikeRequest]:
        queue = list(requests)
        while queue:
            batch_reqs = queue[: self.batch_size]
            queue = queue[self.batch_size:]
            self._serve_batch(batch_reqs)
        return requests

    def stats(self) -> dict:
        """Aggregate hardware-cost telemetry over every request served with
        ``telemetry=True`` (all counters stay zero when telemetry is off)."""
        from repro.core.esam import cost_model as cm

        n = max(1, self._served)
        spec = cm.cell_spec(self.read_ports)
        mean_latency_ns = self._latency_ns_total / n
        return {
            "requests": self._served,
            "telemetry": self.telemetry,
            "cell": spec.name,
            "read_ports": self.read_ports,
            "cycles_mean": self._cycles_total / n,
            "latency_ns_mean": mean_latency_ns,
            "energy_pj_per_inf": self._energy_pj_total / n,
            # un-pipelined device-side rate implied by the mean latency
            "throughput_inf_s": 1e9 / mean_latency_ns if mean_latency_ns else 0.0,
        }

    def _serve_batch(self, reqs: list[SpikeRequest]):
        spikes = np.zeros((self.batch_size, self.n_in), np.uint8)
        for i, r in enumerate(reqs):
            assert r.spikes.shape == (self.n_in,), (r.spikes.shape, self.n_in)
            spikes[i] = np.asarray(r.spikes) != 0
        packed = jnp.asarray(self._packing.pack_spikes_np(spikes))
        if self.telemetry:
            logits_j, counts = self._fwd_telemetry(packed)
            logits = np.asarray(logits_j)
        else:
            logits = np.asarray(self._fwd(packed))
        for i, r in enumerate(reqs):
            r.logits = logits[i]
            r.label = int(logits[i].argmax())
        if self.telemetry:
            self._attach_telemetry(reqs, counts)

    def _attach_telemetry(self, reqs: list[SpikeRequest], counts):
        from repro.core.esam import cost_model as cm

        loads = [np.asarray(c, np.float64)[: len(reqs)] for c in counts]
        rs = cm.request_stats(self.net.topology, loads, self.read_ports)
        for i, r in enumerate(reqs):
            r.cycles = int(rs.cycles[i])
            r.latency_ns = float(rs.latency_ns[i])
            r.energy_pj = float(rs.energy_pj[i])
        self._served += len(reqs)
        self._cycles_total += float(rs.cycles.sum())
        self._latency_ns_total += float(rs.latency_ns.sum())
        self._energy_pj_total += float(rs.energy_pj.sum())

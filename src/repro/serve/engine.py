"""Batched serving engines.

``Engine``: LM prefill + decode loop with slot-based continuous batching
(fixed B decode slots; finished sequences free their slot and the next queued
request is prefilled into it).

``SpikeEngine``: ESAM spike-classification serving on the packed plane —
requests are bit-packed host-side into the uint32 wire format (32 spikes per
lane word, the paper's parallel-pulse inter-tile bus) and continuously
batched through ONE compiled ``EsamPlan`` (optionally ``shard_map``-ped over
a device mesh), so neither the server->device transfer nor the tile cascade
ever materializes an unpacked spike tensor in HBM.  Beyond single-shot
``SpikeRequest``s it admits event *streams* (``EventRequest``,
``submit_events``): T timesteps of spike planes with per-request T, bucketed
on (batch, T) and drained through the membrane-resident temporal plan
(``mode="temporal"``) with the same device-resident telemetry discipline.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import math
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models import lm
from repro.obs import Observability
from repro.serve.overload import AdmissionVerdict, DegradationLadder
from repro.train import fault_tolerance as ft


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # int32[prompt_len]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: Optional[np.ndarray] = None


class Engine:
    """Greedy decoder over the unified LM. Single-slot-group implementation:
    requests are served in batches of ``batch_size`` padded to a shared
    prompt length (continuous batching refills the batch between rounds)."""

    def __init__(self, params, cfg, *, batch_size: int = 8,
                 rules: Optional[shd.ShardingRules] = None):
        self.params = params
        self.cfg = cfg
        self.batch_size = batch_size
        self.rules = rules

        def _prefill(params, batch, *, cache_len):
            with shd.use_rules(rules):
                return lm.prefill(params, cfg, batch, cache_len=cache_len)

        def _decode(params, tokens, caches):
            with shd.use_rules(rules):
                return lm.decode_step(params, cfg, tokens, caches)

        self._prefill = jax.jit(_prefill, static_argnames=("cache_len",))
        self._decode = jax.jit(_decode)

    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        max_len = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), max_len), np.int32)
        for i, r in enumerate(reqs):
            toks[i, max_len - len(r.prompt):] = r.prompt  # left-pad
        return toks

    def serve(self, requests: list[Request]) -> list[Request]:
        queue = list(requests)
        while queue:
            batch_reqs = queue[: self.batch_size]
            queue = queue[self.batch_size:]
            self._serve_batch(batch_reqs)
        return requests

    def _serve_batch(self, reqs: list[Request]):
        toks = self._pad_prompts(reqs)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.is_encdec:
            batch["src_frames"] = jnp.zeros(
                (toks.shape[0], toks.shape[1], self.cfg.d_model), jnp.float32
            )
        max_new = max(r.max_new_tokens for r in reqs)
        logits, caches = self._prefill(
            self.params, batch, cache_len=toks.shape[1] + max_new)
        outs = [[] for _ in reqs]
        done = np.zeros(len(reqs), bool)
        next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for _ in range(max_new):
            for i, r in enumerate(reqs):
                if not done[i]:
                    t = int(next_tok[i, 0])
                    outs[i].append(t)
                    if r.eos_id is not None and t == r.eos_id:
                        done[i] = True
                    if len(outs[i]) >= r.max_new_tokens:
                        done[i] = True
            if done.all():
                break
            logits, caches = self._decode(self.params, next_tok, caches)
            next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for r, o in zip(reqs, outs):
            r.output = np.asarray(o, np.int32)


# ------------------------------------------------------------------ #
# ESAM spike-classification serving (packed plane, plan-compiled)
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class SpikeRequest:
    spikes: np.ndarray                     # {0,1}[n_in] (any dtype)
    # overload plane (optional): absolute deadline in the engine's clock —
    # requests still queued past it are shed instead of dispatched
    deadline_s: Optional[float] = None
    # lifecycle: "pending" -> "done" | "shed" (deadline) | "rejected"
    # (bounded queue full) | "failed" (router retry budget exhausted)
    status: str = "pending"
    attempts: int = 0                      # router retry count
    # filled by the engine:
    logits: Optional[np.ndarray] = None    # float32[n_classes]
    label: Optional[int] = None            # argmax readout
    # filled when the engine runs with telemetry (paper-unit hardware cost):
    cycles: Optional[int] = None           # CIM clock cycles, summed over tiles
    latency_ns: Optional[float] = None     # cycles * cell clock period
    energy_pj: Optional[float] = None      # per-inference energy (pJ/inf)


@dataclasses.dataclass
class EventRequest:
    """An event-stream classification request: T timesteps of spike planes.

    ``events``: {0,1}[T, n_in] (any dtype), or pre-packed wire-format
    uint32[T, ceil(n_in/32)].  T may differ per request — the engine buckets
    event rounds on (batch, T).
    """

    events: np.ndarray
    # overload plane (optional): see SpikeRequest
    deadline_s: Optional[float] = None
    status: str = "pending"
    attempts: int = 0
    # filled by the engine:
    logits: Optional[np.ndarray] = None    # float32[n_classes]
    label: Optional[int] = None            # argmax readout
    served_steps: Optional[int] = None     # timesteps actually served (the
    #                                        ladder may truncate the stream)
    # filled when the engine runs with telemetry (paper-unit hardware cost):
    cycles: Optional[int] = None           # CIM cycles, summed over T steps
    latency_ns: Optional[float] = None     # cycles * cell clock period
    energy_pj: Optional[float] = None      # whole-stream energy
    energy_pj_per_step: Optional[float] = None  # energy_pj / T

    @property
    def n_steps(self) -> int:
        return int(np.asarray(self.events).shape[0])


@functools.lru_cache(maxsize=None)
def _stats_jit(topology: tuple, read_ports: int, temporal: bool):
    """One jitted device-side cost function per (topology, ports, mode).

    The eager ``request_stats_device`` dispatches ~20 tiny jnp ops per tile;
    on a sharded mesh each one fans out across every device, and that host
    overhead — not the datapath — dominated the dp8 round time.  Jitting
    collapses the whole accounting into ONE dispatch.  Module-level cache so
    every engine (sync or fused, any replica) shares the same compiled
    executable — which also makes their telemetry bit-identical by
    construction."""
    from repro.core.esam import cost_model as cm

    fn = (cm.temporal_request_stats_device if temporal
          else cm.request_stats_device)
    return jax.jit(lambda loads: fn(topology, loads, read_ports))


# ------------------------------------------------------------------ #
# stats() schema: documented, versioned, grouped into typed sections.
# CI (PR 7-9) greps several of these keys out of bench derived strings —
# tests/test_obs.py pins the schema so a rename can never silently break
# those gates.  Bump STATS_SCHEMA_VERSION on any key change.
# ------------------------------------------------------------------ #
STATS_SCHEMA_VERSION = 1

_STATS_SCHEMA: dict[str, dict[str, str]] = {
    # engine identity + configuration
    "identity": {
        "stats_schema_version": "int",
        "requests": "int",              # legacy alias of n_requests
        "n_requests": "int",
        "telemetry": "bool",
        "cell": "str",
        "read_ports": "int",
        "data_parallel": "int",
    },
    # fault-aware serving: tile health + dispatch watchdog
    "health": {
        "faulted": "bool",
        "tile_health": "list",
        "health": "float",
        "degraded": "bool",
        "dispatch_rounds": "int",
        "straggler_rounds": "int",
    },
    # overload plane: admission, deadlines, degradation ladder
    "overload": {
        "queue_depth": "int",
        "queue_limit": "int|None",
        "high_water": "int|None",
        "shed_deadline": "int",
        "rejected_full": "int",
        "backpressure_events": "int",
        "degradation_level": "int",
        "degradation_level_name": "str",
        "ladder_transitions": "int",
        "ladder_transition_log": "list",
    },
    # per-round host-sync/dispatch observability (dp8 attribution numbers)
    "rounds": {
        "rounds_static": "int",
        "rounds_event": "int",
        "rows_real_total": "int",
        "rows_padded_total": "int",
        "pad_fraction": "float",
        "rounds_per_bucket": "dict",
        "padded_rows_per_bucket": "dict",
        "real_rows_per_bucket": "dict",
        "pad_fraction_per_bucket": "dict",
        "host_pack_s_total": "float",
        "dispatch_s_total": "float",
    },
    # fused async dispatch (the dp-scaling fix)
    "fusion": {
        "fuse_rounds": "int",
        "overlap": "bool",
        "fused_rounds": "int",
        "rounds_saved": "int",
    },
    # event-stream (temporal plane) aggregates
    "events": {
        "n_event_requests": "int",
        "timesteps_total": "int",
        "event_energy_pj_mean": "float",
        "event_latency_ns_mean": "float",
        "event_cycles_mean": "float",
        "energy_pj_per_timestep": "float",
    },
    # paper-unit hardware cost aggregates (zero-filled before any traffic)
    "cost": {
        "cycles_mean": "float",
        "latency_ns_mean": "float",
        "energy_pj_per_inf": "float",
        "throughput_inf_s": "float",
        "throughput_pipelined_inf_s": "float",
    },
}


def stats_schema() -> dict[str, dict[str, str]]:
    """The versioned schema of ``SpikeEngine.stats()``: section -> key ->
    type name (``"int|None"`` marks optionally-unset config knobs).

    The returned dict is a fresh copy — mutate freely.  ``stats()`` always
    returns exactly the union of these keys (regression-tested), and
    ``stats()["stats_schema_version"] == STATS_SCHEMA_VERSION``.
    """
    return {section: dict(keys) for section, keys in _STATS_SCHEMA.items()}


def _bucket_sizes(max_batch: int, min_bucket: int, dp: int) -> list[int]:
    """Power-of-two bucket ladder: min_bucket, 2*min_bucket, ... >= max_batch.

    Every bucket is a multiple of the data-parallel degree ``dp`` so a padded
    batch always divides the mesh; the smallest bucket never exceeds the
    (rounded-up) ``max_batch`` itself.
    """
    top = 1
    while top < max_batch:
        top <<= 1
    lo = max(min(min_bucket, top), dp)
    b = 1
    while b < lo:
        b <<= 1
    sizes = [b]
    while sizes[-1] < top:
        sizes.append(sizes[-1] * 2)
    return sizes


class SpikeEngine:
    """Continuously-batched ESAM serving over one compiled execution plan.

    Requests enter an admission queue (``submit``; ``serve`` is submit+drain)
    and are dispatched in multi-batch rounds of up to ``max_batch`` requests.
    Each round is zero-padded up to the next power-of-two bucket
    (``min_bucket``-based ladder, always a multiple of the data-parallel
    degree) so the compiled plan sees a handful of static shapes instead of
    one per queue length — silent pad rows are exact for the binary CIM MAC.
    Packing happens on the host (numpy — the device only ever sees the uint32
    wire format); with ``rules`` the plan is compiled ``shard_map``-ped over
    the mesh and each bucket is sharded over the ``spike_batch`` axes.

    With ``telemetry=True`` the plan additionally returns each tile's
    arbiter loads (group popcounts of the inter-tile bitplanes — same pass,
    nothing unpacked) and the paper-unit hardware cost is computed *on
    device* (``cost_model.request_stats_device``), staying device-resident
    through the whole dispatch loop: the engine performs no per-batch host
    sync — per-request costs land on the host in one flush at drain end
    (where the running aggregate folds into exact float64 totals, immune to
    float32 drift over long-lived engines), and ``stats()`` is a pure host
    read.

    **Fused async dispatch** (the dp-scaling plane): ``fuse_rounds``
    coalesces up to that many legacy bucket-rounds into ONE super-batch
    dispatch per drain step (``"auto"`` = the data-parallel degree, so dp8
    issues ~1/8th the rounds over 8x the batch; the bucket ladder is
    extended to ``max_batch * fuse`` and every super-batch stays dp-aligned).
    The fused path is bit-identical per row to the per-bucket path — the
    binary CIM MAC is row-independent and zero padding is exact — so fusion
    changes *when* work is dispatched, never *what* is computed
    (property-tested).  ``overlap=True`` double-buffers the host side: a
    background packer thread builds round N+1's wire-format batch while
    round N's dispatch runs, through a bounded depth-2 ring (no
    ``block_until_ready`` anywhere in the drain — results stay device-side
    until the flush).  A degraded ladder level may cap fusion
    (``LadderLevel.fuse_cap``) so shed/deadline sweeps stay frequent under
    pressure.  ``warmup()`` AOT-compiles the whole bucket ladder (and the
    event (bucket, T) grid) ahead of the first request.
    """

    def __init__(self, net, *, max_batch: int = 128, min_bucket: int = 8,
                 fuse_rounds=None,  # None | "auto" | int >= 1
                 overlap: bool = False,
                 interpret: Optional[bool] = None,
                 telemetry: bool = False, read_ports: int = 4,
                 temporal=None,  # Optional[temporal.TemporalConfig]
                 faults=None,  # Optional[faults.FaultModel]
                 watchdog: Optional[ft.StragglerWatchdog] = None,
                 health_threshold: float = 0.75,
                 rules: Optional[shd.ShardingRules] = None,
                 queue_limit: Optional[int] = None,
                 high_water: Optional[int] = None,
                 ladder: Optional[DegradationLadder] = None,
                 clock=time.monotonic,
                 round_hook=None,
                 observability: Optional[Observability] = None,
                 batch_size: Optional[int] = None):
        from repro.core import packing
        from repro.core.esam import cost_model as cm
        from repro.core.esam import temporal as temporal_mod

        if batch_size is not None:   # deprecated alias (pre-plan engine)
            max_batch = batch_size
        self.net = net
        self.max_batch = max_batch
        self.n_in = net.topology[0]
        self.telemetry = telemetry
        self.read_ports = read_ports
        self.rules = rules
        self.faults = faults
        self.health_threshold = health_threshold
        self._packing = packing
        self._cm = cm
        self._interpret = interpret
        self._min_bucket = min_bucket
        # dispatch-round straggler watchdog: each continuous-batching round's
        # host-side wall time (packing + dispatch; device work is async) is
        # recorded, and rounds slower than threshold x the EMA are flagged —
        # surfaced through stats() so a coordinator can drain traffic away
        self._watchdog = watchdog or ft.StragglerWatchdog()
        self._rounds = 0
        # ---- overload plane -------------------------------------------- #
        # bounded admission: submit() rejects past queue_limit; high-water
        # mark (default: half the limit) turns verdicts into backpressure
        self._clock = clock
        self._queue_limit = queue_limit
        if high_water is None and queue_limit is not None:
            high_water = max(1, queue_limit // 2)
        self._high_water = high_water
        # graceful-degradation ladder state (None => pinned to full service)
        self._ladder = ladder
        self._ladder_level = 0
        self._pressure_streak = 0
        self._clear_streak = 0
        self._ladder_flagged_seen = 0
        self._transitions: list[dict] = []
        # chaos/observability hook: called with the round index before each
        # dispatch round (inside the watchdog-timed section) — a raising hook
        # models a replica crashing mid-drain
        self.round_hook = round_hook
        # ---- observability plane (repro.obs) --------------------------- #
        # All three lanes default off; every emission below is guarded so
        # the off path stays bit-identical to the instrumented path (spans
        # observe, never perturb — property-tested in test_obs_identity).
        self._obs = observability
        self._tracer = observability.tracer if observability else None
        self._metrics = observability.metrics if observability else None
        self._profiler = observability.profile if observability else None
        # id(request) -> (async span id, admit ts us); entries are removed
        # at every terminal transition, so the map never outgrows the queue
        self._req_spans: dict[int, tuple[int, float]] = {}
        self._m = self._make_instruments(self._metrics)
        # overload counters (all surfaced through stats())
        self._shed_deadline = 0
        self._rejected_full = 0
        self._backpressure_events = 0
        # per-round host-sync/dispatch observability (satellite for the dp8
        # serving regression): pack vs dispatch host time, padded-vs-real
        # rows per bucket — aggregates only, O(1) per round
        self._round_counters = {
            "rounds_static": 0, "rounds_event": 0,
            "rows_real": 0, "rows_padded": 0,
            "host_pack_s": 0.0, "dispatch_s": 0.0,
            "fused_rounds": 0, "rounds_saved": 0,
        }
        self._rounds_per_bucket: dict[int, int] = {}
        self._padded_rows_per_bucket: dict[int, int] = {}
        self._real_rows_per_bucket: dict[int, int] = {}
        # LIF dynamics template for event-stream requests; n_steps is taken
        # from each request (per-request T), the rest from this config.  The
        # default (zero leak, zero reset) makes a T=1 event request
        # bit-identical to the static packed path.
        self._temporal = temporal or temporal_mod.TemporalConfig(n_steps=1)
        dp = 1 if rules is None else rules.axis_size("spike_batch")
        # round fusion: how many legacy bucket-rounds may coalesce into one
        # super-batch dispatch ("auto" tracks the dp degree so the dispatch
        # count drops ~1/dp); the bucket ladder is extended to cover the
        # fused super-batches.  fuse=1 (default) is the legacy drain.
        if fuse_rounds is not None and fuse_rounds != "auto":
            assert int(fuse_rounds) >= 1, fuse_rounds
        self._fuse_arg = fuse_rounds
        self._fuse = self._fuse_factor(dp)
        self._overlap = bool(overlap)
        self._pool: Optional[ThreadPoolExecutor] = None
        self._buckets = _bucket_sizes(max_batch * self._fuse, min_bucket, dp)
        # the engine owns every array it hands the plan (packed fresh per
        # round), so the input buffer is donated — XLA reuses the allocation
        # across drain rounds instead of re-allocating per dispatch
        self._plan = net.plan(
            mode="packed", telemetry=telemetry, interpret=interpret,
            faults=faults, rules=rules, donate=True)
        n_tiles = len(net.topology) - 1
        # tile-health calibration: expected mean drain cycles per tile on the
        # reference activity profile (the paper's 53%/50% calibration point).
        # Measured telemetry deviating from this — up (stuck-at-1 load
        # inflation) or down (dead/stuck-at-0 columns silencing traffic) —
        # marks the tile degraded.
        topo = net.topology
        ref = [
            np.full((1, cm.tile_geometry(topo[t], topo[t + 1])[0]),
                    float(cm.REF_SPIKES_PER_GROUP[t])
                    if t < len(cm.REF_SPIKES_PER_GROUP) else 64.0)
            for t in range(n_tiles)
        ]
        self._expected_tile_cycles = cm.request_stats(
            topo, ref, read_ports).cycles_per_tile.mean(axis=0)  # [n_tiles]
        # admission queues + per-round device results awaiting one host flush
        self._pending: list[SpikeRequest] = []
        self._pending_events: list[EventRequest] = []
        self._inflight: list[tuple[list, jax.Array, Optional[dict]]] = []
        # exact float64 telemetry totals, folded in at each drain flush
        self._served = 0
        self._served_events = 0
        self._served_timesteps = 0
        self._totals = {
            "cycles": 0.0,
            "cycles_per_tile": np.zeros((n_tiles,), np.float64),
            "latency_ns": 0.0,
            "energy_pj": 0.0,
        }
        self._event_totals = {
            "cycles": 0.0,
            "latency_ns": 0.0,
            "energy_pj": 0.0,
        }

    # -------------------------------------------------------------- #
    # observability plane: instruments + span helpers (all no-ops when off)
    # -------------------------------------------------------------- #
    @staticmethod
    def _make_instruments(reg) -> Optional[dict]:
        """Pre-register every engine metric so the scrape endpoint shows the
        full (zeroed) surface before the first request.  Counter totals are
        incremented with exactly the values ``stats()`` folds, so the two
        always reconcile (tested)."""
        if reg is None:
            return None
        c, g, h = reg.counter, reg.gauge, reg.histogram
        return {
            "submitted": c("esam_requests_submitted_total",
                           "requests admitted to the engine queue"),
            "rejected": c("esam_requests_rejected_total",
                          "bounded-queue admission rejections"),
            "shed": c("esam_requests_shed_total",
                      "requests shed on an expired deadline"),
            "served_static": c("esam_requests_served_total",
                               "requests served", kind="static"),
            "served_event": c("esam_requests_served_total",
                              "requests served", kind="event"),
            "timesteps": c("esam_timesteps_served_total",
                           "event-stream timesteps served"),
            "rounds": c("esam_dispatch_rounds_total",
                        "continuous-batching dispatch rounds"),
            "fused": c("esam_fused_rounds_total",
                       "rounds that coalesced >1 legacy bucket-round"),
            "rounds_saved": c("esam_rounds_saved_total",
                              "legacy bucket-rounds saved by fusion"),
            "rows_real": c("esam_rows_real_total",
                           "real (non-padded) rows dispatched"),
            "rows_padded": c("esam_rows_padded_total",
                             "zero-padded bucket rows dispatched"),
            "backpressure": c("esam_backpressure_events_total",
                              "admissions past the high-water mark"),
            "ladder_transitions": c("esam_ladder_transitions_total",
                                    "degradation-ladder level changes"),
            "energy": c("esam_energy_pj_total",
                        "modeled inference energy (pJ), telemetry lane"),
            "cycles": c("esam_cycles_total",
                        "modeled CIM cycles, telemetry lane"),
            "queue_depth": g("esam_queue_depth",
                             "requests admitted and awaiting dispatch"),
            "ladder_level": g("esam_degradation_level",
                              "current degradation-ladder level (0=full)"),
            "health": g("esam_health",
                        "weakest-tile health score in [0,1]"),
            "pack_s": h("esam_round_pack_seconds",
                        "host-side wire-format packing time per round"),
            "dispatch_s": h("esam_round_dispatch_seconds",
                            "plan dispatch-call time per round"),
            "queue_s": h("esam_request_queue_seconds",
                         "admit -> round-formation queue wait"),
            "latency_s": h("esam_request_latency_seconds",
                           "admit -> terminal-state request latency"),
        }

    def _dp_degree(self) -> int:
        return 1 if self.rules is None else self.rules.axis_size("spike_batch")

    def _obs_admit(self, r) -> None:
        """Open the request's async span + book the admission."""
        if self._m is not None:
            self._m["submitted"].inc()
            self._m["queue_depth"].set(self.queue_depth())
        if self._tracer is not None:
            rid = self._tracer.next_id()
            self._req_spans[id(r)] = (rid, self._tracer.now_us())
            self._tracer.begin_async(
                "request", rid,
                kind="event" if isinstance(r, EventRequest) else "static",
                deadline_s=r.deadline_s, dp=self._dp_degree())

    def _obs_close(self, r, status: str, **args) -> None:
        """Close the request's async span at a terminal transition."""
        entry = self._req_spans.pop(id(r), None)
        if entry is None:
            return
        rid, t_admit = entry
        now = self._tracer.now_us()
        if self._m is not None:
            self._m["latency_s"].observe((now - t_admit) / 1e6)
        self._tracer.end_async("request", rid, status=status, **args)

    def _obs_queue_spans(self, reqs, bucket: int) -> None:
        """Per-request queue-wait spans: admit time -> round formation."""
        now = self._tracer.now_us()
        for r in reqs:
            entry = self._req_spans.get(id(r))
            if entry is None:
                continue
            rid, t_admit = entry
            self._tracer.complete("queue", t_admit, now - t_admit,
                                  cat="request", req=rid, bucket=bucket)
            if self._m is not None:
                self._m["queue_s"].observe((now - t_admit) / 1e6)

    # -------------------------------------------------------------- #
    # admission + dispatch
    # -------------------------------------------------------------- #
    def queue_depth(self) -> int:
        """Requests currently admitted and awaiting dispatch (both queues)."""
        return len(self._pending) + len(self._pending_events)

    def submit(self, requests):
        """Queue requests without dispatching (single request or list).

        ``SpikeRequest`` and ``EventRequest`` objects may be mixed; each is
        routed to its own admission queue.  Returns an
        :class:`~repro.serve.overload.AdmissionVerdict` per request (a single
        verdict for a single request): with a bounded queue
        (``queue_limit``) a full queue rejects the request (its ``status``
        becomes ``"rejected"``, nothing is queued) and depth beyond the
        high-water mark flags ``backpressure`` so a closed-loop caller can
        slow down.  Unbounded engines always admit — callers that ignore the
        verdict keep the pre-overload behavior.
        """
        single = isinstance(requests, (SpikeRequest, EventRequest))
        if single:
            requests = [requests]
        verdicts = []
        for r in requests:
            depth = self.queue_depth()
            if self._queue_limit is not None and depth >= self._queue_limit:
                r.status = "rejected"
                self._rejected_full += 1
                if self._m is not None:
                    self._m["rejected"].inc()
                if self._tracer is not None:
                    self._tracer.instant("rejected", queue_depth=depth)
                verdicts.append(AdmissionVerdict(
                    admitted=False, reason="queue_full", queue_depth=depth))
                continue
            if isinstance(r, EventRequest):
                self._pending_events.append(r)
            else:
                self._pending.append(r)
            if self._obs is not None:
                self._obs_admit(r)
            depth += 1
            bp = self._high_water is not None and depth > self._high_water
            if bp:
                self._backpressure_events += 1
                if self._m is not None:
                    self._m["backpressure"].inc()
            verdicts.append(AdmissionVerdict(
                admitted=True, backpressure=bp, queue_depth=depth))
        return verdicts[0] if single else verdicts

    def submit_events(self, requests):
        """Queue event-stream requests (single ``EventRequest`` or list)."""
        if isinstance(requests, EventRequest):
            requests = [requests]
        assert all(isinstance(r, EventRequest) for r in requests)
        return self.submit(requests)

    def serve(self, requests=None) -> list:
        """Enqueue ``requests`` (optional), drain both queues, flush results.

        Returns the list of requests served in this call (the passed-in list
        when given, else everything that was pending)."""
        if requests is not None:
            self.submit(requests)
            out = requests if isinstance(requests, list) else [requests]
        else:
            out = list(self._pending) + list(self._pending_events)
        self._shed_expired()
        self._drain_static()
        self._drain_events()
        self._flush()
        return out

    # -------------------------------------------------------------- #
    # drain loops: synchronous (legacy) and overlapped (double-buffered)
    # -------------------------------------------------------------- #
    def _pop_static_round(self) -> list[SpikeRequest]:
        """Pop one round's worth of static requests (up to the fused
        budget — ``fuse_rounds`` legacy rounds coalesced)."""
        self._ladder_tick()
        budget = self._round_budget()
        reqs = self._pending[: budget]
        del self._pending[: budget]
        return reqs

    def _pop_event_round(self) -> tuple[list[EventRequest], int]:
        """Pop one (batch, T) event round: the head request's effective T
        and everything sharing it, in arrival order, up to the fused budget.
        A degraded ladder level caps T, so streams whose effective
        (truncated) T coincides share a round."""
        self._ladder_tick()
        budget = self._round_budget()
        t_cap = self._level().event_t_cap
        t = self._pending_events[0].n_steps
        if t_cap is not None:
            t = min(t, t_cap)
        round_reqs, rest = [], []
        for r in self._pending_events:
            eff = r.n_steps if t_cap is None else min(r.n_steps, t_cap)
            if eff == t and len(round_reqs) < budget:
                round_reqs.append(r)
            else:
                rest.append(r)
        self._pending_events = rest
        return round_reqs, t

    def _drain_static(self) -> None:
        if self._overlap:
            self._drain_overlap("_pending", self._form_static_round)
            return
        while self._pending:
            self._timed_round(self._dispatch, self._pop_static_round())
            self._shed_expired()

    def _drain_events(self) -> None:
        if self._overlap:
            self._drain_overlap("_pending_events", self._form_event_round)
            return
        while self._pending_events:
            round_reqs, t = self._pop_event_round()
            self._timed_round(self._dispatch_events, round_reqs, t)
            self._shed_expired()

    def _form_static_round(self):
        """Pop a round and split it into (pack, launch) halves so the pack
        (host numpy) can run on the packer thread while the previous round's
        dispatch is in flight.  Everything the closures touch is captured
        here on the main thread; ``launch`` runs JAX calls on the main
        thread only."""
        reqs = self._pop_static_round()
        bucket = self._bucket(len(reqs))
        return (lambda: self._pack_static(reqs, bucket),
                lambda packed, pack_s: self._launch_static(
                    reqs, bucket, packed, pack_s))

    def _form_event_round(self):
        reqs, t = self._pop_event_round()
        bucket = self._bucket(len(reqs))
        for r in reqs:
            r.served_steps = t
        events = [np.asarray(r.events) for r in reqs]  # capture on main thread
        return (lambda: self._pack_events(events, t, bucket),
                lambda packed, pack_s: self._launch_events(
                    reqs, bucket, t, packed, pack_s))

    def _drain_overlap(self, queue_name: str, form) -> None:
        """Double-buffered drain: a bounded depth-2 ring of formed rounds —
        the packer thread builds round N+1's wire-format batch while round
        N's dispatch call runs on the main thread.  The watchdog times the
        dispatch half only (pack time is recorded separately per round, as
        always).  A raising round hook (chaos crash) aborts with formed
        rounds popped-but-unserved — exactly the crash-mid-drain state the
        router's retry path recovers."""
        pool = self._packer_pool()
        ring: collections.deque = collections.deque()
        try:
            while getattr(self, queue_name) or ring:
                while getattr(self, queue_name) and len(ring) < 2:
                    pack, launch = form()
                    ring.append((pool.submit(pack), launch))
                fut, launch = ring.popleft()
                packed, pack_s = fut.result()
                self._timed_round(launch, packed, pack_s)
                self._shed_expired()
        finally:
            while ring:
                ring.popleft()[0].cancel()

    def _packer_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="spike-packer")
        return self._pool

    def close(self) -> None:
        """Shut down the background packer thread (no-op when never used)."""
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    # -------------------------------------------------------------- #
    # cold start: AOT-compile the bucket ladder before the first request
    # -------------------------------------------------------------- #
    def warmup(self, *, event_ts=(), aot: bool = True) -> dict:
        """Compile every shape the drain loop can dispatch, ahead of time.

        The static plan is AOT-compiled for the engine's whole bucket
        ladder; ``event_ts`` additionally warms the temporal (bucket, T)
        grid — the set is expanded with the degradation ladder's
        ``event_t_cap`` rungs so degraded rounds stay warm too.  With the
        persistent compilation cache enabled (``launch/env.py``) a restart
        re-warms from disk in milliseconds.  Returns per-shape compile
        seconds plus ``total_s``; after it, serving any warmed shape
        performs zero compilation (regression-tested).
        """
        t0 = time.perf_counter()
        times: dict = {"static": self._plan.warmup(self._buckets, aot=aot)}
        ts = {int(t) for t in event_ts}
        if ts and self._ladder is not None:
            caps = {lv.event_t_cap for lv in self._ladder.levels
                    if lv.event_t_cap is not None}
            ts |= {min(t, c) for t in set(ts) for c in caps}
        for t in sorted(ts):
            times[f"event_t{t}"] = self._event_plan(t).warmup(
                self._buckets, aot=aot)
        if self.telemetry:
            # the jitted cost accounting's dispatch cache keys on the
            # *sharding* of the plan's load outputs, not just their shapes —
            # warm it on real (zeros) plan outputs so the first served round
            # pays no compile outside the plan either.  Nothing is recorded:
            # counters, telemetry totals and the inflight ring stay
            # untouched.
            topo = self.net.topology
            width = self._packing.packed_width(self.n_in)
            ports = self._effective_read_ports()
            tw0 = time.perf_counter()
            for b in self._buckets:
                res = self._plan(jnp.zeros((b, width), jnp.uint32))
                jax.block_until_ready(
                    _stats_jit(topo, ports, False)(res.loads))
                for t in sorted(ts):
                    resT = self._event_plan(t)(
                        jnp.zeros((t, b, width), jnp.uint32))
                    jax.block_until_ready(
                        _stats_jit(topo, ports, True)(resT.loads))
            times["telemetry_s"] = time.perf_counter() - tw0
        times["total_s"] = time.perf_counter() - t0
        if self._metrics is not None:
            from repro.obs.profile import record_warmup_times
            record_warmup_times(self._metrics, times)
        if self._tracer is not None:
            self._tracer.instant("warmup_done", cat="engine",
                                 total_s=times["total_s"],
                                 shapes=len(self._buckets) + len(ts))
        return times

    # -------------------------------------------------------------- #
    # overload plane: deadline shedding + degradation ladder
    # -------------------------------------------------------------- #
    def _shed_expired(self) -> None:
        """Drop still-queued requests whose deadline already passed — they
        would burn a device round only to be useless to the caller.  Shed
        requests get ``status="shed"`` (logits stay None) and are counted in
        ``stats()["shed_deadline"]``.  Requests without a deadline never
        shed (the zero-pressure identity path)."""
        now = None
        for name in ("_pending", "_pending_events"):
            queue = getattr(self, name)
            if not any(r.deadline_s is not None for r in queue):
                continue
            if now is None:
                now = self._clock()
            keep = []
            for r in queue:
                if r.deadline_s is not None and now > r.deadline_s:
                    r.status = "shed"
                    self._shed_deadline += 1
                    if self._m is not None:
                        self._m["shed"].inc()
                    if self._tracer is not None:
                        self._tracer.instant("shed", deadline_s=r.deadline_s)
                        self._obs_close(r, "shed")
                else:
                    keep.append(r)
            setattr(self, name, keep)

    def _level(self):
        if self._ladder is None:
            from repro.serve.overload import LadderLevel
            return LadderLevel("full")
        return self._ladder.level(self._ladder_level)

    def _round_limit(self) -> int:
        cap = self._level().bucket_cap
        return self.max_batch if cap is None else max(1, min(self.max_batch,
                                                             cap))

    def _fuse_factor(self, dp: int) -> int:
        """Resolve the ``fuse_rounds`` knob: None => 1 (legacy drain),
        ``"auto"`` => the data-parallel degree (dp8 fuses 8 legacy rounds
        into one sharded super-batch), an int => itself."""
        if self._fuse_arg is None:
            return 1
        if self._fuse_arg == "auto":
            return max(1, int(dp))
        return max(1, int(self._fuse_arg))

    def _round_budget(self) -> int:
        """Requests per dispatch round: the ladder's bucket ceiling times
        the fusion factor (itself capped by the level's ``fuse_cap`` so a
        degraded engine sweeps deadlines between smaller rounds)."""
        cap = self._level().fuse_cap
        fuse = self._fuse if cap is None else max(1, min(self._fuse, cap))
        return self._round_limit() * fuse

    def _effective_read_ports(self) -> int:
        ports = self._level().read_ports
        return self.read_ports if ports is None else ports

    def _ladder_tick(self) -> None:
        """One pressure observation per dispatch round.  Pressure = queue
        depth beyond the high-water mark OR the watchdog flagged the previous
        round a straggler.  ``step_down_after`` pressured rounds in a row
        move one level down; ``step_up_after`` clear rounds move back up.
        Every transition is recorded (round index, levels, reason)."""
        if self._ladder is None:
            return
        flagged = len(self._watchdog.flagged)
        straggler = flagged > self._ladder_flagged_seen
        self._ladder_flagged_seen = flagged
        deep = (self._high_water is not None
                and self.queue_depth() > self._high_water)
        if deep or straggler:
            self._pressure_streak += 1
            self._clear_streak = 0
            if (self._pressure_streak >= self._ladder.step_down_after
                    and self._ladder_level < self._ladder.n_levels - 1):
                self._record_transition(
                    self._ladder_level + 1,
                    "queue_depth" if deep else "straggler")
                self._pressure_streak = 0
        else:
            self._clear_streak += 1
            self._pressure_streak = 0
            if (self._clear_streak >= self._ladder.step_up_after
                    and self._ladder_level > 0):
                self._record_transition(self._ladder_level - 1,
                                        "pressure_cleared")
                self._clear_streak = 0

    def _record_transition(self, to_level: int, reason: str) -> None:
        self._transitions.append({
            "round": self._rounds,
            "from_level": self._ladder_level,
            "to_level": to_level,
            "from": self._ladder.level(self._ladder_level).name,
            "to": self._ladder.level(to_level).name,
            "reason": reason,
        })
        if self._m is not None:
            self._m["ladder_transitions"].inc()
            self._m["ladder_level"].set(to_level)
        if self._tracer is not None:
            self._tracer.instant(
                "ladder_transition", cat="ladder", round=self._rounds,
                from_level=self._ladder_level, to_level=to_level,
                reason=reason)
        self._ladder_level = to_level

    def _timed_round(self, dispatch, *args) -> None:
        """One dispatch round under the straggler watchdog: the host-side
        round wall time (packing + dispatch; device work stays async) feeds
        the EMA, and slow rounds are flagged into ``stats()``.  The chaos
        hook runs inside the timed section — an injected stall inflates the
        EMA exactly like a real straggler, and a raising hook aborts the
        round before dispatch (the crash-mid-drain model: this round's
        requests are popped but never served, which is what the router's
        retry path recovers)."""
        t0 = time.perf_counter()
        if self._profiler is not None:
            self._profiler.on_round_start(self._rounds)
        trace_t0 = (self._tracer.now_us() if self._tracer is not None
                    else 0.0)
        if self.round_hook is not None:
            self.round_hook(self._rounds)
        dispatch(*args)
        if self._tracer is not None:
            self._tracer.complete(
                "round", trace_t0, self._tracer.now_us() - trace_t0,
                cat="round", round=self._rounds, level=self._level().name,
                dp=self._dp_degree())
        if self._profiler is not None:
            self._profiler.on_round_end(self._rounds)
        if self._m is not None:
            self._m["rounds"].inc()
            self._m["queue_depth"].set(self.queue_depth())
        self._watchdog.record(self._rounds, time.perf_counter() - t0)
        self._rounds += 1

    def _bucket(self, n: int) -> int:
        for b in self._buckets:
            if n <= b:
                return b
        return self._buckets[-1]

    def _note_round(self, kind: str, bucket: int, n_real: int,
                    pack_s: float, dispatch_s: float,
                    n_legacy: int = 1) -> None:
        """Fold one round into the host-sync observability aggregates.
        ``n_legacy`` is how many legacy (un-fused) bucket-rounds this
        dispatch replaced — rounds where it exceeds 1 count as fused and
        the difference accumulates in ``rounds_saved``."""
        c = self._round_counters
        c[f"rounds_{kind}"] += 1
        c["rows_real"] += n_real
        c["rows_padded"] += bucket - n_real
        c["host_pack_s"] += pack_s
        c["dispatch_s"] += dispatch_s
        if n_legacy > 1:
            c["fused_rounds"] += 1
            c["rounds_saved"] += n_legacy - 1
        if self._m is not None:
            self._m[f"served_{kind}"].inc(n_real)
            self._m["rows_real"].inc(n_real)
            self._m["rows_padded"].inc(bucket - n_real)
            self._m["pack_s"].observe(pack_s)
            self._m["dispatch_s"].observe(dispatch_s)
            if n_legacy > 1:
                self._m["fused"].inc()
                self._m["rounds_saved"].inc(n_legacy - 1)
        self._rounds_per_bucket[bucket] = (
            self._rounds_per_bucket.get(bucket, 0) + 1)
        self._padded_rows_per_bucket[bucket] = (
            self._padded_rows_per_bucket.get(bucket, 0) + bucket - n_real)
        self._real_rows_per_bucket[bucket] = (
            self._real_rows_per_bucket.get(bucket, 0) + n_real)

    def _n_legacy(self, n: int) -> int:
        """Legacy bucket-rounds a super-batch of ``n`` requests replaces."""
        return max(1, math.ceil(n / self._round_limit()))

    def _pack_static(self, reqs: list[SpikeRequest],
                     bucket: int) -> tuple[np.ndarray, float]:
        """Host half of a static round: bit-pack to the padded wire format
        (pure numpy — safe on the packer thread)."""
        trace_t0 = self._tracer.now_us() if self._tracer is not None else 0.0
        t0 = time.perf_counter()
        packed = self._packing.pack_padded_rows_np(
            [r.spikes for r in reqs], bucket, self.n_in)
        pack_s = time.perf_counter() - t0
        if self._tracer is not None:
            self._tracer.complete(
                "pack", trace_t0, self._tracer.now_us() - trace_t0,
                cat="round", kind="static", bucket=bucket, n_real=len(reqs))
        return packed, pack_s

    def _launch_static(self, reqs: list[SpikeRequest], bucket: int,
                       packed: np.ndarray, pack_s: float) -> None:
        """Device half: run the plan, keep every result device-side (no
        host sync here).  Pack time and dispatch-call time are recorded
        separately per bucket — the observability that attributed the dp8
        regression to host sync + tiny per-bucket dispatches."""
        if self._tracer is not None:
            self._obs_queue_spans(reqs, bucket)
        trace_t1 = self._tracer.now_us() if self._tracer is not None else 0.0
        t1 = time.perf_counter()
        res = self._plan(jnp.asarray(packed))
        rs = None
        if self.telemetry:
            # lazy device-side cost — nothing is synced inside the drain loop
            rs = _stats_jit(self.net.topology, self._effective_read_ports(),
                            False)(res.loads)
        t2 = time.perf_counter()
        if self._tracer is not None:
            n_legacy = self._n_legacy(len(reqs))
            if n_legacy > 1:
                self._tracer.instant("fuse", cat="round", bucket=bucket,
                                     rounds_coalesced=n_legacy)
            self._tracer.complete(
                "dispatch", trace_t1, self._tracer.now_us() - trace_t1,
                cat="round", kind="static", bucket=bucket, n_real=len(reqs),
                dp=self._dp_degree())
        self._note_round("static", bucket, len(reqs), pack_s, t2 - t1,
                         self._n_legacy(len(reqs)))
        self._served += len(reqs)
        self._inflight.append((reqs, res.logits, rs))

    def _dispatch(self, reqs: list[SpikeRequest]) -> None:
        """One continuous-batching round (synchronous path): pad to bucket,
        pack, launch."""
        bucket = self._bucket(len(reqs))
        packed, pack_s = self._pack_static(reqs, bucket)
        self._launch_static(reqs, bucket, packed, pack_s)

    def _event_plan(self, n_steps: int):
        """The (donated) temporal plan for effective stream length
        ``n_steps`` — cached per (batch-invariant) spec on the network."""
        cfg = dataclasses.replace(self._temporal, n_steps=n_steps)
        return self.net.plan(
            mode="temporal", temporal=cfg, telemetry=self.telemetry,
            interpret=self._interpret, faults=self.faults, rules=self.rules,
            donate=True)

    def _pack_events(self, events: list[np.ndarray], n_steps: int,
                     bucket: int) -> tuple[np.ndarray, float]:
        """Host half of an event round (pure numpy — packer-thread safe)."""
        width = self._packing.packed_width(self.n_in)
        trace_t0 = self._tracer.now_us() if self._tracer is not None else 0.0
        t0 = time.perf_counter()
        packed = np.zeros((n_steps, bucket, width), np.uint32)
        for i, ev in enumerate(events):
            assert ev.shape[0] >= n_steps, (ev.shape, n_steps)
            if ev.dtype == np.uint32 and ev.shape[-1] == width:
                packed[:, i] = ev[:n_steps]
            else:
                assert ev.shape[1:] == (self.n_in,), (ev.shape, self.n_in)
                packed[:, i] = self._packing.pack_spikes_np(
                    ev[:n_steps] != 0)
        pack_s = time.perf_counter() - t0
        if self._tracer is not None:
            self._tracer.complete(
                "pack", trace_t0, self._tracer.now_us() - trace_t0,
                cat="round", kind="event", bucket=bucket, t=n_steps,
                n_real=len(events))
        return packed, pack_s

    def _launch_events(self, reqs: list[EventRequest], bucket: int,
                       n_steps: int, packed: np.ndarray,
                       pack_s: float) -> None:
        if self._tracer is not None:
            self._obs_queue_spans(reqs, bucket)
        trace_t1 = self._tracer.now_us() if self._tracer is not None else 0.0
        t1 = time.perf_counter()
        res = self._event_plan(n_steps)(jnp.asarray(packed))
        rs = None
        if self.telemetry:
            rs = _stats_jit(self.net.topology, self._effective_read_ports(),
                            True)(res.loads)
        t2 = time.perf_counter()
        if self._tracer is not None:
            n_legacy = self._n_legacy(len(reqs))
            if n_legacy > 1:
                self._tracer.instant("fuse", cat="round", bucket=bucket,
                                     rounds_coalesced=n_legacy)
            self._tracer.complete(
                "dispatch", trace_t1, self._tracer.now_us() - trace_t1,
                cat="round", kind="event", bucket=bucket, t=n_steps,
                n_real=len(reqs), dp=self._dp_degree())
        self._note_round("event", bucket, len(reqs), pack_s, t2 - t1,
                         self._n_legacy(len(reqs)))
        self._served_events += len(reqs)
        self._served_timesteps += len(reqs) * n_steps
        if self._m is not None:
            self._m["timesteps"].inc(len(reqs) * n_steps)
        self._inflight.append((reqs, res.logits, rs))

    def _dispatch_events(self, reqs: list[EventRequest], n_steps: int) -> None:
        """One event round (synchronous path): same-T requests padded to a
        batch bucket and run through the temporal plan (compiled once per
        (batch, T) shape); the stream cost stays device-side like the
        static path's.  ``n_steps`` is the *effective* T — a degraded
        ladder level truncates longer streams to it (recorded per request
        as ``served_steps``)."""
        bucket = self._bucket(len(reqs))
        for r in reqs:
            r.served_steps = n_steps
        events = [np.asarray(r.events) for r in reqs]
        packed, pack_s = self._pack_events(events, n_steps, bucket)
        self._launch_events(reqs, bucket, n_steps, packed, pack_s)

    def _flush(self) -> None:
        """Attach logits/labels (+ per-request cost) and fold the telemetry
        totals — one host transfer per round's arrays, all at drain end
        rather than inside the dispatch loop.  Totals accumulate in float64
        here (the arrays are on the host anyway for per-request attachment),
        masking the zero-padded tail slots of each bucket."""
        for reqs, logits_j, rs in self._inflight:
            n = len(reqs)
            is_event = bool(reqs) and isinstance(reqs[0], EventRequest)
            trace_t0 = (self._tracer.now_us() if self._tracer is not None
                        else 0.0)
            logits = np.asarray(logits_j)
            if self._tracer is not None:
                self._tracer.complete(
                    "device_drain", trace_t0,
                    self._tracer.now_us() - trace_t0, cat="flush",
                    kind="event" if is_event else "static", n_real=n)
                trace_t0 = self._tracer.now_us()
            for i, r in enumerate(reqs):
                r.logits = logits[i]
                r.label = int(logits[i].argmax())
                r.status = "done"
            if rs is not None:
                cycles = np.asarray(rs["cycles"])
                latency = np.asarray(rs["latency_ns"])
                energy = np.asarray(rs["energy_pj"])
                for i, r in enumerate(reqs):
                    r.cycles = int(cycles[i])
                    r.latency_ns = float(latency[i])
                    r.energy_pj = float(energy[i])
                if is_event:
                    per_step = np.asarray(rs["energy_pj_per_step"])
                    for i, r in enumerate(reqs):
                        r.energy_pj_per_step = float(per_step[i])
                    tot = self._event_totals
                else:
                    # static pipeline: per-tile stage totals feed the
                    # pipelined-throughput bottleneck model
                    self._totals["cycles_per_tile"] += np.asarray(
                        rs["cycles_per_tile"], np.float64)[:n].sum(axis=0)
                    tot = self._totals
                cycles_sum = float(cycles[:n].sum(dtype=np.float64))
                energy_sum = float(energy[:n].sum(dtype=np.float64))
                tot["cycles"] += cycles_sum
                tot["latency_ns"] += float(latency[:n].sum(dtype=np.float64))
                tot["energy_pj"] += energy_sum
                if self._m is not None:
                    self._m["cycles"].inc(cycles_sum)
                    self._m["energy"].inc(energy_sum)
            if self._tracer is not None:
                self._tracer.complete(
                    "telemetry_flush", trace_t0,
                    self._tracer.now_us() - trace_t0, cat="flush",
                    kind="event" if is_event else "static", n_real=n,
                    telemetry=rs is not None)
            if self._obs is not None:
                for r in reqs:
                    self._obs_close(r, "done", label=r.label)
        self._inflight.clear()
        if self._m is not None and self.telemetry and self._served:
            self._m["health"].set(self.health())

    # -------------------------------------------------------------- #
    # fault-aware serving: tile health + degraded-mesh replan
    # -------------------------------------------------------------- #
    def tile_health(self) -> np.ndarray:
        """Per-tile health score in [0, 1] from device-resident telemetry.

        The engine's telemetry totals already carry each tile's measured
        drain cycles (group popcounts straight off the wire, folded at
        flush).  Health is ``1 - |measured - expected| / expected`` against
        the reference-activity calibration, clipped to [0, 1]: stuck-at-1
        faults inflate a tile's arbiter loads, dead/stuck-at-0 columns
        silence them, and both read as deviation.  Tiles with no traffic yet
        (or telemetry off) score 1.0 — unknown is not degraded.
        """
        n_tiles = len(self.net.topology) - 1
        if not self.telemetry or self._served == 0:
            return np.ones((n_tiles,))
        measured = self._totals["cycles_per_tile"] / self._served
        dev = np.abs(measured - self._expected_tile_cycles) / np.maximum(
            self._expected_tile_cycles, 1e-9)
        return np.clip(1.0 - dev, 0.0, 1.0)

    def health(self) -> float:
        """Engine health: the weakest tile's score (pipeline bottleneck)."""
        return float(self.tile_health().min())

    def replan_degraded(self, n_devices: int) -> ft.ReplanResult:
        """Degraded-mesh operation: shrink the data-parallel mesh to the
        surviving device count and recompile the serving plan.

        In-flight results are flushed first, then ``elastic_replan`` picks
        the largest power-of-two data axis within ``n_devices`` (surplus
        chips idle as hot spares — ``.dropped_chips`` of the returned plan),
        the bucket ladder is rebuilt for the new divisibility, and the
        engine's plan is recompiled with the same fault model.  Telemetry
        totals survive (same network, same tiles).
        """
        self._flush()
        if self._tracer is not None:
            self._tracer.instant("replan_degraded", cat="engine",
                                 n_devices=int(n_devices))
        plan = ft.elastic_replan(max(1, int(n_devices)), model_parallel=1)
        (data, _), _ = plan
        self.rules = (shd.make_esam_rules(shd.esam_data_mesh(data))
                      if data > 1 else None)
        dp = 1 if self.rules is None else self.rules.axis_size("spike_batch")
        self._fuse = self._fuse_factor(dp)   # "auto" tracks the new mesh
        self._buckets = _bucket_sizes(
            self.max_batch * self._fuse, self._min_bucket, dp)
        self._plan = self.net.plan(
            mode="packed", telemetry=self.telemetry,
            interpret=self._interpret, faults=self.faults, rules=self.rules,
            donate=True)
        return plan

    # -------------------------------------------------------------- #
    # aggregate telemetry
    # -------------------------------------------------------------- #
    def _pad_fraction_per_bucket(self) -> dict[int, float]:
        """Per-bucket pad overhead, safe under fused rounds: a bucket a
        fused super-batch only ever grazed (or that saw zero real rows — a
        formed-but-crashed round) divides by its total rows, never by
        zero."""
        out = {}
        for b in sorted(set(self._padded_rows_per_bucket)
                        | set(self._real_rows_per_bucket)):
            pad = self._padded_rows_per_bucket.get(b, 0)
            real = self._real_rows_per_bucket.get(b, 0)
            out[b] = pad / (pad + real) if (pad + real) else 0.0
        return out

    def stats(self) -> dict:
        """Aggregate hardware-cost telemetry in paper units.

        Safe to call at any time: before anything is served it returns the
        well-defined empty aggregate (all-zero costs, ``n_requests == 0``).
        A pure host read — no device work: the totals were folded in exact
        float64 at each drain flush.
        """
        spec = self._cm.cell_spec(self.read_ports)
        n = self._served
        ne, nt = self._served_events, self._served_timesteps
        et = self._event_totals
        base = {
            "stats_schema_version": STATS_SCHEMA_VERSION,
            "requests": n,          # legacy key
            "n_requests": n,
            "telemetry": self.telemetry,
            "cell": spec.name,
            "read_ports": self.read_ports,
            "data_parallel": 1 if self.rules is None
            else self.rules.axis_size("spike_batch"),
            # fault-aware serving: health + dispatch-round watchdog
            "faulted": self.faults is not None,
            "tile_health": [float(h) for h in self.tile_health()],
            "health": self.health(),
            "degraded": self.health() < self.health_threshold,
            "dispatch_rounds": self._rounds,
            "straggler_rounds": len(self._watchdog.flagged),
            # overload plane: admission + deadline + degradation ladder
            "queue_depth": self.queue_depth(),
            "queue_limit": self._queue_limit,
            "high_water": self._high_water,
            "shed_deadline": self._shed_deadline,
            "rejected_full": self._rejected_full,
            "backpressure_events": self._backpressure_events,
            "degradation_level": self._ladder_level,
            "degradation_level_name": self._level().name,
            "ladder_transitions": len(self._transitions),
            "ladder_transition_log": list(self._transitions),
            # per-round host-sync/dispatch observability (dp8 regression
            # diagnosis): pack time vs dispatch-call time, pad overhead
            "rounds_static": self._round_counters["rounds_static"],
            "rounds_event": self._round_counters["rounds_event"],
            "rows_real_total": self._round_counters["rows_real"],
            "rows_padded_total": self._round_counters["rows_padded"],
            "pad_fraction": (
                self._round_counters["rows_padded"]
                / max(1, self._round_counters["rows_real"]
                      + self._round_counters["rows_padded"])),
            "rounds_per_bucket": dict(self._rounds_per_bucket),
            "padded_rows_per_bucket": dict(self._padded_rows_per_bucket),
            "real_rows_per_bucket": dict(self._real_rows_per_bucket),
            "pad_fraction_per_bucket": self._pad_fraction_per_bucket(),
            "host_pack_s_total": self._round_counters["host_pack_s"],
            "dispatch_s_total": self._round_counters["dispatch_s"],
            # fused async dispatch (the dp-scaling fix): configuration plus
            # evidence of fewer, larger rounds
            "fuse_rounds": self._fuse,
            "overlap": self._overlap,
            "fused_rounds": self._round_counters["fused_rounds"],
            "rounds_saved": self._round_counters["rounds_saved"],
            # event-stream aggregates (temporal plane)
            "n_event_requests": ne,
            "timesteps_total": nt,
            "event_energy_pj_mean": et["energy_pj"] / ne if ne else 0.0,
            "event_latency_ns_mean": et["latency_ns"] / ne if ne else 0.0,
            "event_cycles_mean": et["cycles"] / ne if ne else 0.0,
            "energy_pj_per_timestep": et["energy_pj"] / nt if nt else 0.0,
        }
        if n == 0:
            return {**base, "cycles_mean": 0.0, "latency_ns_mean": 0.0,
                    "energy_pj_per_inf": 0.0, "throughput_inf_s": 0.0,
                    "throughput_pipelined_inf_s": 0.0}
        mean_latency_ns = self._totals["latency_ns"] / n
        # pipelined rate: tiles overlap consecutive samples, so the slowest
        # mean tile stage sets the cadence (same model as system_stats)
        bottleneck_cycles = float(np.max(self._totals["cycles_per_tile"])) / n
        return {
            **base,
            "cycles_mean": self._totals["cycles"] / n,
            "latency_ns_mean": mean_latency_ns,
            "energy_pj_per_inf": self._totals["energy_pj"] / n,
            # un-pipelined device-side rate implied by the mean latency
            "throughput_inf_s":
                1e9 / mean_latency_ns if mean_latency_ns else 0.0,
            "throughput_pipelined_inf_s":
                1e9 / (bottleneck_cycles * spec.clock_ns)
                if bottleneck_cycles else 0.0,
        }


# ------------------------------------------------------------------ #
# fault-aware routing across SpikeEngine replicas
# ------------------------------------------------------------------ #
class AllReplicasDownError(RuntimeError):
    """Every replica has crashed — nothing can serve."""


class AllReplicasDegradedError(RuntimeError):
    """Every live replica is below the health threshold and the router was
    built with ``on_all_degraded="raise"``."""


class FaultAwareRouter:
    """Drains spike traffic around degraded, stalled, and crashed replicas.

    Holds N ``SpikeEngine`` replicas (each typically a physical macro / mesh
    slice, possibly built with its own ``FaultModel``) and routes every
    request by tile health: round-robin across the replicas whose weakest
    tile still scores above ``health_threshold``.  When *all* live replicas
    are degraded the router either raises (``on_all_degraded="raise"``) or
    falls back to the healthiest one — but never silently: every fallback is
    counted in ``stats()["degraded_route"]`` so callers can see traffic
    landing on known-bad silicon.  Health comes from each engine's
    device-resident telemetry — the router performs no extra device work.

    Overload hardening (``retry`` — a :class:`fault_tolerance.RetryPolicy`):
    a replica that *crashes mid-drain* (its drain raises; chaos models this
    with a raising round hook) is taken out of rotation and every request it
    had queued-but-not-completed is re-routed to a surviving replica after
    exponential backoff with counter-based seeded jitter (deterministic —
    no wall-clock RNG in the datapath).  A replica whose drain exceeds
    ``retry.attempt_timeout_s`` is counted a timeout and marked *slow*:
    round-robin prefers non-slow healthy replicas from then on.  Requests
    whose retry budget is exhausted get ``status="failed"`` instead of being
    silently lost.
    """

    def __init__(self, engines, *, health_threshold: float = 0.75,
                 retry: Optional[ft.RetryPolicy] = None,
                 on_all_degraded: str = "fallback",
                 observability: Optional[Observability] = None,
                 sleep=time.sleep, clock=time.monotonic):
        assert engines, "router needs at least one engine"
        assert on_all_degraded in ("fallback", "raise"), on_all_degraded
        self.engines = list(engines)
        self.health_threshold = health_threshold
        self.retry = retry or ft.RetryPolicy()
        self.on_all_degraded = on_all_degraded
        self.routed = [0] * len(self.engines)
        self.counters = {"retries": 0, "crashes": 0, "timeouts": 0,
                         "degraded_route": 0, "rejected_full": 0,
                         "failed": 0}
        self._rr = 0
        self._down: set[int] = set()
        self._slow: set[int] = set()
        self._assigned: list[list] = [[] for _ in self.engines]
        self._backoff_counter = 0
        self._sleep = sleep
        self._clock = clock
        self._obs = observability
        self._tracer = observability.tracer if observability else None
        self._metrics = observability.metrics if observability else None

    def _count(self, name: str, n: int = 1) -> None:
        """Bump a router counter, mirrored into ``esam_router_*_total``."""
        self.counters[name] += n
        if self._metrics is not None:
            self._metrics.counter(
                f"esam_router_{name}_total",
                "fault-aware router event counter").inc(n)

    def _health_gauges(self) -> None:
        if self._metrics is not None:
            self._metrics.gauge(
                "esam_router_replicas_down",
                "replicas out of rotation (crashed)").set(len(self._down))
            self._metrics.gauge(
                "esam_router_replicas_slow",
                "replicas flagged slow (drain timeout)").set(len(self._slow))

    def backlog(self) -> int:
        """Routed requests not yet completed on a live replica."""
        return sum(len(self._assigned[i]) for i in range(len(self.engines))
                   if i not in self._down)

    def route(self, request, *, exclude=()) -> Optional[int]:
        """Queue one request on the chosen replica; returns its index, or
        ``None`` when every candidate's bounded queue rejected it (the
        request's status is then ``"rejected"`` and
        ``stats()["rejected_full"]`` counts it)."""
        avoid = set(exclude) | self._down
        candidates = [i for i in range(len(self.engines)) if i not in avoid]
        if not candidates:
            raise AllReplicasDownError(
                f"all {len(self.engines)} replicas are down")
        scores = {i: self.engines[i].health() for i in candidates}
        healthy = [i for i in candidates
                   if scores[i] >= self.health_threshold]
        fast = [i for i in healthy if i not in self._slow]
        pool = fast or healthy
        if pool:
            idx = pool[self._rr % len(pool)]
            self._rr += 1
            order = [idx] + [i for i in pool if i != idx] + sorted(
                (i for i in candidates if i not in pool),
                key=lambda i: -scores[i])
        else:
            # every live candidate is degraded: no silent routing onto
            # known-bad silicon — count it, and raise if so configured
            self._count("degraded_route")
            if self._tracer is not None:
                self._tracer.instant("degraded_route", cat="router",
                                     scores={i: float(s)
                                             for i, s in scores.items()})
            if self.on_all_degraded == "raise":
                raise AllReplicasDegradedError(
                    f"all live replicas below health threshold "
                    f"{self.health_threshold} (scores: {scores})")
            order = sorted(candidates, key=lambda i: -scores[i])
        for idx in order:
            verdict = self.engines[idx].submit(request)
            if verdict is None or verdict.admitted:
                request.status = "pending"   # clear any earlier rejection
                if pool and idx not in pool:
                    # healthy queues were all full and the request spilled
                    # onto a degraded replica — visible, not silent
                    self._count("degraded_route")
                self._assigned[idx].append(request)
                self.routed[idx] += 1
                return idx
        self._count("rejected_full")
        return None

    def serve(self, requests=None) -> list:
        """Route ``requests`` (optional), then drain every live replica —
        re-routing work off any replica that crashes or stalls mid-drain."""
        if requests is not None:
            if isinstance(requests, (SpikeRequest, EventRequest)):
                requests = [requests]
            for r in requests:
                self.route(r)
        self._drain()
        return requests if requests is not None else []

    def _drain(self) -> None:
        """Drain passes until every routed request reaches a terminal state.

        A crash mid-drain moves the replica to ``_down`` and re-routes its
        incomplete requests (retry + backoff), which may enqueue work on a
        replica already drained this pass — hence the outer loop.  Bounded:
        each pass either completes requests or downs a replica."""
        max_passes = 2 * len(self.engines) + 2
        for _ in range(max_passes):
            for idx, eng in enumerate(self.engines):
                if idx in self._down:
                    continue
                if not (self._assigned[idx] or eng.queue_depth()):
                    continue
                trace_t0 = (self._tracer.now_us()
                            if self._tracer is not None else 0.0)
                t0 = self._clock()
                try:
                    eng.serve()
                except Exception:
                    self._on_crash(idx)
                    continue
                dt = self._clock() - t0
                if self._tracer is not None:
                    self._tracer.complete(
                        "replica_drain", trace_t0,
                        self._tracer.now_us() - trace_t0, cat="router",
                        replica=idx, drain_s=dt)
                to = self.retry.attempt_timeout_s
                if to is not None and dt > to:
                    self._count("timeouts")
                    self._slow.add(idx)
                    if self._tracer is not None:
                        self._tracer.instant("replica_slow", cat="router",
                                             replica=idx, drain_s=dt,
                                             timeout_s=to)
                    self._health_gauges()
                self._assigned[idx] = [
                    r for r in self._assigned[idx]
                    if r.logits is None and r.status == "pending"]
            if self.backlog() == 0:
                return

    def _on_crash(self, idx: int) -> None:
        """Crashed replica: out of rotation; re-route its incomplete
        requests with exponential backoff + seeded jitter.  Requests it
        already completed keep their results (exactly-once: results attach
        on exactly one replica; lost in-flight work is re-served)."""
        self._count("crashes")
        self._down.add(idx)
        self._health_gauges()
        victims = [r for r in self._assigned[idx]
                   if r.logits is None and r.status == "pending"]
        if self._tracer is not None:
            self._tracer.instant("replica_crash", cat="router", replica=idx,
                                 victims=len(victims))
        self._assigned[idx] = []
        # empty the dead replica's queues: its pending requests are exactly
        # the victims being re-routed, and leaving them behind would both
        # leak queue depth and double-serve if the engine were ever drained
        # again (exactly-once depends on this)
        eng = self.engines[idx]
        eng._pending.clear()
        eng._pending_events.clear()
        eng._inflight.clear()
        for r in victims:
            r.attempts += 1
            if r.attempts >= self.retry.max_attempts:
                r.status = "failed"
                self._count("failed")
                continue
            self._backoff_counter += 1
            self._sleep(self.retry.backoff_s(r.attempts,
                                             self._backoff_counter))
            try:
                dest = self.route(r, exclude={idx})
            except AllReplicasDownError:
                r.status = "failed"
                self._count("failed")
                continue
            if dest is not None:
                self._count("retries")
                if self._tracer is not None:
                    self._tracer.instant("reroute", cat="router",
                                         from_replica=idx, to_replica=dest,
                                         attempt=r.attempts)

    def stats(self) -> dict:
        per_engine = [
            {"health": e.health(), "degraded": h < self.health_threshold,
             "down": i in self._down, "slow": i in self._slow,
             "routed": n, "n_requests": e.stats()["n_requests"]}
            for i, (e, n, h) in enumerate(zip(
                self.engines, self.routed,
                (e.health() for e in self.engines)))
        ]
        return {
            "n_engines": len(self.engines),
            "health_threshold": self.health_threshold,
            "routed": list(self.routed),
            "engines": per_engine,
            "down": sorted(self._down),
            "slow": sorted(self._slow),
            "backlog": self.backlog(),
            **self.counters,
        }

"""Open-loop traffic generation + chaos harness for the ESAM serving plane.

Closed-loop benchmarks (serve a list, time the wall) can never overload the
engine: the caller waits for the drain before offering more work.  Real edge
traffic is *open-loop* — arrivals come on the traffic's schedule, not the
server's — so saturation shows up as queue growth, deadline sheds, and tail
latency, which is exactly what this module measures:

  * ``TrafficConfig`` + ``build_requests`` — seeded Poisson arrivals
    (exponential inter-arrival gaps) over a mixed request blend: static
    spike requests and event streams with a per-request T drawn from
    ``event_t_choices``.  Fully deterministic in ``seed`` (one
    ``np.random.default_rng((seed, i))`` per request, a counter-based
    scheme like the repo's STDP RNG — replays are bit-identical).
  * ``ChaosConfig`` + ``install_chaos`` — replica slowdowns (an injected
    stall per dispatch round, which the engine's watchdog EMA sees like any
    real straggler), mid-drain crashes (the engine's round hook raises
    ``ReplicaCrashError`` after N rounds, so a round's requests are popped
    but never served — the router's retry path must recover them), and
    request storms (a burst of extra arrivals at one instant).
  * ``run_open_loop`` — drives a ``SpikeEngine`` or ``FaultAwareRouter``
    with the arrival schedule against the wall clock and distills a
    ``TrafficReport``: p50/p99/p99.9 latency, shed / rejected / retry /
    deadline-miss counts, and goodput-under-SLO (completed within the SLO
    per offered request — the number an edge deployment actually ships).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.serve.engine import (EventRequest, FaultAwareRouter, SpikeRequest)


class ReplicaCrashError(RuntimeError):
    """Injected mid-drain replica crash (chaos harness)."""


# ------------------------------------------------------------------ #
# open-loop request generation
# ------------------------------------------------------------------ #
@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Seeded open-loop traffic description.

    ``rate_hz`` is the mean Poisson arrival rate; ``p_event`` the fraction
    of event-stream requests (T drawn uniformly from ``event_t_choices``);
    ``deadline_s`` an optional per-request relative deadline — the engine
    sheds requests still queued past arrival + deadline.
    """

    rate_hz: float
    n_requests: int
    seed: int = 0
    p_event: float = 0.0
    event_t_choices: tuple = (2, 4)
    n_in: int = 768
    spike_p: float = 0.3
    deadline_s: Optional[float] = None


def arrival_times(cfg: TrafficConfig) -> np.ndarray:
    """Poisson arrival offsets (seconds from traffic start), seeded."""
    rng = np.random.default_rng((cfg.seed, 0x0A221))
    gaps = rng.exponential(1.0 / cfg.rate_hz, size=cfg.n_requests)
    return np.cumsum(gaps)


def _one_request(cfg: TrafficConfig, i: int, salt: int = 0):
    rng = np.random.default_rng((cfg.seed, salt, i))
    if rng.random() < cfg.p_event:
        t = int(rng.choice(cfg.event_t_choices))
        ev = (rng.random((t, cfg.n_in)) < cfg.spike_p).astype(np.uint8)
        return EventRequest(events=ev)
    spikes = (rng.random(cfg.n_in) < cfg.spike_p).astype(np.uint8)
    return SpikeRequest(spikes=spikes)


def build_requests(cfg: TrafficConfig, *, chaos: "ChaosConfig" = None):
    """The full arrival schedule: ``(requests, arrival_offsets_s)`` sorted
    by arrival.  A chaos request storm splices ``storm_size`` extra
    requests in at ``storm_at_s`` (all due at the same instant)."""
    reqs = [_one_request(cfg, i) for i in range(cfg.n_requests)]
    arr = arrival_times(cfg)
    if chaos is not None and chaos.storm_size:
        storm = [_one_request(cfg, i, salt=0x570F) for i in
                 range(chaos.storm_size)]
        storm_at = np.full(chaos.storm_size, float(chaos.storm_at_s))
        arr = np.concatenate([arr, storm_at])
        reqs = reqs + storm
        order = np.argsort(arr, kind="stable")
        arr = arr[order]
        reqs = [reqs[j] for j in order]
    return reqs, arr


def warmup_engine(server, cfg: TrafficConfig, *, aot: bool = True) -> dict:
    """AOT-warm every (bucket, T) shape an open-loop run of ``cfg`` can
    dispatch — the whole static bucket ladder, plus the temporal grid for
    each T the blend can draw (``event_t_choices`` when ``p_event > 0``;
    degraded-ladder t-caps are expanded inside ``SpikeEngine.warmup``).
    Router-aware: warms every replica behind a ``FaultAwareRouter``.
    Returns ``{replica_index: warmup_times}``."""
    engines = (server.engines if isinstance(server, FaultAwareRouter)
               else [server])
    ts = (tuple(int(t) for t in cfg.event_t_choices)
          if cfg.p_event > 0 else ())
    return {i: eng.warmup(event_ts=ts, aot=aot)
            for i, eng in enumerate(engines)}


# ------------------------------------------------------------------ #
# chaos harness
# ------------------------------------------------------------------ #
@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """What to break, where, and when.

    ``slowdown``: replica index -> injected stall (seconds) per dispatch
    round.  ``crash_replica``/``crash_after_rounds``: that replica's drain
    raises ``ReplicaCrashError`` once it has run N more rounds.
    ``storm_at_s``/``storm_size``: a burst of extra arrivals at one instant
    (consumed by ``build_requests``).
    """

    slowdown: tuple = ()                 # ((replica_idx, stall_s), ...)
    crash_replica: Optional[int] = None
    crash_after_rounds: int = 1
    storm_at_s: float = 0.0
    storm_size: int = 0

    def stall_s(self, idx: int) -> float:
        return dict(self.slowdown).get(idx, 0.0)


def install_chaos(engines, chaos: ChaosConfig, *, sleep=time.sleep) -> None:
    """Arm each engine's round hook with this chaos plan.  Crash rounds are
    counted from installation (each engine's current round index)."""
    for idx, eng in enumerate(engines):
        stall = chaos.stall_s(idx)
        crash_at = None
        if chaos.crash_replica == idx:
            crash_at = eng._rounds + chaos.crash_after_rounds

        def hook(round_idx, _stall=stall, _crash_at=crash_at, _idx=idx):
            if _crash_at is not None and round_idx >= _crash_at:
                raise ReplicaCrashError(
                    f"chaos: replica {_idx} crashed at round {round_idx}")
            if _stall:
                sleep(_stall)

        eng.round_hook = hook


# ------------------------------------------------------------------ #
# the open-loop driver + report
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class TrafficReport:
    n_offered: int
    n_completed: int
    n_shed: int              # deadline sheds (engine-side)
    n_rejected: int          # bounded-queue rejections
    n_failed: int            # retry budget exhausted (router)
    n_deadline_miss: int     # completed, but after their deadline
    p50_ms: float
    p99_ms: float
    p999_ms: float
    mean_ms: float
    goodput_slo: float       # completed within SLO / offered
    slo_s: Optional[float]
    duration_s: float
    offered_rate_hz: float
    completed_rate_hz: float
    retries: int = 0
    crashes: int = 0
    timeouts: int = 0
    degraded_routes: int = 0
    backpressure_events: int = 0
    ladder_transitions: int = 0
    max_degradation_level: int = 0
    #: metrics-registry snapshot (``Registry.snapshot()``) when the run was
    #: driven with an observability handle; None otherwise
    metrics: Optional[dict] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _percentiles_ms(lat_s: np.ndarray):
    if lat_s.size == 0:
        return 0.0, 0.0, 0.0, 0.0
    ms = lat_s * 1e3
    p50, p99, p999 = np.percentile(ms, [50.0, 99.0, 99.9])
    return float(p50), float(p99), float(p999), float(ms.mean())


def run_open_loop(server, cfg: TrafficConfig, *,
                  slo_s: Optional[float] = None,
                  chaos: Optional[ChaosConfig] = None,
                  observability=None,
                  clock=time.monotonic, sleep=time.sleep,
                  max_wall_s: float = 120.0) -> TrafficReport:
    """Drive ``server`` (a ``SpikeEngine`` or ``FaultAwareRouter``) with the
    open-loop schedule and return the distilled :class:`TrafficReport`.

    Requests are admitted when their arrival time comes due (never before —
    open-loop), deadlines are anchored at the *nominal* arrival (queueing
    delay counts against the SLO, as it does for a user), and each drain's
    completion timestamp closes out every request it finished.  Latency is
    completion minus nominal arrival.

    ``observability`` (an :class:`repro.obs.Observability`, typically the
    same handle the engines were built with) folds the metrics-registry
    snapshot into ``TrafficReport.metrics`` and brackets the run with trace
    instants — the driver itself stays un-instrumented beyond that (the
    engines emit the real spans).
    """
    is_router = isinstance(server, FaultAwareRouter)
    engines = server.engines if is_router else [server]
    if chaos is not None:
        install_chaos(engines, chaos, sleep=sleep)
    reqs, arr = build_requests(cfg, chaos=chaos)
    n = len(reqs)
    tracer = observability.tracer if observability is not None else None
    if tracer is not None:
        tracer.instant("traffic_start", cat="traffic", n_offered=n,
                       rate_hz=cfg.rate_hz, p_event=cfg.p_event)
    t0 = clock()
    completed_at = np.full(n, np.nan)
    done = [False] * n
    i = 0
    while True:
        now = clock() - t0
        if now > max_wall_s:
            break
        admitted_any = False
        while i < n and arr[i] <= now:
            r = reqs[i]
            if cfg.deadline_s is not None:
                r.deadline_s = t0 + float(arr[i]) + cfg.deadline_s
            if is_router:
                server.route(r)
            else:
                server.submit(r)
            admitted_any = True
            i += 1
        backlog = (server.backlog() if is_router
                   else server.queue_depth())
        if not admitted_any and backlog == 0:
            if i >= n:
                break
            wait = (t0 + float(arr[i])) - clock()
            if wait > 0:
                sleep(min(wait, 0.05))
            continue
        server.serve()
        t_done = clock() - t0
        for j in range(n):
            if not done[j] and (reqs[j].logits is not None
                                or reqs[j].status != "pending"):
                done[j] = True
                if reqs[j].logits is not None:
                    completed_at[j] = t_done

    duration = clock() - t0
    completed = ~np.isnan(completed_at)
    lat = completed_at[completed] - arr[completed]
    p50, p99, p999, mean_ms = _percentiles_ms(lat)
    statuses = [r.status for r in reqs]
    n_shed = statuses.count("shed")
    n_rejected = statuses.count("rejected")
    n_failed = statuses.count("failed")
    miss = 0
    if cfg.deadline_s is not None:
        miss = int((lat > cfg.deadline_s).sum())
    slo = slo_s if slo_s is not None else cfg.deadline_s
    goodput = (float((lat <= slo).sum()) / n if slo is not None
               else float(completed.sum()) / n) if n else 0.0

    retries = crashes = timeouts = degraded = 0
    if is_router:
        st = server.stats()
        retries, crashes = st["retries"], st["crashes"]
        timeouts, degraded = st["timeouts"], st["degraded_route"]
    estats = [e.stats() for e in engines]
    if tracer is not None:
        tracer.instant("traffic_end", cat="traffic",
                       n_completed=int(completed.sum()),
                       duration_s=duration)
    metrics_snapshot = (observability.metrics.snapshot()
                        if observability is not None
                        and observability.metrics is not None else None)
    return TrafficReport(
        n_offered=n,
        n_completed=int(completed.sum()),
        n_shed=n_shed,
        n_rejected=n_rejected,
        n_failed=n_failed,
        n_deadline_miss=miss,
        p50_ms=p50, p99_ms=p99, p999_ms=p999, mean_ms=mean_ms,
        goodput_slo=goodput, slo_s=slo,
        duration_s=duration,
        offered_rate_hz=n / max(duration, 1e-9),
        completed_rate_hz=float(completed.sum()) / max(duration, 1e-9),
        retries=retries, crashes=crashes, timeouts=timeouts,
        degraded_routes=degraded,
        backpressure_events=sum(s["backpressure_events"] for s in estats),
        ladder_transitions=sum(s["ladder_transitions"] for s in estats),
        max_degradation_level=max(
            (max((tr["to_level"] for tr in s["ladder_transition_log"]),
                 default=0) for s in estats), default=0),
        metrics=metrics_snapshot,
    )

"""AdamW with large-scale memory tiers.

Tiers (selected per ModelConfig):
  * fp32 master + fp32 m/v (default, <8B params)
  * bf16 m/v + bf16 master with *stochastic rounding* on the param update
    (the 1T tier: fp32 states alone would be 12 TB; SR keeps the update
    unbiased so bf16 states train stably — Kimi-K2-scale necessity)

ZeRO-1: optimizer-state leaves get one extra sharding axis over 'data' (the
"zero" logical axis) on the largest dimension the param leaves unsharded.
Gradients arrive data-replicated (pjit psum), the update is computed on
1/|data| of the state per device, and XLA materializes the implied
reduce-scatter + all-gather — classic ZeRO-1 without manual collectives.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.params import ParamSpec, is_spec


class AdamState(NamedTuple):
    m: dict
    v: dict
    step: jax.Array


class TrainState(NamedTuple):
    params: dict
    opt: AdamState


def _maps_to_data(axis_name, rules) -> bool:
    if rules is None or axis_name is None:
        return False
    mapped = rules.rules.get(axis_name)
    axes = (mapped,) if isinstance(mapped, str) else tuple(mapped or ())
    return "data" in axes


def zero1_spec(spec: ParamSpec, data_size: int, enabled: bool, rules=None) -> ParamSpec:
    """Optimizer-state ParamSpec: same sharding as the param, plus the 'zero'
    axis on the largest still-unsharded, divisible dim.  Leaves that already
    shard over 'data' (FSDP tiers) are left alone — a NamedSharding may map
    each mesh axis to one positional dim only."""
    if not enabled or any(_maps_to_data(a, rules) for a in spec.axes):
        return spec
    best, best_dim = None, 0
    for i, (d, ax) in enumerate(zip(spec.shape, spec.axes)):
        if ax is None and d % data_size == 0 and d > best_dim:
            best, best_dim = i, d
    if best is None:
        return spec
    axes = tuple("zero" if i == best else a for i, a in enumerate(spec.axes))
    return ParamSpec(spec.shape, axes, spec.init, spec.scale, spec.dtype)


def opt_specs(param_specs, *, dtype=jnp.float32, data_size: int = 1,
              zero1: bool = True, rules=None):
    """Spec tree for (m, v) with ZeRO-1 axes and the chosen state dtype."""

    def one(s: ParamSpec) -> ParamSpec:
        z = zero1_spec(s, data_size, zero1, rules)
        return ParamSpec(z.shape, z.axes, "zeros", None, dtype)

    m = jax.tree.map(one, param_specs, is_leaf=is_spec)
    return m, jax.tree.map(lambda s: s, m, is_leaf=is_spec)


def _stochastic_bf16(x: jax.Array, key: jax.Array) -> jax.Array:
    """Unbiased f32 -> bf16 stochastic rounding via mantissa-noise truncation."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    noise = jax.random.randint(key, x.shape, 0, 1 << 16, dtype=jnp.uint32)
    rounded = (bits + noise) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(rounded, jnp.float32).astype(jnp.bfloat16)


def adamw_update(
    params,
    grads,
    opt: AdamState,
    *,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: Optional[float] = 1.0,
    state_dtype=jnp.float32,
    sr_key: Optional[jax.Array] = None,
) -> tuple[dict, AdamState]:
    step = opt.step + 1
    if grad_clip is not None:
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(opt.m)
    leaves_v = treedef.flatten_up_to(opt.v)
    if sr_key is not None:
        keys = jax.random.split(sr_key, len(leaves_p))
    new_p, new_m, new_v = [], [], []
    for i, (p, g, m, v) in enumerate(zip(leaves_p, leaves_g, leaves_m, leaves_v)):
        mf = m.astype(jnp.float32) * b1 + (1 - b1) * g
        vf = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        upd = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (upd + weight_decay * pf)
        if p.dtype == jnp.bfloat16 and sr_key is not None:
            new_p.append(_stochastic_bf16(pf, keys[i]))
        else:
            new_p.append(pf.astype(p.dtype))
        new_m.append(mf.astype(state_dtype))
        new_v.append(vf.astype(state_dtype))
    return (
        jax.tree.unflatten(treedef, new_p),
        AdamState(
            m=jax.tree.unflatten(treedef, new_m),
            v=jax.tree.unflatten(treedef, new_v),
            step=step,
        ),
    )


def lr_schedule(step: jax.Array, *, peak: float = 3e-4, warmup: int = 100,
                total: int = 10000, min_ratio: float = 0.1) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = peak * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)

"""Training loop: jitted train_step factory with sharded state, plus the
host-side loop with fault tolerance (checkpoint/restart, straggler watchdog).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import lm, params as pm
from repro.train import optimizer as opt_mod
from repro.train.optimizer import AdamState, TrainState


@dataclasses.dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    zero1: bool = True
    log_every: int = 10
    checkpoint_every: int = 200
    seed: int = 0


def state_specs(cfg, train_cfg: TrainConfig, rules: Optional[shd.ShardingRules]):
    """(param_specs, m_specs, v_specs) with ZeRO-1 applied when a mesh is active."""
    pspecs = lm.model_specs(cfg)
    data_size = 1
    if rules is not None:
        data_size = rules.mesh.shape.get("data", 1)
    dtype = jnp.bfloat16 if cfg.optimizer_dtype == "bfloat16" else jnp.float32
    m_specs, v_specs = opt_mod.opt_specs(
        pspecs, dtype=dtype, data_size=data_size,
        zero1=train_cfg.zero1 and cfg.zero1, rules=rules
    )
    return pspecs, m_specs, v_specs


def init_state(cfg, train_cfg: TrainConfig, key: jax.Array) -> TrainState:
    pspecs, m_specs, v_specs = state_specs(cfg, train_cfg, None)
    params = pm.init(pspecs, key)
    zeros = lambda specs: pm.init(specs, key)  # init=zeros for opt specs
    return TrainState(
        params=params,
        opt=AdamState(m=zeros(m_specs), v=zeros(v_specs), step=jnp.zeros((), jnp.int32)),
    )


def make_train_step(cfg, train_cfg: TrainConfig,
                    rules: Optional[shd.ShardingRules] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).  Pure; jit it with
    in_shardings derived from state_specs when running on a mesh."""

    state_dtype = jnp.bfloat16 if cfg.optimizer_dtype == "bfloat16" else jnp.float32
    use_sr = cfg.optimizer_dtype == "bfloat16"
    mb = max(1, cfg.microbatches)

    def _loss_and_grads(params, batch):
        if mb == 1:
            return jax.value_and_grad(lm.loss_fn)(params, cfg, batch)

        # gradient accumulation: scan over microbatches; accumulators live in
        # the parameter dtype (bf16 for the 1T tier) so peak memory stays at
        # one microbatch of activations + one grad copy.
        def split(x):
            return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])

        micro_batches = jax.tree.map(split, batch)

        def micro(carry, mb_batch):
            acc_loss, acc_g = carry
            loss_i, g_i = jax.value_and_grad(lm.loss_fn)(params, cfg, mb_batch)
            acc_g = jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc_g, g_i)
            return (acc_loss + loss_i, acc_g), None

        zeros = jax.tree.map(jnp.zeros_like, params)
        (loss_sum, grads), _ = jax.lax.scan(micro, (jnp.zeros(()), zeros), micro_batches)
        return loss_sum / mb, jax.tree.map(lambda g: g / mb, grads)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        with shd.use_rules(rules):
            loss, grads = _loss_and_grads(state.params, batch)
            if cfg.grad_dtype == "bfloat16":
                # gradient compression for the data-parallel all-reduce
                grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
            lr = opt_mod.lr_schedule(
                state.opt.step, peak=train_cfg.lr, warmup=train_cfg.warmup,
                total=train_cfg.total_steps,
            )
            sr_key = (
                jax.random.fold_in(jax.random.PRNGKey(train_cfg.seed), state.opt.step)
                if use_sr else None
            )
            new_params, new_opt = opt_mod.adamw_update(
                state.params, grads, state.opt,
                lr=lr, weight_decay=train_cfg.weight_decay,
                grad_clip=train_cfg.grad_clip, state_dtype=state_dtype, sr_key=sr_key,
            )
            metrics = {"loss": loss, "lr": lr, "step": new_opt.step}
            return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def batch_shardings(cfg, rules: Optional[shd.ShardingRules]):
    if rules is None:
        return None
    bspec = rules.sharding(("batch", None))
    out = {"tokens": bspec, "labels": bspec}
    if cfg.is_encdec:
        out["src_frames"] = rules.sharding(("batch", None, None))
    return out


def jit_train_step(cfg, train_cfg: TrainConfig, rules: shd.ShardingRules):
    """jit with explicit in/out shardings (the dry-run entry point)."""
    pspecs, m_specs, v_specs = state_specs(cfg, train_cfg, rules)
    state_sh = TrainState(
        params=pm.shardings(pspecs, rules),
        opt=AdamState(
            m=pm.shardings(m_specs, rules),
            v=pm.shardings(v_specs, rules),
            step=jax.sharding.NamedSharding(rules.mesh, jax.sharding.PartitionSpec()),
        ),
    )
    step_fn = make_train_step(cfg, train_cfg, rules)
    return (
        jax.jit(
            step_fn,
            in_shardings=(state_sh, batch_shardings(cfg, rules)),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        ),
        state_sh,
        (pspecs, m_specs, v_specs),
    )


# --------------------------------------------------------------------- #
# host-side loop with fault tolerance
# --------------------------------------------------------------------- #
def run(
    cfg,
    train_cfg: TrainConfig,
    data_iter,
    *,
    state: Optional[TrainState] = None,
    ckpt_manager=None,
    watchdog=None,
    hooks: Optional[list[Callable[[int, dict], None]]] = None,
) -> tuple[TrainState, list[dict]]:
    """Simple single-host loop (multi-host launch wires the same step through
    jit_train_step).  Resumes from ckpt_manager when a checkpoint exists."""
    step_fn = jax.jit(make_train_step(cfg, train_cfg))
    start_step = 0
    if state is None:
        state = init_state(cfg, train_cfg, jax.random.PRNGKey(train_cfg.seed))
    if ckpt_manager is not None:
        restored = ckpt_manager.restore_latest(state)
        if restored is not None:
            state, start_step = restored
            data_iter.seek(start_step)
    history = []
    for step in range(start_step, train_cfg.total_steps):
        batch = data_iter.next_batch()
        t0 = time.monotonic()
        state, metrics = step_fn(state, batch)
        if watchdog is not None:
            watchdog.record(step, time.monotonic() - t0)
        if step % train_cfg.log_every == 0 or step == train_cfg.total_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["wall_s"] = time.monotonic() - t0
            history.append(m)
            for h in hooks or []:
                h(step, m)
        if ckpt_manager is not None and (step + 1) % train_cfg.checkpoint_every == 0:
            ckpt_manager.save(state, step + 1, data_state=data_iter.state_dict())
    return state, history

"""Multi-epoch online-learning driver on the fused column-event plane.

The deployment story of Sec 4.4.1: a converted SNN ships with frozen hidden
tiles and adapts its readout on-device through supervised stochastic STDP,
every weight update a column access through the transposable port.  This
driver scales that loop to real batch counts:

* the frozen prefix runs ONCE through a compiled execution plan
  (``EsamNetwork.plan(mode="prefix")`` — the packed fused datapath) and is
  reused across every epoch — the hidden tiles never learn, so their
  activations never change;
* the last-layer bits stay transposed-resident (``{0,1}[n_out, n_in]``)
  across epochs, fed straight back into ``learning.column_event_epoch``
  whose donated carry updates them in place;
* accuracy is tracked per epoch from the resident layout (one readout
  matvec, no re-transposition), and checkpoints are written through
  ``repro.checkpoint.io`` in the network's native ``[n_in, n_out]`` layout so
  they stay compatible with ``EsamNetwork`` consumers and resume.

Run the example: ``PYTHONPATH=src python examples/online_learning.py``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.esam import faults as faults_mod
from repro.core.esam import learning
from repro.core.esam.network import EsamNetwork


@jax.jit
def _readout_accuracy(bits_t, pre, labels, out_offset):
    """argmax accuracy of the transposed-resident readout on (pre, labels)."""
    logits = learning.readout_vmem(bits_t, pre).astype(jnp.float32) + out_offset
    return (jnp.argmax(logits, -1) == labels).mean()


@dataclasses.dataclass
class OnlineTrainResult:
    network: EsamNetwork        # prefix unchanged, learned last tile swapped in
    accuracy: list[float]       # eval accuracy after each epoch run
    n_updates: list[int]        # column updates per epoch (feeds the cost model)
    start_epoch: int            # 0, or where a resumed run picked up
    epochs_run: int


def _checkpoint_tree(network: EsamNetwork, bits_t: jax.Array) -> dict:
    return {"weight_bits": list(network.weight_bits[:-1]) + [bits_t.T]}


def train_online(
    network: EsamNetwork,
    spikes: jax.Array,           # bool[batch, n_in]
    labels: jax.Array,           # int32[batch]
    *,
    epochs: int = 5,
    key: jax.Array | None = None,
    p_pot: float = 0.12,
    p_dep: float = 0.06,
    eval_spikes: jax.Array | None = None,
    eval_labels: jax.Array | None = None,
    shuffle: bool = False,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 0,
    resume: bool = False,
    interpret: bool | None = None,
    faults: faults_mod.FaultModel | None = None,
    observability=None,
) -> OnlineTrainResult:
    """Supervised-STDP training of the readout tile over multiple epochs.

    Evaluation defaults to the training set when no eval split is given.
    ``shuffle=True`` permutes the sample order per epoch (keyed off the epoch
    key, deterministic).  With ``checkpoint_dir`` set, the full weight list is
    checkpointed every ``checkpoint_every`` epochs (and at the end);
    ``resume=True`` restarts from the latest step found there.

    ``faults`` turns the loop into the *online-learning repair* mitigation:
    the frozen prefix runs through a faulted plan (the hidden activations
    are what a damaged array would actually emit — dead columns included),
    and the learned readout state is clamped through the last tile's fault
    masks between epochs (``faults.clamp_readout_t``: writes into stuck
    cells don't take, reads see the disturb flips), so the per-epoch
    accuracy is the accuracy the faulted hardware would really recover.
    The returned network carries the *programmed* bits — evaluate it under
    the same ``FaultModel`` (``network.plan(..., faults=...)``) for the
    deployed faulted accuracy.

    ``observability`` (an :class:`repro.obs.Observability`) traces each
    epoch as a complete span (accuracy/updates in args) and books per-epoch
    wall time, column updates, and the latest accuracy into the registry —
    off by default, and inert for the math (spans observe, never perturb).
    """
    from repro.checkpoint import io as ckpt_io

    tracer = observability.tracer if observability is not None else None
    metrics = observability.metrics if observability is not None else None
    import time as _time

    if key is None:
        key = jax.random.PRNGKey(0)
    if (eval_spikes is None) != (eval_labels is None):
        raise ValueError("eval_spikes and eval_labels must be given together")
    spikes = jnp.asarray(spikes).astype(bool)
    labels = jnp.asarray(labels)
    # one compiled prefix plan, reused for train and eval splits; with a
    # FaultModel the prefix is the faulted executable (same seed => same
    # masks as any other plan built from this model)
    prefix_plan = network.plan(mode="prefix", interpret=interpret,
                               faults=faults)
    n_pre = network.topology[-2]
    fault_masks = None
    if faults is not None:
        fault_masks = faults.build_masks(network.topology, (4,))

    def clamp(bt):
        if fault_masks is None:
            return bt
        return faults_mod.clamp_readout_t(bt, fault_masks, 4)

    def run_prefix(x):
        out = prefix_plan(x).prefix
        if prefix_plan.prefix_packed:
            from repro.core import packing

            out = packing.unpack_spikes(out, n_pre, dtype=jnp.bool_)
        return out

    pre = run_prefix(spikes)
    if eval_spikes is None:
        eval_pre, eval_labels = pre, labels
    else:
        eval_pre = run_prefix(jnp.asarray(eval_spikes).astype(bool))
        eval_labels = jnp.asarray(eval_labels)

    bits_t = jnp.asarray(network.weight_bits[-1]).T
    start_epoch = 0
    if resume and checkpoint_dir is not None:
        step = ckpt_io.latest_step(checkpoint_dir)
        if step is not None:
            restored, _ = ckpt_io.restore(
                _checkpoint_tree(network, bits_t), checkpoint_dir, step)
            bits_t = jnp.asarray(restored["weight_bits"][-1]).T
            start_epoch = step

    n_samples = int(spikes.shape[0])
    accuracy: list[float] = []
    n_updates: list[int] = []
    for epoch in range(start_epoch, epochs):
        ep_t0 = tracer.now_us() if tracer is not None else 0.0
        ep_wall0 = _time.perf_counter() if observability is not None else 0.0
        ep_key = jax.random.fold_in(key, epoch)
        if shuffle:
            # sample draws fold in indices 0..n_samples-1; n_samples is free
            perm = jax.random.permutation(
                jax.random.fold_in(ep_key, n_samples), n_samples)
            x_e, y_e = pre[perm], labels[perm]
        else:
            x_e, y_e = pre, labels
        # learning events target the deployed readout: the wrong winner is the
        # argmax of the offset-shifted logits, matching _readout_accuracy and
        # EsamNetwork.forward
        if fault_masks is None:
            bits_t, n = learning.column_event_epoch(
                bits_t, x_e, y_e, ep_key,
                p_pot=float(p_pot), p_dep=float(p_dep),
                out_offset=network.out_offset, interpret=interpret)
            eval_bits = bits_t
        else:
            # bits_t holds the *programmed* state; the epoch reads and
            # writes the *effective* (clamped) state the array exposes.
            # Writes that landed (effective bit changed) are folded back
            # into the programmed state — a write into a stuck cell is
            # silently dropped, exactly like the hardware.  clamp() is a
            # pure function of static masks, so recomputing it after the
            # donated epoch call is exact.
            eff, n = learning.column_event_epoch(
                clamp(bits_t), x_e, y_e, ep_key,
                p_pot=float(p_pot), p_dep=float(p_dep),
                out_offset=network.out_offset, interpret=interpret)
            bits_t = jnp.where(eff != clamp(bits_t), eff, bits_t)
            eval_bits = clamp(bits_t)
        acc = _readout_accuracy(
            eval_bits, eval_pre, eval_labels, network.out_offset)
        accuracy.append(float(acc))
        n_updates.append(int(n))
        if tracer is not None:
            tracer.complete("train_epoch", ep_t0, tracer.now_us() - ep_t0,
                            cat="train", epoch=epoch,
                            accuracy=accuracy[-1], n_updates=n_updates[-1])
        if metrics is not None:
            metrics.counter(
                "esam_train_epochs_total",
                "online-learning epochs completed").inc()
            metrics.counter(
                "esam_train_column_updates_total",
                "STDP column updates applied").inc(n_updates[-1])
            metrics.gauge(
                "esam_train_accuracy",
                "readout accuracy after the latest epoch").set(accuracy[-1])
            metrics.histogram(
                "esam_train_epoch_seconds",
                "wall time per online-learning epoch").observe(
                    _time.perf_counter() - ep_wall0)
        at_end = epoch + 1 == epochs
        if checkpoint_dir is not None and (
            at_end or (checkpoint_every and (epoch + 1) % checkpoint_every == 0)
        ):
            ckpt_io.save(
                _checkpoint_tree(network, bits_t), checkpoint_dir, epoch + 1,
                extra={"accuracy": accuracy[-1], "n_updates": n_updates[-1]})

    new_net = dataclasses.replace(
        network,
        weight_bits=list(network.weight_bits[:-1]) + [bits_t.T],
    )
    return OnlineTrainResult(
        network=new_net,
        accuracy=accuracy,
        n_updates=n_updates,
        start_epoch=start_epoch,
        epochs_run=len(accuracy),
    )

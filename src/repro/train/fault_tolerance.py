"""Fault tolerance: checkpoint manager, straggler watchdog, elastic replan.

Design notes for the 1000+-node target:
  * CheckpointManager — periodic async sharded saves (atomic renames), keep-K
    pruning, resume discovery; the data-pipeline cursor rides in the manifest
    so restarts are bit-exact.
  * StragglerWatchdog — per-step wall-time EMA; steps slower than
    ``threshold x`` EMA are flagged.  On a real fleet the flags feed the
    coordinator that re-schedules the slow host; here the hook is exercised by
    tests and the example driver.
  * elastic_replan — maps a surviving-chip count to the nearest valid mesh and
    the restore path is a plain device_put re-shard (checkpoint/io.restore),
    so scale-down restarts reuse the same artifacts.
  * RetryPolicy — exponential backoff with counter-based seeded jitter (a
    splitmix64 hash of (seed, counter), no wall-clock RNG anywhere in the
    datapath) and a per-attempt timeout, consumed by the serving plane's
    ``FaultAwareRouter`` to re-route requests off crashed/stalled replicas.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import io as ckpt_io


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._pending = None

    def save(self, state, step: int, *, data_state: Optional[dict] = None):
        self.wait()
        self._pending = ckpt_io.save(
            state, self.directory, step,
            extra={"data_state": data_state or {}}, async_=self.async_save,
        )
        ckpt_io.prune_old(self.directory, self.keep)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, state_like, *, shardings=None):
        step = ckpt_io.latest_step(self.directory)
        if step is None:
            return None
        restored, manifest = ckpt_io.restore(
            state_like, self.directory, step, shardings=shardings
        )
        return restored, step

    def restore_data_state(self) -> Optional[dict]:
        """Data-pipeline cursor from the latest manifest, or ``None``.

        A missing or truncated ``manifest.json`` (crash mid-save of a
        non-atomic copy, partial rsync) degrades to a fresh data cursor
        instead of crashing the restart path.
        """
        step = ckpt_io.latest_step(self.directory)
        if step is None:
            return None
        import json, os
        path = os.path.join(self.directory, f"step_{step:08d}", "manifest.json")
        try:
            with open(path) as f:
                return json.load(f)["extra"].get("data_state")
        except (FileNotFoundError, json.JSONDecodeError, KeyError):
            return None


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 2.0
    ema_decay: float = 0.9
    warmup_steps: int = 3
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    _ema: float = dataclasses.field(default=0.0, init=False)
    _n: int = dataclasses.field(default=0, init=False)
    flagged: list = dataclasses.field(default_factory=list, init=False)

    def record(self, step: int, wall_s: float):
        self._n += 1
        if self._n <= self.warmup_steps:
            # seed the EMA on early steps (skip compile-dominated step 0 bias
            # by averaging rather than trusting the first sample)
            self._ema = wall_s if self._n == 1 else 0.5 * (self._ema + wall_s)
            return
        if wall_s > self.threshold * self._ema:
            self.flagged.append((step, wall_s, self._ema))
            if self.on_straggler:
                self.on_straggler(step, wall_s, self._ema)
        self._ema = self.ema_decay * self._ema + (1 - self.ema_decay) * wall_s


def _splitmix64(x: int) -> int:
    """One splitmix64 round: a strong 64-bit integer mix (pure int math)."""
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return x ^ (x >> 31)


def counter_uniform(seed: int, counter: int) -> float:
    """Deterministic uniform in [0, 1) from (seed, counter) — the serving
    plane's jitter source: counter-based like the STDP RNG, so retries are
    reproducible and no wall-clock entropy enters the datapath."""
    return _splitmix64(_splitmix64(seed) ^ counter) / 2.0 ** 64


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry/backoff knobs for re-routing requests across serving replicas.

    ``backoff_s(attempt, counter)`` is the sleep before retry number
    ``attempt`` (1-based): exponential in the attempt, capped at
    ``max_backoff_s``, jittered by ``jitter`` (fractional, symmetric) using
    the counter-based uniform above.  ``attempt_timeout_s`` bounds one
    replica drain — a drain exceeding it marks the replica slow so the
    router steers subsequent traffic away.
    """

    max_attempts: int = 3
    base_backoff_s: float = 0.01
    backoff_multiplier: float = 2.0
    max_backoff_s: float = 1.0
    jitter: float = 0.5
    seed: int = 0
    attempt_timeout_s: Optional[float] = None

    def backoff_s(self, attempt: int, counter: int) -> float:
        base = min(
            self.base_backoff_s * self.backoff_multiplier ** max(0, attempt - 1),
            self.max_backoff_s,
        )
        u = counter_uniform(self.seed, counter)
        return base * (1.0 + self.jitter * (2.0 * u - 1.0))


class ReplanResult(tuple):
    """``((data, model), ("data", "model"))`` — equality-compatible with the
    historical plain 2-tuple — plus ``dropped_chips``: how many surviving
    chips the replanned mesh leaves idle as spares (non-dividing
    ``model_parallel`` and/or the power-of-two data rounding)."""

    dropped_chips: int

    def __new__(cls, mesh_shape, axis_names, dropped_chips):
        self = super().__new__(cls, (mesh_shape, axis_names))
        self.dropped_chips = int(dropped_chips)
        return self


def elastic_replan(n_chips: int, *, model_parallel: int = 16) -> ReplanResult:
    """Largest valid (data, model) mesh within the surviving chip count.

    Model parallelism is pinned (weights must still fit); the data axis
    absorbs the loss.  ``model_parallel`` need not divide ``n_chips``: the
    leftover chips stay idle as hot spares, and the count is documented in
    the returned :class:`ReplanResult`'s ``dropped_chips`` (0 on a clean
    power-of-two fit).  1000+-node note: on multi-pod meshes the pod axis
    shrinks first (whole-pod failure domain), then data.
    """
    if n_chips < model_parallel:
        raise ValueError(f"need >= {model_parallel} chips, have {n_chips}")
    data = n_chips // model_parallel
    # largest power-of-two data axis keeps batch divisibility
    data = 2 ** int(math.log2(data))
    return ReplanResult((data, model_parallel), ("data", "model"),
                        n_chips - data * model_parallel)


def simulate_failure_and_resume(state, manager: CheckpointManager, step: int):
    """Test helper: persist, 'crash', and restore into a fresh process-like
    state (exercised by tests/test_fault_tolerance.py)."""
    manager.save(state, step)
    manager.wait()
    zeroed = jax.tree.map(lambda a: np.zeros_like(a), state)
    return manager.restore_latest(zeroed)

"""Pallas TPU kernel: transposed-port online-learning update (stochastic STDP).

Hardware mapping (Sec 3.2 / 4.4.1): the transposable column RW port makes
"update every synapse of one learning neuron" a contiguous access.  On TPU the
"port" is a *layout* decision: weights are stored transposed ([N_out, N_in],
one learning neuron's synapses = one contiguous row of lanes), so the learning
write is a dense row-masked VMEM update instead of a strided scatter — the
memory-system analogue of the dedicated column port.

The stochastic potentiate/depress draws ([16]) enter as precomputed uniforms
so the kernel is deterministic and bit-exact against ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import default_interpret


def _stdp_kernel(bits_ref, pre_ref, post_ref, upot_ref, udep_ref, out_ref,
                 *, p_pot: float, p_dep: float):
    bits = bits_ref[...]
    pre = pre_ref[...].astype(bool)        # [1, bn_in]
    post = post_ref[...].astype(bool)      # [bm_out, 1]
    potentiate = post & pre & (upot_ref[...] < p_pot)
    depress = post & ~pre & (udep_ref[...] < p_dep)
    out_ref[...] = jnp.where(potentiate, 1, jnp.where(depress, 0, bits)).astype(bits.dtype)


@functools.partial(
    jax.jit, static_argnames=("p_pot", "p_dep", "block_out", "block_in", "interpret")
)
def stdp_update(
    bits_t: jax.Array,   # {0,1}[N_out, N_in] transposed weight layout
    pre: jax.Array,      # {0,1}[N_in]
    post: jax.Array,     # {0,1}[N_out]
    u_pot: jax.Array,    # float32[N_out, N_in]
    u_dep: jax.Array,    # float32[N_out, N_in]
    *,
    p_pot: float,
    p_dep: float,
    block_out: int = 8,
    block_in: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns the updated transposed weight bits, int8[N_out, N_in]."""
    if interpret is None:
        interpret = default_interpret()
    n_out, n_in = bits_t.shape
    bm, bn = min(block_out, n_out), min(block_in, n_in)
    assert n_out % bm == 0 and n_in % bn == 0
    grid = (n_out // bm, n_in // bn)
    return pl.pallas_call(
        functools.partial(_stdp_kernel, p_pot=p_pot, p_dep=p_dep),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_out, n_in), bits_t.dtype),
        interpret=interpret,
    )(bits_t, pre[None, :], post[:, None], u_pot, u_dep)


def _column_event_kernel(idx_ref, bits_ref, pre_ref, upot_ref, udep_ref, out_ref,
                         *, p_pot: float, p_dep: float):
    bits = bits_ref[...]                       # [1, bn] — the event column only
    pre = pre_ref[...].astype(bool)
    apply = idx_ref[1] > 0
    potentiate = pre & (upot_ref[...] < p_pot)
    depress = jnp.logical_not(pre) & (udep_ref[...] < p_dep)
    new = jnp.where(potentiate, 1, jnp.where(depress, 0, bits)).astype(bits.dtype)
    out_ref[...] = jnp.where(apply, new, bits)


@functools.partial(
    jax.jit, static_argnames=("p_pot", "p_dep", "block_in", "interpret")
)
def stdp_column_event(
    bits_t: jax.Array,   # {0,1}[N_out, N_in] transposed weight layout
    col: jax.Array,      # int32[] — the learning neuron (one column port access)
    apply: jax.Array,    # bool[] — gate; the write is suppressed when False
    pre: jax.Array,      # {0,1}[N_in] pre-synaptic activity trace
    u_pot: jax.Array,    # float32[N_in]
    u_dep: jax.Array,    # float32[N_in]
    *,
    p_pot: float,
    p_dep: float,
    block_in: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Blocked column write: update ONE learning neuron's synapses in place.

    The grid covers only the event column's ``N_in`` synapses (selected by a
    scalar-prefetched row index into the transposed-resident layout); every
    other weight stays untouched through ``input_output_aliases`` — the TPU
    rendering of the 2x4-cycle transposable-port column RMW (Sec 4.4.1),
    instead of rewriting the full ``[N_in, N_out]`` matrix per event.
    """
    if interpret is None:
        interpret = default_interpret()
    n_out, n_in = bits_t.shape
    # largest block <= block_in that divides n_in (keeps the grid small for
    # widths that share few factors with block_in)
    bn = next(b for b in range(min(block_in, n_in), 0, -1) if n_in % b == 0)
    idx = jnp.stack([jnp.asarray(col, jnp.int32), apply.astype(jnp.int32)])
    return pl.pallas_call(
        functools.partial(_column_event_kernel, p_pot=p_pot, p_dep=p_dep),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_in // bn,),
            in_specs=[
                pl.BlockSpec((1, bn), lambda j, idx: (idx[0], j)),
                pl.BlockSpec((1, bn), lambda j, idx: (0, j)),
                pl.BlockSpec((1, bn), lambda j, idx: (0, j)),
                pl.BlockSpec((1, bn), lambda j, idx: (0, j)),
            ],
            out_specs=pl.BlockSpec((1, bn), lambda j, idx: (idx[0], j)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_out, n_in), bits_t.dtype),
        input_output_aliases={1: 0},   # bits_t buffer is the output buffer
        interpret=interpret,
    )(idx, bits_t, pre.astype(jnp.int8)[None, :], u_pot[None, :], u_dep[None, :])

"""Pallas TPU kernel: transposed-port online-learning update (stochastic STDP).

Hardware mapping (Sec 3.2 / 4.4.1): the transposable column RW port makes
"update every synapse of one learning neuron" a contiguous access.  On TPU the
"port" is a *layout* decision: weights are stored transposed ([N_out, N_in],
one learning neuron's synapses = one contiguous row of lanes), so the learning
write is a dense row-masked VMEM update instead of a strided scatter — the
memory-system analogue of the dedicated column port.

The stochastic potentiate/depress draws ([16]) enter as precomputed uniforms
so the kernel is deterministic and bit-exact against ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret


def _stdp_kernel(bits_ref, pre_ref, post_ref, upot_ref, udep_ref, out_ref,
                 *, p_pot: float, p_dep: float):
    bits = bits_ref[...]
    pre = pre_ref[...].astype(bool)        # [1, bn_in]
    post = post_ref[...].astype(bool)      # [bm_out, 1]
    potentiate = post & pre & (upot_ref[...] < p_pot)
    depress = post & ~pre & (udep_ref[...] < p_dep)
    out_ref[...] = jnp.where(potentiate, 1, jnp.where(depress, 0, bits)).astype(bits.dtype)


@functools.partial(
    jax.jit, static_argnames=("p_pot", "p_dep", "block_out", "block_in", "interpret")
)
def stdp_update(
    bits_t: jax.Array,   # {0,1}[N_out, N_in] transposed weight layout
    pre: jax.Array,      # {0,1}[N_in]
    post: jax.Array,     # {0,1}[N_out]
    u_pot: jax.Array,    # float32[N_out, N_in]
    u_dep: jax.Array,    # float32[N_out, N_in]
    *,
    p_pot: float,
    p_dep: float,
    block_out: int = 8,
    block_in: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns the updated transposed weight bits, int8[N_out, N_in]."""
    if interpret is None:
        interpret = default_interpret()
    n_out, n_in = bits_t.shape
    bm, bn = min(block_out, n_out), min(block_in, n_in)
    assert n_out % bm == 0 and n_in % bn == 0
    grid = (n_out // bm, n_in // bn)
    return pl.pallas_call(
        functools.partial(_stdp_kernel, p_pot=p_pot, p_dep=p_dep),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_out, n_in), bits_t.dtype),
        interpret=interpret,
    )(bits_t, pre[None, :], post[:, None], u_pot, u_dep)

"""Jit'd public wrappers for the STDP kernels.

``stdp_update`` is the full-matrix transposed-layout update (one masked
rewrite of the whole tile); ``stdp_column_event`` is the column-event form the
online-learning plane actually issues — one learning neuron per call, grid
over that column's synapses only (see kernel.py).  Both are validated
bit-exact against the ref.py oracles and the functional rule in
``core.esam.learning`` under shared uniforms.
"""

from repro.kernels.stdp.kernel import stdp_column_event, stdp_update
from repro.kernels.stdp.ref import stdp_column_event_ref, stdp_update_ref

__all__ = [
    "stdp_update",
    "stdp_update_ref",
    "stdp_column_event",
    "stdp_column_event_ref",
]

"""Jit'd public wrappers for the STDP kernel."""

from repro.kernels.stdp.kernel import stdp_update
from repro.kernels.stdp.ref import stdp_update_ref

__all__ = ["stdp_update", "stdp_update_ref"]

"""Pure-jnp oracle for the transposed STDP column-update kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stdp_update_ref(
    bits_t: jax.Array,    # {0,1}[N_out, N_in] — column-major ("transposed") layout
    pre: jax.Array,       # {0,1}[N_in]
    post: jax.Array,      # {0,1}[N_out] — learning events
    u_pot: jax.Array,     # float[N_out, N_in] uniforms
    u_dep: jax.Array,     # float[N_out, N_in] uniforms
    p_pot: float,
    p_dep: float,
) -> jax.Array:
    """Stochastic 1-bit STDP on the transposed weight layout."""
    post_m = post.astype(bool)[:, None]
    pre_m = pre.astype(bool)[None, :]
    potentiate = post_m & pre_m & (u_pot < p_pot)
    depress = post_m & ~pre_m & (u_dep < p_dep)
    new = jnp.where(potentiate, 1, jnp.where(depress, 0, bits_t))
    return new.astype(bits_t.dtype)


def stdp_column_event_ref(
    bits_t: jax.Array,    # {0,1}[N_out, N_in] transposed weight layout
    col: jax.Array,       # int32[] — index of the learning neuron (one column)
    apply: jax.Array,     # bool[] — gate; identity when False
    pre: jax.Array,       # {0,1}[N_in] pre-synaptic activity trace
    u_pot: jax.Array,     # float[N_in] uniforms for potentiation
    u_dep: jax.Array,     # float[N_in] uniforms for depression
    p_pot: float,
    p_dep: float,
) -> jax.Array:
    """One column event: stochastic STDP applied to a single learning neuron.

    Only row ``col`` of the transposed layout (= one weight column, all
    synapses of one post neuron) may change — the column-port access pattern.
    """
    old = bits_t[col]
    pre_m = pre.astype(bool)
    potentiate = pre_m & (u_pot < p_pot)
    depress = ~pre_m & (u_dep < p_dep)
    new = jnp.where(potentiate, 1, jnp.where(depress, 0, old)).astype(bits_t.dtype)
    new = jnp.where(apply, new, old)
    return bits_t.at[col].set(new)

"""Pure-jnp oracle for the transposed STDP column-update kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stdp_update_ref(
    bits_t: jax.Array,    # {0,1}[N_out, N_in] — column-major ("transposed") layout
    pre: jax.Array,       # {0,1}[N_in]
    post: jax.Array,      # {0,1}[N_out] — learning events
    u_pot: jax.Array,     # float[N_out, N_in] uniforms
    u_dep: jax.Array,     # float[N_out, N_in] uniforms
    p_pot: float,
    p_dep: float,
) -> jax.Array:
    """Stochastic 1-bit STDP on the transposed weight layout."""
    post_m = post.astype(bool)[:, None]
    pre_m = pre.astype(bool)[None, :]
    potentiate = post_m & pre_m & (u_pot < p_pot)
    depress = post_m & ~pre_m & (u_dep < p_dep)
    new = jnp.where(potentiate, 1, jnp.where(depress, 0, bits_t))
    return new.astype(bits_t.dtype)

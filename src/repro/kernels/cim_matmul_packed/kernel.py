"""Pallas TPU kernels: bit-packed binary CIM MAC (+ fused IF fire + re-pack).

The paper's tiles exchange spikes as parallel single-bit pulses (Sec 3.1); the
packed kernel family is the TPU rendering of that wire: spikes arrive from HBM
as uint32 bitplanes (32 spikes per lane word, LSB-first — see
``repro.core.packing``), are unpacked *in VMEM* with shifts/masks on the VPU,
and feed the MXU exactly like the unpacked ``cim_matmul``.  HBM spike traffic
drops 32x vs f32 spikes (8x vs the int8 wire) while the MAC schedule, block
shapes, and results stay bit-identical.

The fused variant additionally re-packs the fired output spikes before the
store, so a cascade of tiles (``EsamNetwork.forward_fused``) moves *only*
packed words between layers — the inter-tile pulse bus, end to end.

Grid/block layout mirrors ``cim_matmul``: grid (B/bm, N/bn, K/bk) with K
innermost and an f32 VMEM accumulator; the spike operand block is
(bm, bk/32) uint32 rather than (bm, bk).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import LANE_BITS


def unpack_bits_block(packed: jax.Array) -> jax.Array:
    """(bm, bkw) uint32 -> (bm, bkw*32) bf16 {0,1}; VPU shifts + masks only."""
    bm, bkw = packed.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, bkw, LANE_BITS), 2)
    bits = (packed[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(bm, bkw * LANE_BITS).astype(jnp.bfloat16)


def pack_bits_block(fired: jax.Array) -> jax.Array:
    """(bm, bn) bool -> (bm, bn/32) uint32 — the fire-stage re-pack."""
    bm, bn = fired.shape
    bnw = bn // LANE_BITS
    b = fired.reshape(bm, bnw, LANE_BITS).astype(jnp.uint32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, LANE_BITS), 2)
    # distinct powers of two: the sum is an exact bitwise OR
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def mac_packed_kernel(s_ref, w_ref, out_ref, acc_ref, *, n_k: int):
    """grid = (B/bm, N/bn, K/bk); K innermost.  s_ref holds packed words."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    spikes = unpack_bits_block(s_ref[...])
    w = (2.0 * w_ref[...].astype(jnp.bfloat16) - 1.0)
    acc_ref[...] += jax.lax.dot_general(
        spikes, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(jnp.int32)


def fused_fire_packed_kernel(
    s_ref, w_ref, vth_ref, out_ref, acc_ref, *, n_k: int, pack_output: bool
):
    """Packed MAC with the IF threshold compare fused in the epilogue; when
    ``pack_output`` the fired spikes leave the kernel already bit-packed, so
    V_mem *and* the unpacked spike tensor never exist in HBM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    spikes = unpack_bits_block(s_ref[...])
    w = (2.0 * w_ref[...].astype(jnp.bfloat16) - 1.0)
    acc_ref[...] += jax.lax.dot_general(
        spikes, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _fire():
        vmem = acc_ref[...].astype(jnp.int32)
        fired = vmem >= vth_ref[...]
        if pack_output:
            out_ref[...] = pack_bits_block(fired)
        else:
            out_ref[...] = fired.astype(jnp.int8)

"""Pure-jnp oracles for the packed CIM MAC: unpack, then the unpacked oracle."""

from __future__ import annotations

import jax

from repro.core import packing
from repro.kernels.cim_matmul.ref import cim_matmul_ref, esam_layer_ref


def cim_matmul_packed_ref(packed: jax.Array, weight_bits: jax.Array) -> jax.Array:
    """V_mem int32[B, N] from uint32 bitplanes [B, ceil(K/32)]."""
    spikes = packing.unpack_spikes(packed, weight_bits.shape[0])
    return cim_matmul_ref(spikes, weight_bits)


def esam_layer_packed_ref(
    packed: jax.Array,
    weight_bits: jax.Array,
    vth: jax.Array,
    *,
    pack_output: bool = True,
) -> jax.Array:
    """Fused-fire oracle; packed output when ``pack_output``."""
    spikes = packing.unpack_spikes(packed, weight_bits.shape[0])
    out = esam_layer_ref(spikes, weight_bits, vth)
    return packing.pack_spikes(out) if pack_output else out

"""Jit'd public wrappers for the packed CIM MAC kernels.

These wrappers own the padding contract: the caller hands in the natural
shapes (B samples, K pre-neurons packed into ceil(K/32) words, N post
neurons) and the wrapper zero-pads B up to a block multiple and K up to a
packed block multiple.  Zero padding is exact for the binary CIM MAC — a
silent spike contributes nothing whatever the stored weight bit — so padded
and unpadded results are bit-identical on the valid region.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing
from repro.core.packing import LANE_BITS
from repro.kernels.common import default_interpret, pad_dim_to, round_up
from repro.kernels.cim_matmul_packed import kernel as knl
from repro.kernels.cim_matmul_packed.ref import (  # noqa: F401  (re-export)
    cim_matmul_packed_ref,
    esam_layer_packed_ref,
)

__all__ = [
    "cim_matmul_packed",
    "esam_layer_packed",
    "cim_matmul_packed_ref",
    "esam_layer_packed_ref",
]


def _prep(packed, weight_bits, block_b, block_n, block_k):
    """Pad operands to block multiples; returns operands + grid geometry."""
    B, kw = packed.shape
    K, N = weight_bits.shape
    assert kw == packing.packed_width(K), (kw, K)
    k_words = kw * LANE_BITS
    bk = min(block_k, k_words)
    assert bk % LANE_BITS == 0, bk
    k_pad = round_up(k_words, bk)
    w = pad_dim_to(weight_bits, k_pad, 0)
    p = pad_dim_to(packed, k_pad // LANE_BITS, 1)
    bm = min(block_b, B)
    b_pad = round_up(B, bm)
    p = pad_dim_to(p, b_pad, 0)
    bn = min(block_n, N)
    assert N % bn == 0, (N, bn)
    return p, w, (B, b_pad, k_pad, N, bm, bn, bk)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "block_k", "interpret")
)
def cim_matmul_packed(
    packed: jax.Array,       # uint32[B, ceil(K/32)] bit-packed spikes
    weight_bits: jax.Array,  # {0,1}[K, N]
    *,
    block_b: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """V_mem int32[B, N] = unpack(packed) @ (2*bits-1), unpacking in VMEM."""
    if interpret is None:
        interpret = default_interpret()
    p, w, (B, b_pad, k_pad, N, bm, bn, bk) = _prep(
        packed, weight_bits, block_b, block_n, block_k
    )
    n_k = k_pad // bk
    bkw = bk // LANE_BITS
    grid = (b_pad // bm, N // bn, n_k)
    out = pl.pallas_call(
        functools.partial(knl.mac_packed_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bkw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b_pad, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(p, w)
    return out[:B]


@functools.partial(
    jax.jit,
    static_argnames=("pack_output", "block_b", "block_n", "block_k", "interpret"),
)
def esam_layer_packed(
    packed: jax.Array,       # uint32[B, ceil(K/32)]
    weight_bits: jax.Array,  # {0,1}[K, N]
    vth: jax.Array,          # int32[N]
    *,
    pack_output: bool = True,
    block_b: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused packed tile: MAC + IF fire (+ output re-pack).

    Returns uint32[B, N/32] when ``pack_output`` (N must be a multiple of 32)
    else int8[B, N] — in either case V_mem never leaves VMEM.
    """
    if interpret is None:
        interpret = default_interpret()
    _, N = weight_bits.shape
    assert vth.shape == (N,), (vth.shape, N)
    p, w, (B, b_pad, k_pad, N, bm, bn, bk) = _prep(
        packed, weight_bits, block_b, block_n, block_k
    )
    if pack_output:
        assert N % LANE_BITS == 0 and bn % LANE_BITS == 0, (N, bn)
    n_k = k_pad // bk
    bkw = bk // LANE_BITS
    grid = (b_pad // bm, N // bn, n_k)
    vth2d = vth[None, :].astype(jnp.int32)
    if pack_output:
        out_spec = pl.BlockSpec((bm, bn // LANE_BITS), lambda i, j, k: (i, j))
        out_shape = jax.ShapeDtypeStruct((b_pad, N // LANE_BITS), jnp.uint32)
    else:
        out_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
        out_shape = jax.ShapeDtypeStruct((b_pad, N), jnp.int8)
    out = pl.pallas_call(
        functools.partial(
            knl.fused_fire_packed_kernel, n_k=n_k, pack_output=pack_output
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bkw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(p, w, vth2d)
    return out[:B]

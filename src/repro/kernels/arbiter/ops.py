"""Jit'd public wrappers for the arbiter kernels.

``port_schedule`` is the dispatch point the cycle-accurate plane
(``core.esam.tile``) consumes: the fused Pallas kernel on TPU, the jnp
reference elsewhere (interpret-mode Pallas would only slow the batched
simulator down on CPU, and the two are bit-identical — tested).
"""

from __future__ import annotations

import jax

from repro.kernels.arbiter.kernel import arbiter, port_schedule as port_schedule_kernel
from repro.kernels.arbiter.ref import (
    arbiter_ref,
    port_schedule_ref,
    priority_grants_oracle,
)


def port_schedule(
    requests: jax.Array,
    *,
    ports: int,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
):
    """Closed-form drain schedule for N row groups — see ``port_schedule_ref``.

    ``use_kernel=None`` (default) runs the fused Pallas kernel only when the
    backend compiles it natively (TPU); pass True/False to force either path.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        return port_schedule_kernel(requests, ports=ports, interpret=interpret)
    return port_schedule_ref(requests, ports)


__all__ = [
    "arbiter",
    "arbiter_ref",
    "port_schedule",
    "port_schedule_kernel",
    "port_schedule_ref",
    "priority_grants_oracle",
]

"""Jit'd public wrappers for the arbiter kernel."""

from repro.kernels.arbiter.kernel import arbiter
from repro.kernels.arbiter.ref import arbiter_ref, priority_grants_oracle

__all__ = ["arbiter", "arbiter_ref", "priority_grants_oracle"]

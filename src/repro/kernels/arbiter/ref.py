"""Pure-jnp oracle for the multiport arbiter kernel (and the hardware cascade
oracle re-exported from the core for end-to-end checks)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.esam.arbiter import priority_grants_oracle  # noqa: F401  (re-export)


def arbiter_ref(requests: jax.Array, ports: int):
    """Vectorized fixed-priority grants for a batch of row groups.

    Args:
      requests: {0,1}[G, W] — one request vector per 128-row group.
      ports: p.
    Returns:
      grants int8[G, p, W], remaining int8[G, W], valid int8[G, p]
    """
    r = requests.astype(jnp.int32)
    rank = jnp.cumsum(r, axis=-1) - 1                       # [G, W]
    pid = jnp.arange(ports)[None, :, None]                  # [1, p, 1]
    grants = (r[:, None, :] == 1) & (rank[:, None, :] == pid)
    remaining = (r == 1) & ~jnp.any(grants, axis=1)
    valid = jnp.any(grants, axis=2)
    return grants.astype(jnp.int8), remaining.astype(jnp.int8), valid.astype(jnp.int8)

"""Pure-jnp oracle for the multiport arbiter kernel (and the hardware cascade
oracle re-exported from the core for end-to-end checks)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.esam.arbiter import grant_cycles
from repro.core.esam.arbiter import priority_grants_oracle  # noqa: F401  (re-export)


def port_schedule_ref(requests: jax.Array, ports: int):
    """Closed-form drain schedule for a batch of row groups (jnp oracle).

    Replaces the cycle-by-cycle arbitration loop: a request with in-group
    rank r is granted at cycle ``r // p`` (see core ``arbiter.grant_cycles``),
    so the full drain reduces to one rank computation plus a cycle-keyed
    segment count.

    Args:
      requests: {0,1}[N, W] — one request vector per 128-row group.
      ports: p.
    Returns:
      cycle_of int32[N, W] — grant cycle per lane (sentinel ``ceil(W/p)``
        on non-request lanes).
      counts int32[N, C] — grants issued per cycle per group,
        C = ceil(W / p).  ``counts.sum(-1)`` is the group popcount and
        ``(counts > 0).sum(-1)`` its drain-cycle count.
    """
    w = requests.shape[-1]
    n_cycles = -(-w // ports)
    cycle_of = grant_cycles(requests, ports)
    # Requests drain in rank order, p per cycle, so cycle c serves ranks
    # [c*p, (c+1)*p): its grant count is clip(popcount - c*p, 0, p) — the
    # segment histogram in closed form, no per-lane scatter.
    pop = requests.astype(jnp.int32).sum(axis=-1)
    counts = jnp.clip(
        pop[:, None] - jnp.arange(n_cycles)[None, :] * ports, 0, ports
    ).astype(jnp.int32)
    return cycle_of, counts


def arbiter_ref(requests: jax.Array, ports: int):
    """Vectorized fixed-priority grants for a batch of row groups.

    Args:
      requests: {0,1}[G, W] — one request vector per 128-row group.
      ports: p.
    Returns:
      grants int8[G, p, W], remaining int8[G, W], valid int8[G, p]
    """
    r = requests.astype(jnp.int32)
    rank = jnp.cumsum(r, axis=-1) - 1                       # [G, W]
    pid = jnp.arange(ports)[None, :, None]                  # [1, p, 1]
    grants = (r[:, None, :] == 1) & (rank[:, None, :] == pid)
    remaining = (r == 1) & ~jnp.any(grants, axis=1)
    valid = jnp.any(grants, axis=2)
    return grants.astype(jnp.int8), remaining.astype(jnp.int8), valid.astype(jnp.int8)

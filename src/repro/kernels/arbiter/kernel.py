"""Pallas TPU kernel: p-port fixed-priority spike arbiter.

Hardware mapping (DESIGN.md §2): the paper's 1-port arbiter is a fixed
priority encoder; p ports are p cascaded encoders (Fig 4).  The sequential
grant-and-mask cascade is re-expressed as prefix-sum *rank selection*, which
yields bit-identical grants in O(log W) vector steps:

    rank[i]  = inclusive-prefix-count of requests up to lane i, minus 1
    grant_k  = request & (rank == k)          for ports k = 0..p-1
    valid_k  = any(grant_k)                   (the paper's inverted noR flag)
    R'       = request & (rank >= p)

The paper's own critical-path fix — short base priority encoders arbitrated by
a higher-level encoder tree (+8% area, >1100ps -> <800ps) — is structurally a
*blocked* prefix sum; the kernel computes the intra-block cumsum per 32-lane
sub-block and adds block offsets, mirroring that tree.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret

_SUBBLOCK = 32  # base priority-encoder width in the tree decomposition


def _arbiter_kernel(req_ref, grants_ref, rem_ref, valid_ref, *, ports: int):
    r = req_ref[...].astype(jnp.int32)            # [bg, W]
    bg, w = r.shape
    # --- blocked prefix sum (the tree of base priority encoders) ---------
    sub = r.reshape(bg, w // _SUBBLOCK, _SUBBLOCK)
    intra = jnp.cumsum(sub, axis=-1)              # base encoders, 32 wide
    block_tot = intra[..., -1]                    # requests per sub-block
    offsets = jnp.cumsum(block_tot, axis=-1) - block_tot  # higher-level encoder
    rank = (intra + offsets[..., None]).reshape(bg, w) - 1
    # --- grant selection --------------------------------------------------
    pid = jax.lax.broadcasted_iota(jnp.int32, (bg, ports, w), 1)
    is_req = (r == 1)[:, None, :]
    grants = is_req & (rank[:, None, :] == pid)
    grants_ref[...] = grants.astype(jnp.int8)
    rem_ref[...] = ((r == 1) & (rank >= ports)).astype(jnp.int8)
    valid_ref[...] = jnp.any(grants, axis=2).astype(jnp.int8)


def _port_schedule_kernel(req_ref, cycle_ref, counts_ref, *, ports: int, n_cycles: int):
    """Rank + schedule + cycle-keyed segment counts, fused in VMEM.

    One grid step covers a block of row groups.  The blocked prefix sum is
    the same base-encoder tree as ``_arbiter_kernel``; on top of the rank we
    evaluate the *whole* drain in closed form — grant cycle ``rank // p`` per
    lane — instead of one arbitration round, and accumulate the per-cycle
    grant counts (the segment histogram) without leaving VMEM.
    """
    r = req_ref[...].astype(jnp.int32)            # [bg, W]
    bg, w = r.shape
    # --- blocked prefix sum (the tree of base priority encoders) ---------
    sub = r.reshape(bg, w // _SUBBLOCK, _SUBBLOCK)
    intra = jnp.cumsum(sub, axis=-1)
    block_tot = intra[..., -1]
    offsets = jnp.cumsum(block_tot, axis=-1) - block_tot
    rank = (intra + offsets[..., None]).reshape(bg, w) - 1
    # --- closed-form schedule: grant cycle per lane -----------------------
    cycle = jnp.where(r == 1, rank // ports, n_cycles)
    cycle_ref[...] = cycle.astype(jnp.int32)
    # --- segment accumulation: grants per cycle ---------------------------
    # Cycle c serves ranks [c*p, (c+1)*p), so its grant count is
    # clip(popcount - c*p, 0, p): the histogram needs no per-lane scatter.
    pop = offsets[..., -1] + block_tot[..., -1]            # [bg] group popcount
    cid = jax.lax.broadcasted_iota(jnp.int32, (bg, n_cycles), 1)
    counts_ref[...] = jnp.clip(pop[:, None] - cid * ports, 0, ports).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("ports", "block_g", "interpret"))
def port_schedule(
    requests: jax.Array,   # {0,1}[N, W] — W = 128 row-group width
    *,
    ports: int = 4,
    block_g: int = 8,
    interpret: bool | None = None,
):
    """Closed-form drain schedule for N independent row groups (full drain in
    one kernel launch — no per-cycle loop).

    Returns (cycle_of int32[N, W], counts int32[N, C]) with C = ceil(W/p);
    semantics match ``repro.kernels.arbiter.ref.port_schedule_ref``.
    """
    if interpret is None:
        interpret = default_interpret()
    N, W = requests.shape
    assert W % _SUBBLOCK == 0, f"row-group width {W} must be a multiple of {_SUBBLOCK}"
    n_cycles = -(-W // ports)
    bg = math.gcd(N, block_g) if N else 1   # largest block size dividing N
    grid = (N // bg,)
    return pl.pallas_call(
        functools.partial(_port_schedule_kernel, ports=ports, n_cycles=n_cycles),
        grid=grid,
        in_specs=[pl.BlockSpec((bg, W), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bg, W), lambda i: (i, 0)),
            pl.BlockSpec((bg, n_cycles), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, W), jnp.int32),
            jax.ShapeDtypeStruct((N, n_cycles), jnp.int32),
        ],
        interpret=interpret,
    )(requests)


@functools.partial(jax.jit, static_argnames=("ports", "block_g", "interpret"))
def arbiter(
    requests: jax.Array,   # {0,1}[G, W] — W = 128 row-group width
    *,
    ports: int = 4,
    block_g: int = 8,
    interpret: bool | None = None,
):
    """One arbiter clock cycle for G independent row groups.

    Returns (grants int8[G, p, W], remaining int8[G, W], valid int8[G, p]).
    """
    if interpret is None:
        interpret = default_interpret()
    G, W = requests.shape
    assert W % _SUBBLOCK == 0, f"row-group width {W} must be a multiple of {_SUBBLOCK}"
    bg = min(block_g, G)
    assert G % bg == 0
    grid = (G // bg,)
    return pl.pallas_call(
        functools.partial(_arbiter_kernel, ports=ports),
        grid=grid,
        in_specs=[pl.BlockSpec((bg, W), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bg, ports, W), lambda i: (i, 0, 0)),
            pl.BlockSpec((bg, W), lambda i: (i, 0)),
            pl.BlockSpec((bg, ports), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, ports, W), jnp.int8),
            jax.ShapeDtypeStruct((G, W), jnp.int8),
            jax.ShapeDtypeStruct((G, ports), jnp.int8),
        ],
        interpret=interpret,
    )(requests)

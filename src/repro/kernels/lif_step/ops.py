"""Jit'd public wrappers for the LIF-step kernel.

``lif_step`` is the dispatch point the temporal plane
(``core.esam.temporal``) consumes: the fused Pallas kernel on TPU, the jnp
reference elsewhere (an elementwise kernel gains nothing in interpret mode
on CPU, and the two are bit-identical — tested), mirroring the
``kernels/arbiter`` dispatch convention.
"""

from __future__ import annotations

import jax

from repro.kernels.lif_step.kernel import lif_step as lif_step_kernel
from repro.kernels.lif_step.ref import RESET_MODES, lif_step_ref


def lif_step(
    vmem: jax.Array,
    contrib: jax.Array,
    vth: jax.Array,
    refrac: jax.Array,
    *,
    leak: float = 0.0,
    reset: str = "zero",
    refractory: int = 0,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
):
    """One leak-integrate-fire-reset step — see ``lif_step_ref``.

    ``use_kernel=None`` (default) runs the fused Pallas kernel only when the
    backend compiles it natively (TPU); pass True/False to force either path.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        return lif_step_kernel(
            vmem, contrib, vth, refrac,
            leak=leak, reset=reset, refractory=refractory,
            interpret=interpret)
    return lif_step_ref(
        vmem, contrib, vth, refrac,
        leak=leak, reset=reset, refractory=refractory)


__all__ = ["RESET_MODES", "lif_step", "lif_step_kernel", "lif_step_ref"]

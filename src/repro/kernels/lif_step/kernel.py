"""Pallas TPU kernel: LIF step — leak-integrate-fire-reset on resident V_mem.

Hardware mapping (IMPULSE-style fused weight + membrane CIM, Agrawal et al.;
Sec 3.4 / Fig 5 of the source paper): each neuron's m-bit V_mem register
survives *between* timesteps of an event stream, is leaked, accumulates the
cycle's validity-masked port sum, is compared against V_th on R_empty, and on
fire is reset (to zero, or by threshold subtraction) and optionally held
silent for a refractory window.

On TPU the resident register file is the [B, N] membrane tensor the temporal
``lax.scan`` carries: this kernel is the per-step update, one elementwise
VPU pass over (bb, bn) VMEM blocks — leak multiply, integrate add, masked
compare, reset select and refractory count-down all fused so V_mem makes
exactly one HBM round-trip per timestep (the scan keeps even that on-device).
Layout mirrors ``kernels/if_neuron``: grid (B/bb, N/bn), thresholds
broadcast as a (1, bn) row.

Numerics: with ``leak=0`` every value is an integer carried in float32 and
the kernel is bit-identical to ``lif_step_ref`` on every backend (this is
what the temporal plane's T=1 == packed identity rests on).  With a nonzero
leak the compiler may contract the leak-multiply + integrate-add into one
FMA (single rounding) where the jnp reference rounds twice — agreement is
then to float32 ulp, not bitwise (tested with tolerance).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret
from repro.kernels.lif_step.ref import RESET_MODES


def _lif_kernel(vmem_ref, upd_ref, vth_ref, refrac_ref,
                spikes_ref, vout_ref, rout_ref,
                *, leak: float, reset: str, refractory: int):
    th = vth_ref[...].astype(jnp.float32)
    v = vmem_ref[...] * jnp.float32(1.0 - leak) + upd_ref[...].astype(jnp.float32)
    refrac = refrac_ref[...]
    fired = (v >= th) & (refrac == 0)
    if reset == "zero":
        v_next = jnp.where(fired, jnp.float32(0.0), v)
    else:
        v_next = jnp.where(fired, v - th, v)
    spikes_ref[...] = fired.astype(jnp.int8)
    vout_ref[...] = v_next
    rout_ref[...] = jnp.where(
        fired, jnp.int32(refractory), jnp.maximum(refrac - 1, 0))


@functools.partial(
    jax.jit,
    static_argnames=("leak", "reset", "refractory",
                     "block_b", "block_n", "interpret"),
)
def lif_step(
    vmem: jax.Array,       # float32[B, N]
    contrib: jax.Array,    # int32[B, N]
    vth: jax.Array,        # int32[N]
    refrac: jax.Array,     # int32[B, N]
    *,
    leak: float = 0.0,
    reset: str = "zero",
    refractory: int = 0,
    block_b: int = 8,
    block_n: int = 128,
    interpret: bool | None = None,
):
    """Returns (spikes int8[B, N], vmem' float32[B, N], refrac' int32[B, N])."""
    assert reset in RESET_MODES, (reset, RESET_MODES)
    if interpret is None:
        interpret = default_interpret()
    B, N = vmem.shape
    assert contrib.shape == (B, N) and refrac.shape == (B, N)
    assert vth.shape == (N,), (vth.shape, N)
    bb, bn = min(block_b, B), min(block_n, N)
    assert B % bb == 0 and N % bn == 0, (B, N, bb, bn)
    grid = (B // bb, N // bn)
    vth2d = vth[None, :].astype(jnp.int32)
    blk = pl.BlockSpec((bb, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(
            _lif_kernel, leak=leak, reset=reset, refractory=refractory),
        grid=grid,
        in_specs=[
            blk,
            blk,
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            blk,
        ],
        out_specs=[blk, blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((B, N), jnp.int8),
            jax.ShapeDtypeStruct((B, N), jnp.float32),
            jax.ShapeDtypeStruct((B, N), jnp.int32),
        ],
        interpret=interpret,
    )(vmem.astype(jnp.float32), contrib, vth2d, refrac)

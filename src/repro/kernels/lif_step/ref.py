"""Pure-jnp oracle for the LIF-step kernel: leak-integrate-fire-reset on
resident membrane state.

Semantics (one SNN timestep for one tile's neuron array):

    v      = vmem * (1 - leak) + contrib          # leak, then integrate
    fired  = (v >= vth) & (refrac == 0)           # refractory gates the fire
    v'     = 0            where fired (reset="zero")
             v - vth      where fired (reset="subtract")
             v            elsewhere
    refrac'= refractory   where fired, else max(refrac - 1, 0)

V_mem is float32 (the leak multiply needs it); contributions are the int32
CIM MAC outputs, which float32 holds exactly for every reachable magnitude
(|contrib| <= n_in < 2^24), so with ``leak=0`` the datapath is bit-exact
integer arithmetic — the T=1 identity with the static packed plane rests on
this (tests/test_temporal.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

RESET_MODES = ("zero", "subtract")


def lif_step_ref(
    vmem: jax.Array,       # float32[B, N] resident membrane state
    contrib: jax.Array,    # int32[B, N] this step's CIM MAC contribution
    vth: jax.Array,        # int32[N] per-neuron thresholds
    refrac: jax.Array,     # int32[B, N] remaining refractory steps
    *,
    leak: float = 0.0,
    reset: str = "zero",
    refractory: int = 0,
):
    """Returns (spikes int8[B, N], vmem' float32[B, N], refrac' int32[B, N])."""
    assert reset in RESET_MODES, (reset, RESET_MODES)
    th = vth[None, :].astype(jnp.float32)
    v = vmem * jnp.float32(1.0 - leak) + contrib.astype(jnp.float32)
    fired = (v >= th) & (refrac == 0)
    if reset == "zero":
        v_next = jnp.where(fired, jnp.float32(0.0), v)
    else:
        v_next = jnp.where(fired, v - th, v)
    refrac_next = jnp.where(
        fired, jnp.int32(refractory), jnp.maximum(refrac - 1, 0))
    return fired.astype(jnp.int8), v_next, refrac_next

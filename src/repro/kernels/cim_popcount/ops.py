"""Jit'd public wrappers for the popcount-domain CIM MAC kernels.

Same padding contract as ``cim_matmul_packed.ops`` — callers hand in natural
shapes, wrappers zero-pad to block multiples (exact for the binary MAC in
both popcount terms) — plus the backend dispatch of ``kernels/arbiter``:
``use_kernel=None`` runs the Pallas kernel only where it compiles natively
(TPU) and the vectorized popcount reference elsewhere (on CPU the reference
beats both the interpret-mode kernel and an unpack + BLAS round trip).  The
two paths are bit-identical int32 (tests/test_popcount.py).

``esam_cascade_popcount`` is the single-launch mega kernel: the caller
pre-stacks every tile's weight planes and thresholds once
(``stack_cascade_operands``, done at plan-build time by ``EsamPlan``) and
each call runs the whole cascade — MAC, IF fire, re-pack, next tile — in one
``pallas_call`` with double-buffered weight-plane DMA.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing
from repro.core.packing import LANE_BITS
from repro.kernels.common import default_interpret, pad_dim_to, round_up
from repro.kernels.cim_popcount import kernel as knl
from repro.kernels.cim_popcount.ref import (  # noqa: F401  (re-export)
    cim_popcount_ref,
    esam_cascade_popcount_ref,
    esam_layer_popcount_ref,
)

__all__ = [
    "cim_popcount_matmul",
    "esam_layer_popcount",
    "esam_cascade_popcount",
    "stack_cascade_operands",
    "cascade_geometry",
    "cim_popcount_ref",
    "esam_layer_popcount_ref",
    "esam_cascade_popcount_ref",
]

#: lane alignment for per-tile output widths inside the mega kernel
_COL_PAD = 128


def _use_kernel(use_kernel: bool | None) -> bool:
    if use_kernel is None:
        return jax.default_backend() == "tpu"
    return use_kernel


def _prep(packed, planes, block_b, block_n, block_k):
    """Pad operands to block multiples; returns operands + grid geometry.

    Mirrors the packed-MXU ``_prep`` but the weight operand is already in
    word space: planes uint32[N, kw] pad along the word axis.
    """
    B, kw = packed.shape
    N, kw2 = planes.shape
    assert kw == kw2, (packed.shape, planes.shape)
    k_words = kw * LANE_BITS
    bk = min(block_k, k_words)
    assert bk % LANE_BITS == 0, bk
    k_pad = round_up(k_words, bk)
    w = pad_dim_to(planes, k_pad // LANE_BITS, 1)
    p = pad_dim_to(packed, k_pad // LANE_BITS, 1)
    bm = min(block_b, B)
    b_pad = round_up(B, bm)
    p = pad_dim_to(p, b_pad, 0)
    bn = min(block_n, N)
    assert N % bn == 0, (N, bn)
    return p, w, (B, b_pad, k_pad, N, bm, bn, bk)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_n", "block_k", "use_kernel", "interpret"),
)
def cim_popcount_matmul(
    packed: jax.Array,   # uint32[B, ceil(K/32)] bit-packed spikes
    planes: jax.Array,   # uint32[N, ceil(K/32)] weight bit planes
    *,
    block_b: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """V_mem int32[B, N] = 2*popcount(s & w) - popcount(s); nothing unpacks."""
    if not _use_kernel(use_kernel):
        return cim_popcount_ref(packed, planes)
    if interpret is None:
        interpret = default_interpret()
    p, w, (B, b_pad, k_pad, N, bm, bn, bk) = _prep(
        packed, planes, block_b, block_n, block_k
    )
    n_k = k_pad // bk
    bkw = bk // LANE_BITS
    grid = (b_pad // bm, N // bn, n_k)
    out = pl.pallas_call(
        functools.partial(knl.popcount_mac_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bkw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bkw), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b_pad, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(p, w)
    return out[:B]


@functools.partial(
    jax.jit,
    static_argnames=(
        "pack_output", "block_b", "block_n", "block_k", "use_kernel", "interpret"
    ),
)
def esam_layer_popcount(
    packed: jax.Array,   # uint32[B, ceil(K/32)]
    planes: jax.Array,   # uint32[N, ceil(K/32)]
    vth: jax.Array,      # int32[N]
    *,
    pack_output: bool = True,
    block_b: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused popcount tile: MAC + IF fire (+ output re-pack), V_mem in VMEM."""
    if not _use_kernel(use_kernel):
        return esam_layer_popcount_ref(packed, planes, vth, pack_output=pack_output)
    if interpret is None:
        interpret = default_interpret()
    N = planes.shape[0]
    assert vth.shape == (N,), (vth.shape, N)
    p, w, (B, b_pad, k_pad, N, bm, bn, bk) = _prep(
        packed, planes, block_b, block_n, block_k
    )
    if pack_output:
        assert N % LANE_BITS == 0 and bn % LANE_BITS == 0, (N, bn)
    n_k = k_pad // bk
    bkw = bk // LANE_BITS
    grid = (b_pad // bm, N // bn, n_k)
    vth2d = vth[None, :].astype(jnp.int32)
    if pack_output:
        out_spec = pl.BlockSpec((bm, bn // LANE_BITS), lambda i, j, k: (i, j))
        out_shape = jax.ShapeDtypeStruct((b_pad, N // LANE_BITS), jnp.uint32)
    else:
        out_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
        out_shape = jax.ShapeDtypeStruct((b_pad, N), jnp.int8)
    out = pl.pallas_call(
        functools.partial(
            knl.popcount_fire_kernel, n_k=n_k, pack_output=pack_output
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bkw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bkw), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(p, w, vth2d)
    return out[:B]


# --------------------------------------------------------------------- #
# single-launch mega-kernel cascade
# --------------------------------------------------------------------- #
def cascade_geometry(topology: tuple[int, ...]) -> dict:
    """Static padding geometry shared by the stacker and the mega kernel.

    Per tile t (K_t = topology[t] -> N_t = topology[t+1]):
      n_pad[t]    output width padded to the 128-lane grid
      w_words[t]  real input words ceil(K_t/32) — fired bits past a tile's
                  real width never fire (vth padding), so words past this
                  are provably zero and the AND loop skips them.
    """
    n_tiles = len(topology) - 1
    assert n_tiles >= 1, topology
    n_pad = tuple(round_up(n, _COL_PAD) for n in topology[1:])
    w_words = tuple(packing.packed_width(k) for k in topology[:-1])
    return {
        "n_tiles": n_tiles,
        "n_pad": n_pad,
        "w_words": w_words,
        "n_max_pad": max(n_pad),
        "w_max": max(w_words),
    }


def stack_cascade_operands(weight_planes, vth, topology):
    """Stack per-tile planes/thresholds into the mega kernel's DMA slabs.

    weight_planes: per tile uint32[N_t, ceil(K_t/32)]; vth: per tile
    int32[N_t].  Returns (w_stack uint32[n_tiles, n_max_pad, w_max],
    vth_stack int32[n_hidden, n_max_pad]).  Plane padding is zero (AND-dead);
    vth padding is ``VTH_NEVER_FIRE`` so padded neurons stay silent and the
    re-packed inter-tile plane carries only real bits.  Built once per
    parameter set at plan-build time, never per call.
    """
    g = cascade_geometry(tuple(topology))
    n_tiles, n_max_pad, w_max = g["n_tiles"], g["n_max_pad"], g["w_max"]
    assert len(weight_planes) == n_tiles, (len(weight_planes), n_tiles)
    w_stack = jnp.stack([
        pad_dim_to(pad_dim_to(p, n_max_pad, 0), w_max, 1)
        for p in weight_planes
    ])
    n_hidden = max(n_tiles - 1, 1)
    vth_stack = jnp.full((n_hidden, n_max_pad), knl.VTH_NEVER_FIRE, jnp.int32)
    for t, th in enumerate(vth[: n_tiles - 1]):
        vth_stack = vth_stack.at[t, : th.shape[0]].set(th.astype(jnp.int32))
    return w_stack, vth_stack


@functools.partial(
    jax.jit,
    static_argnames=("topology", "block_b", "use_kernel", "interpret"),
)
def esam_cascade_popcount(
    packed: jax.Array,      # uint32[B, ceil(n_in/32)]
    w_stack: jax.Array,     # uint32[n_tiles, n_max_pad, w_max]
    vth_stack: jax.Array,   # int32[n_hidden, n_max_pad]
    *,
    topology: tuple[int, ...],
    block_b: int = 128,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, tuple]:
    """The whole tile cascade in ONE kernel launch.

    grid = (B/bm,): each program carries its batch block through every tile —
    popcount MAC, IF fire, re-pack — with the fired bitplanes resident in
    VMEM and the next tile's weight slab DMA'd in under the current MAC.
    Returns (logits int32[B, n_cls], fired hidden planes tuple of
    uint32[B, N_t/32]) — bit-identical to the per-tile packed cascade.
    """
    topology = tuple(topology)
    g = cascade_geometry(topology)
    n_tiles = g["n_tiles"]
    for n in topology[1:-1]:
        assert n % LANE_BITS == 0, ("hidden widths must be 32-aligned", topology)
    if not _use_kernel(use_kernel):
        planes = tuple(
            w_stack[t, : topology[t + 1], : g["w_words"][t]]
            for t in range(n_tiles)
        )
        vth = tuple(
            vth_stack[t, : topology[t + 1]] for t in range(n_tiles - 1)
        ) + (None,)
        return esam_cascade_popcount_ref(packed, planes, vth)
    if interpret is None:
        interpret = default_interpret()
    if n_tiles == 1:
        return (
            cim_popcount_matmul(
                packed, w_stack[0, : topology[1], : g["w_words"][0]],
                use_kernel=True, interpret=interpret,
            ),
            (),
        )
    B = packed.shape[0]
    bm = min(block_b, B)
    b_pad = round_up(B, bm)
    p = pad_dim_to(packed, b_pad, 0)
    n_cls_pad = g["n_pad"][-1]
    out_shapes = [jax.ShapeDtypeStruct((b_pad, n_cls_pad), jnp.int32)] + [
        jax.ShapeDtypeStruct((b_pad, g["n_pad"][t] // LANE_BITS), jnp.uint32)
        for t in range(n_tiles - 1)
    ]
    out_specs = [pl.BlockSpec((bm, n_cls_pad), lambda i: (i, 0))] + [
        pl.BlockSpec((bm, g["n_pad"][t] // LANE_BITS), lambda i: (i, 0))
        for t in range(n_tiles - 1)
    ]
    outs = pl.pallas_call(
        functools.partial(
            knl.mega_cascade_kernel, n_pad=g["n_pad"], w_words=g["w_words"]
        ),
        grid=(b_pad // bm,),
        in_specs=[
            pl.BlockSpec((bm, g["w_words"][0]), lambda i: (i, 0)),
            pl.BlockSpec(vth_stack.shape, lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((2, g["n_max_pad"], g["w_max"]), jnp.uint32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(p, vth_stack, w_stack)
    logits = outs[0][:B, : topology[-1]]
    fired = tuple(
        outs[1 + t][:B, : packing.packed_width(topology[t + 1])]
        for t in range(n_tiles - 1)
    )
    return logits, fired

"""Pure-jnp oracles for the popcount-domain CIM MAC.

Unlike ``cim_matmul_packed_ref`` (which unpacks and runs the dense oracle),
these references compute in the *same domain as the kernel* — AND + popcount
per uint32 word with the row-popcount offset — so they double as the fast
non-TPU dispatch target: on CPU one vectorized popcount pass beats both the
interpret-mode kernel and an unpack + BLAS round trip, and the arithmetic is
exact int32 end to end (bit-identical to the unpacked oracle, property-tested
in tests/test_popcount.py).

Identity (±1 weights stored as {0,1} bits ``w``, spikes ``s``):

    V[b, n] = sum_k s[b,k] * (2*w[k,n] - 1)
            = 2 * sum_j popcount(packed[b,j] & planes[n,j]) - popcount(packed[b])

Zero tail padding in the wire format is exact in both terms.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing


def _and_popcount(packed: jax.Array, planes: jax.Array) -> jax.Array:
    """sum_j popcount(packed[b,j] & planes[n,j]) -> int32[B, N].

    Word-at-a-time accumulation keeps the intermediate at [B, N] instead of
    materializing the full [B, N, W] AND tensor.
    """
    B, W = packed.shape
    N, W2 = planes.shape
    assert W == W2, (packed.shape, planes.shape)

    def body(j, acc):
        a = jax.lax.dynamic_index_in_dim(packed, j, 1, keepdims=True)   # [B, 1]
        b = jax.lax.dynamic_index_in_dim(planes, j, 1, keepdims=True)   # [N, 1]
        return acc + jax.lax.population_count(a & b.T).astype(jnp.int32)

    return jax.lax.fori_loop(0, W, body, jnp.zeros((B, N), jnp.int32))


def cim_popcount_ref(packed: jax.Array, planes: jax.Array) -> jax.Array:
    """V_mem int32[B, N] from spike words [B, W] and weight planes [N, W]."""
    spc = jax.lax.population_count(packed).astype(jnp.int32).sum(-1)
    return 2 * _and_popcount(packed, planes) - spc[:, None]


def esam_layer_popcount_ref(
    packed: jax.Array,
    planes: jax.Array,
    vth: jax.Array,
    *,
    pack_output: bool = True,
) -> jax.Array:
    """Fused popcount MAC + IF fire (+ re-pack) oracle."""
    fired = cim_popcount_ref(packed, planes) >= vth[None, :].astype(jnp.int32)
    return packing.pack_spikes(fired) if pack_output else fired.astype(jnp.int8)


def esam_cascade_popcount_ref(
    packed: jax.Array,
    planes: tuple,   # per tile: uint32[N_t, ceil(K_t/32)]
    vth: tuple,      # per tile: int32[N_t]
) -> tuple[jax.Array, tuple]:
    """Whole-cascade oracle: hidden fires on the popcount plane, int32 logits.

    Returns (vmem int32[B, n_cls], fired hidden planes tuple) — exactly the
    mega-kernel's outputs, for bit-identity gating.
    """
    p = packed
    fired = []
    for w, th in zip(planes[:-1], vth[:-1]):
        p = esam_layer_popcount_ref(p, w, th, pack_output=True)
        fired.append(p)
    return cim_popcount_ref(p, planes[-1]), tuple(fired)

"""Pallas TPU kernels: popcount-domain CIM MAC + single-launch tile cascade.

``cim_matmul_packed`` already moves spikes as uint32 bitplanes but unpacks
them in VMEM and hands the MAC to the MXU — the wire format buys the bytes
but none of the compute.  This family keeps *both* operands packed: weights
are bit-sliced at plan-build time into the same uint32 layout
(``packing.pack_weight_planes``) and each MAC block is AND + popcount with
the row-popcount offset, entirely on the VPU:

    V = 2 * sum_j popcount(s_word_j & w_word_j) - popcount(s)

summed per K block (the per-block offsets add up exactly).  No unpack, no
bf16 round trip, no MXU — one 32-wide AND+popcount per lane word replaces 32
multiply-accumulates.

``mega_cascade_kernel`` then fuses the whole tile cascade (MAC -> IF fire ->
re-pack -> next tile) into ONE launch: the grid walks batch blocks only, the
fired bitplanes stay resident as kernel values between tiles, and each
tile's weight-plane slab is DMA'd from HBM into a double-buffered VMEM
scratch while the previous tile computes — the layer-wise weight/output-
stationary dataflow of Chauvaux et al. rendered as a Pallas pipeline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cim_matmul_packed.kernel import pack_bits_block

#: vth padding for columns past a tile's real width — no spike plane can
#: reach it (V <= n_in < 2^30), so padded neurons provably never fire.
VTH_NEVER_FIRE = 1 << 30


def popcount_mac_block(s: jax.Array, w: jax.Array) -> jax.Array:
    """AND + popcount MAC of one block: (bm, W) x (bn, W) -> int32 (bm, bn).

    Static unroll over the W lane words; each step is a rank-1-style
    broadcast AND + popcount on a 2-D (bm, bn) tile — pure VPU, no unpack.
    """
    bm, w_words = s.shape
    bn = w.shape[0]
    acc = jnp.zeros((bm, bn), jnp.int32)
    for j in range(w_words):
        acc += jax.lax.population_count(s[:, j][:, None] & w[None, :, j]).astype(
            jnp.int32
        )
    return acc


def popcount_mac_kernel(s_ref, w_ref, out_ref, acc_ref, *, n_k: int):
    """grid = (B/bm, N/bn, K/bk); K innermost.  Both operands packed uint32:
    s block (bm, bk/32), weight-plane block (bn, bk/32)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    s = s_ref[...]
    # per-block V contribution: 2*AND-popcount - row popcount; the offsets
    # sum over K blocks to the total row popcount, so blockwise is exact
    spc = jax.lax.population_count(s).astype(jnp.int32).sum(-1, keepdims=True)
    acc_ref[...] += 2 * popcount_mac_block(s, w_ref[...]) - spc

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


def popcount_fire_kernel(
    s_ref, w_ref, vth_ref, out_ref, acc_ref, *, n_k: int, pack_output: bool
):
    """Popcount MAC with the IF compare (+ output re-pack) fused in the
    epilogue — V_mem never leaves VMEM, mirroring ``fused_fire_packed``."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    s = s_ref[...]
    spc = jax.lax.population_count(s).astype(jnp.int32).sum(-1, keepdims=True)
    acc_ref[...] += 2 * popcount_mac_block(s, w_ref[...]) - spc

    @pl.when(k == n_k - 1)
    def _fire():
        fired = acc_ref[...] >= vth_ref[...]
        if pack_output:
            out_ref[...] = pack_bits_block(fired)
        else:
            out_ref[...] = fired.astype(jnp.int8)


def mega_cascade_kernel(
    s_ref,       # (bm, W_in0) uint32 — the network input plane block
    vth_ref,     # (n_hidden, n_max_pad) int32, padded with VTH_NEVER_FIRE
    w_ref,       # ANY-space uint32[n_tiles, n_max_pad, w_max] stacked planes
    logits_ref,  # (bm, n_cls_pad) int32
    *rest,       # fired refs per hidden tile, then wbuf + DMA semaphores
    n_pad: tuple[int, ...],    # per tile: padded output width (128-aligned)
    w_words: tuple[int, ...],  # per tile: real input words ceil(K_t/32)
):
    """One launch, whole cascade.  grid = (B/bm,).

    The fired bitplanes are plain kernel values (VMEM-resident SSA), never
    stored between tiles except into their own output ref; tile t+1's weight
    slab is prefetched by async copy while tile t computes (double-buffered
    ``wbuf`` + one DMA semaphore per slot).
    """
    n_tiles = len(n_pad)
    fired_refs = rest[: n_tiles - 1]
    wbuf, sem = rest[n_tiles - 1], rest[n_tiles]
    vth = vth_ref[...]

    copies = [
        pltpu.make_async_copy(w_ref.at[t], wbuf.at[t % 2], sem.at[t % 2])
        for t in range(n_tiles)
    ]
    copies[0].start()

    s = s_ref[...]                                             # (bm, W_in0)
    spc = jax.lax.population_count(s).astype(jnp.int32).sum(-1, keepdims=True)
    for t in range(n_tiles):
        if t + 1 < n_tiles:
            copies[t + 1].start()
        copies[t].wait()
        w = wbuf[t % 2]                                        # (n_max_pad, w_max)
        v = 2 * popcount_mac_block(
            s[:, : w_words[t]], w[: n_pad[t], : w_words[t]]
        ) - spc                                                # (bm, n_pad[t])
        if t == n_tiles - 1:
            logits_ref[...] = v
        else:
            fired = v >= vth[t, : n_pad[t]][None, :]
            s = pack_bits_block(fired)                         # stays resident
            fired_refs[t][...] = s
            spc = fired.astype(jnp.int32).sum(-1, keepdims=True)

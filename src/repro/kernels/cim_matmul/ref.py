"""Pure-jnp oracle for the CIM binary MAC."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cim_matmul_ref(spikes: jax.Array, weight_bits: jax.Array) -> jax.Array:
    """V_mem = spikes @ (2*bits - 1).

    Args:
      spikes: {0,1} (any float/int/bool dtype) [batch, n_in]
      weight_bits: {0,1} [n_in, n_out]
    Returns:
      int32 [batch, n_out]
    """
    w = (2 * weight_bits.astype(jnp.int32) - 1)
    return spikes.astype(jnp.int32) @ w


def esam_layer_ref(
    spikes: jax.Array, weight_bits: jax.Array, vth: jax.Array
) -> jax.Array:
    """Fused MAC + IF fire: out spikes = (V_mem >= V_th)."""
    return (cim_matmul_ref(spikes, weight_bits) >= vth[None, :]).astype(jnp.int8)

"""Pallas TPU kernel: batched binary CIM MAC (+ optional fused IF fire).

TPU adaptation of the paper's multiport read (DESIGN.md §2): the MXU plays the
role of an "all-ports" SRAM array — every row of a 128-wide spike tile is a
port.  Spikes {0,1} enter as bf16, stored weight bits are decoded to {-1,+1}
inside the kernel (the Fig-5 bitline decode), and accumulation runs in a f32
VMEM scratch across the K grid dimension; results are exact integers (values
are bounded by n_in << 2^24).

Block shapes are MXU-aligned (multiples of 8 x 128 for bf16 operands) and
sized so one (bm x bk) spike tile, one (bk x bn) weight tile, and the
(bm x bn) accumulator all fit in VMEM simultaneously.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, default_interpret


def _mac_kernel(s_ref, w_ref, out_ref, acc_ref, *, n_k: int):
    """grid = (B/bm, N/bn, K/bk); K is the innermost (fastest) dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    spikes = s_ref[...].astype(jnp.bfloat16)
    # Fig 5 decode: stored bit {0,1} -> synaptic value {-1,+1}
    w = (2.0 * w_ref[...].astype(jnp.bfloat16) - 1.0)
    acc_ref[...] += jax.lax.dot_general(
        spikes, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(jnp.int32)


def _fused_fire_kernel(s_ref, w_ref, vth_ref, out_ref, acc_ref, *, n_k: int):
    """Same MAC, with the IF threshold compare fused in the epilogue so V_mem
    never round-trips through HBM (the R_empty fire event of Sec 3.4)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    spikes = s_ref[...].astype(jnp.bfloat16)
    w = (2.0 * w_ref[...].astype(jnp.bfloat16) - 1.0)
    acc_ref[...] += jax.lax.dot_general(
        spikes, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _fire():
        vmem = acc_ref[...].astype(jnp.int32)
        out_ref[...] = (vmem >= vth_ref[...]).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "block_k", "interpret")
)
def cim_matmul(
    spikes: jax.Array,       # {0,1}[B, K] any dtype
    weight_bits: jax.Array,  # {0,1}[K, N]
    *,
    block_b: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """V_mem int32[B, N] = spikes @ (2*bits-1)."""
    if interpret is None:
        interpret = default_interpret()
    B, K = spikes.shape
    K2, N = weight_bits.shape
    assert K == K2, (K, K2)
    bm, bn, bk = min(block_b, B), min(block_n, N), min(block_k, K)
    assert B % bm == 0 and N % bn == 0 and K % bk == 0, (B, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (B // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_mac_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(spikes, weight_bits)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "block_k", "interpret")
)
def esam_layer(
    spikes: jax.Array,       # {0,1}[B, K]
    weight_bits: jax.Array,  # {0,1}[K, N]
    vth: jax.Array,          # int32[N]
    *,
    block_b: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused tile inference: out spikes int8[B, N] = (V_mem >= V_th)."""
    if interpret is None:
        interpret = default_interpret()
    B, K = spikes.shape
    K2, N = weight_bits.shape
    assert K == K2, (K, K2)
    assert vth.shape == (N,), (vth.shape, N)
    bm, bn, bk = min(block_b, B), min(block_n, N), min(block_k, K)
    assert B % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk
    grid = (B // bm, N // bn, n_k)
    vth2d = vth[None, :].astype(jnp.int32)
    return pl.pallas_call(
        functools.partial(_fused_fire_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(spikes, weight_bits, vth2d)

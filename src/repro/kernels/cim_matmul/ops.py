"""Jit'd public wrappers for the CIM MAC kernels."""

from repro.kernels.cim_matmul.kernel import cim_matmul, esam_layer
from repro.kernels.cim_matmul.ref import cim_matmul_ref, esam_layer_ref

__all__ = ["cim_matmul", "esam_layer", "cim_matmul_ref", "esam_layer_ref"]

"""Pallas TPU kernel: IF neuron array — multi-round V_mem accumulation + fire.

Hardware mapping (Sec 3.4 / Fig 5): the neuron's m-bit V_mem register
accumulates each cycle's validity-masked port sum and is compared against the
t-bit V_th register when R_empty.  On TPU the V_mem "register" is a VMEM
accumulator that stays resident across all T rounds — the kernel reads the
whole round sequence for its neuron tile into VMEM, reduces it with a
fori_loop (keeping per-round semantics: integer adds in order), and fuses the
threshold compare + fire, so V_mem never spills to HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret


def _if_kernel(upd_ref, vth_ref, spikes_ref, vmem_ref):
    # upd_ref: [bb, T, bn]; per-round integer accumulation, order preserved.
    bb, T, bn = upd_ref.shape

    def round_step(t, vmem):
        return vmem + upd_ref[:, t, :].astype(jnp.int32)

    vmem = jax.lax.fori_loop(0, T, round_step, jnp.zeros((bb, bn), jnp.int32))
    vmem_ref[...] = vmem
    spikes_ref[...] = (vmem >= vth_ref[...].astype(jnp.int32)).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def if_neuron(
    updates: jax.Array,   # int32[B, T, N] per-cycle contributions
    vth: jax.Array,       # int32[N]
    *,
    block_b: int = 8,
    block_n: int = 128,
    interpret: bool | None = None,
):
    """Returns (spikes int8[B, N], vmem int32[B, N])."""
    if interpret is None:
        interpret = default_interpret()
    B, T, N = updates.shape
    bb, bn = min(block_b, B), min(block_n, N)
    assert B % bb == 0 and N % bn == 0
    grid = (B // bb, N // bn)
    vth2d = vth[None, :].astype(jnp.int32)
    return pl.pallas_call(
        _if_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, T, bn), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N), jnp.int8),
            jax.ShapeDtypeStruct((B, N), jnp.int32),
        ],
        interpret=interpret,
    )(updates, vth2d)

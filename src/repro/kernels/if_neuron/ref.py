"""Pure-jnp oracle for the IF-neuron accumulation kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def if_neuron_ref(updates: jax.Array, vth: jax.Array):
    """Accumulate T per-cycle contributions, then fire.

    Args:
      updates: int32[B, T, N] — summed validity-masked port contributions for
        each of T arbiter rounds (cycles).
      vth: int32[N]
    Returns:
      (spikes int8[B, N], vmem int32[B, N])
    """
    vmem = updates.astype(jnp.int32).sum(axis=1)
    return (vmem >= vth[None, :]).astype(jnp.int8), vmem

"""Jit'd public wrappers for the IF-neuron kernel."""

from repro.kernels.if_neuron.kernel import if_neuron
from repro.kernels.if_neuron.ref import if_neuron_ref

__all__ = ["if_neuron", "if_neuron_ref"]

"""Shared helpers for the Pallas TPU kernels.

All kernels target TPU (VMEM BlockSpecs, MXU-aligned tiles) and are validated
on CPU via ``interpret=True`` — the kernel body runs in Python with the same
block schedule, so correctness transfers.
"""

from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Interpret mode unless we are actually on TPU."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pad_dim_to(x: jax.Array, size: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` of x up to ``size`` (no-op if already there).

    Zero spike bits / zero weight rows are exact padding for the binary CIM
    MAC: a silent spike contributes nothing regardless of the stored bit.
    """
    cur = x.shape[axis]
    if cur == size:
        return x
    assert cur < size, (cur, size)
    import jax.numpy as jnp

    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, size - cur)
    return jnp.pad(x, widths)

"""Shared helpers for the Pallas TPU kernels.

All kernels target TPU (VMEM BlockSpecs, MXU-aligned tiles) and are validated
on CPU via ``interpret=True`` — the kernel body runs in Python with the same
block schedule, so correctness transfers.
"""

from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Interpret mode unless we are actually on TPU."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)

"""Deterministic synthetic handwritten-digit dataset.

The container has no network/dataset access, so MNIST (Sec 4.4.2) is replaced
by a procedural digit distribution: 7x5 glyph bitmaps upscaled to 28x28,
randomly shifted (+-3 px), dilated, and corrupted with per-pixel flip noise.
The generator is fully deterministic in its seed.  DESIGN.md §8 records the
substitution: accuracy on this set validates the BNN->SNN pipeline, not the
paper's absolute 97.64 % MNIST figure.
"""

from __future__ import annotations

import numpy as np

_GLYPHS = {
    0: ["01110", "10001", "10001", "10001", "10001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],
    3: ["01110", "10001", "00001", "00110", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["01110", "10000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00001", "01110"],
}


def _glyph_array(d: int) -> np.ndarray:
    return np.array([[int(c) for c in row] for row in _GLYPHS[d]], dtype=np.float32)


def make_digits(
    n: int, seed: int = 0, flip_noise: float = 0.02, img: int = 28, max_shift: int = 2
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images float32[n, img, img] in {0,1}, labels int32[n]).

    Digits are roughly centred with +-max_shift jitter (MNIST digits are
    size-normalised and centred, so small jitter is the faithful analogue).
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    images = np.zeros((n, img, img), np.float32)
    scale = 3  # 7x5 -> 21x15 core
    for i, d in enumerate(labels):
        g = np.kron(_glyph_array(int(d)), np.ones((scale, scale), np.float32))
        # random dilation: thicken strokes with 50% probability
        if rng.random() < 0.5:
            gpad = np.pad(g, 1)
            g = np.maximum(g, np.maximum(gpad[2:, 1:-1], gpad[1:-1, 2:]))
        h, w = g.shape
        cy, cx = (img - h) // 2, (img - w) // 2
        dy = int(np.clip(cy + rng.integers(-max_shift, max_shift + 1), 0, img - h))
        dx = int(np.clip(cx + rng.integers(-max_shift, max_shift + 1), 0, img - w))
        images[i, dy : dy + h, dx : dx + w] = g
    flips = rng.random(images.shape) < flip_noise
    images = np.where(flips, 1.0 - images, images)
    return images, labels


def corner_crop_mask(img: int = 28, corner: int = 2) -> np.ndarray:
    """Boolean keep-mask removing a corner x corner block from each corner
    (784 -> 768, Sec 4.4.2: 'a 2x2 set of pixels is removed from every
    corner')."""
    keep = np.ones((img, img), bool)
    keep[:corner, :corner] = False
    keep[:corner, -corner:] = False
    keep[-corner:, :corner] = False
    keep[-corner:, -corner:] = False
    return keep


def make_spike_dataset(
    n: int, seed: int = 0, flip_noise: float = 0.02
) -> tuple[np.ndarray, np.ndarray]:
    """Binary spike vectors (n, 768) + labels, ready for the 768:...:10 net."""
    images, labels = make_digits(n, seed, flip_noise)
    mask = corner_crop_mask()
    spikes = images.reshape(n, -1)[:, mask.reshape(-1)]
    assert spikes.shape[1] == 768
    return spikes.astype(np.float32), labels

"""Spike encoders: float frames -> [T, batch, n_in] event tensors.

The temporal plane (``core/esam/temporal.py``) consumes *event streams*: T
timesteps of binary spike planes, one per clock tick of the SNN, with
membrane potential persisting between them.  This module turns static float
frames (the synthetic digit set, or any [batch, n] array in [0, 1]) and
frame *sequences* into such streams, with the three encodings event cameras
and SNN front-ends actually use:

``rate``     Bernoulli rate coding — pixel intensity is a firing probability,
             sampled i.i.d. per timestep.  The workhorse encoding of
             rate-coded SNN inference (more timesteps -> lower variance).
``latency``  time-to-first-spike — each pixel fires exactly once, earlier for
             stronger intensity (and never, below ``eps``).  T events carry
             the whole frame with at most one spike per wire: the
             lowest-energy encoding on the event bus.
``delta``    change detection — a spike wherever the value changed by at
             least ``threshold`` vs the previous frame (DVS-style).  Defined
             on frame sequences; static frames produce one initial burst.

All encoders are deterministic in their ``seed`` (counter-based numpy
``default_rng`` — same seed, same events, any call order), run host-side in
numpy, and emit uint8 {0,1} events; ``pack_events`` converts a stream to the
uint32 bitplane wire format (``repro.core.packing``) the packed temporal
datapath moves, ``[T, batch, ceil(n/32)]``.  Widths that are not multiples
of 32 pack exactly (tail bits are silent — see packing).
"""

from __future__ import annotations

import numpy as np

from repro.core import packing

ENCODERS = ("rate", "latency", "delta")


def rate_encode(
    frames: np.ndarray, n_steps: int, *, seed: int = 0, gain: float = 1.0
) -> np.ndarray:
    """Bernoulli rate coding.

    frames: float[..., n] intensities, clipped to [0, 1] after ``gain``.
    Returns uint8 {0,1}[T, ..., n]: spike_t ~ Bernoulli(clip(gain * x)),
    i.i.d. across timesteps, deterministic in ``seed``.
    """
    assert n_steps >= 1, n_steps
    p = np.clip(np.asarray(frames, np.float64) * gain, 0.0, 1.0)
    rng = np.random.default_rng(np.random.SeedSequence([seed, n_steps]))
    u = rng.random((n_steps, *p.shape))
    return (u < p[None]).astype(np.uint8)


def latency_encode(
    frames: np.ndarray, n_steps: int, *, eps: float = 1e-3
) -> np.ndarray:
    """Time-to-first-spike coding: one spike per active input, earlier for
    stronger intensity.

    frames: float[..., n] in [0, 1].  A pixel with intensity x >= ``eps``
    fires exactly once at t = round((1 - x) * (T - 1)); x = 1 fires at t = 0,
    x = eps fires last, x < eps never fires.  Deterministic (no RNG).
    Returns uint8 {0,1}[T, ..., n] with per-wire spike count <= 1.
    """
    assert n_steps >= 1, n_steps
    x = np.clip(np.asarray(frames, np.float64), 0.0, 1.0)
    t_fire = np.rint((1.0 - x) * (n_steps - 1)).astype(np.int64)
    steps = np.arange(n_steps).reshape((n_steps,) + (1,) * x.ndim)
    return ((steps == t_fire[None]) & (x[None] >= eps)).astype(np.uint8)


def delta_encode(
    frame_seq: np.ndarray, *, threshold: float = 0.1
) -> np.ndarray:
    """Change-detection (DVS-style) coding over a frame sequence.

    frame_seq: float[T, ..., n].  Emits a spike wherever
    |frame_t - frame_{t-1}| >= ``threshold``, with frame_{-1} = 0 — so the
    first event plane is the initial scene and later planes carry only
    change.  Deterministic (no RNG).  Returns uint8 {0,1}[T, ..., n].
    """
    seq = np.asarray(frame_seq, np.float64)
    assert seq.ndim >= 2, seq.shape
    prev = np.concatenate([np.zeros_like(seq[:1]), seq[:-1]], axis=0)
    return (np.abs(seq - prev) >= threshold).astype(np.uint8)


def encode(
    frames: np.ndarray,
    n_steps: int,
    *,
    encoder: str = "rate",
    seed: int = 0,
    **kw,
) -> np.ndarray:
    """Dispatch over ``ENCODERS``.  ``delta`` tiles a static frame into a
    T-long constant sequence first (one initial burst, then silence)."""
    if encoder == "rate":
        return rate_encode(frames, n_steps, seed=seed, **kw)
    if encoder == "latency":
        return latency_encode(frames, n_steps, **kw)
    if encoder == "delta":
        seq = np.broadcast_to(
            np.asarray(frames)[None], (n_steps, *np.asarray(frames).shape))
        return delta_encode(seq, **kw)
    raise ValueError(f"unknown encoder {encoder!r}; options: {ENCODERS}")


def pack_events(events: np.ndarray) -> np.ndarray:
    """{0,1}[T, ..., n] -> uint32[T, ..., ceil(n/32)] wire-format bitplanes."""
    return packing.pack_spikes_np(events)


def encode_digit_events(
    n: int,
    n_steps: int,
    *,
    encoder: str = "rate",
    seed: int = 0,
    flip_noise: float = 0.02,
    packed: bool = False,
    **kw,
) -> tuple[np.ndarray, np.ndarray]:
    """Synthetic digit set as an event stream.

    Returns (events, labels): events uint8[T, n, 768] (or uint32
    [T, n, 24] when ``packed``), labels int32[n].  Deterministic in ``seed``
    (both the digits and the encoder draw from it).
    """
    from repro.data import digits

    frames, labels = digits.make_spike_dataset(n, seed=seed,
                                               flip_noise=flip_noise)
    ev = encode(frames, n_steps, encoder=encoder, seed=seed, **kw)
    return (pack_events(ev) if packed else ev), labels

"""Deterministic, resumable, sharded synthetic pipelines.

``TokenPipeline``: LM token batches.  ``SpikePipeline``: binary spike planes
for the ESAM system, emitted in the bit-packed uint32 wire format
(``repro.core.packing``) so the feed already matches what the packed kernels
and the serving engine move — 8x fewer bytes than int8 spikes.

Every batch is a pure function of (seed, step, host_shard) via counter-based
hashing — so (a) restarts resume bit-exactly from the step counter alone,
(b) any host generates only its shard, (c) no filesystem or network.  The
synthetic token distribution is a Zipfian unigram mix with short-range
structure (repeated n-grams) so losses move meaningfully during example
training runs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    is_encdec: bool = False
    d_model: int = 0            # for encdec frame stubs


class TokenPipeline:
    """Stateless-per-step generator with a resumable step counter."""

    def __init__(self, cfg: PipelineConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.step = 0
        # Zipf-ish unigram distribution, fixed by seed
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1)
        probs = 1.0 / ranks**1.1
        self._probs = probs / probs.sum()
        self._perm = rng.permutation(cfg.vocab_size)

    # ------------------------------------------------------------ #
    def _rng_for(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.cfg.host_id])
        )

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = self._rng_for(step)
        b = cfg.global_batch // cfg.n_hosts
        toks = rng.choice(cfg.vocab_size, size=(b, cfg.seq_len + 1), p=self._probs)
        toks = self._perm[toks]
        # inject short-range structure: copy spans forward so context helps
        for row in range(b):
            n_spans = rng.integers(2, 6)
            for _ in range(n_spans):
                src = rng.integers(0, cfg.seq_len // 2)
                ln = rng.integers(8, 32)
                dst = src + ln + rng.integers(1, 64)
                if dst + ln < cfg.seq_len + 1:
                    toks[row, dst : dst + ln] = toks[row, src : src + ln]
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if cfg.is_encdec:
            batch["src_frames"] = rng.standard_normal(
                (b, cfg.seq_len, cfg.d_model), dtype=np.float32
            )
        return batch

    def next_batch(self) -> dict:
        out = self.batch_at(self.step)
        self.step += 1
        return out

    # ---- checkpointable state ---------------------------------- #
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, d: dict):
        self.step = int(d["step"])

    def seek(self, step: int):
        self.step = step


# ------------------------------------------------------------------ #
# Spike-plane pipeline (ESAM serving / online-learning feed)
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class SpikePipelineConfig:
    batch: int
    seed: int = 0
    flip_noise: float = 0.02
    packed: bool = True          # emit the uint32 bitplane wire format
    n_hosts: int = 1
    host_id: int = 0


class SpikePipeline:
    """Stateless-per-step spike-batch stream with a resumable step counter.

    Each batch holds ``labels`` int32[b] plus either ``spikes_packed``
    uint32[b, ceil(768/32)] (default — ready for
    ``EsamNetwork.forward_fused_packed``) or unpacked ``spikes``
    float32[b, 768].  ``n_in`` records the unpacked width so consumers can
    unpack without out-of-band knowledge.
    """

    N_IN = 768  # corner-cropped 28x28 digits (see repro.data.digits)

    def __init__(self, cfg: SpikePipelineConfig):
        assert cfg.batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.step = 0

    def batch_at(self, step: int) -> dict:
        from repro.core import packing
        from repro.data import digits

        cfg = self.cfg
        b = cfg.batch // cfg.n_hosts
        # counter-based derived seed: bit-exact resume from the step alone
        seed = int(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]).generate_state(1)[0]
        )
        spikes, labels = digits.make_spike_dataset(b, seed=seed,
                                                   flip_noise=cfg.flip_noise)
        batch = {"labels": labels, "n_in": self.N_IN}
        if cfg.packed:
            batch["spikes_packed"] = packing.pack_spikes_np(spikes)
        else:
            batch["spikes"] = spikes
        return batch

    def next_batch(self) -> dict:
        out = self.batch_at(self.step)
        self.step += 1
        return out

    # ---- checkpointable state ---------------------------------- #
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, d: dict):
        self.step = int(d["step"])

    def seek(self, step: int):
        self.step = step

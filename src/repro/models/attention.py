"""Grouped-query attention with RoPE, KV cache, optional sliding window and
optional QK-norm (chameleon).  Pure functions over a params dict.

Layouts: activations [B, S, D]; q/k/v [B, S, H, hd]; KV cache [B, S_max, KV, hd].
TP: q heads sharded over 'model' when divisible (logical axis "act_heads");
the out-projection is row-parallel — XLA inserts the psum.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.params import ParamSpec

NEG_INF = -2.3819763e38


class KVCache(NamedTuple):
    k: jax.Array          # [B, S_max, KV, hd]
    v: jax.Array          # [B, S_max, KV, hd]
    length: jax.Array     # int32[] — tokens currently in the cache


def attn_specs(cfg) -> dict:
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    s = {
        "wq": ParamSpec((cfg.d_model, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv", "head_dim")),
        "wv": ParamSpec((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv", "head_dim")),
        "wo": ParamSpec((cfg.n_heads, hd, cfg.d_model), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = layers.rmsnorm_spec(hd)
        s["k_norm"] = layers.rmsnorm_spec(hd)
    return s


def _qkv(p, cfg, x, positions):
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = constrain(q, "batch", None, "act_heads", None)
    if cfg.qk_norm:
        q = layers.rmsnorm(q, p["q_norm"])
        k = layers.rmsnorm(k, p["k_norm"])
    if cfg.rope_theta:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v, hd


def _sdpa(q, k, v, mask, hd):
    """q: [B,S,H,hd]; k/v: [B,T,KV,hd]; GQA via head grouping."""
    b, s, h, _ = q.shape
    kv = k.shape[2]
    group = h // kv
    qg = q.reshape(b, s, kv, group, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, hd)


def causal_mask(s: int, window: Optional[int] = None) -> jax.Array:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window is not None:
        m &= j > i - window
    return m[None]  # [1, S, S]


def self_attention(
    p: dict,
    cfg,
    x: jax.Array,                  # [B, S, D]
    *,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v, hd = _qkv(p, cfg, x, positions)
    if causal:
        mask = causal_mask(s, window)
    else:
        mask = jnp.ones((1, s, s), bool)
    out = _sdpa(q, k, v, mask, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return constrain(out, "batch", None, "act_embed")


def prefill_attention(p, cfg, x, cache: KVCache, *, window=None):
    """Full-sequence prefill that also fills the KV cache.

    With a sliding window and a ring cache smaller than the prompt, only the
    last ``window`` tokens are stored, rotated so token t sits at slot
    t % window — the exact layout decode_attention continues from."""
    b, s, _ = x.shape
    s_max = cache.k.shape[1]
    positions = jnp.arange(s)[None, :]
    q, k, v, hd = _qkv(p, cfg, x, positions)
    k_st, v_st = k.astype(cache.k.dtype), v.astype(cache.v.dtype)
    if window and s > s_max:
        assert s_max == window, (s_max, window)
        k_tail, v_tail = k_st[:, -window:], v_st[:, -window:]
        shift = (s - window) % window
        k_st = jnp.roll(k_tail, shift, axis=1)
        v_st = jnp.roll(v_tail, shift, axis=1)
    k_cache = jax.lax.dynamic_update_slice(cache.k, k_st, (0, 0, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache.v, v_st, (0, 0, 0, 0))
    mask = causal_mask(s, window)
    out = _sdpa(q, k, v, mask, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    new_cache = KVCache(k=k_cache, v=v_cache, length=jnp.asarray(s, jnp.int32))
    return constrain(out, "batch", None, "act_embed"), new_cache


def decode_attention(p, cfg, x, cache: KVCache, *, window=None):
    """One-token decode step against the KV cache.

    x: [B, 1, D].  The cache holds ``cache.length`` valid tokens; the new
    token is written at position ``length`` (ring-buffered when a sliding
    window is active — the window case sizes the cache to the window).
    """
    b, one, _ = x.shape
    s_max = cache.k.shape[1]
    pos = cache.length
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v, hd = _qkv(p, cfg, x, positions)
    slot = (pos % s_max) if window else pos
    k_cache = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
    # valid slots: the first length+1 (linear cache), or the whole ring once
    # it has wrapped (window case — cache is sized to the window)
    j = jnp.arange(s_max)                                    # [S]
    valid = j < jnp.minimum(pos + 1, s_max) if window else j < pos + 1
    mask = jnp.broadcast_to(valid[None, None, :], (b, 1, s_max))
    out = _sdpa(q, k_cache, v_cache, mask, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    new_cache = KVCache(k=k_cache, v=v_cache, length=pos + 1)
    return constrain(out, "batch", None, "act_embed"), new_cache


def cross_attention_specs(cfg) -> dict:
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    return {
        "wq": ParamSpec((cfg.d_model, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv", "head_dim")),
        "wv": ParamSpec((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv", "head_dim")),
        "wo": ParamSpec((cfg.n_heads, hd, cfg.d_model), ("heads", "head_dim", "embed")),
    }


def cross_attention(p, cfg, x, memory, memory_kv=None):
    """Decoder cross-attention.  memory: [B, T, D] encoder output; if
    memory_kv (precomputed K/V of the memory) is given, reuse it."""
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = constrain(q, "batch", None, "act_heads", None)
    if memory_kv is None:
        k = jnp.einsum("btd,dhk->bthk", memory, p["wk"])
        v = jnp.einsum("btd,dhk->bthk", memory, p["wv"])
    else:
        k, v = memory_kv
    b, s = q.shape[0], q.shape[1]
    mask = jnp.ones((1, s, k.shape[1]), bool)
    out = _sdpa(q, k, v, mask, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return constrain(out, "batch", None, "act_embed")


def init_cache(cfg, batch: int, s_max: int, dtype=jnp.bfloat16) -> KVCache:
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    shape = (batch, s_max, cfg.n_kv_heads, hd)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        length=jnp.zeros((), jnp.int32),
    )


def cache_axes() -> KVCache:
    """Logical axes for the cache pytree (for dry-run shardings)."""
    ax = ("cache_batch", "cache_seq", "cache_kv", "head_dim")
    return KVCache(k=ax, v=ax, length=())

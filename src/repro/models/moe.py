"""Mixture-of-Experts FFN: top-k routing + sort-based dropless expert compute.

Expert parallelism (EP): expert weights are sharded over the 'model' mesh axis
(and optionally FSDP-sharded over 'data' for the 1T-param tier).  Tokens stay
sharded over the batch axes and *replicated* over 'model'; each device
computes only its local experts for its token shard and the partial outputs
are combined with a psum over 'model'.  This avoids all-to-all dispatch
entirely — the combine is the same collective a tensor-parallel dense FFN
needs, so MoE costs no extra collective class on this mesh.

Local expert compute is dropless: the token·top_k assignments routed to local
experts are sorted by expert id and fed through ``jax.lax.ragged_dot`` with an
overflow group for non-local assignments (weights padded with one zero
expert), so no capacity factor, no token dropping.

There is an intentional structural echo of the paper here (DESIGN.md §4):
top-k routing is an arbiter — each token raises a "request" and the router
grants up to k expert ports; the sort-by-expert is the TPU idiom for the
grant-vector formation, exactly as prefix-sum rank selection is for the SNN
arbiter.  The MoE layer is where ESAM's event-driven-selection insight
survives at LM scale.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, current_rules
from repro.models import layers
from repro.models.params import ParamSpec


def moe_specs(cfg) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    # NOTE: expert tensors use 'expert_embed' (never data-sharded) for their
    # d_model dim — the FSDP data shard already lives on 'expert_mlp'.
    return {
        "router": ParamSpec((d, e), ("embed", None), dtype=jnp.float32),
        "w_gate": ParamSpec((e, d, f), ("experts", "expert_embed", "expert_mlp")),
        "w_up": ParamSpec((e, d, f), ("experts", "expert_embed", "expert_mlp")),
        "w_down": ParamSpec((e, f, d), ("experts", "expert_mlp", "expert_embed")),
    }


def _local_expert_ffn(
    x_flat, w_gate, w_up, w_down, expert_ids, gates, n_local, e_offset,
    *, n_experts_total: int, capacity_factor: float = 1.25,
):
    """Capacity-based expert compute for one device's local expert shard.

    EP compute partitioning: each device owns ``n_local`` experts and
    processes at most ``cap`` rows per local expert, where cap is the
    *balanced* per-expert load (T*k / E_total) x capacity_factor — so the
    routed FLOPs split across the model axis instead of being replicated.
    Rows beyond capacity are dropped (standard Switch-style overflow;
    their residual path passes through untouched).

    x_flat: [T, D] local tokens; expert_ids/gates: [T, k] routing decisions;
    w_*: [E_local, ...] local expert weights; e_offset: first local expert id.
    Returns the local partial output [T, D] (zeros for non-local picks).
    """
    t, d = x_flat.shape
    k = expert_ids.shape[1]
    rows = t * k
    cap = max(8, int(np.ceil(rows / n_experts_total * capacity_factor)))
    flat_ids = expert_ids.reshape(-1)                      # [T*k]
    flat_gate = gates.reshape(-1)
    local = (flat_ids >= e_offset) & (flat_ids < e_offset + n_local)
    local_ids = jnp.where(local, flat_ids - e_offset, n_local)
    # position of each row within its expert queue (exclusive running count)
    onehot = (local_ids[:, None] == jnp.arange(n_local)[None, :]).astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot              # [T*k, E_local]
    row_pos = jnp.take_along_axis(
        pos, jnp.minimum(local_ids, n_local - 1)[:, None], axis=1)[:, 0]
    keep = local & (row_pos < cap)
    slot = jnp.where(keep, local_ids * cap + row_pos, n_local * cap)  # drop slot
    token_idx = jnp.arange(rows) // k
    # scatter rows into the per-expert capacity buffer (+1 drop slot)
    x_buf = jnp.zeros((n_local * cap + 1, d), x_flat.dtype).at[slot].set(x_flat[token_idx])
    xe = x_buf[: n_local * cap].reshape(n_local, cap, d)
    gate_h = jnp.einsum("ecd,edf->ecf", xe, w_gate)
    up_h = jnp.einsum("ecd,edf->ecf", xe, w_up)
    h = layers.silu(gate_h) * up_h
    out = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(n_local * cap, d)
    out = jnp.concatenate([out, jnp.zeros((1, d), out.dtype)], axis=0)
    # gather back + gate-weighted combine over the k picks
    contrib = out[slot] * (flat_gate * keep)[:, None].astype(out.dtype)
    y = jnp.zeros((t, d), out.dtype).at[token_idx].add(contrib)
    return y


def _capacity_dispatch(x_flat, expert_ids, gates, n_local, e_offset,
                       n_experts_total, capacity_factor):
    """Shared dispatch: scatter local-expert-routed rows into the
    [E_local, cap, D] capacity buffer.  Returns (xe, slot, token_idx,
    gate_scale, cap)."""
    t, d = x_flat.shape
    k = expert_ids.shape[1]
    rows = t * k
    cap = max(8, int(np.ceil(rows / n_experts_total * capacity_factor)))
    flat_ids = expert_ids.reshape(-1)
    flat_gate = gates.reshape(-1)
    local = (flat_ids >= e_offset) & (flat_ids < e_offset + n_local)
    local_ids = jnp.where(local, flat_ids - e_offset, n_local)
    onehot = (local_ids[:, None] == jnp.arange(n_local)[None, :]).astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    row_pos = jnp.take_along_axis(
        pos, jnp.minimum(local_ids, n_local - 1)[:, None], axis=1)[:, 0]
    keep = local & (row_pos < cap)
    slot = jnp.where(keep, local_ids * cap + row_pos, n_local * cap)
    token_idx = jnp.arange(rows) // k
    x_buf = jnp.zeros((n_local * cap + 1, d), x_flat.dtype).at[slot].set(x_flat[token_idx])
    xe = x_buf[: n_local * cap].reshape(n_local, cap, d)
    gate_scale = flat_gate * keep
    return xe, slot, token_idx, gate_scale, cap


def _token_gather_expert_ffn(
    x_flat, wg, wu, wd, expert_ids, gates, n_local, e_offset,
    *, n_experts_total: int, capacity_factor: float, pod_fsdp: bool,
):
    """§Perf/HC2: weight-stationary FSDP-MoE — move tokens, not weights.

    Expert shards never leave their device: wg/wu [E_local, D/pod, F/data] and
    wd [E_local, F/data, D/pod] stay resident.  Instead, the (much smaller)
    routed-token capacity buffers are all-gathered across the 'data' axis,
    each device computes its F-slice (and D-slice under pod FSDP) of the
    expert FFN, and a psum_scatter returns each device exactly its own rows.
    Per layer-traversal this moves ~activations instead of ~2 TB of expert
    parameters, and — unlike weight gathering — does NOT multiply with
    gradient-accumulation microbatches (weights stream zero bytes).
    """
    t, d = x_flat.shape
    xe, slot, token_idx, gate_scale, cap = _capacity_dispatch(
        x_flat, expert_ids, gates, n_local, e_offset, n_experts_total,
        capacity_factor)
    # gather every data-shard's capacity buffer: [E_local, R=data*cap, D]
    xg = jax.lax.all_gather(xe, "data", axis=1, tiled=True)
    if pod_fsdp:
        # W1 holds a D-shard: contract x against the matching slice, psum the
        # partial over 'pod' (h is small: [E_local, R, F/data])
        d_shard = wg.shape[1]
        lo = jax.lax.axis_index("pod") * d_shard
        xg_slice = jax.lax.dynamic_slice_in_dim(xg, lo, d_shard, axis=2)
        gate_h = jax.lax.psum(jnp.einsum("erd,edf->erf", xg_slice, wg), "pod")
        up_h = jax.lax.psum(jnp.einsum("erd,edf->erf", xg_slice, wu), "pod")
    else:
        gate_h = jnp.einsum("erd,edf->erf", xg, wg)
        up_h = jnp.einsum("erd,edf->erf", xg, wu)
    h = layers.silu(gate_h) * up_h
    out_part = jnp.einsum("erf,efd->erd", h, wd)   # [E_local, R, D/pod] partial in F
    # reduce over 'data' (sum F-slices) while scattering R back to its home shard
    out = jax.lax.psum_scatter(out_part, "data", scatter_dimension=1, tiled=True)
    if pod_fsdp:                                   # restore full D
        out = jax.lax.all_gather(out, "pod", axis=2, tiled=True)
    out = out.reshape(n_local * cap, d)
    out = jnp.concatenate([out, jnp.zeros((1, d), out.dtype)], axis=0)
    contrib = out[slot] * gate_scale[:, None].astype(out.dtype)
    y = jnp.zeros((t, d), out.dtype).at[token_idx].add(contrib)
    return y


def _dropless_expert_ffn(x_flat, w_gate, w_up, w_down, expert_ids, gates, n_experts):
    """Dropless sort+ragged_dot path (single-device / correctness reference)."""
    t, d = x_flat.shape
    k = expert_ids.shape[1]
    flat_ids = expert_ids.reshape(-1)
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_ids)
    token_idx = order // k
    xs = x_flat[token_idx]
    group_sizes = jnp.bincount(flat_ids[order], length=n_experts).astype(jnp.int32)
    gate_h = jax.lax.ragged_dot(xs, w_gate, group_sizes)
    up_h = jax.lax.ragged_dot(xs, w_up, group_sizes)
    h = layers.silu(gate_h) * up_h
    out = jax.lax.ragged_dot(h, w_down, group_sizes)        # [T*k, D]
    out = out * flat_gate[order][:, None].astype(out.dtype)
    y = jnp.zeros((t, d), out.dtype).at[token_idx].add(out)
    return y


def moe_ffn(p: dict, cfg, x: jax.Array) -> jax.Array:
    """x: [B, S, D] -> [B, S, D].  Router in fp32; top-k softmax-after-top-k."""
    b, s, d = x.shape
    router_logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    gates, ids = jax.lax.top_k(router_logits, cfg.top_k)          # [B,S,k]
    gates = jax.nn.softmax(gates, axis=-1)
    rules = current_rules()

    if rules is None:
        # single-device functional path (smoke tests): dropless reference
        y = _dropless_expert_ffn(
            x.reshape(-1, d), p["w_gate"], p["w_up"], p["w_down"],
            ids.reshape(-1, cfg.top_k), gates.reshape(-1, cfg.top_k),
            cfg.n_experts,
        )
        return y.reshape(b, s, d)

    mesh = rules.mesh
    n_model = mesh.shape["model"]
    n_local = cfg.n_experts // n_model
    batch_spec = rules.rules.get("batch")
    expert_fsdp = rules.rules.get("expert_mlp") is not None

    pod_fsdp = rules.rules.get("expert_embed") == "pod"
    gather_tokens = cfg.moe_impl == "gather_tokens" and expert_fsdp

    def per_device(x_loc, ids_loc, gates_loc, wg, wu, wd):
        e_off = jax.lax.axis_index("model") * n_local
        bl, sl, _ = x_loc.shape
        if gather_tokens:
            y = _token_gather_expert_ffn(
                x_loc.reshape(-1, d), wg, wu, wd,
                ids_loc.reshape(-1, cfg.top_k), gates_loc.reshape(-1, cfg.top_k),
                n_local, e_off, n_experts_total=cfg.n_experts,
                capacity_factor=cfg.capacity_factor, pod_fsdp=pod_fsdp,
            )
        else:
            # weight-gathering FSDP: all-gather the expert shards at use
            # (baseline; traffic = full expert params per traversal)
            if expert_fsdp:
                wg = jax.lax.all_gather(wg, "data", axis=2, tiled=True)
                wu = jax.lax.all_gather(wu, "data", axis=2, tiled=True)
                wd = jax.lax.all_gather(wd, "data", axis=1, tiled=True)
            if pod_fsdp:
                wg = jax.lax.all_gather(wg, "pod", axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, "pod", axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, "pod", axis=2, tiled=True)
            y = _local_expert_ffn(
                x_loc.reshape(-1, d), wg, wu, wd,
                ids_loc.reshape(-1, cfg.top_k), gates_loc.reshape(-1, cfg.top_k),
                n_local, e_off, n_experts_total=cfg.n_experts,
                capacity_factor=cfg.capacity_factor,
            )
        y = jax.lax.psum(y, "model")          # combine expert partials (EP)
        return y.reshape(bl, sl, d)

    w_axis = ("experts", "expert_embed", "expert_mlp")
    spec_w = rules.spec(w_axis)
    spec_wd = rules.spec(("experts", "expert_mlp", "expert_embed"))
    spec_x = P(batch_spec, None, None)
    spec_r = P(batch_spec, None, None)
    from repro import compat

    y = compat.shard_map(
        per_device,
        mesh=mesh,
        in_specs=(spec_x, spec_r, spec_r, spec_w, spec_w, spec_wd),
        out_specs=spec_x,
        check=False,
    )(x, ids, gates, p["w_gate"], p["w_up"], p["w_down"])
    return constrain(y, "batch", None, "act_embed")


def moe_aux_loss(router_logits: jax.Array, ids: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style load-balancing loss (mean fraction * mean prob per expert)."""
    probs = jax.nn.softmax(router_logits, axis=-1)
    counts = jax.nn.one_hot(ids[..., 0], n_experts).mean(axis=(0, 1))
    return n_experts * jnp.sum(counts * probs.mean(axis=(0, 1)))

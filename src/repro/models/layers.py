"""Shared layer primitives: norms, embeddings, RoPE, projections."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec


def rmsnorm_spec(dim: int) -> ParamSpec:
    return ParamSpec((dim,), ("act_embed",), init="ones", dtype=jnp.float32)


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    # NOTE (EXPERIMENTS §Perf/HC4 iter2): a custom_vjp variant emitting bf16
    # dx was tried to narrow the TP all-reduces of the residual-stream
    # cotangent.  It changed nothing on the targeted cell (the wide ARs are
    # forward psums XLA places before the dot's output convert) and it
    # REGRESSED the pure-DP sLSTM cell 200x — with bf16 cotangents XLA moved
    # the recurrent-weight grad psum inside the 4096-step time scan.
    # Reverted; the interaction is recorded in EXPERIMENTS.md.
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight
    return out.astype(x.dtype)


def layernorm_specs(dim: int) -> dict:
    return {
        "scale": ParamSpec((dim,), ("act_embed",), init="ones", dtype=jnp.float32),
        "bias": ParamSpec((dim,), ("act_embed",), init="zeros", dtype=jnp.float32),
    }


def layernorm(x: jax.Array, p: dict, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


def embed_spec(vocab: int, dim: int, tied: bool = False) -> ParamSpec:
    """Token-embedding table, sharded over vocab (row-parallel unembed).

    §Perf/HC2 iter3 (refuted): sharding untied lookup tables over the
    *embedding dim* instead would avoid the involuntary-full-remat warning the
    vocab-sharded gather triggers in XLA SPMD — but the partitioner currently
    miscompiles a dim-sharded gather under the layer scan (HLO verifier:
    "Slice dim size 7168 greater than dynamic slice dimension: 448"), so the
    vocab-sharded layout stays until Shardy lands (XLA b/433785288)."""
    del tied
    return ParamSpec((vocab, dim), ("vocab", "embed"), init="scaled", scale=0.02)


def embed_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Logits projection (tied or untied table [V, D])."""
    return jnp.einsum("...d,vd->...v", x, table)


# ------------------------------------------------------------------ #
# RoPE
# ------------------------------------------------------------------ #
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                                # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs    # [..., S, hd/2]
    cos, sin = jnp.cos(angles)[..., None, :], jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_spec(
    d_in: int, d_out: int, axes: tuple, scale: Optional[float] = None
) -> ParamSpec:
    return ParamSpec((d_in, d_out), axes, init="scaled", scale=scale)


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


def silu(x: jax.Array) -> jax.Array:
    return jax.nn.silu(x)

"""Dense MLP blocks (SwiGLU / GELU), tensor-parallel over the 'model' axis."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.params import ParamSpec


def mlp_specs(d_model: int, d_ff: int, *, gated: bool = True) -> dict:
    s = {
        "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed")),
    }
    if gated:
        s["w_gate"] = ParamSpec((d_model, d_ff), ("embed", "mlp"))
    return s


def mlp(p: dict, x: jax.Array, *, gated: bool = True) -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    up = constrain(up, "batch", None, "act_mlp")
    if gated:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = layers.silu(gate) * up
    else:
        h = layers.gelu(up)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return constrain(out, "batch", None, "act_embed")

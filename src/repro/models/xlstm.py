"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar
memory, true recurrence).  Beck et al. 2024 (arXiv:2405.04517), simplified to
the components the assigned 125M config exercises.

mLSTM state per head: C [P, P] matrix memory, n [P] normalizer, m stabilizer.
    C_t = f_t C_{t-1} + i_t v_t k_t^T ;  y_t = (C_t q_t) / max(|n_t.q_t|, 1)
with exponential input gates stabilized by m_t = max(log f_t + m_{t-1}, log i_t).
Decode is O(P^2) per head per token — long_500k state is constant-size, which
is what qualifies xlstm for the long-context shape.

sLSTM: per-unit scalar memory with recurrent weights — a genuine sequential
scan over time (kept on a small subset of layers, as in the paper).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.params import ParamSpec


class MLstmCache(NamedTuple):
    C: jax.Array       # [B, H, P, P]
    n: jax.Array       # [B, H, P]
    m: jax.Array       # [B, H]
    length: jax.Array


class SLstmCache(NamedTuple):
    c: jax.Array       # [B, D]
    n: jax.Array       # [B, D]
    h: jax.Array       # [B, D]
    m: jax.Array       # [B, D]
    length: jax.Array


# ------------------------------------------------------------------ #
# mLSTM
# ------------------------------------------------------------------ #
def mlstm_specs(cfg) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    up = 2 * d
    p = up // h           # heads operate in the up-projected space
    return {
        "w_up": ParamSpec((d, up), ("embed", "mlp")),          # pre-up-projection
        "w_qkv": ParamSpec((up, 3, h, p), (None, None, "heads", "head_dim")),
        "w_if": ParamSpec((up, 2, h), (None, None, "heads"), dtype=jnp.float32),
        "b_if": ParamSpec((2, h), (None, "heads"), init="zeros", dtype=jnp.float32),
        "w_o": ParamSpec((up, up), (None, "mlp")),             # output gate
        "norm": layers.rmsnorm_spec(up),
        "w_down": ParamSpec((up, d), ("mlp", "embed")),
    }


def _mlstm_chunked(q, k, v, logf, logi, chunk: int, quad_dtype=jnp.float32):
    """Chunkwise-parallel mLSTM.  q/k/v: [B,S,H,P]; logf/logi: [B,S,H].

    quad_dtype: operand dtype for the O(L^2) intra-chunk einsums and the
    [H,P,P] chunk-state einsums (accumulation always f32).  HC1 iter3/4 set
    this to bf16 — the gate/stabilizer math stays f32 either way."""
    b, s, h, p = q.shape
    L = min(chunk, s)
    nc = s // L
    qc = q.reshape(b, nc, L, h, p)
    kc = k.reshape(b, nc, L, h, p)
    vc = v.reshape(b, nc, L, h, p)
    lf = logf.reshape(b, nc, L, h).astype(jnp.float32)
    li = logi.reshape(b, nc, L, h).astype(jnp.float32)
    cumf = jnp.cumsum(lf, axis=2)                          # [B,nc,L,H]

    # intra-chunk attention-like term with stabilized gates:
    # w[i,j] = exp(cumf_i - cumf_j + li_j - m_i),   i >= j
    log_w = cumf[:, :, :, None, :] - cumf[:, :, None, :, :] + li[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((L, L), bool))[None, None, :, :, None]
    log_w = jnp.where(mask, log_w, -jnp.inf)
    # chunk-local stabilizer (max over j), combined with carried state below
    m_intra = jnp.max(log_w, axis=3)                        # [B,nc,L,H]
    # inter-chunk: log weight of carried state at step i = cumf_i (+ m_carry)
    # stabilize jointly:
    m_tot = jnp.maximum(m_intra, cumf)                      # [B,nc,L,H]
    w = jnp.exp(log_w - m_tot[:, :, :, None, :])            # [B,nc,L,L,H]
    scale = 1.0 / jnp.sqrt(p)
    # §Perf/HC1 iter3: the O(L^2) intra-chunk tensors dominate HBM traffic —
    # run the quadratic einsums on quad_dtype operands (f32 accumulation),
    # keeping the gate/stabilizer math in f32.
    qk = jnp.einsum("bcihp,bcjhp->bcijh", qc.astype(quad_dtype),
                    kc.astype(quad_dtype),
                    preferred_element_type=jnp.float32) * scale
    wqk = (w * qk).astype(quad_dtype)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", wqk, vc.astype(quad_dtype),
                         preferred_element_type=jnp.float32)
    n_intra = (w * qk).sum(axis=3)                          # [B,nc,L,H]
    # --- chunk summaries for the recurrence ---
    # §Perf/HC1 iter4: the [B,nc,H,P,P] chunk states are the real HBM hog
    # (P=384 matrix memory per head) — build them from quad_dtype operands
    # with f32 accumulation; larger chunks (fewer states) come from the config.
    w_end = jnp.exp(cumf[:, :, -1:, :] - cumf + li)          # [B,nc,L,H]
    wk = (w_end[..., None] * kc.astype(jnp.float32)).astype(quad_dtype)
    Ck = jnp.einsum("bcjhp,bcjhq->bchpq", wk, vc.astype(quad_dtype),
                    preferred_element_type=jnp.float32)
    nk = jnp.einsum("bcjh,bcjhp->bchp", w_end, kc.astype(jnp.float32))
    chunk_f = cumf[:, :, -1, :]                              # [B,nc,H] log decay

    def scan_fn(carry, inp):
        C_prev, n_prev, m_prev = carry
        Cc_, nc_, f_ = inp                                   # [B,H,P,P],[B,H,P],[B,H]
        m_new = jnp.maximum(f_ + m_prev, 0.0)                # new-state log-max (chunk terms stabilized at 0)
        C_new = jnp.exp(f_ + m_prev - m_new)[..., None, None] * C_prev + \
                jnp.exp(-m_new)[..., None, None] * Cc_
        n_new = jnp.exp(f_ + m_prev - m_new)[..., None] * n_prev + \
                jnp.exp(-m_new)[..., None] * nc_
        return (C_new, n_new, m_new), (C_prev, n_prev, m_prev)

    zeroC = jnp.zeros((b, h, p, p), jnp.float32)
    zeron = jnp.zeros((b, h, p), jnp.float32)
    zerom = jnp.full((b, h), -jnp.inf, jnp.float32)
    # m carry starts at -inf => exp(-inf)=0 contribution from the empty state
    _, (C_hist, n_hist, m_hist) = jax.lax.scan(
        scan_fn, (zeroC, zeron, zerom),
        (Ck.swapaxes(0, 1), nk.swapaxes(0, 1), chunk_f.swapaxes(0, 1)),
    )
    C_hist = C_hist.swapaxes(0, 1)                           # [B,nc,H,P,P] state before chunk
    n_hist = n_hist.swapaxes(0, 1)
    m_hist = m_hist.swapaxes(0, 1)                           # [B,nc,H]
    # inter-chunk contribution: weight exp(cumf_i + m_carry - m_tot)
    w_carry = jnp.exp(cumf + m_hist[:, :, None, :] - m_tot)  # [B,nc,L,H]
    y_inter = jnp.einsum("bcihp,bchpq->bcihq",
                         (qc.astype(jnp.float32) * scale).astype(quad_dtype),
                         C_hist.astype(quad_dtype),
                         preferred_element_type=jnp.float32)
    n_inter = jnp.einsum("bcihp,bchp->bcih", qc.astype(jnp.float32) * scale, n_hist)
    y = y_intra + w_carry[..., None] * y_inter
    n_tot = n_intra + w_carry * n_inter
    denom = jnp.maximum(jnp.abs(n_tot), jnp.exp(-m_tot))     # [B,nc,L,H]
    y = y / denom[..., None]
    return y.reshape(b, s, h, p)


def _mlstm_chunked_with_state(q, k, v, logf, logi, chunk: int):
    """Same as _mlstm_chunked but also returns the exact final (C, n, m)
    carry in the decode-step convention (C stored = true_C * exp(-m))."""
    b, s, h, p = q.shape
    y = _mlstm_chunked(q, k, v, logf, logi, chunk)
    # recompute the final carry via the same scan (cheap: state-sized)
    L = min(chunk, s)
    nc = s // L
    kc = k.reshape(b, nc, L, h, p)
    vc = v.reshape(b, nc, L, h, p)
    lf = logf.reshape(b, nc, L, h).astype(jnp.float32)
    li = logi.reshape(b, nc, L, h).astype(jnp.float32)
    cumf = jnp.cumsum(lf, axis=2)
    w_end = jnp.exp(cumf[:, :, -1:, :] - cumf + li)
    Ck = jnp.einsum("bcjh,bcjhp,bcjhq->bchpq", w_end, kc.astype(jnp.float32), vc.astype(jnp.float32))
    nk = jnp.einsum("bcjh,bcjhp->bchp", w_end, kc.astype(jnp.float32))
    chunk_f = cumf[:, :, -1, :]

    def scan_fn(carry, inp):
        C_prev, n_prev, m_prev = carry
        Cc_, nc_, f_ = inp
        m_new = jnp.maximum(f_ + m_prev, 0.0)
        C_new = jnp.exp(f_ + m_prev - m_new)[..., None, None] * C_prev + \
                jnp.exp(-m_new)[..., None, None] * Cc_
        n_new = jnp.exp(f_ + m_prev - m_new)[..., None] * n_prev + \
                jnp.exp(-m_new)[..., None] * nc_
        return (C_new, n_new, m_new), None

    init = (jnp.zeros((b, h, p, p), jnp.float32), jnp.zeros((b, h, p), jnp.float32),
            jnp.full((b, h), -jnp.inf, jnp.float32))
    (C_fin, n_fin, m_fin), _ = jax.lax.scan(
        scan_fn, init, (Ck.swapaxes(0, 1), nk.swapaxes(0, 1), chunk_f.swapaxes(0, 1)))
    return y, (C_fin, n_fin, m_fin)


def mlstm_block(p: dict, cfg, x: jax.Array, *, chunk: int | None = None,
                return_state: bool = False):
    b, s, d = x.shape
    h = cfg.n_heads
    chunk = chunk or getattr(cfg, "mlstm_chunk", 64)
    quad_dtype = jnp.bfloat16 if getattr(cfg, "quad_dtype", "float32") == "bfloat16" \
        else jnp.float32
    up = jnp.einsum("bsd,du->bsu", x, p["w_up"])
    up = constrain(up, "batch", None, "act_mlp")
    qkv = jnp.einsum("bsu,uthp->btshp", up, p["w_qkv"])      # [B,3,S,H,P]
    q, k, v = qkv[:, 0], qkv[:, 1], qkv[:, 2]
    gates = jnp.einsum("bsu,uth->btsh", up.astype(jnp.float32), p["w_if"]) + \
        p["b_if"][None, :, None, :]
    logi, logf = gates[:, 0], jax.nn.log_sigmoid(gates[:, 1])
    if return_state:
        y, state = _mlstm_chunked_with_state(q, k, v, logf, logi, chunk)
    else:
        y = _mlstm_chunked(q, k, v, logf, logi, chunk, quad_dtype)  # [B,S,H,P]
    y = y.reshape(b, s, -1).astype(x.dtype)
    o = jax.nn.sigmoid(jnp.einsum("bsu,uv->bsv", up, p["w_o"]))
    y = layers.rmsnorm(y * o, p["norm"])
    out = jnp.einsum("bsu,ud->bsd", y, p["w_down"])
    out = constrain(out, "batch", None, "act_embed")
    if return_state:
        return out, state
    return out


def mlstm_decode_step(p: dict, cfg, x: jax.Array, cache: MLstmCache):
    b, _, d = x.shape
    h = cfg.n_heads
    up = jnp.einsum("bsd,du->bsu", x, p["w_up"])[:, 0]
    qkv = jnp.einsum("bu,uthp->bthp", up, p["w_qkv"])
    q, k, v = qkv[:, 0], qkv[:, 1], qkv[:, 2]                # [B,H,P]
    pdim = q.shape[-1]
    gates = jnp.einsum("bu,uth->bth", up.astype(jnp.float32), p["w_if"]) + p["b_if"][None]
    logi, logf = gates[:, 0], jax.nn.log_sigmoid(gates[:, 1])
    m_new = jnp.maximum(logf + cache.m, logi)                # [B,H]
    wf = jnp.exp(logf + cache.m - m_new)[..., None]
    wi = jnp.exp(logi - m_new)[..., None]
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C_new = wf[..., None] * cache.C + wi[..., None] * (kf[..., :, None] * vf[..., None, :])
    n_new = wf * cache.n + wi * kf
    qf = q.astype(jnp.float32) / jnp.sqrt(pdim)
    y = jnp.einsum("bhp,bhpq->bhq", qf, C_new)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", qf, n_new)), jnp.exp(-m_new))
    y = (y / denom[..., None]).reshape(b, -1).astype(x.dtype)
    o = jax.nn.sigmoid(jnp.einsum("bu,uv->bv", up, p["w_o"]))
    y = layers.rmsnorm(y * o, p["norm"])
    out = jnp.einsum("bu,ud->bd", y, p["w_down"])[:, None]
    return out, MLstmCache(C=C_new, n=n_new, m=m_new, length=cache.length + 1)


def init_mlstm_cache(cfg, batch: int) -> MLstmCache:
    h = cfg.n_heads
    pdim = 2 * cfg.d_model // h
    return MLstmCache(
        C=jnp.zeros((batch, h, pdim, pdim), jnp.float32),
        n=jnp.zeros((batch, h, pdim), jnp.float32),
        m=jnp.full((batch, h), -jnp.inf, jnp.float32),
        length=jnp.zeros((), jnp.int32),
    )


def mlstm_cache_axes() -> MLstmCache:
    return MLstmCache(
        C=("cache_batch", "act_heads", None, None),
        n=("cache_batch", "act_heads", None),
        m=("cache_batch", "act_heads"),
        length=(),
    )


# ------------------------------------------------------------------ #
# sLSTM
# ------------------------------------------------------------------ #
def slstm_specs(cfg) -> dict:
    d = cfg.d_model
    return {
        "w_x": ParamSpec((d, 4, d), ("embed", None, "mlp")),   # i, f, z, o from input
        "w_h": ParamSpec((d, 4, d), (None, None, "mlp")),      # recurrent
        "b": ParamSpec((4, d), (None, "mlp"), init="zeros", dtype=jnp.float32),
        "norm": layers.rmsnorm_spec(d),
        "w_down": ParamSpec((d, d), ("mlp", "embed")),
    }


def _slstm_step(p, x_t, carry):
    c, n, hprev, m = carry
    pre = jnp.einsum("bd,dgk->bgk", x_t, p["w_x"]) + \
        jnp.einsum("bd,dgk->bgk", hprev.astype(x_t.dtype), p["w_h"])
    pre = pre.astype(jnp.float32) + p["b"][None]
    i_, f_, z_, o_ = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    logf = jax.nn.log_sigmoid(f_)
    m_new = jnp.maximum(logf + m, i_)
    ig = jnp.exp(i_ - m_new)
    fg = jnp.exp(logf + m - m_new)
    c_new = fg * c + ig * jnp.tanh(z_)
    n_new = fg * n + ig
    h_new = jax.nn.sigmoid(o_) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new)


def _slstm_block_impl(p: dict, cfg, x: jax.Array, return_state: bool):
    b, s, d = x.shape
    zeros = jnp.zeros((b, d), jnp.float32)
    init = (zeros, zeros, zeros, jnp.full((b, d), -jnp.inf, jnp.float32))

    def step(carry, x_t):
        new = _slstm_step(p, x_t, carry)
        return new, new[2]

    final, hs = jax.lax.scan(step, init, x.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)                    # [B,S,D]
    y = layers.rmsnorm(y, p["norm"])
    out = jnp.einsum("bsd,dk->bsk", y, p["w_down"])
    if return_state:
        return out, final
    return out


def slstm_block(p: dict, cfg, x: jax.Array, *, return_state: bool = False):
    """True recurrence over time (lax.scan over S).

    §Perf/HC1 iter5: under pjit with batch-sharded x and replicated weights,
    XLA SPMD places the recurrent-weight grad psum INSIDE the time scan
    (2 x 9.4 MB x 4096 steps per layer).  Wrapping the block in shard_map
    pins the replicated-param cotangent reduction to the block boundary —
    one psum per block instead of one per timestep.  Applied only when the
    active rules replicate the weights (pure-DP profile); sharded-weight
    (TP) configs keep the pjit path.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import current_rules

    rules = current_rules()
    replicated = rules is not None and rules.rules.get("mlp") is None
    if not replicated:
        out = _slstm_block_impl(p, cfg, x, return_state)
        if return_state:
            out, final = out
            return constrain(out, "batch", None, "act_embed"), final
        return constrain(out, "batch", None, "act_embed")

    mesh = rules.mesh
    bspec3 = rules.spec(("batch", None, None))
    bspec2 = rules.spec(("batch", None))
    p_specs = jax.tree.map(lambda _: P(), p)
    out_specs = (bspec3, (bspec2, bspec2, bspec2, bspec2)) if return_state else bspec3

    def inner(p_, x_):
        return _slstm_block_impl(p_, cfg, x_, return_state)

    from repro import compat

    return compat.shard_map(
        inner, mesh=mesh, in_specs=(p_specs, bspec3), out_specs=out_specs,
        check=False,
    )(p, x)


def slstm_decode_step(p: dict, cfg, x: jax.Array, cache: SLstmCache):
    carry = (cache.c, cache.n, cache.h, cache.m)
    c, n, h, m = _slstm_step(p, x[:, 0], carry)
    y = layers.rmsnorm(h[:, None].astype(x.dtype), p["norm"])
    out = jnp.einsum("bsd,dk->bsk", y, p["w_down"])
    return out, SLstmCache(c=c, n=n, h=h, m=m, length=cache.length + 1)


def init_slstm_cache(cfg, batch: int) -> SLstmCache:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLstmCache(c=z, n=z, h=z, m=jnp.full((batch, d), -jnp.inf), length=jnp.zeros((), jnp.int32))


def slstm_cache_axes() -> SLstmCache:
    ax = ("cache_batch", "act_mlp")
    return SLstmCache(c=ax, n=ax, h=ax, m=ax, length=())

"""Mamba2 (SSD) blocks — chunked parallel training form + O(1) decode step.

Scalar-per-head decay SSD (Dao & Gu 2024), ngroups=1.  Shapes:
  x  [B, S, H, P]   (P = headdim, H = d_inner/P)
  dt [B, S, H]      (softplus(dt_raw + bias))
  A  [H]            (negative: -exp(A_log))
  B,C [B, S, N]     (state dim N, shared across heads; ngroups=1)

The chunked algorithm splits S into chunks of L: quadratic attention-like
intra-chunk term + an inter-chunk state recurrence (lax.scan over chunks) —
sub-quadratic overall, which is what qualifies the hybrid archs for the
long_500k shape.  Heads are sharded over 'model' (logical "ssm_heads").
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.params import ParamSpec

HEADDIM = 64
CONV_K = 4


class MambaCache(NamedTuple):
    ssm: jax.Array     # [B, H, N, P] state
    conv: jax.Array    # [B, CONV_K-1, conv_dim] rolling conv input buffer
    length: jax.Array  # int32[]


def dims(cfg):
    d_inner = 2 * cfg.d_model
    n_heads = d_inner // HEADDIM
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def mamba_specs(cfg) -> dict:
    d, n = cfg.d_model, cfg.ssm_state
    d_inner, h, conv_dim = dims(cfg)
    common = {
        "A_log": ParamSpec((h,), (None,), init="zeros", dtype=jnp.float32),
        "D": ParamSpec((h,), (None,), init="ones", dtype=jnp.float32),
        "dt_bias": ParamSpec((h,), (None,), init="zeros", dtype=jnp.float32),
        "norm": layers.rmsnorm_spec(d_inner),
        "out_proj": ParamSpec((d_inner, d), ("ssm_inner", "embed")),
    }
    if getattr(cfg, "mamba_split_proj", False):
        # §Perf/HC4 (zamba2): the fused in_proj splits [z|xs|B|C|dt] at
        # offsets that never align with a 16-way-sharded last axis, so XLA
        # reshards every component per layer (all-to-all + collective-permute
        # observed in the HLO).  Separate, individually-sharded projections
        # make every downstream split collective-free; B/C/dt are tiny and
        # stay replicated.
        return dict(common,
            z_proj=ParamSpec((d, d_inner), ("embed", "ssm_inner")),
            xs_proj=ParamSpec((d, d_inner), ("embed", "ssm_inner")),
            bc_proj=ParamSpec((d, 2 * n), ("embed", None)),
            dt_proj=ParamSpec((d, h), ("embed", None)),
            conv_w_xs=ParamSpec((CONV_K, d_inner), ("conv", "ssm_inner")),
            conv_b_xs=ParamSpec((d_inner,), ("ssm_inner",), init="zeros"),
            conv_w_bc=ParamSpec((CONV_K, 2 * n), ("conv", None)),
            conv_b_bc=ParamSpec((2 * n,), (None,), init="zeros"),
        )
    return dict(common,
        in_proj=ParamSpec((d, 2 * d_inner + 2 * n + h), ("embed", "ssm_inner")),
        conv_w=ParamSpec((CONV_K, conv_dim), ("conv", None)),
        conv_b=ParamSpec((conv_dim,), (None,), init="zeros"),
    )


def _split_proj(cfg, proj):
    d_inner, h, _ = dims(cfg)
    n = cfg.ssm_state
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv1d, kernel CONV_K. xbc: [B, S, C]."""
    pad = jnp.pad(xbc, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(CONV_K))
    return jax.nn.silu(out + b)


def _project(p, cfg, x, return_raw: bool = False):
    """(z, xs_conv, B, C, dt_raw[, raw_xbc]) for either parameterization.

    raw_xbc is the pre-conv [xs|B|C] stream (the decode conv-cache payload).
    x: [B,S,D]."""
    d_inner, h, _ = dims(cfg)
    n = cfg.ssm_state
    if "in_proj" in p:
        proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
        proj = constrain(proj, "batch", None, "act_mlp")
        z, xbc_raw, dt_raw = _split_proj(cfg, proj)
        xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
        xs, B, C = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    else:
        z = constrain(jnp.einsum("bsd,de->bse", x, p["z_proj"]), "batch", None, "act_mlp")
        xs_raw = constrain(jnp.einsum("bsd,de->bse", x, p["xs_proj"]),
                           "batch", None, "act_mlp")
        bc_raw = jnp.einsum("bsd,de->bse", x, p["bc_proj"])
        dt_raw = jnp.einsum("bsd,de->bse", x, p["dt_proj"])
        xs = _causal_conv(xs_raw, p["conv_w_xs"], p["conv_b_xs"])
        bc = _causal_conv(bc_raw, p["conv_w_bc"], p["conv_b_bc"])
        B, C = jnp.split(bc, [n], axis=-1)
        xbc_raw = jnp.concatenate([xs_raw, bc_raw], axis=-1)
    if return_raw:
        return z, xs, B, C, dt_raw, xbc_raw
    return z, xs, B, C, dt_raw


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan.  x:[B,S,H,P] dt:[B,S,H] A:[H] B,C:[B,S,N]."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    L = min(chunk, s)
    nc = s // L
    assert s % L == 0, (s, L)
    xc = x.reshape(b, nc, L, h, p)
    dtc = dt.reshape(b, nc, L, h)
    Bc = B.reshape(b, nc, L, n)
    Cc = C.reshape(b, nc, L, n)

    dA = dtc * A[None, None, None, :]                      # [B,nc,L,H] (<=0)
    cum = jnp.cumsum(dA, axis=2)                           # within-chunk cumulative
    # ---- intra-chunk (quadratic within L) ----
    # att[b,c,h,i,j] = exp(cum_i - cum_j) * (C_i . B_j) * dt_j   for i >= j
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])   # [B,nc,L,L,H]
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                        # [B,nc,L,L]
    mask = jnp.tril(jnp.ones((L, L), bool))
    att = decay * cb[..., None] * dtc[:, :, None, :, :]
    att = jnp.where(mask[None, None, :, :, None], att, 0.0)
    att = constrain(att, "batch", None, None, None, "act_heads")
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att.astype(x.dtype), xc)
    # ---- chunk states ----
    # S_c = sum_j exp(cum_L - cum_j) dt_j B_j (x) x_j   -> [B,nc,H,N,P]
    w_end = jnp.exp(cum[:, :, -1:, :] - cum) * dtc                    # [B,nc,L,H]
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", w_end, Bc, xc.astype(jnp.float32))
    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(cum[:, :, -1, :])                           # [B,nc,H]

    def scan_fn(h_prev, inp):
        st, dec = inp                                                 # [B,H,N,P],[B,H]
        h_new = h_prev * dec[:, :, None, None] + st
        return h_new, h_prev

    init = jnp.zeros((b, h, n, p), jnp.float32)
    h_last, h_before = jax.lax.scan(
        scan_fn, init, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    h_before = h_before.swapaxes(0, 1)                                # [B,nc,H,N,P]
    # ---- inter-chunk output: y_i += C_i . (exp(cum_i) * h_prev_chunk) ----
    y_inter = jnp.einsum(
        "bcin,bchnp->bcihp", Cc.astype(jnp.float32),
        h_before) * jnp.exp(cum)[..., None]
    y = y_intra.astype(jnp.float32) + y_inter
    return y.reshape(b, s, h, p), h_last


def mamba_block(p: dict, cfg, x: jax.Array, *, chunk: int = 128) -> jax.Array:
    """Full Mamba2 mixer (training / prefill form). x: [B, S, D]."""
    b, s, d = x.shape
    d_inner, h, conv_dim = dims(cfg)
    n = cfg.ssm_state
    z, xs, B, C, dt_raw = _project(p, cfg, x)
    xs = xs.reshape(b, s, h, HEADDIM)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = _ssd_chunked(xs, dt, A, B.astype(jnp.float32), C.astype(jnp.float32), chunk)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = layers.rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return constrain(out, "batch", None, "act_embed")


def mamba_decode_step(p: dict, cfg, x: jax.Array, cache: MambaCache):
    """One-token decode. x: [B, 1, D].  State update is O(H*P*N) per token."""
    b, _, d = x.shape
    d_inner, h, conv_dim = dims(cfg)
    n = cfg.ssm_state
    if "in_proj" in p:
        proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]       # [B, E]
        z, xbc, dt_raw = _split_proj(cfg, proj)
        conv_w, conv_b = p["conv_w"], p["conv_b"]
    else:
        z = jnp.einsum("bsd,de->bse", x, p["z_proj"])[:, 0]
        xs_raw = jnp.einsum("bsd,de->bse", x, p["xs_proj"])[:, 0]
        bc_raw = jnp.einsum("bsd,de->bse", x, p["bc_proj"])[:, 0]
        dt_raw = jnp.einsum("bsd,de->bse", x, p["dt_proj"])[:, 0]
        xbc = jnp.concatenate([xs_raw, bc_raw], axis=-1)
        conv_w = jnp.concatenate([p["conv_w_xs"], p["conv_w_bc"]], axis=-1)
        conv_b = jnp.concatenate([p["conv_b_xs"], p["conv_b_bc"]], axis=-1)
    # rolling conv buffer: [B, K-1, conv_dim] + current input
    window = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)   # [B, K, C]
    conv_out = jnp.einsum("bkc,kc->bc", window, conv_w) + conv_b
    xbc_t = jax.nn.silu(conv_out)
    new_conv = window[:, 1:, :]
    xs, B, C = jnp.split(xbc_t, [d_inner, d_inner + n], axis=-1)
    xs = xs.reshape(b, h, HEADDIM)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # [B, H]
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A[None, :])                                  # [B, H]
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, B.astype(jnp.float32), xs.astype(jnp.float32))
    h_new = cache.ssm * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), h_new)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(b, d_inner).astype(x.dtype)
    y = layers.rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None, :]
    return out, MambaCache(ssm=h_new, conv=new_conv, length=cache.length + 1)


def init_mamba_cache(cfg, batch: int, dtype=jnp.bfloat16) -> MambaCache:
    d_inner, h, conv_dim = dims(cfg)
    return MambaCache(
        ssm=jnp.zeros((batch, h, cfg.ssm_state, HEADDIM), jnp.float32),  # [B,H,N,P]
        conv=jnp.zeros((batch, CONV_K - 1, conv_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def mamba_cache_axes() -> MambaCache:
    return MambaCache(
        ssm=("cache_batch", "act_heads", None, None),
        conv=("cache_batch", None, None),
        length=(),
    )

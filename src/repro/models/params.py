"""Declarative parameter specs.

Each model family declares its parameters as a pytree of ``ParamSpec`` leaves
(shape + logical axes + init).  From one spec tree we derive:

  * ``init(specs, key)``            — materialized params (smoke tests, examples)
  * ``shape_structs(specs)``        — ShapeDtypeStructs (dry-run: NO allocation)
  * ``logical_axes(specs)``         — same-structure tree of logical-axis tuples
  * ``shardings(specs, rules)``     — NamedShardings for jit in_shardings

This is what lets ``dryrun.py`` lower+compile trillion-parameter configs on a
CPU container: parameters never exist, only their metadata.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | scaled
    scale: Optional[float] = None  # stddev override
    dtype: jnp.dtype = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _leaf_init(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    std = spec.scale
    if std is None:
        fan_in = spec.shape[0] if len(spec.shape) >= 1 else 1
        if len(spec.shape) >= 2:
            fan_in = int(np.prod(spec.shape[:-1]))
        std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)


def init(specs, key: jax.Array):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_leaf_init(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def shape_structs(specs, rules=None):
    """ShapeDtypeStructs, optionally with shardings attached (for .lower())."""

    def one(s: ParamSpec):
        if rules is None:
            return jax.ShapeDtypeStruct(s.shape, s.dtype)
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=rules.sharding(s.axes))

    return jax.tree.map(one, specs, is_leaf=is_spec)


def logical_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def shardings(specs, rules):
    return jax.tree.map(lambda s: rules.sharding(s.axes), specs, is_leaf=is_spec)


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(specs, is_leaf=is_spec))


def param_bytes(specs) -> int:
    return sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(specs, is_leaf=is_spec)
    )

"""Unified language model: specs + train/prefill/decode entry points for every
assigned architecture family.

Layer stacks use ``jax.lax.scan`` over stacked parameters (compile-time and
HLO-size critical for the 61-layer/384-expert configs); activation
checkpointing wraps the scan body.  Family dispatch:

  dense / vlm      pre-norm GQA attn + SwiGLU MLP                   (scan)
  moe              pre-norm GQA attn + MoE FFN                      (scan)
  hybrid (zamba2)  groups of ``shared_attn_period`` Mamba2 blocks,
                   one *shared-weight* attn+MLP block after each    (scan over
                   group; inner scan over the group's mamba layers)
  xlstm            mLSTM blocks with sLSTM at cfg.slstm_layers      (unrolled;
                   12 layers, HLO stays small)
  encdec           bidirectional encoder + causal decoder w/ cross-attn
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import layers, mlp, moe, params as pm, ssm, xlstm
from repro.models.params import ParamSpec


# --------------------------------------------------------------------- #
# spec helpers
# --------------------------------------------------------------------- #
def _stack_specs(n: int, specs):
    """Prepend a scan-stacked 'layers' dim to every spec in the tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale, s.dtype),
        specs,
        is_leaf=pm.is_spec,
    )


def _block_specs(cfg) -> dict:
    """One standard transformer block (attn + ffn + norms)."""
    s = {
        "ln_attn": layers.rmsnorm_spec(cfg.d_model),
        "attn": attn.attn_specs(cfg),
        "ln_ffn": layers.rmsnorm_spec(cfg.d_model),
    }
    if cfg.family == "moe":
        s["ffn"] = moe.moe_specs(cfg)
    else:
        s["ffn"] = mlp.mlp_specs(cfg.d_model, cfg.d_ff)
    return s


def model_specs(cfg) -> dict:
    specs: dict[str, Any] = {
        "embed": layers.embed_spec(cfg.vocab_size, cfg.d_model, cfg.tied_embeddings),
        "ln_f": layers.rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tied_embeddings:
        specs["unembed"] = ParamSpec(
            (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), init="scaled", scale=0.02
        )
    if cfg.family in ("dense", "vlm", "moe"):
        specs["blocks"] = _stack_specs(cfg.n_layers, _block_specs(cfg))
    elif cfg.family == "hybrid":
        period = cfg.shared_attn_period
        assert cfg.n_layers % period == 0
        groups = cfg.n_layers // period
        mamba_layer = {"pre_ln": layers.rmsnorm_spec(cfg.d_model),
                       "mamba": ssm.mamba_specs(cfg)}
        specs["mamba"] = _stack_specs(groups, _stack_specs(period, mamba_layer))
        specs["shared"] = _block_specs(dataclasses.replace(cfg, family="dense"))
    elif cfg.family == "xlstm":
        blocks = []
        for i in range(cfg.n_layers):
            if i in cfg.slstm_layers:
                blocks.append({"kind_slstm": xlstm.slstm_specs(cfg),
                               "ln": layers.rmsnorm_spec(cfg.d_model)})
            else:
                blocks.append({"kind_mlstm": xlstm.mlstm_specs(cfg),
                               "ln": layers.rmsnorm_spec(cfg.d_model)})
        specs["blocks"] = blocks
    elif cfg.family == "encdec":
        enc_cfg = dataclasses.replace(cfg, family="dense")
        enc_block = {
            "ln_attn": layers.rmsnorm_spec(cfg.d_model),
            "attn": attn.attn_specs(enc_cfg),
            "ln_ffn": layers.rmsnorm_spec(cfg.d_model),
            "ffn": mlp.mlp_specs(cfg.d_model, cfg.d_ff),
        }
        dec_block = dict(enc_block)
        dec_block["ln_cross"] = layers.rmsnorm_spec(cfg.d_model)
        dec_block["cross"] = attn.cross_attention_specs(enc_cfg)
        specs["encoder"] = _stack_specs(cfg.enc_layers, enc_block)
        specs["decoder"] = _stack_specs(cfg.dec_layers, dec_block)
        # audio frontend is a stub: inputs arrive as precomputed frame
        # embeddings (DESIGN.md §4); only a projection is learned here.
        specs["frontend_proj"] = ParamSpec((cfg.d_model, cfg.d_model), ("embed", "embed"))
    else:
        raise ValueError(cfg.family)
    return specs


# --------------------------------------------------------------------- #
# block applications
# --------------------------------------------------------------------- #
def _remat_wrap(cfg, fn):
    """Wrap a scan body / block fn with the configured remat policy."""
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:  # "full"
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def _apply_block(bp, cfg, x, *, window=None):
    h = attn.self_attention(bp["attn"], cfg, layers.rmsnorm(x, bp["ln_attn"]),
                            causal=True, window=window)
    x = x + h
    ffn_in = layers.rmsnorm(x, bp["ln_ffn"])
    if cfg.family == "moe":
        x = x + moe.moe_ffn(bp["ffn"], cfg, ffn_in)
    else:
        x = x + mlp.mlp(bp["ffn"], ffn_in)
    return x


def _scan_blocks(stacked, cfg, x, *, window=None):
    def body(carry, bp):
        y = _apply_block(bp, cfg, carry, window=window)
        return y, None

    x, _ = jax.lax.scan(_remat_wrap(cfg, body), x, stacked)
    return x


def _hybrid_forward(p, cfg, x, *, window=None):
    def group_body(carry, gp):
        def mamba_body(c, lp):
            return c + ssm.mamba_block(lp["mamba"], cfg,
                                       layers.rmsnorm(c, lp["pre_ln"])), None

        y, _ = jax.lax.scan(mamba_body, carry, gp)
        y = _apply_block(p["shared"], cfg, y, window=window)   # shared weights
        return y, None

    x, _ = jax.lax.scan(_remat_wrap(cfg, group_body), x, p["mamba"])
    return x


def _xlstm_forward(p, cfg, x):
    def one_block(bp, h_in):
        h = layers.rmsnorm(h_in, bp["ln"])
        if "kind_slstm" in bp:
            return h_in + xlstm.slstm_block(bp["kind_slstm"], cfg, h)
        return h_in + xlstm.mlstm_block(bp["kind_mlstm"], cfg, h)

    one_block = _remat_wrap(cfg, one_block)
    for bp in p["blocks"]:
        x = one_block(bp, x)
    return x


# --------------------------------------------------------------------- #
# top-level entry points
# --------------------------------------------------------------------- #
def _embed_in(params, cfg, tokens):
    x = layers.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    return constrain(x, "batch", None, "act_embed")


def _logits_out(params, cfg, x):
    x = layers.rmsnorm(x, params["ln_f"])
    table = params["embed"] if cfg.tied_embeddings else params["unembed"]
    logits = layers.unembed(x, table)
    return constrain(logits, "batch", None, "vocab")


def forward_train(params, cfg, batch) -> jax.Array:
    """Teacher-forced logits. batch: {'tokens': [B,S]} (+ 'src_frames' for
    encdec audio: [B, S_src, D] precomputed frame embeddings)."""
    if cfg.is_encdec:
        return _encdec_forward(params, cfg, batch)
    x = _embed_in(params, cfg, batch["tokens"])
    window = cfg.window if (cfg.window and batch["tokens"].shape[1] > cfg.window) else None
    if cfg.family in ("dense", "vlm", "moe"):
        x = _scan_blocks(params["blocks"], cfg, x, window=window)
    elif cfg.family == "hybrid":
        x = _hybrid_forward(params, cfg, x, window=window)
    elif cfg.family == "xlstm":
        x = _xlstm_forward(params, cfg, x)
    else:
        raise ValueError(cfg.family)
    return _logits_out(params, cfg, x)


def _encdec_forward(params, cfg, batch):
    frames = batch["src_frames"].astype(jnp.bfloat16)          # [B, S_src, D] stub
    mem = jnp.einsum("bsd,de->bse", frames, params["frontend_proj"])
    mem = constrain(mem, "batch", None, "act_embed")

    def enc_body(carry, bp):
        h = attn.self_attention(bp["attn"], cfg, layers.rmsnorm(carry, bp["ln_attn"]),
                                causal=False)
        y = carry + h
        y = y + mlp.mlp(bp["ffn"], layers.rmsnorm(y, bp["ln_ffn"]))
        return y, None

    def dec_body(carry, bp):
        h = attn.self_attention(bp["attn"], cfg, layers.rmsnorm(carry, bp["ln_attn"]),
                                causal=True)
        y = carry + h
        y = y + attn.cross_attention(bp["cross"], cfg, layers.rmsnorm(y, bp["ln_cross"]), mem)
        y = y + mlp.mlp(bp["ffn"], layers.rmsnorm(y, bp["ln_ffn"]))
        return y, None

    enc_body = _remat_wrap(cfg, enc_body)
    dec_body = _remat_wrap(cfg, dec_body)
    mem, _ = jax.lax.scan(enc_body, mem, params["encoder"])
    x = _embed_in(params, cfg, batch["tokens"])
    x, _ = jax.lax.scan(dec_body, x, params["decoder"])
    return _logits_out(params, cfg, x)


def loss_fn(params, cfg, batch) -> jax.Array:
    logits = forward_train(params, cfg, batch)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


# ===================================================================== #
# Serving: prefill + single-token decode with per-family caches
# ===================================================================== #
class Caches(NamedTuple):
    """Family-polymorphic cache container (unused fields are () placeholders)."""

    attn: Any = ()      # stacked KVCache [L, ...]        (dense/moe/vlm; encdec dec self)
    cross: Any = ()     # (k, v) stacked [L, B, T, KV, hd] (encdec)
    mamba: Any = ()     # stacked MambaCache [G, P, ...]   (hybrid)
    shared: Any = ()    # stacked KVCache [G, ...]         (hybrid shared blocks)
    xl: Any = ()        # tuple of per-block caches        (xlstm)


def _decode_window(cfg, s_max: int):
    """Sliding window active for long-context decode on windowed archs."""
    if cfg.window and s_max > cfg.window:
        return cfg.window
    return None


def init_caches(cfg, batch: int, s_max: int, src_len: Optional[int] = None) -> Caches:
    if cfg.family in ("dense", "vlm", "moe"):
        win = _decode_window(cfg, s_max)
        c = attn.init_cache(cfg, batch, min(s_max, win or s_max))
        stacked = jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), c)
        return Caches(attn=attn.KVCache(*stacked))
    if cfg.family == "hybrid":
        period = cfg.shared_attn_period
        groups = cfg.n_layers // period
        mc = ssm.init_mamba_cache(cfg, batch)
        mstack = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (groups, period) + a.shape), mc)
        win = _decode_window(cfg, s_max)
        sc = attn.init_cache(cfg, batch, min(s_max, win or s_max))
        sstack = jax.tree.map(lambda a: jnp.broadcast_to(a, (groups,) + a.shape), sc)
        return Caches(mamba=ssm.MambaCache(*mstack), shared=attn.KVCache(*sstack))
    if cfg.family == "xlstm":
        xl = []
        for i in range(cfg.n_layers):
            if i in cfg.slstm_layers:
                xl.append(xlstm.init_slstm_cache(cfg, batch))
            else:
                xl.append(xlstm.init_mlstm_cache(cfg, batch))
        return Caches(xl=tuple(xl))
    if cfg.family == "encdec":
        # s_max = decoder (target) cache capacity; src_len = encoder memory len
        src = src_len if src_len is not None else s_max
        c = attn.init_cache(cfg, batch, s_max)
        stacked = jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.dec_layers,) + a.shape), c)
        hd = cfg.hd
        cross_k = jnp.zeros((cfg.dec_layers, batch, src, cfg.n_kv_heads, hd), jnp.bfloat16)
        return Caches(attn=attn.KVCache(*stacked), cross=(cross_k, cross_k))
    raise ValueError(cfg.family)


def cache_axes(cfg) -> Caches:
    """Logical-axis tree matching init_caches (leading 'layers'/group dims)."""
    def stack(ax_tuple, extra=1):
        return tuple(("layers",) * extra) + ax_tuple if isinstance(ax_tuple, tuple) else ax_tuple

    if cfg.family in ("dense", "vlm", "moe"):
        base = attn.cache_axes()
        return Caches(attn=attn.KVCache(
            k=("layers",) + base.k, v=("layers",) + base.v, length=()))
    if cfg.family == "hybrid":
        mb = ssm.mamba_cache_axes()
        mstack = ssm.MambaCache(
            ssm=("layers", "layers") + mb.ssm,
            conv=("layers", "layers") + mb.conv, length=())
        base = attn.cache_axes()
        sstack = attn.KVCache(k=("layers",) + base.k, v=("layers",) + base.v, length=())
        return Caches(mamba=mstack, shared=sstack)
    if cfg.family == "xlstm":
        xl = []
        for i in range(cfg.n_layers):
            xl.append(xlstm.slstm_cache_axes() if i in cfg.slstm_layers
                      else xlstm.mlstm_cache_axes())
        return Caches(xl=tuple(xl))
    if cfg.family == "encdec":
        base = attn.cache_axes()
        ax = ("layers", "cache_batch", "cache_seq", "cache_kv", "head_dim")
        return Caches(attn=attn.KVCache(k=("layers",) + base.k, v=("layers",) + base.v,
                                        length=()), cross=(ax, ax))
    raise ValueError(cfg.family)


def prefill(params, cfg, batch, cache_len: Optional[int] = None) -> tuple[jax.Array, Caches]:
    """Run the full prompt; returns (last-token logits [B, V], filled caches).

    cache_len: KV-cache capacity (>= prompt length); pass prompt + max_new
    when the caches will be decoded into afterwards."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    capacity = max(s, cache_len or s)
    src_len = batch["src_frames"].shape[1] if cfg.is_encdec else None
    caches = init_caches(cfg, b, capacity, src_len=src_len)
    win = cfg.window if (cfg.window and s > cfg.window) else None
    if cfg.family in ("dense", "vlm", "moe"):
        x = _embed_in(params, cfg, tokens)

        def body(carry, xs):
            bp, cache_l = xs
            h, new_c = attn.prefill_attention(
                bp["attn"], cfg, layers.rmsnorm(carry, bp["ln_attn"]), cache_l, window=win)
            y = carry + h
            ffn_in = layers.rmsnorm(y, bp["ln_ffn"])
            if cfg.family == "moe":
                y = y + moe.moe_ffn(bp["ffn"], cfg, ffn_in)
            else:
                y = y + mlp.mlp(bp["ffn"], ffn_in)
            return y, new_c

        x, new_attn = jax.lax.scan(body, x, (params["blocks"], caches.attn))
        logits = _logits_out(params, cfg, x[:, -1:, :])[:, 0]
        return logits, Caches(attn=new_attn)
    if cfg.family == "hybrid":
        return _hybrid_prefill(params, cfg, tokens, caches, win)
    if cfg.family == "xlstm":
        return _xlstm_prefill(params, cfg, tokens, caches)
    if cfg.family == "encdec":
        return _encdec_prefill(params, cfg, batch, caches)
    raise ValueError(cfg.family)


def _hybrid_prefill(params, cfg, tokens, caches, win):
    x = _embed_in(params, cfg, tokens)
    b, s = tokens.shape

    def group_body(carry, xs):
        gp, mcache_g, scache_g = xs

        def mamba_body(c, xs2):
            lp, mcache_l = xs2
            h = layers.rmsnorm(c, lp["pre_ln"])
            # prefill = run the chunked form AND capture the final state
            out, final_state = _mamba_prefill_block(lp["mamba"], cfg, h)
            new_cache = ssm.MambaCache(
                ssm=final_state[0], conv=final_state[1],
                length=jnp.asarray(s, jnp.int32))
            return c + out, new_cache

        y, new_mcaches = jax.lax.scan(mamba_body, carry, (gp, mcache_g))
        h, new_scache = attn.prefill_attention(
            params["shared"]["attn"], cfg,
            layers.rmsnorm(y, params["shared"]["ln_attn"]), scache_g, window=win)
        y = y + h
        y = y + mlp.mlp(params["shared"]["ffn"], layers.rmsnorm(y, params["shared"]["ln_ffn"]))
        return y, (new_mcaches, new_scache)

    x, (new_m, new_s) = jax.lax.scan(group_body, x, (params["mamba"], caches.mamba, caches.shared))
    logits = _logits_out(params, cfg, x[:, -1:, :])[:, 0]
    return logits, Caches(mamba=new_m, shared=new_s)


def _mamba_prefill_block(p, cfg, x, chunk: int = 128):
    """Mamba block that also returns (final ssm state, final conv window)."""
    b, s, d = x.shape
    d_inner, h, conv_dim = ssm.dims(cfg)
    n = cfg.ssm_state
    z, xs_, B, C, dt_raw, xbc_raw = ssm._project(p, cfg, x, return_raw=True)
    conv_tail = xbc_raw[:, -(ssm.CONV_K - 1):, :]              # final conv window
    xs_ = xs_.reshape(b, s, h, ssm.HEADDIM)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h_last = ssm._ssd_chunked(xs_, dt, A, B.astype(jnp.float32), C.astype(jnp.float32), chunk)
    y = y + xs_.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = layers.rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    # h_last: [B,H,N,P] — already the MambaCache layout
    return constrain(out, "batch", None, "act_embed"), (h_last, conv_tail)


def _xlstm_prefill(params, cfg, tokens, caches):
    """Chunkwise prefill with exact recurrent-state capture per block."""
    x = _embed_in(params, cfg, tokens)
    b, s, _ = x.shape
    length = jnp.asarray(s, jnp.int32)
    new_caches = list(caches.xl)
    for i, bp in enumerate(params["blocks"]):
        h = layers.rmsnorm(x, bp["ln"])
        if "kind_slstm" in bp:
            out, (c, n, hst, m) = xlstm.slstm_block(bp["kind_slstm"], cfg, h,
                                                    return_state=True)
            new_caches[i] = xlstm.SLstmCache(c=c, n=n, h=hst, m=m, length=length)
        else:
            out, (C, n, m) = xlstm.mlstm_block(bp["kind_mlstm"], cfg, h,
                                               return_state=True)
            new_caches[i] = xlstm.MLstmCache(C=C, n=n, m=m, length=length)
        x = x + out
    logits = _logits_out(params, cfg, x[:, -1:, :])[:, 0]
    return logits, Caches(xl=tuple(new_caches))


def _encdec_prefill(params, cfg, batch, caches):
    frames = batch["src_frames"].astype(jnp.bfloat16)
    mem = jnp.einsum("bsd,de->bse", frames, params["frontend_proj"])

    def enc_body(carry, bp):
        h = attn.self_attention(bp["attn"], cfg, layers.rmsnorm(carry, bp["ln_attn"]),
                                causal=False)
        y = carry + h
        y = y + mlp.mlp(bp["ffn"], layers.rmsnorm(y, bp["ln_ffn"]))
        return y, None

    mem, _ = jax.lax.scan(enc_body, mem, params["encoder"])
    x = _embed_in(params, cfg, batch["tokens"])

    def dec_body(carry, xs):
        bp, cache_l = xs
        h, new_c = attn.prefill_attention(
            bp["attn"], cfg, layers.rmsnorm(carry, bp["ln_attn"]), cache_l)
        y = carry + h
        ck = jnp.einsum("btd,dhk->bthk", mem, bp["cross"]["wk"]).astype(jnp.bfloat16)
        cv = jnp.einsum("btd,dhk->bthk", mem, bp["cross"]["wv"]).astype(jnp.bfloat16)
        y = y + attn.cross_attention(bp["cross"], cfg, layers.rmsnorm(y, bp["ln_cross"]),
                                     mem, memory_kv=(ck, cv))
        y = y + mlp.mlp(bp["ffn"], layers.rmsnorm(y, bp["ln_ffn"]))
        return y, (new_c, ck, cv)

    x, (new_attn, cks, cvs) = jax.lax.scan(dec_body, x, (params["decoder"], caches.attn))
    logits = _logits_out(params, cfg, x[:, -1:, :])[:, 0]
    return logits, Caches(attn=new_attn, cross=(cks, cvs))


def decode_step(params, cfg, tokens, caches: Caches) -> tuple[jax.Array, Caches]:
    """One new token per sequence. tokens: [B, 1] -> (logits [B, V], caches)."""
    x = _embed_in(params, cfg, tokens)
    if cfg.family in ("dense", "vlm", "moe"):
        win = _decode_window(cfg, int(caches.attn.k.shape[2]) + 1) if cfg.window else None

        def body(carry, xs):
            bp, cache_l = xs
            h, new_c = attn.decode_attention(
                bp["attn"], cfg, layers.rmsnorm(carry, bp["ln_attn"]), cache_l,
                window=cfg.window if cfg.window else None)
            y = carry + h
            ffn_in = layers.rmsnorm(y, bp["ln_ffn"])
            if cfg.family == "moe":
                y = y + moe.moe_ffn(bp["ffn"], cfg, ffn_in)
            else:
                y = y + mlp.mlp(bp["ffn"], ffn_in)
            return y, new_c

        x, new_attn = jax.lax.scan(body, x, (params["blocks"], caches.attn))
        return _logits_out(params, cfg, x)[:, 0], Caches(attn=new_attn)
    if cfg.family == "hybrid":
        def group_body(carry, xs):
            gp, mcache_g, scache_g = xs

            def mamba_body(c, xs2):
                lp, mcache_l = xs2
                h = layers.rmsnorm(c, lp["pre_ln"])
                out, new_c = ssm.mamba_decode_step(lp["mamba"], cfg, h, mcache_l)
                return c + out, new_c

            y, new_m = jax.lax.scan(mamba_body, carry, (gp, mcache_g))
            h, new_s = attn.decode_attention(
                params["shared"]["attn"], cfg,
                layers.rmsnorm(y, params["shared"]["ln_attn"]), scache_g,
                window=cfg.window)
            y = y + h
            y = y + mlp.mlp(params["shared"]["ffn"],
                            layers.rmsnorm(y, params["shared"]["ln_ffn"]))
            return y, (new_m, new_s)

        x, (new_m, new_s) = jax.lax.scan(
            group_body, x, (params["mamba"], caches.mamba, caches.shared))
        return _logits_out(params, cfg, x)[:, 0], Caches(mamba=new_m, shared=new_s)
    if cfg.family == "xlstm":
        new_caches = list(caches.xl)
        for i, bp in enumerate(params["blocks"]):
            h = layers.rmsnorm(x, bp["ln"])
            if "kind_slstm" in bp:
                out, new_caches[i] = xlstm.slstm_decode_step(bp["kind_slstm"], cfg, h, caches.xl[i])
            else:
                out, new_caches[i] = xlstm.mlstm_decode_step(bp["kind_mlstm"], cfg, h, caches.xl[i])
            x = x + out
        return _logits_out(params, cfg, x)[:, 0], Caches(xl=tuple(new_caches))
    if cfg.family == "encdec":
        def dec_body(carry, xs):
            bp, cache_l, ck, cv = xs
            h, new_c = attn.decode_attention(
                bp["attn"], cfg, layers.rmsnorm(carry, bp["ln_attn"]), cache_l)
            y = carry + h
            y = y + attn.cross_attention(bp["cross"], cfg,
                                         layers.rmsnorm(y, bp["ln_cross"]),
                                         None, memory_kv=(ck, cv))
            y = y + mlp.mlp(bp["ffn"], layers.rmsnorm(y, bp["ln_ffn"]))
            return y, new_c

        cks, cvs = caches.cross
        x, new_attn = jax.lax.scan(dec_body, x, (params["decoder"], caches.attn, cks, cvs))
        return _logits_out(params, cfg, x)[:, 0], Caches(attn=new_attn, cross=caches.cross)
    raise ValueError(cfg.family)

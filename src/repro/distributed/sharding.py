"""Logical-axis sharding rules (MaxText-style) for the LM substrate.

Every parameter/activation dimension carries a *logical* axis name; a rule set
maps logical names to mesh axes per (config, mesh, parallelism tier).  The
model code only ever names logical axes — switching DP/TP/FSDP/EP layouts (or
hillclimbing new ones) edits the rule table, not the model.

Mesh axes:  single-pod ("data", "model") = (16, 16)
            multi-pod  ("pod", "data", "model") = (2, 16, 16)
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Mapping, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Thread-local current (mesh, rules) so model code can constrain activations
# without threading plumbing through every call.
_CTX = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Maps logical axis name -> mesh axis (str, tuple of str, or None)."""

    rules: Mapping[str, object]
    mesh: Mesh

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        return P(*[self.rules.get(a) if a is not None else None for a in axes])

    def sharding(self, axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes))

    def mesh_axes(self, logical: str) -> tuple[str, ...]:
        """Mesh axis names a logical axis maps to (empty when replicated)."""
        ax = self.rules.get(logical)
        if ax is None:
            return ()
        return (ax,) if isinstance(ax, str) else tuple(ax)

    def axis_size(self, logical: str) -> int:
        """Number of shards a logical axis is split into on this mesh."""
        axes = self.mesh_axes(logical)
        if not axes:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in axes]))


def _divisible(dim: int, mesh: Mesh, axis: object) -> bool:
    if axis is None:
        return True
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return dim % size == 0


def make_rules(
    mesh: Mesh,
    *,
    n_heads: int,
    n_kv_heads: int,
    n_experts: int = 0,
    d_ff: int = 0,
    d_model: int = 0,
    vocab_size: int = 0,
    fsdp: bool = False,
    zero1: bool = True,
    expert_fsdp: bool = False,
    seq_shard: bool = False,
    global_batch: int = 0,
    pure_dp: bool = False,
) -> ShardingRules:
    """Build the rule table for one architecture on one mesh.

    fsdp:        shard weight 'embed' dims over the data axis (large archs).
    zero1:       shard optimizer-state over the data axis (see optimizer.py).
    expert_fsdp: additionally shard each expert's ff dim over data (kimi-k2:
                 1T params can't live on the model axis alone).
    seq_shard:   context parallelism for long prefill (hillclimb option).
    """
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)
    mdl = "model"
    if pure_dp:
        # small-model profile (EXPERIMENTS §Perf/HC1): replicate every weight,
        # spread the batch over ALL mesh axes — no forward collectives at all,
        # one gradient all-reduce per step.
        batch_axes = batch_axes + (mdl,)
        mdl = None
    # tiny-batch shapes (long-context decode, batch=1) can't shard the batch
    if global_batch and not _divisible(global_batch, mesh, batch_axes):
        batch_axes = ("pod", "data") if has_pod else ("data",)
        if global_batch and not _divisible(global_batch, mesh, batch_axes):
            batch_axes = None

    def if_div(dim, axis):
        return axis if _divisible(dim, mesh, axis) else None

    rules = {
        # activations
        "batch": batch_axes,
        "seq": if_div(0, None) if not seq_shard else "data",
        "act_embed": None,
        "act_heads": if_div(n_heads, mdl),
        "act_mlp": mdl,
        # weights
        "vocab": if_div(vocab_size, mdl),
        "embed": ("data" if fsdp else None),
        "embed_dim": if_div(d_model, mdl),   # untied lookup tables (see embed_spec)
        "heads": if_div(n_heads, mdl),
        "kv": if_div(n_kv_heads, mdl),
        "head_dim": None,
        "mlp": if_div(d_ff, mdl),
        "experts": if_div(n_experts, mdl) if n_experts else None,
        # 2-level FSDP for the 1T tier: expert ff dim over 'data', and the
        # expert d_model dim over 'pod' when a pod axis exists (2 TB of bf16
        # expert params / 512 chips = 4 GB/chip); both gathered at use.
        "expert_embed": ("pod" if (expert_fsdp and has_pod) else None),
        "expert_mlp": ("data" if expert_fsdp else None),
        "ssm_inner": if_div(2 * d_model, mdl),
        "state": None,
        "conv": None,
        "layers": None,      # scan-stacked dim — never sharded
        "groups": None,
        # KV-cache
        "cache_batch": batch_axes,
        "cache_seq": None,
        "cache_kv": if_div(n_kv_heads, mdl),
        # optimizer-state extra sharding axis (ZeRO-1)
        "zero": ("data" if zero1 else None),
    }
    return ShardingRules(rules=rules, mesh=mesh)


# --------------------------------------------------------------------- #
# ESAM spike-plane logical axes (core/esam/plan.py)
# --------------------------------------------------------------------- #
#: Logical axes of the ESAM datapath.  ``spike_batch`` is the request/sample
#: axis of every spike plane and output; ``tile_row`` the pre-synaptic (K)
#: dim of a tile's synapse matrix — never sharded, the CIM contraction stays
#: local; ``tile_col`` the post-synaptic (N) dim, shardable for wide layers
#: (model parallelism: each device owns a slice of a tile's columns and the
#: fired plane is all-gathered onto the inter-tile pulse bus).
ESAM_LOGICAL_AXES = ("spike_batch", "tile_row", "tile_col")


def make_esam_rules(
    mesh: Mesh,
    *,
    batch_axis: object = "data",
    col_axis: Optional[object] = None,
) -> ShardingRules:
    """Rule set for the ESAM spike plane on ``mesh``.

    The default is pure data parallelism: the batch over ``batch_axis``,
    every tile's weights replicated.  Passing ``col_axis`` additionally
    shards hidden-layer columns (``tile_col``) over that mesh axis —
    ``EsamPlan`` applies it per layer only where the width divides evenly,
    so narrow layers (the 10-class readout) silently stay replicated.
    """
    for ax in (batch_axis, col_axis):
        for a in ((ax,) if isinstance(ax, str) else tuple(ax or ())):
            assert a in mesh.axis_names, (a, mesh.axis_names)
    return ShardingRules(
        rules={"spike_batch": batch_axis, "tile_row": None, "tile_col": col_axis},
        mesh=mesh,
    )


def esam_data_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D ``("data",)`` mesh over the first ``n_devices`` local devices."""
    n = len(jax.devices()) if n_devices is None else n_devices
    return make_mesh_axes((n,), ("data",))


# --------------------------------------------------------------------- #
# context plumbing
# --------------------------------------------------------------------- #
@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_CTX, "rules", None)
    _CTX.rules = rules
    try:
        yield
    finally:
        _CTX.rules = prev


def current_rules() -> Optional[ShardingRules]:
    return getattr(_CTX, "rules", None)


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint if a rule context is active."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.sharding(axes))


def make_mesh_axes(shape: tuple[int, ...], names: tuple[str, ...]) -> Mesh:
    from repro import compat

    return compat.make_mesh(shape, names)

"""Gradient compression for the data-parallel all-reduce.

Two tiers (DESIGN.md §6):
  * bf16 cast (``ModelConfig.grad_dtype="bfloat16"``) — wired into the train
    step; halves AR bytes, unbiased.
  * int8 with error feedback — per-tensor symmetric quantization; the
    quantization residual is carried in a state buffer and added back before
    the next step's quantization, so the *accumulated* update is unbiased
    (Seide et al. / 1-bit-Adam lineage).  4x AR reduction; the pod-axis
    (DCN-ish) all-reduce is the intended consumer at 1000+-node scale.

The compress/decompress pair is pure and jit-safe; the trainer owns the
error-feedback state (same pytree structure as the grads).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    q: jax.Array          # int8 payload
    scale: jax.Array      # f32[] per-tensor scale


def compress(x: jax.Array, error: jax.Array | None = None) -> tuple[Compressed, jax.Array]:
    """Quantize x (+ carried error) to int8.  Returns (payload, new_error)."""
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_error = xf - q.astype(jnp.float32) * scale
    return Compressed(q=q, scale=scale), new_error


def decompress(c: Compressed) -> jax.Array:
    return c.q.astype(jnp.float32) * c.scale


def compressed_allreduce(grads, errors, axis_name: str):
    """int8 all-reduce with error feedback, for use inside shard_map.

    grads/errors: matching pytrees.  Returns (mean-reduced f32 grads,
    new error state).  Payload on the wire is int8 (psum of int32-upcast
    payloads keeps exactness across <=2^23 shards).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        c, new_e = compress(g, e)
        summed = jax.lax.psum(c.q.astype(jnp.int32), axis_name)
        scale = jax.lax.pmax(c.scale, axis_name)  # conservative shared scale
        return (summed.astype(jnp.float32) * scale / n).astype(g.dtype), new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = tree.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tree, [o[0] for o in out]),
            jax.tree.unflatten(tree, [o[1] for o in out]))


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

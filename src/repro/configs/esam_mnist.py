"""The paper's own system: 768:256:256:256:10 binary-SNN for digit
classification (Sec 4.4.2), as a config on the same substrate.

Inference is embarrassingly data-parallel: the batched functional plane
(dense binary MAC) shards the sample batch over ('pod','data') and the
weights are replicated (330K synapses = 41 KB of bits).
"""

TOPOLOGY = (768, 256, 256, 256, 10)
READ_PORTS = 4

# Shape used for the ESAM dry-run cell (batched inference serving).
ESAM_BATCH = 65536

"""zamba2-2.7b [hybrid] 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + shared-weight attention blocks.
[arXiv:2411.15242; hf]

Zamba2 scheme: the 54 Mamba2 layers are grouped; one *shared* transformer
block (attn + MLP, weights reused) is applied after every 6th Mamba2 layer.
Long-context (long_500k) runs sub-quadratically: Mamba2 state is O(1) per
token and the shared attention uses a 4096-token sliding window for decode.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    shared_attn_period=6,
    window=4096,
    supports_long_context=True,
    # §Perf/HC4 (bonus): the fused mamba in_proj splits at offsets misaligned
    # with 16-way TP, forcing per-layer all-to-all/collective-permute
    # resharding; separate shard-aligned projections remove it.
    mamba_split_proj=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=512, ssm_state=16, shared_attn_period=2, window=64, remat=False,
)

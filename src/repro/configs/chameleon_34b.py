"""chameleon-34b [vlm] 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
Early-fusion: VQ image tokens live in the 65536-entry vocab, so the frontend
stub supplies precomputed token ids; QK-norm per the Chameleon recipe.
[arXiv:2405.09818; unverified]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    frontend="vq_tokens",
    fsdp=True,                 # 34B params: TP alone leaves ~25 GB fp32 opt state
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
    vocab_size=512, remat=False, fsdp=False,
)

"""kimi-k2-1t-a32b [moe] 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 — trillion-parameter tier.
[arXiv:2501.kimi2; unverified — paper-table config]

Parallelism tier: expert weights are the 1T bulk; they shard over
'model' (EP, 384/16=24 local experts) AND 'data' (FSDP on the expert ff dim,
2048/16=128) — 2 TB of bf16 params / 512 chips = 4 GB/chip.  Optimizer states
run in bf16 with stochastic rounding (train.optimizer), since fp32 m/v alone
would be 8 TB.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    n_experts=384,
    top_k=8,
    head_dim=112,
    fsdp=True,
    expert_fsdp=True,
    optimizer_dtype="bfloat16",
    # §Perf/HC2: weight-stationary MoE (move tokens, not the 2 TB of expert
    # weights — iter4), 4-way grad accumulation for activation temp,
    # dots-saveable remat, capacity factor 1.0 (kills the 25% pad overcompute).
    microbatches=4,
    remat_policy="dots",
    capacity_factor=1.0,
    moe_impl="gather_tokens",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab_size=512, n_experts=8, top_k=2, head_dim=32, remat=False,
    fsdp=False, expert_fsdp=False, optimizer_dtype="float32",
    microbatches=1, remat_policy="full", capacity_factor=1.25,
)

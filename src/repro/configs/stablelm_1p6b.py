"""stablelm-1.6b [dense] 24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=192,
    vocab_size=512, remat=False,
)

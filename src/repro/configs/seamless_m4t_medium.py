"""seamless-m4t-medium [audio] 12L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206 — encoder-decoder, multimodal.  [arXiv:2308.11596; hf]

The assigned "12L" is realized as 12 encoder + 12 decoder layers (M4T-medium
is an encoder-decoder; DESIGN.md §8).  The audio frontend is a STUB: inputs
arrive as precomputed speech-frame embeddings [B, S_src, d_model] via
``input_specs()``; only the backbone is built.  Training shape splits the
assigned seq_len as src=tgt=seq_len/2.  No decode-skip: the decoder serves
decode_32k; long_500k is skipped (full attention, enc-dec).
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=24,
    enc_layers=12,
    dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio_frames",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, enc_layers=2, dec_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab_size=512, remat=False,
)

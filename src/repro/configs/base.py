"""Config system: architecture + shape + parallelism configs.

Every assigned architecture is a ``ModelConfig`` in its own module
(``repro.configs.<id>``); shapes are the four assigned input-shape sets.
``--arch <id>`` in the launchers resolves via ``repro.configs.get(<id>)``.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm | xlstm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    shared_attn_period: int = 0       # zamba2: shared attn block every N layers
    # xLSTM
    slstm_layers: tuple = ()
    # attention details
    head_dim: Optional[int] = None
    rope_theta: float = 10000.0
    qk_norm: bool = False
    tied_embeddings: bool = False
    window: Optional[int] = None      # sliding window used for long-context attn
    # encoder-decoder
    enc_layers: int = 0
    dec_layers: int = 0
    # shape applicability
    supports_long_context: bool = False
    has_decoder: bool = True
    # parallelism tier
    fsdp: bool = False                # FSDP weight sharding over 'data'
    expert_fsdp: bool = False         # additionally shard expert ff over 'data'
    optimizer_dtype: str = "float32"  # "bfloat16" for the 1T tier
    remat: bool = True                # activation checkpointing on the layer scan
    # -- beyond-baseline perf knobs (EXPERIMENTS.md §Perf; the dry-run's
    #    --profile=baseline ignores these, --profile=optimized applies them) --
    sharding_profile: str = "tp"      # "tp" (uniform TP rules) | "pure_dp"
    microbatches: int = 1             # grad-accumulation splits of the global batch
    remat_policy: str = "full"        # "full"=nothing_saveable | "dots" | "none"
    capacity_factor: float = 1.25     # MoE EP capacity factor
    zero1: bool = True                # ZeRO-1 optimizer-state sharding over data
    grad_dtype: str = "float32"       # gradient all-reduce dtype ("bfloat16" halves it)
    mlstm_chunk: int = 64             # mLSTM chunk length (HC1 iter4: 256)
    quad_dtype: str = "float32"       # intra-chunk quadratic operand dtype (HC1: bf16)
    moe_impl: str = "gather_weights"  # FSDP-MoE: "gather_weights" | "gather_tokens"
                                      # (HC2: weight-stationary, move tokens instead)
    mamba_split_proj: bool = False    # HC4: shard-aligned split mamba projections
    # modality frontend stub
    frontend: Optional[str] = None    # None | "audio_frames" | "vq_tokens"

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "zamba2_2p7b",
    "seamless_m4t_medium",
    "stablelm_3b",
    "llama3p2_1b",
    "stablelm_1p6b",
    "granite_3_2b",
    "xlstm_125m",
    "chameleon_34b",
    "llama4_scout_17b_a16e",
    "kimi_k2_1t_a32b",
)

# Mapping used by launchers: --arch accepts either the module id or the
# human-readable paper id.
ALIASES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "stablelm-3b": "stablelm_3b",
    "llama3.2-1b": "llama3p2_1b",
    "stablelm-1.6b": "stablelm_1p6b",
    "granite-3-2b": "granite_3_2b",
    "xlstm-125m": "xlstm_125m",
    "chameleon-34b": "chameleon_34b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "esam-mnist": "esam_mnist",
}


def get(arch: str):
    """Return (module, ModelConfig) for an architecture id."""
    arch_id = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def smoke(arch: str):
    """Reduced same-family config for CPU smoke tests."""
    arch_id = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """The assigned shape cells this architecture runs (skip rules per
    DESIGN.md §4: long_500k needs sub-quadratic; decode needs a decoder)."""
    shapes = ["train_4k", "prefill_32k"]
    if cfg.has_decoder:
        shapes.append("decode_32k")
        if cfg.supports_long_context:
            shapes.append("long_500k")
    return shapes

"""xlstm-125m [ssm] 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks.  d_ff=0 per the assignment: there is no separate FFN; the mLSTM block
carries its own 2x up/down projection (Beck et al. 2024 block design).
sLSTM at layers (1, 7) approximating the paper's 7:1 mixing ratio.
[arXiv:2405.04517; unverified]

Decode state is O(1) in sequence length (matrix memory per head), which
qualifies this arch for long_500k.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_layers=(1, 7),
    rope_theta=0.0,          # xLSTM has no positional rotation
    supports_long_context=True,
    # §Perf/HC1: 125M params (250 MB bf16) never justify 16-way TP — the
    # per-timestep sLSTM collectives made the baseline 82x collective-bound.
    # Replicate all weights, spread the batch over every mesh axis.  At this
    # size ZeRO's param all-gather costs more than replicated opt state
    # (1.1 GB/dev), and the grad all-reduce rides in bf16.
    sharding_profile="pure_dp",
    zero1=False,
    grad_dtype="bfloat16",
    mlstm_chunk=256,
    quad_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=2, n_kv_heads=2,
    vocab_size=512, slstm_layers=(1,), remat=False,
)

"""llama4-scout-17b-a16e [moe] 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    n_experts=16,
    top_k=1,
    head_dim=128,
    frontend="vq_tokens",
    fsdp=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab_size=512, n_experts=4, top_k=1, head_dim=32, remat=False, fsdp=False,
)

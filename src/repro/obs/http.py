"""Prometheus scrape endpoint over stdlib ``http.server``.

One daemon-threaded ``ThreadingHTTPServer`` per :class:`MetricsServer`:

  * ``GET /metrics``       — Prometheus text exposition (0.0.4) of a
    :class:`repro.obs.metrics.Registry`
  * ``GET /metrics.json``  — the registry's JSON snapshot (quantiles
    pre-computed per histogram)
  * ``GET /trace.json``    — the attached tracer's current ring buffer as a
    Perfetto ``trace_event`` document (when a tracer was attached)
  * ``GET /healthz``       — liveness

``port=0`` binds an ephemeral port (``start()`` returns the real one) so
tests and parallel CI lanes never collide.  The handler reads the registry
under its own locks — scrapes are safe while the serving drain is writing.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.obs.metrics import Registry

PROMETHEUS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsServer:
    """Serve a registry (and optionally a tracer) over HTTP."""

    def __init__(self, registry: Registry, *, port: int = 0,
                 host: str = "127.0.0.1", tracer=None):
        self.registry = registry
        self.tracer = tracer
        self._host = host
        self._port = int(port)
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    @property
    def port(self) -> Optional[int]:
        return None if self._httpd is None else self._httpd.server_address[1]

    def start(self) -> int:
        """Bind and serve on a daemon thread; returns the bound port."""
        assert self._httpd is None, "server already started"
        server = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):   # keep launcher stdout clean
                pass

            def _reply(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):
                path = self.path.split("?", 1)[0]
                if path == "/metrics":
                    body = server.registry.prometheus_text().encode()
                    self._reply(200, body, PROMETHEUS_CONTENT_TYPE)
                elif path == "/metrics.json":
                    body = json.dumps(server.registry.snapshot()).encode()
                    self._reply(200, body, "application/json")
                elif path == "/trace.json" and server.tracer is not None:
                    body = json.dumps(server.tracer.export()).encode()
                    self._reply(200, body, "application/json")
                elif path == "/healthz":
                    self._reply(200, b"ok\n", "text/plain")
                else:
                    self._reply(404, b"not found\n", "text/plain")

        self._httpd = ThreadingHTTPServer((self._host, self._port), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="obs-metrics-http",
            daemon=True)
        self._thread.start()
        return self.port

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
            self._thread = None

    def __enter__(self) -> "MetricsServer":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

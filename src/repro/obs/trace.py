"""Request tracing for the serving stack: Perfetto ``trace_event`` spans.

The serving plane's perf story so far lives in aggregate counters
(``SpikeEngine.stats()``) — good for gating, useless for *attribution*: when
a dp8 round stalls you want to see which phase (host pack, dispatch, device
drain, telemetry flush) ate the time, per round, on a timeline.  This module
is the zero-dependency substrate for that:

  * :class:`Tracer` — a thread-safe, bounded ring buffer of trace events
    with an injectable monotonic clock (tests drive it with a fake clock for
    deterministic timestamps).  When the buffer fills, the *oldest* events
    drop and ``dropped`` counts them — memory stays bounded no matter how
    long an engine lives.
  * Chrome/Perfetto ``trace_event`` export (:meth:`Tracer.export`): the JSON
    a drain produces opens directly in https://ui.perfetto.dev (or
    ``chrome://tracing``).  Request lifecycles are async ``"b"``/``"e"``
    span pairs keyed by request id; phases (``queue``/``pack``/``dispatch``/
    ``device_drain``/``telemetry_flush``) are complete ``"X"`` events with
    real measured durations; ladder transitions, sheds, and crashes are
    instants.
  * :func:`validate_trace` — the schema check the CI observability smoke
    (and the tests) run against an exported file: well-formed events, and
    every begun request span accounted for.

Nothing here imports the serving stack (the engine imports *us*), and a
``Tracer`` never touches JAX: spans observe host-side control flow only, so
the traced datapath stays bit-identical to the untraced one (property-tested
in ``tests/test_obs_identity.py``).
"""

from __future__ import annotations

import collections
import contextlib
import itertools
import json
import os
import threading
import time
from typing import Optional

#: the full request lifecycle the engine emits, in order (admit/complete are
#: the async "b"/"e" pair; the rest are "X" phase spans or instants)
REQUEST_PHASES = ("admit", "queue", "pack", "fuse", "dispatch",
                  "device_drain", "telemetry_flush", "complete")

_VALID_PH = {"X", "B", "E", "b", "e", "n", "i", "I", "C", "M"}


class Tracer:
    """Thread-safe bounded trace-event recorder.

    ``clock`` is any zero-arg callable returning seconds (monotonic);
    timestamps are microseconds relative to construction.  ``capacity``
    bounds memory: the ring holds at most that many events and evicts the
    oldest (``dropped`` counts evictions).
    """

    def __init__(self, *, clock=time.monotonic, capacity: int = 1 << 16,
                 pid: Optional[int] = None):
        assert capacity >= 1, capacity
        self._clock = clock
        self._t0 = clock()
        self._lock = threading.Lock()
        self._events: collections.deque = collections.deque(maxlen=capacity)
        self.capacity = capacity
        self.dropped = 0
        self.pid = os.getpid() if pid is None else int(pid)
        self._ids = itertools.count(1)   # thread-safe in CPython

    # ------------------------------------------------------------------ #
    # emission
    # ------------------------------------------------------------------ #
    def now_us(self) -> float:
        """Microseconds since this tracer was created (injected clock)."""
        return (self._clock() - self._t0) * 1e6

    def next_id(self) -> int:
        """A fresh id for an async (request) span."""
        return next(self._ids)

    def _push(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)

    def _base(self, name: str, ph: str, cat: str, ts_us, args: dict) -> dict:
        ev = {"name": name, "ph": ph, "cat": cat,
              "ts": float(self.now_us() if ts_us is None else ts_us),
              "pid": self.pid, "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        return ev

    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 cat: str = "serve", **args) -> None:
        """One complete ("X") span with an explicit start and duration."""
        ev = self._base(name, "X", cat, ts_us, args)
        ev["dur"] = max(0.0, float(dur_us))
        self._push(ev)

    def instant(self, name: str, *, cat: str = "serve", **args) -> None:
        ev = self._base(name, "i", cat, None, args)
        ev["s"] = "t"                    # thread-scoped instant
        self._push(ev)

    def begin_async(self, name: str, span_id: int, *, cat: str = "request",
                    **args) -> None:
        ev = self._base(name, "b", cat, None, args)
        ev["id"] = int(span_id)
        self._push(ev)

    def end_async(self, name: str, span_id: int, *, cat: str = "request",
                  **args) -> None:
        ev = self._base(name, "e", cat, None, args)
        ev["id"] = int(span_id)
        self._push(ev)

    @contextlib.contextmanager
    def span(self, name: str, *, cat: str = "serve", **args):
        """Context manager emitting one "X" span around the body."""
        t0 = self.now_us()
        try:
            yield
        finally:
            self.complete(name, t0, self.now_us() - t0, cat=cat, **args)

    # ------------------------------------------------------------------ #
    # inspection + export
    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self) -> list[dict]:
        """A snapshot copy of the buffered events (oldest first)."""
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    def export(self, path: Optional[str] = None, *,
               process_name: str = "esam-serve") -> dict:
        """The Chrome/Perfetto ``trace_event`` JSON document (optionally
        written to ``path``).  Open it in ui.perfetto.dev."""
        meta = [{
            "name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
            "ts": 0.0, "cat": "__metadata",
            "args": {"name": process_name},
        }]
        doc = {
            "traceEvents": meta + self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped,
                          "capacity": self.capacity},
        }
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


def validate_trace(doc: dict) -> dict:
    """Validate a ``trace_event`` document; raises ``ValueError`` on schema
    violations.  Returns a summary the CI smoke asserts on::

        {"events", "request_begun", "request_closed", "request_close_fraction",
         "phases"}

    ``request_close_fraction`` is closed/begun async request spans — the
    acceptance criterion wants it >= 0.99 for accepted requests (every
    admitted request must reach a terminal state that closes its span).
    """
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace document must be a dict with 'traceEvents'")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    begun: set = set()
    closed: set = set()
    phases: dict[str, int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object: {ev!r}")
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {i} missing '{key}': {ev!r}")
        if not isinstance(ev["name"], str) or ev["ph"] not in _VALID_PH:
            raise ValueError(f"event {i} bad name/ph: {ev!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event {i} bad ts: {ev!r}")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"X event {i} needs dur >= 0: {ev!r}")
        if ev["ph"] in ("b", "e"):
            if "id" not in ev:
                raise ValueError(f"async event {i} needs an id: {ev!r}")
            if ev.get("cat") == "request":
                (begun if ev["ph"] == "b" else closed).add(ev["id"])
        phases[ev["name"]] = phases.get(ev["name"], 0) + 1
    unmatched = closed - begun
    if unmatched:
        raise ValueError(f"request spans closed but never begun: "
                         f"{sorted(unmatched)[:8]}")
    return {
        "events": len(events),
        "request_begun": len(begun),
        "request_closed": len(begun & closed),
        "request_close_fraction": (len(begun & closed) / len(begun)
                                   if begun else 1.0),
        "phases": phases,
    }

"""Metrics registry: counters, gauges, and log-bucketed latency histograms.

Zero-dependency (stdlib only) and cheap enough to leave on in the serve
path: a counter increment is one lock + one float add, a histogram
observation is a bit-length bucket lookup — no sample is ever stored, so
p50/p95/p99/p99.9 come from the bucket counts (log-spaced bounds, so the
quantile error is bounded by the bucket ratio) and memory stays O(buckets)
for the life of the process.

Two export surfaces:

  * :meth:`Registry.prometheus_text` — the Prometheus text exposition format
    (version 0.0.4), served over HTTP by :mod:`repro.obs.http`; histograms
    render as cumulative ``_bucket{le=...}`` series plus ``_sum``/``_count``.
  * :meth:`Registry.snapshot` — a JSON-able dict the traffic harness folds
    into ``TrafficReport`` and ``--report-json`` writes to disk, with
    pre-computed quantiles per histogram.

``REGISTRY`` is the process-global default (one scrape endpoint per
process); anything that wants isolation (tests, per-lane benches) builds its
own ``Registry``.
"""

from __future__ import annotations

import math
import threading
from typing import Optional

#: default histogram bounds: geometric, 1us .. ~67s in factor-of-2 steps —
#: 27 buckets (+inf) covers a pack span to a chaos-stalled drain round with
#: a bounded-by-2x quantile error, in O(1) memory per histogram
DEFAULT_BOUNDS = tuple(1e-6 * (2.0 ** i) for i in range(27))


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _escape(v) -> str:
    return (str(v).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _render_labels(labels: tuple, extra: tuple = ()) -> str:
    items = tuple(labels) + tuple(extra)
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{_escape(v)}"' for k, v in items) + "}"


class _Instrument:
    kind = "untyped"

    def __init__(self, name: str, help_: str, labels: dict):
        self.name = name
        self.help = help_
        self.labels = _label_key(labels)
        self._lock = threading.Lock()

    @property
    def full_name(self) -> str:
        return self.name + _render_labels(self.labels)


class Counter(_Instrument):
    """Monotonically increasing float counter."""

    kind = "counter"

    def __init__(self, name, help_="", labels=()):
        super().__init__(name, help_, dict(labels))
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        assert n >= 0, f"counter {self.name} cannot decrease (inc {n})"
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge(_Instrument):
    """Set-to-current-value instrument (queue depth, ladder level, health)."""

    kind = "gauge"

    def __init__(self, name, help_="", labels=()):
        super().__init__(name, help_, dict(labels))
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram(_Instrument):
    """Log-bucketed histogram: quantiles without storing samples.

    ``bounds`` are the inclusive upper edges (ascending); one implicit +Inf
    bucket catches the tail.  ``quantile(q)`` linearly interpolates inside
    the covering bucket, so with the default factor-2 bounds the estimate is
    within 2x of the true value — the right fidelity for "did p99 blow up",
    at O(len(bounds)) memory forever.
    """

    kind = "histogram"

    def __init__(self, name, help_="", labels=(), bounds=DEFAULT_BOUNDS):
        super().__init__(name, help_, dict(labels))
        assert bounds and all(b > a for a, b in zip(bounds, bounds[1:])), \
            f"bounds must be ascending: {bounds}"
        self.bounds = tuple(float(b) for b in bounds)
        self._counts = [0] * (len(self.bounds) + 1)   # +Inf tail bucket
        self._sum = 0.0
        self._count = 0

    def _bucket_index(self, v: float) -> int:
        lo, hi = 0, len(self.bounds)
        while lo < hi:                 # first bound >= v (bisect, no import)
            mid = (lo + hi) // 2
            if self.bounds[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        return lo

    def observe(self, v: float) -> None:
        v = float(v)
        i = self._bucket_index(v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (0 < q <= 1) from the bucket counts."""
        assert 0.0 < q <= 1.0, q
        with self._lock:
            total = self._count
            counts = list(self._counts)
        if total == 0:
            return 0.0
        target = q * total
        cum = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = 0.0 if i == 0 else self.bounds[i - 1]
                hi = self.bounds[i] if i < len(self.bounds) else self.bounds[-1]
                frac = (target - cum) / c
                return lo + (hi - lo) * min(1.0, max(0.0, frac))
            cum += c
        return self.bounds[-1]

    def cumulative_buckets(self) -> list[tuple[float, int]]:
        """Prometheus-style cumulative ``(le, count)`` pairs, +Inf last."""
        with self._lock:
            counts = list(self._counts)
        out, cum = [], 0
        for b, c in zip(self.bounds, counts):
            cum += c
            out.append((b, cum))
        out.append((math.inf, cum + counts[-1]))
        return out


class Registry:
    """Name-keyed instrument registry with idempotent getters.

    ``counter``/``gauge``/``histogram`` return the existing instrument when
    one was already registered under the same (name, labels) — callers can
    re-derive handles without coordination.  Re-registering a name as a
    different kind raises.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[tuple, _Instrument] = {}

    def _get(self, cls, name: str, help_: str, labels: dict, **kw):
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._metrics.get(key)
            if inst is None:
                inst = cls(name, help_, labels, **kw)
                self._metrics[key] = inst
            elif not isinstance(inst, cls):
                raise ValueError(
                    f"{name} already registered as {inst.kind}")
            return inst

    def counter(self, name: str, help_: str = "", **labels) -> Counter:
        return self._get(Counter, name, help_, labels)

    def gauge(self, name: str, help_: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help_, labels)

    def histogram(self, name: str, help_: str = "",
                  bounds=DEFAULT_BOUNDS, **labels) -> Histogram:
        return self._get(Histogram, name, help_, labels, bounds=bounds)

    def instruments(self) -> list[_Instrument]:
        with self._lock:
            return list(self._metrics.values())

    def get(self, name: str, **labels) -> Optional[_Instrument]:
        with self._lock:
            return self._metrics.get((name, _label_key(labels)))

    # ------------------------------------------------------------------ #
    # export surfaces
    # ------------------------------------------------------------------ #
    def prometheus_text(self) -> str:
        """Prometheus text exposition (format version 0.0.4)."""
        by_family: dict[str, list[_Instrument]] = {}
        for inst in self.instruments():
            by_family.setdefault(inst.name, []).append(inst)
        lines = []
        for name in sorted(by_family):
            family = by_family[name]
            kind = family[0].kind
            help_ = next((i.help for i in family if i.help), "")
            if help_:
                lines.append(f"# HELP {name} {_escape(help_)}")
            lines.append(f"# TYPE {name} {kind}")
            for inst in sorted(family, key=lambda i: i.labels):
                if isinstance(inst, Histogram):
                    for le, cum in inst.cumulative_buckets():
                        le_s = "+Inf" if math.isinf(le) else repr(le)
                        lines.append(
                            f"{name}_bucket"
                            f"{_render_labels(inst.labels, (('le', le_s),))}"
                            f" {cum}")
                    lines.append(f"{name}_sum"
                                 f"{_render_labels(inst.labels)} {inst.sum}")
                    lines.append(f"{name}_count"
                                 f"{_render_labels(inst.labels)} {inst.count}")
                else:
                    lines.append(f"{name}{_render_labels(inst.labels)} "
                                 f"{inst.value}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-able snapshot: full metric name -> {type, value | quantiles}.

        Histograms carry ``count``/``sum`` plus p50/p95/p99/p99.9 — the same
        percentile ladder ``TrafficReport`` reports, so the two reconcile.
        """
        out: dict[str, dict] = {}
        for inst in self.instruments():
            if isinstance(inst, Histogram):
                out[inst.full_name] = {
                    "type": inst.kind,
                    "count": inst.count,
                    "sum": inst.sum,
                    "p50": inst.quantile(0.50),
                    "p95": inst.quantile(0.95),
                    "p99": inst.quantile(0.99),
                    "p999": inst.quantile(0.999),
                }
            else:
                out[inst.full_name] = {"type": inst.kind, "value": inst.value}
        return out


#: the process-global registry (one scrape surface per process); modules that
#: need isolation build their own Registry instead
REGISTRY = Registry()

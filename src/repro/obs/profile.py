"""Device profiling hooks: ``jax.profiler`` capture + compile-time lanes.

Three profiling surfaces for the serving stack:

  * :class:`DeviceProfiler` — arms ``jax.profiler`` trace capture around a
    window of serving drain rounds (skip the first ``skip_rounds``, capture
    ``n_rounds``).  The engine calls ``on_round_start``/``on_round_end`` per
    dispatch round; the profiler starts/stops exactly once, never raises
    into the drain (a failed backend capture is recorded in ``error``
    instead — profiling must not take down serving), and books the captured
    window into the metrics registry.  The resulting logdir opens in
    TensorBoard/Perfetto next to the host-side ``Tracer`` export.
  * :func:`record_warmup_times` — folds ``SpikeEngine.warmup()`` /
    ``EsamPlan.warmup()`` per-shape compile seconds into registry gauges
    (``esam_warmup_compile_seconds{shape=...}``), so AOT warmup and
    persistent-cache behavior are visible on the scrape endpoint rather
    than only in a returned dict.
  * :func:`kernel_timer` — a per-kernel timing lane: a context manager that
    observes one kernel call's wall time into a labeled histogram
    (``esam_kernel_seconds{kernel=...,lane=...}``).  ``bench_kernels`` runs
    the popcount mega-kernel and the packed cascade through it so per-kernel
    quantiles ride in the same registry as the serving metrics.
"""

from __future__ import annotations

import contextlib
import time
from typing import Optional

from repro.obs.metrics import Registry


class DeviceProfiler:
    """Capture a ``jax.profiler`` trace around N serving drain rounds."""

    def __init__(self, logdir: str, *, skip_rounds: int = 0,
                 n_rounds: int = 1, registry: Optional[Registry] = None,
                 profiler=None):
        assert n_rounds >= 1, n_rounds
        self.logdir = logdir
        self.skip_rounds = int(skip_rounds)
        self.n_rounds = int(n_rounds)
        self.registry = registry
        self._profiler = profiler      # injectable for tests; None => jax's
        self.active = False
        self.done = False
        self.captured = 0
        self.error: Optional[str] = None
        self._seen = 0

    def _jax_profiler(self):
        if self._profiler is None:
            import jax
            self._profiler = jax.profiler
        return self._profiler

    def on_round_start(self, round_idx: int) -> None:
        """Called by the engine before each dispatch round."""
        if self.done or self.active:
            return
        if self._seen < self.skip_rounds:
            self._seen += 1
            return
        try:
            self._jax_profiler().start_trace(self.logdir)
            self.active = True
        except Exception as e:  # noqa: BLE001 — profiling never kills serving
            self.error = f"{type(e).__name__}: {e}"
            self.done = True

    def on_round_end(self, round_idx: int) -> None:
        """Called by the engine after each dispatch round."""
        if not self.active:
            return
        self.captured += 1
        if self.captured >= self.n_rounds:
            self.stop()

    def stop(self) -> None:
        """Stop an in-flight capture (idempotent; also the abort path)."""
        if self.active:
            try:
                self._jax_profiler().stop_trace()
            except Exception as e:  # noqa: BLE001
                self.error = f"{type(e).__name__}: {e}"
            self.active = False
        self.done = True
        if self.registry is not None:
            self.registry.gauge(
                "esam_profile_rounds_captured",
                "drain rounds inside the jax.profiler capture window",
            ).set(self.captured)


def record_warmup_times(registry: Registry, times: dict,
                        prefix: str = "static") -> None:
    """Fold a ``warmup()`` result dict into per-shape compile-time gauges.

    Accepts both shapes the repo produces: ``EsamPlan.warmup`` returns
    ``{batch: seconds}``; ``SpikeEngine.warmup`` returns
    ``{"static": {batch: s}, "event_t4": {batch: s}, ..., "telemetry_s": s,
    "total_s": s}`` — nesting is flattened into the ``shape`` label.
    """
    for key, val in times.items():
        if isinstance(val, dict):
            record_warmup_times(registry, val, prefix=str(key))
            continue
        shape = (f"{prefix}_b{key}" if isinstance(key, int)
                 else (str(key) if prefix == "static" else f"{prefix}_{key}"))
        registry.gauge(
            "esam_warmup_compile_seconds",
            "AOT warmup compile seconds per plan shape",
            shape=shape,
        ).set(float(val))


@contextlib.contextmanager
def kernel_timer(registry: Registry, kernel: str, *, lane: str = "default",
                 clock=time.perf_counter):
    """Time one kernel call into ``esam_kernel_seconds{kernel=,lane=}``.

    The caller is responsible for making the timed section synchronous
    (``jax.block_until_ready`` inside the body) — this lane measures wall
    time, like every bench in the repo.
    """
    hist = registry.histogram(
        "esam_kernel_seconds", "per-kernel wall time", kernel=kernel,
        lane=lane)
    t0 = clock()
    try:
        yield hist
    finally:
        hist.observe(clock() - t0)

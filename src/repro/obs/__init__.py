"""Unified observability plane: tracing, metrics, and device profiling.

The serving stack (``SpikeEngine``, ``FaultAwareRouter``, the traffic
harness, the online-learning driver) takes one optional
:class:`Observability` handle and, when given, emits:

  * request-lifecycle + round-phase spans into an :class:`~repro.obs.trace.
    Tracer` (exportable as Perfetto ``trace_event`` JSON),
  * counters / gauges / latency histograms into a
    :class:`~repro.obs.metrics.Registry` (scraped over HTTP by
    :class:`~repro.obs.http.MetricsServer`, snapshotted into
    ``TrafficReport`` and ``--report-json``),
  * ``jax.profiler`` captures around drain rounds via a
    :class:`~repro.obs.profile.DeviceProfiler`.

Everything defaults **off** (``observability=None``), and the off path is
property-tested bit-identical to the instrumented path — spans observe,
never perturb.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.obs.metrics import REGISTRY, Registry
from repro.obs.profile import DeviceProfiler
from repro.obs.trace import Tracer

__all__ = ["Observability", "Registry", "REGISTRY", "Tracer",
           "DeviceProfiler"]


@dataclasses.dataclass
class Observability:
    """The bundle a serving component is instrumented with.

    Any field may be None — tracing, metrics, and profiling are independent
    lanes; a component guards each emission on the lane being present.
    """

    tracer: Optional[Tracer] = None
    metrics: Optional[Registry] = None
    profile: Optional[DeviceProfiler] = None

    @classmethod
    def enabled(cls, *, clock=time.monotonic, capacity: int = 1 << 16,
                registry: Optional[Registry] = None,
                profile: Optional[DeviceProfiler] = None) -> "Observability":
        """Tracer + metrics on (the common case); profiling opt-in."""
        return cls(tracer=Tracer(clock=clock, capacity=capacity),
                   metrics=REGISTRY if registry is None else registry,
                   profile=profile)

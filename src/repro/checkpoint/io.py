"""Sharded checkpointing: npz-per-step + JSON manifest, mesh-shape agnostic.

Save: every leaf is written under its pytree path; the manifest records
shapes/dtypes and the step.  Restore: leaves are loaded and device_put against
the *target* shardings — which may belong to a different mesh than the one
that saved (elastic restart: 512 -> 256 chips re-sharding is a device_put).
Writes are atomic (tmp dir + rename) so a crash mid-save never corrupts the
latest checkpoint.  An async mode hands the write to a daemon thread so the
train loop never blocks on IO.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        flat[key] = leaf
    return flat


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """npz can't store ml_dtypes (bfloat16 etc.) — persist as a bit-view."""
    dtype = str(arr.dtype)
    if arr.dtype.kind not in "fiub?" or dtype == "bfloat16":
        return arr.view(np.uint16) if dtype == "bfloat16" else arr, dtype
    return arr, dtype


def _decode(arr: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        import ml_dtypes
        return arr.view(ml_dtypes.bfloat16)
    return arr


def save(tree, directory: str, step: int, *, extra: Optional[dict] = None,
         async_: bool = False) -> threading.Thread | None:
    """Write checkpoint ``directory/step_<N>``. Returns the writer thread when
    async (join it before exiting the process)."""
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    encoded = {k: _encode(v) for k, v in flat.items()}
    flat = {k: v[0] for k, v in encoded.items()}
    dtypes = {k: v[1] for k, v in encoded.items()}

    def _write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "leaves.npz"), **flat)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(v.shape), "dtype": dtypes[k]} for k, v in flat.items()},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(tree_like, directory: str, step: int, *, shardings=None):
    """Restore into the structure of ``tree_like``; device_put against
    ``shardings`` (same structure) when given — this is where elastic
    re-sharding happens."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    flat_keys = list(_flatten(tree_like).keys())
    leaves = []
    for k in flat_keys:
        arr = _decode(data[k], manifest["leaves"][k]["dtype"])
        leaves.append(arr)
    treedef = jax.tree.structure(tree_like)
    restored = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings
        )
    else:
        restored = jax.tree.map(
            lambda a, t: jax.numpy.asarray(a, dtype=t.dtype), restored, tree_like
        )
    return restored, manifest


def prune_old(directory: str, keep: int = 3):
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)

"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16x16).  Multi-pod: 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    from repro import compat

    return compat.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever the current host offers (tests / examples): (n, 1) mesh."""
    n = len(jax.devices())
    from repro import compat

    return compat.make_mesh((n, 1), ("data", "model"))

"""Tuned runtime environment, shared by CI, the launcher, and benchmarks.

Two cold-start levers live here so every entry point pulls the same ones:

  * **Host-platform mesh flags** — ``--xla_force_host_platform_device_count``
    turns one CPU into an N-device mesh (how CI exercises dp8 sharding).
    ``host_device_flags``/``apply_host_devices`` compose the flag into
    ``XLA_FLAGS`` without clobbering whatever the caller already set.
  * **Persistent compilation cache** — ``enable_compilation_cache`` points
    JAX's disk cache at a stable directory with the thresholds zeroed, so a
    process restart re-warms the engine's whole bucket ladder from disk
    (``EsamPlan.warmup`` + this cache is what makes cold start instant:
    measured on this repo's CPU lanes, a cache hit cuts plan compiles by
    ~3x and repeat warmups to near-zero).

Nothing here imports JAX at module load — ``apply_host_devices`` must be able
to run before the backend initializes.
"""

from __future__ import annotations

import os
from typing import Optional

#: default on-disk location of the persistent JAX compilation cache
DEFAULT_CACHE_DIR = os.path.join(
    os.path.expanduser("~"), ".cache", "repro-jax-compilation")

HOST_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def host_device_flags(n_devices: int, base: Optional[str] = None) -> str:
    """``XLA_FLAGS`` value forcing an ``n_devices`` host-platform mesh,
    composed with ``base`` (default: the current env var) minus any previous
    setting of the same flag."""
    base = os.environ.get("XLA_FLAGS", "") if base is None else base
    kept = [f for f in base.split() if not f.startswith(HOST_DEVICE_FLAG)]
    kept.append(f"{HOST_DEVICE_FLAG}={int(n_devices)}")
    return " ".join(kept)


def apply_host_devices(n_devices: int) -> None:
    """Set ``XLA_FLAGS`` for an ``n_devices`` host mesh, in-process.

    Must run before the JAX backend initializes (before the first
    ``jax.devices()`` / computation — importing ``jax`` alone is fine).
    Raises if the backend is already up with a different device count, since
    the flag would silently not apply.
    """
    os.environ["XLA_FLAGS"] = host_device_flags(n_devices)
    import jax

    if jax._src.xla_bridge._backends:  # already initialized: verify, loudly
        if len(jax.devices()) != int(n_devices):
            raise RuntimeError(
                f"JAX backend already initialized with "
                f"{len(jax.devices())} devices; {HOST_DEVICE_FLAG} can no "
                f"longer apply — set XLA_FLAGS before first device use "
                f"(or use tuned_env() for a subprocess)")


def enable_compilation_cache(cache_dir: Optional[str] = None) -> str:
    """Point JAX's persistent compilation cache at ``cache_dir`` (default
    ``DEFAULT_CACHE_DIR``) with the size/time thresholds zeroed so every
    executable — including the engine's small bucket plans — persists.
    Returns the directory used.  Safe to call repeatedly."""
    import jax

    d = cache_dir or os.environ.get(
        "JAX_COMPILATION_CACHE_DIR", DEFAULT_CACHE_DIR)
    os.makedirs(d, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", d)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    try:  # cache autotune/topology sub-caches too, where the knob exists
        jax.config.update("jax_persistent_cache_enable_xla_caches", "all")
    except Exception:
        pass
    return d


def tuned_env(host_devices: Optional[int] = None,
              cache_dir: Optional[str] = None) -> dict:
    """Environment-variable dict for a tuned subprocess launch (CI smoke
    lanes spawn the launcher with exactly this): host mesh flags, cpu
    platform pinning, and the persistent-cache directory."""
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    if host_devices is not None:
        env["XLA_FLAGS"] = host_device_flags(
            host_devices, env.get("XLA_FLAGS", ""))
    env["JAX_COMPILATION_CACHE_DIR"] = (
        cache_dir or env.get("JAX_COMPILATION_CACHE_DIR", DEFAULT_CACHE_DIR))
    return env

"""Render the EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
JSON cache (results/dryrun/*.json).  Usage:

    PYTHONPATH=src python -m repro.launch.report > /tmp/tables.md
"""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def _fmt_bytes(n):
    if n is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def _fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-6:
        return f"{x*1e9:.2f}ns"
    if x < 1e-3:
        return f"{x*1e6:.2f}us"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.3f}s"


def load():
    cells = []
    for path in sorted(glob.glob(os.path.join(os.path.normpath(RESULTS), "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def improvement_hint(c) -> str:
    b = c["bottleneck"]
    if b == "collective_s":
        return "re-shard to cut loop-carried collectives (replicate small weights / pure-DP)"
    if b == "memory_s":
        if c["shape"].startswith("decode") or c["shape"].startswith("long"):
            return "inherent weight-streaming floor at this batch; grow batch or quantize weights"
        return "chunked (flash-style) attention / fuse to avoid S^2 + remat traffic"
    return "cut remat recompute + capacity-factor overcompute; raise useful-FLOP fraction"


def dryrun_table(cells) -> str:
    rows = ["| cell | mesh | peak B/dev | args B/dev | temp B/dev | HLO flops | coll bytes (fleet) |",
            "|---|---|---|---|---|---|---|"]
    for c in cells:
        m = c["memory"]
        peak = m.get("bytes_per_device_peak") or (
            (m.get("bytes_per_device_argument") or 0) + (m.get("bytes_per_device_temp") or 0))
        rows.append(
            f"| {c['arch']}×{c['shape']} | {c['mesh']} | {_fmt_bytes(peak)} | "
            f"{_fmt_bytes(m.get('bytes_per_device_argument'))} | "
            f"{_fmt_bytes(m.get('bytes_per_device_temp'))} | {c['flops']:.2e} | "
            f"{c['collective_bytes_total']:.2e} |")
    return "\n".join(rows)


def roofline_table(cells) -> str:
    rows = ["| cell | mesh | compute | memory | collective | bottleneck | useful-FLOP frac | what would move the dominant term |",
            "|---|---|---|---|---|---|---|---|"]
    for c in cells:
        r = c["roofline"]
        frac = c.get("useful_flops_frac")
        rows.append(
            f"| {c['arch']}×{c['shape']} | {c['mesh']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"**{c['bottleneck'].replace('_s','')}** | "
            f"{frac:.3f} | {improvement_hint(c)} |" if frac is not None else "| - |")
    return "\n".join(rows)


def main():
    cells = load()
    print("### §Dry-run (generated from results/dryrun)\n")
    print(dryrun_table(cells))
    print("\n### §Roofline (generated)\n")
    print(roofline_table(cells))


if __name__ == "__main__":
    main()

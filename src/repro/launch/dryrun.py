import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --------------------------------------------------------------------------
# Multi-pod dry-run (deliverable e): lower + compile every
# (architecture x input shape x mesh) cell on 512 placeholder devices and
# record memory_analysis / cost_analysis / per-collective byte counts.
#
# The two lines above MUST precede every other import (jax locks the device
# count at first init).  Do not set the flag anywhere global — smoke tests and
# benches must see 1 device.
# --------------------------------------------------------------------------
import argparse       # noqa: E402
import json           # noqa: E402
import sys            # noqa: E402
import time           # noqa: E402

import jax            # noqa: E402
import jax.numpy as jnp                    # noqa: E402
import numpy as np    # noqa: E402

from repro.configs import base as cb       # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch import hlo_analysis      # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm, params as pm  # noqa: E402
from repro.train import loop as train_loop  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

# v5e roofline constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link


def input_specs(cfg, shape: cb.ShapeConfig, rules):
    """ShapeDtypeStruct stand-ins for every model input of one cell."""
    B, S = shape.global_batch, shape.seq_len
    tok_shard = rules.sharding(("batch", None))
    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=tok_shard),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=tok_shard),
        }
        if cfg.is_encdec:
            # src/tgt split S/2 each (DESIGN.md §4)
            batch["tokens"] = jax.ShapeDtypeStruct((B, S // 2), jnp.int32, sharding=tok_shard)
            batch["labels"] = jax.ShapeDtypeStruct((B, S // 2), jnp.int32, sharding=tok_shard)
            batch["src_frames"] = jax.ShapeDtypeStruct(
                (B, S // 2, cfg.d_model), jnp.bfloat16,
                sharding=rules.sharding(("batch", None, None)))
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=tok_shard)}
        if cfg.is_encdec:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S // 2), jnp.int32, sharding=tok_shard)
            batch["src_frames"] = jax.ShapeDtypeStruct(
                (B, S // 2, cfg.d_model), jnp.bfloat16,
                sharding=rules.sharding(("batch", None, None)))
        return batch
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok_shard)}
    raise ValueError(shape.kind)


def cache_specs(cfg, shape: cb.ShapeConfig, rules):
    """ShapeDtypeStructs for the decode-step KV/state caches."""
    B, S = shape.global_batch, shape.seq_len
    src_len = S // 2 if cfg.is_encdec else None
    s_cache = S // 2 if cfg.is_encdec else S
    caches = jax.eval_shape(lambda: lm.init_caches(cfg, B, s_cache, src_len=src_len))
    axes = lm.cache_axes(cfg)

    def attach(sds, ax):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=rules.sharding(ax))

    return jax.tree.map(attach, caches, axes)


def make_rules_for(cfg, mesh, shape: cb.ShapeConfig | None = None):
    return shd.make_rules(
        mesh,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, n_experts=cfg.n_experts,
        d_ff=cfg.d_ff, d_model=cfg.d_model, vocab_size=cfg.vocab_size,
        fsdp=cfg.fsdp, expert_fsdp=cfg.expert_fsdp,
        global_batch=shape.global_batch if shape else 0,
        pure_dp=(cfg.sharding_profile == "pure_dp"),
    )


#: fields neutralized under --profile=baseline (the paper-faithful, uniform
#: naive-TP reference the §Perf hillclimb measures against)
_BASELINE_OVERRIDES = dict(
    sharding_profile="tp", microbatches=1, remat_policy="full",
    capacity_factor=1.25, zero1=True, grad_dtype="float32",
    mlstm_chunk=64, quad_dtype="float32", moe_impl="gather_weights",
    mamba_split_proj=False,
)


def lower_cell(cfg, shape: cb.ShapeConfig, mesh):
    """Build the jitted step for one cell.

    Returns (lowered, jaxpr_stats) — jaxpr_stats carries scan-trip-exact
    logical FLOPs + dot-traffic bytes (hlo_analysis), since XLA's
    cost_analysis counts while bodies once.
    """
    rules = make_rules_for(cfg, mesh, shape)
    if shape.kind == "train":
        tcfg = train_loop.TrainConfig()
        step, state_sh, (pspecs, m_specs, v_specs) = train_loop.jit_train_step(cfg, tcfg, rules)
        state_structs = train_loop.TrainState(
            params=pm.shape_structs(pspecs, rules),
            opt=train_loop.AdamState(
                m=pm.shape_structs(m_specs, rules),
                v=pm.shape_structs(v_specs, rules),
                step=jax.ShapeDtypeStruct((), jnp.int32),
            ),
        )
        args = (state_structs, input_specs(cfg, shape, rules))
        raw_fn = train_loop.make_train_step(cfg, tcfg, rules)
        stats = hlo_analysis.trace_stats(raw_fn, *args)
        return step.lower(*args), stats
    pspecs = lm.model_specs(cfg)
    param_structs = pm.shape_structs(pspecs, rules)
    if shape.kind == "prefill":
        def fn(params, batch):
            with shd.use_rules(rules):
                return lm.prefill(params, cfg, batch)
        args = (param_structs, input_specs(cfg, shape, rules))
        stats = hlo_analysis.trace_stats(fn, *args)
        return jax.jit(fn).lower(*args), stats
    # decode
    def fn(params, tokens, caches):
        with shd.use_rules(rules):
            return lm.decode_step(params, cfg, tokens, caches)
    args = (param_structs, input_specs(cfg, shape, rules)["tokens"],
            cache_specs(cfg, shape, rules))
    stats = hlo_analysis.trace_stats(fn, *args)
    return jax.jit(fn, donate_argnums=(2,)).lower(*args), stats


def lower_esam(mesh, optimized: bool = False):
    """The paper's own system as a dry-run cell: batched binary-SNN inference,
    data-parallel over the full mesh.

    optimized=False: the int32 functional plane (decode to {-1,+1} int32,
    int32 einsum, int32 V_mem written per tile) — a direct transcription of
    the hardware semantics.
    optimized=True (§Perf/HC3): int8 spike/weight operands with int32 MXU
    accumulation and the threshold compare fused into each tile so V_mem never
    round-trips — 4x less operand traffic, int8 outputs between tiles.
    """
    from repro.configs import esam_mnist as em
    from repro.core.esam import tile as esam_tile

    # HC3 iter2: baseline rules park the batch on the data axis only, idling
    # 15/16 of the mesh; optimized spreads it over every axis (weights are
    # 41 KB of bits — replication is free).  The roofline *terms* are
    # formula-identical (they already divide by all chips), but realized time
    # changes 16x: §Perf records utilization alongside the terms.
    rules = shd.make_rules(mesh, n_heads=1, n_kv_heads=1, vocab_size=0,
                           pure_dp=optimized)
    topo = em.TOPOLOGY

    def serve_step(weights, vth, spikes):
        with shd.use_rules(rules):
            s = spikes
            if optimized:
                s = s.astype(jnp.int8)
                for i, (w, t) in enumerate(zip(weights, vth)):
                    s = shd.constrain(s, "batch", None)
                    w_signed = (2 * w - 1).astype(jnp.int8)
                    vmem = jax.lax.dot_general(
                        s, w_signed, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.int32)
                    if i < len(weights) - 1:
                        s = (vmem >= t).astype(jnp.int8)   # fused fire
                return jnp.argmax(vmem, axis=-1)
            for i, (w, t) in enumerate(zip(weights, vth)):
                s = shd.constrain(s, "batch", None)
                s, vmem = esam_tile.functional_tile(w, s, t)
            return jnp.argmax(vmem, axis=-1)

    w_structs = [
        jax.ShapeDtypeStruct((topo[i], topo[i + 1]), jnp.int8,
                             sharding=rules.sharding((None, None)))
        for i in range(len(topo) - 1)
    ]
    vth_structs = [
        jax.ShapeDtypeStruct((topo[i + 1],), jnp.int32, sharding=rules.sharding((None,)))
        for i in range(len(topo) - 1)
    ]
    spikes = jax.ShapeDtypeStruct((em.ESAM_BATCH, topo[0]), jnp.bool_,
                                  sharding=rules.sharding(("batch", None)))
    args = (w_structs, vth_structs, spikes)
    stats = hlo_analysis.trace_stats(serve_step, *args)
    return jax.jit(serve_step).lower(*args), stats


def model_flops(cfg, shape: cb.ShapeConfig) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) reference FLOPs for the cell."""
    specs = lm.model_specs(cfg)
    n_params = pm.param_count(specs)
    if cfg.n_experts:
        # active = non-expert params + top_k/E of expert params
        expert = sum(
            int(np.prod(s.shape)) for k, s in _named_leaves(specs)
            if "w_gate" in k or "w_up" in k or "w_down" in k
        )
        n_active = (n_params - expert) + expert * cfg.top_k / cfg.n_experts
    else:
        n_active = n_params
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else
                                   (shape.seq_len if shape.kind == "prefill" else 1))
    if cfg.is_encdec and shape.kind in ("train", "prefill"):
        tokens = shape.global_batch * shape.seq_len // 2
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def _named_leaves(tree, prefix=""):
    from repro.models.params import is_spec
    out = []
    if is_spec(tree):
        return [(prefix, tree)]
    if isinstance(tree, dict):
        for k, v in tree.items():
            out += _named_leaves(v, f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out += _named_leaves(v, f"{prefix}/{i}")
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             profile: str = "baseline") -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_chips = 512 if multi_pod else 256
    key = f"{arch}__{shape_name}__{mesh_name}"
    if arch == "esam-mnist":
        (lowered, stats) = lower_esam(mesh, optimized=(profile == "optimized"))
        mflops = 2.0 * 330_000 * 65536  # 2*synapses*batch
        cfg = None
    else:
        import dataclasses as _dc
        cfg = cb.get(arch)
        if profile == "baseline":
            cfg = _dc.replace(cfg, **_BASELINE_OVERRIDES)
        shape = cb.SHAPES[shape_name]
        lowered, stats = lower_cell(cfg, shape, mesh)
        mflops = model_flops(cfg, shape)
    from repro import compat

    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    coll = hlo_analysis.collective_bytes(compiled.as_text())

    # logical (jaxpr, scan-exact) workload — primary roofline source;
    # raw XLA cost_analysis kept for cross-checking (undercounts loop bodies)
    flops = float(stats["flops"])
    bytes_traffic = float(stats["dot_bytes"])
    coll_total = sum(coll.values()) * n_chips      # per-device HLO -> fleet-wide
    result = {
        "key": key,
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": n_chips,
        "flops": flops,
        "bytes_traffic": bytes_traffic,
        "xla_cost_flops": float(cost.get("flops", 0.0)),
        "xla_cost_bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "collective_bytes_total": coll_total,
        "model_flops": mflops,
        "memory": {
            "bytes_per_device_argument": getattr(mem, "argument_size_in_bytes", None),
            "bytes_per_device_output": getattr(mem, "output_size_in_bytes", None),
            "bytes_per_device_temp": getattr(mem, "temp_size_in_bytes", None),
            "bytes_per_device_peak": getattr(mem, "peak_memory_in_bytes", None),
            "generated_code": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "roofline": {
            "compute_s": flops / (n_chips * PEAK_FLOPS),
            "memory_s": bytes_traffic / (n_chips * HBM_BW),
            "collective_s": coll_total / (n_chips * ICI_BW),
        },
        "wall_s": time.time() - t0,
    }
    r = result["roofline"]
    result["bottleneck"] = max(r, key=r.get)
    result["useful_flops_frac"] = mflops / flops if flops else None
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, key + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    print(f"[dryrun] {key}: flops={flops:.3e} bytes={bytes_traffic:.3e} "
          f"coll={coll_total:.3e} bottleneck={result['bottleneck']} "
          f"({result['wall_s']:.0f}s)")
    print(f"[dryrun]   memory_analysis: {mem}")
    return result


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in cb.ARCH_IDS:
        cfg = cb.get(arch)
        for shape_name in cb.applicable_shapes(cfg):
            cells.append((arch, shape_name))
    cells.append(("esam-mnist", "batch64k"))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all applicable)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default=os.path.normpath(RESULTS_DIR))
    ap.add_argument("--profile", choices=["baseline", "optimized"], default="baseline",
                    help="baseline: uniform naive-TP reference; optimized: "
                         "per-arch tuned knobs (EXPERIMENTS §Perf)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    if args.profile == "optimized" and args.out == os.path.normpath(RESULTS_DIR):
        args.out = args.out.replace("dryrun", "perf")

    cells = all_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch or cb.ALIASES.get(a) == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch, shape_name in cells:
        for multi_pod in meshes:
            mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
            key = f"{arch}__{shape_name}__{mesh_name}"
            path = os.path.join(args.out, key + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[dryrun] skip {key} (cached)")
                continue
            try:
                run_cell(arch, shape_name, multi_pod, args.out, profile=args.profile)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((key, f"{type(e).__name__}: {e}"))
                print(f"[dryrun] FAIL {key}: {type(e).__name__}: {str(e)[:500]}")
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:")
        for k, msg in failures:
            print(f"  {k}: {msg[:300]}")
        sys.exit(1)
    print("\n[dryrun] all cells compiled OK")


if __name__ == "__main__":
    main()

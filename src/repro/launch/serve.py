"""Serving launcher: LM decoding or ESAM spike serving.

LM mode (default): batched greedy decoding over the unified LM.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --requests 6 --max-new 16

ESAM mode (``--esam``): synthetic spike traffic served end-to-end through
the sharded execution plan — requests flow through ``SpikeEngine``'s
admission queue, power-of-two buckets, and the ``shard_map``-ped packed
plan when more than one device is visible.  Prints the aggregate paper-unit
operating point (MInf/s + pJ/Inf) next to the wall-clock serving rate.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --esam --smoke
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import base as cb
from repro.models import lm, params as pm
from repro.serve.engine import Engine, Request, SpikeEngine, SpikeRequest


def _lm_main(args):
    cfg = cb.smoke(args.arch) if args.smoke else cb.get(args.arch)
    params = pm.init(lm.model_specs(cfg), jax.random.PRNGKey(args.seed))
    batch_size = 4 if args.batch_size is None else args.batch_size
    n_requests = 4 if args.requests is None else args.requests
    eng = Engine(params, cfg, batch_size=batch_size)
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)).astype(np.int32),
                max_new_tokens=args.max_new)
        for _ in range(n_requests)
    ]
    out = eng.serve(reqs)
    for i, r in enumerate(out):
        print(f"req {i}: prompt[{len(r.prompt)}] -> {r.output.tolist()}")


def _random_esam_network(topology, seed: int):
    import jax.numpy as jnp

    from repro.core.esam.network import EsamNetwork

    key = jax.random.PRNGKey(seed)
    bits, vth = [], []
    for i in range(len(topology) - 1):
        k = jax.random.fold_in(key, i)
        bits.append(jax.random.bernoulli(
            k, 0.5, (topology[i], topology[i + 1])).astype(jnp.int8))
        vth.append(jnp.zeros((topology[i + 1],), jnp.int32))
    return EsamNetwork(
        weight_bits=bits, vth=vth,
        out_offset=jnp.zeros((topology[-1],), jnp.float32))


def _esam_main(args):
    from repro.core.esam import cost_model as cm
    from repro.data import digits
    from repro.distributed import sharding as shd

    topology = (768, 256, 10) if args.smoke else cm.PAPER_TOPOLOGY
    n_requests = args.requests if args.requests is not None else (
        64 if args.smoke else 512)
    max_batch = 128 if args.batch_size is None else args.batch_size
    net = _random_esam_network(topology, args.seed)

    rules = None
    if len(jax.devices()) > 1:
        rules = shd.make_esam_rules(shd.esam_data_mesh())
    engine_kw = dict(max_batch=max_batch, telemetry=True,
                     read_ports=args.read_ports, rules=rules)

    x, _ = digits.make_spike_dataset(n_requests, seed=args.seed)
    reqs = [SpikeRequest(spikes=x[i]) for i in range(n_requests)]
    # warm on a throwaway engine serving the SAME workload shape, so every
    # bucket the timed run dispatches is already compiled (plans are cached
    # per network) and the timed engine's stats() see only the timed requests
    SpikeEngine(net, **engine_kw).serve(
        [SpikeRequest(spikes=r) for r in x])
    eng = SpikeEngine(net, **engine_kw)
    t0 = time.perf_counter()
    eng.serve(reqs)
    wall_s = time.perf_counter() - t0

    st = eng.stats()
    print(f"esam-serve: {st['n_requests']} requests "
          f"(data_parallel={st['data_parallel']}, cell={st['cell']}, "
          f"buckets={eng._buckets})")
    print(f"  wall-clock        : {wall_s*1e3:8.1f} ms  "
          f"({len(reqs)/wall_s:,.0f} req/s)")
    print(f"  model throughput  : {st['throughput_pipelined_inf_s']/1e6:8.2f} MInf/s "
          f"(pipelined; paper {cm.PAPER_THROUGHPUT_INF_S/1e6:.0f})")
    print(f"  model energy      : {st['energy_pj_per_inf']:8.1f} pJ/Inf "
          f"(paper {cm.PAPER_ENERGY_PJ_PER_INF:.0f})")
    print(f"  model latency     : {st['latency_ns_mean']:8.1f} ns/inf "
          f"({st['cycles_mean']:.1f} cycles)")
    labels = [r.label for r in reqs]
    assert all(l is not None for l in labels)


def _events_main(args):
    """Synthetic event-stream traffic through the temporal plan: mixed-T
    rate-encoded digit streams drain via ``SpikeEngine.submit_events``
    ((batch, T)-bucketed rounds), printing spikes/s next to the modeled
    pJ/timestep from the measured per-step activity."""
    from repro.core.esam import cost_model as cm
    from repro.core.esam.temporal import TemporalConfig
    from repro.data import events as events_mod
    from repro.serve.engine import EventRequest, SpikeEngine

    topology = (768, 256, 10) if args.smoke else cm.PAPER_TOPOLOGY
    t_mix = (2, 4) if args.smoke else (4, 8, 16)
    n_requests = args.requests if args.requests is not None else (
        32 if args.smoke else 256)
    max_batch = 64 if args.batch_size is None else args.batch_size
    net = _random_esam_network(topology, args.seed)
    cfg = TemporalConfig(n_steps=1, leak=args.leak)
    engine_kw = dict(max_batch=max_batch, telemetry=True,
                     read_ports=args.read_ports, temporal=cfg)

    def make_requests():
        reqs, rng = [], np.random.default_rng(args.seed)
        for i, t in enumerate(rng.choice(t_mix, size=n_requests)):
            ev, _ = events_mod.encode_digit_events(
                1, int(t), encoder="rate", seed=args.seed + i, gain=0.7,
                packed=True)
            reqs.append(EventRequest(events=ev[:, 0]))
        return reqs

    # warm a throwaway engine on the same workload shape (plans are cached
    # per network) so the timed engine's stats() see only the timed requests
    SpikeEngine(net, **engine_kw).serve(make_requests())
    eng = SpikeEngine(net, **engine_kw)
    reqs = make_requests()
    t0 = time.perf_counter()
    eng.serve(reqs)
    wall_s = time.perf_counter() - t0

    st = eng.stats()
    n_spikes = sum(
        int(np.bitwise_count(np.asarray(r.events)).sum()) for r in reqs)
    print(f"esam-events: {st['n_event_requests']} streams, "
          f"{st['timesteps_total']} timesteps (T mix {tuple(t_mix)}, "
          f"cell={st['cell']})")
    print(f"  wall-clock        : {wall_s*1e3:8.1f} ms  "
          f"({st['timesteps_total']/wall_s:,.0f} steps/s, "
          f"{n_spikes/wall_s:,.0f} spikes/s)")
    print(f"  model energy      : {st['energy_pj_per_timestep']:8.1f} "
          f"pJ/timestep ({st['event_energy_pj_mean']:.1f} pJ/stream)")
    print(f"  model latency     : {st['event_latency_ns_mean']:8.1f} "
          f"ns/stream ({st['event_cycles_mean']:.1f} cycles)")
    assert all(r.label is not None for r in reqs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--esam", action="store_true",
                    help="serve ESAM spike traffic through the sharded plan")
    ap.add_argument("--events", action="store_true",
                    help="serve ESAM event-stream traffic (temporal plan)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=None,
                    help="default: 4 (LM), 64 (--esam --smoke), 512 (--esam), "
                         "32 (--events --smoke), 256 (--events)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=None,
                    help="default: 4 (LM), 128 (--esam max_batch)")
    ap.add_argument("--read-ports", type=int, default=4)
    ap.add_argument("--leak", type=float, default=0.125,
                    help="--events: LIF leak per timestep")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.events:
        _events_main(args)
    elif args.esam:
        _esam_main(args)
    else:
        _lm_main(args)


if __name__ == "__main__":
    main()

"""Serving launcher: LM decoding or ESAM spike serving.

LM mode (default): batched greedy decoding over the unified LM.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --requests 6 --max-new 16

ESAM mode (``--esam``): synthetic spike traffic served end-to-end through
the sharded execution plan — requests flow through ``SpikeEngine``'s
admission queue, power-of-two buckets, and the ``shard_map``-ped packed
plan when more than one device is visible, with fused multi-round dispatch
and host/device overlap on by default (``--fuse``/``--no-overlap`` to
tune).  Prints the aggregate paper-unit operating point (MInf/s + pJ/Inf)
next to the wall-clock serving rate.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --esam --smoke

Cold start: ``--warmup`` AOT-compiles the engine's whole bucket ladder
before the first request and prints a greppable ``COLDSTART
first_request_ms=...`` line; ``--compile-cache [DIR]`` additionally enables
the persistent JAX compilation cache (``launch/env.py``) so a *restarted*
server re-warms from disk; ``--host-devices N`` forces an N-device host
mesh without hand-writing XLA_FLAGS.

    PYTHONPATH=src python -m repro.launch.serve --esam --smoke \
        --warmup --compile-cache --host-devices 8

Traffic mode (``--traffic``): open-loop Poisson traffic (seeded arrivals,
mixed static/event blends) through the overload-hardened plane — bounded
admission queue, per-request deadlines, the degradation ladder, and (with
``--replicas N``) the retrying ``FaultAwareRouter``; ``--chaos`` arms a
canned chaos plan (replica 0 crashes mid-drain, replica 1 slowed).  Prints
p50/p99/p99.9 latency, shed/rejected/retry counts, and goodput-under-SLO.

    PYTHONPATH=src python -m repro.launch.serve --traffic --smoke \
        --rate 2000 --requests 64 --deadline-ms 500 --replicas 2
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import base as cb
from repro.models import lm, params as pm
from repro.serve.engine import Engine, Request, SpikeEngine, SpikeRequest


# ------------------------------------------------------------------ #
# observability plane: --metrics-port / --trace-out / --profile-rounds
# ------------------------------------------------------------------ #
def _build_observability(args):
    """Build the launcher's Observability handle (or None when every lane
    is off) plus the scrape server when ``--metrics-port`` was given.

    Returns ``(obs, metrics_server)``; the caller threads ``obs`` into the
    engines and finishes with :func:`_finish_observability`."""
    want_trace = args.trace_out is not None
    want_metrics = args.metrics_port is not None or args.report_json
    want_profile = args.profile_rounds > 0
    if not (want_trace or want_metrics or want_profile):
        return None, None
    from repro.obs import DeviceProfiler, Observability, Registry, Tracer

    registry = Registry() if (want_metrics or want_profile) else None
    tracer = Tracer() if want_trace else None
    profiler = None
    if want_profile:
        profiler = DeviceProfiler(
            args.profile_dir, skip_rounds=args.profile_skip,
            n_rounds=args.profile_rounds, registry=registry)
    obs = Observability(tracer=tracer, metrics=registry, profile=profiler)
    server = None
    if args.metrics_port is not None:
        from repro.obs.http import MetricsServer

        server = MetricsServer(registry, port=args.metrics_port,
                               tracer=tracer)
        port = server.start()
        print(f"METRICS port={port} url=http://127.0.0.1:{port}/metrics")
    return obs, server


def _finish_observability(args, obs, server) -> None:
    """Export the trace, print the greppable summary lines, then hold the
    scrape endpoint open for ``--metrics-hold-s`` (CI curls it here)."""
    if obs is None:
        return
    if obs.profile is not None:
        obs.profile.stop()
        status = obs.profile.error or "ok"
        print(f"PROFILE dir={obs.profile.logdir} "
              f"rounds={obs.profile.captured} status={status}")
    if obs.tracer is not None and args.trace_out is not None:
        from repro.obs.trace import validate_trace

        doc = obs.tracer.export(args.trace_out)
        summary = validate_trace(doc)
        print(f"TRACE path={args.trace_out} events={summary['events']} "
              f"requests={summary['request_begun']} "
              f"close_fraction={summary['request_close_fraction']:.4f}")
    if server is not None:
        if args.metrics_hold_s > 0:
            print(f"METRICS holding for {args.metrics_hold_s:.0f}s", flush=True)
            time.sleep(args.metrics_hold_s)
        server.stop()


def _lm_main(args):
    cfg = cb.smoke(args.arch) if args.smoke else cb.get(args.arch)
    params = pm.init(lm.model_specs(cfg), jax.random.PRNGKey(args.seed))
    batch_size = 4 if args.batch_size is None else args.batch_size
    n_requests = 4 if args.requests is None else args.requests
    eng = Engine(params, cfg, batch_size=batch_size)
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)).astype(np.int32),
                max_new_tokens=args.max_new)
        for _ in range(n_requests)
    ]
    out = eng.serve(reqs)
    for i, r in enumerate(out):
        print(f"req {i}: prompt[{len(r.prompt)}] -> {r.output.tolist()}")


def _random_esam_network(topology, seed: int):
    import jax.numpy as jnp

    from repro.core.esam.network import EsamNetwork

    key = jax.random.PRNGKey(seed)
    bits, vth = [], []
    for i in range(len(topology) - 1):
        k = jax.random.fold_in(key, i)
        bits.append(jax.random.bernoulli(
            k, 0.5, (topology[i], topology[i + 1])).astype(jnp.int8))
        vth.append(jnp.zeros((topology[i + 1],), jnp.int32))
    return EsamNetwork(
        weight_bits=bits, vth=vth,
        out_offset=jnp.zeros((topology[-1],), jnp.float32))


def _esam_main(args, obs=None):
    from repro.core.esam import cost_model as cm
    from repro.data import digits
    from repro.distributed import sharding as shd

    topology = (768, 256, 10) if args.smoke else cm.PAPER_TOPOLOGY
    n_requests = args.requests if args.requests is not None else (
        64 if args.smoke else 512)
    max_batch = 128 if args.batch_size is None else args.batch_size
    net = _random_esam_network(topology, args.seed)

    rules = None
    if len(jax.devices()) > 1:
        rules = shd.make_esam_rules(shd.esam_data_mesh())
    engine_kw = dict(max_batch=max_batch, telemetry=True,
                     read_ports=args.read_ports, rules=rules,
                     fuse_rounds=_fuse_arg(args), overlap=not args.no_overlap)

    x, _ = digits.make_spike_dataset(n_requests, seed=args.seed)
    reqs = [SpikeRequest(spikes=x[i]) for i in range(n_requests)]
    eng = SpikeEngine(net, observability=obs, **engine_kw)
    if args.warmup:
        # AOT-compile the whole bucket ladder up front, then time the very
        # first request the warmed engine serves — the cold-start headline
        wt = eng.warmup()
        t0 = time.perf_counter()
        eng.serve([reqs[0]])
        first_ms = (time.perf_counter() - t0) * 1e3
        print(f"COLDSTART first_request_ms={first_ms:.2f} "
              f"warmup_s={wt['total_s']:.2f} "
              f"buckets={len(eng._buckets)} "
              f"cache={'on' if args.compile_cache is not None else 'off'}")
        reqs_timed = reqs[1:]
    else:
        # warm on a throwaway engine serving the SAME workload shape, so
        # every bucket the timed run dispatches is already compiled (plans
        # are cached per network) and the timed engine's stats() see only
        # the timed requests
        SpikeEngine(net, **engine_kw).serve(
            [SpikeRequest(spikes=r) for r in x])
        reqs_timed = reqs
    t0 = time.perf_counter()
    eng.serve(reqs_timed)
    wall_s = time.perf_counter() - t0

    st = eng.stats()
    print(f"esam-serve: {st['n_requests']} requests "
          f"(data_parallel={st['data_parallel']}, cell={st['cell']}, "
          f"buckets={eng._buckets}, fuse={st['fuse_rounds']}, "
          f"overlap={st['overlap']}, rounds_saved={st['rounds_saved']})")
    print(f"  wall-clock        : {wall_s*1e3:8.1f} ms  "
          f"({len(reqs_timed)/wall_s:,.0f} req/s)")
    print(f"  model throughput  : {st['throughput_pipelined_inf_s']/1e6:8.2f} MInf/s "
          f"(pipelined; paper {cm.PAPER_THROUGHPUT_INF_S/1e6:.0f})")
    print(f"  model energy      : {st['energy_pj_per_inf']:8.1f} pJ/Inf "
          f"(paper {cm.PAPER_ENERGY_PJ_PER_INF:.0f})")
    print(f"  model latency     : {st['latency_ns_mean']:8.1f} ns/inf "
          f"({st['cycles_mean']:.1f} cycles)")
    labels = [r.label for r in reqs]
    assert all(l is not None for l in labels)


def _events_main(args, obs=None):
    """Synthetic event-stream traffic through the temporal plan: mixed-T
    rate-encoded digit streams drain via ``SpikeEngine.submit_events``
    ((batch, T)-bucketed rounds), printing spikes/s next to the modeled
    pJ/timestep from the measured per-step activity."""
    from repro.core.esam import cost_model as cm
    from repro.core.esam.temporal import TemporalConfig
    from repro.data import events as events_mod
    from repro.serve.engine import EventRequest, SpikeEngine

    topology = (768, 256, 10) if args.smoke else cm.PAPER_TOPOLOGY
    t_mix = (2, 4) if args.smoke else (4, 8, 16)
    n_requests = args.requests if args.requests is not None else (
        32 if args.smoke else 256)
    max_batch = 64 if args.batch_size is None else args.batch_size
    net = _random_esam_network(topology, args.seed)
    cfg = TemporalConfig(n_steps=1, leak=args.leak)
    engine_kw = dict(max_batch=max_batch, telemetry=True,
                     read_ports=args.read_ports, temporal=cfg)

    def make_requests():
        reqs, rng = [], np.random.default_rng(args.seed)
        for i, t in enumerate(rng.choice(t_mix, size=n_requests)):
            ev, _ = events_mod.encode_digit_events(
                1, int(t), encoder="rate", seed=args.seed + i, gain=0.7,
                packed=True)
            reqs.append(EventRequest(events=ev[:, 0]))
        return reqs

    # warm a throwaway engine on the same workload shape (plans are cached
    # per network) so the timed engine's stats() see only the timed requests
    SpikeEngine(net, **engine_kw).serve(make_requests())
    eng = SpikeEngine(net, observability=obs, **engine_kw)
    reqs = make_requests()
    t0 = time.perf_counter()
    eng.serve(reqs)
    wall_s = time.perf_counter() - t0

    st = eng.stats()
    n_spikes = sum(
        int(np.bitwise_count(np.asarray(r.events)).sum()) for r in reqs)
    print(f"esam-events: {st['n_event_requests']} streams, "
          f"{st['timesteps_total']} timesteps (T mix {tuple(t_mix)}, "
          f"cell={st['cell']})")
    print(f"  wall-clock        : {wall_s*1e3:8.1f} ms  "
          f"({st['timesteps_total']/wall_s:,.0f} steps/s, "
          f"{n_spikes/wall_s:,.0f} spikes/s)")
    print(f"  model energy      : {st['energy_pj_per_timestep']:8.1f} "
          f"pJ/timestep ({st['event_energy_pj_mean']:.1f} pJ/stream)")
    print(f"  model latency     : {st['event_latency_ns_mean']:8.1f} "
          f"ns/stream ({st['event_cycles_mean']:.1f} cycles)")
    assert all(r.label is not None for r in reqs)


def _traffic_main(args, obs=None):
    """Open-loop Poisson traffic (optionally chaos-injected) through the
    overload-hardened serving plane, printing the SLO-facing numbers."""
    from repro.core.esam import cost_model as cm
    from repro.serve.engine import FaultAwareRouter, SpikeEngine
    from repro.serve.overload import DegradationLadder
    from repro.serve.traffic import ChaosConfig, TrafficConfig, run_open_loop
    from repro.train.fault_tolerance import RetryPolicy

    topology = (768, 256, 10) if args.smoke else cm.PAPER_TOPOLOGY
    n_requests = args.requests if args.requests is not None else (
        64 if args.smoke else 256)
    max_batch = 32 if args.batch_size is None else args.batch_size
    net = _random_esam_network(topology, args.seed)

    def make_engine(engine_obs=None):
        # the warmup engine stays un-instrumented so the scrape/trace
        # surfaces carry only the measured open-loop run
        return SpikeEngine(
            net, max_batch=max_batch, telemetry=True,
            read_ports=args.read_ports, queue_limit=4 * max_batch,
            fuse_rounds=_fuse_arg(args), overlap=not args.no_overlap,
            observability=engine_obs,
            ladder=DegradationLadder.default(max_batch, args.read_ports))

    # closed-loop warmup on the same request blend: first pass compiles
    # every (bucket, T) the traffic can hit, second pass measures the
    # sustainable rate, so --rate defaults land relative to saturation
    from repro.serve.traffic import build_requests
    warm = make_engine()
    blend = dict(rate_hz=1.0, n_requests=n_requests, p_event=args.p_event,
                 event_t_choices=(2, 4), n_in=topology[0])
    warm.serve(build_requests(TrafficConfig(seed=args.seed, **blend))[0])
    timed = build_requests(TrafficConfig(seed=args.seed + 1, **blend))[0]
    t0 = time.perf_counter()
    warm.serve(timed)
    rate_sust = len(timed) / (time.perf_counter() - t0)
    rate = args.rate if args.rate is not None else 2.0 * rate_sust

    engines = [make_engine(obs) for _ in range(max(1, args.replicas))]
    # health_threshold=0: a random network's measured telemetry deviates
    # from the reference calibration, so tile-health routing would mark
    # every replica degraded and starve all but one — this lane exercises
    # the overload plane (crash/retry/deadlines), not health scoring
    server = engines[0] if len(engines) == 1 else FaultAwareRouter(
        engines, health_threshold=0.0, observability=obs,
        retry=RetryPolicy(base_backoff_s=1e-3, attempt_timeout_s=2.0))
    chaos = None
    if args.chaos:
        chaos = ChaosConfig(
            slowdown=((1, 5e-3),) if len(engines) > 1 else (),
            crash_replica=0 if len(engines) > 1 else None,
            crash_after_rounds=2,
            storm_at_s=0.0, storm_size=2 * max_batch)
    deadline_s = args.deadline_ms / 1e3 if args.deadline_ms else None
    slo_s = args.slo_ms / 1e3 if args.slo_ms else deadline_s
    cfg = TrafficConfig(
        rate_hz=rate, n_requests=n_requests, seed=args.seed,
        p_event=args.p_event, event_t_choices=(2, 4),
        n_in=topology[0], deadline_s=deadline_s)
    if args.warmup:
        from repro.serve.traffic import warmup_engine
        warmup_engine(server, cfg)
    rep = run_open_loop(server, cfg, slo_s=slo_s, chaos=chaos,
                        observability=obs)
    if args.report_json:
        import json
        with open(args.report_json, "w") as f:
            json.dump(rep.to_dict(), f, indent=2, default=str)
        print(f"REPORT path={args.report_json}")

    print(f"esam-traffic: offered {rep.n_offered} requests @ {rate:,.0f}/s "
          f"(sustainable ~{rate_sust:,.0f}/s, replicas={len(engines)}, "
          f"chaos={'on' if chaos else 'off'})")
    print(f"  completed         : {rep.n_completed}  "
          f"(shed {rep.n_shed}, rejected {rep.n_rejected}, "
          f"failed {rep.n_failed}, deadline-miss {rep.n_deadline_miss})")
    print(f"  latency           : p50 {rep.p50_ms:8.1f} ms   "
          f"p99 {rep.p99_ms:8.1f} ms   p99.9 {rep.p999_ms:8.1f} ms")
    print(f"  goodput under SLO : {100 * rep.goodput_slo:6.1f} %  "
          f"(SLO {1e3 * rep.slo_s:.0f} ms)" if rep.slo_s else
          f"  goodput           : {100 * rep.goodput_slo:6.1f} %")
    print(f"  resilience        : retries {rep.retries}, "
          f"crashes {rep.crashes}, timeouts {rep.timeouts}, "
          f"degraded routes {rep.degraded_routes}")
    print(f"  degradation       : {rep.ladder_transitions} transitions, "
          f"deepest level {rep.max_degradation_level}; "
          f"backpressure events {rep.backpressure_events}")


def _fuse_arg(args):
    """Resolve --fuse: "auto" (default) | "off" | an integer factor."""
    if args.fuse in ("off", "none", "0"):
        return None
    if args.fuse == "auto":
        return "auto"
    return int(args.fuse)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--esam", action="store_true",
                    help="serve ESAM spike traffic through the sharded plan")
    ap.add_argument("--events", action="store_true",
                    help="serve ESAM event-stream traffic (temporal plan)")
    ap.add_argument("--traffic", action="store_true",
                    help="open-loop Poisson traffic through the "
                         "overload-hardened plane (deadlines, ladder, "
                         "retries); see also --chaos/--replicas")
    ap.add_argument("--rate", type=float, default=None,
                    help="--traffic: offered arrival rate in req/s "
                         "(default: 2x the measured sustainable rate)")
    ap.add_argument("--deadline-ms", type=float, default=250.0,
                    help="--traffic: per-request deadline (0 disables)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="--traffic: goodput SLO (default: the deadline)")
    ap.add_argument("--p-event", type=float, default=0.25,
                    help="--traffic: fraction of event-stream requests")
    ap.add_argument("--replicas", type=int, default=1,
                    help="--traffic: engine replicas behind the router")
    ap.add_argument("--chaos", action="store_true",
                    help="--traffic: crash replica 0 mid-drain, slow "
                         "replica 1, and inject a request storm")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=None,
                    help="default: 4 (LM), 64 (--esam --smoke), 512 (--esam), "
                         "32 (--events --smoke), 256 (--events)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=None,
                    help="default: 4 (LM), 128 (--esam max_batch)")
    ap.add_argument("--read-ports", type=int, default=4)
    ap.add_argument("--leak", type=float, default=0.125,
                    help="--events: LIF leak per timestep")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fuse", default="auto",
                    help="round fusion factor: 'auto' (= dp degree), "
                         "'off', or an integer")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable the background host packer "
                         "(synchronous legacy drain)")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-compile the bucket ladder before serving and "
                         "print COLDSTART first-request latency")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force an N-device host-platform mesh "
                         "(XLA_FLAGS, applied before backend init)")
    ap.add_argument("--compile-cache", nargs="?", const="", default=None,
                    metavar="DIR",
                    help="enable the persistent JAX compilation cache "
                         "(optional directory; default "
                         "~/.cache/repro-jax-compilation)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve Prometheus /metrics on this port "
                         "(0 = ephemeral; prints 'METRICS port=...')")
    ap.add_argument("--metrics-hold-s", type=float, default=0.0,
                    help="keep the /metrics endpoint up this long after the "
                         "run finishes (lets CI scrape before exit)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export a Perfetto trace_event JSON of the run "
                         "(open at ui.perfetto.dev)")
    ap.add_argument("--profile-rounds", type=int, default=0, metavar="N",
                    help="capture a jax.profiler trace around N drain "
                         "rounds (see --profile-dir/--profile-skip)")
    ap.add_argument("--profile-dir", default="/tmp/esam-profile",
                    help="logdir for the jax.profiler capture")
    ap.add_argument("--profile-skip", type=int, default=1,
                    help="drain rounds to skip before the profiler arms "
                         "(skips cold-start compiles; default 1)")
    ap.add_argument("--report-json", default=None, metavar="PATH",
                    help="--traffic: write the TrafficReport (with the "
                         "metrics snapshot) as JSON")
    args = ap.parse_args()
    from repro.launch import env as env_mod
    if args.host_devices is not None:
        env_mod.apply_host_devices(args.host_devices)
    if args.compile_cache is not None:
        env_mod.enable_compilation_cache(args.compile_cache or None)
    obs, metrics_server = _build_observability(args)
    try:
        if args.traffic:
            _traffic_main(args, obs)
        elif args.events:
            _events_main(args, obs)
        elif args.esam:
            _esam_main(args, obs)
        else:
            _lm_main(args)
    finally:
        _finish_observability(args, obs, metrics_server)


if __name__ == "__main__":
    main()

"""Serving launcher: batched greedy decoding over the unified LM.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --requests 6 --max-new 16
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import base as cb
from repro.models import lm, params as pm
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = cb.smoke(args.arch) if args.smoke else cb.get(args.arch)
    params = pm.init(lm.model_specs(cfg), jax.random.PRNGKey(args.seed))
    eng = Engine(params, cfg, batch_size=args.batch_size)
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)).astype(np.int32),
                max_new_tokens=args.max_new)
        for _ in range(args.requests)
    ]
    out = eng.serve(reqs)
    for i, r in enumerate(out):
        print(f"req {i}: prompt[{len(r.prompt)}] -> {r.output.tolist()}")


if __name__ == "__main__":
    main()

"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 100 --ckpt-dir /tmp/ckpt

On a real fleet this binary runs per-host under the usual JAX multi-host
bootstrap (jax.distributed.initialize from the cluster env); on this CPU
container it drives the same code path on the local device mesh.  --smoke
selects the reduced same-family config so the driver is runnable anywhere.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import base as cb
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.train import fault_tolerance as ft
from repro.train import loop as train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = cb.smoke(args.arch) if args.smoke else cb.get(args.arch)
    tcfg = train_loop.TrainConfig(
        lr=args.lr, warmup=min(20, args.steps // 10 + 1), total_steps=args.steps,
        log_every=max(1, args.steps // 20), checkpoint_every=args.ckpt_every,
        seed=args.seed,
    )
    pipe = TokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=args.seed,
        is_encdec=cfg.is_encdec, d_model=cfg.d_model,
    ))
    mgr = ft.CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    wd = ft.StragglerWatchdog(
        on_straggler=lambda s, w, e: print(f"[watchdog] step {s} straggled: "
                                           f"{w:.2f}s vs EMA {e:.2f}s"))

    def log(step, metrics):
        print(f"step {step:5d}  loss {metrics['loss']:.4f}  lr {metrics['lr']:.2e}  "
              f"wall {metrics['wall_s']:.2f}s")

    print(f"training {cfg.name} ({'smoke' if args.smoke else 'full'}) on "
          f"{len(jax.devices())} device(s)")
    state, history = train_loop.run(
        cfg, tcfg, pipe, ckpt_manager=mgr, watchdog=wd, hooks=[log])
    if mgr:
        mgr.wait()
    print(f"done: loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}; "
          f"stragglers flagged: {len(wd.flagged)}")


if __name__ == "__main__":
    main()

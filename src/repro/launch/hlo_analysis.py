"""Roofline-term extraction that survives XLA's loop-body-counted-once
cost analysis.

Two sources, cross-checked in EXPERIMENTS.md:

1. ``jaxpr_stats``: walks the traced jaxpr, counting dot/ragged_dot/conv FLOPs
   and their operand/output bytes, multiplying through ``lax.scan`` trip
   counts.  This is the *logical* workload — exact FLOPs, and an unfused
   upper-bound HBM-traffic proxy (every dot reads its operands and writes its
   output once; XLA fusion only reduces this, so the memory term is
   conservative).

2. ``collective_bytes``: parses the compiled (post-SPMD) HLO text — shapes
   there are per-device shards — summing result bytes of all-gather /
   all-reduce / reduce-scatter / all-to-all / collective-permute.  Collectives
   inside while-loop bodies (the layer scan) are multiplied by the loop trip
   count, which the caller supplies from the model structure (n_layers or
   group count).  Reported bytes are per-device x chips = fleet-wide, matching
   the assignment's ``collective_bytes / (chips x link_bw)`` convention.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.extend.core as jcore
import numpy as np

# ------------------------------------------------------------------ #
# jaxpr walker
# ------------------------------------------------------------------ #
def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001 — abstract tokens etc.
        return 0


def _dot_flops(eqn) -> int:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    batch = int(np.prod([a.shape[i] for i in lb])) if lb else 1
    k = int(np.prod([a.shape[i] for i in lc])) if lc else 1
    m = int(np.prod([d for i, d in enumerate(a.shape) if i not in lc and i not in lb]))
    n = int(np.prod([d for i, d in enumerate(b.shape) if i not in rc and i not in rb]))
    return 2 * batch * m * n * k


def _ragged_dot_flops(eqn) -> int:
    x, w = eqn.invars[0].aval, eqn.invars[1].aval   # [m,k], [g,k,n]
    return 2 * x.shape[0] * x.shape[1] * w.shape[2]


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    return 2 * int(np.prod(out.shape)) * int(np.prod(rhs.shape[1:]))


_CALL_JAXPR_KEYS = ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr")


def _sub_jaxprs(eqn):
    subs = []
    for key in _CALL_JAXPR_KEYS:
        if key in eqn.params:
            subs.append(eqn.params[key])
    if "branches" in eqn.params:
        subs.extend(eqn.params["branches"])
    return subs


def _as_jaxpr(obj):
    if isinstance(obj, jcore.ClosedJaxpr):
        return obj.jaxpr
    return obj


def jaxpr_stats(closed_jaxpr, mult: float = 1.0) -> dict[str, float]:
    """Returns {'flops', 'dot_bytes'} with scan trip counts applied."""
    jaxpr = _as_jaxpr(closed_jaxpr)
    flops = 0.0
    dot_bytes = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            flops += mult * _dot_flops(eqn)
            dot_bytes += mult * (
                sum(_aval_bytes(v.aval) for v in eqn.invars)
                + sum(_aval_bytes(v.aval) for v in eqn.outvars)
            )
        elif name == "ragged_dot":
            flops += mult * _ragged_dot_flops(eqn)
            dot_bytes += mult * (
                sum(_aval_bytes(v.aval) for v in eqn.invars)
                + sum(_aval_bytes(v.aval) for v in eqn.outvars)
            )
        elif name.startswith("conv_general"):
            flops += mult * _conv_flops(eqn)
            dot_bytes += mult * (
                sum(_aval_bytes(v.aval) for v in eqn.invars)
                + sum(_aval_bytes(v.aval) for v in eqn.outvars)
            )
        elif name == "scan":
            length = eqn.params.get("length", 1)
            inner = jaxpr_stats(eqn.params["jaxpr"], mult * length)
            flops += inner["flops"]
            dot_bytes += inner["dot_bytes"]
        elif name == "shard_map":
            # body avals are per-device shards: scale to physical fleet-wide
            # work (counts replicated compute — exactly what the
            # MODEL_FLOPS/HLO_FLOPs "useful fraction" metric should expose)
            mesh_obj = eqn.params.get("mesh")
            size = 1
            if mesh_obj is not None:
                try:
                    size = int(np.prod(list(mesh_obj.shape.values())))
                except Exception:  # noqa: BLE001
                    size = getattr(mesh_obj, "size", 1)
            for sub in _sub_jaxprs(eqn):
                inner = jaxpr_stats(sub, mult * size)
                flops += inner["flops"]
                dot_bytes += inner["dot_bytes"]
        elif name == "while":
            # our models only use scan; treat unknown trip count as 1 + warn
            for sub in _sub_jaxprs(eqn):
                inner = jaxpr_stats(sub, mult)
                flops += inner["flops"]
                dot_bytes += inner["dot_bytes"]
        else:
            for sub in _sub_jaxprs(eqn):
                inner = jaxpr_stats(sub, mult)
                flops += inner["flops"]
                dot_bytes += inner["dot_bytes"]
    return {"flops": flops, "dot_bytes": dot_bytes}


def trace_stats(fn, *args, **kwargs) -> dict[str, float]:
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return jaxpr_stats(closed)


# ------------------------------------------------------------------ #
# compiled-HLO collective parser (loop-trip-count aware)
# ------------------------------------------------------------------ #
_COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}
# header e.g. "%while_body.12 (p: (s32[], bf16[2,4])) -> (s32[], bf16[2,4]) {"
# — parameter tuples nest parens, so the params group must match greedily.
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_RE = re.compile(r"=\s*\(?.*?while\(")
_KW_COMP_RE = re.compile(r"(body|condition|to_apply|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_REF_RE = re.compile(r"%([\w.\-]+)")


def _split_computations(text: str) -> tuple[dict[str, str], str]:
    comps: dict[str, list[str]] = {}
    entry = None
    current = None
    for line in text.splitlines():
        stripped = line.strip()
        m = _COMP_HEADER_RE.match(stripped)
        if m and stripped.endswith("{"):
            current = m.group(2)
            comps[current] = []
            if m.group(1):
                entry = current
        elif current is not None:
            if stripped == "}":
                current = None
            else:
                comps[current].append(line)
    if entry is None and comps:
        entry = list(comps)[-1]
    return {k: "\n".join(v) for k, v in comps.items()}, entry


def _collective_on_line(line: str):
    """HLO format: ``%name = TYPE[dims] opcode(...)`` — the opcode (and result
    shapes) sit right of '='; the instruction *name* may also contain the
    opcode string, so only match the RHS.  Returns (kind, result_bytes)."""
    if "=" not in line:
        return None
    rhs = line.split("=", 1)[1]
    m = _COLLECTIVE_RE.search(rhs)
    if not m:
        return None
    # the match must be the OPCODE itself (followed by '('), not an operand
    # reference like get-tuple-element(%all-reduce.176) — those would re-count
    # every tuple element of a grouped gradient all-reduce.
    tail = rhs[m.start():]
    kind = m.group(1)
    if not (tail.startswith(kind + "(") or tail.startswith(kind + "-start(")):
        return None
    # result type(s) = everything on the RHS before the opcode
    prefix = rhs[: m.start()]
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(prefix):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * _DTYPE_BYTES[dt]
    return kind, nbytes


def _trip_count(cond_body: str) -> float:
    """Scan-lowered while conditions compare a counter against a constant —
    take the largest integer constant in the condition computation."""
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    return float(max(consts)) if consts else 1.0


def collective_bytes(text: str) -> dict[str, float]:
    """Per-device collective result bytes with loop trip counts applied.

    Builds the computation call graph; crossing a while-body edge multiplies
    the accumulated weight by that loop's trip count (parsed from its
    condition).  Nested layer/chunk scans therefore weight correctly.
    """
    comps, entry = _split_computations(text)

    # edges: caller -> [(callee, weight)]
    edges: dict[str, list[tuple[str, float]]] = {k: [] for k in comps}
    for caller, body in comps.items():
        for line in body.splitlines():
            kws = dict((k, v) for k, v in _KW_COMP_RE.findall(line))
            if _WHILE_RE.search(line) and "body" in kws:
                cond = kws.get("condition")
                trip = _trip_count(comps.get(cond, "")) if cond else 1.0
                edges[caller].append((kws["body"], trip))
                if cond:
                    edges[caller].append((cond, trip))
            else:
                for _, name in _KW_COMP_RE.findall(line):
                    if name in comps:
                        edges[caller].append((name, 1.0))
                # plain %references (fusions etc.)
                for name in _REF_RE.findall(line):
                    if name in comps and all(name != e[0] for e in edges[caller]):
                        edges[caller].append((name, 1.0))

    # propagate multipliers from entry (max over paths; DAG in practice)
    mult: dict[str, float] = {entry: 1.0}
    frontier = [entry]
    for _ in range(10 * max(len(comps), 1)):  # bounded fixpoint
        if not frontier:
            break
        nxt = []
        for caller in frontier:
            for callee, w in edges.get(caller, []):
                cand = mult[caller] * w
                if cand > mult.get(callee, 0.0):
                    mult[callee] = cand
                    nxt.append(callee)
        frontier = nxt

    out: dict[str, float] = {}
    for name, body in comps.items():
        m = mult.get(name, 1.0)
        for line in body.splitlines():
            hit = _collective_on_line(line.strip())
            if hit:
                kind, nbytes = hit
                if nbytes:
                    out[kind] = out.get(kind, 0.0) + m * float(nbytes)
    return out

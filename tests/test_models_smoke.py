"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step on CPU, asserting output shapes + no NaNs, plus a
prefill+decode round for every arch with a decoder."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.models import lm, params as pm

ARCHS = list(cb.ARCH_IDS)


def _batch(cfg, key, b=2, s=32):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encdec:
        batch["src_frames"] = jax.random.normal(
            jax.random.fold_in(key, 1), (b, s, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = cb.smoke(arch)
    specs = lm.model_specs(cfg)
    params = pm.init(specs, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits = lm.forward_train(params, cfg, batch)
    assert logits.shape == (*batch["tokens"].shape, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    loss, grads = jax.value_and_grad(lm.loss_fn)(params, cfg, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = cb.smoke(arch)
    specs = lm.model_specs(cfg)
    params = pm.init(specs, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(2))
    logits, caches = lm.prefill(params, cfg, batch)
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    logits2, caches2 = lm.decode_step(params, cfg, tok, caches)
    assert logits2.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2).any())


@pytest.mark.parametrize("arch", ["llama3.2-1b", "xlstm-125m", "zamba2-2.7b"])
def test_decode_matches_teacher_forcing(arch):
    """Prefill(t0..tn) + decode(t_{n+1}) must equal forward_train on the full
    sequence — the KV/state caches carry exactly the right context."""
    cfg = cb.smoke(arch)
    specs = lm.model_specs(cfg)
    params = pm.init(specs, jax.random.PRNGKey(0))
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)
    full_logits = lm.forward_train(params, cfg, {"tokens": tokens, "labels": tokens})
    # prefill on the first s-1 tokens, decode the final token
    pre_logits, caches = lm.prefill(
        params, cfg, {"tokens": tokens[:, : s - 1]}, cache_len=s)
    np.testing.assert_allclose(
        np.asarray(pre_logits, np.float32),
        np.asarray(full_logits[:, s - 2], np.float32), rtol=2e-2, atol=2e-2)
    dec_logits, _ = lm.decode_step(params, cfg, tokens[:, s - 1 :], caches)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits[:, s - 1], np.float32), rtol=2e-2, atol=2e-2)


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact assigned hyperparameters."""
    expect = {
        "zamba2_2p7b": dict(n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
                            d_ff=10240, vocab_size=32000, ssm_state=64),
        "seamless_m4t_medium": dict(d_model=1024, n_heads=16, n_kv_heads=16,
                                    d_ff=4096, vocab_size=256206),
        "stablelm_3b": dict(n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
                            d_ff=6912, vocab_size=50304),
        "llama3p2_1b": dict(n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
                            d_ff=8192, vocab_size=128256),
        "stablelm_1p6b": dict(n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
                              d_ff=5632, vocab_size=100352),
        "granite_3_2b": dict(n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
                             d_ff=8192, vocab_size=49155),
        "xlstm_125m": dict(n_layers=12, d_model=768, n_heads=4, vocab_size=50304, d_ff=0),
        "chameleon_34b": dict(n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
                              d_ff=22016, vocab_size=65536),
        "llama4_scout_17b_a16e": dict(n_layers=48, d_model=5120, n_heads=40,
                                      n_kv_heads=8, d_ff=8192, vocab_size=202048,
                                      n_experts=16, top_k=1),
        "kimi_k2_1t_a32b": dict(n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
                                d_ff=2048, vocab_size=163840, n_experts=384, top_k=8),
    }
    for arch, fields in expect.items():
        cfg = cb.get(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_in_expected_range():
    """Sanity: full-config param counts land near the advertised sizes."""
    expected_b = {
        "llama3p2_1b": (1.0, 1.7),
        "stablelm_1p6b": (1.3, 2.1),
        "granite_3_2b": (2.0, 3.0),
        "stablelm_3b": (2.5, 3.6),
        "zamba2_2p7b": (2.2, 3.6),
        "xlstm_125m": (0.1, 0.25),  # mLSTM up-proj 2x makes ours ~0.21B
        "chameleon_34b": (30.0, 38.0),
        "kimi_k2_1t_a32b": (950.0, 1150.0),
    }
    for arch, (lo, hi) in expected_b.items():
        cfg = cb.get(arch)
        n = pm.param_count(lm.model_specs(cfg)) / 1e9
        assert lo <= n <= hi, (arch, n)


def test_applicable_shapes_rules():
    """Skip rules: long_500k only for sub-quadratic; decode only with decoder."""
    assert "long_500k" in cb.applicable_shapes(cb.get("zamba2_2p7b"))
    assert "long_500k" in cb.applicable_shapes(cb.get("xlstm_125m"))
    for arch in ("llama3p2_1b", "chameleon_34b", "kimi_k2_1t_a32b",
                 "seamless_m4t_medium"):
        assert "long_500k" not in cb.applicable_shapes(cb.get(arch))
    assert "decode_32k" in cb.applicable_shapes(cb.get("seamless_m4t_medium"))
    total = sum(len(cb.applicable_shapes(cb.get(a))) for a in cb.ARCH_IDS)
    assert total == 32  # 30 base cells + 2 long_500k

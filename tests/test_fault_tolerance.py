"""Fault tolerance: checkpoint save/restore, bit-exact resume, straggler
watchdog, elastic replan, data-pipeline determinism."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.configs import base as cb
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.train import fault_tolerance as ft
from repro.train import loop as train_loop


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16), "step": jnp.asarray(7)}}
    ckpt_io.save(tree, str(tmp_path), 7)
    zero = jax.tree.map(jnp.zeros_like, tree)
    restored, manifest = ckpt_io.restore(zero, str(tmp_path), 7)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_atomicity_and_pruning(tmp_path):
    tree = {"w": jnp.ones((4,))}
    for step in (1, 2, 3, 4):
        ckpt_io.save(tree, str(tmp_path), step)
    ckpt_io.prune_old(str(tmp_path), keep=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]
    assert ckpt_io.latest_step(str(tmp_path)) == 4
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_resume_is_bit_exact(tmp_path):
    """Interrupted training (checkpoint + restart) == uninterrupted run."""
    cfg = cb.smoke("llama3.2-1b")
    pipe_cfg = PipelineConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4, seed=3)

    # uninterrupted: 8 steps
    tcfg_a = train_loop.TrainConfig(lr=1e-3, warmup=2, total_steps=8,
                                    log_every=1, checkpoint_every=10**9)
    state_a, _ = train_loop.run(cfg, tcfg_a, TokenPipeline(pipe_cfg))

    # interrupted: crash mid-step-5 (after the step-4 checkpoint), then resume.
    # NOTE: the tcfg must be identical to run A — total_steps feeds the LR
    # schedule, so a different horizon would legitimately change the updates.
    mgr = ft.CheckpointManager(str(tmp_path), async_save=False)

    class Crash(RuntimeError):
        pass

    def crash_at_5(step, metrics):
        if step == 5:
            raise Crash()

    with pytest.raises(Crash):
        train_loop.run(cfg, tcfg_a, TokenPipeline(pipe_cfg), ckpt_manager=mgr,
                       hooks=[crash_at_5])
    state_b2, _ = train_loop.run(cfg, tcfg_a, TokenPipeline(pipe_cfg), ckpt_manager=mgr)

    for a, b in zip(jax.tree.leaves(state_a.params), jax.tree.leaves(state_b2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_across_mesh_shapes(tmp_path):
    """Elastic restart: restore against different target shardings (device_put
    re-shard) — on 1 CPU device this exercises the API path end-to-end."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt_io.save(tree, str(tmp_path), 1)
    from repro import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    restored, _ = ckpt_io.restore(jax.tree.map(jnp.zeros_like, tree), str(tmp_path), 1,
                                  shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding == sh["w"]


def test_straggler_watchdog_flags_slow_steps():
    wd = ft.StragglerWatchdog(threshold=2.0, warmup_steps=2)
    for step in range(10):
        wd.record(step, 0.1)
    wd.record(10, 0.5)  # 5x the EMA -> straggler
    assert len(wd.flagged) == 1 and wd.flagged[0][0] == 10
    wd.record(11, 0.1)
    assert len(wd.flagged) == 1


def test_elastic_replan():
    assert ft.elastic_replan(512) == ((32, 16), ("data", "model"))
    assert ft.elastic_replan(496) == ((16, 16), ("data", "model"))  # pod loss -> pow2
    assert ft.elastic_replan(256) == ((16, 16), ("data", "model"))
    with pytest.raises(ValueError):
        ft.elastic_replan(8)


def test_elastic_replan_non_dividing_model_parallel():
    """model_parallel need not divide n_chips; the leftovers become spares
    and the count is reported on the result."""
    res = ft.elastic_replan(500, model_parallel=12)
    assert res == ((32, 12), ("data", "model"))
    assert res.dropped_chips == 500 - 32 * 12  # 116 hot spares
    # clean power-of-two fit drops nothing
    assert ft.elastic_replan(512).dropped_chips == 0
    # pod loss: 496 chips, mp=16 -> data 31 -> 16; 240 idle
    assert ft.elastic_replan(496).dropped_chips == 496 - 16 * 16
    # the result still unpacks like the historical plain tuple
    (data, model), axes = ft.elastic_replan(500, model_parallel=12)
    assert (data, model, axes) == (32, 12, ("data", "model"))


def test_restore_data_state_missing_or_truncated_manifest(tmp_path):
    mgr = ft.CheckpointManager(str(tmp_path), async_save=False)
    # empty directory: no steps at all
    assert mgr.restore_data_state() is None
    mgr.save({"w": jnp.ones((4,))}, 3, data_state={"cursor": 17})
    mgr.wait()
    assert mgr.restore_data_state() == {"cursor": 17}
    manifest = os.path.join(str(tmp_path), "step_00000003", "manifest.json")
    # truncated manifest (crash mid-copy): degrade to a fresh cursor
    with open(manifest) as f:
        content = f.read()
    with open(manifest, "w") as f:
        f.write(content[: len(content) // 2])
    assert mgr.restore_data_state() is None
    # missing manifest entirely
    os.remove(manifest)
    assert mgr.restore_data_state() is None


def test_data_pipeline_deterministic_and_resumable():
    cfg = PipelineConfig(vocab_size=1000, seq_len=64, global_batch=8, seed=5)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    for _ in range(3):
        b1, b2 = p1.next_batch(), p2.next_batch()
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # resume from state dict
    p3 = TokenPipeline(cfg)
    p3.load_state_dict(p1.state_dict())
    np.testing.assert_array_equal(p3.next_batch()["tokens"], p2.next_batch()["tokens"])


def test_data_pipeline_host_sharding():
    base = PipelineConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=9)
    hosts = [TokenPipeline(dataclasses.replace(base, n_hosts=2, host_id=i)) for i in range(2)]
    b0, b1 = hosts[0].next_batch(), hosts[1].next_batch()
    assert b0["tokens"].shape == (4, 32)
    assert not np.array_equal(b0["tokens"], b1["tokens"])  # distinct shards


def test_straggler_watchdog_warmup_crossing_recovery():
    # warmup: even wildly slow steps never flag while the EMA seeds
    wd = ft.StragglerWatchdog(threshold=2.0, warmup_steps=3)
    for step, wall in enumerate((10.0, 0.1, 50.0)):
        wd.record(step, wall)
    assert wd.flagged == []

    hits = []
    wd2 = ft.StragglerWatchdog(threshold=2.0, warmup_steps=2,
                               on_straggler=lambda s, w, e: hits.append(s))
    for s in range(8):
        wd2.record(s, 0.1)
    assert wd2._ema == pytest.approx(0.1)
    # crossing: wall > threshold x EMA flags (step, wall, ema) and fires
    # the callback; the slow sample still feeds the EMA afterwards
    wd2.record(8, 0.21)
    assert hits == [8]
    step, wall, ema = wd2.flagged[0]
    assert step == 8 and wall == 0.21 and ema == pytest.approx(0.1)
    assert wd2._ema > ema
    # recovery: normal-speed rounds stop flagging and the EMA decays back
    for s in range(9, 30):
        wd2.record(s, 0.1)
    assert len(wd2.flagged) == 1
    assert wd2._ema == pytest.approx(0.1, rel=2e-2)


def test_retry_policy_backoff_deterministic_and_bounded():
    p = ft.RetryPolicy(max_attempts=5, base_backoff_s=0.01,
                       backoff_multiplier=2.0, max_backoff_s=0.05,
                       jitter=0.5, seed=42)
    # counter-based: same (seed, counter) replays, either moving changes it
    assert p.backoff_s(2, 7) == p.backoff_s(2, 7)
    assert p.backoff_s(2, 7) != p.backoff_s(2, 8)
    assert p.backoff_s(2, 7) != dataclasses.replace(p, seed=43).backoff_s(2, 7)
    # jitter bounds: base * (1 +- jitter) at every attempt
    for attempt in range(1, 6):
        base = min(0.01 * 2.0 ** (attempt - 1), 0.05)
        for c in range(25):
            assert base * 0.5 <= p.backoff_s(attempt, c) <= base * 1.5
    # exponential growth capped at max_backoff_s (jitter off)
    q = ft.RetryPolicy(base_backoff_s=0.01, jitter=0.0, max_backoff_s=0.05)
    assert [q.backoff_s(a, 0) for a in range(1, 6)] == pytest.approx(
        [0.01, 0.02, 0.04, 0.05, 0.05])


def test_counter_uniform_is_in_range_and_well_spread():
    us = [ft.counter_uniform(0, c) for c in range(1000)]
    assert all(0.0 <= u < 1.0 for u in us)
    assert len(set(us)) == 1000          # no collisions over the counter
    assert abs(np.mean(us) - 0.5) < 0.05

"""The roofline extraction tools: jaxpr FLOPs/bytes with scan multipliers,
HLO collective parsing with loop trip counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as ha


def test_jaxpr_flops_plain_matmul():
    f = lambda a, b: a @ b
    stats = ha.trace_stats(f, jnp.zeros((64, 32)), jnp.zeros((32, 128)))
    assert stats["flops"] == 2 * 64 * 32 * 128
    assert stats["dot_bytes"] == (64 * 32 + 32 * 128 + 64 * 128) * 4


def test_jaxpr_flops_counts_scan_trips():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    stats = ha.trace_stats(f, jnp.zeros((64, 64)), jnp.zeros((10, 64, 64)))
    assert stats["flops"] == 10 * 2 * 64**3  # trip count applied


def test_jaxpr_flops_nested_scan_and_remat():
    def f(x, ws):
        def outer(c, wpair):
            def inner(ci, w):
                return ci @ w, None
            y, _ = jax.lax.scan(inner, c, wpair)
            return y, None
        body = jax.checkpoint(outer)
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    ws = jnp.zeros((3, 2, 32, 32))
    stats = ha.trace_stats(f, jnp.zeros((8, 32)), ws)
    assert stats["flops"] == 3 * 2 * 2 * 8 * 32 * 32


def test_jaxpr_grad_includes_backward_dots():
    f = lambda a, b: (a @ b).sum()
    g = jax.grad(f, argnums=(0, 1))
    stats = ha.trace_stats(g, jnp.zeros((16, 16)), jnp.zeros((16, 16)))
    fwd = 2 * 16**3
    assert stats["flops"] >= 3 * fwd * 0.99  # fwd + two transposed bwd dots


def test_collective_parser_trip_counts():
    hlo = """
HloModule test

%cond (p: (s32[], f32[128])) -> pred[] {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %x = f32[128] get-tuple-element(%p), index=1
  %ar = f32[128]{0} all-reduce(%x), replica_groups={}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[128]) tuple(%i, %ar)
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %a = f32[128] parameter(0)
  %ag = f32[256]{0} all-gather(%a), dimensions={0}
  %init = (s32[], f32[128]) tuple(s32[] constant(0), %a)
  %w = (s32[], f32[128]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[128] get-tuple-element(%w), index=1
}
"""
    coll = ha.collective_bytes(hlo)
    assert coll["all-gather"] == 256 * 4              # entry: counted once
    assert coll["all-reduce"] == 12 * 128 * 4         # loop body x trip count


def test_collective_parser_ignores_operand_references():
    hlo = """
ENTRY %main (a: f32[64]) -> f32[64] {
  %a = f32[64] parameter(0)
  %all-reduce.5 = f32[64]{0} all-reduce(%a), replica_groups={}
  ROOT %g = f32[64] add(%all-reduce.5, %all-reduce.5)
}
"""
    coll = ha.collective_bytes(hlo)
    assert coll == {"all-reduce": 64 * 4.0}  # the add line must not count


def test_collective_parser_tuple_results():
    hlo = """
ENTRY %main (a: f32[64], b: f32[32]) -> f32[64] {
  %a = f32[64] parameter(0)
  %b = f32[32] parameter(1)
  %ar = (f32[64]{0}, f32[32]{0}) all-reduce(%a, %b), replica_groups={}
  ROOT %o = f32[64] get-tuple-element(%ar), index=0
}
"""
    coll = ha.collective_bytes(hlo)
    assert coll["all-reduce"] == (64 + 32) * 4

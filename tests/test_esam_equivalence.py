"""DESIGN.md changed-assumption #1: the event-driven multiport schedule and the
batched dense MAC (TPU plane) must produce identical outputs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esam import EsamNetwork
from repro.core.esam import tile as tile_mod


def _rand_tile(key, n_in, n_out):
    kw, kt = jax.random.split(key)
    bits = jax.random.bernoulli(kw, 0.5, (n_in, n_out)).astype(jnp.int8)
    vth = jax.random.randint(kt, (n_out,), -10, 10, jnp.int32)
    return bits, vth


@pytest.mark.parametrize("ports", [1, 2, 3, 4])
@pytest.mark.parametrize("n_in,n_out", [(128, 128), (256, 64), (384, 128)])
def test_cycle_accurate_tile_equals_functional(ports, n_in, n_out):
    key = jax.random.PRNGKey(ports * 1000 + n_in)
    bits, vth = _rand_tile(key, n_in, n_out)
    spikes = jax.random.bernoulli(jax.random.fold_in(key, 7), 0.4, (n_in,))
    trace = tile_mod.simulate_tile(bits, spikes, vth, ports)
    f_spikes, f_vmem = tile_mod.functional_tile(bits, spikes, vth)
    np.testing.assert_array_equal(np.asarray(trace.vmem_final), np.asarray(f_vmem))
    np.testing.assert_array_equal(np.asarray(trace.out_spikes), np.asarray(f_spikes))


@pytest.mark.parametrize("ports", [1, 4])
def test_cycle_count_is_max_group_drain(ports):
    key = jax.random.PRNGKey(3)
    bits, vth = _rand_tile(key, 256, 32)
    spikes = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.3, (256,))
    trace = tile_mod.simulate_tile(bits, spikes, vth, ports)
    counts = np.asarray(spikes).reshape(2, 128).sum(-1)
    assert int(trace.cycles) == int(np.ceil(counts / ports).max())


def test_network_cycle_accurate_equals_functional():
    key = jax.random.PRNGKey(0)
    topo = (256, 128, 128, 10)
    bits, vth = [], []
    for i in range(len(topo) - 1):
        b, t = _rand_tile(jax.random.fold_in(key, i), topo[i], topo[i + 1])
        bits.append(b)
        vth.append(t)
    net = EsamNetwork(weight_bits=bits, vth=vth, out_offset=jnp.zeros((10,)))
    s = jax.random.bernoulli(jax.random.fold_in(key, 99), 0.45, (256,))
    logits_f = net.forward(s)
    logits_c, traces = net.forward_cycle_accurate(s, ports=4)
    np.testing.assert_array_equal(np.asarray(logits_f), np.asarray(logits_c))
    assert len(traces) == 3


def test_unused_port_never_contributes():
    """A tile with a single spike must add exactly one row, regardless of p."""
    n_in, n_out = 128, 16
    bits = jnp.ones((n_in, n_out), jnp.int8)  # all +1
    vth = jnp.zeros((n_out,), jnp.int32)
    spikes = jnp.zeros((n_in,), bool).at[17].set(True)
    for ports in (1, 2, 4):
        tr = tile_mod.simulate_tile(bits, spikes, vth, ports)
        assert int(tr.vmem_final[0]) == 1  # not p; validity flags mask idle ports

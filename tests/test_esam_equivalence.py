"""DESIGN.md changed-assumption #1: the event-driven multiport schedule and the
batched dense MAC (TPU plane) must produce identical outputs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esam import EsamNetwork
from repro.core.esam import tile as tile_mod


def _rand_tile(key, n_in, n_out):
    kw, kt = jax.random.split(key)
    bits = jax.random.bernoulli(kw, 0.5, (n_in, n_out)).astype(jnp.int8)
    vth = jax.random.randint(kt, (n_out,), -10, 10, jnp.int32)
    return bits, vth


@pytest.mark.parametrize("ports", [1, 2, 3, 4])
@pytest.mark.parametrize("n_in,n_out", [(128, 128), (256, 64), (384, 128)])
def test_cycle_accurate_tile_equals_functional(ports, n_in, n_out):
    key = jax.random.PRNGKey(ports * 1000 + n_in)
    bits, vth = _rand_tile(key, n_in, n_out)
    spikes = jax.random.bernoulli(jax.random.fold_in(key, 7), 0.4, (n_in,))
    trace = tile_mod.simulate_tile(bits, spikes, vth, ports)
    f_spikes, f_vmem = tile_mod.functional_tile(bits, spikes, vth)
    np.testing.assert_array_equal(np.asarray(trace.vmem_final), np.asarray(f_vmem))
    np.testing.assert_array_equal(np.asarray(trace.out_spikes), np.asarray(f_spikes))


@pytest.mark.parametrize("ports", [1, 4])
def test_cycle_count_is_max_group_drain(ports):
    key = jax.random.PRNGKey(3)
    bits, vth = _rand_tile(key, 256, 32)
    spikes = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.3, (256,))
    trace = tile_mod.simulate_tile(bits, spikes, vth, ports)
    counts = np.asarray(spikes).reshape(2, 128).sum(-1)
    assert int(trace.cycles) == int(np.ceil(counts / ports).max())


def test_network_cycle_accurate_equals_functional():
    key = jax.random.PRNGKey(0)
    topo = (256, 128, 128, 10)
    bits, vth = [], []
    for i in range(len(topo) - 1):
        b, t = _rand_tile(jax.random.fold_in(key, i), topo[i], topo[i + 1])
        bits.append(b)
        vth.append(t)
    net = EsamNetwork(weight_bits=bits, vth=vth, out_offset=jnp.zeros((10,)))
    s = jax.random.bernoulli(jax.random.fold_in(key, 99), 0.45, (256,))
    logits_f = net.forward(s)
    logits_c, traces = net.forward_cycle_accurate(s, ports=4)
    np.testing.assert_array_equal(np.asarray(logits_f), np.asarray(logits_c))
    assert len(traces) == 3


@pytest.mark.parametrize("ports", [1, 4])
def test_batched_simulate_tile_matches_single_sample(ports):
    """vmapped cycle-accurate plane == per-sample simulator, field by field."""
    key = jax.random.PRNGKey(17)
    bits, vth = _rand_tile(key, 256, 64)
    spikes = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.35, (6, 256))
    batched = tile_mod.simulate_tile_batch(bits, spikes, vth, ports)
    for i in range(spikes.shape[0]):
        single = tile_mod.simulate_tile(bits, spikes[i], vth, ports)
        np.testing.assert_array_equal(
            np.asarray(batched.vmem_final[i]), np.asarray(single.vmem_final))
        np.testing.assert_array_equal(
            np.asarray(batched.out_spikes[i]), np.asarray(single.out_spikes))
        np.testing.assert_array_equal(
            np.asarray(batched.grants_per_cycle[i]),
            np.asarray(single.grants_per_cycle))
        assert int(batched.cycles[i]) == int(single.cycles)


def test_vmem_trace_is_opt_in():
    """Default scan state is O(n_out): the trace is empty unless requested,
    and when requested it ends at the final V_mem."""
    key = jax.random.PRNGKey(23)
    bits, vth = _rand_tile(key, 256, 32)
    spikes = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.4, (256,))
    lean = tile_mod.simulate_tile(bits, spikes, vth, 4)
    assert lean.vmem_trace.shape == (0, 32)
    full = tile_mod.simulate_tile(bits, spikes, vth, 4, record_vmem_trace=True)
    assert full.vmem_trace.shape == (tile_mod.max_drain_cycles(256, 4), 32)
    np.testing.assert_array_equal(
        np.asarray(full.vmem_trace[-1]), np.asarray(full.vmem_final))
    np.testing.assert_array_equal(
        np.asarray(full.vmem_final), np.asarray(lean.vmem_final))


def test_network_batched_cycle_accurate_equals_functional():
    key = jax.random.PRNGKey(31)
    topo = (256, 128, 128, 10)
    bits, vth = [], []
    for i in range(len(topo) - 1):
        b, t = _rand_tile(jax.random.fold_in(key, i), topo[i], topo[i + 1])
        bits.append(b)
        vth.append(t)
    net = EsamNetwork(weight_bits=bits, vth=vth, out_offset=jnp.zeros((10,)))
    s = jax.random.bernoulli(jax.random.fold_in(key, 7), 0.4, (8, 256))
    logits_b, traces = net.forward_cycle_accurate_batch(s, ports=4)
    np.testing.assert_array_equal(np.asarray(logits_b), np.asarray(net.forward(s)))
    assert len(traces) == 3 and traces[0].out_spikes.shape == (8, 128)


def test_unused_port_never_contributes():
    """A tile with a single spike must add exactly one row, regardless of p."""
    n_in, n_out = 128, 16
    bits = jnp.ones((n_in, n_out), jnp.int8)  # all +1
    vth = jnp.zeros((n_out,), jnp.int32)
    spikes = jnp.zeros((n_in,), bool).at[17].set(True)
    for ports in (1, 2, 4):
        tr = tile_mod.simulate_tile(bits, spikes, vth, ports)
        assert int(tr.vmem_final[0]) == 1  # not p; validity flags mask idle ports

"""EsamPlan: the single compiled entry point.

Property tests assert the plan's output is bit-identical to the raw
datapaths each legacy ``forward*`` variant was built on — functional tile
chain, packed kernel cascade, rank-schedule simulator — across packed /
unpacked inputs, collect on/off, telemetry on/off; plus the continuously
batched ``SpikeEngine`` on top, and the sharded-vs-single-device identity
on an 8-device host-platform mesh (subprocess, XLA_FLAGS)."""

from __future__ import annotations

import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import packing
from repro.core.esam import EsamNetwork
from repro.core.esam import tile as tile_mod

TOPOLOGIES = [(256, 128, 10), (768, 256, 256, 10), (128, 64, 32)]


def _rand_net(key, topo):
    bits, vth = [], []
    for i in range(len(topo) - 1):
        k = jax.random.fold_in(key, i)
        bits.append(jax.random.bernoulli(k, 0.5, (topo[i], topo[i + 1])).astype(jnp.int8))
        vth.append(jax.random.randint(
            jax.random.fold_in(k, 1), (topo[i + 1],), -10, 10, jnp.int32))
    off = jax.random.normal(jax.random.fold_in(key, 99), (topo[-1],))
    return EsamNetwork(weight_bits=bits, vth=vth, out_offset=off)


def _oracle_functional(net, s):
    """Hand-rolled functional chain — the pre-plan ``forward`` body."""
    per_layer = []
    x = s
    for w, th in zip(net.weight_bits[:-1], net.vth[:-1]):
        x, _ = tile_mod.functional_tile(w, x, th)
        per_layer.append(x)
    _, vmem = tile_mod.functional_tile(net.weight_bits[-1], x, net.vth[-1])
    return vmem.astype(jnp.float32) + net.out_offset, per_layer


# ----------------------------------------------------------------------- #
# plan vs raw datapaths, all flag combinations
# ----------------------------------------------------------------------- #
@pytest.mark.parametrize("topo", TOPOLOGIES)
@pytest.mark.parametrize("collect", [False, True])
@pytest.mark.parametrize("telemetry", [False, True])
def test_functional_plan_bit_identical(topo, collect, telemetry):
    if telemetry and any(n % 128 for n in topo[:-1]):
        pytest.skip("telemetry loads need 128-aligned layer widths")
    net = _rand_net(jax.random.PRNGKey(sum(topo)), topo)
    s = jax.random.bernoulli(jax.random.PRNGKey(7), 0.4, (9, topo[0]))
    want, per_layer = _oracle_functional(net, s)
    res = net.plan(mode="functional", collect=collect, telemetry=telemetry)(s)
    np.testing.assert_array_equal(np.asarray(res.logits), np.asarray(want))
    if collect:
        assert len(res.planes) == len(per_layer)
        for a, b in zip(res.planes, per_layer):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    else:
        assert res.planes is None
    if telemetry:
        inputs = [s, *per_layer]
        assert len(res.loads) == len(topo) - 1
        for ld, si in zip(res.loads, inputs):
            n_groups = -(-si.shape[-1] // 128)
            want_ld = np.asarray(si, np.int32).reshape(
                9, n_groups, -1).sum(-1)
            np.testing.assert_array_equal(np.asarray(ld), want_ld)
    else:
        assert res.loads is None


@pytest.mark.parametrize("topo", [(256, 128, 10), (768, 256, 256, 10)])
@pytest.mark.parametrize("packed_input", [False, True])
@pytest.mark.parametrize("collect", [False, True])
def test_packed_plan_bit_identical(topo, packed_input, collect):
    net = _rand_net(jax.random.PRNGKey(13 + sum(topo)), topo)
    s = jax.random.bernoulli(jax.random.PRNGKey(3), 0.35, (21, topo[0]))
    want, _ = _oracle_functional(net, s)
    plan = net.plan(mode="packed", collect=collect, telemetry=True,
                    interpret=True)
    x = packing.pack_spikes(s) if packed_input else s
    res = plan(x)
    np.testing.assert_array_equal(np.asarray(res.logits), np.asarray(want))
    # telemetry loads come straight off the wire format (group popcounts)
    inputs = [s]
    xx = s
    for w, th in zip(net.weight_bits[:-1], net.vth[:-1]):
        xx, _ = tile_mod.functional_tile(w, xx, th)
        inputs.append(xx)
    for ld, si in zip(res.loads, inputs):
        n_groups = -(-si.shape[-1] // 128)
        want_ld = np.asarray(si, np.int32).reshape(21, n_groups, -1).sum(-1)
        np.testing.assert_array_equal(np.asarray(ld), want_ld)
    if collect:
        assert len(res.planes) == len(topo) - 1
        np.testing.assert_array_equal(
            np.asarray(res.planes[0]), np.asarray(packing.pack_spikes(s)))


def test_prefix_plan_matches_packed_cascade():
    topo = (768, 256, 256, 10)
    net = _rand_net(jax.random.PRNGKey(29), topo)
    s = jax.random.bernoulli(jax.random.PRNGKey(5), 0.3, (16, 768))
    plan = net.plan(mode="prefix", interpret=True)
    assert plan.prefix_packed
    res = plan(packing.pack_spikes(s))
    # oracle: functional chain through the hidden tiles, then pack
    x = s
    for w, th in zip(net.weight_bits[:-1], net.vth[:-1]):
        x, _ = tile_mod.functional_tile(w, x, th)
    np.testing.assert_array_equal(
        np.asarray(res.prefix), np.asarray(packing.pack_spikes(x)))
    # unpacked spikes accepted too
    np.testing.assert_array_equal(
        np.asarray(plan(s).prefix), np.asarray(res.prefix))


def test_prefix_plan_dense_fallback_unaligned_hidden():
    topo = (128, 48, 10)          # 48 not 32-aligned -> dense prefix
    net = _rand_net(jax.random.PRNGKey(31), topo)
    s = jax.random.bernoulli(jax.random.PRNGKey(6), 0.5, (7, 128))
    plan = net.plan(mode="prefix")
    assert not plan.prefix_packed
    x, _ = tile_mod.functional_tile(net.weight_bits[0], s, net.vth[0])
    np.testing.assert_array_equal(
        np.asarray(plan(s).prefix), np.asarray(x))


@pytest.mark.parametrize("ports", [1, 3])
def test_cycle_plan_matches_simulator(ports):
    topo = (256, 128, 10)
    net = _rand_net(jax.random.PRNGKey(41), topo)
    s = jax.random.bernoulli(jax.random.PRNGKey(8), 0.4, (6, 256))
    res = net.plan(mode="cycle", read_ports=ports)(s)
    want, _ = _oracle_functional(net, s)
    np.testing.assert_array_equal(np.asarray(res.logits), np.asarray(want))
    x = s
    for i, (w, th) in enumerate(zip(net.weight_bits, net.vth)):
        tr = tile_mod.simulate_tile_batch(w, x, th, ports)
        for field in ("out_spikes", "vmem_final", "cycles", "grants_per_cycle"):
            np.testing.assert_array_equal(
                np.asarray(getattr(res.traces[i], field)),
                np.asarray(getattr(tr, field)))
        x = tr.out_spikes


def test_cycle_sweep_plan_is_one_call_and_shares_port_counts():
    topo = (256, 128, 10)
    net = _rand_net(jax.random.PRNGKey(43), topo)
    s = jax.random.bernoulli(jax.random.PRNGKey(9), 0.4, (5, 256))
    res = net.plan(mode="cycle", read_ports=(0, 1, 4))(s)
    assert sorted(res.sweep) == [0, 1, 4]
    # options 0 and 1 share the single-port simulation
    np.testing.assert_array_equal(
        np.asarray(res.sweep[0]["traces"][0].cycles),
        np.asarray(res.sweep[1]["traces"][0].cycles))
    want, _ = _oracle_functional(net, s)
    for p in (0, 1, 4):
        np.testing.assert_array_equal(
            np.asarray(res.sweep[p]["logits"]), np.asarray(want))


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_plan_property_random_batch_and_leading_shapes(seed):
    """Packed and functional plans agree with the oracle on random shapes,
    including single samples (empty leading shape) and 3-D batches."""
    rng = np.random.default_rng(seed)
    topo = (128, 64, 10)
    net = _rand_net(jax.random.PRNGKey(seed), topo)
    shape = [(128,), (int(rng.integers(1, 9)), 128),
             (2, int(rng.integers(1, 5)), 128)][int(rng.integers(0, 3))]
    s = jax.random.bernoulli(
        jax.random.PRNGKey(seed + 1), float(rng.uniform(0.1, 0.9)), shape)
    want, _ = _oracle_functional(net, s)
    got_f = net.plan(mode="functional")(s).logits
    got_p = net.plan(mode="packed", interpret=True)(s).logits
    assert got_f.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want))


def test_legacy_wrappers_delegate_and_warn():
    """Every legacy forward* wrapper returns plan output and deprecation-warns
    (once per process — the filter here just makes them visible)."""
    from repro.core.esam import network as network_mod

    net = _rand_net(jax.random.PRNGKey(51), (256, 128, 10))
    s = jax.random.bernoulli(jax.random.PRNGKey(10), 0.4, (4, 256))
    want, per_layer = _oracle_functional(net, s)
    network_mod.reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        np.testing.assert_array_equal(np.asarray(net.forward(s)), np.asarray(want))
        np.testing.assert_array_equal(
            np.asarray(net.forward_fused(s, interpret=True)), np.asarray(want))
        packed = packing.pack_spikes(s)
        np.testing.assert_array_equal(
            np.asarray(net.forward_fused_packed(packed, interpret=True)),
            np.asarray(want))
        logits, planes = net.forward_fused_packed_collect(packed, interpret=True)
        np.testing.assert_array_equal(np.asarray(logits), np.asarray(want))
        np.testing.assert_array_equal(
            np.asarray(net.forward_prefix_packed(packed, interpret=True)),
            np.asarray(planes[-1]))
        lc, traces = net.forward_cycle_accurate(s[0], ports=4)
        np.testing.assert_array_equal(np.asarray(lc), np.asarray(want[0]))
        assert traces[0].cycles.shape == ()
        lb, _ = net.forward_cycle_accurate_batch(s, ports=2)
        np.testing.assert_array_equal(np.asarray(lb), np.asarray(want))
    names = {str(w.message).split(" ")[0] for w in caught
             if issubclass(w.category, DeprecationWarning)}
    assert any("EsamNetwork.forward" in n for n in names)
    assert len([w for w in caught
                if issubclass(w.category, DeprecationWarning)]) >= 7


def test_cached_plan_reads_current_weights():
    """A cached plan must serve the network's CURRENT parameters — in-place
    weight swaps (e.g. a learned readout) may not return stale logits."""
    net = _rand_net(jax.random.PRNGKey(52), (128, 64, 10))
    s = jax.random.bernoulli(jax.random.PRNGKey(14), 0.4, (5, 128))
    plan = net.plan(mode="functional")
    before = np.asarray(plan(s).logits)
    net.weight_bits[-1] = (1 - net.weight_bits[-1]).astype(jnp.int8)
    after = np.asarray(net.plan(mode="functional")(s).logits)
    want, _ = _oracle_functional(net, s)
    assert net.plan(mode="functional") is plan   # same compiled plan ...
    np.testing.assert_array_equal(after, np.asarray(want))  # ... fresh weights
    assert not np.array_equal(after, before)


def test_plan_executable_closes_over_presliced_operands():
    """The compiled executable never sees raw weight_bits: every mode's prep
    hands it mode-native operands built once at plan-build/prep time — uint32
    weight bit planes / DMA slabs for the popcount datapaths, decoded +-1
    matrices for the dense ones — and the prep cache only rebuilds when the
    parameter objects actually change."""
    net = _rand_net(jax.random.PRNGKey(71), (256, 128, 10))
    # packed (mega cascade): stacked uint32 planes + vth slab, no raw bits
    plan = net.plan(mode="packed", interpret=True)
    assert plan._use_mega
    params = plan._prepare()
    assert "weight_bits" not in params
    assert params["w_stack"].dtype == jnp.uint32
    assert params["w_stack"].shape[0] == 2           # one slab per tile
    assert params["vth_stack"].shape == (1, 128)     # hidden-tile thresholds
    # prep is cached: same params object until a weight actually changes
    assert plan._prepare() is params
    net.weight_bits[-1] = (1 - net.weight_bits[-1]).astype(jnp.int8)
    params2 = plan._prepare()
    assert params2 is not params
    assert not np.array_equal(np.asarray(params2["w_stack"]),
                              np.asarray(params["w_stack"]))
    # functional: decoded +-1 matrices, hoisted out of the traced body
    fplan = net.plan(mode="functional")
    fparams = fplan._prepare()
    assert "weight_bits" not in fparams
    assert all(np.isin(np.asarray(w), (-1, 1)).all()
               for w in fparams["w_signed"])
    # temporal: per-step MAC operands (bit planes + f32 signed) pre-built
    from repro.core.esam.temporal import TemporalConfig

    tplan = net.plan(mode="temporal",
                     temporal=TemporalConfig(n_steps=2), interpret=True)
    tparams = tplan._prepare()
    assert all(p.dtype == jnp.uint32 for p in tparams["w_planes"])
    assert all(w.dtype == jnp.float32 for w in tparams["w_signed_f32"])
    # cycle: decoded matrices shared across the port sweep when unfaulted
    cplan = net.plan(mode="cycle", read_ports=(0, 4))
    by_ports = cplan._prepare()["cycle_w_signed"]
    assert set(by_ports) == {1, 4}
    assert by_ports[1] is by_ports[4]


@pytest.mark.parametrize("mode", ["functional", "packed", "prefix", "cycle",
                                  "temporal"])
@pytest.mark.parametrize("faulted", [False, True])
def test_plan_modes_bit_identical_clean_and_faulted(mode, faulted):
    """Popcount-backed packed/prefix/temporal plans agree bit-exactly with
    the functional (unpacked) plane per mode, clean and under a fault model
    (faults now applied at prep time, outside the executable)."""
    from repro.core.esam.faults import FaultModel
    from repro.core.esam.temporal import TemporalConfig

    topo = (256, 128, 10)
    net = _rand_net(jax.random.PRNGKey(73 + faulted), topo)
    s = jax.random.bernoulli(jax.random.PRNGKey(15), 0.4, (13, 256))
    fm = FaultModel(seed=5, stuck0_rate=0.03, stuck1_rate=0.03,
                    vth_sigma=1.0, read_disturb=1e-3) if faulted else None
    # oracle: functional chain on the eagerly-faulted parameters, at the
    # same effective port count the plan will use
    ports = 2 if mode == "cycle" else 4
    if faulted:
        from repro.core.esam import faults as faults_mod

        masks = fm.build_masks(net.topology, (ports,))
        wb = faults_mod.faulted_weights(net.weight_bits, masks, ports)
        vth = faults_mod.faulted_vth(net.vth, masks)
        oracle_net = EsamNetwork(weight_bits=list(wb), vth=list(vth),
                                 out_offset=net.out_offset)
    else:
        oracle_net = net
    want, _ = _oracle_functional(oracle_net, s)
    kw = {"faults": fm} if faulted else {}
    if mode == "temporal":
        # T=1, no leak, zero-state: one step == the static forward pass
        cfg = TemporalConfig(n_steps=1, leak=0.0, reset="zero", refractory=0)
        res = net.plan(mode="temporal", interpret=True, temporal=cfg,
                       **kw)(s[None])
    elif mode == "cycle":
        res = net.plan(mode="cycle", read_ports=2, **kw)(s)
    elif mode == "prefix":
        plan = net.plan(mode="prefix", interpret=True, **kw)
        prefix = plan(s).prefix
        # readout on the popcount prefix == functional hidden chain packed
        x = s
        for w, th in zip(oracle_net.weight_bits[:-1], oracle_net.vth[:-1]):
            x, _ = tile_mod.functional_tile(w, x, th)
        np.testing.assert_array_equal(
            np.asarray(prefix), np.asarray(packing.pack_spikes(x)))
        return
    else:
        res = net.plan(mode=mode, interpret=True, **kw)(s)
    np.testing.assert_array_equal(np.asarray(res.logits), np.asarray(want))
    if faulted:
        clean, _ = _oracle_functional(net, s)
        assert not np.array_equal(np.asarray(res.logits), np.asarray(clean))


def test_plans_are_cached_per_network():
    net = _rand_net(jax.random.PRNGKey(53), (128, 64, 10))
    assert net.plan(mode="functional") is net.plan(mode="functional")
    assert net.plan(mode="functional") is not net.plan(
        mode="functional", collect=True)
    # replace() drops the cache (weights changed -> stale executables)
    import dataclasses

    net2 = dataclasses.replace(net, weight_bits=list(net.weight_bits))
    assert net2.plan(mode="functional") is not net.plan(mode="functional")


# ----------------------------------------------------------------------- #
# sharded plan == single device, on the 8-device host-platform mesh
# ----------------------------------------------------------------------- #
_SHARDED_SCRIPT = r"""
import warnings; warnings.simplefilter("ignore")
import jax, jax.numpy as jnp, numpy as np
from repro.core.esam.network import EsamNetwork
from repro.distributed import sharding as shd
from repro.core import packing

assert len(jax.devices()) == 8, jax.devices()
key = jax.random.PRNGKey(0)
topo = (768, 256, 256, 10)
bits = [jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topo[i], topo[i+1])).astype(jnp.int8)
        for i in range(len(topo)-1)]
vth = [jax.random.randint(jax.random.fold_in(key, 10+i), (topo[i+1],),
                          -10, 10, jnp.int32) for i in range(len(topo)-1)]
net = EsamNetwork(weight_bits=bits, vth=vth,
                  out_offset=jax.random.normal(jax.random.fold_in(key, 99),
                                               (topo[-1],)))
s = jax.random.bernoulli(jax.random.fold_in(key, 7), 0.35, (37, 768))

single = net.plan(mode="packed", telemetry=True, collect=True, interpret=True)(s)
dp_rules = shd.make_esam_rules(shd.esam_data_mesh())
dp_plan = net.plan(mode="packed", telemetry=True, collect=True, interpret=True,
                   rules=dp_rules)
# dp-sharded packed plans run the popcount mega cascade (batch-only shard);
# the executable closes over the prepped uint32 DMA slabs, not raw bits
assert dp_plan._use_mega
dp_params = dp_plan._prepare()
assert dp_params["w_stack"].dtype == jnp.uint32, dp_params["w_stack"].dtype
assert "weight_bits" not in dp_params
dp = dp_plan(s)
np.testing.assert_array_equal(np.asarray(dp.logits), np.asarray(single.logits))
for a, b in zip(dp.planes, single.planes):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
for a, b in zip(dp.loads, single.loads):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# data x model: hidden tile columns sharded over the model axis
mp_rules = shd.make_esam_rules(
    shd.make_mesh_axes((4, 2), ("data", "model")), col_axis="model")
mp_plan = net.plan(mode="packed", telemetry=True, interpret=True,
                   rules=mp_rules)
assert any(mp_plan._col_shard), mp_plan._col_shard
# column-sharded tiles cannot all_gather inside one launch: the plan falls
# back to per-tile popcount kernels over sharded uint32 weight planes
assert not mp_plan._use_mega
assert all(p.dtype == jnp.uint32 for p in mp_plan._prepare()["w_planes"])
mp = mp_plan(s)
np.testing.assert_array_equal(np.asarray(mp.logits), np.asarray(single.logits))
for a, b in zip(mp.loads, single.loads):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

fmp = net.plan(mode="functional", rules=mp_rules)(s)
np.testing.assert_array_equal(np.asarray(fmp.logits), np.asarray(single.logits))

# cycle-accurate sweep, data-parallel
cy_single = net.plan(mode="cycle", read_ports=(0, 4))(s)
cy_dp = net.plan(mode="cycle", read_ports=(0, 4), rules=dp_rules)(s)
for p in (0, 4):
    np.testing.assert_array_equal(
        np.asarray(cy_dp.sweep[p]["logits"]),
        np.asarray(cy_single.sweep[p]["logits"]))
    for ta, tb in zip(cy_dp.sweep[p]["traces"], cy_single.sweep[p]["traces"]):
        np.testing.assert_array_equal(np.asarray(ta.cycles), np.asarray(tb.cycles))
        np.testing.assert_array_equal(
            np.asarray(ta.grants_per_cycle), np.asarray(tb.grants_per_cycle))

# temporal plan, data-parallel: bit-identical to single device
from repro.core.esam.temporal import TemporalConfig
tcfg = TemporalConfig(n_steps=3, leak=0.25, reset="subtract")
ev = jax.random.bernoulli(jax.random.fold_in(key, 8), 0.3, (3, 37, 768))
t_single = net.plan(mode="temporal", temporal=tcfg, telemetry=True,
                    interpret=True)(ev)
t_dp = net.plan(mode="temporal", temporal=tcfg, telemetry=True,
                interpret=True, rules=dp_rules)(ev)
np.testing.assert_array_equal(np.asarray(t_dp.logits),
                              np.asarray(t_single.logits))
for a, b in zip(t_dp.loads, t_single.loads):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# serving engine through the sharded plan
from repro.serve.engine import SpikeEngine, SpikeRequest
eng = SpikeEngine(net, max_batch=16, interpret=True, telemetry=True,
                  rules=dp_rules)
reqs = eng.serve([SpikeRequest(spikes=np.asarray(s[i])) for i in range(11)])
for i, r in enumerate(reqs):
    np.testing.assert_array_equal(r.logits, np.asarray(single.logits[i]))
st = eng.stats()
assert st["n_requests"] == 11 and st["data_parallel"] == 8

# faulted plan, dp-sharded: the counter-based fault masks are built on the
# host from the topology alone, so the sharded executable must be
# bit-identical to the faulted single-device one (and differ from clean)
from repro.core.esam.faults import FaultModel
fm = FaultModel(seed=3, stuck0_rate=0.02, stuck1_rate=0.02,
                vth_sigma=1.0, read_disturb=1e-3)
f_single = net.plan(mode="packed", telemetry=True, interpret=True,
                    faults=fm)(s)
f_dp = net.plan(mode="packed", telemetry=True, interpret=True,
                faults=fm, rules=dp_rules)(s)
np.testing.assert_array_equal(np.asarray(f_dp.logits),
                              np.asarray(f_single.logits))
for a, b in zip(f_dp.loads, f_single.loads):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
assert not np.array_equal(np.asarray(f_dp.logits), np.asarray(single.logits))
f_fn = net.plan(mode="functional", faults=fm, rules=dp_rules)(s)
np.testing.assert_array_equal(np.asarray(f_fn.logits),
                              np.asarray(f_single.logits))
print("SHARDED_IDENTITY_OK")
"""


def test_sharded_plan_identity_on_host_mesh():
    """The shard_map-ped plan is bit-identical to single-device, verified in a
    subprocess so the host platform can be split into 8 devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src, env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SHARDED_IDENTITY_OK" in proc.stdout


# ----------------------------------------------------------------------- #
# the continuously batched SpikeEngine on top of the plan
# ----------------------------------------------------------------------- #
def test_spike_engine_stats_empty_regression():
    """stats() before any serve() is a well-defined zero aggregate."""
    from repro.serve.engine import SpikeEngine

    net = _rand_net(jax.random.PRNGKey(61), (128, 64, 10))
    st = SpikeEngine(net, interpret=True, telemetry=True).stats()
    assert st["n_requests"] == 0 and st["requests"] == 0
    for key in ("cycles_mean", "latency_ns_mean", "energy_pj_per_inf",
                "throughput_inf_s", "throughput_pipelined_inf_s"):
        assert st[key] == 0.0, (key, st[key])
    assert np.isfinite(list(
        v for v in st.values() if isinstance(v, float))).all()


def test_spike_engine_bucket_ladder_and_queue():
    from repro.serve.engine import SpikeEngine, SpikeRequest, _bucket_sizes

    assert _bucket_sizes(128, 8, 1) == [8, 16, 32, 64, 128]
    assert _bucket_sizes(128, 8, 16) == [16, 32, 64, 128]
    assert _bucket_sizes(2, 8, 1) == [2]       # min_bucket never exceeds max
    assert _bucket_sizes(100, 8, 1) == [8, 16, 32, 64, 128]

    net = _rand_net(jax.random.PRNGKey(63), (128, 64, 10))
    s = np.asarray(jax.random.bernoulli(jax.random.PRNGKey(11), 0.4, (11, 128)))
    eng = SpikeEngine(net, max_batch=8, min_bucket=2, interpret=True)
    assert eng._bucket(1) == 2 and eng._bucket(3) == 4 and eng._bucket(8) == 8
    # submit() queues without running; serve() drains everything pending
    eng.submit([SpikeRequest(spikes=s[i]) for i in range(3)])
    assert all(r.logits is None for r in eng._pending)
    out = eng.serve([SpikeRequest(spikes=s[i]) for i in range(3, 11)])
    assert not eng._pending and not eng._inflight
    want = np.asarray(net.plan(mode="functional")(jnp.asarray(s)).logits)
    for i, r in enumerate(out):        # the 8 passed to serve()
        np.testing.assert_array_equal(r.logits, want[3 + i])


def test_spike_engine_device_telemetry_matches_numpy_cost_model():
    """Device-resident float32 accounting agrees with the float64 numpy
    request_stats to ~1e-6 relative; cycles stay exact."""
    from repro.core.esam import cost_model as cm
    from repro.serve.engine import SpikeEngine, SpikeRequest

    net = _rand_net(jax.random.PRNGKey(65), (768, 256, 10))
    s = np.asarray(jax.random.bernoulli(jax.random.PRNGKey(12), 0.3, (9, 768)))
    eng = SpikeEngine(net, max_batch=4, interpret=True, telemetry=True,
                      read_ports=3)
    reqs = eng.serve([SpikeRequest(spikes=s[i]) for i in range(9)])
    act = net.measured_activity(jnp.asarray(s).astype(bool))
    rs = cm.request_stats(net.topology, act, 3)
    for i, r in enumerate(reqs):
        assert r.cycles == int(rs.cycles[i])
        assert r.latency_ns == pytest.approx(float(rs.latency_ns[i]))
        assert r.energy_pj == pytest.approx(float(rs.energy_pj[i]))
    st = eng.stats()
    assert st["cycles_mean"] == pytest.approx(rs.cycles.mean())
    assert st["energy_pj_per_inf"] == pytest.approx(rs.energy_pj.mean())
    # pipelined rate: bottleneck mean tile stage, same model as system_stats
    bottleneck = rs.cycles_per_tile.mean(axis=0).max()
    want_pipe = 1e9 / (bottleneck * cm.cell_spec(3).clock_ns)
    assert st["throughput_pipelined_inf_s"] == pytest.approx(want_pipe)


def test_request_stats_device_matches_numpy():
    from repro.core.esam import cost_model as cm

    rng = np.random.default_rng(0)
    topo = (768, 256, 256, 256, 10)
    loads = [rng.integers(0, 129, size=(13, -(-topo[t] // 128))).astype(np.int32)
             for t in range(len(topo) - 1)]
    for p in range(5):
        dev = cm.request_stats_device(topo, [jnp.asarray(l) for l in loads], p)
        ref = cm.request_stats(topo, [l.astype(np.float64) for l in loads], p)
        np.testing.assert_array_equal(np.asarray(dev["cycles"]), ref.cycles)
        np.testing.assert_allclose(
            np.asarray(dev["latency_ns"]), ref.latency_ns, rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(dev["energy_pj"]), ref.energy_pj, rtol=1e-5)


def test_packing_batch_prep_helpers():
    rows = [np.ones(100, np.int8), np.zeros(100, np.float32),
            (np.arange(100) % 2).astype(np.int32)]
    padded = packing.pad_spike_rows_np(rows, 8, 100)
    assert padded.shape == (8, 100) and padded.dtype == np.uint8
    np.testing.assert_array_equal(padded[0], 1)
    np.testing.assert_array_equal(padded[3:], 0)
    packed = packing.pack_padded_rows_np(rows, 8, 100)
    np.testing.assert_array_equal(packed, packing.pack_spikes_np(padded))

"""Gradient compression: int8 + error feedback invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.distributed import compression as comp


@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-4, 1e3))
@settings(max_examples=30, deadline=None)
def test_single_step_error_bounded(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * scale
    c, err = comp.compress(x)
    rec = comp.decompress(c)
    # per-element error bounded by half a quantization step
    step = float(c.scale)
    assert float(jnp.abs(rec + err - x).max()) < 1e-4 * scale + 1e-6
    assert float(jnp.abs(rec - x).max()) <= step / 2 + 1e-6


def test_error_feedback_makes_accumulation_unbiased():
    """Sum of decompressed grads + final error == sum of true grads exactly."""
    key = jax.random.PRNGKey(0)
    true_sum = jnp.zeros((128,))
    sent_sum = jnp.zeros((128,))
    err = jnp.zeros((128,))
    for i in range(50):
        g = jax.random.normal(jax.random.fold_in(key, i), (128,)) * 0.01
        true_sum = true_sum + g
        c, err = comp.compress(g, err)
        sent_sum = sent_sum + comp.decompress(c)
    np.testing.assert_allclose(np.asarray(sent_sum + err), np.asarray(true_sum),
                               rtol=1e-4, atol=1e-5)


def test_compressed_allreduce_under_shard_map():
    from repro import compat

    mesh = compat.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P

    g = {"w": jnp.linspace(-1.0, 1.0, 64)}
    e = comp.init_error_state(g)

    def f(g, e):
        return comp.compressed_allreduce(g, e, "data")

    out, new_e = jax.jit(
        compat.shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                         check=False)
    )(g, e)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]), atol=0.02)
    # residual consistent with the quantization
    np.testing.assert_allclose(np.asarray(out["w"] + new_e["w"]),
                               np.asarray(g["w"]), atol=1e-6)


def test_payload_is_int8():
    c, _ = comp.compress(jnp.ones((32,)))
    assert c.q.dtype == jnp.int8  # 4x smaller than f32 on the wire

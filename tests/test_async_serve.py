"""Asynchronous fused-dispatch drain loop: the zero-pressure property
(fused + overlapped drain is bit-identical to the synchronous drain — outputs
AND telemetry), fused-round counters, deadline interleavings, AOT warmup
(no compile left in the serve path), and the dp8 super-batch path on the
8-device host-platform mesh (subprocess, XLA_FLAGS)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.esam.network import EsamNetwork
from repro.serve.engine import (EventRequest, SpikeEngine, SpikeRequest,
                                _stats_jit)


def _net(key=None, topo=(128, 128, 10)):
    key = key if key is not None else jax.random.PRNGKey(0)
    n_tiles = len(topo) - 1
    bits = [
        jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topo[i], topo[i + 1])).astype(jnp.int8)
        for i in range(n_tiles)
    ]
    vth = [jnp.zeros((topo[i + 1],), jnp.int32) for i in range(n_tiles)]
    return EsamNetwork(weight_bits=bits, vth=vth,
                       out_offset=jnp.zeros((topo[-1],), jnp.float32))


def _spike_reqs(n, n_in=128, seed=0):
    return [
        SpikeRequest(spikes=(np.random.default_rng((seed, i)).random(n_in)
                             < 0.3).astype(np.uint8))
        for i in range(n)
    ]


def _event_reqs(n, t, n_in=128, seed=100):
    return [
        EventRequest(events=(np.random.default_rng((seed, i))
                             .random((t, n_in)) < 0.3).astype(np.uint8))
        for i in range(n)
    ]


def _mixed(n_static, event_spec, seed=0):
    """n_static static requests + one batch of event streams per (n, t)."""
    reqs = _spike_reqs(n_static, seed=seed)
    for j, (n, t) in enumerate(event_spec):
        reqs += _event_reqs(n, t, seed=seed + 1000 + j)
    return reqs


_TELEMETRY_FIELDS = ("cycles", "latency_ns", "energy_pj")


def _assert_same_results(got, want):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g.status == w.status, (g.status, w.status)
        if w.logits is None:
            assert g.logits is None
            continue
        np.testing.assert_array_equal(np.asarray(g.logits),
                                      np.asarray(w.logits))
        assert g.label == w.label
        for f in _TELEMETRY_FIELDS:
            gv, wv = getattr(g, f, None), getattr(w, f, None)
            if wv is None:
                assert gv is None, f
            else:
                np.testing.assert_array_equal(np.asarray(gv),
                                              np.asarray(wv), err_msg=f)


# ----------------------------------------------------------------------- #
# the zero-pressure property: async fused drain == synchronous drain
# ----------------------------------------------------------------------- #
@settings(max_examples=8, deadline=None)
@given(n_static=st.integers(0, 40),
       n_ev2=st.integers(0, 9),
       n_ev4=st.integers(0, 9),
       fuse=st.sampled_from([2, 4, "auto"]),
       overlap=st.booleans(),
       seed=st.integers(0, 3))
def test_fused_drain_bit_identical_to_sync(n_static, n_ev2, n_ev4, fuse,
                                           overlap, seed):
    """Property: under zero pressure (no deadlines, no admission limits) the
    fused + overlapped drain serves mixed static/event traffic bit-identically
    to the synchronous drain — logits, labels, AND per-request telemetry."""
    net = _net()
    spec = [(n_ev2, 2), (n_ev4, 4)]
    sync = SpikeEngine(net, interpret=True, max_batch=8, telemetry=True)
    a = _mixed(n_static, spec, seed=seed)
    sync.serve(a)

    fused = SpikeEngine(net, interpret=True, max_batch=8, telemetry=True,
                        fuse_rounds=fuse, overlap=overlap)
    b = _mixed(n_static, spec, seed=seed)
    fused.serve(b)
    _assert_same_results(b, a)

    # aggregate telemetry (exact float64 fold) agrees too
    ss, fs = sync.stats(), fused.stats()
    for key in ("n_requests", "cycles_mean", "latency_ns_mean",
                "energy_pj_per_inf"):
        assert ss[key] == fs[key], key
    fused.close()


def test_fused_counters_and_rounds_saved():
    """fuse_rounds=4 coalesces what would be 4 legacy bucket-rounds into one
    dispatch and books the savings in fused_rounds / rounds_saved."""
    eng = SpikeEngine(_net(), interpret=True, max_batch=8, fuse_rounds=4)
    eng.serve(_spike_reqs(32))
    st_ = eng.stats()
    assert st_["rounds_static"] == 1
    assert st_["fused_rounds"] == 1
    assert st_["rounds_saved"] == 3
    assert st_["fuse_rounds"] == 4
    eng.close()

    # sync engine books nothing
    sync = SpikeEngine(_net(), interpret=True, max_batch=8)
    sync.serve(_spike_reqs(32))
    st_ = sync.stats()
    assert st_["fused_rounds"] == 0 and st_["rounds_saved"] == 0
    assert st_["rounds_static"] == 4


def test_stats_division_guards_under_fused_rounds():
    """The per-bucket aggregates never divide by zero — empty engine, a
    served fused engine, and an all-padding bucket all yield finite stats."""
    eng = SpikeEngine(_net(), interpret=True, max_batch=8, fuse_rounds=4,
                      telemetry=True)
    st_ = eng.stats()                      # nothing served yet
    assert st_["pad_fraction_per_bucket"] == {}
    for key in ("cycles_mean", "latency_ns_mean", "energy_pj_per_inf"):
        assert st_[key] == 0.0

    eng.serve(_spike_reqs(9))              # 9 real rows in a 16-bucket
    st_ = eng.stats()
    for bucket, frac in st_["pad_fraction_per_bucket"].items():
        assert 0.0 <= frac < 1.0, (bucket, frac)
        real = st_["real_rows_per_bucket"][bucket]
        padded = st_["padded_rows_per_bucket"][bucket]
        assert frac == padded / (padded + real)
    assert st_["rows_real_total"] == 9
    eng.close()


# ----------------------------------------------------------------------- #
# deadline / shed interleavings
# ----------------------------------------------------------------------- #
@settings(max_examples=6, deadline=None)
@given(expired=st.lists(st.integers(0, 19), min_size=0, max_size=8),
       fuse=st.sampled_from([1, 2, 4]),
       overlap=st.booleans())
def test_expired_deadlines_shed_identically_under_fusion(expired, fuse,
                                                         overlap):
    """Already-expired requests shed identically in sync and fused drains,
    and every survivor's outputs stay bit-identical (fusion changes round
    boundaries, never results)."""
    expired = set(expired)

    def run(fuse_arg, ov):
        t = [0.0]
        eng = SpikeEngine(_net(), interpret=True, max_batch=4,
                          telemetry=True, fuse_rounds=fuse_arg, overlap=ov,
                          clock=lambda: t[0])
        reqs = _mixed(14, [(6, 2)], seed=5)
        for i in expired:
            reqs[i].deadline_s = -1.0      # expired before the drain starts
        eng.serve(reqs)
        st_ = eng.stats()
        eng.close()
        return reqs, st_

    a, sa = run(None, False)
    b, sb = run(fuse, overlap)
    _assert_same_results(b, a)
    assert sa["shed_deadline"] == sb["shed_deadline"] == len(expired)


def test_mid_drain_deadline_sweep_still_runs_between_fused_rounds():
    """Deadlines are swept between fused rounds: requests whose deadline
    passes after round 1 of a fused drain are shed, not served late."""
    t = [0.0]
    eng = SpikeEngine(_net(), interpret=True, max_batch=4, fuse_rounds=2,
                      clock=lambda: t[0])
    orig = eng._launch_static

    def advancing(reqs, bucket, packed, pack_s):
        orig(reqs, bucket, packed, pack_s)
        t[0] += 1.0

    eng._launch_static = advancing
    reqs = _spike_reqs(20)
    for r in reqs:
        r.deadline_s = 0.5
    eng.serve(reqs)
    done = [r for r in reqs if r.status == "done"]
    shed = [r for r in reqs if r.status == "shed"]
    # one fused round of 2*max_batch dispatches; everything else sheds
    assert len(done) == 8 and len(shed) == 12
    assert eng.stats()["shed_deadline"] == 12
    eng.close()


# ----------------------------------------------------------------------- #
# AOT warmup: no compile left in the serve path
# ----------------------------------------------------------------------- #
def test_warmup_leaves_no_compile_in_static_serve_path():
    """After warmup() the static serve path runs entirely through the AOT
    executables: replacing the plan's jit entry point with a bomb does not
    detonate."""
    eng = SpikeEngine(_net(), max_batch=8, telemetry=True, fuse_rounds=2)
    times = eng.warmup()
    assert set(eng._buckets) <= set(times["static"])
    assert set(eng._plan._aot) == set(eng._buckets)

    def bomb(*a, **k):
        raise AssertionError("jit dispatch reached after warmup")

    eng._plan._exec = bomb
    reqs = _spike_reqs(13)
    eng.serve(reqs)
    assert all(r.status == "done" for r in reqs)
    eng.close()


def test_warmup_covers_event_grid_too():
    """warmup(event_ts=...) AOT-compiles the (bucket, T) temporal grid; the
    cached per-T plans then serve event streams without touching jit."""
    eng = SpikeEngine(_net(), max_batch=8, telemetry=True)
    eng.warmup(event_ts=(2, 3))

    def bomb(*a, **k):
        raise AssertionError("jit dispatch reached after warmup")

    for t in (2, 3):
        plan = eng._event_plan(t)
        assert set(plan._aot) == set(eng._buckets), t
        plan._exec = bomb
    reqs = _event_reqs(5, t=2) + _event_reqs(4, t=3)
    eng.serve(reqs)
    assert all(r.status == "done" for r in reqs)
    eng.close()


def test_warmup_aot_false_falls_back_to_jit_warm():
    """aot=False warms by executing (populating the jit cache) instead of
    AOT-compiling — serve still works, nothing is pinned in _aot."""
    eng = SpikeEngine(_net(), max_batch=8)
    eng.warmup(aot=False)
    assert not eng._plan._aot
    reqs = _spike_reqs(3)
    eng.serve(reqs)
    assert all(r.status == "done" for r in reqs)
    eng.close()


def test_warmup_shares_stats_jit_with_serve():
    """The telemetry warm and the drain loop hit the same module-level jitted
    cost executable — warming it once covers every engine on the topology."""
    net = _net()
    eng = SpikeEngine(net, max_batch=8, telemetry=True)
    eng.warmup()
    fn = _stats_jit(net.topology, eng._effective_read_ports(), False)
    assert fn is _stats_jit(net.topology, eng._effective_read_ports(), False)
    eng.serve(_spike_reqs(3))
    assert eng.stats()["n_requests"] == 3
    eng.close()


def test_warmup_times_are_reported():
    eng = SpikeEngine(_net(), max_batch=8, telemetry=True)
    times = eng.warmup()
    assert times["total_s"] > 0.0
    assert times["telemetry_s"] >= 0.0
    for b in eng._buckets:
        assert times["static"][b] >= 0.0
    eng.close()


# ----------------------------------------------------------------------- #
# overlap machinery details
# ----------------------------------------------------------------------- #
def test_overlap_packer_thread_never_touches_jax():
    """The background packer only runs numpy packing; every launch happens on
    the caller thread (JAX dispatch is not thread-safe by contract here)."""
    import threading

    main = threading.get_ident()
    seen = []
    eng = SpikeEngine(_net(), interpret=True, max_batch=4, fuse_rounds=2,
                      overlap=True)
    orig = eng._launch_static

    def spy(reqs, bucket, packed, pack_s):
        seen.append(threading.get_ident())
        orig(reqs, bucket, packed, pack_s)

    eng._launch_static = spy
    eng.serve(_spike_reqs(24))
    assert seen and all(t == main for t in seen)
    eng.close()


def test_close_is_idempotent_and_shuts_down_packer():
    eng = SpikeEngine(_net(), interpret=True, max_batch=4, overlap=True)
    eng.serve(_spike_reqs(9))
    assert eng._pool is not None
    eng.close()
    eng.close()
    assert eng._pool is None


def test_degradation_ladder_caps_fusion():
    """A ladder level with fuse_cap throttles the super-batch so shed sweeps
    stay frequent under pressure (economy caps at 2, survival at 1)."""
    from repro.serve.overload import DegradationLadder

    ladder = DegradationLadder.default(8)
    names = [lv.name for lv in ladder.levels]
    eng = SpikeEngine(_net(), interpret=True, max_batch=8, fuse_rounds=8,
                      ladder=ladder, queue_limit=256)
    assert eng._round_budget() == 8 * eng._round_limit()
    eng._ladder_level = names.index("economy")       # fuse_cap=2
    assert eng._round_budget() == 2 * eng._round_limit()
    eng._ladder_level = names.index("survival")      # fuse_cap=1
    assert eng._round_budget() == eng._round_limit()
    eng.close()


# ----------------------------------------------------------------------- #
# dp super-batches on the 8-device host mesh (subprocess)
# ----------------------------------------------------------------------- #
_DP_FUSED_SCRIPT = r"""
import warnings; warnings.simplefilter("ignore")
import numpy as np, jax, jax.numpy as jnp
from repro.core.esam.network import EsamNetwork
from repro.distributed import sharding as shd
from repro.serve.engine import SpikeEngine, SpikeRequest, EventRequest

assert len(jax.devices()) == 8, jax.devices()
key = jax.random.PRNGKey(0)
topo = (256, 128, 10)
bits = [jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topo[i], topo[i+1])).astype(jnp.int8)
        for i in range(len(topo)-1)]
vth = [jnp.zeros((topo[i+1],), jnp.int32) for i in range(len(topo)-1)]
net = EsamNetwork(weight_bits=bits, vth=vth,
                  out_offset=jnp.zeros((topo[-1],), jnp.float32))
rules = shd.make_esam_rules(shd.esam_data_mesh(8))

def mk(seed):
    r = np.random.default_rng(seed)
    out = [SpikeRequest(spikes=(r.random(256) < 0.3).astype(np.uint8))
           for _ in range(40)]
    out += [EventRequest(events=(r.random((2, 256)) < 0.3).astype(np.uint8))
            for _ in range(6)]
    return out

# ground truth: synchronous single-device drain
sync = SpikeEngine(net, max_batch=16, telemetry=True)
a = mk(7); sync.serve(a)

# dp8 fused + overlapped + warmed drain must be bit-identical
fused = SpikeEngine(net, max_batch=16, telemetry=True, rules=rules,
                    fuse_rounds="auto", overlap=True)
assert fused._fuse == 8
fused.warmup(event_ts=(2,))
b = mk(7); fused.serve(b)
for x, y in zip(a, b):
    np.testing.assert_array_equal(np.asarray(x.logits), np.asarray(y.logits))
    assert x.label == y.label
    for f in ("cycles", "latency_ns", "energy_pj"):
        np.testing.assert_array_equal(np.asarray(getattr(x, f)),
                                      np.asarray(getattr(y, f)), err_msg=f)
st = fused.stats()
assert st["data_parallel"] == 8
assert st["rounds_saved"] > 0, st["rounds_saved"]
assert st["fused_rounds"] >= 1
fused.close(); sync.close()
print("DP_FUSED_IDENTITY_OK")
"""


def test_dp_fused_super_batch_identity_on_host_mesh():
    """dp8 fused super-batches are bit-identical to the single-device sync
    drain (outputs + telemetry), and actually save dispatch rounds."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src, env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-c", _DP_FUSED_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "DP_FUSED_IDENTITY_OK" in proc.stdout

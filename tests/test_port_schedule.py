"""Rank-schedule cycle-accurate plane vs the retained scan oracle.

The tentpole claim: ``simulate_tile{,_batch}`` (closed-form rank schedule,
no sequential loop) is bit-identical to ``simulate_tile_scan{,_batch}``
(the per-cycle arbitration loop) in EVERY TileTrace field — logits/V_mem,
cycles, grants-per-cycle, and opt-in V_mem traces — across ports 1..4,
non-128-multiple output widths, and degenerate request vectors."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.esam import cost_model as cm
from repro.core.esam import tile as tile_mod
from repro.core.esam.network import EsamNetwork, system_stats


def _rand_tile(key, n_in, n_out):
    kw, kt = jax.random.split(key)
    bits = jax.random.bernoulli(kw, 0.5, (n_in, n_out)).astype(jnp.int8)
    vth = jax.random.randint(kt, (n_out,), -10, 10, jnp.int32)
    return bits, vth


def _assert_traces_equal(a: tile_mod.TileTrace, b: tile_mod.TileTrace):
    for fa, fb, name in zip(a, b, tile_mod.TileTrace._fields):
        assert fa.shape == fb.shape and fa.dtype == fb.dtype, (
            name, fa.shape, fb.shape, fa.dtype, fb.dtype)
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb),
                                      err_msg=name)


@given(data=st.data())
@settings(max_examples=40, deadline=None)
def test_schedule_plane_bit_identical_to_scan_oracle(data):
    """Property sweep: ports 1..4, 128-multiple inputs, non-128-multiple
    outputs, random densities incl. the degenerate ends, both trace modes."""
    ports = data.draw(st.integers(1, 4))
    n_in = data.draw(st.sampled_from([128, 256, 384]))
    n_out = data.draw(st.sampled_from([10, 33, 64, 128]))  # incl. non-128-multiples
    density = data.draw(st.sampled_from([0.0, 0.1, 0.5, 0.9, 1.0]))
    record = data.draw(st.booleans())
    seed = data.draw(st.integers(0, 2**16))

    key = jax.random.PRNGKey(seed)
    bits, vth = _rand_tile(key, n_in, n_out)
    spikes = jax.random.bernoulli(jax.random.fold_in(key, 1), density, (n_in,))
    sched = tile_mod.simulate_tile(bits, spikes, vth, ports, record)
    scan = tile_mod.simulate_tile_scan(bits, spikes, vth, ports, record)
    _assert_traces_equal(sched, scan)


@pytest.mark.parametrize("ports", [1, 2, 3, 4])
@pytest.mark.parametrize("fill", [0, 1])
def test_all_zero_and_all_ones_requests(ports, fill):
    """The degenerate request vectors, with the full V_mem trace recorded."""
    n_in, n_out = 256, 10
    key = jax.random.PRNGKey(ports * 10 + fill)
    bits, vth = _rand_tile(key, n_in, n_out)
    spikes = jnp.full((n_in,), bool(fill))
    sched = tile_mod.simulate_tile(bits, spikes, vth, ports,
                                   record_vmem_trace=True)
    scan = tile_mod.simulate_tile_scan(bits, spikes, vth, ports,
                                       record_vmem_trace=True)
    _assert_traces_equal(sched, scan)
    want_cycles = 0 if fill == 0 else -(-128 // ports)
    assert int(sched.cycles) == want_cycles


@pytest.mark.parametrize("ports", [1, 3, 4])
def test_batched_schedule_plane_matches_scan_batch(ports):
    key = jax.random.PRNGKey(ports)
    bits, vth = _rand_tile(key, 384, 33)
    spikes = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.4, (16, 384))
    sched = tile_mod.simulate_tile_batch(bits, spikes, vth, ports,
                                         record_vmem_trace=True)
    scan = tile_mod.simulate_tile_scan_batch(bits, spikes, vth, ports,
                                             record_vmem_trace=True)
    _assert_traces_equal(sched, scan)


# ----------------------------------------------------------------------- #
# port_sweep API
# ----------------------------------------------------------------------- #
def _rand_net(key, topo):
    bits, vth = [], []
    for i in range(len(topo) - 1):
        b, t = _rand_tile(jax.random.fold_in(key, i), topo[i], topo[i + 1])
        bits.append(b)
        vth.append(t)
    return EsamNetwork(weight_bits=bits, vth=vth,
                       out_offset=jnp.zeros((topo[-1],), jnp.float32))


def test_port_sweep_covers_all_cells_in_one_call():
    key = jax.random.PRNGKey(0)
    net = _rand_net(key, (256, 128, 10))
    spikes = jax.random.bernoulli(jax.random.fold_in(key, 9), 0.4, (8, 256))
    sweep = net.port_sweep(spikes, read_ports=range(5))
    assert sorted(sweep) == [0, 1, 2, 3, 4]
    want = np.asarray(net.forward(spikes))
    for p, (logits, traces) in sweep.items():
        # logits are schedule-invariant; cycle counts are not
        np.testing.assert_array_equal(np.asarray(logits), want)
        assert len(traces) == 2 and traces[0].cycles.shape == (8,)
        ports = max(1, p)
        loads = np.asarray(spikes, np.int32).reshape(8, 2, 128).sum(-1)
        np.testing.assert_array_equal(
            np.asarray(traces[0].cycles),
            np.ceil(loads / ports).max(axis=1).astype(np.int32))


def test_port_sweep_traces_match_scan_oracle():
    key = jax.random.PRNGKey(4)
    net = _rand_net(key, (128, 128, 10))
    spikes = jax.random.bernoulli(jax.random.fold_in(key, 5), 0.3, (4, 128))
    sweep = net.port_sweep(spikes, read_ports=(2,))
    _, traces = sweep[2]
    s = spikes
    for w, th, tr in zip(net.weight_bits, net.vth, traces):
        _assert_traces_equal(tr, tile_mod.simulate_tile_scan_batch(w, s, th, 2))
        s = tr.out_spikes


# ----------------------------------------------------------------------- #
# measured activity -> cost model, per-request accounting
# ----------------------------------------------------------------------- #
def test_measured_activity_feeds_system_stats():
    key = jax.random.PRNGKey(7)
    net = _rand_net(key, (256, 128, 10))
    spikes = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (12, 256))
    sweep = net.port_sweep(spikes, read_ports=(4,))
    act = net.measured_activity(spikes, traces=sweep[4][1])
    # trace-fed loads == functional-plane loads (same datapath)
    act_fn = net.measured_activity(spikes)
    for a, b in zip(act, act_fn):
        np.testing.assert_array_equal(a, b)
    s4 = system_stats(net.topology, act, 4)
    rs = cm.request_stats(net.topology, act, 4)
    assert rs.energy_pj.shape == (12,)
    # system stats are the batch means of the per-request accounting
    assert s4.energy_pj_per_inf == pytest.approx(rs.energy_pj.mean())
    assert s4.latency_ns == pytest.approx(rs.cycles_per_tile.mean(0).sum()
                                          * cm.cell_spec(4).clock_ns)


def test_request_stats_matches_system_stats_on_reference_profile():
    from repro.core.esam.network import reference_activity

    act = reference_activity()
    for p in (0, 4):
        rs = cm.request_stats(cm.PAPER_TOPOLOGY, act, p)
        st_ = system_stats(cm.PAPER_TOPOLOGY, act, p)
        assert rs.energy_pj.mean() == pytest.approx(st_.energy_pj_per_inf)
        assert rs.latency_ns.mean() == pytest.approx(st_.latency_ns)
        # drain cycles per tile: ceil(load/p) + 1 fire cycle
        spec = cm.cell_spec(p)
        want = [np.ceil(cm.REF_SPIKES_PER_GROUP[t] / spec.ports) + 1
                for t in range(4)]
        np.testing.assert_allclose(rs.cycles_per_tile[0], want)


def test_spike_engine_telemetry_matches_request_stats():
    from repro.serve.engine import SpikeEngine, SpikeRequest

    key = jax.random.PRNGKey(11)
    net = _rand_net(key, (768, 256, 10))
    s = np.asarray(jax.random.bernoulli(jax.random.fold_in(key, 2), 0.3, (5, 768)))
    eng = SpikeEngine(net, batch_size=2, interpret=True,
                      telemetry=True, read_ports=4)
    reqs = eng.serve([SpikeRequest(spikes=s[i]) for i in range(5)])

    act = net.measured_activity(jnp.asarray(s).astype(bool))
    rs = cm.request_stats(net.topology, act, 4)
    for i, r in enumerate(reqs):
        assert r.cycles == int(rs.cycles[i])
        assert r.latency_ns == pytest.approx(float(rs.latency_ns[i]))
        assert r.energy_pj == pytest.approx(float(rs.energy_pj[i]))
    stats = eng.stats()
    assert stats["requests"] == 5 and stats["cell"] == "1RW+4R"
    assert stats["energy_pj_per_inf"] == pytest.approx(rs.energy_pj.mean())


def test_spike_engine_telemetry_zero_spike_request():
    """A silent request still pays the fire cycle on every tile, nothing more."""
    from repro.serve.engine import SpikeEngine, SpikeRequest

    key = jax.random.PRNGKey(13)
    net = _rand_net(key, (768, 256, 10))
    # vth > 0 so a silent input stays silent through the hidden tile
    net.vth[0] = jnp.ones((256,), jnp.int32)
    eng = SpikeEngine(net, batch_size=2, interpret=True, telemetry=True)
    r = eng.serve([SpikeRequest(spikes=np.zeros(768, np.uint8))])[0]
    assert r.cycles == len(net.weight_bits)  # one compare/fire cycle per tile

"""Online learning (stochastic 1-bit STDP via the transposable port)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.esam import learning, tile
from repro.data import digits


def test_stdp_only_touches_event_columns():
    key = jax.random.PRNGKey(0)
    bits = jax.random.bernoulli(key, 0.5, (64, 16)).astype(jnp.int8)
    pre = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (64,))
    post = jnp.zeros((16,), bool).at[3].set(True)
    new = learning.stdp_update(bits, pre, post, jax.random.fold_in(key, 2), 1.0, 1.0)
    untouched = np.delete(np.asarray(new), 3, axis=1)
    np.testing.assert_array_equal(untouched, np.delete(np.asarray(bits), 3, axis=1))
    # with p=1.0 the event column becomes exactly the pre-spike pattern
    np.testing.assert_array_equal(np.asarray(new[:, 3]), np.asarray(pre).astype(np.int8))


def test_stdp_probability_zero_is_identity():
    key = jax.random.PRNGKey(1)
    bits = jax.random.bernoulli(key, 0.5, (64, 16)).astype(jnp.int8)
    new = learning.stdp_update(
        bits, jnp.ones((64,), bool), jnp.ones((16,), bool), key, 0.0, 0.0
    )
    np.testing.assert_array_equal(np.asarray(new), np.asarray(bits))


def test_online_learning_improves_readout():
    """Supervised STDP on a tile improves accuracy from chance (prototype
    learning on the input spikes — the paper's online-adaptation use case)."""
    x, y = digits.make_spike_dataset(768, seed=3)
    x, y = jnp.asarray(x).astype(bool), jnp.asarray(y)
    key = jax.random.PRNGKey(0)
    bits = jax.random.bernoulli(key, 0.5, (768, 10)).astype(jnp.int8)
    vth = [jnp.full((10,), 2**31 - 1, jnp.int32)]

    def accuracy(b):
        _, vmem = tile.functional_tile(b, x, vth[0])
        return float((vmem.argmax(-1) == y).mean())

    acc0 = accuracy(bits)
    n_upd = 0
    for epoch in range(6):
        bits, n = learning.online_learning_epoch(
            [bits], vth, x, y, jax.random.PRNGKey(10 + epoch), p_pot=0.2, p_dep=0.1
        )
        n_upd += int(n)          # device scalar — cast once at the caller
    acc1 = accuracy(bits)
    assert acc0 < 0.25                      # random readout is near chance
    assert acc1 > acc0 + 0.3, (acc0, acc1)  # online STDP learns prototypes
    assert n_upd > 0


def test_online_learning_epoch_accepts_precomputed_pre_spikes():
    """Passing the collected last-hidden spikes (forward collect=True) gives
    bit-identical updates to letting the epoch re-run the frozen prefix."""
    from repro.core.esam import EsamNetwork

    key = jax.random.PRNGKey(4)
    topo = (128, 64, 10)
    bits = [
        jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topo[i], topo[i + 1])).astype(jnp.int8)
        for i in range(2)
    ]
    vth = [jax.random.randint(jax.random.fold_in(key, 10), (64,), -5, 5, jnp.int32),
           jnp.full((10,), 2**31 - 1, jnp.int32)]
    x = jax.random.bernoulli(jax.random.fold_in(key, 20), 0.4, (32, 128))
    y = jax.random.randint(jax.random.fold_in(key, 21), (32,), 0, 10, jnp.int32)

    new_a, n_a = learning.online_learning_epoch(
        bits, vth, x, y, jax.random.PRNGKey(9), p_pot=0.3, p_dep=0.15)
    net = EsamNetwork(weight_bits=bits, vth=vth, out_offset=jnp.zeros((10,)))
    _, per_layer = net.forward(x, collect=True)
    new_b, n_b = learning.online_learning_epoch(
        bits, vth, x, y, jax.random.PRNGKey(9), p_pot=0.3, p_dep=0.15,
        pre_spikes=per_layer[-1])
    np.testing.assert_array_equal(np.asarray(new_a), np.asarray(new_b))
    assert int(n_a) == int(n_b)


def test_online_learning_epoch_count_is_device_array():
    """The update count stays on device — no host sync inside the epoch."""
    x, y = digits.make_spike_dataset(16, seed=5)
    x, y = jnp.asarray(x).astype(bool), jnp.asarray(y)
    bits = jax.random.bernoulli(jax.random.PRNGKey(2), 0.5, (768, 10)).astype(jnp.int8)
    vth = [jnp.full((10,), 2**31 - 1, jnp.int32)]
    _, n = learning.online_learning_epoch(
        [bits], vth, x, y, jax.random.PRNGKey(0), p_pot=0.2, p_dep=0.1)
    assert isinstance(n, jax.Array) and n.dtype == jnp.int32 and n.ndim == 0
    _, n_scan = learning.online_learning_epoch_scan(
        [bits], vth, x, y, jax.random.PRNGKey(0), p_pot=0.2, p_dep=0.1)
    assert isinstance(n_scan, jax.Array) and n_scan.ndim == 0


def test_learning_cost_scales_with_columns():
    c = learning.column_update_cost(4)
    # updating k columns costs k * (col read + col write) on the transposed port
    k = 37
    assert k * (c.read_ns + c.write_ns) < 128 * 2 * 1.01 * 5  # far below 1RW cost

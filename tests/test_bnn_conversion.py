"""V3: BNN training + exact BNN->SNN conversion (Kim et al. [15] scheme)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esam import bnn, conversion
from repro.data import digits


@pytest.fixture(scope="module")
def trained():
    x, y = digits.make_spike_dataset(2048, seed=0)
    params, acc = bnn.fit(
        jax.random.PRNGKey(0), (768, 64, 64, 10), jnp.asarray(x), jnp.asarray(y),
        steps=200, batch=128, lr=3e-3,
    )
    return params, jnp.asarray(x), jnp.asarray(y), acc


def test_bnn_trains(trained):
    _, _, _, acc = trained
    assert acc > 0.9  # synthetic digits are easy; STE training must work


def test_conversion_hidden_spikes_match_bnn_activations(trained):
    params, x, _, _ = trained
    net = conversion.bnn_to_snn(params)
    xb = x[:256]
    bnn_acts = bnn.hidden_activations(params, xb)           # {-1,+1}
    _, snn_spikes = net.forward(xb.astype(bool), collect=True)
    for a, s in zip(bnn_acts, snn_spikes):
        np.testing.assert_array_equal(np.asarray(a) > 0, np.asarray(s))


def test_conversion_preserves_logits_affinely(trained):
    params, x, _, _ = trained
    net = conversion.bnn_to_snn(params)
    xb = x[:256]
    # exact BNN forward (no STE): recompute with hard signs
    h = xb
    for i, layer in enumerate(params):
        z = h @ bnn.sign_pm1(layer["w"]) + layer["b"]
        h = bnn.sign_pm1(z) if i < len(params) - 1 else z
    snn_scores = net.forward(xb.astype(bool))
    np.testing.assert_allclose(np.asarray(h), 2 * np.asarray(snn_scores), rtol=0, atol=1e-4)


def test_conversion_preserves_accuracy_exactly(trained):
    params, x, y, _ = trained
    net = conversion.bnn_to_snn(params)
    logits_bnn = bnn.forward(params, x)
    pred_snn = net.forward(x.astype(bool)).argmax(-1)
    np.testing.assert_array_equal(np.asarray(logits_bnn.argmax(-1)), np.asarray(pred_snn))


def test_paper_topology_trains_and_converts():
    """Full 768:256:256:256:10 network (paper topology), short training run."""
    x, y = digits.make_spike_dataset(1024, seed=1)
    params, acc = bnn.fit(
        jax.random.PRNGKey(1), (768, 256, 256, 256, 10), jnp.asarray(x), jnp.asarray(y),
        steps=120, batch=128,
    )
    net = conversion.bnn_to_snn(params)
    assert net.topology == (768, 256, 256, 256, 10)
    pred = net.forward(jnp.asarray(x[:512]).astype(bool)).argmax(-1)
    snn_acc = float((pred == jnp.asarray(y[:512])).mean())
    assert snn_acc > 0.8


def test_single_layer_conversion_regression():
    """A 1-tile BNN converts without UnboundLocalError: the only tile is the
    readout tile, its inputs are {0,1} spikes, so offset = b exactly and the
    SNN scores are the BNN logits up to the positive 1/sqrt(fan_in) scale."""
    key = jax.random.PRNGKey(7)
    params = [{
        "w": jax.random.normal(key, (32, 10), jnp.float32),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (10,)),
    }]
    net = conversion.bnn_to_snn(params)          # raised UnboundLocalError
    assert net.topology == (32, 10)
    np.testing.assert_array_equal(np.asarray(net.out_offset),
                                  np.asarray(params[0]["b"]))
    x = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (64, 32))
    scores = np.asarray(net.plan(mode="functional")(x).logits)
    want = np.asarray(x.astype(jnp.float32) @ bnn.sign_pm1(params[0]["w"])
                      + params[0]["b"])
    np.testing.assert_allclose(scores, want, rtol=0, atol=1e-5)
    np.testing.assert_array_equal(
        scores.argmax(-1), np.asarray(bnn.forward(params, x.astype(jnp.float32)).argmax(-1)))

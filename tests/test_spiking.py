"""SpikingLinear (beyond-paper ESAM-mode LM layer)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spiking
from repro.models import params as pm


def test_forward_matches_cim_kernel_plane():
    """The layer's forward MAC == the ESAM binary MAC (kernels plane)."""
    from repro.kernels.cim_matmul import ops as cim_ops

    key = jax.random.PRNGKey(0)
    params = pm.init(spiking.spiking_linear_specs(128, 128), key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, 128))
    out = spiking.spiking_linear(params, x)
    spikes = (x >= 0).astype(jnp.float32)
    bits = ((jnp.sign(params["w"]) + 1) // 2).astype(jnp.int8)
    vmem = cim_ops.cim_matmul(spikes, bits, interpret=True)
    np.testing.assert_allclose(np.asarray(out - params["b"]),
                               np.asarray(vmem, np.float32), atol=1e-4)


def test_top_p_arbiter_limits_events():
    x = jnp.asarray([[5.0, 3.0, -1.0, 4.0, 0.5]])
    masked = spiking.top_p_arbiter(x, 2)
    assert int((masked >= 0).sum()) == 2     # only the 2 largest remain active
    assert float(spiking.event_rate(x, ports=2)) == pytest.approx(0.4)


def test_gradients_flow_through_ste():
    params = pm.init(spiking.spiking_linear_specs(64, 32), jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64)) * 0.1

    def loss(p, x):
        return jnp.sum(spiking.spiking_linear(p, x) ** 2)

    g = jax.grad(loss)(params, x)
    assert float(jnp.abs(g["w"]).sum()) > 0
    assert np.isfinite(float(jnp.abs(g["w"]).max()))


def test_trains_a_toy_task():
    """Binary layer learns a linearly separable task through the STE."""
    key = jax.random.PRNGKey(3)
    w_true = jax.random.normal(key, (32,))
    x = jax.random.normal(jax.random.fold_in(key, 1), (512, 32))
    y = (x @ w_true > 0).astype(jnp.int32)
    params = pm.init(spiking.spiking_linear_specs(32, 2), jax.random.fold_in(key, 2))

    def loss_fn(p):
        logits = spiking.spiking_linear(p, x)
        lp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(lp, y[:, None], axis=1).mean()

    @jax.jit
    def step(p, lr=0.1):
        l, g = jax.value_and_grad(loss_fn)(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g), l

    for _ in range(150):
        params, l = step(params)
    logits = spiking.spiking_linear(params, x)
    acc = float((logits.argmax(-1) == y).mean())
    # {0,1} spikes discard the magnitude/sign detail of x, capping a single
    # binary layer well below 100% on this task; >0.65 shows the STE learns.
    assert acc > 0.65, acc

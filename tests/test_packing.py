"""Packed spike plane: wire-format round trips, packed-kernel bit-exactness
vs the unpacked kernels and jnp oracles, and the fused multi-tile cascade."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import packing
from repro.core.esam import EsamNetwork
from repro.kernels.cim_matmul import ops as cim_ops
from repro.kernels.cim_matmul_packed import ops as pk_ops


# ----------------------------------------------------------------------- #
# pack / unpack round trips
# ----------------------------------------------------------------------- #
@given(
    n=st.integers(1, 300),
    batch=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=40, deadline=None)
def test_pack_unpack_round_trip(n, batch, seed):
    """unpack(pack(x)) == x for random shapes incl. non-multiple-of-32 n."""
    s = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (batch, n))
    p = packing.pack_spikes(s)
    assert p.dtype == jnp.uint32 and p.shape == (batch, packing.packed_width(n))
    np.testing.assert_array_equal(
        np.asarray(packing.unpack_spikes(p, n)), np.asarray(s, np.int8)
    )


@given(n=st.sampled_from([128, 256, 768]), seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_group_popcount_matches_unpacked_counts(n, seed):
    """Arbiter loads straight off the wire == counts on the unpacked plane."""
    s = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.4, (4, n))
    counts = packing.group_popcount(packing.pack_spikes(s))
    want = np.asarray(s, np.int32).reshape(4, n // 128, 128).sum(-1)
    np.testing.assert_array_equal(np.asarray(counts), want)


@given(n=st.integers(1, 300), seed=st.integers(0, 2**16))
@settings(max_examples=40, deadline=None)
def test_pack_of_unpack_is_identity_on_words(n, seed):
    """pack(unpack(w)) == w when the tail bits beyond n are zero."""
    s = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (4, n))
    p = packing.pack_spikes(s)
    p2 = packing.pack_spikes(packing.unpack_spikes(p, n))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(p))


def test_numpy_and_jnp_packing_are_bit_identical():
    s = np.asarray(jax.random.bernoulli(jax.random.PRNGKey(3), 0.4, (16, 100)))
    np.testing.assert_array_equal(
        packing.pack_spikes_np(s), np.asarray(packing.pack_spikes(jnp.asarray(s)))
    )
    np.testing.assert_array_equal(
        packing.unpack_spikes_np(packing.pack_spikes_np(s), 100),
        s.astype(np.int8),
    )


def test_packed_width_and_nbytes():
    assert packing.packed_width(768) == 24
    assert packing.packed_width(10) == 1
    # >= 8x wire reduction vs the int8 spike plane for 32-aligned widths
    assert packing.packed_nbytes(768) * 8 == 768


# ----------------------------------------------------------------------- #
# weight bit planes (popcount-domain wire format for the weight matrix)
# ----------------------------------------------------------------------- #
@given(
    n_in=st.integers(1, 300),
    n_out=st.integers(1, 40),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=40, deadline=None)
def test_weight_plane_round_trip(n_in, n_out, seed):
    """unpack(pack(W)) == W for random [K, N] incl. non-multiple-of-32 K."""
    w = jax.random.bernoulli(
        jax.random.PRNGKey(seed), 0.5, (n_in, n_out)).astype(jnp.int8)
    planes = packing.pack_weight_planes(w)
    assert planes.dtype == jnp.uint32
    assert planes.shape == (n_out, packing.packed_width(n_in))
    np.testing.assert_array_equal(
        np.asarray(packing.unpack_weight_planes(planes, n_in)), np.asarray(w)
    )


@given(n_in=st.integers(1, 200), seed=st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_weight_plane_signed_round_trip(n_in, seed):
    """Signed +-1 matrices ride the same planes: bits = (W > 0), and the
    plane round trip reconstructs W exactly via 2b - 1."""
    bits = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (n_in, 24))
    w_signed = 2 * bits.astype(jnp.int32) - 1
    planes = packing.pack_weight_planes((w_signed > 0).astype(jnp.int8))
    back = packing.unpack_weight_planes(planes, n_in, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(2 * back - 1), np.asarray(w_signed)
    )


@pytest.mark.parametrize("n_in", [32, 100, 256])
def test_weight_plane_all_zero_and_all_one(n_in):
    """Degenerate planes: all-zero rows pack to zero words; all-one rows set
    exactly the first n_in bits (tail stays silent — padding is exact)."""
    zeros = packing.pack_weight_planes(jnp.zeros((n_in, 8), jnp.int8))
    np.testing.assert_array_equal(np.asarray(zeros), 0)
    ones = packing.pack_weight_planes(jnp.ones((n_in, 8), jnp.int8))
    per_plane = np.array(
        [bin(int(wd)).count("1") for wd in np.asarray(ones[0])]
    ).sum()
    assert per_plane == n_in  # no stray bits past n_in in the last word
    np.testing.assert_array_equal(
        np.asarray(packing.unpack_weight_planes(ones, n_in)), 1
    )


def test_weight_plane_numpy_and_jnp_bit_identical():
    w = np.asarray(
        jax.random.bernoulli(jax.random.PRNGKey(17), 0.5, (100, 12)), np.int8)
    np.testing.assert_array_equal(
        packing.pack_weight_planes_np(w),
        np.asarray(packing.pack_weight_planes(jnp.asarray(w))),
    )
    np.testing.assert_array_equal(
        packing.unpack_weight_planes_np(packing.pack_weight_planes_np(w), 100),
        w,
    )


def test_weight_planes_share_spike_wire_layout():
    """Weight planes are pack_spikes of W^T — one plane per output column,
    bit j*32+b of plane n holds W[j*32+b, n]; same LSB-first lane format the
    spike wire uses, so AND+popcount needs no per-operand shuffling."""
    w = jax.random.bernoulli(jax.random.PRNGKey(23), 0.5, (96, 4))
    np.testing.assert_array_equal(
        np.asarray(packing.pack_weight_planes(w)),
        np.asarray(packing.pack_spikes(w.T)),
    )


# ----------------------------------------------------------------------- #
# packed kernels vs unpacked kernel + oracle — bit exact
# ----------------------------------------------------------------------- #
# includes K not a multiple of 128 (100, 160) and B/N off the tile grid;
# the packed wrapper pads internally, the unpacked kernel cannot take every
# shape (its blocks must divide the operands), so kernel-vs-kernel runs where
# both are legal and the jnp oracle covers the rest.
PACKED_SHAPES = [(8, 128, 128), (64, 384, 128), (37, 100, 10), (200, 160, 32)]
UNPACKED_LEGAL = {(8, 128, 128), (64, 384, 128), (37, 100, 10)}


@pytest.mark.parametrize("B,K,N", PACKED_SHAPES)
def test_cim_matmul_packed_bit_exact(B, K, N):
    key = jax.random.PRNGKey(B * 7 + K + N)
    s = jax.random.bernoulli(key, 0.4, (B, K))
    w = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (K, N)).astype(jnp.int8)
    p = packing.pack_spikes(s)
    out = pk_ops.cim_matmul_packed(p, w, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(pk_ops.cim_matmul_packed_ref(p, w))
    )
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(cim_ops.cim_matmul_ref(s, w))
    )
    if (B, K, N) in UNPACKED_LEGAL:
        np.testing.assert_array_equal(
            np.asarray(out),
            np.asarray(cim_ops.cim_matmul(s.astype(jnp.float32), w, interpret=True)),
        )


@pytest.mark.parametrize("B,K,N", [(8, 128, 128), (64, 384, 256), (37, 100, 64)])
@pytest.mark.parametrize("pack_output", [True, False])
def test_esam_layer_packed_bit_exact(B, K, N, pack_output):
    key = jax.random.PRNGKey(B + K + N)
    s = jax.random.bernoulli(key, 0.5, (B, K))
    w = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (K, N)).astype(jnp.int8)
    vth = jax.random.randint(jax.random.fold_in(key, 2), (N,), -9, 9, jnp.int32)
    p = packing.pack_spikes(s)
    out = pk_ops.esam_layer_packed(p, w, vth, pack_output=pack_output, interpret=True)
    ref = pk_ops.esam_layer_packed_ref(p, w, vth, pack_output=pack_output)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    fired = cim_ops.esam_layer_ref(s, w, vth)
    if pack_output:
        np.testing.assert_array_equal(
            np.asarray(packing.unpack_spikes(out, N)), np.asarray(fired)
        )
    else:
        np.testing.assert_array_equal(np.asarray(out), np.asarray(fired))


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_cim_matmul_packed_property(seed):
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 64))
    K = int(rng.integers(1, 300))
    N = int(rng.integers(1, 96))
    key = jax.random.PRNGKey(seed)
    s = jax.random.bernoulli(key, float(rng.uniform(0, 1)), (B, K))
    w = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (K, N)).astype(jnp.int8)
    out = pk_ops.cim_matmul_packed(packing.pack_spikes(s), w, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(cim_ops.cim_matmul_ref(s, w))
    )


# ----------------------------------------------------------------------- #
# fused multi-tile cascade == layer-by-layer functional plane
# ----------------------------------------------------------------------- #
def _rand_net(key, topo):
    bits, vth = [], []
    for i in range(len(topo) - 1):
        k = jax.random.fold_in(key, i)
        bits.append(
            jax.random.bernoulli(k, 0.5, (topo[i], topo[i + 1])).astype(jnp.int8)
        )
        vth.append(
            jax.random.randint(jax.random.fold_in(k, 1), (topo[i + 1],), -10, 10, jnp.int32)
        )
    off = jax.random.normal(jax.random.fold_in(key, 99), (topo[-1],))
    return EsamNetwork(weight_bits=bits, vth=vth, out_offset=off)


def test_forward_fused_equals_forward_esam_mnist_topology():
    """256-sample batch through the paper's 768:256:256:256:10 topology."""
    from repro.core.esam import cost_model as cm

    net = _rand_net(jax.random.PRNGKey(0), cm.PAPER_TOPOLOGY)
    s = jax.random.bernoulli(jax.random.PRNGKey(42), 0.35, (256, 768))
    np.testing.assert_array_equal(
        np.asarray(net.forward_fused(s, interpret=True)),
        np.asarray(net.forward(s)),
    )


def test_forward_fused_single_sample_and_odd_batch():
    net = _rand_net(jax.random.PRNGKey(5), (128, 64, 10))
    s1 = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (128,))
    np.testing.assert_array_equal(
        np.asarray(net.forward_fused(s1, interpret=True)), np.asarray(net.forward(s1))
    )
    s = jax.random.bernoulli(jax.random.PRNGKey(2), 0.5, (37, 128))
    np.testing.assert_array_equal(
        np.asarray(net.forward_fused(s, interpret=True)), np.asarray(net.forward(s))
    )


def test_forward_fused_packed_accepts_wire_format():
    """Pre-packed host-side batches (the serving path) give identical logits."""
    net = _rand_net(jax.random.PRNGKey(9), (256, 128, 10))
    s = np.asarray(jax.random.bernoulli(jax.random.PRNGKey(3), 0.4, (64, 256)))
    packed = jnp.asarray(packing.pack_spikes_np(s))
    np.testing.assert_array_equal(
        np.asarray(net.forward_fused_packed(packed, interpret=True)),
        np.asarray(net.forward(jnp.asarray(s))),
    )


# ----------------------------------------------------------------------- #
# packed plane consumers: data pipeline + serving engine
# ----------------------------------------------------------------------- #
def test_spike_pipeline_emits_packed_wire_format_and_resumes():
    from repro.data.pipeline import SpikePipeline, SpikePipelineConfig

    pipe = SpikePipeline(SpikePipelineConfig(batch=16, seed=3))
    b0 = pipe.next_batch()
    assert b0["spikes_packed"].dtype == np.uint32
    assert b0["spikes_packed"].shape == (16, packing.packed_width(b0["n_in"]))
    # resumable: a fresh pipeline sought to the same step is bit-exact
    pipe2 = SpikePipeline(SpikePipelineConfig(batch=16, seed=3))
    pipe2.seek(1)
    b1a, b1b = pipe.next_batch(), pipe2.next_batch()
    np.testing.assert_array_equal(b1a["spikes_packed"], b1b["spikes_packed"])
    np.testing.assert_array_equal(b1a["labels"], b1b["labels"])
    # packed plane matches the unpacked plane of the same step
    pipe3 = SpikePipeline(SpikePipelineConfig(batch=16, seed=3, packed=False))
    b0u = pipe3.batch_at(0)
    np.testing.assert_array_equal(
        b0["spikes_packed"], packing.pack_spikes_np(b0u["spikes"])
    )


def test_spike_engine_serves_packed_batches():
    from repro.serve.engine import SpikeEngine, SpikeRequest

    net = _rand_net(jax.random.PRNGKey(11), (768, 256, 10))
    s = np.asarray(jax.random.bernoulli(jax.random.PRNGKey(4), 0.3, (5, 768)))
    # batch_size=2 forces multiple rounds + a padded final slot
    eng = SpikeEngine(net, batch_size=2, interpret=True)
    reqs = [SpikeRequest(spikes=s[i]) for i in range(5)]
    out = eng.serve(reqs)
    want = np.asarray(net.forward(jnp.asarray(s)))
    for i, r in enumerate(out):
        np.testing.assert_array_equal(r.logits, want[i])
        assert r.label == int(want[i].argmax())

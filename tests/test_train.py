"""Training stack: optimizer semantics, loss descent, ZeRO spec rules,
stochastic rounding, schedule."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import base as cb
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models.params import ParamSpec
from repro.train import loop as train_loop
from repro.train import optimizer as opt


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = opt.AdamState(
        m=jax.tree.map(jnp.zeros_like, params),
        v=jax.tree.map(jnp.zeros_like, params),
        step=jnp.zeros((), jnp.int32),
    )
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.adamw_update(params, grads, state, lr=jnp.asarray(0.05),
                                         weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros((4,))}
    state = opt.AdamState(m=jax.tree.map(jnp.zeros_like, params),
                          v=jax.tree.map(jnp.zeros_like, params),
                          step=jnp.zeros((), jnp.int32))
    huge = {"w": jnp.full((4,), 1e9)}
    p2, _ = opt.adamw_update(params, huge, state, lr=jnp.asarray(1e-3), grad_clip=1.0)
    assert float(jnp.abs(p2["w"]).max()) < 0.01  # clipped -> bounded step


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_stochastic_rounding_is_unbiased_and_bounded(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(jax.random.fold_in(key, 1), (256,), jnp.float32) * 0.1
    keys = jax.random.split(key, 64)
    rounded = jnp.stack([opt._stochastic_bf16(x, k).astype(jnp.float32) for k in keys])
    # every draw is one of the two neighbouring bf16 values
    lo = jnp.minimum(rounded.min(0), x)
    assert float(jnp.abs(rounded.mean(0) - x).max()) < 2e-3   # unbiased-ish
    err = jnp.abs(rounded - x[None])
    ulp = jnp.abs(x) * 2**-7 + 1e-38
    assert bool(jnp.all(err <= ulp + 1e-6))                    # within 1 ulp


def test_zero1_adds_data_axis_only_when_safe():
    class R:  # minimal rules stub
        rules = {"embed": "data", "mlp": "model", "heads": "model"}
    # param with an fsdp'd (data-mapped) dim: no zero axis added
    s1 = ParamSpec((1024, 512), ("embed", "mlp"))
    z1 = opt.zero1_spec(s1, 16, True, R())
    assert z1.axes == s1.axes
    # param with a free dim: zero axis lands on the largest free dim
    s2 = ParamSpec((1024, 512), (None, "mlp"))
    z2 = opt.zero1_spec(s2, 16, True, R())
    assert z2.axes == ("zero", "mlp")
    # non-divisible free dim: untouched
    s3 = ParamSpec((1023, 512), (None, "mlp"))
    assert opt.zero1_spec(s3, 16, True, R()).axes == s3.axes


def test_lr_schedule_shape():
    s = jnp.asarray
    assert float(opt.lr_schedule(s(0), peak=1.0, warmup=10, total=100)) == 0.0
    assert float(opt.lr_schedule(s(10), peak=1.0, warmup=10, total=100)) == pytest.approx(1.0, rel=0.01)
    end = float(opt.lr_schedule(s(100), peak=1.0, warmup=10, total=100))
    assert end == pytest.approx(0.1, rel=0.05)  # min_ratio floor


def test_train_loop_descends_loss():
    cfg = cb.smoke("llama3.2-1b")
    tcfg = train_loop.TrainConfig(lr=1e-3, warmup=5, total_steps=30, log_every=1)
    pipe = TokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0))
    state, history = train_loop.run(cfg, tcfg, pipe)
    assert history[0]["loss"] > history[-1]["loss"] + 0.3
    assert np.isfinite(history[-1]["loss"])


def test_bf16_sr_training_works():
    """The 1T-tier optimizer mode (bf16 states + SR) still trains a small model."""
    import dataclasses
    cfg = dataclasses.replace(cb.smoke("llama3.2-1b"), optimizer_dtype="bfloat16")
    tcfg = train_loop.TrainConfig(lr=1e-3, warmup=5, total_steps=25, log_every=1)
    pipe = TokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=1))
    state, history = train_loop.run(cfg, tcfg, pipe)
    assert history[0]["loss"] > history[-1]["loss"] + 0.2

"""The hypothesis fallback shim must work whether or not real hypothesis is
installed — CI installs the real package, so this test drives the shim
directly instead of relying on the import-time fallback path."""

import sys

import conftest


def _shim_modules():
    """Build the shim into a scratch namespace without touching sys.modules."""
    saved = {k: sys.modules.get(k) for k in ("hypothesis", "hypothesis.strategies")}
    try:
        for k in saved:
            sys.modules.pop(k, None)
        conftest._install_hypothesis_fallback()
        return sys.modules["hypothesis"], sys.modules["hypothesis.strategies"]
    finally:
        for k, v in saved.items():
            if v is None:
                sys.modules.pop(k, None)
            else:
                sys.modules[k] = v


def test_shim_given_settings_run_examples_and_hide_params():
    hyp, st = _shim_modules()
    assert getattr(hyp, "__is_repro_fallback__", False)
    calls = []

    @hyp.given(seed=st.integers(0, 99), flag=st.booleans())
    @hyp.settings(max_examples=7, deadline=None)
    def prop(seed, flag):
        assert 0 <= seed <= 99 and isinstance(flag, bool)
        calls.append((seed, flag))

    prop()
    assert len(calls) == 7
    # deterministic: a second run draws the same examples
    first = list(calls)
    calls.clear()
    prop()
    assert calls == first
    # drawn params are hidden from pytest's fixture resolution
    import inspect

    assert list(inspect.signature(prop).parameters) == []


def test_shim_strategies_draw_within_bounds():
    hyp, st = _shim_modules()
    seen = []

    @hyp.settings(max_examples=15)
    @hyp.given(data=st.data())  # settings-inside order must work too
    def prop(data):
        xs = data.draw(st.lists(st.booleans(), min_size=1, max_size=5))
        assert 1 <= len(xs) <= 5 and all(isinstance(b, bool) for b in xs)
        assert data.draw(st.sampled_from([3, 5, 8])) in (3, 5, 8)
        f = data.draw(st.floats(0.25, 0.75))
        assert 0.25 <= f <= 0.75
        seen.append(len(xs))

    prop()
    assert len(seen) == 15

"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp ref oracles,
sweeping shapes/dtypes (deliverable (c))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.arbiter import ops as arb_ops
from repro.kernels.cim_matmul import ops as cim_ops
from repro.kernels.if_neuron import ops as if_ops
from repro.kernels.stdp import ops as stdp_ops

# ----------------------------------------------------------------------- #
# cim_matmul / esam_layer
# ----------------------------------------------------------------------- #
SHAPES = [(8, 128, 128), (128, 128, 256), (64, 384, 128), (256, 256, 384)]
SPIKE_DTYPES = [jnp.float32, jnp.bfloat16, jnp.int8, jnp.bool_]


@pytest.mark.parametrize("B,K,N", SHAPES)
@pytest.mark.parametrize("sdt", SPIKE_DTYPES)
def test_cim_matmul_matches_ref(B, K, N, sdt):
    key = jax.random.PRNGKey(B + K + N)
    s = jax.random.bernoulli(key, 0.4, (B, K)).astype(sdt)
    w = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (K, N)).astype(jnp.int8)
    out = cim_ops.cim_matmul(s, w, interpret=True)
    ref = cim_ops.cim_matmul_ref(s, w)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("B,K,N", SHAPES[:2])
@pytest.mark.parametrize("blocks", [(128, 128, 128), (8, 128, 64), (64, 128, 128)])
def test_cim_matmul_block_shape_sweep(B, K, N, blocks):
    bb, bn, bk = blocks
    key = jax.random.PRNGKey(7)
    s = jax.random.bernoulli(key, 0.3, (B, K)).astype(jnp.float32)
    w = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (K, N)).astype(jnp.int8)
    out = cim_ops.cim_matmul(s, w, block_b=bb, block_n=bn, block_k=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(cim_ops.cim_matmul_ref(s, w)))


@pytest.mark.parametrize("B,K,N", SHAPES[:3])
def test_esam_layer_fused_fire(B, K, N):
    key = jax.random.PRNGKey(11)
    s = jax.random.bernoulli(key, 0.5, (B, K)).astype(jnp.float32)
    w = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (K, N)).astype(jnp.int8)
    vth = jax.random.randint(jax.random.fold_in(key, 2), (N,), -9, 9, jnp.int32)
    out = cim_ops.esam_layer(s, w, vth, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(cim_ops.esam_layer_ref(s, w, vth)))


def test_cim_matmul_extreme_inputs():
    # all-zero spikes, all-one spikes, all-one weights
    for sval, wval in [(0, 0), (0, 1), (1, 0), (1, 1)]:
        s = jnp.full((8, 128), sval, jnp.float32)
        w = jnp.full((128, 128), wval, jnp.int8)
        out = cim_ops.cim_matmul(s, w, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(cim_ops.cim_matmul_ref(s, w)))


# ----------------------------------------------------------------------- #
# arbiter
# ----------------------------------------------------------------------- #
@pytest.mark.parametrize("ports", [1, 2, 3, 4])
@pytest.mark.parametrize("G,W", [(8, 128), (16, 128), (8, 256), (24, 64)])
def test_arbiter_kernel_matches_ref(ports, G, W):
    key = jax.random.PRNGKey(ports * 100 + G)
    req = jax.random.bernoulli(key, 0.3, (G, W)).astype(jnp.int8)
    g, rem, val = arb_ops.arbiter(req, ports=ports, interpret=True)
    g2, rem2, val2 = arb_ops.arbiter_ref(req, ports)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g2))
    np.testing.assert_array_equal(np.asarray(rem), np.asarray(rem2))
    np.testing.assert_array_equal(np.asarray(val), np.asarray(val2))


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_arbiter_kernel_property(data):
    """Property sweep vs the hardware cascade oracle, random densities."""
    G = data.draw(st.sampled_from([8, 16]))
    density = data.draw(st.floats(0.0, 1.0))
    ports = data.draw(st.integers(1, 4))
    seed = data.draw(st.integers(0, 2**16))
    req = jax.random.bernoulli(jax.random.PRNGKey(seed), density, (G, 128)).astype(jnp.int8)
    g, rem, val = arb_ops.arbiter(req, ports=ports, interpret=True)
    for row in range(G):
        g_ref, rem_ref, val_ref = arb_ops.priority_grants_oracle(
            np.asarray(req[row], bool), ports
        )
        np.testing.assert_array_equal(np.asarray(g[row], bool), g_ref)
        np.testing.assert_array_equal(np.asarray(rem[row], bool), rem_ref)
        np.testing.assert_array_equal(np.asarray(val[row], bool), val_ref)


@pytest.mark.parametrize("ports", [1, 2, 3, 4])
@pytest.mark.parametrize("N,W", [(8, 128), (16, 128), (6, 128), (5, 128), (8, 256)])
def test_port_schedule_kernel_matches_ref(ports, N, W):
    key = jax.random.PRNGKey(ports * 100 + N + W)
    req = jax.random.bernoulli(key, 0.4, (N, W)).astype(jnp.int8)
    c, n = arb_ops.port_schedule_kernel(req, ports=ports, interpret=True)
    c2, n2 = arb_ops.port_schedule_ref(req, ports)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(n), np.asarray(n2))


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_port_schedule_matches_cascade_oracle(data):
    """The closed-form schedule is the cascade's grant order: replaying the
    priority-encoder oracle cycle by cycle must land every grant on the cycle
    the schedule assigned it."""
    ports = data.draw(st.integers(1, 4))
    density = data.draw(st.floats(0.0, 1.0))
    seed = data.draw(st.integers(0, 2**16))
    req = jax.random.bernoulli(jax.random.PRNGKey(seed), density, (4, 128))
    cycle_of, counts = arb_ops.port_schedule(req.astype(jnp.int8), ports=ports,
                                             use_kernel=False)
    n_cycles = counts.shape[-1]
    for g in range(4):
        r = np.asarray(req[g], bool)
        for cyc in range(n_cycles):
            grants, r, valid = arb_ops.priority_grants_oracle(r, ports)
            granted = np.flatnonzero(grants.any(axis=0))
            assert int(np.asarray(counts)[g, cyc]) == int(valid.sum())
            np.testing.assert_array_equal(
                np.asarray(cycle_of)[g, granted], cyc)
        assert not r.any()


# ----------------------------------------------------------------------- #
# compile-path (non-interpret) coverage — skip gracefully where the backend
# cannot compile Pallas TPU kernels (e.g. plain CPU CI)
# ----------------------------------------------------------------------- #
def _compiled_or_skip(fn):
    try:
        return jax.block_until_ready(fn())
    except Exception as e:  # noqa: BLE001 — Mosaic/XLA raises backend-specific types
        if jax.default_backend() == "tpu":
            raise  # TPU is the dispatch target of ops.port_schedule — fail loudly
        pytest.skip(
            f"non-interpret pallas unsupported on {jax.default_backend()}: "
            f"{type(e).__name__}")


@pytest.mark.parametrize("ports", [1, 4])
def test_arbiter_kernel_compiled(ports):
    req = jax.random.bernoulli(jax.random.PRNGKey(ports), 0.3, (8, 128)).astype(jnp.int8)
    g, rem, val = _compiled_or_skip(
        lambda: arb_ops.arbiter(req, ports=ports, interpret=False))
    g2, rem2, val2 = arb_ops.arbiter_ref(req, ports)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g2))
    np.testing.assert_array_equal(np.asarray(rem), np.asarray(rem2))
    np.testing.assert_array_equal(np.asarray(val), np.asarray(val2))


@pytest.mark.parametrize("ports", [1, 4])
def test_port_schedule_kernel_compiled(ports):
    req = jax.random.bernoulli(jax.random.PRNGKey(ports), 0.5, (8, 128)).astype(jnp.int8)
    c, n = _compiled_or_skip(
        lambda: arb_ops.port_schedule_kernel(req, ports=ports, interpret=False))
    c2, n2 = arb_ops.port_schedule_ref(req, ports)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(n), np.asarray(n2))


# ----------------------------------------------------------------------- #
# if_neuron
# ----------------------------------------------------------------------- #
@pytest.mark.parametrize("B,T,N", [(8, 32, 128), (16, 5, 256), (8, 1, 128)])
def test_if_neuron_matches_ref(B, T, N):
    key = jax.random.PRNGKey(B * T + N)
    upd = jax.random.randint(key, (B, T, N), -4, 5, jnp.int32)
    vth = jax.random.randint(jax.random.fold_in(key, 1), (N,), -20, 20, jnp.int32)
    spikes, vmem = if_ops.if_neuron(upd, vth, interpret=True)
    s_ref, v_ref = if_ops.if_neuron_ref(upd, vth)
    np.testing.assert_array_equal(np.asarray(spikes), np.asarray(s_ref))
    np.testing.assert_array_equal(np.asarray(vmem), np.asarray(v_ref))


def test_if_neuron_threshold_edge():
    """fire iff V_mem >= V_th — equality must fire (Sec 2.1)."""
    upd = jnp.ones((8, 3, 128), jnp.int32)
    vth = jnp.full((128,), 3, jnp.int32)
    spikes, vmem = if_ops.if_neuron(upd, vth, interpret=True)
    assert bool(jnp.all(vmem == 3)) and bool(jnp.all(spikes == 1))


# ----------------------------------------------------------------------- #
# stdp
# ----------------------------------------------------------------------- #
@pytest.mark.parametrize("n_out,n_in", [(16, 128), (128, 256), (8, 128)])
@pytest.mark.parametrize("p_pot,p_dep", [(0.0, 0.0), (1.0, 1.0), (0.3, 0.1)])
def test_stdp_kernel_matches_ref(n_out, n_in, p_pot, p_dep):
    key = jax.random.PRNGKey(n_out + n_in)
    ks = jax.random.split(key, 5)
    bits = jax.random.bernoulli(ks[0], 0.5, (n_out, n_in)).astype(jnp.int8)
    pre = jax.random.bernoulli(ks[1], 0.4, (n_in,)).astype(jnp.int8)
    post = jax.random.bernoulli(ks[2], 0.2, (n_out,)).astype(jnp.int8)
    u_pot = jax.random.uniform(ks[3], (n_out, n_in))
    u_dep = jax.random.uniform(ks[4], (n_out, n_in))
    out = stdp_ops.stdp_update(bits, pre, post, u_pot, u_dep,
                               p_pot=p_pot, p_dep=p_dep, interpret=True)
    ref = stdp_ops.stdp_update_ref(bits, pre, post, u_pot, u_dep, p_pot, p_dep)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_stdp_kernel_agrees_with_core_learning_rule():
    """kernel(transposed layout) == core stdp_update (row-major functional)."""
    from repro.core.esam import learning as core_learning

    key = jax.random.PRNGKey(5)
    n_in, n_out = 256, 128
    bits = jax.random.bernoulli(key, 0.5, (n_in, n_out)).astype(jnp.int8)
    pre = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (n_in,))
    post = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.3, (n_out,))
    # core rule with fixed uniforms == kernel with the same uniforms
    k1, k2 = jax.random.split(jax.random.fold_in(key, 3))
    u_pot = jax.random.uniform(k1, (n_in, n_out))
    u_dep = jax.random.uniform(k2, (n_in, n_out))
    ref = stdp_ops.stdp_update_ref(bits.T, pre, post, u_pot.T, u_dep.T, 0.25, 0.1)
    out = stdp_ops.stdp_update(bits.T, pre.astype(jnp.int8), post.astype(jnp.int8),
                               u_pot.T, u_dep.T, p_pot=0.25, p_dep=0.1, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ----------------------------------------------------------------------- #
# kernel-vs-core end-to-end
# ----------------------------------------------------------------------- #
def test_kernel_layer_equals_core_functional_tile():
    from repro.core.esam import tile as core_tile

    key = jax.random.PRNGKey(21)
    s = jax.random.bernoulli(key, 0.45, (64, 256))
    bits = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (256, 128)).astype(jnp.int8)
    vth = jax.random.randint(jax.random.fold_in(key, 2), (128,), -8, 8, jnp.int32)
    spikes_k = cim_ops.esam_layer(s.astype(jnp.float32), bits, vth, interpret=True)
    spikes_c, _ = core_tile.functional_tile(bits, s, vth)
    np.testing.assert_array_equal(np.asarray(spikes_k, bool), np.asarray(spikes_c))

"""Serving engine: batched greedy decode, continuous batching, eos handling."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cb
from repro.models import lm, params as pm
from repro.serve.engine import Engine, Request


def _engine(arch="llama3.2-1b", batch_size=2):
    cfg = cb.smoke(arch)
    params = pm.init(lm.model_specs(cfg), jax.random.PRNGKey(0))
    return Engine(params, cfg, batch_size=batch_size), cfg


def test_engine_serves_batch():
    eng, cfg = _engine()
    reqs = [Request(prompt=np.arange(5) % cfg.vocab_size, max_new_tokens=4)
            for _ in range(2)]
    out = eng.serve(reqs)
    for r in out:
        assert r.output is not None and r.output.shape == (4,)
        assert (0 <= r.output).all() and (r.output < cfg.vocab_size).all()


def test_engine_queues_beyond_batch_size():
    eng, cfg = _engine(batch_size=2)
    reqs = [Request(prompt=np.asarray([1, 2, 3]), max_new_tokens=3) for _ in range(5)]
    out = eng.serve(reqs)
    assert all(r.output is not None for r in out)


def test_engine_greedy_matches_manual_decode():
    eng, cfg = _engine()
    prompt = np.asarray([5, 6, 7, 8])
    out = eng.serve([Request(prompt=prompt, max_new_tokens=3)])[0].output
    # manual greedy rollout with the raw model API
    params = eng.params
    toks = jnp.asarray(prompt)[None, :]
    logits, caches = lm.prefill(params, cfg, {"tokens": toks}, cache_len=4 + 3)
    manual = []
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        manual.append(int(nxt[0, 0]))
        logits, caches = lm.decode_step(params, cfg, nxt, caches)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    np.testing.assert_array_equal(out, np.asarray(manual))


def test_engine_eos_stops_early():
    eng, cfg = _engine()
    # find the first emitted token, then use it as eos for a second request
    probe = eng.serve([Request(prompt=np.asarray([1, 2]), max_new_tokens=1)])[0]
    eos = int(probe.output[0])
    r = eng.serve([Request(prompt=np.asarray([1, 2]), max_new_tokens=8, eos_id=eos)])[0]
    assert len(r.output) == 1 and int(r.output[0]) == eos

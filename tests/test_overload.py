"""Overload-hardened serving plane: deadlines, bounded admission,
backpressure, the degradation ladder, per-round dispatch counters, the
``degraded_route`` fix, and the zero-pressure identity property (engine with
no overload knobs == the raw packed plan, bit for bit)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import packing
from repro.core.esam.network import EsamNetwork
from repro.train import fault_tolerance as ft
from repro.serve.engine import (EventRequest, FaultAwareRouter, SpikeEngine,
                                SpikeRequest, _bucket_sizes)
from repro.serve.overload import (AdmissionVerdict, DegradationLadder,
                                  LadderLevel)


def _net(key=None, topo=(128, 128, 10)):
    key = key if key is not None else jax.random.PRNGKey(0)
    n_tiles = len(topo) - 1
    bits = [
        jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topo[i], topo[i + 1])).astype(jnp.int8)
        for i in range(n_tiles)
    ]
    vth = [jnp.zeros((topo[i + 1],), jnp.int32) for i in range(n_tiles)]
    return EsamNetwork(weight_bits=bits, vth=vth,
                       out_offset=jnp.zeros((topo[-1],), jnp.float32))


def _spike_reqs(n, n_in=128, seed=0):
    return [
        SpikeRequest(spikes=(np.random.default_rng((seed, i)).random(n_in)
                             < 0.3).astype(np.uint8))
        for i in range(n)
    ]


def _event_reqs(n, t, n_in=128, seed=100):
    return [
        EventRequest(events=(np.random.default_rng((seed, i))
                             .random((t, n_in)) < 0.3).astype(np.uint8))
        for i in range(n)
    ]


# ----------------------------------------------------------------------- #
# bounded admission queue + backpressure
# ----------------------------------------------------------------------- #
def test_bounded_queue_rejects_and_counts():
    eng = SpikeEngine(_net(), interpret=True, max_batch=8, queue_limit=4)
    reqs = _spike_reqs(7)
    verdicts = eng.submit(reqs)
    assert [v.admitted for v in verdicts] == [True] * 4 + [False] * 3
    assert all(v.reason == "queue_full" for v in verdicts[4:])
    assert all(r.status == "rejected" for r in reqs[4:])
    assert eng.queue_depth() == 4
    eng.serve()
    st_ = eng.stats()
    assert st_["rejected_full"] == 3
    assert st_["n_requests"] == 4
    assert all(r.logits is not None for r in reqs[:4])
    assert all(r.logits is None for r in reqs[4:])


def test_backpressure_past_high_water():
    eng = SpikeEngine(_net(), interpret=True, max_batch=8, queue_limit=8,
                      high_water=2)
    verdicts = eng.submit(_spike_reqs(5))
    assert [v.backpressure for v in verdicts] == [False, False, True, True,
                                                  True]
    assert eng.stats()["backpressure_events"] == 3
    # default high-water = half the queue limit
    eng2 = SpikeEngine(_net(), interpret=True, queue_limit=8)
    assert eng2.stats()["high_water"] == 4


def test_unbounded_queue_always_admits():
    eng = SpikeEngine(_net(), interpret=True, max_batch=8)
    verdicts = eng.submit(_spike_reqs(40))
    assert all(v.admitted and not v.backpressure for v in verdicts)
    single = eng.submit(_spike_reqs(1)[0])
    assert isinstance(single, AdmissionVerdict) and single.admitted


# ----------------------------------------------------------------------- #
# per-request deadlines
# ----------------------------------------------------------------------- #
def test_deadline_shed_counted_and_terminal():
    t = [0.0]
    eng = SpikeEngine(_net(), interpret=True, max_batch=8,
                      clock=lambda: t[0])
    reqs = _spike_reqs(6)
    reqs[1].deadline_s = -1.0          # already expired
    reqs[4].deadline_s = 100.0         # far future
    eng.serve(reqs)
    assert reqs[1].status == "shed" and reqs[1].logits is None
    assert reqs[4].status == "done" and reqs[4].logits is not None
    st_ = eng.stats()
    assert st_["shed_deadline"] == 1
    assert st_["n_requests"] == 5


def test_deadline_expiring_mid_drain_sheds_later_round():
    """The clock advances one unit per dispatch round; a deadline of 0.5
    sheds everything not dispatched in the very first round."""
    t = [0.0]
    eng = SpikeEngine(_net(), interpret=True, max_batch=4,
                      clock=lambda: t[0])
    orig = eng._dispatch

    def advancing(reqs):
        orig(reqs)
        t[0] += 1.0

    eng._dispatch = advancing
    reqs = _spike_reqs(10)
    for r in reqs:
        r.deadline_s = 0.5
    eng.serve(reqs)
    done = [r for r in reqs if r.status == "done"]
    shed = [r for r in reqs if r.status == "shed"]
    assert len(done) == 4 and len(shed) == 6       # one round, rest shed
    assert eng.stats()["shed_deadline"] == 6


def test_event_requests_shed_on_deadline_too():
    t = [0.0]
    eng = SpikeEngine(_net(), interpret=True, max_batch=8,
                      clock=lambda: t[0])
    reqs = _event_reqs(3, t=2)
    reqs[0].deadline_s = -1.0
    eng.serve(reqs)
    assert reqs[0].status == "shed"
    assert all(r.status == "done" for r in reqs[1:])
    assert eng.stats()["shed_deadline"] == 1


# ----------------------------------------------------------------------- #
# degradation ladder
# ----------------------------------------------------------------------- #
def _pressure_ladder(**kw):
    return DegradationLadder(levels=(
        LadderLevel("full"),
        LadderLevel("reduced", event_t_cap=2, read_ports=2, bucket_cap=4),
    ), **kw)


def test_ladder_steps_down_on_queue_depth_and_back_up():
    # a never-flagging watchdog pins the pressure signal to queue depth
    eng = SpikeEngine(_net(), interpret=True, max_batch=4, high_water=4,
                      watchdog=ft.StragglerWatchdog(threshold=1e9),
                      ladder=_pressure_ladder(step_down_after=2,
                                              step_up_after=2))
    eng.serve(_spike_reqs(24))          # deep queue -> sustained pressure
    st_ = eng.stats()
    assert st_["ladder_transitions"] >= 1
    log = st_["ladder_transition_log"]
    assert log[0]["from"] == "full" and log[0]["to"] == "reduced"
    assert log[0]["reason"] == "queue_depth"
    # pressure cleared: a few quiet rounds step back up to full service
    for _ in range(3):
        eng.serve(_spike_reqs(2, seed=7))
    st2 = eng.stats()
    assert st2["degradation_level"] == 0
    assert st2["ladder_transition_log"][-1]["reason"] == "pressure_cleared"


def test_degraded_level_truncates_event_streams():
    ladder = _pressure_ladder(step_down_after=1, step_up_after=50)
    eng = SpikeEngine(_net(), interpret=True, max_batch=4, high_water=1,
                      ladder=ladder)
    reqs = _event_reqs(10, t=4)
    eng.serve(reqs)
    served = [r for r in reqs if r.status == "done"]
    assert served
    # once degraded, streams are truncated to the level's T cap
    assert eng.stats()["degradation_level"] == 1
    assert any(r.served_steps == 2 for r in served)
    full = [r for r in served if r.served_steps == 4]
    trunc = [r for r in served if r.served_steps == 2]
    assert len(full) + len(trunc) == len(served)


def test_degraded_level_caps_round_size():
    ladder = _pressure_ladder(step_down_after=1, step_up_after=50)
    eng = SpikeEngine(_net(), interpret=True, max_batch=16, min_bucket=4,
                      high_water=1, ladder=ladder)
    eng.serve(_spike_reqs(32))
    st_ = eng.stats()
    assert st_["degradation_level"] == 1
    # after the step-down, rounds are capped at bucket_cap=4
    assert 4 in st_["rounds_per_bucket"]


def test_ladder_default_levels_are_pow2_buckets():
    lad = DegradationLadder.default(128, 4)
    assert lad.levels[0].event_t_cap is None
    for lv in lad.levels[1:]:
        if lv.bucket_cap is not None:
            assert lv.bucket_cap & (lv.bucket_cap - 1) == 0
        assert lv.read_ports is None or 1 <= lv.read_ports <= 4


def test_no_ladder_means_pinned_full_service():
    eng = SpikeEngine(_net(), interpret=True, max_batch=4, high_water=1)
    eng.serve(_spike_reqs(20))
    st_ = eng.stats()
    assert st_["degradation_level"] == 0 and st_["ladder_transitions"] == 0


# ----------------------------------------------------------------------- #
# per-round host-sync/dispatch counters (dp8 regression observability)
# ----------------------------------------------------------------------- #
def test_round_counters_track_padding_and_times():
    eng = SpikeEngine(_net(), interpret=True, max_batch=8, min_bucket=8)
    eng.serve(_spike_reqs(11))          # rounds of 8 + 3 -> bucket 8 twice
    st_ = eng.stats()
    assert st_["rounds_static"] == 2 and st_["rounds_event"] == 0
    assert st_["rows_real_total"] == 11
    assert st_["rows_padded_total"] == 5            # 3-row round padded to 8
    assert st_["rounds_per_bucket"] == {8: 2}
    assert st_["padded_rows_per_bucket"] == {8: 5}
    assert st_["pad_fraction"] == pytest.approx(5 / 16)
    assert st_["host_pack_s_total"] > 0.0
    assert st_["dispatch_s_total"] > 0.0
    eng.serve(_event_reqs(3, t=2))
    st2 = eng.stats()
    assert st2["rounds_event"] == 1
    assert st2["rows_real_total"] == 14


# ----------------------------------------------------------------------- #
# FaultAwareRouter: degraded_route is visible, raise mode available
# ----------------------------------------------------------------------- #
def _degraded_engine():
    """An engine whose health() reads 0 (forced), without any device work."""
    eng = SpikeEngine(_net(), interpret=True, max_batch=8)
    eng.health = lambda: 0.0
    return eng


def test_all_degraded_fallback_counts_degraded_route():
    eng = _degraded_engine()
    router = FaultAwareRouter([eng], health_threshold=0.5)
    idx = router.route(_spike_reqs(1)[0])
    assert idx == 0
    assert router.stats()["degraded_route"] == 1


def test_all_degraded_raise_mode():
    from repro.serve.engine import AllReplicasDegradedError

    router = FaultAwareRouter([_degraded_engine()], health_threshold=0.5,
                              on_all_degraded="raise")
    with pytest.raises(AllReplicasDegradedError):
        router.route(_spike_reqs(1)[0])
    assert router.stats()["degraded_route"] == 1
    assert router.routed == [0]                    # nothing silently queued


def test_router_spill_to_degraded_on_full_healthy_queue_is_counted():
    healthy = SpikeEngine(_net(), interpret=True, max_batch=8, queue_limit=1)
    degraded = _degraded_engine()
    router = FaultAwareRouter([healthy, degraded], health_threshold=0.5)
    r1, r2 = _spike_reqs(2)
    assert router.route(r1) == 0
    assert router.route(r2) == 1                   # healthy queue full
    assert router.stats()["degraded_route"] == 1
    assert r2.status == "pending"                  # overflow, not rejection


def test_router_rejects_when_every_queue_full():
    engines = [SpikeEngine(_net(), interpret=True, queue_limit=1)
               for _ in range(2)]
    router = FaultAwareRouter(engines)
    reqs = _spike_reqs(3)
    assert router.route(reqs[0]) == 0
    assert router.route(reqs[1]) == 1
    assert router.route(reqs[2]) is None
    assert reqs[2].status == "rejected"
    assert router.stats()["rejected_full"] == 1


# ----------------------------------------------------------------------- #
# _bucket_sizes / _bucket edge cases (property tests)
# ----------------------------------------------------------------------- #
@settings(max_examples=60)
@given(max_batch=st.integers(1, 512), min_bucket=st.integers(1, 64),
       dp_exp=st.integers(0, 4))
def test_bucket_sizes_properties(max_batch, min_bucket, dp_exp):
    dp = 2 ** dp_exp
    sizes = _bucket_sizes(max_batch, min_bucket, dp)
    assert sizes == sorted(sizes)
    # every bucket is a power of two and a multiple of the dp degree
    for b in sizes:
        assert b & (b - 1) == 0
        assert b % dp == 0
    # the ladder covers max_batch: the top bucket fits any round the engine
    # can form (rounds are capped at max_batch requests)
    assert sizes[-1] >= max_batch
    # strictly doubling ladder (no duplicate shapes to compile)
    for a, b in zip(sizes, sizes[1:]):
        assert b == 2 * a


def test_bucket_sizes_min_bucket_larger_than_max_batch():
    # max_batch < min_bucket: the smallest bucket never exceeds the
    # rounded-up max_batch, so tiny engines don't over-pad
    sizes = _bucket_sizes(4, 64, 1)
    assert sizes == [4]


def test_bucket_sizes_dp_larger_than_max_batch():
    # dp > max_batch: divisibility wins, a single dp-wide bucket
    sizes = _bucket_sizes(3, 2, 8)
    assert sizes == [8]


def test_bucket_sizes_non_pow2_max_batch():
    sizes = _bucket_sizes(100, 8, 2)
    assert sizes == [8, 16, 32, 64, 128]


def test_bucket_clamps_to_top_bucket():
    """A round larger than the top bucket clamps to it — the serve loop
    never forms such a round (rounds are capped at max_batch), so the clamp
    is the documented safety behavior, not a truncation path."""
    eng = SpikeEngine(_net(), interpret=True, max_batch=8, min_bucket=4)
    assert eng._buckets == [4, 8]
    assert eng._bucket(3) == 4
    assert eng._bucket(8) == 8
    assert eng._bucket(1000) == 8


# ----------------------------------------------------------------------- #
# zero-pressure identity: acceptance-criteria property test
# ----------------------------------------------------------------------- #
@settings(max_examples=8)
@given(seed=st.integers(0, 2 ** 16), n=st.integers(1, 20))
def test_zero_pressure_identity_vs_raw_plan(seed, n):
    """No deadline, unbounded queue, no ladder, no chaos: the overloaded
    engine's results are bit-identical to the raw packed plan on the same
    padded bucket — i.e. to the pre-overload engine."""
    net = _net()
    eng = SpikeEngine(net, interpret=True, max_batch=16)
    reqs = _spike_reqs(n, seed=seed)
    eng.serve(reqs)
    bucket = eng._bucket(min(n, 16))
    # reference: the raw plan on the first round's padded bucket
    first = reqs[:16]
    packed = jnp.asarray(packing.pack_padded_rows_np(
        [r.spikes for r in first], bucket, 128))
    want = np.asarray(net.plan(mode="packed", interpret=True)(packed).logits)
    for i, r in enumerate(first):
        np.testing.assert_array_equal(r.logits, want[i])
        assert r.status == "done"


def test_mixed_static_event_serve_preserves_order_and_results():
    """Satellite: mixed static+event serve() returns the caller's list in
    order, each request carrying its own kind's results."""
    net = _net()
    eng = SpikeEngine(net, interpret=True, max_batch=8)
    statics = _spike_reqs(3, seed=1)
    events = _event_reqs(3, t=2, seed=2)
    mixed = [statics[0], events[0], statics[1], events[1], statics[2],
             events[2]]
    out = eng.serve(list(mixed))
    assert [id(r) for r in out] == [id(r) for r in mixed]
    assert all(r.logits is not None for r in mixed)
    # static results == packed plan on the static bucket
    packed = jnp.asarray(packing.pack_padded_rows_np(
        [r.spikes for r in statics], 8, 128))
    want = np.asarray(net.plan(mode="packed", interpret=True)(packed).logits)
    for i, r in enumerate(statics):
        np.testing.assert_array_equal(r.logits, want[i])
    # event labels are argmax of their own logits, T recorded
    for r in events:
        assert r.served_steps == 2
        assert r.label == int(np.asarray(r.logits).argmax())

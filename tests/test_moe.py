"""MoE layer: routing semantics, EP paths (weight-gather vs token-gather vs
dropless), capacity behaviour, gradient flow."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.distributed import sharding as shd
from repro.models import moe, params as pm


def _setup(expert_fsdp=False, moe_impl="gather_weights", cf=8.0):
    cfg = dataclasses.replace(
        cb.smoke("kimi-k2-1t-a32b"), expert_fsdp=expert_fsdp,
        moe_impl=moe_impl, capacity_factor=cf)
    params = pm.init(moe.moe_specs(cfg), jax.random.PRNGKey(0))
    x = (jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
         * 0.5).astype(jnp.bfloat16)
    return cfg, params, x


def _rules(cfg, expert_fsdp):
    from repro import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    return shd.make_rules(
        mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        n_experts=cfg.n_experts, d_ff=cfg.d_ff, d_model=cfg.d_model,
        vocab_size=cfg.vocab_size, expert_fsdp=expert_fsdp)


def test_capacity_path_matches_dropless_at_high_capacity():
    """With capacity >> balanced load nothing drops: EP == dropless exactly."""
    cfg, params, x = _setup()
    with shd.use_rules(_rules(cfg, False)):
        y_ep = moe.moe_ffn(params, cfg, x)
    with shd.use_rules(None):
        y_ref = moe.moe_ffn(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y_ep, np.float32),
                               np.asarray(y_ref, np.float32), atol=1e-2)


def test_token_gather_path_matches_dropless():
    cfg, params, x = _setup(expert_fsdp=True, moe_impl="gather_tokens")
    with shd.use_rules(_rules(cfg, True)):
        y_tok = moe.moe_ffn(params, cfg, x)
    with shd.use_rules(None):
        y_ref = moe.moe_ffn(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y_tok, np.float32),
                               np.asarray(y_ref, np.float32), atol=1e-2)


def test_low_capacity_drops_but_stays_finite():
    cfg, params, x = _setup(cf=0.2)
    with shd.use_rules(_rules(cfg, False)):
        y = moe.moe_ffn(params, cfg, x)
    assert not bool(jnp.isnan(y.astype(jnp.float32)).any())
    # dropped rows pass through as zeros -> smaller norm than high-capacity
    cfg2, params2, _ = _setup(cf=8.0)
    with shd.use_rules(_rules(cfg2, False)):
        y_full = moe.moe_ffn(params2, cfg2, x)
    assert float(jnp.abs(y).sum()) <= float(jnp.abs(y_full).sum()) + 1e-3


def test_router_topk_gates_normalized():
    cfg, params, x = _setup()
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    gates, ids = jax.lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(gates, axis=-1)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(ids.max()) < cfg.n_experts


@pytest.mark.parametrize("impl", ["gather_weights", "gather_tokens"])
def test_moe_gradients_flow(impl):
    cfg, params, x = _setup(expert_fsdp=(impl == "gather_tokens"), moe_impl=impl)
    rules = _rules(cfg, impl == "gather_tokens")

    def loss(p):
        with shd.use_rules(rules):
            return jnp.sum(moe.moe_ffn(p, cfg, x).astype(jnp.float32) ** 2)

    g = jax.grad(loss)(params)
    for k in ("w_gate", "w_up", "w_down"):
        assert float(jnp.abs(g[k].astype(jnp.float32)).sum()) > 0, (impl, k)

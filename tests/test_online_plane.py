"""Column-event online-learning plane: the fused transposable-port epoch.

Covers the PR-2 tentpole: 3-way STDP rule equivalence (functional rule vs
Pallas transposed-layout kernel vs jnp oracle under shared uniforms), the
column-event kernel's blocked in-place write, bit-identity of the fused epoch
against the scan reference under the shared key-folding scheme, multi-tile
learning through the packed prefix, and the multi-epoch train/online driver
(accuracy tracking, checkpointing, resume).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.esam import learning, tile
from repro.core.esam.network import EsamNetwork
from repro.data import digits
from repro.kernels.stdp import ops as stdp_ops
from repro.train import online as online_train


# ----------------------------------------------------------------------- #
# STDP rule: functional plane vs Pallas kernel vs oracle (shared uniforms)
# ----------------------------------------------------------------------- #
@pytest.mark.parametrize("n_in,n_out", [(128, 16), (256, 128), (64, 8)])
@pytest.mark.parametrize("p_pot,p_dep", [(0.0, 0.0), (1.0, 1.0), (0.3, 0.1)])
def test_stdp_three_way_equivalence(n_in, n_out, p_pot, p_dep):
    """learning rule == Pallas transposed kernel == stdp/ref, bit-exact."""
    key = jax.random.PRNGKey(n_in + n_out)
    ks = jax.random.split(key, 5)
    bits = jax.random.bernoulli(ks[0], 0.5, (n_in, n_out)).astype(jnp.int8)
    pre = jax.random.bernoulli(ks[1], 0.4, (n_in,))
    post = jax.random.bernoulli(ks[2], 0.3, (n_out,))
    u_pot = jax.random.uniform(ks[3], (n_in, n_out))
    u_dep = jax.random.uniform(ks[4], (n_in, n_out))

    functional = learning.stdp_update_from_uniforms(
        bits, pre, post, u_pot, u_dep, p_pot, p_dep)
    kernel = stdp_ops.stdp_update(
        bits.T, pre.astype(jnp.int8), post.astype(jnp.int8), u_pot.T, u_dep.T,
        p_pot=p_pot, p_dep=p_dep, interpret=True)
    oracle = stdp_ops.stdp_update_ref(
        bits.T, pre, post, u_pot.T, u_dep.T, p_pot, p_dep)
    np.testing.assert_array_equal(np.asarray(functional), np.asarray(kernel.T))
    np.testing.assert_array_equal(np.asarray(kernel), np.asarray(oracle))


def test_stdp_update_use_kernel_routes_through_pallas():
    """learning.stdp_update(use_kernel=True) == the functional path, same key."""
    key = jax.random.PRNGKey(3)
    bits = jax.random.bernoulli(key, 0.5, (128, 32)).astype(jnp.int8)
    pre = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (128,))
    post = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.4, (32,))
    a = learning.stdp_update(bits, pre, post, jax.random.fold_in(key, 3), 0.3, 0.2)
    b = learning.stdp_update(bits, pre, post, jax.random.fold_in(key, 3), 0.3, 0.2,
                             use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------- #
# Column-event kernel: blocked in-place write of one learning neuron
# ----------------------------------------------------------------------- #
@pytest.mark.parametrize("n_out,n_in", [(10, 768), (16, 256), (128, 128),
                                        (10, 384), (8, 100)])  # non-256-multiples
@pytest.mark.parametrize("p_pot,p_dep", [(1.0, 1.0), (0.25, 0.1)])
def test_column_event_kernel_matches_ref(n_out, n_in, p_pot, p_dep):
    key = jax.random.PRNGKey(n_out * n_in)
    ks = jax.random.split(key, 4)
    bits_t = jax.random.bernoulli(ks[0], 0.5, (n_out, n_in)).astype(jnp.int8)
    pre = jax.random.bernoulli(ks[1], 0.4, (n_in,))
    u_pot = jax.random.uniform(ks[2], (n_in,))
    u_dep = jax.random.uniform(ks[3], (n_in,))
    col = jnp.asarray(n_out // 2, jnp.int32)
    for apply in (True, False):
        out = stdp_ops.stdp_column_event(
            bits_t, col, jnp.asarray(apply), pre, u_pot, u_dep,
            p_pot=p_pot, p_dep=p_dep, interpret=True)
        ref = stdp_ops.stdp_column_event_ref(
            bits_t, col, jnp.asarray(apply), pre, u_pot, u_dep, p_pot, p_dep)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        # the column port touches exactly one row of the transposed layout
        others = np.delete(np.asarray(out), int(col), axis=0)
        np.testing.assert_array_equal(
            others, np.delete(np.asarray(bits_t), int(col), axis=0))
        if not apply:
            np.testing.assert_array_equal(np.asarray(out), np.asarray(bits_t))


def test_column_event_kernel_matches_full_matrix_rule():
    """A gated column event == the full-matrix rule with a one-hot post mask."""
    key = jax.random.PRNGKey(9)
    n_in, n_out = 256, 16
    bits = jax.random.bernoulli(key, 0.5, (n_in, n_out)).astype(jnp.int8)
    pre = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (n_in,))
    u_pot = jax.random.uniform(jax.random.fold_in(key, 2), (n_in,))
    u_dep = jax.random.uniform(jax.random.fold_in(key, 3), (n_in,))
    col = jnp.asarray(7, jnp.int32)
    out_t = stdp_ops.stdp_column_event(
        bits.T, col, jnp.asarray(True), pre, u_pot, u_dep,
        p_pot=0.4, p_dep=0.2, interpret=True)
    full = learning.stdp_update_from_uniforms(
        bits, pre, jax.nn.one_hot(col, n_out, dtype=bool),
        u_pot[:, None], u_dep[:, None], 0.4, 0.2)
    np.testing.assert_array_equal(np.asarray(out_t.T), np.asarray(full))


# ----------------------------------------------------------------------- #
# Fused epoch vs scan reference: bit-identity under the shared key scheme
# ----------------------------------------------------------------------- #
@given(data=st.data())
@settings(max_examples=12, deadline=None)
def test_column_event_epoch_bit_identical_to_scan(data):
    n_in = data.draw(st.sampled_from([64, 128, 256]))
    n_out = data.draw(st.sampled_from([8, 10, 16]))
    batch = data.draw(st.integers(1, 24))
    density = data.draw(st.floats(0.0, 1.0))
    seed = data.draw(st.integers(0, 2**16))
    p_pot = data.draw(st.floats(0.0, 1.0))
    p_dep = data.draw(st.floats(0.0, 1.0))
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bernoulli(key, 0.5, (n_in, n_out)).astype(jnp.int8)
    x = jax.random.bernoulli(jax.random.fold_in(key, 1), density, (batch, n_in))
    y = jax.random.randint(jax.random.fold_in(key, 2), (batch,), 0, n_out, jnp.int32)
    vth = [jnp.full((n_out,), 2**31 - 1, jnp.int32)]
    ep_key = jax.random.fold_in(key, 3)

    b_fused, n_fused = learning.online_learning_epoch(
        [bits], vth, x, y, ep_key, p_pot=p_pot, p_dep=p_dep)
    b_scan, n_scan = learning.online_learning_epoch_scan(
        [bits], vth, x, y, ep_key, p_pot=p_pot, p_dep=p_dep, rng_scheme="column")
    np.testing.assert_array_equal(np.asarray(b_fused), np.asarray(b_scan))
    assert int(n_fused) == int(n_scan)


def test_fused_epoch_matches_scan_through_hidden_tiles():
    """Packed-prefix fused epoch == functional-prefix scan, multi-tile."""
    topo = (128, 64, 10)
    key = jax.random.PRNGKey(4)
    bits = [
        jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topo[i], topo[i + 1])).astype(jnp.int8)
        for i in range(2)
    ]
    vth = [jax.random.randint(jax.random.fold_in(key, 10), (64,), -5, 5, jnp.int32),
           jnp.full((10,), 2**31 - 1, jnp.int32)]
    x = jax.random.bernoulli(jax.random.fold_in(key, 20), 0.4, (48, 128))
    y = jax.random.randint(jax.random.fold_in(key, 21), (48,), 0, 10, jnp.int32)
    b_fused, n_f = learning.online_learning_epoch(
        bits, vth, x, y, jax.random.PRNGKey(9), p_pot=0.3, p_dep=0.15)
    b_scan, n_s = learning.online_learning_epoch_scan(
        bits, vth, x, y, jax.random.PRNGKey(9), p_pot=0.3, p_dep=0.15,
        rng_scheme="column")
    np.testing.assert_array_equal(np.asarray(b_fused), np.asarray(b_scan))
    assert int(n_f) == int(n_s)


def test_multi_tile_learning_improves_accuracy_packed_prefix():
    """768:256:10 net: supervised STDP on the readout learns through the
    frozen random hidden tile, prefix on the packed plane (Sec 4.4.1's
    on-device adaptation use case at paper scale)."""
    topo = (768, 256, 10)
    key = jax.random.PRNGKey(0)
    bits = [
        jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topo[i], topo[i + 1])).astype(jnp.int8)
        for i in range(2)
    ]
    vth = [jnp.zeros((256,), jnp.int32), jnp.full((10,), 2**31 - 1, jnp.int32)]
    x, y = digits.make_spike_dataset(512, seed=3)
    x, y = jnp.asarray(x).astype(bool), jnp.asarray(y)
    pre = learning.last_hidden_spikes(bits, vth, x)

    def accuracy(b_last):
        _, vmem = tile.functional_tile(b_last, pre, vth[-1])
        return float((vmem.argmax(-1) == y).mean())

    acc0 = accuracy(bits[-1])
    b = bits[-1]
    for epoch in range(6):
        b, _ = learning.online_learning_epoch(
            [bits[0], b], vth, x, y, jax.random.PRNGKey(10 + epoch),
            p_pot=0.2, p_dep=0.1, pre_spikes=pre)
    acc1 = accuracy(b)
    assert acc0 < 0.2, acc0                 # random readout is near chance
    assert acc1 > acc0 + 0.1, (acc0, acc1)  # STDP learns through the prefix


# ----------------------------------------------------------------------- #
# train/online.py: the multi-epoch driver
# ----------------------------------------------------------------------- #
def _driver_fixture():
    topo = (768, 64, 10)
    key = jax.random.PRNGKey(1)
    bits = [
        jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topo[i], topo[i + 1])).astype(jnp.int8)
        for i in range(2)
    ]
    vth = [jnp.zeros((64,), jnp.int32), jnp.full((10,), 2**31 - 1, jnp.int32)]
    net = EsamNetwork(weight_bits=bits, vth=vth, out_offset=jnp.zeros((10,)))
    x, y = digits.make_spike_dataset(256, seed=11)
    return net, jnp.asarray(x).astype(bool), jnp.asarray(y)


def test_train_online_tracks_accuracy_and_updates():
    net, x, y = _driver_fixture()
    res = online_train.train_online(
        net, x, y, epochs=4, key=jax.random.PRNGKey(5), p_pot=0.2, p_dep=0.1)
    assert res.epochs_run == 4 and res.start_epoch == 0
    assert len(res.accuracy) == 4 and len(res.n_updates) == 4
    assert all(n > 0 for n in res.n_updates)
    # the driver's resident-layout accuracy matches the network-level readout
    logits = res.network.forward(x)
    acc = float((jnp.argmax(logits, -1) == y).mean())
    assert abs(acc - res.accuracy[-1]) < 1e-6
    # prefix tiles are untouched; the readout actually learned
    np.testing.assert_array_equal(
        np.asarray(res.network.weight_bits[0]), np.asarray(net.weight_bits[0]))
    assert res.accuracy[-1] > 0.2


def test_train_online_checkpoint_resume_bit_identical(tmp_path):
    """2 epochs + checkpoint + resume to 4 == straight 4-epoch run."""
    net, x, y = _driver_fixture()
    key = jax.random.PRNGKey(5)
    straight = online_train.train_online(
        net, x, y, epochs=4, key=key, p_pot=0.2, p_dep=0.1)

    ckpt = str(tmp_path / "online")
    first = online_train.train_online(
        net, x, y, epochs=2, key=key, p_pot=0.2, p_dep=0.1,
        checkpoint_dir=ckpt, checkpoint_every=1)
    assert first.epochs_run == 2
    resumed = online_train.train_online(
        net, x, y, epochs=4, key=key, p_pot=0.2, p_dep=0.1,
        checkpoint_dir=ckpt, resume=True)
    assert resumed.start_epoch == 2 and resumed.epochs_run == 2
    np.testing.assert_array_equal(
        np.asarray(resumed.network.weight_bits[-1]),
        np.asarray(straight.network.weight_bits[-1]))
    assert resumed.accuracy[-1] == straight.accuracy[-1]


def test_train_online_rejects_partial_eval_split():
    net, x, y = _driver_fixture()
    with pytest.raises(ValueError, match="eval_labels"):
        online_train.train_online(net, x, y, epochs=1, eval_spikes=x)
    with pytest.raises(ValueError, match="eval_labels"):
        online_train.train_online(net, x, y, epochs=1, eval_labels=y)


def test_train_online_learns_against_deployed_offset_readout():
    """With a folded out_offset, the driver's events target the offset-shifted
    argmax (the deployed winner), and its tracked accuracy still matches the
    network-level forward readout."""
    import dataclasses

    net, x, y = _driver_fixture()
    offset = jnp.linspace(-3.0, 3.0, 10)
    net = dataclasses.replace(net, out_offset=offset)
    res = online_train.train_online(
        net, x, y, epochs=3, key=jax.random.PRNGKey(6), p_pot=0.2, p_dep=0.1)
    logits = res.network.forward(x)
    acc = float((jnp.argmax(logits, -1) == y).mean())
    assert abs(acc - res.accuracy[-1]) < 1e-6
    assert res.accuracy[-1] > 0.2


def test_train_online_shuffle_is_deterministic():
    net, x, y = _driver_fixture()
    a = online_train.train_online(
        net, x, y, epochs=2, key=jax.random.PRNGKey(7), shuffle=True)
    b = online_train.train_online(
        net, x, y, epochs=2, key=jax.random.PRNGKey(7), shuffle=True)
    np.testing.assert_array_equal(
        np.asarray(a.network.weight_bits[-1]),
        np.asarray(b.network.weight_bits[-1]))
    assert a.n_updates == b.n_updates

"""The observability off-path is bit-identical to the instrumented path.

Property (hypothesis): for any request blend and engine configuration —
including the fused super-batch path and the background packer thread
(``overlap=True``) — an engine built with ``observability=None`` produces
exactly the same outputs (logits, labels, per-request telemetry) AND the
same ``stats()`` as one built with the full tracing + metrics plane on.
Spans observe, never perturb.

Wall-clock-valued stats keys (``host_pack_s_total``, ``dispatch_s_total``,
``straggler_rounds``) are excluded: they measure the host's actual timing,
which no two runs — instrumented or not — ever reproduce bit-for-bit.
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.obs import Observability
from repro.obs.metrics import Registry
from repro.serve.engine import SpikeEngine

from test_async_serve import _assert_same_results, _mixed, _net

#: stats keys that are functions of host wall time, not of the datapath
_WALL_CLOCK_KEYS = frozenset(
    {"host_pack_s_total", "dispatch_s_total", "straggler_rounds"})


def _comparable(stats: dict) -> dict:
    return {k: v for k, v in stats.items() if k not in _WALL_CLOCK_KEYS}


def _serve(reqs, *, observability, fuse, overlap, telemetry):
    eng = SpikeEngine(_net(), interpret=True, max_batch=8,
                      telemetry=telemetry, fuse_rounds=fuse, overlap=overlap,
                      observability=observability)
    eng.serve(reqs)
    st = eng.stats()
    eng.close()
    return st


@settings(max_examples=10, deadline=None)
@given(n_static=st.integers(0, 24),
       n_ev2=st.integers(0, 6),
       n_ev4=st.integers(0, 6),
       fuse=st.sampled_from([None, 2, "auto"]),
       overlap=st.booleans(),
       telemetry=st.booleans(),
       seed=st.integers(0, 3))
def test_observability_off_path_is_bit_identical(
        n_static, n_ev2, n_ev4, fuse, overlap, telemetry, seed):
    spec = [(n_ev2, 2), (n_ev4, 4)]
    base_reqs = _mixed(n_static, spec, seed=seed)
    obs_reqs = _mixed(n_static, spec, seed=seed)

    st_base = _serve(base_reqs, observability=None, fuse=fuse,
                     overlap=overlap, telemetry=telemetry)
    obs = Observability.enabled(registry=Registry())
    st_obs = _serve(obs_reqs, observability=obs, fuse=fuse,
                    overlap=overlap, telemetry=telemetry)

    _assert_same_results(obs_reqs, base_reqs)
    assert _comparable(st_obs) == _comparable(st_base)


def test_observability_off_engine_holds_no_instruments():
    eng = SpikeEngine(_net(), interpret=True, max_batch=8)
    assert eng._obs is None and eng._tracer is None and eng._m is None
    eng.serve(_mixed(4, [(2, 2)]))
    assert eng._req_spans == {}              # nothing booked on the off path


def test_tracer_only_and_metrics_only_lanes_are_also_inert():
    """Partial bundles (tracer without metrics, metrics without tracer)
    must be exactly as inert for the datapath as the full bundle."""
    from repro.obs.trace import Tracer

    want = _mixed(8, [(3, 2)], seed=9)
    _serve(want, observability=None, fuse="auto", overlap=True,
           telemetry=True)
    for bundle in (Observability(tracer=Tracer()),
                   Observability(metrics=Registry())):
        got = _mixed(8, [(3, 2)], seed=9)
        _serve(got, observability=bundle, fuse="auto", overlap=True,
               telemetry=True)
        _assert_same_results(got, want)

"""System-level reproduction checks: V1/V2/V4/V5/V6 of DESIGN.md §1."""

import numpy as np
import pytest

from repro.core.esam import cost_model as cm
from repro.core.esam import learning
from repro.core.esam.network import reference_activity, system_stats

TOPO = cm.PAPER_TOPOLOGY
ACT = reference_activity()


def test_v5_clock_periods_match_table2():
    for p in range(5):
        spec = cm.cell_spec(p)
        assert spec.clock_ns == max(cm.ARBITER_STAGE_NS[p], cm.SRAM_NEURON_STAGE_NS[p])
    # 4R system clock ~ paper's 810 MHz
    assert cm.cell_spec(4).clock_hz == pytest.approx(cm.PAPER_CLOCK_MHZ * 1e6, rel=0.01)


def test_v1_speedup_and_energy_efficiency():
    s0 = system_stats(TOPO, ACT, 0)
    s4 = system_stats(TOPO, ACT, 4)
    speedup = s4.throughput_inf_s / s0.throughput_inf_s
    eff = s0.energy_pj_per_inf / s4.energy_pj_per_inf
    assert speedup == pytest.approx(cm.PAPER_SPEEDUP_4R, rel=0.05)   # 3.1x
    assert eff == pytest.approx(cm.PAPER_ENERGY_EFF_4R, rel=0.05)    # 2.2x


def test_v2_system_operating_point():
    s4 = system_stats(TOPO, ACT, 4)
    assert s4.throughput_inf_s == pytest.approx(cm.PAPER_THROUGHPUT_INF_S, rel=0.05)
    assert s4.energy_pj_per_inf == pytest.approx(cm.PAPER_ENERGY_PJ_PER_INF, rel=0.05)
    assert s4.power_mw == pytest.approx(cm.PAPER_POWER_MW, rel=0.05)


def test_v6_area():
    s4 = system_stats(TOPO, ACT, 4)
    assert s4.area_ratio_vs_1rw == pytest.approx(2.4, rel=0.01)
    ratios = [cm.CELL_AREA_RATIO[p] for p in range(5)]
    assert ratios == [1.0, 1.5, 1.875, 2.25, 2.625]


def test_fig8_trends():
    stats = [system_stats(TOPO, ACT, p) for p in range(5)]
    power = [s.power_mw for s in stats]
    thr = [s.throughput_inf_s for s in stats]
    energy = [s.energy_pj_per_inf for s in stats]
    # "the system's power implemented with the standard 1RW cells is higher
    #  than that of the 1RW+1R and 1RW+2R cells"
    assert power[0] > power[1] and power[0] > power[2]
    # power otherwise increases with ports
    assert power[1] < power[2] < power[3] < power[4]
    # "throughput decreases slightly" 1RW -> +1R, then recovers at 2+ ports
    assert thr[1] < thr[0] < thr[2] < thr[3] < thr[4]
    # "with every added port, the overall energy/Inference decreases"
    assert energy[0] > energy[1] > energy[2] > energy[3] > energy[4]


def test_v4_online_learning_column_access():
    base = learning.column_update_cost(0)
    # paper: 157 pJ for the 1RW full-column RMW; time baselines per the
    # cost_model decode of the published 26.0x/19.5x ratios
    assert base.read_ns == pytest.approx(cm.T1RW_COL_READ_NS, rel=0.01)
    assert base.write_ns == pytest.approx(cm.T1RW_COL_WRITE_NS, rel=0.01)
    assert base.energy_pj == pytest.approx(cm.T1RW_ARRAY_RW_PJ, rel=0.01)
    c4 = learning.column_update_cost(4)
    assert c4.read_ns == pytest.approx(cm.T4R_COL_READ_NS)
    assert c4.write_ns == pytest.approx(cm.T4R_COL_WRITE_NS)
    assert c4.speedup_read_vs_1rw == pytest.approx(26.0, rel=0.02)   # 26.0x
    assert c4.speedup_write_vs_1rw == pytest.approx(19.5, rel=0.03)  # 19.5x


def test_array_size_limit_rule():
    assert cm.MAX_ARRAY_ROWS == 128 and cm.MAX_ARRAY_COLS == 128
    for t in range(len(TOPO) - 1):
        # every tile decomposes into <=128x128 arrays
        assert TOPO[t] % 128 == 0 or TOPO[t] <= 128


def test_neuron_synapse_counts_match_table3():
    neurons = sum(TOPO[1:])
    synapses = sum(TOPO[i] * TOPO[i + 1] for i in range(len(TOPO) - 1))
    assert neurons == cm.PAPER_NEURONS  # 778
    assert synapses == pytest.approx(cm.PAPER_SYNAPSES, rel=0.01)  # ~330K

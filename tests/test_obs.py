"""Observability plane: tracer/metrics/http/profile units, engine + router
integration (spans close, counters reconcile with stats()), and the
versioned stats-schema regression gate."""

import json
import threading
import urllib.request

import numpy as np
import pytest

from repro.obs import Observability
from repro.obs.http import PROMETHEUS_CONTENT_TYPE, MetricsServer
from repro.obs.metrics import DEFAULT_BOUNDS, Registry
from repro.obs.profile import DeviceProfiler, kernel_timer, record_warmup_times
from repro.obs.trace import REQUEST_PHASES, Tracer, validate_trace
from repro.serve.engine import (STATS_SCHEMA_VERSION, FaultAwareRouter,
                                SpikeEngine, stats_schema)
from repro.train import fault_tolerance as ft

from test_async_serve import _mixed, _net, _spike_reqs


class FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ----------------------------------------------------------------------- #
# tracer
# ----------------------------------------------------------------------- #
def test_tracer_complete_and_instant_deterministic_timestamps():
    clk = FakeClock()
    tr = Tracer(clock=clk, pid=7)
    clk.advance(0.001)                       # +1000us
    t0 = tr.now_us()
    assert t0 == pytest.approx(1000.0)
    clk.advance(0.0005)
    tr.complete("pack", t0, tr.now_us() - t0, cat="round", bucket=8)
    tr.instant("shed", deadline_s=1.0)
    ev = tr.events()
    assert [e["ph"] for e in ev] == ["X", "i"]
    assert ev[0]["ts"] == pytest.approx(1000.0)
    assert ev[0]["dur"] == pytest.approx(500.0)
    assert ev[0]["args"] == {"bucket": 8}
    assert ev[0]["pid"] == 7
    assert ev[1]["s"] == "t"


def test_tracer_async_pair_and_span_context():
    tr = Tracer(clock=FakeClock())
    rid = tr.next_id()
    tr.begin_async("request", rid, kind="static")
    with tr.span("drain", cat="engine", round=3):
        pass
    tr.end_async("request", rid, status="done")
    ev = tr.events()
    assert [e["ph"] for e in ev] == ["b", "X", "e"]
    assert ev[0]["id"] == ev[2]["id"] == rid
    assert ev[1]["args"] == {"round": 3}


def test_tracer_ring_buffer_bounds_memory_and_counts_drops():
    tr = Tracer(clock=FakeClock(), capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr) == 4
    assert tr.dropped == 6
    assert [e["name"] for e in tr.events()] == ["e6", "e7", "e8", "e9"]
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_thread_safety_under_concurrent_emission():
    tr = Tracer(clock=FakeClock(), capacity=1 << 14)

    def emit():
        for _ in range(500):
            tr.instant("tick")

    threads = [threading.Thread(target=emit) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr) == 2000


def test_tracer_export_is_valid_trace_event_json(tmp_path):
    tr = Tracer(clock=FakeClock())
    rid = tr.next_id()
    tr.begin_async("request", rid)
    tr.complete("dispatch", 0.0, 10.0)
    tr.end_async("request", rid)
    path = str(tmp_path / "trace.json")
    doc = tr.export(path)
    on_disk = json.load(open(path))
    assert on_disk == json.loads(json.dumps(doc))
    summary = validate_trace(on_disk)
    assert summary["request_begun"] == summary["request_closed"] == 1
    assert summary["request_close_fraction"] == 1.0
    # the metadata record names the process for the Perfetto UI
    assert on_disk["traceEvents"][0]["ph"] == "M"


def test_validate_trace_rejects_malformed_events():
    with pytest.raises(ValueError):
        validate_trace({"nope": []})
    bad_x = {"traceEvents": [{"name": "a", "ph": "X", "ts": 0.0,
                              "pid": 1, "tid": 1}]}       # missing dur
    with pytest.raises(ValueError):
        validate_trace(bad_x)
    bad_async = {"traceEvents": [{"name": "a", "ph": "b", "ts": 0.0,
                                  "pid": 1, "tid": 1}]}   # missing id
    with pytest.raises(ValueError):
        validate_trace(bad_async)
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"name": "a", "ph": "??", "ts": 0.0,
                                         "pid": 1, "tid": 1}]})


def test_unclosed_request_span_lowers_close_fraction():
    tr = Tracer(clock=FakeClock())
    tr.begin_async("request", tr.next_id())
    tr.begin_async("request", tr.next_id())
    tr.end_async("request", 1)
    s = validate_trace(tr.export())
    assert s["request_begun"] == 2 and s["request_closed"] == 1
    assert s["request_close_fraction"] == 0.5


# ----------------------------------------------------------------------- #
# metrics registry
# ----------------------------------------------------------------------- #
def test_counter_gauge_basics_and_idempotent_getters():
    reg = Registry()
    c = reg.counter("esam_test_total", "a counter")
    c.inc()
    c.inc(2.5)
    assert reg.counter("esam_test_total").value == 3.5   # same instrument
    with pytest.raises(AssertionError):
        c.inc(-1)
    g = reg.gauge("esam_depth")
    g.set(7)
    g.inc(-2)
    assert g.value == 5.0
    with pytest.raises(ValueError):
        reg.gauge("esam_test_total")                     # kind mismatch


def test_labeled_series_are_independent():
    reg = Registry()
    reg.counter("esam_served_total", kind="static").inc(3)
    reg.counter("esam_served_total", kind="event").inc(4)
    assert reg.counter("esam_served_total", kind="static").value == 3
    assert reg.counter("esam_served_total", kind="event").value == 4
    snap = reg.snapshot()
    assert snap['esam_served_total{kind="event"}']["value"] == 4


def test_histogram_quantiles_without_storing_samples():
    reg = Registry()
    h = reg.histogram("esam_lat_seconds")
    rng = np.random.default_rng(0)
    samples = rng.uniform(1e-4, 1e-1, size=2000)
    for s in samples:
        h.observe(float(s))
    assert h.count == 2000
    assert h.sum == pytest.approx(samples.sum(), rel=1e-9)
    # log-bucketed (factor-2 bounds): estimates land within 2x of truth
    for q in (0.5, 0.95, 0.99):
        true = np.quantile(samples, q)
        est = h.quantile(q)
        assert true / 2 <= est <= true * 2, (q, true, est)


def test_histogram_cumulative_buckets_are_monotone_with_inf_tail():
    reg = Registry()
    h = reg.histogram("esam_h", bounds=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    cum = h.cumulative_buckets()
    assert [c for _, c in cum] == [1, 2, 3, 4]
    assert np.isinf(cum[-1][0])


def test_prometheus_text_exposition_format():
    reg = Registry()
    reg.counter("esam_req_total", "requests").inc(5)
    reg.gauge("esam_depth", "queue depth").set(2)
    h = reg.histogram("esam_lat", "latency", bounds=(1.0, 2.0))
    h.observe(1.5)
    text = reg.prometheus_text()
    assert "# HELP esam_req_total requests" in text
    assert "# TYPE esam_req_total counter" in text
    assert "esam_req_total 5.0" in text
    assert "# TYPE esam_lat histogram" in text
    assert 'esam_lat_bucket{le="1.0"} 0' in text
    assert 'esam_lat_bucket{le="2.0"} 1' in text
    assert 'esam_lat_bucket{le="+Inf"} 1' in text
    assert "esam_lat_sum 1.5" in text
    assert "esam_lat_count 1" in text
    assert text.endswith("\n")


def test_default_bounds_cover_microseconds_to_minutes():
    assert DEFAULT_BOUNDS[0] == pytest.approx(1e-6)
    assert DEFAULT_BOUNDS[-1] > 60.0
    assert all(b2 / b1 == pytest.approx(2.0)
               for b1, b2 in zip(DEFAULT_BOUNDS, DEFAULT_BOUNDS[1:]))


# ----------------------------------------------------------------------- #
# http scrape endpoint
# ----------------------------------------------------------------------- #
def _get(port, path):
    with urllib.request.urlopen(f"http://127.0.0.1:{port}{path}") as r:
        return r.status, r.headers.get("Content-Type"), r.read()


def test_metrics_server_serves_prometheus_json_trace_and_health():
    reg = Registry()
    reg.counter("esam_req_total").inc(3)
    tr = Tracer(clock=FakeClock())
    tr.instant("tick")
    with MetricsServer(reg, port=0, tracer=tr) as srv:
        port = srv.port
        status, ctype, body = _get(port, "/metrics")
        assert status == 200 and ctype == PROMETHEUS_CONTENT_TYPE
        assert b"esam_req_total 3.0" in body
        status, ctype, body = _get(port, "/metrics.json")
        assert status == 200 and ctype == "application/json"
        assert json.loads(body)["esam_req_total"]["value"] == 3.0
        status, _, body = _get(port, "/trace.json")
        assert status == 200
        validate_trace(json.loads(body))
        status, _, body = _get(port, "/healthz")
        assert status == 200 and body == b"ok\n"
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(port, "/nope")
        assert ei.value.code == 404
    assert srv.port is None                  # stopped


def test_metrics_server_scrape_while_writing():
    reg = Registry()
    c = reg.counter("esam_live_total")
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            c.inc()

    t = threading.Thread(target=writer)
    t.start()
    try:
        with MetricsServer(reg, port=0) as srv:
            for _ in range(5):
                status, _, body = _get(srv.port, "/metrics")
                assert status == 200 and b"esam_live_total" in body
    finally:
        stop.set()
        t.join()


# ----------------------------------------------------------------------- #
# device profiling hooks
# ----------------------------------------------------------------------- #
class FakeJaxProfiler:
    def __init__(self, fail=False):
        self.fail = fail
        self.started = []
        self.stopped = 0

    def start_trace(self, logdir):
        if self.fail:
            raise RuntimeError("no backend")
        self.started.append(logdir)

    def stop_trace(self):
        self.stopped += 1


def test_device_profiler_captures_exact_round_window():
    reg = Registry()
    fake = FakeJaxProfiler()
    prof = DeviceProfiler("/tmp/x", skip_rounds=2, n_rounds=3,
                          registry=reg, profiler=fake)
    for i in range(10):
        prof.on_round_start(i)
        prof.on_round_end(i)
    assert fake.started == ["/tmp/x"]
    assert fake.stopped == 1
    assert prof.captured == 3 and prof.done and not prof.active
    assert reg.get("esam_profile_rounds_captured").value == 3
    prof.stop()                              # idempotent
    assert fake.stopped == 1


def test_device_profiler_failure_never_raises_into_the_drain():
    prof = DeviceProfiler("/tmp/x", profiler=FakeJaxProfiler(fail=True))
    prof.on_round_start(0)                   # must not raise
    assert prof.done and prof.error is not None
    prof.on_round_end(0)
    assert prof.captured == 0


def test_record_warmup_times_flattens_nested_engine_shapes():
    reg = Registry()
    record_warmup_times(reg, {"static": {8: 0.5, 16: 0.25},
                              "event_t4": {8: 0.125},
                              "telemetry_s": 0.0625, "total_s": 1.0})
    assert reg.get("esam_warmup_compile_seconds",
                   shape="static_b8").value == 0.5
    assert reg.get("esam_warmup_compile_seconds",
                   shape="event_t4_b8").value == 0.125
    assert reg.get("esam_warmup_compile_seconds",
                   shape="total_s").value == 1.0


def test_kernel_timer_books_labeled_histogram():
    reg = Registry()
    clk = FakeClock()
    with kernel_timer(reg, "mega_cascade", lane="interpret", clock=clk):
        clk.advance(0.25)
    h = reg.get("esam_kernel_seconds", kernel="mega_cascade",
                lane="interpret")
    assert h.count == 1
    assert h.sum == pytest.approx(0.25)


# ----------------------------------------------------------------------- #
# engine integration: spans close + counters reconcile with stats()
# ----------------------------------------------------------------------- #
def _obs():
    return Observability.enabled(registry=Registry())


def test_engine_trace_covers_lifecycle_and_closes_every_request():
    obs = _obs()
    eng = SpikeEngine(_net(), interpret=True, max_batch=8, telemetry=True,
                      observability=obs)
    eng.serve(_mixed(10, [(3, 2)]))
    summary = validate_trace(obs.tracer.export())
    assert summary["request_begun"] == 13
    assert summary["request_close_fraction"] == 1.0
    for phase in ("queue", "pack", "dispatch", "device_drain",
                  "telemetry_flush"):
        assert phase in REQUEST_PHASES or True
        assert summary["phases"].get(phase, 0) > 0, (phase, summary["phases"])
    assert summary["phases"]["round"] == eng.stats()["dispatch_rounds"]


def test_engine_metrics_reconcile_with_stats():
    obs = _obs()
    eng = SpikeEngine(_net(), interpret=True, max_batch=8, telemetry=True,
                      observability=obs)
    eng.serve(_mixed(12, [(4, 2), (2, 4)]))
    st = eng.stats()
    snap = obs.metrics.snapshot()

    def v(name):
        return snap[name]["value"]

    assert v("esam_requests_submitted_total") == 18
    assert v('esam_requests_served_total{kind="static"}') == st["n_requests"]
    assert (v('esam_requests_served_total{kind="event"}')
            == st["n_event_requests"])
    assert v("esam_timesteps_served_total") == st["timesteps_total"]
    assert v("esam_dispatch_rounds_total") == st["dispatch_rounds"]
    assert v("esam_rows_real_total") == st["rows_real_total"]
    assert v("esam_rows_padded_total") == st["rows_padded_total"]
    assert v("esam_fused_rounds_total") == st["fused_rounds"]
    assert v("esam_rounds_saved_total") == st["rounds_saved"]
    # energy/cycles counters inc with exactly the float64 sums stats() folds
    total_energy = (st["energy_pj_per_inf"] * st["n_requests"]
                    + st["event_energy_pj_mean"] * st["n_event_requests"])
    assert v("esam_energy_pj_total") == pytest.approx(total_energy)
    assert snap["esam_request_latency_seconds"]["count"] == 18
    assert v("esam_queue_depth") == 0


def test_engine_rejection_and_shed_paths_are_counted_and_closed():
    obs = _obs()
    eng = SpikeEngine(_net(), interpret=True, max_batch=4, telemetry=False,
                      queue_limit=4, observability=obs)
    reqs = _spike_reqs(8)
    eng.submit(reqs)                         # queue of 4: half rejected
    st_depth = eng.queue_depth()
    assert st_depth == 4
    for r in reqs[:4]:
        r.deadline_s = -1.0                  # already expired => shed
    eng.serve()
    snap = obs.metrics.snapshot()
    assert snap["esam_requests_rejected_total"]["value"] == 4
    assert snap["esam_requests_shed_total"]["value"] == 4
    summary = validate_trace(obs.tracer.export())
    # every admitted request closed (shed is a terminal transition)
    assert summary["request_close_fraction"] == 1.0
    names = {e["name"] for e in obs.tracer.events()}
    assert "rejected" in names and "shed" in names


def test_engine_ladder_transitions_traced_and_counted():
    from repro.serve.overload import DegradationLadder
    obs = _obs()
    eng = SpikeEngine(_net(), interpret=True, max_batch=4, telemetry=True,
                      observability=obs,
                      ladder=DegradationLadder.default(4))
    eng.submit(_spike_reqs(40))              # depth 40 >> 2*max_batch
    eng.serve()
    st = eng.stats()
    if st["ladder_transitions"]:             # depends on drain pacing
        snap = obs.metrics.snapshot()
        assert (snap["esam_ladder_transitions_total"]["value"]
                == st["ladder_transitions"])
        names = {e["name"] for e in obs.tracer.events()}
        assert "ladder_transition" in names


def test_engine_profiler_hooks_called_per_round():
    fake = FakeJaxProfiler()
    reg = Registry()
    obs = Observability(
        tracer=None, metrics=reg,
        profile=DeviceProfiler("/tmp/p", skip_rounds=0, n_rounds=2,
                               registry=reg, profiler=fake))
    eng = SpikeEngine(_net(), interpret=True, max_batch=4, telemetry=False,
                      observability=obs)
    eng.serve(_spike_reqs(12))
    assert obs.profile.captured == 2 and obs.profile.done
    assert fake.stopped == 1


def test_engine_warmup_books_compile_time_gauges():
    obs = _obs()
    eng = SpikeEngine(_net(), interpret=True, max_batch=8, telemetry=True,
                      observability=obs)
    eng.warmup(event_ts=(2,))
    total = obs.metrics.get("esam_warmup_compile_seconds", shape="total_s")
    assert total is not None and total.value > 0
    names = {e["name"] for e in obs.tracer.events()}
    assert "warmup_done" in names


# ----------------------------------------------------------------------- #
# router integration
# ----------------------------------------------------------------------- #
def test_router_counters_mirrored_into_registry_on_crash():
    obs = _obs()
    engines = [SpikeEngine(_net(), interpret=True, max_batch=8,
                           telemetry=True, observability=obs)
               for _ in range(2)]
    crashed = []

    def hook(round_idx):
        if not crashed:
            crashed.append(round_idx)
            raise RuntimeError("chaos")

    engines[0].round_hook = hook
    router = FaultAwareRouter(
        engines, health_threshold=0.0, observability=obs,
        retry=ft.RetryPolicy(base_backoff_s=1e-4), sleep=lambda s: None)
    reqs = _spike_reqs(6)
    router.serve(reqs)
    st = router.stats()
    assert st["crashes"] == 1 and st["retries"] > 0
    snap = obs.metrics.snapshot()
    assert snap["esam_router_crashes_total"]["value"] == st["crashes"]
    assert snap["esam_router_retries_total"]["value"] == st["retries"]
    assert snap["esam_router_replicas_down"]["value"] == 1
    names = {e["name"] for e in obs.tracer.events()}
    assert {"replica_crash", "reroute", "replica_drain"} <= names
    assert all(r.status == "done" for r in reqs)


# ----------------------------------------------------------------------- #
# versioned stats schema (satellite a)
# ----------------------------------------------------------------------- #
def test_stats_schema_matches_stats_keys_exactly():
    schema = stats_schema()
    documented = {k for section in schema.values() for k in section}
    eng = SpikeEngine(_net(), interpret=True, max_batch=8, telemetry=True)
    eng.serve(_mixed(6, [(2, 2)]))
    st = eng.stats()
    assert set(st) == documented, (
        f"stats() and stats_schema() diverged; bump STATS_SCHEMA_VERSION "
        f"and update the schema. only_in_stats={set(st) - documented} "
        f"only_in_schema={documented - set(st)}")
    assert st["stats_schema_version"] == STATS_SCHEMA_VERSION


def test_stats_schema_ci_grepped_keys_stay_stable():
    """The keys CI scripts and the launcher grep today, frozen at v1 —
    removing or renaming any is a breaking change that must bump
    STATS_SCHEMA_VERSION."""
    frozen_v1 = {
        "n_requests", "data_parallel", "cell", "fuse_rounds", "overlap",
        "rounds_saved", "fused_rounds", "rounds_static",
        "throughput_pipelined_inf_s", "energy_pj_per_inf",
        "latency_ns_mean", "cycles_mean", "n_event_requests",
        "timesteps_total", "energy_pj_per_timestep", "event_energy_pj_mean",
        "event_latency_ns_mean", "event_cycles_mean", "health",
        "tile_health", "degraded", "dispatch_rounds", "straggler_rounds",
        "queue_depth", "shed_deadline", "rejected_full",
        "backpressure_events", "ladder_transitions",
        "ladder_transition_log", "degradation_level", "pad_fraction",
    }
    documented = {k for section in stats_schema().values() for k in section}
    missing = frozen_v1 - documented
    assert not missing, f"v1 stats keys went missing: {missing}"
    assert STATS_SCHEMA_VERSION == 1


def test_stats_schema_returns_fresh_copy():
    a = stats_schema()
    a["identity"]["n_requests"] = "mutated"
    assert stats_schema()["identity"]["n_requests"] != "mutated"

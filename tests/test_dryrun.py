"""Dry-run machinery: production-mesh compile in a subprocess (the 512-device
XLA flag must not leak into this test process) + input-spec construction."""

import json
import os
import subprocess
import sys

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_local_process_has_one_device():
    # the dry-run flag must never be set globally
    assert len(jax.devices()) >= 1
    assert "xla_force_host_platform_device_count=512" not in os.environ.get("XLA_FLAGS", "")


@pytest.mark.parametrize("arch,shape", [("xlstm_125m", "decode_32k")])
def test_dryrun_cell_compiles_in_subprocess(tmp_path, arch, shape):
    """End-to-end: one real cell through the production 16x16 mesh."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=420,
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    files = list(tmp_path.glob("*.json"))
    assert len(files) == 1
    cell = json.loads(files[0].read_text())
    assert cell["chips"] == 256
    assert cell["flops"] > 0
    assert cell["bottleneck"] in ("compute_s", "memory_s", "collective_s")
    assert set(cell["roofline"]) == {"compute_s", "memory_s", "collective_s"}


def test_input_specs_cover_all_cells():
    """input_specs/cache_specs build for every (arch x applicable shape)
    without touching devices (pure ShapeDtypeStruct plumbing)."""
    from repro.configs import base as cb
    from repro.distributed import sharding as shd
    from repro.launch import dryrun as dr

    from repro import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    for arch in cb.ARCH_IDS:
        cfg = cb.get(arch)
        for shape_name in cb.applicable_shapes(cfg):
            shape = cb.SHAPES[shape_name]
            rules = dr.make_rules_for(cfg, mesh, shape)
            specs = dr.input_specs(cfg, shape, rules)
            assert "tokens" in specs
            if shape.kind == "decode":
                caches = dr.cache_specs(cfg, shape, rules)
                assert jax.tree.leaves(caches)


def test_model_flops_moe_discount():
    from repro.configs import base as cb
    from repro.launch import dryrun as dr

    dense = dr.model_flops(cb.get("llama3p2_1b"), cb.SHAPES["train_4k"])
    assert dense > 0
    kimi = cb.get("kimi_k2_1t_a32b")
    moe = dr.model_flops(kimi, cb.SHAPES["train_4k"])
    # active params far below total: 6*N_active*D << 6*N_total*D
    from repro.models import lm, params as pm
    total = 6 * pm.param_count(lm.model_specs(kimi)) * 4096 * 256
    assert moe < 0.1 * total

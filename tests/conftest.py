"""Test-suite compatibility shims.

Several modules property-test with ``hypothesis``; bare environments may not
have it installed (the CI lane installs it, so the shim is the bare-machine
fallback — tests/test_conftest_shim.py exercises it directly either way).
When the real package is absent we install a minimal deterministic stand-in
into ``sys.modules`` *before* test collection imports the modules.  The stand-in covers exactly the API surface
this suite uses — ``given``/``settings`` and the ``integers``, ``floats``,
``booleans``, ``lists``, ``sampled_from``, ``data`` strategies — and replays
each property over ``max_examples`` seeded-random draws, so the property tests
still sweep their input space (deterministically) instead of being skipped.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types


def _install_hypothesis_fallback() -> None:
    class _Strategy:
        def __init__(self, draw):
            self.draw_from = draw

    class _DataObject:
        """Stand-in for hypothesis's interactive ``data()`` draw handle."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.draw_from(self._rng)

    def integers(min_value=0, max_value=2**31 - 1):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    def lists(elements, min_size=0, max_size=None):
        hi = min_size + 16 if max_size is None else max_size
        return _Strategy(
            lambda r: [elements.draw_from(r) for _ in range(r.randint(min_size, hi))]
        )

    def sampled_from(seq):
        choices = list(seq)
        return _Strategy(lambda r: r.choice(choices))

    def data():
        return _Strategy(lambda r: _DataObject(r))

    def given(**strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", None) or getattr(
                    fn, "_max_examples", None
                ) or 20
                for example in range(n):
                    rng = random.Random((example + 1) * 7919)
                    drawn = {k: s.draw_from(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # hide the drawn parameters from pytest's fixture resolution
            sig = inspect.signature(fn)
            params = [p for p in sig.parameters.values() if p.name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            return wrapper

        return decorate

    def settings(max_examples=20, **_):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn

        return decorate

    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "lists", "sampled_from", "data"):
        setattr(st_mod, name, locals()[name])

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = st_mod
    mod.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:  # pragma: no cover - exercised implicitly by collection
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_fallback()

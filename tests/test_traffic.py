"""Open-loop traffic generator + chaos harness: seeded determinism, the
acceptance-criteria chaos drill (crash mid-drain + 10x slowdown behind the
retrying router, every non-shed request completes exactly once), storms
against bounded queues, and report integrity."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.esam.network import EsamNetwork
from repro.serve.engine import (EventRequest, FaultAwareRouter, SpikeEngine,
                                SpikeRequest)
from repro.serve.traffic import (ChaosConfig, ReplicaCrashError,
                                 TrafficConfig, arrival_times, build_requests,
                                 install_chaos, run_open_loop)
from repro.train.fault_tolerance import RetryPolicy

N_IN = 128


def _net(seed=0, topo=(N_IN, 128, 10)):
    key = jax.random.PRNGKey(seed)
    bits = [
        jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topo[i], topo[i + 1])).astype(jnp.int8)
        for i in range(len(topo) - 1)
    ]
    vth = [jnp.zeros((n,), jnp.int32) for n in topo[1:]]
    return EsamNetwork(weight_bits=bits, vth=vth,
                       out_offset=jnp.zeros((topo[-1],), jnp.float32))


def _engine(net=None, **kw):
    kw.setdefault("interpret", True)
    kw.setdefault("max_batch", 8)
    return SpikeEngine(net if net is not None else _net(), **kw)


# ----------------------------------------------------------------------- #
# generator determinism
# ----------------------------------------------------------------------- #
def test_arrivals_are_seeded_poisson():
    cfg = TrafficConfig(rate_hz=100.0, n_requests=500, seed=3, n_in=N_IN)
    a1, a2 = arrival_times(cfg), arrival_times(cfg)
    np.testing.assert_array_equal(a1, a2)
    assert (np.diff(a1) >= 0).all() and a1[0] > 0
    # mean gap ~ 1/rate (500 samples: within 20%)
    assert np.diff(a1, prepend=0.0).mean() == pytest.approx(0.01, rel=0.2)
    # a different seed is a different schedule
    assert not np.array_equal(
        a1, arrival_times(TrafficConfig(rate_hz=100.0, n_requests=500,
                                        seed=4, n_in=N_IN)))


def test_build_requests_blend_and_replay():
    cfg = TrafficConfig(rate_hz=50.0, n_requests=200, seed=9, p_event=0.4,
                        event_t_choices=(2, 4), n_in=N_IN)
    reqs1, arr1 = build_requests(cfg)
    reqs2, arr2 = build_requests(cfg)
    np.testing.assert_array_equal(arr1, arr2)
    assert len(reqs1) == 200
    n_event = sum(isinstance(r, EventRequest) for r in reqs1)
    assert 0 < n_event < 200                       # mixed blend
    assert {r.n_steps for r in reqs1
            if isinstance(r, EventRequest)} <= {2, 4}
    # replay is bit-identical, request by request
    for r1, r2 in zip(reqs1, reqs2):
        assert type(r1) is type(r2)
        payload = "events" if isinstance(r1, EventRequest) else "spikes"
        np.testing.assert_array_equal(getattr(r1, payload),
                                      getattr(r2, payload))


def test_storm_splices_extra_arrivals_sorted():
    cfg = TrafficConfig(rate_hz=10.0, n_requests=20, seed=1, n_in=N_IN)
    chaos = ChaosConfig(storm_at_s=0.05, storm_size=15)
    reqs, arr = build_requests(cfg, chaos=chaos)
    assert len(reqs) == 35 and len(arr) == 35
    assert (np.diff(arr) >= 0).all()
    assert (arr == 0.05).sum() >= 15               # the burst lands at once


# ----------------------------------------------------------------------- #
# chaos harness wiring
# ----------------------------------------------------------------------- #
def test_install_chaos_crash_hook_raises_after_n_rounds():
    eng = _engine()
    install_chaos([eng], ChaosConfig(crash_replica=0, crash_after_rounds=2))
    reqs = [SpikeRequest(spikes=np.zeros(N_IN, np.uint8)) for _ in range(20)]
    with pytest.raises(ReplicaCrashError):
        eng.serve(reqs)
    # two rounds ran before the crash round aborted
    assert eng.stats()["dispatch_rounds"] == 2


def test_install_chaos_slowdown_feeds_watchdog():
    slept = []
    eng = _engine()
    install_chaos([eng], ChaosConfig(slowdown=((0, 0.25),)),
                  sleep=slept.append)
    eng.serve([SpikeRequest(spikes=np.zeros(N_IN, np.uint8))
               for _ in range(20)])
    assert slept == [0.25, 0.25, 0.25]             # one stall per round


# ----------------------------------------------------------------------- #
# open-loop driver
# ----------------------------------------------------------------------- #
def test_open_loop_completes_everything_below_saturation():
    eng = _engine()
    cfg = TrafficConfig(rate_hz=2000.0, n_requests=24, seed=11, n_in=N_IN,
                        p_event=0.25)
    rep = run_open_loop(eng, cfg, max_wall_s=60.0)
    assert rep.n_offered == 24 and rep.n_completed == 24
    assert rep.n_shed == rep.n_rejected == rep.n_failed == 0
    assert 0.0 < rep.p50_ms <= rep.p99_ms <= rep.p999_ms
    assert rep.goodput_slo == 1.0                  # no SLO -> completion rate
    assert rep.duration_s < 60.0
    d = rep.to_dict()
    assert d["n_completed"] == 24 and "p999_ms" in d


def test_open_loop_storm_against_bounded_queue_sheds():
    eng = _engine(queue_limit=8)
    cfg = TrafficConfig(rate_hz=500.0, n_requests=8, seed=13, n_in=N_IN,
                        deadline_s=5.0)
    chaos = ChaosConfig(storm_at_s=0.0, storm_size=64)
    rep = run_open_loop(eng, cfg, slo_s=5.0, chaos=chaos, max_wall_s=60.0)
    assert rep.n_offered == 72
    # a 64-request burst against an 8-deep queue must reject
    assert rep.n_rejected > 0
    assert rep.n_completed + rep.n_shed + rep.n_rejected == 72
    assert rep.backpressure_events > 0
    assert 0.0 <= rep.goodput_slo < 1.0


def test_open_loop_deadline_sheds_are_counted():
    # an engine stalled 50ms per round vs 1ms deadlines: later arrivals
    # expire while queued
    eng = _engine()
    install_chaos([eng], ChaosConfig(slowdown=((0, 0.05),)))
    cfg = TrafficConfig(rate_hz=400.0, n_requests=40, seed=17, n_in=N_IN,
                        deadline_s=0.001)
    rep = run_open_loop(eng, cfg, max_wall_s=60.0)
    assert rep.n_shed > 0
    assert rep.n_completed + rep.n_shed == 40
    # every completion that beat its deadline counts toward goodput; the
    # sheds never do
    assert rep.goodput_slo <= rep.n_completed / 40


# ----------------------------------------------------------------------- #
# the acceptance-criteria chaos drill
# ----------------------------------------------------------------------- #
def test_chaos_crash_plus_slowdown_exactly_once():
    """One of two replicas crashes mid-drain and the survivor runs with a
    10x stall; every non-shed request still completes exactly once, with
    retries and the crash visible in the router's counters."""
    net = _net()
    engines = [_engine(net), _engine(net)]
    router = FaultAwareRouter(
        engines,
        retry=RetryPolicy(max_attempts=4, base_backoff_s=1e-4, seed=7),
    )
    # replica 0 crashes on its second round; replica 1 stalls 10x a typical
    # ~1ms interpret round
    chaos = ChaosConfig(slowdown=((1, 0.01),), crash_replica=0,
                        crash_after_rounds=1)
    cfg = TrafficConfig(rate_hz=5000.0, n_requests=32, seed=23, n_in=N_IN)
    rep = run_open_loop(router, cfg, chaos=chaos, max_wall_s=60.0)

    assert rep.n_offered == 32
    # exactly-once: every request reached exactly one terminal state and
    # every completed request carries exactly one result
    assert (rep.n_completed + rep.n_shed + rep.n_rejected
            + rep.n_failed) == 32
    assert rep.n_completed == 32                   # nothing shed or lost
    assert rep.crashes == 1
    assert rep.retries > 0                         # victims were re-routed
    st = router.stats()
    assert st["down"] == [0]
    assert st["backlog"] == 0
    # the crashed replica's queues were emptied — a later direct drain
    # cannot double-serve anything
    assert engines[0].queue_depth() == 0
    # per-engine dispatch counts add up to >= offered: the crashed replica
    # still counted the round whose results it discarded, and those requests
    # were served again on the survivor — but each request object carries
    # exactly one result (rep.n_completed above), never two
    served = sum(e.stats()["n_requests"] for e in engines)
    assert served >= 32


def test_chaos_results_match_clean_replay():
    """Chaos must not corrupt results: the same seeded traffic served
    cleanly on a fresh engine yields bit-identical logits, request by
    request, even for the re-routed crash victims."""
    net = _net()
    # 32 requests round-robin to 16 per replica = two rounds each, so the
    # crash (second round) fires with one round's results already in flight
    cfg = TrafficConfig(rate_hz=5000.0, n_requests=32, seed=29, n_in=N_IN)
    reqs, _ = build_requests(cfg)
    engines = [_engine(net), _engine(net)]
    router = FaultAwareRouter(
        engines, retry=RetryPolicy(max_attempts=4, base_backoff_s=1e-5))
    # replica 0 crashes on its second round: its first round's results are
    # discarded pre-flush and the victims re-route to replica 1
    install_chaos(engines, ChaosConfig(crash_replica=0,
                                       crash_after_rounds=1))
    router.serve(reqs)
    assert all(r.status == "done" for r in reqs)
    assert router.stats()["crashes"] == 1

    clean, _ = build_requests(cfg)                 # bit-identical replay
    _engine(net).serve(clean)
    for a, b in zip(reqs, clean):
        np.testing.assert_array_equal(a.logits, b.logits)
        assert a.label == b.label


def test_all_replicas_down_fails_remaining_requests():
    net = _net()
    engines = [_engine(net), _engine(net)]
    router = FaultAwareRouter(
        engines, retry=RetryPolicy(max_attempts=5, base_backoff_s=1e-5))
    install_chaos(engines, ChaosConfig(crash_replica=0,
                                       crash_after_rounds=0))
    install_chaos([engines[1]], ChaosConfig(crash_replica=0,
                                            crash_after_rounds=0))
    reqs = [SpikeRequest(spikes=np.zeros(N_IN, np.uint8)) for _ in range(4)]
    router.serve(reqs)
    st = router.stats()
    assert st["crashes"] == 2 and sorted(st["down"]) == [0, 1]
    assert all(r.status == "failed" for r in reqs)
    assert st["failed"] == 4
    with pytest.raises(Exception):
        router.route(SpikeRequest(spikes=np.zeros(N_IN, np.uint8)))


def test_retry_budget_exhaustion_marks_failed_not_lost():
    net = _net()
    engines = [_engine(net), _engine(net)]
    router = FaultAwareRouter(
        engines, retry=RetryPolicy(max_attempts=1, base_backoff_s=1e-5))
    install_chaos(engines, ChaosConfig(crash_replica=0,
                                       crash_after_rounds=0))
    reqs = [SpikeRequest(spikes=np.zeros(N_IN, np.uint8)) for _ in range(6)]
    for r in reqs:
        router.route(r)
    router.serve()
    # with a 1-attempt budget, replica 0's victims fail instead of retrying;
    # replica 1's share completes normally
    statuses = {r.status for r in reqs}
    assert statuses <= {"done", "failed"}
    assert sum(r.status == "failed" for r in reqs) == router.stats()["failed"]
    assert sum(r.status == "done" for r in reqs) == sum(
        e.stats()["n_requests"] for e in engines)
    lost = [r for r in reqs if r.status == "pending"]
    assert not lost

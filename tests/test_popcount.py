"""Popcount-domain CIM MAC: bit identity of the AND+popcount datapath
(jnp reference, interpret-mode Pallas kernels, single-launch mega cascade)
against the packed-MXU oracle (``cim_matmul_packed``) and the unpacked
functional plane.  Nothing here is approximate — every assert is exact
int32 / uint32 equality."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import packing
from repro.kernels.cim_matmul import ops as cim_ops
from repro.kernels.cim_matmul_packed import ops as pk_ops
from repro.kernels.cim_popcount import ops as pop_ops
from repro.kernels.cim_popcount.kernel import VTH_NEVER_FIRE


def _operands(key, B, K, N, p_spike=0.4):
    s = jax.random.bernoulli(key, p_spike, (B, K))
    w = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (K, N)).astype(
        jnp.int8
    )
    return s, w, packing.pack_spikes(s), packing.pack_weight_planes(w)


# ----------------------------------------------------------------------- #
# MAC: ref and interpret kernel vs packed-MXU oracle + dense oracle
# ----------------------------------------------------------------------- #
# odd K (non-multiple of 32/128) and odd B exercise both padding terms of
# the identity 2*popcount(s & w) - popcount(s); kernel rows need
# N % min(128, N) == 0 (the packed ops' block contract).
MAC_SHAPES = [(8, 128, 128), (37, 100, 10), (64, 384, 256), (200, 70, 32),
              (5, 33, 64)]


@pytest.mark.parametrize("B,K,N", MAC_SHAPES)
def test_popcount_matmul_bit_exact(B, K, N):
    s, w, p, planes = _operands(jax.random.PRNGKey(B * 31 + K + N), B, K, N)
    oracle = pk_ops.cim_matmul_packed(p, w, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(oracle), np.asarray(cim_ops.cim_matmul_ref(s, w))
    )
    ref = pop_ops.cim_popcount_matmul(p, planes, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(oracle))
    out = pop_ops.cim_popcount_matmul(
        p, planes, use_kernel=True, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_popcount_matmul_property(seed):
    """Random B/K/N incl. single-word K and tiny N — ref path only (every
    shape is legal there), against the dense jnp oracle."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 64))
    K = int(rng.integers(1, 300))
    N = int(rng.integers(1, 96))
    s, w, p, planes = _operands(
        jax.random.PRNGKey(seed), B, K, N, float(rng.uniform(0.05, 0.95))
    )
    np.testing.assert_array_equal(
        np.asarray(pop_ops.cim_popcount_ref(p, planes)),
        np.asarray(cim_ops.cim_matmul_ref(s, w)),
    )


@pytest.mark.parametrize("pack_output", [True, False])
@pytest.mark.parametrize("B,K,N", [(8, 128, 128), (37, 100, 64), (21, 96, 32)])
def test_popcount_layer_fused_fire_bit_exact(B, K, N, pack_output):
    """Fused MAC + IF fire (+ re-pack) == the packed-MXU fused layer."""
    key = jax.random.PRNGKey(B + K * 3 + N)
    s, w, p, planes = _operands(key, B, K, N)
    vth = jax.random.randint(jax.random.fold_in(key, 2), (N,), -9, 9, jnp.int32)
    oracle = pk_ops.esam_layer_packed(
        p, w, vth, pack_output=pack_output, interpret=True
    )
    ref = pop_ops.esam_layer_popcount(
        p, planes, vth, pack_output=pack_output, use_kernel=False
    )
    out = pop_ops.esam_layer_popcount(
        p, planes, vth, pack_output=pack_output, use_kernel=True, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(oracle))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


# ----------------------------------------------------------------------- #
# mega cascade: one launch == per-tile packed cascade == functional chain
# ----------------------------------------------------------------------- #
CASCADE_TOPOS = [(768, 256, 256, 10), (300, 128, 96, 10), (100, 64, 32),
                 (256, 128)]


def _cascade_operands(key, topo):
    planes, vth = [], []
    for i in range(len(topo) - 1):
        k = jax.random.fold_in(key, i)
        w = jax.random.bernoulli(k, 0.5, (topo[i], topo[i + 1])).astype(jnp.int8)
        planes.append(packing.pack_weight_planes(w))
        vth.append(jax.random.randint(
            jax.random.fold_in(k, 1), (topo[i + 1],), -10, 10, jnp.int32))
    return planes, vth


def _oracle_cascade(packed, planes, vth, topo):
    """Per-tile packed-MXU cascade: 2 launches per hidden tile + readout."""
    p = packed
    fired = []
    for t in range(len(topo) - 2):
        w = packing.unpack_weight_planes(planes[t], topo[t])
        p = pk_ops.esam_layer_packed(p, w, vth[t], interpret=True)
        fired.append(p)
    w = packing.unpack_weight_planes(planes[-1], topo[-2])
    return pk_ops.cim_matmul_packed(p, w, interpret=True), fired


@pytest.mark.parametrize("topo", CASCADE_TOPOS)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_mega_cascade_bit_exact(topo, use_kernel):
    key = jax.random.PRNGKey(sum(topo))
    planes, vth = _cascade_operands(key, topo)
    s = jax.random.bernoulli(jax.random.fold_in(key, 7), 0.35, (37, topo[0]))
    p = packing.pack_spikes(s)
    want, want_fired = _oracle_cascade(p, planes, vth, topo)
    w_stack, vth_stack = pop_ops.stack_cascade_operands(planes, vth, topo)
    logits, fired = pop_ops.esam_cascade_popcount(
        p, w_stack, vth_stack, topology=topo,
        use_kernel=use_kernel, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(want))
    assert len(fired) == len(want_fired)
    for a, b in zip(fired, want_fired):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mega_cascade_batch_off_grid_and_single_row():
    """Batch padding rows are dead weight, never aliased into real rows."""
    topo = (128, 64, 10)
    key = jax.random.PRNGKey(3)
    planes, vth = _cascade_operands(key, topo)
    w_stack, vth_stack = pop_ops.stack_cascade_operands(planes, vth, topo)
    for B in (1, 5, 129):
        s = jax.random.bernoulli(jax.random.fold_in(key, B), 0.5, (B, 128))
        p = packing.pack_spikes(s)
        want, _ = _oracle_cascade(p, planes, vth, topo)
        logits, _ = pop_ops.esam_cascade_popcount(
            p, w_stack, vth_stack, topology=topo,
            use_kernel=True, interpret=True,
        )
        assert logits.shape == (B, 10)
        np.testing.assert_array_equal(np.asarray(logits), np.asarray(want))


def test_cascade_geometry_and_operand_stacking():
    """Padding contract: lane-aligned widths, real word counts, zero plane
    padding (AND-dead) and VTH_NEVER_FIRE threshold padding (silent)."""
    topo = (300, 128, 96, 10)
    g = pop_ops.cascade_geometry(topo)
    assert g["n_tiles"] == 3
    assert g["n_pad"] == (128, 128, 128)
    assert g["w_words"] == (10, 4, 3)
    assert g["n_max_pad"] == 128 and g["w_max"] == 10
    planes, vth = _cascade_operands(jax.random.PRNGKey(9), topo)
    w_stack, vth_stack = pop_ops.stack_cascade_operands(planes, vth, topo)
    assert w_stack.shape == (3, 128, 10) and w_stack.dtype == jnp.uint32
    assert vth_stack.shape == (2, 128)
    # real region round-trips; padding is zero / never-fire
    for t in range(3):
        n_t, kw_t = topo[t + 1], g["w_words"][t]
        np.testing.assert_array_equal(
            np.asarray(w_stack[t, :n_t, :kw_t]), np.asarray(planes[t]))
        assert not np.asarray(w_stack[t, n_t:, :]).any()
        assert not np.asarray(w_stack[t, :, kw_t:]).any()
    np.testing.assert_array_equal(np.asarray(vth_stack[0, :128]),
                                  np.asarray(vth[0]))
    np.testing.assert_array_equal(np.asarray(vth_stack[1, :96]),
                                  np.asarray(vth[1]))
    assert (np.asarray(vth_stack[1, 96:]) == VTH_NEVER_FIRE).all()


def test_vth_never_fire_is_unreachable():
    """No binary MAC can reach the padding threshold: |V| <= K << 2^30."""
    assert VTH_NEVER_FIRE > 2**20  # far beyond any supported fan-in


def test_popcount_dispatch_defaults_to_backend():
    """use_kernel=None routes to the jnp reference off-TPU (and the real
    kernel on TPU) — same contract as kernels/arbiter."""
    key = jax.random.PRNGKey(5)
    s, w, p, planes = _operands(key, 8, 128, 128)
    out = pop_ops.cim_popcount_matmul(p, planes)  # use_kernel=None
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(cim_ops.cim_matmul_ref(s, w)))

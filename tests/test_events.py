"""Event encoders (``repro.data.events``): determinism, coding semantics,
and wire-format packing — including widths that are not multiples of 32."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import packing
from repro.data import events


def test_rate_encode_deterministic_in_seed():
    frames = np.random.default_rng(0).random((5, 40))
    a = events.rate_encode(frames, 6, seed=3)
    b = events.rate_encode(frames, 6, seed=3)
    np.testing.assert_array_equal(a, b)
    c = events.rate_encode(frames, 6, seed=4)
    assert not np.array_equal(a, c)
    assert a.shape == (6, 5, 40) and a.dtype == np.uint8


def test_rate_encode_extremes_and_gain():
    frames = np.array([[0.0, 1.0, 2.0]])
    ev = events.rate_encode(frames, 8, seed=0)
    np.testing.assert_array_equal(ev[:, 0, 0], 0)     # p=0 never fires
    np.testing.assert_array_equal(ev[:, 0, 1:], 1)    # p>=1 clips, always fires
    np.testing.assert_array_equal(
        events.rate_encode(frames, 8, seed=0, gain=0.0), 0)


def test_latency_encode_single_spike_timing():
    frames = np.array([[1.0, 0.5, 0.0, 1e-4]])
    ev = events.latency_encode(frames, 5)
    counts = ev.sum(axis=0)[0]
    np.testing.assert_array_equal(counts, [1, 1, 0, 0])   # <=1 spike per wire
    assert ev[0, 0, 0] == 1                 # x=1 fires first...
    assert ev[2, 0, 1] == 1                 # ...x=0.5 mid-window
    # stronger intensity never fires later than weaker
    t = np.argmax(ev[:, 0, :2], axis=0)
    assert t[0] <= t[1]
    # deterministic, no RNG at all
    np.testing.assert_array_equal(ev, events.latency_encode(frames, 5))


def test_delta_encode_change_detection():
    seq = np.zeros((4, 1, 3), np.float64)
    seq[0] = [[0.5, 0.0, 0.05]]
    seq[1] = [[0.5, 0.3, 0.05]]             # pixel 1 changes
    seq[2] = [[0.1, 0.3, 0.05]]             # pixel 0 changes
    seq[3] = seq[2]                         # nothing changes
    ev = events.delta_encode(seq, threshold=0.1)
    np.testing.assert_array_equal(ev[0, 0], [1, 0, 0])   # vs implicit zero frame
    np.testing.assert_array_equal(ev[1, 0], [0, 1, 0])
    np.testing.assert_array_equal(ev[2, 0], [1, 0, 0])
    np.testing.assert_array_equal(ev[3, 0], [0, 0, 0])


def test_encode_dispatch_and_unknown_encoder():
    frames = np.random.default_rng(1).random((3, 20))
    np.testing.assert_array_equal(
        events.encode(frames, 4, encoder="rate", seed=7),
        events.rate_encode(frames, 4, seed=7))
    np.testing.assert_array_equal(
        events.encode(frames, 4, encoder="latency"),
        events.latency_encode(frames, 4))
    # delta on a static frame: one initial burst, then silence
    ev = events.encode(frames, 4, encoder="delta", threshold=0.5)
    np.testing.assert_array_equal(ev[0], frames >= 0.5)
    np.testing.assert_array_equal(ev[1:], 0)
    with pytest.raises(ValueError):
        events.encode(frames, 4, encoder="nope")


@pytest.mark.parametrize("n_in", [50, 96, 100, 768])
def test_pack_events_arbitrary_widths_roundtrip(n_in):
    """Packing event tensors whose n_in is not a multiple of 32 is exact:
    the tail bits are silent and unpack restores the stream bit for bit."""
    ev = events.rate_encode(
        np.random.default_rng(n_in).random((4, n_in)), 3, seed=0)
    packed = events.pack_events(ev)
    assert packed.shape == (3, 4, packing.packed_width(n_in))
    assert packed.dtype == np.uint32
    np.testing.assert_array_equal(
        packing.unpack_spikes_np(packed, n_in, np.uint8), ev)
    if n_in % 32:
        # tail padding is all-zero ("silent"), never spurious spikes
        tail_bits = packed[..., -1] >> (n_in % 32)
        np.testing.assert_array_equal(tail_bits, 0)


def test_encode_digit_events_deterministic_and_packed():
    ev1, y1 = events.encode_digit_events(6, 4, encoder="rate", seed=5)
    ev2, y2 = events.encode_digit_events(6, 4, encoder="rate", seed=5)
    np.testing.assert_array_equal(ev1, ev2)
    np.testing.assert_array_equal(y1, y2)
    assert ev1.shape == (4, 6, 768)
    evp, yp = events.encode_digit_events(6, 4, encoder="rate", seed=5,
                                         packed=True)
    np.testing.assert_array_equal(yp, y1)
    np.testing.assert_array_equal(evp, events.pack_events(ev1))

"""Arbiter: vectorized rank-selection vs the pure-Python priority-encoder oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.esam import arbiter as arb


@given(
    bits=st.lists(st.booleans(), min_size=1, max_size=256),
    ports=st.integers(min_value=1, max_value=8),
)
@settings(max_examples=200, deadline=None)
def test_grants_match_hardware_cascade(bits, ports):
    r = np.array(bits, dtype=bool)
    g_ref, rem_ref, v_ref = arb.priority_grants_oracle(r, ports)
    g, rem, v = arb.priority_grants(jnp.asarray(r), ports)
    np.testing.assert_array_equal(np.asarray(g), g_ref)
    np.testing.assert_array_equal(np.asarray(rem), rem_ref)
    np.testing.assert_array_equal(np.asarray(v), v_ref)


@given(bits=st.lists(st.booleans(), min_size=1, max_size=300))
@settings(max_examples=100, deadline=None)
def test_drain_is_exhaustive_and_in_priority_order(bits):
    """Repeated arbitration drains every request exactly once, leftmost-first."""
    r = jnp.array(bits, dtype=bool)
    ports = 4
    order = []
    for _ in range(len(bits) // ports + 2):
        g, r, v = arb.priority_grants(r, ports)
        for k in range(ports):
            if bool(v[k]):
                order.append(int(jnp.argmax(g[k])))
    expected = [i for i, b in enumerate(bits) if b]
    assert order == expected  # every spike served once, fixed-priority order
    assert not bool(jnp.any(r))


def test_validity_flags_block_unused_ports():
    r = jnp.array([False, True, False], dtype=bool)
    g, rem, v = arb.priority_grants(r, 4)
    assert v.tolist() == [True, False, False, False]
    assert not bool(jnp.any(g[1:]))


@pytest.mark.parametrize(
    "pending,ports,expect", [(0, 4, 0), (1, 4, 1), (4, 4, 1), (5, 4, 2), (128, 4, 32), (128, 1, 128)]
)
def test_drain_cycles(pending, ports, expect):
    assert int(arb.drain_cycles(jnp.asarray(pending), ports)) == expect


def test_layer_drain_is_max_over_row_groups():
    counts = jnp.array([3, 10, 0])
    assert int(arb.layer_drain_cycles(counts, 4)) == 3  # ceil(10/4)


def test_split_row_groups_rejects_ragged():
    with pytest.raises(ValueError):
        arb.split_row_groups(jnp.zeros((100,), bool))

"""Temporal event plane: LIF-step kernel, membrane-resident fused scan,
``mode="temporal"`` plans, temporal cost model, and event-stream serving.

The two pillars:
  * the fused scan is bit-identical to the naive per-step loop (the oracle
    ``temporal.temporal_forward_naive``), across leak / reset / refractory;
  * a T=1, zero-leak, zero-reset temporal plan is bit-identical to the
    static ``packed`` plan (property-tested — the acceptance criterion).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import packing
from repro.core.esam import cost_model as cm
from repro.core.esam.network import EsamNetwork
from repro.core.esam.temporal import (
    TemporalConfig,
    temporal_forward_naive,
)
from repro.kernels.lif_step.kernel import lif_step as lif_step_kernel
from repro.kernels.lif_step.ops import lif_step
from repro.kernels.lif_step.ref import lif_step_ref


def _rand_net(key, topo):
    bits, vth = [], []
    for i in range(len(topo) - 1):
        k = jax.random.fold_in(key, i)
        bits.append(jax.random.bernoulli(
            k, 0.5, (topo[i], topo[i + 1])).astype(jnp.int8))
        vth.append(jax.random.randint(
            jax.random.fold_in(k, 1), (topo[i + 1],), -10, 10, jnp.int32))
    off = jax.random.normal(jax.random.fold_in(key, 99), (topo[-1],))
    return EsamNetwork(weight_bits=bits, vth=vth, out_offset=off)


def _rand_events(key, n_steps, batch, n_in, rate=0.3):
    return np.asarray(
        jax.random.bernoulli(key, rate, (n_steps, batch, n_in))
    ).astype(np.uint8)


# ----------------------------------------------------------------------- #
# lif_step: Pallas kernel vs jnp reference
# ----------------------------------------------------------------------- #
@pytest.mark.parametrize("leak", [0.0, 0.25])
@pytest.mark.parametrize("reset", ["zero", "subtract"])
@pytest.mark.parametrize("refractory", [0, 2])
def test_lif_step_kernel_matches_ref(leak, reset, refractory):
    seed = {"zero": 0, "subtract": 100}[reset] + refractory
    key = jax.random.PRNGKey(seed)
    B, N = 8, 256
    vmem = jax.random.uniform(key, (B, N), jnp.float32, -20.0, 20.0)
    contrib = jax.random.randint(
        jax.random.fold_in(key, 1), (B, N), -16, 17, jnp.int32)
    vth = jax.random.randint(jax.random.fold_in(key, 2), (N,), -5, 6, jnp.int32)
    refrac = jax.random.randint(
        jax.random.fold_in(key, 3), (B, N), 0, refractory + 1, jnp.int32)
    kw = dict(leak=leak, reset=reset, refractory=refractory)
    s_r, v_r, r_r = lif_step_ref(vmem, contrib, vth, refrac, **kw)
    s_k, v_k, r_k = lif_step_kernel(
        vmem, contrib, vth, refrac, interpret=True, **kw)
    if leak == 0.0:
        # integer datapath: bit-identical on every backend
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_r))
    else:
        # nonzero leak: the compiler may FMA-contract mul+add (one rounding
        # vs the ref's two) — agreement is to float32 ulp, not bitwise
        np.testing.assert_allclose(
            np.asarray(v_k), np.asarray(v_r), rtol=1e-6, atol=1e-4)
        agree = np.asarray(s_k) == np.asarray(s_r)
        assert agree.mean() > 0.99          # flips only at exact-threshold ulp
        np.testing.assert_array_equal(
            np.asarray(r_k)[agree], np.asarray(r_r)[agree])
    if leak == 0.0:
        np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_r))
    # the ops dispatch point returns one of the two paths (ref off-TPU)
    s_d, v_d, r_d = lif_step(vmem, contrib, vth, refrac,
                             interpret=True, **kw)
    np.testing.assert_array_equal(np.asarray(s_d), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(v_d), np.asarray(v_r))
    np.testing.assert_array_equal(np.asarray(r_d), np.asarray(r_r))


def test_lif_step_semantics_hand_example():
    """vth=2: contrib 3 fires (zero->0, subtract->1); a refractory neuron
    integrates but cannot fire until its counter drains."""
    vmem = jnp.zeros((1, 2), jnp.float32)
    vth = jnp.array([2, 2], jnp.int32)
    contrib = jnp.array([[3, 3]], jnp.int32)
    refrac = jnp.array([[0, 2]], jnp.int32)     # neuron 1 is refractory
    s, v, r = lif_step_ref(vmem, contrib, vth, refrac, reset="zero",
                           refractory=2)
    np.testing.assert_array_equal(np.asarray(s), [[1, 0]])
    np.testing.assert_array_equal(np.asarray(v), [[0.0, 3.0]])  # no reset w/o fire
    np.testing.assert_array_equal(np.asarray(r), [[2, 1]])      # reload / decay
    s2, v2, _ = lif_step_ref(vmem, contrib, vth, jnp.zeros_like(refrac),
                             reset="subtract")
    np.testing.assert_array_equal(np.asarray(s2), [[1, 1]])
    np.testing.assert_array_equal(np.asarray(v2), [[1.0, 1.0]])  # 3 - vth


def test_lif_step_leak_is_exact_identity_at_zero():
    v = jnp.full((1, 8), 7.0, jnp.float32)
    z = jnp.zeros((1, 8), jnp.int32)
    _, v1, _ = lif_step_ref(v, z, jnp.full((8,), 99, jnp.int32), z, leak=0.0)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v))
    _, v2, _ = lif_step_ref(v, z, jnp.full((8,), 99, jnp.int32), z, leak=0.5)
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v) * 0.5)


# ----------------------------------------------------------------------- #
# fused scan vs the naive per-step loop (the oracle)
# ----------------------------------------------------------------------- #
@pytest.mark.parametrize("cfg", [
    TemporalConfig(n_steps=6),
    TemporalConfig(n_steps=5, leak=0.25),
    TemporalConfig(n_steps=4, reset="subtract"),
    TemporalConfig(n_steps=7, leak=0.125, reset="subtract", refractory=2),
])
def test_fused_scan_matches_naive_loop(cfg):
    topo = (256, 128, 128, 10)
    net = _rand_net(jax.random.PRNGKey(cfg.n_steps), topo)
    ev = _rand_events(jax.random.PRNGKey(77 + cfg.n_steps), cfg.n_steps, 9,
                      topo[0])
    got = net.plan(mode="temporal", temporal=cfg, interpret=True)(ev).logits
    want = temporal_forward_naive(net, ev, cfg)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_temporal_accepts_wire_format_and_leading_shapes():
    topo = (256, 128, 10)
    cfg = TemporalConfig(n_steps=3, leak=0.5)
    net = _rand_net(jax.random.PRNGKey(3), topo)
    ev = _rand_events(jax.random.PRNGKey(4), 3, 5, topo[0])
    plan = net.plan(mode="temporal", temporal=cfg, interpret=True)
    base = np.asarray(plan(ev).logits)
    # packed wire input
    np.testing.assert_array_equal(
        np.asarray(plan(packing.pack_spikes_np(ev)).logits), base)
    # single sample [T, n_in] -> unbatched logits
    one = np.asarray(plan(ev[:, 2]).logits)
    assert one.shape == base.shape[1:]
    np.testing.assert_array_equal(one, base[2])
    # wrong T is rejected
    with pytest.raises(ValueError):
        plan(ev[:2])


def test_temporal_non_32_multiple_input_width():
    """n_in that is not a multiple of 32 packs with silent tail bits and
    matches the naive dense loop exactly (hidden widths stay 32-aligned)."""
    topo = (100, 64, 10)
    cfg = TemporalConfig(n_steps=4, leak=0.25)
    net = _rand_net(jax.random.PRNGKey(9), topo)
    ev = _rand_events(jax.random.PRNGKey(10), 4, 6, 100, rate=0.5)
    got = net.plan(mode="temporal", temporal=cfg, interpret=True)(ev).logits
    np.testing.assert_array_equal(
        np.asarray(got), temporal_forward_naive(net, ev, cfg))


# ----------------------------------------------------------------------- #
# T=1 identity with the static packed plane (acceptance criterion)
# ----------------------------------------------------------------------- #
@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_temporal_t1_bit_identical_to_packed(seed):
    """mode='temporal' with T=1, zero leak, zero reset == mode='packed',
    bit for bit, on random networks and spike batches."""
    rng = np.random.default_rng(seed)
    topo = [(128, 64, 10), (256, 128, 128, 10), (96, 32, 10)][seed % 3]
    net = _rand_net(jax.random.PRNGKey(seed), topo)
    batch = int(rng.integers(1, 9))
    ev = _rand_events(jax.random.PRNGKey(seed + 1), 1, batch, topo[0],
                      rate=float(rng.uniform(0.1, 0.9)))
    cfg = TemporalConfig(n_steps=1, leak=0.0, reset="zero", refractory=0)
    got = net.plan(mode="temporal", temporal=cfg, interpret=True)(ev).logits
    want = net.plan(mode="packed", interpret=True)(ev[0]).logits
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_temporal_plan_is_cached_per_spec():
    net = _rand_net(jax.random.PRNGKey(21), (128, 64, 10))
    cfg = TemporalConfig(n_steps=4)
    assert (net.plan(mode="temporal", temporal=cfg)
            is net.plan(mode="temporal", temporal=cfg))
    assert (net.plan(mode="temporal", temporal=cfg)
            is not net.plan(mode="temporal",
                            temporal=dataclasses.replace(cfg, n_steps=8)))
    with pytest.raises(AssertionError):
        net.plan(mode="temporal")            # needs a TemporalConfig
    with pytest.raises(AssertionError):
        net.plan(mode="packed", temporal=cfg)  # only temporal mode takes one


# ----------------------------------------------------------------------- #
# telemetry: per-step measured activity and the temporal cost model
# ----------------------------------------------------------------------- #
def test_temporal_telemetry_matches_per_step_popcounts():
    topo = (256, 128, 10)
    cfg = TemporalConfig(n_steps=5, leak=0.25)
    net = _rand_net(jax.random.PRNGKey(31), topo)
    ev = _rand_events(jax.random.PRNGKey(32), 5, 7, topo[0])
    res = net.plan(mode="temporal", temporal=cfg, collect=True,
                   telemetry=True, interpret=True)(ev)
    assert len(res.planes) == len(res.loads) == len(topo) - 1
    for pl, ld in zip(res.planes, res.loads):
        assert pl.shape[:2] == (7, 5) and ld.shape[:2] == (7, 5)
        want = np.asarray(packing.group_popcount(jnp.asarray(pl)))
        np.testing.assert_array_equal(np.asarray(ld), want)
    # tile 0's plane is the input stream itself (batch-first)
    np.testing.assert_array_equal(
        np.asarray(res.planes[0]),
        packing.pack_spikes_np(ev).swapaxes(0, 1))


def test_temporal_request_stats_device_matches_numpy():
    rng = np.random.default_rng(0)
    topo = (768, 256, 256, 10)
    loads = [rng.integers(0, 129, size=(6, 9, -(-topo[t] // 128)))
             .astype(np.int32) for t in range(len(topo) - 1)]
    for p in (0, 2, 4):
        dev = cm.temporal_request_stats_device(
            topo, [jnp.asarray(l) for l in loads], p)
        ref = cm.temporal_request_stats(topo, loads, p)
        assert dev["n_steps"] == ref["n_steps"] == 9
        np.testing.assert_array_equal(
            np.asarray(dev["cycles"]), ref["cycles"])
        np.testing.assert_array_equal(
            np.asarray(dev["cycles_per_tile"]), ref["cycles_per_tile"])
        np.testing.assert_allclose(
            np.asarray(dev["latency_ns"]), ref["latency_ns"], rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(dev["energy_pj"]), ref["energy_pj"], rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(dev["energy_pj_per_step"]),
            ref["energy_pj_per_step"], rtol=1e-5)


def test_temporal_stream_cost_is_sum_of_per_step_costs():
    """A T-step stream costs exactly the sum of T static requests run on its
    per-step activity — the temporal model adds no hidden constants."""
    rng = np.random.default_rng(1)
    topo = (256, 128, 10)
    loads = [rng.integers(0, 129, size=(3, 4, -(-topo[t] // 128)))
             .astype(np.float64) for t in range(len(topo) - 1)]
    got = cm.temporal_request_stats(topo, loads, 4)
    want = sum(
        cm.request_stats(topo, [l[:, t] for l in loads], 4).energy_pj
        for t in range(4))
    np.testing.assert_allclose(got["energy_pj"], want, rtol=1e-12)


# ----------------------------------------------------------------------- #
# event-stream serving
# ----------------------------------------------------------------------- #
def test_spike_engine_serves_event_streams_mixed_T():
    from repro.serve.engine import EventRequest, SpikeEngine, SpikeRequest

    topo = (256, 128, 10)
    net = _rand_net(jax.random.PRNGKey(41), topo)
    cfg = TemporalConfig(n_steps=1, leak=0.25, reset="subtract")
    eng = SpikeEngine(net, max_batch=4, min_bucket=2, interpret=True,
                      telemetry=True, read_ports=3, temporal=cfg)
    ev8 = _rand_events(jax.random.PRNGKey(42), 8, 5, topo[0])
    ev3 = _rand_events(jax.random.PRNGKey(43), 3, 3, topo[0])
    sp = _rand_events(jax.random.PRNGKey(44), 1, 2, topo[0])[0]

    e8 = [EventRequest(events=ev8[:, i]) for i in range(5)]
    # wire-format submissions work too
    e3 = [EventRequest(events=packing.pack_spikes_np(ev3[:, i]))
          for i in range(3)]
    s = [SpikeRequest(spikes=sp[i]) for i in range(2)]
    eng.submit_events(e8[:2])
    eng.submit(e3[0])                     # submit() routes EventRequests too
    out = eng.serve(s + e8[2:] + e3[1:])
    assert len(out) == 2 + 3 + 2
    assert not eng._pending and not eng._pending_events and not eng._inflight

    want8 = temporal_forward_naive(
        net, ev8, dataclasses.replace(cfg, n_steps=8))
    want3 = temporal_forward_naive(
        net, ev3, dataclasses.replace(cfg, n_steps=3))
    for i, r in enumerate(e8):
        np.testing.assert_array_equal(r.logits, want8[i])
        assert r.label == int(want8[i].argmax())
    for i, r in enumerate(e3):
        np.testing.assert_array_equal(r.logits, want3[i])

    # telemetry: whole-stream device costs agree with the numpy model
    res = net.plan(mode="temporal",
                   temporal=dataclasses.replace(cfg, n_steps=8),
                   telemetry=True, interpret=True)(ev8)
    rs = cm.temporal_request_stats(
        net.topology, [np.asarray(l) for l in res.loads], 3)
    for i, r in enumerate(e8):
        assert r.cycles == int(rs["cycles"][i])
        assert r.latency_ns == pytest.approx(float(rs["latency_ns"][i]))
        assert r.energy_pj == pytest.approx(float(rs["energy_pj"][i]),
                                            rel=1e-5)
        assert r.energy_pj_per_step == pytest.approx(r.energy_pj / 8,
                                                     rel=1e-5)

    st_ = eng.stats()
    assert st_["n_requests"] == 2 and st_["n_event_requests"] == 8
    assert st_["timesteps_total"] == 5 * 8 + 3 * 3
    want_total = float(rs["energy_pj"].sum()) + sum(
        r.energy_pj for r in e3)
    assert st_["event_energy_pj_mean"] * 8 == pytest.approx(want_total,
                                                            rel=1e-5)
    assert st_["energy_pj_per_timestep"] == pytest.approx(
        want_total / st_["timesteps_total"], rel=1e-5)


def test_spike_engine_event_stats_empty():
    from repro.serve.engine import SpikeEngine

    net = _rand_net(jax.random.PRNGKey(51), (128, 64, 10))
    st_ = SpikeEngine(net, interpret=True, telemetry=True).stats()
    assert st_["n_event_requests"] == 0 and st_["timesteps_total"] == 0
    assert st_["energy_pj_per_timestep"] == 0.0
    assert st_["event_energy_pj_mean"] == 0.0

"""Extended training-stack invariants: microbatch equivalence, pure-DP rules,
conversion property sweep."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import base as cb
from repro.models import lm, params as pm
from repro.train import loop as train_loop
from repro.train.optimizer import AdamState


def test_microbatched_grads_match_full_batch():
    """mb=4 grad accumulation == single-batch gradients (fp32 accumulators)."""
    cfg1 = cb.smoke("llama3.2-1b")
    cfg4 = dataclasses.replace(cfg1, microbatches=4)
    tcfg = train_loop.TrainConfig()
    key = jax.random.PRNGKey(0)
    state = train_loop.init_state(cfg1, tcfg, key)
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (8, 16), 0, cfg1.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    s1, m1 = jax.jit(train_loop.make_train_step(cfg1, tcfg))(state, batch)
    state2 = train_loop.init_state(cfg1, tcfg, key)
    s4, m4 = jax.jit(train_loop.make_train_step(cfg4, tcfg))(state2, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-2
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-2)


def test_pure_dp_rules_replicate_weights():
    from repro.distributed import sharding as shd

    from repro import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    rules = shd.make_rules(mesh, n_heads=4, n_kv_heads=4, d_ff=256, d_model=64,
                           vocab_size=512, pure_dp=True)
    assert rules.rules["mlp"] is None and rules.rules["heads"] is None
    assert rules.rules["vocab"] is None
    assert "model" in tuple(rules.rules["batch"])  # batch over every axis


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_conversion_exact_for_random_bnns(seed):
    """Property: BNN->SNN conversion is prediction-exact for ANY parameters,
    not just trained ones (the [15] derivation is data-independent)."""
    from repro.core.esam import bnn, conversion

    key = jax.random.PRNGKey(seed)
    topo = (128, 64, 32, 10)
    params = bnn.init_params(key, topo)
    # randomize biases too (init is zeros)
    params = [
        {"w": p["w"], "b": jax.random.normal(jax.random.fold_in(key, i), p["b"].shape)}
        for i, p in enumerate(params)
    ]
    x = jax.random.bernoulli(jax.random.fold_in(key, 99), 0.4, (64, 128)).astype(jnp.float32)
    net = conversion.bnn_to_snn(params)
    bnn_pred = bnn.forward(params, x).argmax(-1)
    snn_pred = net.forward(x.astype(bool)).argmax(-1)
    np.testing.assert_array_equal(np.asarray(bnn_pred), np.asarray(snn_pred))

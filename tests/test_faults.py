"""Fault-injection & mitigation plane.

Covers the robustness tentpole: seeded mask determinism, ``faults=None`` /
zero-rate bit-identity against the clean datapath in all five plan modes,
fault application equivalence across modes (faulted weights are just
different weights, so every mode-identity property survives injection),
read-disturb port/V_prech scaling, column remapping onto spares, the
online-learning repair driver, and fault-aware serving (tile health,
traffic draining, degraded-mesh replan, dispatch-round watchdog).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.esam import cost_model as cm
from repro.core.esam import faults as faults_mod
from repro.core.esam.faults import FaultModel
from repro.core.esam.network import EsamNetwork
from repro.core.esam.temporal import TemporalConfig


def _rand_net(key, topo, vth_lo=-5, vth_hi=5):
    n_tiles = len(topo) - 1
    bits = [
        jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                             (topo[i], topo[i + 1])).astype(jnp.int8)
        for i in range(n_tiles)
    ]
    vth = [
        jax.random.randint(jax.random.fold_in(key, 100 + i),
                           (topo[i + 1],), vth_lo, vth_hi, jnp.int32)
        for i in range(n_tiles)
    ]
    off = jax.random.normal(jax.random.fold_in(key, 999), (topo[-1],))
    return EsamNetwork(weight_bits=bits, vth=vth, out_offset=off)


TOPO = (256, 128, 128, 10)          # 128-aligned: every mode can run it


def _spikes(key, n=9, width=TOPO[0]):
    return jax.random.bernoulli(key, 0.35, (n, width))


# ----------------------------------------------------------------------- #
# mask generation: determinism, disjointness, scaling
# ----------------------------------------------------------------------- #
def test_masks_deterministic_under_seed():
    fm = FaultModel(seed=11, stuck0_rate=0.1, stuck1_rate=0.05,
                    vth_sigma=1.5, read_disturb=0.02)
    m1 = fm.build_masks(TOPO, (1, 4))
    m2 = fm.build_masks(TOPO, (1, 4))
    for a, b in zip(jax.tree.leaves(m1), jax.tree.leaves(m2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    m3 = dataclasses.replace(fm, seed=12).build_masks(TOPO, (1, 4))
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(m1), jax.tree.leaves(m3))
    )


def test_stuck_masks_disjoint_and_rates_plausible():
    fm = FaultModel(seed=0, stuck0_rate=0.2, stuck1_rate=0.2)
    m = fm.build_masks(TOPO)
    for s0, s1 in zip(m["stuck0"], m["stuck1"]):
        assert not bool(jnp.any(s0 & s1))
        rate0 = float(jnp.mean(s0))
        rate1 = float(jnp.mean(s1))
        assert abs(rate0 - 0.2) < 0.05 and abs(rate1 - 0.2) < 0.05


def test_upset_rate_scales_with_ports_and_vprech():
    fm = FaultModel(seed=0, read_disturb=0.01)
    assert fm.upset_rate(4) == pytest.approx(4 * fm.upset_rate(1))
    hot = dataclasses.replace(fm, v_prech=2 * cm.VPRECH)
    assert hot.upset_rate(1) == pytest.approx(4 * fm.upset_rate(1))
    assert FaultModel(read_disturb=1.0).upset_rate(4) == 1.0  # clipped
    # nested draws: the 1-port upset set is a subset of the 4-port set
    m = fm.build_masks(TOPO, (1, 4))
    for u1, u4 in zip(m["upset"][1], m["upset"][4]):
        assert bool(jnp.all(~u1 | u4))
        assert int(u4.sum()) > int(u1.sum())


# ----------------------------------------------------------------------- #
# zero-fault bit-identity: the acceptance-criteria property, all 5 modes
# ----------------------------------------------------------------------- #
@pytest.mark.parametrize("mode", ["functional", "packed", "prefix", "cycle",
                                  "temporal"])
def test_zero_rate_faults_bit_identical_to_clean(mode):
    """A FaultModel with every rate at 0 runs the full mask datapath and
    still lands bit-identical to the ``faults=None`` clean plan."""
    net = _rand_net(jax.random.PRNGKey(1), TOPO)
    s = _spikes(jax.random.PRNGKey(2))
    kw = {}
    if mode in ("packed", "prefix"):
        kw["interpret"] = True
    if mode == "temporal":
        kw.update(temporal=TemporalConfig(n_steps=2, leak=0.25),
                  interpret=True)
        s = jnp.stack([s, s[::-1]])
    fm0 = FaultModel(seed=9)
    assert not fm0.any_faults
    a = net.plan(mode=mode, telemetry=True, faults=fm0, **kw)(s)
    b = net.plan(mode=mode, telemetry=True, **kw)(s)
    for name in ("logits", "prefix"):
        va, vb = getattr(a, name), getattr(b, name)
        assert (va is None) == (vb is None)
        if va is not None:
            np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
    for la, lb in zip(a.loads, b.loads):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ----------------------------------------------------------------------- #
# faulted datapath: mode equivalence + semantics
# ----------------------------------------------------------------------- #
def test_faulted_modes_agree_and_differ_from_clean():
    """Faulted weights are just different weights: functional == packed ==
    cycle == temporal(T=1) under the same FaultModel, and != the clean
    logits at a non-trivial rate."""
    net = _rand_net(jax.random.PRNGKey(3), TOPO)
    s = _spikes(jax.random.PRNGKey(4))
    fm = FaultModel(seed=5, stuck0_rate=0.08, stuck1_rate=0.06,
                    vth_sigma=1.0, read_disturb=0.01)
    clean = np.asarray(net.plan(mode="functional")(s).logits)
    f_fun = np.asarray(net.plan(mode="functional", faults=fm)(s).logits)
    f_pk = np.asarray(
        net.plan(mode="packed", faults=fm, interpret=True)(s).logits)
    f_cy = np.asarray(net.plan(mode="cycle", faults=fm)(s).logits)
    f_tmp = np.asarray(net.plan(
        mode="temporal", faults=fm, interpret=True,
        temporal=TemporalConfig(n_steps=1))(s[None]).logits)
    np.testing.assert_array_equal(f_fun, f_pk)
    np.testing.assert_array_equal(f_fun, f_cy)
    np.testing.assert_array_equal(f_fun, f_tmp)
    assert not np.array_equal(f_fun, clean)


def test_stuck_at_semantics_extreme_rates():
    """stuck1_rate=1 reads every cell as '1' (+1 weights) regardless of the
    stored bits; stuck0_rate=1 reads all '0' (-1 weights)."""
    net = _rand_net(jax.random.PRNGKey(6), (64, 32, 10))
    s = _spikes(jax.random.PRNGKey(7), n=5, width=64)
    for rate_field, bit in (("stuck1_rate", 1), ("stuck0_rate", 0)):
        fm = FaultModel(seed=0, **{rate_field: 1.0})
        forced = EsamNetwork(
            weight_bits=[jnp.full_like(w, bit) for w in net.weight_bits],
            vth=net.vth, out_offset=net.out_offset)
        got = net.plan(mode="functional", faults=fm)(s).logits
        want = forced.plan(mode="functional")(s).logits
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cycle_sweep_faults_scale_with_port_option():
    """In the one-executable port sweep, each cell option reads through its
    own port count, so read-disturb injects more upsets at 4R than 1R."""
    net = _rand_net(jax.random.PRNGKey(8), TOPO)
    s = _spikes(jax.random.PRNGKey(9))
    fm = FaultModel(seed=1, read_disturb=0.02)
    sweep = net.plan(mode="cycle", read_ports=(0, 1, 4), faults=fm)(s).sweep
    # 0 and 1 share the single effective port -> identical logits
    np.testing.assert_array_equal(np.asarray(sweep[0]["logits"]),
                                  np.asarray(sweep[1]["logits"]))
    assert not np.array_equal(np.asarray(sweep[1]["logits"]),
                              np.asarray(sweep[4]["logits"]))


# ----------------------------------------------------------------------- #
# mitigation 1: column remapping onto spares
# ----------------------------------------------------------------------- #
def test_remap_full_budget_restores_clean_datapath():
    net = _rand_net(jax.random.PRNGKey(10), TOPO)
    s = _spikes(jax.random.PRNGKey(11))
    clean = np.asarray(net.plan(mode="functional")(s).logits)
    fm = FaultModel(seed=13, dead_col_rate=0.15)
    faulted = np.asarray(net.plan(mode="functional", faults=fm)(s).logits)
    assert not np.array_equal(faulted, clean)
    # enough spares to absorb every dead column -> bit-identical to clean
    fm_remap = dataclasses.replace(fm, spare_cols=64)
    remapped = np.asarray(
        net.plan(mode="functional", faults=fm_remap)(s).logits)
    np.testing.assert_array_equal(remapped, clean)


def test_remap_partial_budget_clears_worst_columns():
    fm = FaultModel(seed=3, dead_col_rate=0.2, stuck0_rate=0.01)
    k = 4
    fm_remap = dataclasses.replace(fm, spare_cols=k)
    m0 = fm.build_masks(TOPO)
    m1 = fm_remap.build_masks(TOPO)
    for s0_a, s0_b in zip(m0["stuck0"], m1["stuck0"]):
        col_a = np.asarray(s0_a.sum(0))
        col_b = np.asarray(s0_b.sum(0))
        cleared = np.nonzero((col_a > 0) & (col_b == 0))[0]
        assert len(cleared) == k                     # exactly the budget
        # the cleared columns were the worst-scoring ones
        assert col_a[cleared].min() >= np.sort(col_a)[-k:].min() or (
            col_a[cleared].min() >= np.partition(col_a, -k)[-k])
    assert sum(faults_mod.faulty_cells(m1)) < sum(faults_mod.faulty_cells(m0))


def test_spare_column_area_overhead():
    a0 = cm.spare_column_area_um2(cm.PAPER_TOPOLOGY, 0, 4)
    a8 = cm.spare_column_area_um2(cm.PAPER_TOPOLOGY, 8, 4)
    a16 = cm.spare_column_area_um2(cm.PAPER_TOPOLOGY, 16, 4)
    assert a0 == 0.0 and a16 == pytest.approx(2 * a8)
    # spares pay the chosen cell option's area ratio
    assert cm.spare_column_area_um2(cm.PAPER_TOPOLOGY, 8, 0) < a8


# ----------------------------------------------------------------------- #
# mitigation 2: online-learning repair around dead columns
# ----------------------------------------------------------------------- #
def test_stdp_repair_recovers_accuracy_around_dead_columns():
    from repro.train import online as online_train

    key = jax.random.PRNGKey(0)
    # 10 prototype spike patterns + flip noise: a cleanly separable task so
    # the recovery margin is large and deterministic
    protos = jax.random.bernoulli(jax.random.fold_in(key, 50), 0.35,
                                  (10, 768))

    def make_split(k, n):
        y = jax.random.randint(jax.random.fold_in(k, 0), (n,), 0, 10)
        flips = jax.random.bernoulli(jax.random.fold_in(k, 1), 0.03,
                                     (n, 768))
        return jnp.logical_xor(protos[y], flips), y

    x_tr, y_tr = make_split(jax.random.fold_in(key, 60), 360)
    x_te, y_te = make_split(jax.random.fold_in(key, 61), 120)
    topo = (768, 64, 10)
    bits = [jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                                 (topo[i], topo[i + 1])).astype(jnp.int8)
            for i in range(2)]
    vth = [jax.random.randint(jax.random.fold_in(key, 5), (64,), 0, 12,
                              jnp.int32),
           jnp.full((10,), 2 ** 30, jnp.int32)]
    net = EsamNetwork(weight_bits=bits, vth=vth,
                      out_offset=jnp.zeros((10,)))

    # deploy with 30% of the hidden columns dead, readout unadapted
    fm = FaultModel(seed=7, dead_col_rate=0.3)
    acc_fault = float((jnp.argmax(
        net.plan(mode="functional", faults=fm)(x_te).logits, -1)
        == y_te).mean())
    res = online_train.train_online(
        net, x_tr, y_tr, epochs=3, interpret=True, shuffle=True,
        eval_spikes=x_te, eval_labels=y_te, faults=fm)
    # STDP re-learns the readout around the dead columns: accuracy
    # recovered per epoch, far above the unrepaired faulted baseline
    assert res.accuracy[-1] > acc_fault + 0.3
    assert res.accuracy[-1] > 0.5
    # ...and the reported accuracy is exactly what the deployed faulted
    # plan achieves on the programmed bits (clamp consistency)
    deployed = float((jnp.argmax(
        res.network.plan(mode="functional", faults=fm)(x_te).logits, -1)
        == y_te).mean())
    assert deployed == pytest.approx(res.accuracy[-1], abs=1e-6)


def test_clamp_readout_writes_to_stuck_cells_do_not_take():
    fm = FaultModel(seed=2, stuck0_rate=0.3, stuck1_rate=0.2)
    masks = fm.build_masks((64, 32, 10))
    bits_t = jnp.ones((10, 32), jnp.int8)        # try to program all-1
    eff = faults_mod.clamp_readout_t(bits_t, masks, 4)
    s0 = np.asarray(masks["stuck0"][-1].T)
    assert bool(jnp.all(jnp.where(s0, eff == 0, eff == 1)))


# ----------------------------------------------------------------------- #
# mitigation 3: fault-aware serving
# ----------------------------------------------------------------------- #
def _serve_net(key):
    # vth 0: ~half the hidden neurons fire, near the calibration profile
    net = _rand_net(key, (128, 128, 10), vth_lo=0, vth_hi=1)
    return net


def test_engine_health_scores_degraded_tiles():
    from repro.serve.engine import SpikeEngine, SpikeRequest

    net = _serve_net(jax.random.PRNGKey(20))
    s = np.asarray(_spikes(jax.random.PRNGKey(21), n=16, width=128),
                   dtype=np.uint8)
    # stuck-at-1 floods the hidden tile with spikes -> load inflation on the
    # downstream tile -> measured cycles deviate from calibration
    fm = FaultModel(seed=4, stuck1_rate=0.7)
    clean = SpikeEngine(net, interpret=True, telemetry=True, max_batch=16)
    bad = SpikeEngine(net, interpret=True, telemetry=True, max_batch=16,
                      faults=fm)
    clean.serve([SpikeRequest(spikes=row) for row in s])
    bad.serve([SpikeRequest(spikes=row) for row in s])
    assert clean.health() > bad.health()
    assert bad.health() < 0.5
    st = bad.stats()
    assert st["faulted"] and st["degraded"]
    assert st["tile_health"] == [float(h) for h in bad.tile_health()]
    # before any traffic, health is the well-defined optimistic 1.0
    idle = SpikeEngine(net, interpret=True, telemetry=True)
    assert idle.health() == 1.0


def test_router_drains_traffic_around_degraded_engine():
    from repro.serve.engine import FaultAwareRouter, SpikeEngine, SpikeRequest

    net = _serve_net(jax.random.PRNGKey(22))
    s = np.asarray(_spikes(jax.random.PRNGKey(23), n=12, width=128),
                   dtype=np.uint8)
    clean = SpikeEngine(net, interpret=True, telemetry=True, max_batch=16)
    bad = SpikeEngine(net, interpret=True, telemetry=True, max_batch=16,
                      faults=FaultModel(seed=4, stuck1_rate=0.7))
    # calibration traffic so health reflects the fault
    clean.serve([SpikeRequest(spikes=row) for row in s])
    bad.serve([SpikeRequest(spikes=row) for row in s])
    thr = (clean.health() + bad.health()) / 2
    router = FaultAwareRouter([clean, bad], health_threshold=thr)
    out = router.serve([SpikeRequest(spikes=row) for row in s])
    assert router.routed == [len(s), 0]
    assert all(r.logits is not None for r in out)
    rst = router.stats()
    assert rst["engines"][1]["degraded"] and not rst["engines"][0]["degraded"]
    # all replicas degraded -> falls back to the healthiest, never stalls
    router_all_bad = FaultAwareRouter([bad], health_threshold=0.99)
    out2 = router_all_bad.serve([SpikeRequest(spikes=s[0])])
    assert out2[0].logits is not None
    assert router_all_bad.routed == [1]


def test_engine_watchdog_flags_slow_rounds_in_stats():
    from repro.serve.engine import SpikeEngine, SpikeRequest
    from repro.train.fault_tolerance import StragglerWatchdog

    net = _serve_net(jax.random.PRNGKey(24))
    s = np.asarray(_spikes(jax.random.PRNGKey(25), n=24, width=128),
                   dtype=np.uint8)
    # threshold 0 => every post-warmup round is a straggler (deterministic)
    eng = SpikeEngine(net, interpret=True, max_batch=8,
                      watchdog=StragglerWatchdog(threshold=0.0,
                                                 warmup_steps=1))
    eng.serve([SpikeRequest(spikes=row) for row in s])
    st = eng.stats()
    assert st["dispatch_rounds"] == 3
    assert st["straggler_rounds"] == 2                 # rounds after warmup


def test_engine_replan_degraded_serves_and_reports_spares():
    from repro.serve.engine import SpikeEngine, SpikeRequest

    net = _serve_net(jax.random.PRNGKey(26))
    s = np.asarray(_spikes(jax.random.PRNGKey(27), n=6, width=128),
                   dtype=np.uint8)
    eng = SpikeEngine(net, interpret=True, telemetry=True, max_batch=8)
    before = eng.serve([SpikeRequest(spikes=row) for row in s])
    plan = eng.replan_degraded(1)      # single surviving device
    assert plan == ((1, 1), ("data", "model")) and plan.dropped_chips == 0
    after = eng.serve([SpikeRequest(spikes=row) for row in s])
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a.logits, b.logits)
    assert eng.stats()["n_requests"] == 2 * len(s)
